"""Admission control for the serving tier: token-bucket rate limiting, a
bounded FIFO with load shedding, and a latency circuit breaker.

Every primitive takes time as an explicit ``now`` argument (any monotone
float clock); nothing here reads a wall clock or sleeps.  The service
drives these with a *virtual* clock measured in decode steps, which is
what makes the admission property tests (``tests/test_admission.py``)
and the load benches (``benchmarks/bench_serve.py``) deterministic.

The contract each piece keeps (hypothesis-checked):

  * :class:`TokenBucket` — over any window ``(t0, t1]`` it admits at most
    ``burst + rate * (t1 - t0)`` unit-cost requests.
  * :class:`BoundedQueue` — FIFO for admitted items, and
    ``admitted + shed == offered`` at all times.
  * :class:`CircuitBreaker` — trips only after ``breach_window``
    *consecutive* SLO breaches, always half-opens after ``cooldown``,
    and can never deadlock refusing (lost probes re-arm after another
    cooldown).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    """One decode request: a prompt in, up to ``max_new`` greedy ids out.

    ``arrival`` / ``admitted_at`` / ``finished_at`` are service-clock
    stamps (decode steps under the virtual clock); ``tokens`` accumulates
    the generated ids, including the EOS id when one stops the request.
    """
    rid: int
    prompt: np.ndarray
    max_new: int
    eos_id: Optional[int] = None
    arrival: float = 0.0
    admitted_at: Optional[float] = None
    finished_at: Optional[float] = None
    tokens: List[int] = dataclasses.field(default_factory=list)

    @property
    def latency(self) -> Optional[float]:
        """Arrival-to-finish latency in clock units (None while open)."""
        if self.finished_at is None:
            return None
        return self.finished_at - self.arrival

    def done(self) -> bool:
        """True once EOS was emitted or ``max_new`` ids were generated."""
        if len(self.tokens) >= self.max_new:
            return True
        return bool(self.tokens) and self.eos_id is not None \
            and self.tokens[-1] == self.eos_id


class TokenBucket:
    """Classic token bucket: capacity ``burst``, refill ``rate`` per unit
    time, one token per unit-cost admit.

    The invariant the property tests pin: the number of successful
    ``admit(now)`` calls with times inside any window ``(t0, t1]`` is at
    most ``burst + rate * (t1 - t0)`` — tokens held at ``t0`` are capped
    by ``burst`` and refill inside the window is ``rate * (t1 - t0)``.
    Time may not run backwards; a stale ``now`` is clamped forward.
    """

    def __init__(self, rate: float, burst: float):
        if rate <= 0:
            raise ValueError(f"rate must be > 0, got {rate}")
        if burst < 1:
            raise ValueError(f"burst must be >= 1, got {burst}")
        self.rate = float(rate)
        self.burst = float(burst)
        self._tokens = float(burst)
        self._last: Optional[float] = None

    def admit(self, now: float, cost: float = 1.0) -> bool:
        """Try to take ``cost`` tokens at time ``now``."""
        if self._last is None:
            self._last = now
        now = max(now, self._last)
        self._tokens = min(self.burst,
                           self._tokens + (now - self._last) * self.rate)
        self._last = now
        if self._tokens + 1e-12 >= cost:
            self._tokens -= cost
            return True
        return False


class BoundedQueue:
    """Bounded FIFO with shed counters: full queue sheds, never blocks."""

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._q: Deque = deque()
        self.offered = 0
        self.admitted = 0
        self.shed = 0

    def offer(self, item) -> bool:
        """Enqueue unless full; counts every call as offered."""
        self.offered += 1
        if len(self._q) >= self.capacity:
            self.shed += 1
            return False
        self._q.append(item)
        self.admitted += 1
        return True

    def pop(self):
        """Dequeue the oldest admitted item (None when empty)."""
        return self._q.popleft() if self._q else None

    def __len__(self) -> int:
        return len(self._q)


class CircuitBreaker:
    """Latency circuit breaker: closed -> open on sustained SLO breach,
    open -> half-open after ``cooldown``, half-open -> closed on
    ``probes`` consecutive probe successes (any probe breach re-opens).

    * Trips only after ``breach_window`` *consecutive* completions over
      ``slo`` while closed (one good completion resets the streak).
    * While open, ``allow`` refuses until ``cooldown`` has elapsed, then
      the breaker half-opens and admits up to ``probes`` probe requests.
    * Liveness: if every in-flight probe is lost (its completion never
      recorded), the probe budget re-arms after another ``cooldown`` —
      the breaker can never deadlock refusing forever.

    Completions recorded while open (stragglers admitted before the
    trip) are ignored: they describe the overloaded past, not the probe.
    """

    CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"

    def __init__(self, slo: float, *, breach_window: int = 8,
                 cooldown: float = 16.0, probes: int = 2):
        if slo <= 0:
            raise ValueError(f"slo must be > 0, got {slo}")
        if breach_window < 1:
            raise ValueError(
                f"breach_window must be >= 1, got {breach_window}")
        if cooldown <= 0:
            raise ValueError(f"cooldown must be > 0, got {cooldown}")
        if probes < 1:
            raise ValueError(f"probes must be >= 1, got {probes}")
        self.slo = float(slo)
        self.breach_window = int(breach_window)
        self.cooldown = float(cooldown)
        self.probes = int(probes)
        self.state = self.CLOSED
        self.trips = 0
        self._streak = 0
        self._opened_at: Optional[float] = None
        self._half_opened_at: Optional[float] = None
        self._probe_sent = 0
        self._probe_ok = 0

    def allow(self, now: float) -> bool:
        """May a request be admitted at ``now``?  (Half-open admits count
        against the probe budget.)"""
        if self.state == self.OPEN:
            if now - self._opened_at >= self.cooldown:
                self._half_open(now)
            else:
                return False
        if self.state == self.HALF_OPEN:
            if self._probe_sent >= self.probes \
                    and now - self._half_opened_at >= self.cooldown:
                self._half_open(now)      # probes lost in flight: re-arm
            if self._probe_sent < self.probes:
                self._probe_sent += 1
                return True
            return False
        return True

    def record(self, now: float, latency: float) -> None:
        """Feed one completed request's latency back into the breaker."""
        breach = latency > self.slo
        if self.state == self.CLOSED:
            self._streak = self._streak + 1 if breach else 0
            if self._streak >= self.breach_window:
                self._trip(now)
        elif self.state == self.HALF_OPEN:
            if breach:
                self._trip(now)
            else:
                self._probe_ok += 1
                if self._probe_ok >= self.probes:
                    self.state = self.CLOSED
                    self._streak = 0

    def _trip(self, now: float) -> None:
        self.state = self.OPEN
        self._opened_at = now
        self._streak = 0
        self.trips += 1

    def _half_open(self, now: float) -> None:
        self.state = self.HALF_OPEN
        self._half_opened_at = now
        self._probe_sent = 0
        self._probe_ok = 0


@dataclasses.dataclass
class AdmissionStats:
    """Per-reason admission counters; ``offered`` equals the sum of
    ``admitted`` and the three shed counters at all times."""
    offered: int = 0
    admitted: int = 0
    shed_rate: int = 0
    shed_queue: int = 0
    shed_breaker: int = 0

    @property
    def shed(self) -> int:
        """Total shed across all reasons."""
        return self.shed_rate + self.shed_queue + self.shed_breaker


class AdmissionController:
    """Breaker -> token bucket -> bounded queue, in that order.

    The breaker is consulted first (an open breaker sheds before any
    token is spent), the bucket second (so rate-shed requests never
    occupy queue slots), the queue last.  A half-open probe slot can be
    consumed by a request the bucket then sheds; the breaker's re-arm
    cooldown guarantees that leak cannot wedge it (see
    :class:`CircuitBreaker`).
    """

    def __init__(self, *, rate: float, burst: float, queue_cap: int,
                 slo: float, breach_window: int = 8, cooldown: float = 16.0,
                 probes: int = 2):
        self.bucket = TokenBucket(rate, burst)
        self.queue = BoundedQueue(queue_cap)
        self.breaker = CircuitBreaker(slo, breach_window=breach_window,
                                      cooldown=cooldown, probes=probes)
        self.stats = AdmissionStats()

    def offer(self, req: Request, now: float) -> str:
        """Admit or shed one request; returns ``"admitted"`` or the shed
        reason (``"shed_breaker"`` | ``"shed_rate"`` | ``"shed_queue"``)."""
        self.stats.offered += 1
        if not self.breaker.allow(now):
            self.stats.shed_breaker += 1
            return "shed_breaker"
        if not self.bucket.admit(now):
            self.stats.shed_rate += 1
            return "shed_rate"
        if not self.queue.offer(req):
            self.stats.shed_queue += 1
            return "shed_queue"
        req.admitted_at = now
        self.stats.admitted += 1
        return "admitted"

    def next_request(self) -> Optional[Request]:
        """Oldest admitted request still waiting (None when empty)."""
        return self.queue.pop()

    def pending(self) -> int:
        """Admitted requests not yet handed to the scheduler."""
        return len(self.queue)

    def complete(self, req: Request, now: float) -> None:
        """Stamp a finished request and feed its latency to the breaker."""
        req.finished_at = now
        self.breaker.record(now, now - req.arrival)
