"""Sparse-allreduce-backed serving tier (ARCHITECTURE.md "Serving tier").

Continuous-batching decode over the existing model stack, with admission
control in front and the paper's sparse exchange underneath:

  * :mod:`repro.serve.queue`      — token bucket, bounded FIFO, circuit
    breaker, and the :class:`~repro.serve.queue.AdmissionController`
    composing them (deterministic injected clock, no sleeps).
  * :mod:`repro.serve.scheduler`  — slot-based continuous batching
    (join-on-free-slot prefill, evict-on-EOS) over the fused greedy
    prefill/decode steps from ``repro.train.step``.
  * :mod:`repro.serve.dispatch`   — the Zipf token/expert exchange routed
    through ``SparseAllreduce``: frozen-plan hot set + shape-bucketed
    union path for the tail.
  * :mod:`repro.serve.service`    — the virtual-clock service loop tying
    admission to the scheduler, plus the Zipf request-stream generator.

Request-level correctness (continuous-batched == sequential oracle,
token for token) is proven by ``tests/test_serve_tier.py``; service
behaviour under load by ``benchmarks/bench_serve.py``.
"""
from .queue import (AdmissionController, BoundedQueue, CircuitBreaker,
                    Request, TokenBucket)
from .scheduler import ContinuousBatchingScheduler
from .service import DecodeService, zipf_request_stream

__all__ = [
    "AdmissionController", "BoundedQueue", "CircuitBreaker", "Request",
    "TokenBucket", "ContinuousBatchingScheduler", "DecodeService",
    "zipf_request_stream",
]
