"""Slot-based continuous batching over the fused greedy decode steps.

A fixed number of batch ``slots`` is compiled once (one decode program
per slot count); requests join a free slot via a per-request prefill and
leave on EOS / ``max_new`` (evict-on-EOS), so decode never waits for the
longest request in a batch — the standard continuous-batching shape, on
top of ``repro.train.step.make_prefill_greedy_step`` /
``make_decode_greedy_step``.

Correctness story (proven request-level in ``tests/test_serve_tier.py``):

  * A request's decode rows are *bitwise independent* of what the other
    slots hold: attention masks by position, prefill fully overwrites a
    slot's cache/state slice, and per-row compute never crosses the batch
    axis.  So continuous batching returns token-for-token the ids the
    sequential one-request-at-a-time oracle returns — **when both run
    through the same compiled slot geometry**.  Different batch sizes
    compile different programs whose accumulation order may differ in the
    last ulp, which is why the oracle is "one request at a time through
    the same scheduler", not a separate batch-1 program.
  * Join prefill on a data-sharded mesh tiles the prompt to ``dp`` rows
    (prefill batch must divide the data axis) and writes row 0 into the
    slot; tiled prefill rows are bitwise identical.

Host <-> device traffic per step is ``O(slots)`` int32 ids — never the
vocab-sized logits (``audit_serve_decode`` pins this).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.models.common import ModelConfig
from repro.train.step import (_ns, init_cache_global,
                              make_decode_greedy_step,
                              make_prefill_greedy_step, mesh_ctx)

from .queue import Request


@dataclasses.dataclass
class SchedulerMetrics:
    """Counters the service and benches report from."""
    decode_steps: int = 0
    joins: int = 0
    evictions: int = 0
    tokens_out: int = 0


def _write_slot(cache, pcache, slot):
    """Write prefill cache row 0 into batch index ``slot`` of every leaf
    (batch axis is 1 on all cache leaves: [n_periods, B, ...])."""
    return jax.tree.map(
        lambda c, n: lax.dynamic_update_index_in_dim(
            c, n[:, 0].astype(c.dtype), slot, 1), cache, pcache)


class ContinuousBatchingScheduler:
    """Continuous-batching decode over ``slots`` compiled batch rows.

    Decoder-only configs (no encoder / image prefix): the serving tier
    batches requests with nothing in common, so there is no shared
    cross-cache to carry.  ``slots`` must be a multiple of the mesh's
    data-axis size (batch rows shard contiguously over data).

    ``dispatch`` (optional, :class:`repro.serve.dispatch.SparseServeDispatch`)
    is fed the active slots' current token ids — grouped by owning data
    shard — every ``dispatch_every`` decode steps; it only *observes* the
    token stream (load/popularity exchange), it never perturbs it.
    """

    def __init__(self, cfg: ModelConfig, mesh, params, *, slots: int,
                 max_seq: int, dispatch=None, dispatch_every: int = 1):
        if cfg.enc_layers or cfg.img_tokens:
            raise ValueError(
                "continuous batching serves decoder-only configs; "
                "encoder/vision archs use the fixed-batch path "
                "(repro.launch.serve)")
        mc = mesh_ctx(mesh)
        if slots < 1 or slots % mc.dp:
            raise ValueError(
                f"slots={slots} must be a positive multiple of the data "
                f"axis size dp={mc.dp} (batch rows shard over data)")
        if dispatch is not None and dispatch.num_shards != mc.dp:
            raise ValueError(
                f"dispatch has {dispatch.num_shards} shards, mesh has "
                f"dp={mc.dp}")
        self.cfg = cfg
        self.mesh = mesh
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.dispatch = dispatch
        self.dispatch_every = max(1, int(dispatch_every))
        self.metrics = SchedulerMetrics()
        self._mc = mc
        self._prefill, _ = make_prefill_greedy_step(cfg, mesh, max_seq)
        self._decode, dspecs = make_decode_greedy_step(cfg, mesh)
        # pin the slot write's output sharding to the decode cache spec:
        # an unconstrained jit would re-lay-out the cache on multi-device
        # meshes and the decode pjit would reject it
        self._write = jax.jit(
            _write_slot, out_shardings=_ns(mesh, dspecs["cache"]))
        self._cache = init_cache_global(cfg, mc, slots, max_seq)
        self._tok = np.zeros(slots, np.int32)
        self._pos = np.zeros(slots, np.int32)
        self._reqs: List[Optional[Request]] = [None] * slots
        self._completed: List[Request] = []

    # ------------------------------------------------------------------
    @property
    def active(self) -> int:
        """Occupied slot count."""
        return sum(r is not None for r in self._reqs)

    def free_slots(self) -> List[int]:
        """Indices of currently free slots (ascending)."""
        return [s for s, r in enumerate(self._reqs) if r is None]

    def pop_completed(self) -> List[Request]:
        """Drain requests finished since the last call (join or step)."""
        out, self._completed = self._completed, []
        return out

    def reset(self) -> None:
        """Clear all slots and counters, keeping the compiled programs.

        Stale cache contents are harmless by construction — prefill
        overwrites a joining slot's entire cache/state slice and decode
        attends only positions this request wrote — which is exactly what
        the consistency harness proves when it reuses one scheduler for
        the batched run and the sequential oracle."""
        self._tok[:] = 0
        self._pos[:] = 0
        self._reqs = [None] * self.slots
        self._completed = []
        self.metrics = SchedulerMetrics()

    # ------------------------------------------------------------------
    def join(self, req: Request) -> int:
        """Prefill ``req`` into the lowest free slot; returns the slot.

        The prompt is tiled to ``dp`` rows (prefill batch must divide the
        data axis) and row 0 of the resulting cache is written into the
        slot.  The prefill's greedy next token is the request's first
        generated id; a ``max_new=1`` request completes here without ever
        entering the decode batch."""
        free = self.free_slots()
        if not free:
            raise RuntimeError("join() with no free slot")
        prompt = np.asarray(req.prompt, np.int32).reshape(-1)
        if len(prompt) + req.max_new > self.max_seq:
            raise ValueError(
                f"request {req.rid}: prompt {len(prompt)} + max_new "
                f"{req.max_new} exceeds max_seq {self.max_seq}")
        slot = free[0]
        bp = self._mc.dp
        toks = jnp.asarray(np.tile(prompt[None], (bp, 1)))
        ids, pcache = self._prefill(self.params, {"tokens": toks})
        self._cache = self._write(self._cache, pcache, jnp.int32(slot))
        first = int(np.asarray(ids)[0])
        req.tokens.append(first)
        self.metrics.joins += 1
        self.metrics.tokens_out += 1
        if req.done():
            self._evict_into_completed(req, slot, occupied=False)
        else:
            self._reqs[slot] = req
            self._tok[slot] = first
            self._pos[slot] = len(prompt)
        return slot

    def step(self) -> None:
        """One fused decode step over all slots (no-op when idle).

        Each active slot consumes its pending token at its position and
        produces the next greedy id; free slots decode garbage rows whose
        results are discarded (bitwise independence makes them harmless,
        and their positions are pinned at 0 so nothing grows unbounded).
        Completions are queued for :meth:`pop_completed`."""
        if self.active == 0:
            return
        if self.dispatch is not None \
                and self.metrics.decode_steps % self.dispatch_every == 0:
            self.dispatch.on_step(self._active_tokens_by_shard())
        ids, self._cache = self._decode(
            self.params, jnp.asarray(self._tok), jnp.asarray(self._pos),
            self._cache)
        ids = np.asarray(ids)
        self.metrics.decode_steps += 1
        for slot, req in enumerate(self._reqs):
            if req is None:
                continue
            tok = int(ids[slot])
            req.tokens.append(tok)
            self.metrics.tokens_out += 1
            if req.done():
                self._evict_into_completed(req, slot, occupied=True)
            else:
                self._tok[slot] = tok
                self._pos[slot] += 1

    def _evict_into_completed(self, req: Request, slot: int,
                              occupied: bool) -> None:
        if occupied:
            self._reqs[slot] = None
            self.metrics.evictions += 1
        self._tok[slot] = 0
        self._pos[slot] = 0
        self._completed.append(req)

    def _active_tokens_by_shard(self) -> List[np.ndarray]:
        """Current input ids of active slots, grouped by the data shard
        that owns each contiguous slot block."""
        per = self.slots // self._mc.dp
        out = []
        for n in range(self._mc.dp):
            sl = [self._tok[s] for s in range(n * per, (n + 1) * per)
                  if self._reqs[s] is not None]
            out.append(np.asarray(sl, np.int32))
        return out
