"""The serving tier's sparse exchange: Zipf token statistics and MoE
expert load routed through ``SparseAllreduce``.

Three exchanges, all over the data shards of the serving mesh (one
logical allreduce node per shard):

  * **Hot set (frozen plan).**  Zipf head token ids are learned once
    from a warmup sample (:meth:`SparseServeDispatch.fit_hot_set`) and
    frozen into the paper's two-call ``config``/``reduce`` path: every
    decode step is a ``reduce`` of per-shard head-count vectors over the
    same plan — config once, reduce many, zero retraces.  This is the
    PowerGraph-style hot/cold split: the head is dense-in-head, so it
    rides a fixed pattern.
  * **Tail (union path, shape-bucketed).**  Per-step tail ids go through
    ``union_reduce`` — the paper's dynamic mini-batch mode — with both
    capacities rounded to power-of-two buckets
    (``repro.core.allreduce.shape_bucket``), so the compiled-pipeline
    cache is keyed by O(log) shapes and batch churn almost always hits
    (``union_plan_stats``; bench floor 0.8).  The ``wire=`` codecs from
    PR 8 compose here.
  * **Expert load (frozen plan).**  The expert-id space is static, so
    per-shard expert-load vectors reduce over a plan configured once at
    construction.  Assignments come from
    :func:`make_expert_predictor` — the token's input embedding routed
    through a real router via ``repro.models.moe.router_topk``, i.e. the
    exact routing decision the MoE block would make for that token at
    layer entry.

The dispatch only *observes* the token stream (its outputs feed metrics
and admission decisions), so enabling it cannot perturb generation —
``tests/test_serve_tier.py`` asserts both that and the exchange's
numerical agreement with a dense numpy oracle.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core import SparseAllreduce
from repro.core.allreduce import shape_bucket
from repro.core.sparse_vec import SENTINEL


@dataclasses.dataclass
class StepExchange:
    """One step's combined statistics: global counts over the head set
    plus the union-reduced tail, and the union overflow (dropped tail
    entries when the bucketed out-capacity saturates)."""
    head_ids: np.ndarray        # [H] uint32
    head_counts: np.ndarray     # [H] float32, summed over shards
    tail_ids: np.ndarray        # [U] uint32, union over shards
    tail_counts: np.ndarray     # [U] float32
    overflow: int

    def count_of(self, token_id: int) -> float:
        """Global observed count of one token id this step."""
        hit = np.nonzero(self.head_ids == np.uint32(token_id))[0]
        if len(hit):
            return float(self.head_counts[hit[0]])
        hit = np.nonzero(self.tail_ids == np.uint32(token_id))[0]
        return float(self.tail_counts[hit[0]]) if len(hit) else 0.0


class SparseServeDispatch:
    """Per-step sparse exchange over ``num_shards`` serving data shards.

    Requires a JAX mesh whose device count is a multiple of
    ``num_shards`` (the default mesh path of ``SparseAllreduce``).
    ``wire`` applies to the dynamic tail union; the frozen head / expert
    plans stay ``raw`` (the planned path is the bit-exact baseline the
    harness checks against)."""

    def __init__(self, num_shards: int, *, vocab: int, n_experts: int = 0,
                 degrees=None, merge: str = "sort", wire: str = "raw",
                 mesh=None, seed: int = 1234, union_floor: int = 8):
        if num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {num_shards}")
        self.num_shards = num_shards
        self.vocab = int(vocab)
        self.n_experts = int(n_experts)
        self.union_floor = int(union_floor)
        if degrees is None:
            degrees = (num_shards,) if num_shards > 1 else ()
        kw = dict(backend="device", merge=merge, mesh=mesh, seed=seed)
        self._head_ar = SparseAllreduce(num_shards, degrees, wire="raw", **kw)
        self._tail_ar = SparseAllreduce(num_shards, degrees, wire=wire, **kw)
        self._moe_ar = None
        if self.n_experts:
            self._moe_ar = SparseAllreduce(num_shards, degrees, wire="raw",
                                           **kw)
            eids = np.arange(self.n_experts, dtype=np.uint32)
            self._moe_ar.config([eids] * num_shards, [eids] * num_shards)
        self.head_ids: Optional[np.ndarray] = None
        self._head_lookup: Optional[dict] = None
        self.frozen_reduces = 0      # reduce() calls over frozen plans
        self.steps = 0
        self.last: Optional[StepExchange] = None

    # ------------------------------------------------------------------
    def fit_hot_set(self, sample_ids: np.ndarray, head_size: int = 64
                    ) -> np.ndarray:
        """Learn the Zipf head from a warmup sample and freeze its plan.

        ``head_size`` is bucketed (power of two) and clipped to the
        vocab; the head is the top-``H`` ids by sample frequency, ties
        broken by id.  Returns the head ids.  Must be called before
        :meth:`on_step`."""
        sample = np.asarray(sample_ids, np.int64).reshape(-1)
        h = min(shape_bucket(head_size, self.union_floor), self.vocab)
        counts = np.bincount(sample, minlength=self.vocab)[:self.vocab]
        order = np.lexsort((np.arange(self.vocab), -counts))
        self.head_ids = order[:h].astype(np.uint32)
        self._head_lookup = {int(t): i for i, t in enumerate(self.head_ids)}
        ids = [self.head_ids] * self.num_shards
        self._head_ar.config(ids, ids)
        return self.head_ids

    # ------------------------------------------------------------------
    def on_step(self, tok_shards: Sequence[np.ndarray]) -> StepExchange:
        """Exchange one decode step's active token ids.

        ``tok_shards``: one int array per data shard (the shard's active
        slots' current input ids; may be empty).  Returns the global
        :class:`StepExchange`; every shard would see the same result —
        the union butterfly is a gather-all."""
        if self.head_ids is None:
            raise RuntimeError("fit_hot_set() must run before on_step()")
        if len(tok_shards) != self.num_shards:
            raise ValueError(
                f"expected {self.num_shards} shard token lists, got "
                f"{len(tok_shards)}")
        h = len(self.head_ids)
        head_vals = []
        tails = []
        for toks in tok_shards:
            toks = np.asarray(toks, np.int64).reshape(-1)
            hv = np.zeros(h, np.float32)
            tail_list = []
            for t in toks:
                j = self._head_lookup.get(int(t))
                if j is None:
                    tail_list.append(int(t))
                else:
                    hv[j] += 1.0
            head_vals.append(hv)
            u, c = np.unique(np.asarray(tail_list, np.int64),
                             return_counts=True)
            tails.append((u.astype(np.uint32), c.astype(np.float32)))

        head_out = self._head_ar.reduce(head_vals)[0].astype(np.float32)
        self.frozen_reduces += 1
        tail_ids, tail_counts, ovf = self._union_tail(tails)
        self.steps += 1
        self.last = StepExchange(
            head_ids=self.head_ids, head_counts=head_out,
            tail_ids=tail_ids, tail_counts=tail_counts, overflow=ovf)
        return self.last

    def _union_tail(self, tails):
        """Union-reduce per-shard (ids, counts) through the bucketed
        dynamic path; returns (ids, counts, overflow)."""
        m = self.num_shards
        longest = max((len(u) for u, _ in tails), default=0)
        cap = shape_bucket(longest, self.union_floor)
        out_cap = shape_bucket(min(self.vocab, cap * m), self.union_floor)
        idx = np.full((m, cap), SENTINEL, np.uint32)
        val = np.zeros((m, cap), np.float32)
        perm = self._tail_ar.perm
        for n, (u, c) in enumerate(tails):
            if not len(u):
                continue
            hashed = perm.fwd_np(u)
            order = np.argsort(hashed)
            idx[n, :len(u)] = hashed[order]
            val[n, :len(u)] = c[order]
        oi, ov, ovf = self._tail_ar.union_reduce(idx, val, out_cap)
        oi, ov = np.asarray(oi[0]), np.asarray(ov[0])
        ok = oi != np.uint32(SENTINEL)
        ids = perm.inv_np(oi[ok])
        return ids, ov[ok].astype(np.float32), int(np.asarray(ovf)[0])

    # ------------------------------------------------------------------
    def expert_load(self, ek_shards: Sequence[np.ndarray]) -> np.ndarray:
        """Combine per-shard expert assignments into the global per-expert
        load via the frozen expert plan.

        ``ek_shards``: one int array of expert ids per shard (any shape —
        typically the ``[N, K]`` output of the predictor).  Returns
        float32 ``[n_experts]`` global assignment counts."""
        if self._moe_ar is None:
            raise RuntimeError(
                "expert_load requires n_experts > 0 at construction")
        vals = []
        for ek in ek_shards:
            ek = np.asarray(ek, np.int64).reshape(-1)
            vals.append(np.bincount(ek, minlength=self.n_experts)
                        [:self.n_experts].astype(np.float32))
        out = self._moe_ar.reduce(vals)[0].astype(np.float32)
        self.frozen_reduces += 1
        return out

    # ------------------------------------------------------------------
    @property
    def plan_resolutions(self) -> int:
        """Total plan lookups: frozen reduces + union-path resolutions."""
        u = self._tail_ar.union_plan_stats
        return self.frozen_reduces + u["hits"] + u["misses"]

    @property
    def plan_hit_rate(self) -> float:
        """Fraction of plan resolutions served without replanning or
        retracing: frozen-plan reduces (the plan was configured once) and
        union-cache hits, over all resolutions."""
        u = self._tail_ar.union_plan_stats
        total = self.plan_resolutions
        return (self.frozen_reduces + u["hits"]) / total if total else 1.0


def make_expert_predictor(cfg):
    """Jitted shadow router: ``fn(emb, router, ids) -> ek [N, K]``.

    Routes each token's *input embedding* through a router matrix using
    the shared :func:`repro.models.moe.router_topk` — the same masked
    softmax / top-k / renormalize the MoE block applies — so the serving
    tier's expert-load signal counts the experts the model would engage
    for those tokens at layer entry.  ``emb``: ``[V_pad, d]``;
    ``router``: ``[d, E_pad]`` (e.g. the first MoE block's, period 0)."""
    import jax
    import jax.numpy as jnp

    from repro.models.moe import router_topk

    def fn(emb, router, ids):
        x = emb[ids.astype(jnp.int32)].astype(jnp.float32)
        _, _, ek = router_topk(x @ router.astype(jnp.float32), cfg)
        return ek

    return jax.jit(fn)


def first_moe_router(params) -> Optional[np.ndarray]:
    """The first MoE block's period-0 router matrix from a param tree
    (``blocks.b*.moe.router`` is ``[n_periods, d, E_pad]``), or None for
    dense archs."""
    blocks = params.get("blocks", {})
    for key in sorted(blocks):
        if isinstance(blocks[key], dict) and "moe" in blocks[key]:
            return blocks[key]["moe"]["router"][0]
    return None
