"""The decode service loop: admission control in front of the
continuous-batching scheduler, on a deterministic virtual clock.

Time is measured in *decode steps*: every loop tick delivers due
arrivals to the :class:`~repro.serve.queue.AdmissionController`, joins
admitted requests onto free slots, runs one fused decode step, and
advances the clock by 1.  Latencies/SLOs are therefore in steps, and the
whole trajectory — admissions, sheds, breaker trips, token streams — is
a pure function of the request stream, which is what the consistency
harness and the load benches rely on.  Wall time is tracked only for the
tokens/s conversion in :class:`ServiceReport` and never feeds a
decision.

:func:`zipf_request_stream` generates the paper's workload wearing its
serving hat — prompts drawn by ``repro.data.pipeline.zipf_tokens`` (the
same power-law collision statistics the allreduce core is built for),
with seeded exponential inter-arrivals at a configurable offered rate.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Tuple

import numpy as np

from repro.data.pipeline import zipf_tokens

from .queue import AdmissionController, Request
from .scheduler import ContinuousBatchingScheduler


def zipf_request_stream(n: int, vocab: int, *, alpha: float = 1.2,
                        prompt_lens: Tuple[int, ...] = (4, 8, 16),
                        max_new: Tuple[int, int] = (1, 8),
                        arrival_rate: Optional[float] = None,
                        eos_id: Optional[int] = None,
                        seed: int = 0) -> List[Request]:
    """Seeded Zipf request stream: ``n`` requests with prompts drawn from
    ``zipf_tokens``, prompt lengths cycling through ``prompt_lens``,
    ``max_new`` uniform over its inclusive range, and exponential
    inter-arrivals at ``arrival_rate`` requests per step (None: all
    arrive at t=0)."""
    rng = np.random.RandomState(seed)
    reqs = []
    t = 0.0
    lo, hi = max_new
    for i in range(n):
        plen = int(prompt_lens[i % len(prompt_lens)])
        prompt = zipf_tokens(rng, (1, plen), vocab, alpha=alpha)[0]
        if arrival_rate is not None:
            t += float(rng.exponential(1.0 / arrival_rate))
        reqs.append(Request(rid=i, prompt=np.asarray(prompt, np.int32),
                            max_new=int(rng.randint(lo, hi + 1)),
                            eos_id=eos_id, arrival=t))
    return reqs


@dataclasses.dataclass
class ServiceReport:
    """What one service run produced: the completed requests (in
    completion order), latency percentiles over *admitted* requests (in
    steps), throughput, and the admission/dispatch statistics."""
    completed: List[Request]
    steps: int
    tokens_out: int
    wall_s: float
    p50_steps: float
    p99_steps: float
    admission: Optional[object] = None       # AdmissionStats | None
    plan_hit_rate: Optional[float] = None

    @property
    def tokens_per_s(self) -> float:
        """Generated ids per wall second over the run."""
        return self.tokens_out / max(self.wall_s, 1e-9)


def _percentile(xs: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs, np.float64), q)) if xs \
        else 0.0


class DecodeService:
    """Drives a scheduler from a request stream under admission control.

    ``admission=None`` admits everything (the consistency harness runs
    this way: correctness must not depend on load shedding)."""

    def __init__(self, scheduler: ContinuousBatchingScheduler,
                 admission: Optional[AdmissionController] = None):
        self.scheduler = scheduler
        self.admission = admission

    def run(self, requests: List[Request],
            max_steps: int = 100_000) -> ServiceReport:
        """Serve the stream to completion (or ``max_steps``) and report.

        One tick = deliver due arrivals -> join admitted onto free slots
        -> one decode step -> stamp completions -> advance the clock."""
        sched = self.scheduler
        adm = self.admission
        pending = sorted(requests, key=lambda r: (r.arrival, r.rid))
        direct: List[Request] = []        # admission-free FIFO
        completed: List[Request] = []
        t = 0.0
        t0 = time.time()
        while pending or direct or sched.active \
                or (adm is not None and adm.pending()):
            while pending and pending[0].arrival <= t:
                req = pending.pop(0)
                if adm is None:
                    req.admitted_at = t
                    direct.append(req)
                else:
                    adm.offer(req, t)
            queue_next = (lambda: direct.pop(0) if direct else None) \
                if adm is None else adm.next_request
            while sched.free_slots():
                req = queue_next()
                if req is None:
                    break
                sched.join(req)
            sched.step()
            t += 1.0
            for req in sched.pop_completed():
                if adm is None:
                    req.finished_at = t
                else:
                    adm.complete(req, t)
                completed.append(req)
            if t >= max_steps:
                break
        wall = time.time() - t0
        lats = [r.latency for r in completed if r.latency is not None]
        hit = None
        if sched.dispatch is not None:
            hit = sched.dispatch.plan_hit_rate
        return ServiceReport(
            completed=completed, steps=int(t),
            tokens_out=sched.metrics.tokens_out, wall_s=wall,
            p50_steps=_percentile(lats, 50), p99_steps=_percentile(lats, 99),
            admission=adm.stats if adm is not None else None,
            plan_hit_rate=hit)


def run_sequential_oracle(scheduler: ContinuousBatchingScheduler,
                          requests: List[Request]) -> List[List[int]]:
    """The consistency oracle: the same scheduler instance (same compiled
    slot geometry), one request at a time.

    Returns per-request token lists indexed by position in ``requests``.
    Running through the *same* slots-compiled programs is the point: a
    different batch size would compile a different program whose
    accumulation order may differ in the last ulp, which would test XLA's
    numerics instead of the scheduler's request isolation."""
    out = []
    for req in requests:
        clone = Request(rid=req.rid, prompt=np.array(req.prompt),
                        max_new=req.max_new, eos_id=req.eos_id)
        scheduler.join(clone)
        while scheduler.active:
            scheduler.step()
        scheduler.pop_completed()
        out.append(list(clone.tokens))
    return out
