"""HADI diameter estimation over Sparse Allreduce (paper §I-A.2, eq. 3).

HADI iterates b^{h+1} = G x_or b^h with Flajolet-Martin bitstrings.  Our
allreduce is additive; OR transfers exactly because the bitstrings are 0/1
vectors: OR(a, b) = min(a + b, 1) — sum through the network, clamp at the
receiver.  (This is the documented adaptation of eq. 3's x_or operator.)

Neighbourhood-size estimate per FM: N(h) ~ 2^{b(h)} / 0.77351 with b the
average lowest-zero-bit position; effective diameter = smallest h with
N(h) >= 0.9 * N(h_max).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.core import SparseAllreduce
from .pagerank import build_partitions

FM_PHI = 0.77351


def fm_bitstrings(n: int, bits: int, trials: int, rng) -> np.ndarray:
    """[n, trials, bits] 0/1 — bit i set with prob 2^-(i+1)."""
    probs = 2.0 ** (-(np.arange(bits) + 1.0))
    return (rng.random_sample((n, trials, bits)) < probs).astype(np.float64)


def _fm_estimate(b: np.ndarray) -> float:
    """b: [n, trials, bits] union bitstrings -> neighbourhood size sum."""
    zero = b < 0.5
    # lowest zero bit per (vertex, trial)
    low = np.argmax(zero, axis=-1)
    low = np.where(zero.any(axis=-1), low, b.shape[-1])
    return float(np.sum(2.0 ** np.mean(low, axis=-1) / FM_PHI))


def hadi(edges: np.ndarray, n_vertices: int, m: int, degrees=(4, 2),
         max_hops: int = 16, bits: int = 24, trials: int = 4,
         backend: str = "sim", seed: int = 0) -> Tuple[int, np.ndarray, dict]:
    """Returns (effective diameter, N(h) curve, stats)."""
    rng = np.random.RandomState(seed)
    parts = build_partitions(edges, n_vertices, m, seed=seed)
    ar = SparseAllreduce(m, degrees, backend=backend, seed=seed,
                         value_width=trials * bits)
    # inbound = read-set for the next hop PLUS own written rows, so every
    # vertex with in-edges receives its updated bitstring somewhere
    req = [np.union1d(p.in_idx, p.out_idx).astype(np.uint32) for p in parts]
    ar.config([p.out_idx.astype(np.uint32) for p in parts], req)

    b = fm_bitstrings(n_vertices, bits, trials, rng)  # global (self-bit)
    b0 = b.copy()
    curve = [_fm_estimate(b)]
    for h in range(max_hops):
        # out value of a row v = OR over partition edges of b[src]
        outs = []
        for p in parts:
            acc = np.zeros((len(p.out_idx), trials, bits))
            np.add.at(acc, p.dst_pos, b[p.src])
            outs.append(np.minimum(acc, 1.0).reshape(len(p.out_idx), -1))
        ins = ar.reduce(outs)
        newb = b.copy()
        for i, p in enumerate(parts):
            ridx = np.union1d(p.in_idx, p.out_idx)
            got = np.minimum(ins[i], 1.0).reshape(-1, trials, bits)
            newb[ridx] = np.maximum(newb[ridx], got)
        # vertices also OR their own previous bits (self-loop in HADI)
        b = np.maximum(b, newb)
        est = _fm_estimate(b)
        curve.append(est)
        if est <= curve[-2] * 1.0001:
            break
    curve = np.array(curve)
    target = 0.9 * curve[-1]
    eff = int(np.argmax(curve >= target))
    return eff, curve, {"hops_run": len(curve) - 1, "b0": b0, "b_final": b}


def hadi_bitstring_reference(edges: np.ndarray, n_vertices: int,
                             b0: np.ndarray, hops: int) -> np.ndarray:
    """Deterministic oracle: global OR-iteration of the same bitstrings.
    Distributed HADI must produce bit-identical strings after each hop."""
    b = b0.copy()
    for _ in range(hops):
        new = b.copy()
        acc = np.zeros_like(b)
        np.add.at(acc, edges[:, 1], b[edges[:, 0]])
        new = np.maximum(new, np.minimum(acc, 1.0))
        b = np.maximum(b, new)
    return b


def bfs_neighbourhood_reference(edges: np.ndarray, n_vertices: int,
                                max_hops: int) -> np.ndarray:
    """Exact N(h) = total pairs within h hops (small graphs; oracle)."""
    radj = [[] for _ in range(n_vertices)]   # in-neighbours: b[d] |= b[s]
    for s, d in edges:
        radj[d].append(s)
    curve = [n_vertices]
    reach = [1 << v for v in range(n_vertices)]  # bitset per vertex
    for h in range(max_hops):
        new = list(reach)
        for v in range(n_vertices):
            acc = reach[v]
            for u in radj[v]:
                acc |= reach[u]
            new[v] = acc
        reach = new
        curve.append(sum(bin(r).count("1") for r in reach))
        if curve[-1] == curve[-2]:
            break
    return np.array(curve, np.float64)
