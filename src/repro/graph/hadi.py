"""HADI diameter estimation over Sparse Allreduce (paper §I-A.2, eq. 3).

HADI iterates b^{h+1} = G x_or b^h with Flajolet-Martin bitstrings.  Our
allreduce is additive; OR transfers exactly because the bitstrings are 0/1
vectors: OR(a, b) = min(a + b, 1) — sum through the network, clamp at the
receiver.  (This is the documented adaptation of eq. 3's x_or operator.)

Neighbourhood-size estimate per FM: N(h) ~ 2^{b(h)} / 0.77351 with b the
average lowest-zero-bit position; effective diameter = smallest h with
N(h) >= 0.9 * N(h_max).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.core import SparseAllreduce
from .pagerank import build_partitions

FM_PHI = 0.77351


def fm_bitstrings(n: int, bits: int, trials: int, rng) -> np.ndarray:
    """[n, trials, bits] 0/1 — bit i set with prob 2^-(i+1)."""
    probs = 2.0 ** (-(np.arange(bits) + 1.0))
    return (rng.random_sample((n, trials, bits)) < probs).astype(np.float64)


def _fm_estimate(b: np.ndarray) -> float:
    """b: [n, trials, bits] union bitstrings -> neighbourhood size sum."""
    zero = b < 0.5
    # lowest zero bit per (vertex, trial)
    low = np.argmax(zero, axis=-1)
    low = np.where(zero.any(axis=-1), low, b.shape[-1])
    return float(np.sum(2.0 ** np.mean(low, axis=-1) / FM_PHI))


def hadi(edges: np.ndarray, n_vertices: int, m: int, degrees=(4, 2),
         max_hops: int = 16, bits: int = 24, trials: int = 4,
         backend: str = "sim", seed: int = 0, mesh=None
         ) -> Tuple[int, np.ndarray, dict]:
    """Returns (effective diameter, N(h) curve, stats).

    ``backend="sim"`` (oracle): per-hop numpy loop through the simulator.
    ``backend="device"``: the iterative graph engine fuses all
    ``max_hops`` OR-rounds into one jitted dispatch (per-hop bitstrings
    collected on device, early-stop applied post-hoc on the host curve —
    bit-identical to the sim because the 0/1 sums are exact in fp32);
    ``stats["engine"]`` carries the dispatch/sync report.
    """
    rng = np.random.RandomState(seed)
    parts = build_partitions(edges, n_vertices, m, seed=seed)
    # inbound = read-set for the next hop PLUS own written rows, so every
    # vertex with in-edges receives its updated bitstring somewhere
    req = [np.union1d(p.in_idx, p.out_idx).astype(np.uint32) for p in parts]
    if backend == "device":
        return _hadi_device(parts, req, n_vertices, degrees, max_hops,
                            bits, trials, rng, seed, mesh)
    ar = SparseAllreduce(m, degrees, backend=backend, seed=seed,
                         value_width=trials * bits)
    ar.config([p.out_idx.astype(np.uint32) for p in parts], req)

    b = fm_bitstrings(n_vertices, bits, trials, rng)  # global (self-bit)
    b0 = b.copy()
    curve = [_fm_estimate(b)]
    for h in range(max_hops):
        # out value of a row v = OR over partition edges of b[src]
        outs = []
        for p in parts:
            acc = np.zeros((len(p.out_idx), trials, bits))
            np.add.at(acc, p.dst_pos, b[p.src])
            outs.append(np.minimum(acc, 1.0).reshape(len(p.out_idx), -1))
        ins = ar.reduce(outs)
        newb = b.copy()
        for i, p in enumerate(parts):
            ridx = np.union1d(p.in_idx, p.out_idx)
            got = np.minimum(ins[i], 1.0).reshape(-1, trials, bits)
            newb[ridx] = np.maximum(newb[ridx], got)
        # vertices also OR their own previous bits (self-loop in HADI)
        b = np.maximum(b, newb)
        est = _fm_estimate(b)
        curve.append(est)
        if est <= curve[-2] * 1.0001:
            break
    curve = np.array(curve)
    target = 0.9 * curve[-1]
    eff = int(np.argmax(curve >= target))
    return eff, curve, {"hops_run": len(curve) - 1, "b0": b0, "b_final": b}


def _hadi_device(parts, req, n_vertices: int, degrees, max_hops: int,
                 bits: int, trials: int, rng, seed: int, mesh
                 ) -> Tuple[int, np.ndarray, dict]:
    """Device path: all hops in one dispatch, early stop applied post-hoc.

    Per-node state = bitstrings over the node's request set (OR transfers
    through the additive reduce as sum + clamp; 0/1 sums are exact in
    fp32, so per-hop strings are bit-identical to the sim oracle).  The
    scan collects every hop's state (``collect="trajectory"``); the host
    then assembles the global per-hop strings and applies the same
    plateau early-stop the sim loop uses, truncating the curve.
    """
    from . import engine as eng
    m, w = len(parts), trials * bits

    def out_fn(s, e):
        acc = eng.ell_matvec(e["cols"], e["wts"], s)
        import jax.numpy as jnp
        return jnp.minimum(acc, 1.0)

    def update_fn(s, in_raw, e, ax):
        import jax.numpy as jnp
        return jnp.maximum(s, jnp.minimum(in_raw, 1.0))

    app = eng.EngineApp(name="hadi", out_fn=out_fn, update_fn=update_fn,
                        value_width=w)
    engine = eng.GraphEngine(
        [p.out_idx.astype(np.uint32) for p in parts], req, app,
        degrees=degrees, mesh=mesh, seed=seed)
    # edge (src, dst) contributes b[src] to row dst: cols = src position in
    # the request set, rows = dst position in out_idx, weight 1 (OR)
    tables = [eng.build_ell(p.dst_pos,
                            np.searchsorted(req[i], p.src),
                            np.ones(len(p.src), np.float32),
                            len(p.out_idx))
              for i, p in enumerate(parts)]
    cols, wts = eng.stack_ell(tables, engine.u_cap)

    b0 = fm_bitstrings(n_vertices, bits, trials, rng)
    state0 = np.zeros((m, engine.uin_cap, w), np.float32)
    for i, r in enumerate(req):
        state0[i, : len(r)] = b0[r].reshape(len(r), w)
    _, _, traj = engine.run(max_hops, state0, {"cols": cols, "wts": wts},
                            collect="trajectory")
    traj = np.asarray(traj, np.float64)           # [hops, M, req_cap, w]

    b = b0.copy()
    curve = [_fm_estimate(b)]
    for h in range(max_hops):
        for i, r in enumerate(req):
            b[r] = np.maximum(b[r],
                              traj[h, i, : len(r)].reshape(len(r), trials,
                                                           bits))
        est = _fm_estimate(b)
        curve.append(est)
        if est <= curve[-2] * 1.0001:
            break
    curve = np.array(curve)
    target = 0.9 * curve[-1]
    eff = int(np.argmax(curve >= target))
    return eff, curve, {"hops_run": len(curve) - 1, "b0": b0, "b_final": b,
                        "engine": engine.sync_report()}


def hadi_bitstring_reference(edges: np.ndarray, n_vertices: int,
                             b0: np.ndarray, hops: int) -> np.ndarray:
    """Deterministic oracle: global OR-iteration of the same bitstrings.
    Distributed HADI must produce bit-identical strings after each hop."""
    b = b0.copy()
    for _ in range(hops):
        new = b.copy()
        acc = np.zeros_like(b)
        np.add.at(acc, edges[:, 1], b[edges[:, 0]])
        new = np.maximum(new, np.minimum(acc, 1.0))
        b = np.maximum(b, new)
    return b


def bfs_neighbourhood_reference(edges: np.ndarray, n_vertices: int,
                                max_hops: int) -> np.ndarray:
    """Exact N(h) = total pairs within h hops (small graphs; oracle)."""
    radj = [[] for _ in range(n_vertices)]   # in-neighbours: b[d] |= b[s]
    for s, d in edges:
        radj[d].append(s)
    curve = [n_vertices]
    reach = [1 << v for v in range(n_vertices)]  # bitset per vertex
    for h in range(max_hops):
        new = list(reach)
        for v in range(n_vertices):
            acc = reach[v]
            for u in radj[v]:
                acc |= reach[u]
            new[v] = acc
        reach = new
        curve.append(sum(bin(r).count("1") for r in reach))
        if curve[-1] == curve[-2]:
            break
    return np.array(curve, np.float64)
