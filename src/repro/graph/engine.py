"""Device-resident iterative graph engine (paper §I-A.2, §III-B, Fig 8-9).

The paper's headline workloads are *iterative*: PageRank, HADI and spectral
partitioning amortize one ``config`` over many ``reduce`` rounds.  The
per-call device path (``SparseAllreduce.reduce``) still pays one host
staging + one jitted dispatch per round; this module closes that gap by
composing the **local SpMV** (the blocked ELL Pallas kernel,
``repro.kernels.spmv_ell``) with the **planned sparse-allreduce reduce**
(``PlannedSparseAllreduce.reduce_on_device``) inside one jitted
multi-iteration step:

    engine = GraphEngine(out_sets, in_sets, app, degrees=(4, 2), mesh=mesh)
    final_state, last_out, traj = engine.run(k, state0, extras)

``run(k)`` executes k rounds — ``lax.scan`` over a shard_map step whose
body is ``out = app.out_fn(state)`` → ``in = reduce_on_device(out)`` →
``state = app.update_fn(state, in)`` — with a **single host↔device
round-trip and a single jitted dispatch**, reusing the frozen config /
staging layout (``SparseAllreduce.planned_parts`` /
``staging_metadata``) across all rounds.  The routing tensors are
scan-invariant, so XLA hoists them; per-round work is the SpMV, the
2·depth ``all_to_all`` phases of the butterfly, and the app update.

Backend contract: the engine is the ``backend="device"`` path of the graph
apps (``pagerank`` / ``hadi`` / ``power_iteration`` route here); their
numpy-per-round ``backend="sim"`` loops are preserved untouched as the
oracle.  Replication is not plumbed through the engine yet — construct it
unreplicated (the planned path underneath does support r-way replication
for per-call reduces).

Scaling caveat: the stacked ELL tables pad every partition to the global
max rows × max per-row nonzeros.  The hash permutation balances *columns*
(that is the paper's point), not row degrees — power-law hub rows inflate
``K``; a segmented-CSR kernel is the planned fix for hub-heavy partitions.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import numpy as np

from repro.core import SparseAllreduce
from repro.core.netmodel import EC2_2013, Fabric


# ---------------------------------------------------------------------------
# Vectorized ELL construction (shared with Partition.spmv_ell)
# ---------------------------------------------------------------------------

def build_ell(rows: np.ndarray, cols: np.ndarray, weights: np.ndarray,
              n_rows: int, min_k: int = 1
              ) -> Tuple[np.ndarray, np.ndarray]:
    """Vectorized ELL build: COO triplets -> padded ``[n_rows, K]`` tables.

    ``rows`` / ``cols`` / ``weights``: [E] coordinate triplets (local row
    and column positions).  Returns ``(ell_cols int32, ell_wts float32)``
    with ``K = max(row_count, min_k)``; empty slots are ``-1`` / ``0``.
    Entries within a row keep their original (stable) edge order — the
    same layout the old per-edge Python loop produced, without the loop:
    a stable argsort groups rows, and each entry's slot is its offset from
    its row's start (``arange(E) - row_start[row]``).
    """
    if n_rows == 0:
        return (np.full((0, min_k), -1, np.int32),
                np.zeros((0, min_k), np.float32))
    order = np.argsort(rows, kind="stable")
    r = rows[order]
    counts = np.bincount(r, minlength=n_rows)
    kmax = max(int(counts.max(initial=0)), min_k)
    starts = np.zeros(n_rows + 1, np.int64)
    np.cumsum(counts, out=starts[1:])
    slots = np.arange(len(r), dtype=np.int64) - starts[r]
    ell_cols = np.full((n_rows, kmax), -1, np.int32)
    ell_wts = np.zeros((n_rows, kmax), np.float32)
    ell_cols[r, slots] = np.asarray(cols)[order]
    ell_wts[r, slots] = np.asarray(weights)[order]
    return ell_cols, ell_wts


def stack_ell(tables, n_rows: int) -> Tuple[np.ndarray, np.ndarray]:
    """Stack per-node ``build_ell`` outputs into ``[M, n_rows, K]`` tensors
    (K = global max; rows/K padded with ``-1`` / ``0``) — the static
    per-device extras the engine shards over the mesh."""
    m = len(tables)
    kmax = max(max(c.shape[1] for c, _ in tables), 1)
    cols = np.full((m, n_rows, kmax), -1, np.int32)
    wts = np.zeros((m, n_rows, kmax), np.float32)
    for i, (c, w) in enumerate(tables):
        cols[i, : c.shape[0], : c.shape[1]] = c
        wts[i, : w.shape[0], : w.shape[1]] = w
    return cols, wts


def ell_matvec(cols, wts, x, use_kernel: bool = False):
    """``y[r] = sum_k wts[r,k] * x[cols[r,k]]`` with ``cols < 0`` padding.

    ``x``: [N] or [N, W] (per-device state).  With ``use_kernel=True`` and
    1-D ``x`` the blocked ELL Pallas kernel (``repro.kernels.spmv_ell``)
    runs — natively on TPU, interpret mode elsewhere; the jnp gather-sum
    fallback (and the only W>1 path) computes the identical product.
    """
    import jax.numpy as jnp
    if use_kernel and x.ndim == 1:
        from repro.kernels import ops
        return ops.spmv(cols, wts, x)
    safe = jnp.maximum(cols, 0)
    g = x[safe]                                  # [R, K] or [R, K, W]
    mask = (cols >= 0).astype(x.dtype)
    if x.ndim == 1:
        return jnp.sum(wts * mask * g, axis=1)
    return jnp.sum((wts * mask)[..., None] * g, axis=1)


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EngineApp:
    """Per-round behaviour of one iterative workload, staged into the jit.

    ``out_fn(state, extras) -> out``: per-device traced fn producing the
    round's outbound values ``[u_cap(,W)]`` from the per-device state
    pytree (typically the local SpMV over ELL extras).

    ``update_fn(state, in_raw, extras, axis_name) -> state``: per-device
    traced fn folding the reduced values ``[uin_cap(,W)]`` back into the
    state.  ``axis_name`` is the mesh axis — apps may run extra collectives
    (e.g. spectral's norm ``psum``) inside the same dispatch.

    ``value_width``: trailing value width W (1 for scalar-per-index).
    """
    out_fn: Callable[[Any, Any], Any]
    update_fn: Callable[[Any, Any, Any, str], Any]
    value_width: int = 1
    name: str = "app"


class GraphEngine:
    """k iterations on device per dispatch (see module docstring).

    Construction runs the paper's ``config`` once (host numpy) and freezes
    the plan; ``run`` then executes whole k-round blocks.  Device backend
    only — requires a mesh (or the process default devices) with exactly
    ``len(out_sets)`` devices.

    ``report`` (also :meth:`sync_report`) tracks the amortization
    contract: ``dispatches`` counts jitted invocations, ``rounds`` total
    iterations executed, ``step_traces`` how many times the per-round body
    was traced — after any ``run(k)``, dispatches/traces grow by exactly
    one however large k is (asserted in tests/test_graph_engine.py).

    ``degrees="auto"`` resolves through the calibrated autotuner's
    persistent plan cache (``repro.core.autotune``, TUNING.md), and the
    ``config`` underneath is memo/disk-cached: a second engine over the
    same mesh + index pattern reuses the frozen plan without host
    re-planning (``report["config_cache"]`` says which tier hit).
    ``plan_cache`` / ``retune`` forward to ``SparseAllreduce`` — pass
    ``retune=True`` after recalibrating the fabric, ``plan_cache=False``
    to opt out of the disk tier.

    ``overlap=True`` selects the double-buffered round schedule
    (:meth:`_build_overlap`; ARCHITECTURE.md "Overlap & scheduling"):
    round k's top-half return shares a scanned body with round k+1's SpMV
    and down half, with the in-flight bottom buffer carried across the
    scan boundary.  Same ops, same collective totals, bitwise-identical
    results — only the issue order changes (k=1 has nothing to rotate and
    runs the synchronous body).  The run-fn cache, zero-retrace contract
    and ``report`` semantics are unchanged.
    """

    def __init__(self, out_sets, in_sets, app: EngineApp, *,
                 degrees="auto", mesh=None, seed: int = 0,
                 fabric: Fabric = EC2_2013, plan_cache=True,
                 retune: bool = False, overlap: bool = False):
        self.app = app
        self.overlap = bool(overlap)
        self.num_nodes = len(out_sets)
        self.out_sets = [np.asarray(o, np.uint32) for o in out_sets]
        self.in_sets = [np.asarray(i, np.uint32) for i in in_sets]
        self.seed = seed
        self.fabric = fabric
        self.plan_cache_arg = plan_cache
        self.ar = SparseAllreduce(self.num_nodes, degrees, backend="device",
                                  mesh=mesh, seed=seed, fabric=fabric,
                                  value_width=app.value_width,
                                  plan_cache=plan_cache, retune=retune)
        self.config_stats = self.ar.config(self.out_sets, self.in_sets)
        self.config_cache = self.ar.config_cache
        self.planned, self.mesh = self.ar.planned_parts()
        meta = self.ar.staging_metadata()
        self.u_cap: int = meta["u_cap"]
        self.uin_cap: int = meta["uin_cap"]
        self.out_lens = meta["out_lens"]
        self.in_lens = meta["in_lens"]
        self.axis: str = self.mesh.axis_names[0]
        self._routing = tuple(self.planned.device_args())
        self._run_cache: Dict[Tuple[int, str], Callable] = {}
        self.report = {"dispatches": 0, "rounds": 0, "step_traces": 0}

    # ---------------------------------------------------------------------
    def remesh(self, mesh) -> "GraphEngine":
        """The same engine program on a different device set.

        The recovery move for whole-device loss when spare devices exist
        (``repro.resilience.engine``): the partition, index pattern,
        *resolved* degrees, and seed carry over unchanged, so the rebuilt
        plan's routing — and therefore every reduce result — is
        bit-identical to this engine's; only the mesh binding differs.
        Plan configs are memo-keyed on the mesh's device ids
        (``repro.core.autotune``), so remapping back to a previously used
        device set is a zero-retrace memo hit.  ``mesh`` must span
        ``num_nodes`` devices.
        """
        return GraphEngine(self.out_sets, self.in_sets, self.app,
                           degrees=self.ar.plan.degrees, mesh=mesh,
                           seed=self.seed, fabric=self.fabric,
                           plan_cache=self.plan_cache_arg, retune=False,
                           overlap=self.overlap)

    # -- static per-reduce sync structure ---------------------------------
    def sync_report(self) -> dict:
        """Per-round sync accounting: one reduce = ``depth`` down +
        ``depth`` up ``all_to_all`` phases; host round-trips equal
        dispatches (one per ``run`` call), not rounds.  ``overlap``
        reports the schedule: the rotated double-buffered scan keeps the
        same per-round collective total, split as ``depth`` prologue +
        ``depth`` epilogue phases outside the scan plus ``2 * depth`` per
        interior round inside it (audited by
        ``repro.analysis.auditor.audit_engine``)."""
        return dict(self.report,
                    butterfly_depth=self.planned.depth,
                    reduce_collectives_per_round=2 * self.planned.depth,
                    host_roundtrips=self.report["dispatches"],
                    config_cache=self.config_cache,
                    overlap=self.overlap)

    # ---------------------------------------------------------------------
    def _build_overlap(self, k: int, collect: str) -> Callable:
        """Double-buffered k-round pipeline (``overlap=True``, k >= 2).

        The synchronous body runs SpMV → down half → up half → update, so
        both butterfly halves sit back-to-back with no independent work
        adjacent to either.  This build *rotates* the loop at the round
        boundary: the carry holds round j's in-flight bottom-half buffer
        (``[q_cap(,W)]`` root partials, issued at the end of body j-1 and
        consumed at the start of body j), so each scanned body is

            up half of round j  →  update  →  SpMV of round j+1
                                →  down half of round j+1

        — round j's top-half return and round j+1's SpMV/down issue share
        one body, with the scan boundary between a buffer's issue and its
        consumption (the async-friendly shape XLA's collective pipeliner
        and latency-hiding scheduler need).  Round 1's SpMV + down half
        run as a prologue before the scan and round k's up half + update
        as an epilogue after it, so the per-dispatch collective total is
        unchanged: ``depth`` + (k-1) * ``2 depth`` + ``depth`` = k *
        ``2 depth``.  Every round still executes the identical op
        sequence on identical inputs — results are bitwise equal to the
        synchronous build (tests/test_overlap.py) — and the frozen
        routing / run-fn caches are shared, so the zero-retrace contract
        holds unchanged (tests/test_graph_engine.py).
        """
        import jax
        import jax.numpy as jnp
        from jax import lax
        from jax.sharding import PartitionSpec as P
        from jax.tree_util import tree_map

        from repro.compat import shard_map

        planned, app, axis = self.planned, self.app, self.axis
        spec = P(axis)

        def unsq(t):
            return tree_map(lambda a: a.reshape(a.shape[1:]), t)

        def resq(t):
            return tree_map(lambda a: a.reshape((1,) + a.shape), t)

        def pre_body(state, extras, *routing):
            # round 1: SpMV + bottom half, issued before the scan starts
            s, e = unsq(state), unsq(extras)
            out = app.out_fn(s, e)
            bottom = planned.reduce_down_on_device(out, *routing)
            return resq(bottom), resq(out)

        def mid_body(state, bottom, extras, *routing):
            # round j's top-half return + round j+1's SpMV and down half
            self.report["step_traces"] += 1
            s, b, e = unsq(state), unsq(bottom), unsq(extras)
            in_raw = planned.reduce_up_on_device(b, *routing)
            s2 = app.update_fn(s, in_raw, e, axis)
            out = app.out_fn(s2, e)
            b2 = planned.reduce_down_on_device(out, *routing)
            return resq(s2), resq(b2), resq(out)

        def post_body(state, bottom, extras, *routing):
            # round k: top-half return + update, after the scan drains
            s, b, e = unsq(state), unsq(bottom), unsq(extras)
            in_raw = planned.reduce_up_on_device(b, *routing)
            return resq(app.update_fn(s, in_raw, e, axis))

        rspecs = (spec,) * len(self._routing)
        smap_pre = shard_map(pre_body, mesh=self.mesh,
                             in_specs=(spec, spec) + rspecs,
                             out_specs=(spec, spec), check_vma=False)
        smap_mid = shard_map(mid_body, mesh=self.mesh,
                             in_specs=(spec, spec, spec) + rspecs,
                             out_specs=(spec, spec, spec), check_vma=False)
        smap_post = shard_map(post_body, mesh=self.mesh,
                              in_specs=(spec, spec, spec) + rspecs,
                              out_specs=spec, check_vma=False)

        def run_k(state, extras, *routing):
            bottom, out1 = smap_pre(state, extras, *routing)

            def scan_body(carry, _):
                s, b, _last = carry
                s2, b2, out = smap_mid(s, b, extras, *routing)
                ys = s2 if collect == "trajectory" else None
                return (s2, b2, out), ys

            (s, b, last_out), traj = lax.scan(
                scan_body, (state, bottom, out1), None, length=k - 1)
            final = smap_post(s, b, extras, *routing)
            if collect == "trajectory":
                traj = tree_map(
                    lambda ys, f: jnp.concatenate([ys, f[None]], axis=0),
                    traj, final)
            return final, last_out, traj

        return jax.jit(run_k)

    # ---------------------------------------------------------------------
    def _build(self, k: int, collect: str) -> Callable:
        if self.overlap and k >= 2:
            return self._build_overlap(k, collect)
        import jax
        import jax.numpy as jnp
        from jax import lax
        from jax.sharding import PartitionSpec as P
        from jax.tree_util import tree_map

        from repro.compat import shard_map

        planned, app, axis = self.planned, self.app, self.axis
        spec = P(axis)
        w = app.value_width
        out_shape = (self.num_nodes, self.u_cap) + ((w,) if w > 1 else ())

        def step_body(state, extras, *routing):
            # per-device blocks arrive with a leading mesh dim of size 1
            self.report["step_traces"] += 1
            s = tree_map(lambda a: a.reshape(a.shape[1:]), state)
            e = tree_map(lambda a: a.reshape(a.shape[1:]), extras)
            out = app.out_fn(s, e)
            in_raw = planned.reduce_on_device(out, *routing)
            s2 = app.update_fn(s, in_raw, e, axis)
            return (tree_map(lambda a: a.reshape((1,) + a.shape), s2),
                    out.reshape((1,) + out.shape))

        smap = shard_map(
            step_body, mesh=self.mesh,
            in_specs=(spec, spec) + (spec,) * len(self._routing),
            out_specs=(spec, spec), check_vma=False)

        def run_k(state, extras, *routing):
            def scan_body(carry, _):
                s, _last = carry
                s2, out = smap(s, extras, *routing)
                ys = s2 if collect == "trajectory" else None
                return (s2, out), ys

            zero_out = jnp.zeros(out_shape, jnp.float32)
            (final, last_out), traj = lax.scan(
                scan_body, (state, zero_out), None, length=k)
            return final, last_out, traj

        return jax.jit(run_k)

    # ---------------------------------------------------------------------
    def run_fn(self, k: int, collect: str = "last"):
        """The jitted k-round callable ``run(state, extras, *routing) ->
        (final, last_out, traj)`` that :meth:`run` dispatches, without
        executing it.  ``engine.run_fn(k)(state, extras,
        *engine.routing_args())`` is exactly one dispatch; the static
        auditor (``repro.analysis.auditor``) traces this to verify the
        whole k-round block lowers to a single ``lax.scan`` with all
        collectives inside.  Cached per ``(k, collect)`` like :meth:`run`.
        """
        if collect not in ("last", "trajectory"):
            raise ValueError(f"collect must be 'last' or 'trajectory', "
                             f"got {collect!r}")
        if k < 1:
            raise ValueError(f"need k >= 1 rounds, got {k}")
        fn = self._run_cache.get((k, collect))
        if fn is None:
            fn = self._run_cache[(k, collect)] = self._build(k, collect)
        return fn

    def routing_args(self):
        """The frozen routing tensors :meth:`run` threads into every
        dispatch (positionally after ``state, extras``)."""
        return self._routing

    # ---------------------------------------------------------------------
    def run(self, k: int, state, extras=None, *, collect: str = "last"):
        """Execute k rounds in ONE jitted dispatch.

        ``state``: pytree of ``[M, ...]`` arrays (leading dim = logical
        nodes; typically ``[M, uin_cap(,W)]`` per-node vectors), sharded
        over the mesh.  ``extras``: pytree of iteration-invariant ``[M,
        ...]`` arrays handed to the app fns per-device (e.g. stacked ELL
        tables).  ``collect="trajectory"`` additionally stacks the
        post-update state of every round (``[k, M, ...]`` leaves — HADI's
        per-hop curve needs this); ``"last"`` keeps memory flat.

        Returns ``(final_state, last_out, traj)`` — ``last_out`` is round
        k's pre-reduce outbound values ``[M, u_cap(,W)]`` (PageRank's
        final partial products), ``traj`` is ``None`` unless collecting.
        Compiled functions are cached per ``(k, collect)``; repeated calls
        with the same k re-dispatch without re-tracing.
        """
        import jax.numpy as jnp
        from jax.tree_util import tree_map
        fn = self.run_fn(k, collect)
        state = tree_map(jnp.asarray, state)
        extras = tree_map(jnp.asarray, extras if extras is not None else {})
        final, last_out, traj = fn(state, extras, *self._routing)
        self.report["dispatches"] += 1
        self.report["rounds"] += k
        return final, last_out, traj
