"""Distributed PageRank on Sparse Allreduce (paper §I-A.2, §III-B, Fig 9).

Faithful to the paper's workflow: random edge partition across M nodes; each
node's outbound set = rows its edges write, inbound set = columns its edges
read; ``config`` once (static graph), then per iteration
``in.values = reduce(out.values)`` + local SpMV.

The local SpMV runs in numpy (simulator backend) or through the ELL Pallas
kernel (``use_kernel=True``, interpret mode off-TPU).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.core import SparseAllreduce
from repro.core.netmodel import EC2_2013, Fabric
from repro.data.pipeline import random_edge_partition


@dataclasses.dataclass
class Partition:
    """One node's share of the edge-partitioned graph."""
    src: np.ndarray           # [E_i] global column ids (reads)
    dst: np.ndarray           # [E_i] global row ids (writes)
    in_idx: np.ndarray        # unique sorted src
    out_idx: np.ndarray       # unique sorted dst
    src_pos: np.ndarray       # src -> position in in_idx
    dst_pos: np.ndarray       # dst -> position in out_idx
    inv_outdeg: np.ndarray    # [E_i] 1/outdeg of src (column-normalized G)

    def spmv(self, in_values: np.ndarray) -> np.ndarray:
        """out[dst_pos] += in[src_pos] / outdeg(src)."""
        out = np.zeros(len(self.out_idx), np.float64)
        np.add.at(out, self.dst_pos, in_values[self.src_pos] * self.inv_outdeg)
        return out

    def ell_tables(self, weights: Optional[np.ndarray] = None
                   ) -> Tuple[np.ndarray, np.ndarray]:
        """Padded ELL ``(cols, wts)`` of this partition's SpMV — vectorized
        (``engine.build_ell``: bincount/argsort, no per-edge Python loop);
        the same construction the device engine stacks across nodes."""
        from .engine import build_ell
        w = self.inv_outdeg if weights is None else weights
        return build_ell(self.dst_pos, self.src_pos, w, len(self.out_idx))

    def spmv_ell(self, in_values: np.ndarray, use_kernel: bool = True
                 ) -> np.ndarray:
        """Same product through the blocked ELL Pallas kernel."""
        import jax.numpy as jnp
        from repro.kernels import ops
        if len(self.out_idx) == 0:
            return np.zeros(0, np.float64)
        cols, wts = self.ell_tables()
        y = ops.spmv(jnp.asarray(cols), jnp.asarray(wts),
                     jnp.asarray(in_values, jnp.float32))
        return np.asarray(y, np.float64)


def build_partitions(edges: np.ndarray, n_vertices: int, m: int,
                     seed: int = 0) -> List[Partition]:
    outdeg = np.bincount(edges[:, 0], minlength=n_vertices).astype(np.float64)
    outdeg[outdeg == 0] = 1.0
    parts = []
    for e in random_edge_partition(edges, m, seed=seed):
        src, dst = e[:, 0], e[:, 1]
        in_idx = np.unique(src)
        out_idx = np.unique(dst)
        parts.append(Partition(
            src=src, dst=dst, in_idx=in_idx, out_idx=out_idx,
            src_pos=np.searchsorted(in_idx, src),
            dst_pos=np.searchsorted(out_idx, dst),
            inv_outdeg=1.0 / outdeg[src]))
    return parts


def pagerank(edges: np.ndarray, n_vertices: int, m: int,
             degrees=(4, 2), iters: int = 10, damping: float = 0.85,
             backend: str = "sim", fabric: Fabric = EC2_2013,
             use_kernel: bool = False, seed: int = 0, mesh=None
             ) -> Tuple[np.ndarray, dict]:
    """Returns (scores [n_vertices], stats).  Unreached vertices keep the
    teleport mass only.

    ``backend="sim"`` (oracle): per-iteration numpy loop through the
    message-level simulator — float64, runs anywhere.
    ``backend="device"``: the device-resident iterative engine
    (``repro.graph.engine``) — all ``iters`` rounds of SpMV + planned
    reduce fused into ONE jitted dispatch on a mesh of ``m`` devices
    (``mesh`` or the process defaults); float32, tolerance-bounded against
    the sim oracle.  ``use_kernel`` selects the ELL Pallas SpMV on both
    backends; ``stats["engine"]`` carries the dispatch/sync report.
    """
    parts = build_partitions(edges, n_vertices, m, seed=seed)
    if backend == "device":
        return _pagerank_device(parts, n_vertices, degrees, iters, damping,
                                use_kernel, seed, fabric, mesh)
    ar = SparseAllreduce(m, degrees, backend=backend, fabric=fabric,
                         seed=seed)
    cstats = ar.config([p.out_idx.astype(np.uint32) for p in parts],
                       [p.in_idx.astype(np.uint32) for p in parts])

    # iterate: node i holds P over its in_idx; outbound values are the
    # *partial products* q_i (no teleport — the receiver applies
    # P = (1-d)/n + d * sum(q) after the reduce, so teleport counts once).
    p_in = [np.full(len(p.in_idx), 1.0 / n_vertices) for p in parts]
    q_partial = [np.zeros(len(p.out_idx)) for p in parts]
    reduce_time = 0.0
    for it in range(iters):
        for i, p in enumerate(parts):
            q_partial[i] = p.spmv_ell(p_in[i], use_kernel) if use_kernel \
                else p.spmv(p_in[i])
        in_raw = ar.reduce(q_partial)
        if ar.stats is not None:
            reduce_time += ar.stats.reduce_time_s
        for i in range(m):
            p_in[i] = (1 - damping) / n_vertices + damping * in_raw[i]

    # assemble final scores from the last partials (teleport added once)
    qsum = np.zeros(n_vertices)
    for i, p in enumerate(parts):
        np.add.at(qsum, p.out_idx, q_partial[i])
    scores = (1 - damping) / n_vertices + damping * qsum
    stats = {"config": cstats, "reduce_time_s": reduce_time}
    return scores, stats


def make_pagerank_app(parts: List[Partition], n_vertices: int,
                      damping: float = 0.85, use_kernel: bool = False):
    """The engine-agnostic PageRank pieces: ``(app, out_sets, in_sets)``.

    Shared by :func:`make_pagerank_engine` and the supervised loop
    (``repro.resilience.engine.SupervisedEngineLoop``), which owns its own
    engine construction / remapping and only needs the per-round app."""
    from . import engine as eng
    app = eng.EngineApp(
        name="pagerank",
        out_fn=lambda s, e: eng.ell_matvec(e["cols"], e["wts"], s,
                                           use_kernel=use_kernel),
        update_fn=lambda s, in_raw, e, ax:
            (1.0 - damping) / n_vertices + damping * in_raw)
    return (app,
            [p.out_idx.astype(np.uint32) for p in parts],
            [p.in_idx.astype(np.uint32) for p in parts])


def pagerank_state(parts: List[Partition], n_vertices: int,
                   u_cap: int, uin_cap: int):
    """Stacked ELL extras + the uniform initial state for a PageRank run
    over ``parts``, sized to an engine's frozen ``u_cap`` / ``uin_cap``."""
    from . import engine as eng
    cols, wts = eng.stack_ell([p.ell_tables() for p in parts], u_cap)
    p0 = np.zeros((len(parts), uin_cap), np.float32)
    for i, p in enumerate(parts):
        p0[i, : len(p.in_idx)] = 1.0 / n_vertices
    return {"cols": cols, "wts": wts}, p0


def make_pagerank_engine(parts: List[Partition], n_vertices: int,
                         degrees=(4, 2), damping: float = 0.85,
                         use_kernel: bool = False, seed: int = 0,
                         fabric: Fabric = EC2_2013, mesh=None):
    """Build the device-resident PageRank engine (config once, reuse per
    ``run``): returns ``(engine, extras, p0)`` — everything
    ``engine.run(k, p0, extras)`` needs.  Shared by
    ``pagerank(backend="device")`` and the fig8/fig9 benchmarks."""
    from . import engine as eng
    app, out_sets, in_sets = make_pagerank_app(parts, n_vertices, damping,
                                               use_kernel)
    engine = eng.GraphEngine(out_sets, in_sets, app, degrees=degrees,
                             mesh=mesh, seed=seed, fabric=fabric)
    extras, p0 = pagerank_state(parts, n_vertices, engine.u_cap,
                                engine.uin_cap)
    return engine, extras, p0


def assemble_pagerank_scores(parts: List[Partition], last_q: np.ndarray,
                             n_vertices: int, damping: float) -> np.ndarray:
    """Global scores from the engine's final partial products ``last_q``
    ``[M, u_cap]`` (teleport added once, same as the sim loop's
    assembly)."""
    last_q = np.asarray(last_q, np.float64)
    qsum = np.zeros(n_vertices)
    for i, p in enumerate(parts):
        np.add.at(qsum, p.out_idx, last_q[i, : len(p.out_idx)])
    return (1 - damping) / n_vertices + damping * qsum


def _pagerank_device(parts: List[Partition], n_vertices: int, degrees,
                     iters: int, damping: float, use_kernel: bool,
                     seed: int, fabric: Fabric, mesh
                     ) -> Tuple[np.ndarray, dict]:
    """Device path: k PageRank rounds in one dispatch (graph engine)."""
    engine, extras, p0 = make_pagerank_engine(
        parts, n_vertices, degrees, damping, use_kernel, seed, fabric, mesh)
    _, last_q, _ = engine.run(iters, p0, extras)
    scores = assemble_pagerank_scores(parts, last_q, n_vertices, damping)
    stats = {"config": engine.config_stats, "reduce_time_s": 0.0,
             "engine": engine.sync_report()}
    return scores, stats


def pagerank_dense_reference(edges: np.ndarray, n_vertices: int,
                             iters: int = 10, damping: float = 0.85
                             ) -> np.ndarray:
    outdeg = np.bincount(edges[:, 0], minlength=n_vertices).astype(np.float64)
    outdeg[outdeg == 0] = 1.0
    p = np.full(n_vertices, 1.0 / n_vertices)
    for _ in range(iters):
        q = np.zeros(n_vertices)
        np.add.at(q, edges[:, 1], p[edges[:, 0]] / outdeg[edges[:, 0]])
        p = (1 - damping) / n_vertices + damping * q
    return p
