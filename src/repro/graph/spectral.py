"""Spectral methods: distributed power iteration (paper §I-A.2).

"Almost all eigenvalue algorithms use repeated matrix-vector products" — the
matvec is the same edge-partitioned SpMV + Sparse Allreduce as PageRank; the
Rayleigh normalization is a scalar allreduce per iteration (negligible, done
through the same primitive with a single shared index so the schedule stays
on-network rather than through a driver).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.core import SparseAllreduce
from .pagerank import build_partitions


def power_iteration(edges: np.ndarray, n_vertices: int, m: int,
                    degrees=(4, 2), iters: int = 30, symmetrize: bool = True,
                    backend: str = "sim", seed: int = 0
                    ) -> Tuple[float, np.ndarray, dict]:
    """Leading eigenvalue/eigenvector of the (symmetrized) adjacency matrix.

    Returns (eigenvalue, eigenvector [n], stats).
    """
    if symmetrize:
        edges = np.concatenate([edges, edges[:, ::-1]], axis=0)
    parts = build_partitions(edges, n_vertices, m, seed=seed)
    # adjacency matvec (unnormalized): weight 1 per edge
    for p in parts:
        p.inv_outdeg = np.ones_like(p.inv_outdeg)

    # one allreduce handles the matvec; scalar reductions ride along on a
    # reserved index (n_vertices) appended to every node's out/in sets.
    SCALAR = np.uint32(n_vertices)
    ar = SparseAllreduce(m, degrees, backend=backend, seed=seed)
    out_sets = [np.concatenate([p.out_idx, [SCALAR]]).astype(np.uint32)
                for p in parts]
    in_sets = [np.concatenate([p.in_idx, [SCALAR]]).astype(np.uint32)
               for p in parts]
    ar.config(out_sets, in_sets)

    rng = np.random.RandomState(seed)
    v = rng.randn(n_vertices)
    v /= np.linalg.norm(v)
    p_in = [v[p.in_idx] for p in parts]
    lam = 0.0
    for it in range(iters):
        outs = []
        for i, p in enumerate(parts):
            q = p.spmv(p_in[i])
            # local partial squared-norm of the partial product: nodes owning
            # disjoint EDGES may share rows, so the exact norm needs the
            # reduced vector; we reduce values first, norms second.
            outs.append(np.concatenate([q, [0.0]]))
        ins = ar.reduce(outs)
        # second pass: everyone now holds reduced q on its in-set; compute
        # partial norms over the *bottom-owned* disjoint ranges to avoid
        # double counting: approximate with driver norm on assembled vector.
        q_full = np.zeros(n_vertices)
        seen = np.zeros(n_vertices, bool)
        for i, p in enumerate(parts):
            vals = ins[i][:-1]
            put = ~seen[p.in_idx]
            q_full[p.in_idx[put]] = vals[put]
            seen[p.in_idx] = True
        nrm = np.linalg.norm(q_full)
        if nrm == 0:
            break
        lam = nrm  # Rayleigh estimate for symmetric A with unit v
        v = q_full / nrm
        p_in = [v[p.in_idx] for p in parts]
    return float(lam), v, {"iters": iters}


def power_iteration_reference(edges: np.ndarray, n_vertices: int,
                              iters: int = 30, symmetrize: bool = True,
                              seed: int = 0) -> Tuple[float, np.ndarray]:
    if symmetrize:
        edges = np.concatenate([edges, edges[:, ::-1]], axis=0)
    rng = np.random.RandomState(seed)
    v = rng.randn(n_vertices)
    v /= np.linalg.norm(v)
    lam = 0.0
    for _ in range(iters):
        q = np.zeros(n_vertices)
        np.add.at(q, edges[:, 1], v[edges[:, 0]])
        lam = np.linalg.norm(q)
        if lam == 0:
            break
        v = q / lam
    return float(lam), v
