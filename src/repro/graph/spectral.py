"""Spectral methods: distributed power iteration (paper §I-A.2).

"Almost all eigenvalue algorithms use repeated matrix-vector products" — the
matvec is the same edge-partitioned SpMV + Sparse Allreduce as PageRank; the
Rayleigh normalization is a scalar allreduce per iteration (negligible, done
through the same primitive with a single shared index so the schedule stays
on-network rather than through a driver).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.core import SparseAllreduce
from .pagerank import build_partitions


def power_iteration(edges: np.ndarray, n_vertices: int, m: int,
                    degrees=(4, 2), iters: int = 30, symmetrize: bool = True,
                    backend: str = "sim", seed: int = 0, mesh=None
                    ) -> Tuple[float, np.ndarray, dict]:
    """Leading eigenvalue/eigenvector of the (symmetrized) adjacency matrix.

    Returns (eigenvalue, eigenvector [n], stats).

    ``backend="sim"`` (oracle): per-iteration numpy loop, driver-side
    Rayleigh normalization in float64.  ``backend="device"``: the graph
    engine fuses all ``iters`` matvec+reduce+normalize rounds into one
    jitted dispatch — the normalization runs as an ownership-weighted
    ``lax.psum`` inside the same shard_map step, so the whole power
    iteration stays on device; float32, tolerance-bounded vs the oracle;
    ``stats["engine"]`` carries the dispatch/sync report.
    """
    if symmetrize:
        edges = np.concatenate([edges, edges[:, ::-1]], axis=0)
    parts = build_partitions(edges, n_vertices, m, seed=seed)
    # adjacency matvec (unnormalized): weight 1 per edge
    for p in parts:
        p.inv_outdeg = np.ones_like(p.inv_outdeg)
    if backend == "device":
        return _power_iteration_device(parts, n_vertices, degrees, iters,
                                       seed, mesh)

    # one allreduce handles the matvec; scalar reductions ride along on a
    # reserved index (n_vertices) appended to every node's out/in sets.
    SCALAR = np.uint32(n_vertices)
    ar = SparseAllreduce(m, degrees, backend=backend, seed=seed)
    out_sets = [np.concatenate([p.out_idx, [SCALAR]]).astype(np.uint32)
                for p in parts]
    in_sets = [np.concatenate([p.in_idx, [SCALAR]]).astype(np.uint32)
               for p in parts]
    ar.config(out_sets, in_sets)

    rng = np.random.RandomState(seed)
    v = rng.randn(n_vertices)
    v /= np.linalg.norm(v)
    p_in = [v[p.in_idx] for p in parts]
    lam = 0.0
    for it in range(iters):
        outs = []
        for i, p in enumerate(parts):
            q = p.spmv(p_in[i])
            # local partial squared-norm of the partial product: nodes owning
            # disjoint EDGES may share rows, so the exact norm needs the
            # reduced vector; we reduce values first, norms second.
            outs.append(np.concatenate([q, [0.0]]))
        ins = ar.reduce(outs)
        # second pass: everyone now holds reduced q on its in-set; compute
        # partial norms over the *bottom-owned* disjoint ranges to avoid
        # double counting: approximate with driver norm on assembled vector.
        q_full = np.zeros(n_vertices)
        seen = np.zeros(n_vertices, bool)
        for i, p in enumerate(parts):
            vals = ins[i][:-1]
            put = ~seen[p.in_idx]
            q_full[p.in_idx[put]] = vals[put]
            seen[p.in_idx] = True
        nrm = np.linalg.norm(q_full)
        if nrm == 0:
            break
        lam = nrm  # Rayleigh estimate for symmetric A with unit v
        v = q_full / nrm
        p_in = [v[p.in_idx] for p in parts]
    return float(lam), v, {"iters": iters}


def _power_iteration_device(parts, n_vertices: int, degrees, iters: int,
                            seed: int, mesh
                            ) -> Tuple[float, np.ndarray, dict]:
    """Device path: matvec + reduce + Rayleigh normalization fused per
    round.  Each vertex of the in-set union is *owned* by the first node
    requesting it (host-precomputed 0/1 weights), so the squared-norm
    ``psum`` counts every vertex exactly once — the on-device analogue of
    the sim's driver-side dedup."""
    from . import engine as eng
    m = len(parts)

    def out_fn(s, e):
        return eng.ell_matvec(e["cols"], e["wts"], s["v"])

    def update_fn(s, in_raw, e, ax):
        import jax.numpy as jnp
        from jax import lax
        part = jnp.sum(e["norm_w"] * in_raw * in_raw)
        nrm = jnp.sqrt(lax.psum(part, ax))
        ok = nrm > 0
        v2 = jnp.where(ok, in_raw / jnp.maximum(nrm, 1e-30), s["v"])
        lam = jnp.where(ok, nrm, s["lam"][0]) * jnp.ones_like(s["lam"])
        return {"v": v2, "lam": lam}

    app = eng.EngineApp(name="spectral", out_fn=out_fn, update_fn=update_fn)
    engine = eng.GraphEngine(
        [p.out_idx.astype(np.uint32) for p in parts],
        [p.in_idx.astype(np.uint32) for p in parts],
        app, degrees=degrees, mesh=mesh, seed=seed)
    cols, wts = eng.stack_ell([p.ell_tables() for p in parts], engine.u_cap)

    # ownership: vertex counted at the first node (in index order) whose
    # in-set requests it — mirrors the sim's first-writer-wins assembly
    norm_w = np.zeros((m, engine.uin_cap), np.float32)
    seen = np.zeros(n_vertices, bool)
    for i, p in enumerate(parts):
        own = ~seen[p.in_idx]
        norm_w[i, : len(p.in_idx)] = own
        seen[p.in_idx] = True

    rng = np.random.RandomState(seed)
    v = rng.randn(n_vertices)
    v /= np.linalg.norm(v)
    v0 = np.zeros((m, engine.uin_cap), np.float32)
    for i, p in enumerate(parts):
        v0[i, : len(p.in_idx)] = v[p.in_idx]
    state0 = {"v": v0, "lam": np.zeros((m, 1), np.float32)}
    final, _, _ = engine.run(iters, state0,
                             {"cols": cols, "wts": wts, "norm_w": norm_w})
    v_dev = np.asarray(final["v"], np.float64)
    lam = float(np.asarray(final["lam"])[0, 0])

    v_full = np.zeros(n_vertices)
    seen[:] = False
    for i, p in enumerate(parts):
        own = ~seen[p.in_idx]
        v_full[p.in_idx[own]] = v_dev[i, : len(p.in_idx)][own]
        seen[p.in_idx] = True
    return lam, v_full, {"iters": iters, "engine": engine.sync_report()}


def power_iteration_reference(edges: np.ndarray, n_vertices: int,
                              iters: int = 30, symmetrize: bool = True,
                              seed: int = 0) -> Tuple[float, np.ndarray]:
    if symmetrize:
        edges = np.concatenate([edges, edges[:, ::-1]], axis=0)
    rng = np.random.RandomState(seed)
    v = rng.randn(n_vertices)
    v /= np.linalg.norm(v)
    lam = 0.0
    for _ in range(iters):
        q = np.zeros(n_vertices)
        np.add.at(q, edges[:, 1], v[edges[:, 0]])
        lam = np.linalg.norm(q)
        if lam == 0:
            break
        v = q / lam
    return float(lam), v
