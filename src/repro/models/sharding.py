"""Partition-spec trees for every block kind.

Each entry is a tuple over the leaf's dims (excluding the leading
period-stack dim, added by ``stacked``): "model" (TP axis), "fsdp"
(sharded over the data axes when cfg.fsdp, gathered per scan step inside
the body), or None (replicated).

These trees drive (a) pjit in/out_shardings at the launcher and (b) the
per-period all_gathers inside the shard_map body — one source of truth.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax import lax
from jax.sharding import PartitionSpec as P

from .common import ModelConfig

Tree = Dict[str, Any]


def attn_spec(cfg: ModelConfig, tp: int) -> Tree:
    kv_sh = cfg.n_kv >= tp   # else kv weights replicated, sliced per device
    s = {
        "wq": ("fsdp", "model"),
        "wk": ("fsdp", "model" if kv_sh else None),
        "wv": ("fsdp", "model" if kv_sh else None),
        "wo": ("model", "fsdp"),
    }
    if cfg.qkv_bias:
        s["bq"] = ("model",)
        s["bk"] = ("model" if kv_sh else None,)
        s["bv"] = ("model" if kv_sh else None,)
    return s


def ffn_spec(cfg: ModelConfig, tp: int) -> Tree:
    return {"w1": ("fsdp", "model"), "w3": ("fsdp", "model"),
            "w2": ("model", "fsdp")}


def moe_spec(cfg: ModelConfig, tp: int) -> Tree:
    return {"router": ("fsdp", None),
            "w1": ("model", "fsdp", None),
            "w3": ("model", "fsdp", None),
            "w2": ("model", None, "fsdp")}


def mamba_spec(cfg: ModelConfig, tp: int) -> Tree:
    return {"in_x": ("fsdp", "model"), "in_z": ("fsdp", "model"),
            "conv": (None, "model"), "w_dt": ("fsdp", "model"),
            "w_B": ("fsdp", None), "w_C": ("fsdp", None),
            "A_log": ("model", None), "D": ("model",),
            "out": ("model", "fsdp")}


def mlstm_spec(cfg: ModelConfig, tp: int) -> Tree:
    return {"wq": ("fsdp", None), "wk": ("fsdp", None),
            "wv": ("fsdp", "model"), "wi": ("fsdp", None),
            "wf": ("fsdp", None), "out": ("model", "fsdp")}


def slstm_spec(cfg: ModelConfig, tp: int) -> Tree:
    # sequential block: replicated across model (see ssm.py docstring)
    return {"wx": ("fsdp", None), "wr": (None, None, None),
            "out": ("fsdp", None), "bias": (None,)}


BLOCK_SPECS = {"attn": attn_spec, "mamba": mamba_spec,
               "mlstm": mlstm_spec, "slstm": slstm_spec}
FFN_SPECS = {"dense": ffn_spec, "moe": moe_spec}


def period_spec(cfg: ModelConfig, tp: int) -> Tree:
    out: Tree = {}
    for j, (blk, ffn) in enumerate(zip(cfg.pattern, cfg.ffn_pattern)):
        e: Tree = {"ln1": (None,), blk: BLOCK_SPECS[blk](cfg, tp)}
        if ffn != "none":
            e["ln2"] = (None,)
        if ffn in ("dense", "moe+dense"):
            e["ffn"] = ffn_spec(cfg, tp)
        if ffn in ("moe", "moe+dense"):
            e["moe"] = moe_spec(cfg, tp)
        out[f"b{j}"] = e
    return out


def model_spec(cfg: ModelConfig, tp: int) -> Tree:
    s: Tree = {"emb": ("model", None), "final_ln": (None,),
               "blocks": period_spec(cfg, tp)}
    if not cfg.tie_embeddings:
        s["head"] = (None, "model")
    if cfg.enc_layers:
        enc = {}
        for j in range(1):
            enc["b0"] = {"ln1": (None,), "attn": attn_spec(cfg, tp),
                         "ln2": (None,), "ffn": ffn_spec(cfg, tp)}
        s["enc_blocks"] = enc
        s["enc_ln"] = (None,)
        s["cross"] = attn_spec(cfg, tp)  # per-period cross-attn (decoder)
        s["ln_cross"] = (None,)
    return s


# ---------------------------------------------------------------------------
# Spec-tree -> PartitionSpec / gather helpers
# ---------------------------------------------------------------------------

def to_pspec(tree: Tree, fsdp_axes: Optional[Tuple[str, ...]],
             stacked: bool = False):
    """Spec-tuple tree -> jax PartitionSpec tree.

    stacked=True prepends the period dim (None).  fsdp_axes=None (or cfg not
    fsdp) turns "fsdp" entries into replication.
    """
    def conv(t):
        if isinstance(t, dict):
            return {k: conv(v) for k, v in t.items()}
        dims = []
        for d in t:
            if d == "fsdp":
                dims.append(fsdp_axes if fsdp_axes else None)
            else:
                dims.append(d)
        if stacked:
            dims = [None] + dims
        return P(*dims)
    return conv(tree)


def full_model_pspec(cfg: ModelConfig, tp: int,
                     fsdp_axes: Optional[Tuple[str, ...]]):
    """PartitionSpec tree for the full model param pytree (init_params)."""
    spec = model_spec(cfg, tp)
    fa = fsdp_axes if cfg.fsdp else None
    out = {"emb": to_pspec(spec["emb"], fa),
           "final_ln": to_pspec(spec["final_ln"], fa),
           "blocks": to_pspec(spec["blocks"], fa, stacked=True)}
    if "head" in spec:
        out["head"] = to_pspec(spec["head"], fa)
    if cfg.enc_layers:
        out["enc_blocks"] = to_pspec(spec["enc_blocks"], fa, stacked=True)
        out["enc_ln"] = to_pspec(spec["enc_ln"], fa)
        out["cross"] = to_pspec(spec["cross"], fa, stacked=True)
        out["ln_cross"] = to_pspec(spec["ln_cross"], fa)
    return out


def full_model_spec_tuples(cfg: ModelConfig, tp: int):
    """Raw spec-tuple tree (prepended period dim) mirroring init_params —
    used by grad sync to classify leaves (fsdp vs replicated)."""
    spec = model_spec(cfg, tp)

    def stack(t):
        if isinstance(t, dict):
            return {k: stack(v) for k, v in t.items()}
        return (None,) + tuple(t)

    out = {"emb": tuple(spec["emb"]), "final_ln": tuple(spec["final_ln"]),
           "blocks": stack(spec["blocks"])}
    if "head" in spec:
        out["head"] = tuple(spec["head"])
    if cfg.enc_layers:
        out["enc_blocks"] = stack(spec["enc_blocks"])
        out["enc_ln"] = tuple(spec["enc_ln"])
        out["cross"] = stack(spec["cross"])
        out["ln_cross"] = tuple(spec["ln_cross"])
    return out


def fsdp_gather(params: Tree, spec: Tree, fsdp_axes: Tuple[str, ...]):
    """Inside shard_map: all_gather every "fsdp" dim (transpose derives the
    reduce-scatter on the backward pass — that IS the FSDP grad sync)."""
    def g(p, s):
        if isinstance(s, dict):
            return {k: g(p[k], s[k]) for k in s}
        x = p
        for i, d in enumerate(s):
            if d == "fsdp":
                for ax in fsdp_axes:
                    x = lax.all_gather(x, ax, axis=i, tiled=True)
        return x
    return g(params, spec)


def is_fsdp_leaf(spec_leaf) -> bool:
    return any(d == "fsdp" for d in spec_leaf)


def flat_spec_leaves(tree: Tree):
    out = []

    def walk(t, path):
        if isinstance(t, dict):
            for k, v in t.items():
                walk(v, path + (k,))
        else:
            out.append((path, t))
    walk(tree, ())
    return out
