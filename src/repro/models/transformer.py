"""Model assembly: period-pattern blocks scanned over depth, inside shard_map.

One code path serves all ten assigned architectures; the period ``pattern``
in the config decides which blocks appear (attn / mamba / mlstm / slstm) and
which FFN kind follows (dense / moe / moe+dense / none).  Whisper adds an
encoder stack + per-period cross-attention; VLM prepends stub patch
embeddings.  All functions here run INSIDE shard_map (axis names passed in).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from . import attention as A
from . import moe as MOE
from . import ssm as SSM
from .common import (KeyGen, ModelConfig, act_fn, dense_init, embed,
                     lm_head_logits, lm_head_loss, rmsnorm)
from .sharding import fsdp_gather, model_spec, period_spec, to_pspec

Params = Dict[str, Any]


def padded_vocab(cfg: ModelConfig, tp: int) -> int:
    return -(-cfg.vocab // (tp * 16)) * (tp * 16)


# ---------------------------------------------------------------------------
# Global-shape parameter builders (sharded by pjit via sharding.model_spec)
# ---------------------------------------------------------------------------

def _attn_params_global(key, cfg: ModelConfig, tp: int, dtype):
    d, hd = cfg.d_model, cfg.hd
    hq = cfg.n_heads_padded(tp)
    kvw = cfg.n_kv * hd if cfg.n_kv >= tp else cfg.n_kv * hd
    ks = jax.random.split(key, 4)
    p = {"wq": dense_init(ks[0], (d, hq * hd), dtype=dtype),
         "wk": dense_init(ks[1], (d, kvw), dtype=dtype),
         "wv": dense_init(ks[2], (d, kvw), dtype=dtype),
         "wo": dense_init(ks[3], (hq * hd, d), dtype=dtype)}
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), dtype)
        p["bk"] = jnp.zeros((kvw,), dtype)
        p["bv"] = jnp.zeros((kvw,), dtype)
    return p


def _ffn_params_global(key, cfg: ModelConfig, dtype):
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {"w1": dense_init(ks[0], (d, ff), dtype=dtype),
            "w3": dense_init(ks[1], (d, ff), dtype=dtype),
            "w2": dense_init(ks[2], (ff, d), dtype=dtype)}


def _moe_params_global(key, cfg: ModelConfig, tp: int, dtype):
    d, eff = cfg.d_model, cfg.expert_d_ff
    ep = cfg.n_experts_padded(tp)
    ks = jax.random.split(key, 4)
    return {"router": dense_init(ks[0], (d, ep), dtype=jnp.float32),
            "w1": dense_init(ks[1], (ep, d, eff), scale_axis=1, dtype=dtype),
            "w3": dense_init(ks[2], (ep, d, eff), scale_axis=1, dtype=dtype),
            "w2": dense_init(ks[3], (ep, eff, d), scale_axis=1, dtype=dtype)}


def _mamba_params_global(key, cfg: ModelConfig, dtype):
    d, n, k = cfg.d_model, cfg.ssm_state, cfg.ssm_conv
    di = 2 * d
    ks = jax.random.split(key, 8)
    return {"in_x": dense_init(ks[0], (d, di), dtype=dtype),
            "in_z": dense_init(ks[1], (d, di), dtype=dtype),
            "conv": dense_init(ks[2], (k, di), dtype=dtype),
            "w_dt": dense_init(ks[3], (d, di), dtype=dtype),
            "w_B": dense_init(ks[4], (d, n), dtype=dtype),
            "w_C": dense_init(ks[5], (d, n), dtype=dtype),
            "A_log": jnp.zeros((di, n), jnp.float32),
            "D": jnp.ones((di,), jnp.float32),
            "out": dense_init(ks[6], (di, d), dtype=dtype)}


def _mlstm_params_global(key, cfg: ModelConfig, dtype):
    d, h = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 6)
    return {"wq": dense_init(ks[0], (d, d), dtype=dtype),
            "wk": dense_init(ks[1], (d, d), dtype=dtype),
            "wv": dense_init(ks[2], (d, d), dtype=dtype),
            "wi": dense_init(ks[3], (d, h), dtype=jnp.float32),
            "wf": dense_init(ks[4], (d, h), dtype=jnp.float32),
            "out": dense_init(ks[5], (d, d), dtype=dtype)}


def _slstm_params_global(key, cfg: ModelConfig, dtype):
    d, h = cfg.d_model, cfg.n_heads
    dh = d // h
    ks = jax.random.split(key, 3)
    return {"wx": dense_init(ks[0], (d, 4 * d), dtype=dtype),
            "wr": dense_init(ks[1], (h, dh, 4 * dh), scale_axis=1, dtype=dtype),
            "out": dense_init(ks[2], (d, d), dtype=dtype),
            "bias": jnp.zeros((4 * d,), jnp.float32)}


_BLOCK_BUILDERS = {
    "attn": lambda k, cfg, tp, dt: _attn_params_global(k, cfg, tp, dt),
    "mamba": lambda k, cfg, tp, dt: _mamba_params_global(k, cfg, dt),
    "mlstm": lambda k, cfg, tp, dt: _mlstm_params_global(k, cfg, dt),
    "slstm": lambda k, cfg, tp, dt: _slstm_params_global(k, cfg, dt),
}


def _period_params(key, cfg: ModelConfig, tp: int, dtype):
    out = {}
    kg = jax.random.split(key, 3 * len(cfg.pattern))
    for j, (blk, ffn) in enumerate(zip(cfg.pattern, cfg.ffn_pattern)):
        e = {"ln1": jnp.zeros((cfg.d_model,), jnp.float32),
             blk: _BLOCK_BUILDERS[blk](kg[3 * j], cfg, tp, dtype)}
        if ffn != "none":
            e["ln2"] = jnp.zeros((cfg.d_model,), jnp.float32)
        if ffn in ("dense", "moe+dense"):
            e["ffn"] = _ffn_params_global(kg[3 * j + 1], cfg, dtype)
        if ffn in ("moe", "moe+dense"):
            e["moe"] = _moe_params_global(kg[3 * j + 2], cfg, tp, dtype)
        out[f"b{j}"] = e
    return out


def init_params(cfg: ModelConfig, tp: int, seed: int = 0) -> Params:
    """Global-shape parameter pytree (shard via sharding.model_spec)."""
    kg = KeyGen(seed)
    dtype = cfg.dtype
    vp = padded_vocab(cfg, tp)
    keys = jax.random.split(kg(), cfg.n_periods)
    blocks = jax.vmap(lambda k: _period_params(k, cfg, tp, dtype))(keys)
    p: Params = {
        "emb": dense_init(kg(), (vp, cfg.d_model), scale_axis=1, dtype=dtype),
        "final_ln": jnp.zeros((cfg.d_model,), jnp.float32),
        "blocks": blocks,
    }
    if not cfg.tie_embeddings:
        p["head"] = dense_init(kg(), (cfg.d_model, vp), dtype=dtype)
    if cfg.enc_layers:
        ekeys = jax.random.split(kg(), cfg.enc_layers)

        def enc_period(k):
            ks = jax.random.split(k, 2)
            return {"b0": {"ln1": jnp.zeros((cfg.d_model,), jnp.float32),
                           "attn": _attn_params_global(ks[0], cfg, tp, dtype),
                           "ln2": jnp.zeros((cfg.d_model,), jnp.float32),
                           "ffn": _ffn_params_global(ks[1], cfg, dtype)}}
        p["enc_blocks"] = jax.vmap(enc_period)(ekeys)
        p["enc_ln"] = jnp.zeros((cfg.d_model,), jnp.float32)
        ckeys = jax.random.split(kg(), cfg.n_periods)
        p["cross"] = jax.vmap(
            lambda k: _attn_params_global(k, cfg, tp, dtype))(ckeys)
        p["ln_cross"] = jnp.zeros((cfg.d_model,), jnp.float32)
    return p


# ---------------------------------------------------------------------------
# Localization inside shard_map (kv replication slice)
# ---------------------------------------------------------------------------

def _localize_attn(p: Params, cfg: ModelConfig, tp_axis: str, tp: int):
    """Slice replicated kv weights down to this device's kv head(s)."""
    if cfg.n_kv >= tp:
        return p
    kvl, hd = cfg.kv_local(tp), cfg.hd
    idx = (lax.axis_index(tp_axis) * cfg.n_kv) // tp
    q = dict(p)
    q["wk"] = lax.dynamic_slice_in_dim(p["wk"], idx * kvl * hd, kvl * hd, 1)
    q["wv"] = lax.dynamic_slice_in_dim(p["wv"], idx * kvl * hd, kvl * hd, 1)
    if cfg.qkv_bias:
        q["bk"] = lax.dynamic_slice_in_dim(p["bk"], idx * kvl * hd, kvl * hd, 0)
        q["bv"] = lax.dynamic_slice_in_dim(p["bv"], idx * kvl * hd, kvl * hd, 0)
    return q


def ffn_fwd(p: Params, x: jax.Array, cfg: ModelConfig, tp_axis: str):
    h = act_fn(jnp.einsum("btd,df->btf", x, p["w1"]), cfg.act) \
        * jnp.einsum("btd,df->btf", x, p["w3"])
    return lax.psum(jnp.einsum("btf,fd->btd", h, p["w2"]), tp_axis)


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def _attn_any(pa, h, cfg, ax, w, positions, causal=True, return_kv=False):
    """Dispatch: blocked (flash-style) attention for long sequences."""
    fn = A.attn_train_blocked if h.shape[1] >= A.BLOCKED_ATTN_THRESHOLD \
        else A.attn_train
    return fn(pa, h, cfg, ax.tp_axis, ax.tp, w, positions=positions,
              causal=causal, return_kv=return_kv)


@dataclasses.dataclass(frozen=True)
class AxisCtx:
    tp_axis: str = "model"
    tp: int = 1
    dp_axes: Tuple[str, ...] = ("data",)
    fsdp_axes: Optional[Tuple[str, ...]] = None


def _make_ckpt(cfg: ModelConfig):
    """Per-block remat wrapper honoring cfg.remat_policy (perf knob):
    "full"  — recompute everything in the backward (min memory);
    "dots"  — save matmul outputs, recompute elementwise only (cuts the
              remat recompute FLOPs; SPerf hillclimb H3)."""
    import functools
    if cfg.remat_policy == "dots":
        pol = jax.checkpoint_policies.checkpoint_dots
        return functools.partial(jax.checkpoint, policy=pol)
    return jax.checkpoint


def _period_fwd(pp: Params, x: jax.Array, cfg: ModelConfig, ax: AxisCtx,
                positions: jax.Array, cross_kv=None, cross_p=None,
                ln_cross=None, causal: bool = True):
    """One period of blocks, full-sequence.  Returns (x, aux_loss).

    Each block is individually remat'd (nested under the period-scan
    checkpoint): the backward pass holds ONE block's internals at a time —
    without this, rematerializing a whole jamba period keeps 7 mamba scans
    + 4 MoE dispatch buffers live simultaneously (~180 GB/device measured).
    """
    aux = jnp.zeros((), jnp.float32)
    ckpt = _make_ckpt(cfg)
    for j, (blk, ffn) in enumerate(zip(cfg.pattern, cfg.ffn_pattern)):
        e = pp[f"b{j}"]
        w = cfg.window_pattern[j] if cfg.window_pattern else cfg.window

        def mixer(e, x):
            h = rmsnorm(x, e["ln1"], cfg.norm_eps)
            if blk == "attn":
                pa = _localize_attn(e["attn"], cfg, ax.tp_axis, ax.tp)
                return x + _attn_any(pa, h, cfg, ax, w, positions,
                                     causal=causal)
            if blk == "mamba":
                return x + SSM.mamba_train(e["mamba"], h, cfg, ax.tp_axis,
                                           ax.tp)
            if blk == "mlstm":
                return x + SSM.mlstm_train(e["mlstm"], h, cfg, ax.tp_axis,
                                           ax.tp)
            if blk == "slstm":
                return x + SSM.slstm_train(e["slstm"], h, cfg, ax.tp_axis,
                                           ax.tp)
            raise ValueError(blk)

        x = ckpt(mixer)(e, x)
        if cross_kv is not None and blk == "attn":
            def crossblk(cp, ck, cv, x):
                hc = rmsnorm(x, ln_cross, cfg.norm_eps)
                pc = _localize_attn(cp, cfg, ax.tp_axis, ax.tp)
                return x + A.cross_attn(pc, hc, ck, cv, cfg, ax.tp_axis,
                                        ax.tp)
            x = ckpt(crossblk)(cross_p, cross_kv[0], cross_kv[1], x)
        if ffn == "none":
            continue

        def ffnblk(e, x):
            h2 = rmsnorm(x, e["ln2"], cfg.norm_eps)
            y2 = jnp.zeros_like(x)
            a = jnp.zeros((), jnp.float32)
            if ffn in ("dense", "moe+dense"):
                y2 = y2 + ffn_fwd(e["ffn"], h2, cfg, ax.tp_axis)
            if ffn in ("moe", "moe+dense"):
                ym, a, _ = MOE.moe_ffn(e["moe"], h2, cfg, ax.tp_axis, ax.tp,
                                       capacity_factor=cfg.moe_capacity,
                                       token_shard=cfg.moe_token_shard)
                y2 = y2 + ym
            return x + y2, a

        x, a = ckpt(ffnblk)(e, x)
        aux = aux + a
    return x, aux


def encoder_fwd(params: Params, frames: jax.Array, cfg: ModelConfig,
                ax: AxisCtx) -> jax.Array:
    """Whisper-style encoder over stub frame embeddings [B, S, d]."""
    x = frames
    t = frames.shape[1]
    pos = jnp.arange(t, dtype=jnp.int32)[None].repeat(frames.shape[0], 0)

    def body(x, pp):
        x, _ = _period_fwd(pp, x, cfg, ax, pos, causal=False)
        return x, None

    x, _ = lax.scan(body, x, params["enc_blocks"])
    return rmsnorm(x, params["enc_ln"], cfg.norm_eps)


def forward_loss(params: Params, tokens: jax.Array, labels: jax.Array,
                 cfg: ModelConfig, ax: AxisCtx,
                 extra_embeds: Optional[jax.Array] = None,
                 enc_frames: Optional[jax.Array] = None,
                 loss_mask: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Training forward. tokens/labels [B, T_text].  Returns (loss, aux)."""
    x = embed(params["emb"], tokens, ax.tp_axis).astype(cfg.dtype)
    mask = loss_mask
    if extra_embeds is not None:  # VLM: prepend patch embeddings
        b, ti = extra_embeds.shape[:2]
        x = jnp.concatenate([extra_embeds.astype(cfg.dtype), x], axis=1)
        pad_lbl = jnp.zeros((b, ti), labels.dtype)
        labels = jnp.concatenate([pad_lbl, labels], axis=1)
        m0 = jnp.ones_like(tokens, jnp.float32) if mask is None else mask
        mask = jnp.concatenate([jnp.zeros((b, ti), jnp.float32), m0], axis=1)
    b, t, _ = x.shape
    positions = jnp.arange(t, dtype=jnp.int32)[None].repeat(b, 0)

    cross_kv = None
    if cfg.enc_layers:
        enc_out = encoder_fwd(params, enc_frames.astype(cfg.dtype), cfg, ax)

    def body(carry, pp_and_cross):
        x, aux = carry
        if cfg.enc_layers:
            pp, cross_p = pp_and_cross
            pa = _localize_attn(cross_p, cfg, ax.tp_axis, ax.tp)
            ckv = A.encode_kv(pa, enc_out, cfg, ax.tp)
            x, a = _period_fwd(pp, x, cfg, ax, positions, cross_kv=ckv,
                               cross_p=cross_p, ln_cross=params["ln_cross"])
        else:
            pp = pp_and_cross
            if ax.fsdp_axes:
                pp = fsdp_gather(pp, period_spec(cfg, ax.tp), ax.fsdp_axes)
            x, a = _period_fwd(pp, x, cfg, ax, positions)
        return (x, aux + a), None

    xs = (params["blocks"], params["cross"]) if cfg.enc_layers \
        else params["blocks"]
    (x, aux), _ = lax.scan(jax.checkpoint(body), (x, jnp.zeros((), jnp.float32)),
                           xs)
    x = rmsnorm(x, params["final_ln"], cfg.norm_eps)
    head = params["emb"].T if cfg.tie_embeddings else params["head"]
    # mask out padded vocab columns via label validity only (padded ids never
    # appear as labels; padded logits participate in softmax as noise columns
    # with ~N(0, 1/d) init — acceptable, noted in DESIGN).
    loss = lm_head_loss(x, head.astype(jnp.float32), labels, ax.tp_axis, mask)
    return loss, aux


# ---------------------------------------------------------------------------
# Prefill / decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, b: int, max_seq: int, tp: int,
               seq_shards: int = 1):
    """Stacked per-period cache pytree (attn caches hold S/seq_shards)."""
    s_loc = max_seq // seq_shards
    kvl, hd = cfg.kv_local(tp), cfg.hd
    per = {}
    for j, blk in enumerate(cfg.pattern):
        if blk == "attn":
            per[f"b{j}"] = {
                "k": jnp.zeros((cfg.n_periods, b, s_loc, kvl, hd), cfg.dtype),
                "v": jnp.zeros((cfg.n_periods, b, s_loc, kvl, hd), cfg.dtype)}
        elif blk == "mamba":
            st = SSM.mamba_init_state(b, cfg, tp, cfg.dtype)
            per[f"b{j}"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (cfg.n_periods,) + x.shape), st)
        elif blk == "mlstm":
            st = SSM.mlstm_init_state(b, cfg, tp)
            per[f"b{j}"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (cfg.n_periods,) + x.shape), st)
        elif blk == "slstm":
            st = SSM.slstm_init_state(b, cfg)
            per[f"b{j}"] = tuple(
                jnp.broadcast_to(x, (cfg.n_periods,) + x.shape) for x in st)
    return per


def forward_decode(params: Params, token: jax.Array, pos: jax.Array,
                   cache, cfg: ModelConfig, ax: AxisCtx,
                   seq_axis: Optional[str] = None, seq_shards: int = 1,
                   cross_cache=None, serve2d: bool = False,
                   mesh_sizes=None) -> Tuple[jax.Array, Any]:
    """One decode step.  token [B] ids; pos [B]; returns (local logits
    [B, V_local], new cache).  seq_axis set => split-KV sharded cache.

    serve2d: 2D weight-stationary decode (SPerf H4) — FSDP shards are used
    in place (no per-period weight gathers); activations batch-replicate
    around each projection instead.  Dense-attention fsdp archs with a
    batch-sharded cache only (not with seq_axis; MoE/SSM: future work).
    """
    if serve2d:
        assert cfg.fsdp, "serve2d: fsdp archs only"
        assert all(b in ("attn", "mamba") for b in cfg.pattern), \
            "serve2d: attn/mamba blocks (mlstm/slstm archs are not fsdp)"
    x = embed(params["emb"], token[:, None], ax.tp_axis).astype(cfg.dtype)

    def body(x, scanned):
        if cfg.enc_layers:
            pp, cc, cross_p, ckv = scanned
        else:
            pp, cc = scanned
            cross_p = ckv = None
        if ax.fsdp_axes and not cfg.enc_layers and not serve2d:
            pp = fsdp_gather(pp, period_spec(cfg, ax.tp), ax.fsdp_axes)
        new_cc = {}
        for j, (blk, ffn) in enumerate(zip(cfg.pattern, cfg.ffn_pattern)):
            e = pp[f"b{j}"]
            h = rmsnorm(x, e["ln1"], cfg.norm_eps)
            w = cfg.window_pattern[j] if cfg.window_pattern else cfg.window
            if blk == "attn" and serve2d:
                y, nk, nv = A.attn_decode_2d(
                    e["attn"], h, cc[f"b{j}"]["k"], cc[f"b{j}"]["v"], pos,
                    cfg, ax.tp_axis, ax.tp, w, ax.fsdp_axes, mesh_sizes,
                    seq_axis=seq_axis, seq_shards=seq_shards)
                new_cc[f"b{j}"] = {"k": nk, "v": nv}
            elif blk == "attn":
                pa = _localize_attn(e["attn"], cfg, ax.tp_axis, ax.tp)
                if seq_axis is not None:
                    y, nk, nv = A.attn_decode_splitkv(
                        pa, h, cc[f"b{j}"]["k"], cc[f"b{j}"]["v"], pos, cfg,
                        ax.tp_axis, ax.tp, w, seq_axis, seq_shards)
                else:
                    y, nk, nv = A.attn_decode(
                        pa, h, cc[f"b{j}"]["k"], cc[f"b{j}"]["v"], pos, cfg,
                        ax.tp_axis, ax.tp, w)
                new_cc[f"b{j}"] = {"k": nk, "v": nv}
            elif blk == "mamba" and serve2d:
                from . import serve2d as S2D
                y, st = S2D.mamba_decode_2d(
                    e["mamba"], h, cc[f"b{j}"], cfg, ax.tp_axis, ax.tp,
                    ax.fsdp_axes, mesh_sizes,
                    batch_replicated=seq_axis is not None)
                new_cc[f"b{j}"] = st
            elif blk == "mamba":
                y, st = SSM.mamba_decode(e["mamba"], h, cc[f"b{j}"], cfg,
                                         ax.tp_axis, ax.tp)
                new_cc[f"b{j}"] = st
            elif blk == "mlstm":
                y, st = SSM.mlstm_decode(e["mlstm"], h, cc[f"b{j}"], cfg,
                                         ax.tp_axis, ax.tp)
                new_cc[f"b{j}"] = st
            elif blk == "slstm":
                y, st = SSM.slstm_decode(e["slstm"], h, cc[f"b{j}"], cfg,
                                         ax.tp_axis, ax.tp)
                new_cc[f"b{j}"] = st
            x = x + y
            if ckv is not None and blk == "attn":
                hc = rmsnorm(x, params["ln_cross"], cfg.norm_eps)
                pc = _localize_attn(cross_p, cfg, ax.tp_axis, ax.tp)
                x = x + A.cross_attn(pc, hc, ckv[0], ckv[1], cfg,
                                     ax.tp_axis, ax.tp)
            if ffn == "none":
                continue
            h2 = rmsnorm(x, e["ln2"], cfg.norm_eps)
            y2 = jnp.zeros_like(x)
            if ffn in ("dense", "moe+dense"):
                if serve2d:
                    y2 = y2 + A.ffn_2d(e["ffn"], h2, cfg, ax.tp_axis,
                                       ax.fsdp_axes, mesh_sizes,
                                       batch_replicated=seq_axis is not None)
                else:
                    y2 = y2 + ffn_fwd(e["ffn"], h2, cfg, ax.tp_axis)
            if ffn in ("moe", "moe+dense"):
                if serve2d:
                    from . import serve2d as S2D
                    ym = S2D.moe_ffn_2d(e["moe"], h2, cfg, ax.tp_axis,
                                        ax.tp, ax.fsdp_axes, mesh_sizes,
                                        batch_replicated=seq_axis is not None)
                else:
                    ym, _, _ = MOE.moe_ffn(
                        e["moe"], h2, cfg, ax.tp_axis, ax.tp,
                        capacity_factor=cfg.moe_capacity,
                        token_shard=cfg.moe_token_shard)
                y2 = y2 + ym
            x = x + y2
        return x, new_cc

    if cfg.enc_layers:
        xs = (params["blocks"], cache, params["cross"], cross_cache)
    else:
        xs = (params["blocks"], cache)
    x, new_cache = lax.scan(body, x, xs)
    x = rmsnorm(x, params["final_ln"], cfg.norm_eps)
    head = params["emb"].T if cfg.tie_embeddings else params["head"]
    logits = lm_head_logits(x, head.astype(jnp.float32))[:, 0]
    return logits, new_cache


def forward_prefill(params: Params, tokens: jax.Array, cfg: ModelConfig,
                    ax: AxisCtx, max_seq: int,
                    enc_frames: Optional[jax.Array] = None,
                    extra_embeds: Optional[jax.Array] = None
                    ) -> Tuple[jax.Array, Any]:
    """Prompt forward; returns (last-position local logits [B, V_local],
    cache sized to ``max_seq``).  Prefill is always dense over the prompt."""
    x = embed(params["emb"], tokens, ax.tp_axis).astype(cfg.dtype)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(cfg.dtype), x], axis=1)
    b, t, _ = x.shape
    positions = jnp.arange(t, dtype=jnp.int32)[None].repeat(b, 0)
    if cfg.enc_layers:
        enc_out = encoder_fwd(params, enc_frames.astype(cfg.dtype), cfg, ax)

    def body(x, scanned):
        if cfg.enc_layers:
            pp, cross_p = scanned
        else:
            pp = scanned
            cross_p = None
            if ax.fsdp_axes:
                pp = fsdp_gather(pp, period_spec(cfg, ax.tp), ax.fsdp_axes)
        cc = {}
        xx = x
        for j, (blk, ffn) in enumerate(zip(cfg.pattern, cfg.ffn_pattern)):
            e = pp[f"b{j}"]
            h = rmsnorm(xx, e["ln1"], cfg.norm_eps)
            w = cfg.window_pattern[j] if cfg.window_pattern else cfg.window
            if blk == "attn":
                pa = _localize_attn(e["attn"], cfg, ax.tp_axis, ax.tp)
                y, (k, v) = _attn_any(pa, h, cfg, ax, w, positions,
                                      return_kv=True)
                pad = max_seq - t
                cc[f"b{j}"] = {
                    "k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
                    "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))}
            elif blk == "mamba":
                y, st = SSM.mamba_train(e["mamba"], h, cfg, ax.tp_axis, ax.tp,
                                        return_state=True)
                cc[f"b{j}"] = st
            elif blk == "mlstm":
                y, st = SSM.mlstm_train(e["mlstm"], h, cfg, ax.tp_axis, ax.tp,
                                        return_state=True)
                cc[f"b{j}"] = st
            elif blk == "slstm":
                y, st = SSM.slstm_train(e["slstm"], h, cfg, ax.tp_axis, ax.tp,
                                        return_state=True)
                cc[f"b{j}"] = st
            xx = xx + y
            if cfg.enc_layers and blk == "attn":
                hc = rmsnorm(xx, params["ln_cross"], cfg.norm_eps)
                pc = _localize_attn(cross_p, cfg, ax.tp_axis, ax.tp)
                ck, cv = A.encode_kv(pc, enc_out, cfg, ax.tp)
                xx = xx + A.cross_attn(pc, hc, ck, cv, cfg, ax.tp_axis, ax.tp)
            if ffn == "none":
                continue
            h2 = rmsnorm(xx, e["ln2"], cfg.norm_eps)
            y2 = jnp.zeros_like(xx)
            if ffn in ("dense", "moe+dense"):
                y2 = y2 + ffn_fwd(e["ffn"], h2, cfg, ax.tp_axis)
            if ffn in ("moe", "moe+dense"):
                ym, _, _ = MOE.moe_ffn(e["moe"], h2, cfg, ax.tp_axis, ax.tp,
                                       capacity_factor=cfg.moe_capacity,
                                       token_shard=cfg.moe_token_shard)
                y2 = y2 + ym
            xx = xx + y2
        return xx, cc

    xs = (params["blocks"], params["cross"]) if cfg.enc_layers \
        else params["blocks"]
    x, cache = lax.scan(jax.checkpoint(body), x, xs)
    x = rmsnorm(x[:, -1:], params["final_ln"], cfg.norm_eps)
    head = params["emb"].T if cfg.tie_embeddings else params["head"]
    logits = lm_head_logits(x, head.astype(jnp.float32))[:, 0]
    return logits, cache


def build_cross_cache(params: Params, enc_frames: jax.Array,
                      cfg: ModelConfig, ax: AxisCtx):
    """Whisper: encoder forward + per-period cross K/V."""
    enc_out = encoder_fwd(params, enc_frames.astype(cfg.dtype), cfg, ax)

    def per(cross_p):
        pa = _localize_attn(cross_p, cfg, ax.tp_axis, ax.tp)
        k, v = A.encode_kv(pa, enc_out, cfg, ax.tp)
        return k, v

    return jax.vmap(per)(params["cross"]) if False else \
        lax.map(per, params["cross"])
