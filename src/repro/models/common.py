"""Model substrate: configs, init, norms, rope, sharded embedding/head.

Every model in the zoo is built from a *period pattern* of blocks scanned
over the depth (jax.lax.scan with stacked params + remat), and runs INSIDE
shard_map with explicit tensor-parallel collectives over the "model" mesh
axis — the framework owns its collective schedule (that is the paper's
subject matter), nothing is delegated to GSPMD auto-sharding.

Parallelism per device (mesh axes ("pod",) "data", "model"):
  * batch over ("pod","data")          — data parallel
  * attention heads / ffn hidden / vocab / experts over "model"
  * optional FSDP: params + optimizer state sharded over the data axes,
    all-gathered per scan step (transpose auto-derives reduce-scatter).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    # block pattern for ONE period; scanned n_layers/len(pattern) times.
    # entries: "attn", "mamba", "mlstm", "slstm" each paired with an ffn kind
    pattern: Tuple[str, ...] = ("attn",)
    ffn_pattern: Tuple[str, ...] = ("dense",)   # dense | moe | moe+dense | none
    # attention
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    window: int = 0                 # sliding window size; 0 = full
    window_pattern: Tuple[int, ...] = ()  # per-period-layer window (0=full)
    logit_softcap: float = 0.0
    # moe
    n_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    # ssm
    ssm_state: int = 16
    ssm_conv: int = 4
    # enc-dec / frontend stubs
    enc_layers: int = 0             # >0 => encoder-decoder (audio)
    enc_seq: int = 0                # encoder length (stub frame embeddings)
    img_tokens: int = 0             # >0 => VLM stub patch embeddings
    # numerics / distribution
    dtype: Any = jnp.bfloat16
    fsdp: bool = False
    tie_embeddings: bool = True
    act: str = "silu"               # silu (swiglu) | gelu
    norm_eps: float = 1e-6
    moe_capacity: float = 2.0       # dispatch capacity factor (perf knob)
    remat_policy: str = "full"      # full | dots (save matmul outputs)
    moe_token_shard: bool = True    # dedup replicated tokens across TP (SPerf H2)

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_periods(self) -> int:
        assert self.n_layers % len(self.pattern) == 0, \
            f"{self.n_layers} layers vs period {len(self.pattern)}"
        return self.n_layers // len(self.pattern)

    def heads_local(self, tp: int) -> int:
        return max(1, -(-self.n_heads // tp))   # ceil; padded heads masked

    def n_heads_padded(self, tp: int) -> int:
        return self.heads_local(tp) * tp

    def kv_local(self, tp: int) -> int:
        return max(1, self.n_kv // tp)

    def experts_local(self, tp: int) -> int:
        return max(1, -(-self.n_experts // tp))

    def n_experts_padded(self, tp: int) -> int:
        return self.experts_local(tp) * tp

    def reduced(self, **kw) -> "ModelConfig":
        """Smoke-test variant: <=2 periods, small dims, <=4 experts."""
        period = len(self.pattern)
        small = dict(
            n_layers=period, d_model=256, n_heads=4, n_kv=2,
            d_ff=512, vocab=512, head_dim=64,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            expert_d_ff=128 if self.n_experts else 0,
            enc_layers=1 if self.enc_layers else 0,
            enc_seq=32 if self.enc_seq else 0,
            img_tokens=8 if self.img_tokens else 0,
            window=min(self.window, 16) if self.window else 0,
            window_pattern=tuple(min(w, 16) for w in self.window_pattern),
            dtype=jnp.float32, fsdp=False)
        small.update(kw)
        return dataclasses.replace(self, **small)

    def param_count(self) -> float:
        """Approximate total parameters (for 6ND roofline accounting)."""
        d, ff, hd = self.d_model, self.d_ff, self.hd
        per_layer = {}
        attn = d * hd * self.n_heads + 2 * d * hd * self.n_kv + hd * self.n_heads * d
        dense_ffn = 3 * d * ff if self.act == "silu" else 2 * d * ff
        moe_ffn = self.n_experts * 3 * d * self.expert_d_ff + d * self.n_experts \
            if self.n_experts else 0
        ssm_inner = 2 * d
        mamba = d * ssm_inner * 2 + ssm_inner * (self.ssm_state * 2 + 2) \
            + ssm_inner * d
        total = 0.0
        for blk, ffn in zip(self.pattern, self.ffn_pattern):
            if blk == "attn":
                total += attn
            elif blk == "mamba":
                total += mamba
            elif blk in ("mlstm", "slstm"):
                total += 4 * d * d  # qkv/io projections approx
            if ffn == "dense":
                total += dense_ffn
            elif ffn == "moe":
                total += moe_ffn
            elif ffn == "moe+dense":
                total += moe_ffn + dense_ffn
        total *= self.n_periods
        total += self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.enc_layers:
            total += self.enc_layers * (attn + dense_ffn)
        return total

    def active_param_count(self) -> float:
        """Active params per token (MoE: top_k of n_experts)."""
        if not self.n_experts:
            return self.param_count()
        full = self.param_count()
        moe_total = self.n_periods * sum(
            self.n_experts * 3 * self.d_model * self.expert_d_ff
            for f in self.ffn_pattern if f in ("moe", "moe+dense"))
        moe_active = moe_total * self.top_k / self.n_experts
        return full - moe_total + moe_active


# ---------------------------------------------------------------------------
# Elementwise pieces
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, g: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return ((x32 * scale) * (1.0 + g.astype(jnp.float32))).astype(x.dtype)


def act_fn(x: jax.Array, kind: str) -> jax.Array:
    return jax.nn.silu(x) if kind == "silu" else jax.nn.gelu(x)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., T, H, hd]; positions: [..., T] int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs      # [..., T, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]
    sin = sin[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Vocab-sharded embedding + LM head (run inside shard_map)
# ---------------------------------------------------------------------------

def embed(emb_local: jax.Array, ids: jax.Array, tp_axis: str) -> jax.Array:
    """emb_local: [V_local, d] shard on tp_axis; ids global int32 [...]."""
    v_local = emb_local.shape[0]
    shard = lax.axis_index(tp_axis)
    lo = shard * v_local
    loc = ids - lo
    ok = (loc >= 0) & (loc < v_local)
    safe = jnp.clip(loc, 0, v_local - 1)
    out = emb_local[safe] * ok[..., None].astype(emb_local.dtype)
    return lax.psum(out, tp_axis)


def lm_head_loss(x: jax.Array, head_local: jax.Array, labels: jax.Array,
                 tp_axis: str, mask: Optional[jax.Array] = None) -> jax.Array:
    """Cross-entropy with vocab-sharded logits.

    x: [B, T, d]; head_local: [d, V_local]; labels: [B, T] global ids.
    Stable softmax via psum(max) / psum(sumexp) over the tp axis.
    """
    logits = jnp.einsum("btd,dv->btv", x.astype(jnp.float32),
                        head_local.astype(jnp.float32))
    v_local = head_local.shape[1]
    shard = lax.axis_index(tp_axis)
    lo = shard * v_local
    # stop_gradient: the stabilizer contributes zero gradient and pmax has
    # no differentiation rule
    gmax = lax.pmax(jnp.max(lax.stop_gradient(logits), axis=-1),
                    tp_axis)                                      # [B, T]
    z = jnp.exp(logits - gmax[..., None])
    denom = lax.psum(jnp.sum(z, axis=-1), tp_axis)                # [B, T]
    loc = labels - lo
    ok = (loc >= 0) & (loc < v_local)
    safe = jnp.clip(loc, 0, v_local - 1)
    picked = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    picked = lax.psum(jnp.where(ok, picked - gmax, 0.0), tp_axis)
    nll = jnp.log(denom) - picked
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def lm_head_logits(x: jax.Array, head_local: jax.Array) -> jax.Array:
    """Local logits shard [B, T, V_local] (serving keeps them sharded)."""
    return jnp.einsum("btd,dv->btv", x.astype(jnp.float32),
                      head_local.astype(jnp.float32))


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, scale_axis: int = 0, dtype=jnp.float32):
    fan_in = shape[scale_axis]
    return (jax.random.normal(key, shape, jnp.float32)
            / math.sqrt(fan_in)).astype(dtype)


class KeyGen:
    def __init__(self, seed: int):
        self.key = jax.random.PRNGKey(seed)

    def __call__(self):
        self.key, k = jax.random.split(self.key)
        return k
