"""SSM blocks: Mamba (jamba hybrid) and xLSTM (mLSTM / sLSTM).

TPU adaptations (recorded in DESIGN.md):
  * Mamba's selective scan uses ``lax.associative_scan`` over time on the
    diagonal recurrence h_t = a_t h_{t-1} + b_t (parallel prefix — the GPU
    kernel's work-efficient scan maps directly onto this).
  * mLSTM uses the *chunkwise* linear-attention form: quadratic attention
    within chunks of ``CHUNK`` tokens, a tiny recurrent state
    [B, H, dk, dv_local] carried across chunks by lax.scan — this is the
    standard TPU/MXU formulation (matmul-rich, O(T·c) memory instead of the
    O(T·dk·dv) a naive scan would materialize).
  * sLSTM has true recurrence (R·h_{t-1} inside the gates) and cannot be
    parallelized over time; it runs as lax.scan over steps, *replicated*
    across the model axis (its FLOPs share in xlstm-1.3b is 1/8 of layers;
    TP would insert a psum per step for no win — noted as non-transferable
    parallelism).

Sharding: mamba inner channels and mLSTM value-dim shard over "model";
q/k and gates are computed from replicated weights.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .common import ModelConfig, dense_init

CHUNK = 128


# ---------------------------------------------------------------------------
# Mamba
# ---------------------------------------------------------------------------

def mamba_inner(cfg: ModelConfig, tp: int) -> int:
    di = 2 * cfg.d_model
    return max(8, di // tp)


def mamba_params(key, cfg: ModelConfig, tp: int, dtype):
    d, n = cfg.d_model, cfg.ssm_state
    dil = mamba_inner(cfg, tp)
    ks = jax.random.split(key, 8)
    return {
        "in_x": dense_init(ks[0], (d, dil), dtype=dtype),
        "in_z": dense_init(ks[1], (d, dil), dtype=dtype),
        "conv": dense_init(ks[2], (cfg.ssm_conv, dil), dtype=dtype),
        "w_dt": dense_init(ks[3], (d, dil), dtype=dtype),
        "w_B": dense_init(ks[4], (d, n), dtype=dtype),
        "w_C": dense_init(ks[5], (d, n), dtype=dtype),
        "A_log": jnp.zeros((dil, n), jnp.float32),
        "D": jnp.ones((dil,), jnp.float32),
        "out": dense_init(ks[6], (dil, d), dtype=dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """x [B,T,C], w [K,C]: depthwise causal conv via shifted adds."""
    k = w.shape[0]
    out = x * w[k - 1]
    for i in range(1, k):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :-i]
        out = out + shifted * w[k - 1 - i]
    return out


SCAN_CHUNK = 256


def _chunked_selective_scan(dt: jax.Array, xi: jax.Array, Bm: jax.Array,
                            Cm: jax.Array, A: jax.Array):
    """y_t = C_t . h_t,  h_t = exp(dt_t A) h_{t-1} + (dt_t xi_t) B_t —
    chunked + fully fused.

    dt, xi: [B,T,dil] f32; Bm, Cm: [B,T,n] f32; A: [dil,n].  The [*,dil,n]
    gate/state tensors exist per chunk of SCAN_CHUNK steps only — neither
    the gates nor h ever materialize full-sequence (measured ~8 GB/layer
    saved).  Returns (y [B,T,dil], final state h [B,dil,n]).
    """
    bsz, t, dil = dt.shape
    n = Bm.shape[-1]
    ck = min(SCAN_CHUNK, t)
    nc = t // ck
    assert t % ck == 0

    def chunked(x):
        return x.reshape((bsz, nc, ck) + x.shape[2:]).swapaxes(0, 1)

    def comb(l, r):
        al, bl = l
        ar_, br_ = r
        return al * ar_, bl * ar_ + br_

    def chunk_step(carry, inp):
        dt_c, xi_c, b_c, c_c = inp                # [B,ck,dil] / [B,ck,n]
        a_c = jnp.exp(dt_c[..., None] * A)        # [B,ck,dil,n]
        bt_c = (dt_c * xi_c)[..., None] * b_c[:, :, None, :]
        acum, hin = lax.associative_scan(comb, (a_c, bt_c), axis=1)
        h = hin + acum * carry[:, None]
        y_c = jnp.einsum("bkcn,bkn->bkc", h, c_c)  # C-contraction fused too
        return h[:, -1], y_c

    h0 = jnp.zeros((bsz, dil, n), jnp.float32)
    h_fin, ys = lax.scan(chunk_step, h0,
                         (chunked(dt), chunked(xi), chunked(Bm), chunked(Cm)))
    return ys.swapaxes(0, 1).reshape(bsz, t, dil), h_fin


def _chunked_linear_scan(a: jax.Array, b: jax.Array) -> jax.Array:
    """h_t = a_t * h_{t-1} + b_t over axis 1, chunked (mamba2-style).

    Within a chunk of SCAN_CHUNK steps: parallel associative scan; across
    chunks: a tiny sequential lax.scan carrying [B, dil, n] state.  Peak
    memory O(B * chunk * dil * n) instead of O(B * T * dil * n * log T).
    """
    bsz, t = a.shape[0], a.shape[1]
    ck = min(SCAN_CHUNK, t)
    nc = t // ck
    assert t % ck == 0, f"seq {t} % chunk {ck}"
    ar = a.reshape((bsz, nc, ck) + a.shape[2:]).transpose(1, 0, 2, 3, 4)
    br = b.reshape((bsz, nc, ck) + b.shape[2:]).transpose(1, 0, 2, 3, 4)

    def comb(l, r):
        al, bl = l
        ar_, br_ = r
        return al * ar_, bl * ar_ + br_

    def chunk_step(carry, inp):
        ac, bc = inp                                   # [B, ck, dil, n]
        acum, hin = lax.associative_scan(comb, (ac, bc), axis=1)
        h = hin + acum * carry[:, None]
        return h[:, -1], h

    h0 = jnp.zeros(a.shape[:1] + a.shape[2:], a.dtype)
    _, hs = lax.scan(chunk_step, h0, (ar, br))
    return hs.transpose(1, 0, 2, 3, 4).reshape(a.shape)


def mamba_train(p: Dict, x: jax.Array, cfg: ModelConfig, tp_axis: str,
                tp: int, return_state: bool = False):
    b, t, d = x.shape
    n = cfg.ssm_state
    xi_pre = jnp.einsum("btd,dc->btc", x, p["in_x"])      # [B,T,dil]
    z = jnp.einsum("btd,dc->btc", x, p["in_z"])
    xi = jax.nn.silu(_causal_conv(xi_pre, p["conv"]))
    dt = jax.nn.softplus(jnp.einsum("btd,dc->btc", x, p["w_dt"])
                         .astype(jnp.float32))            # [B,T,dil]
    Bm = jnp.einsum("btd,dn->btn", x, p["w_B"]).astype(jnp.float32)
    Cm = jnp.einsum("btd,dn->btn", x, p["w_C"]).astype(jnp.float32)
    A = -jnp.exp(p["A_log"])                              # [dil, n]
    ys, h_fin = _chunked_selective_scan(dt, xi.astype(jnp.float32), Bm, Cm, A)
    y = ys.astype(x.dtype) + xi * p["D"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("btc,cd->btd", y, p["out"])
    out = lax.psum(out, tp_axis)
    if return_state:
        kconv = p["conv"].shape[0]
        state = {"h": h_fin, "conv": xi_pre[:, t - (kconv - 1):]}
        return out, state
    return out


def mamba_decode(p: Dict, x: jax.Array, state: Dict, cfg: ModelConfig,
                 tp_axis: str, tp: int) -> Tuple[jax.Array, Dict]:
    """x [B,1,d]; state: {"h": [B,dil,n], "conv": [B,K-1,dil]}."""
    b = x.shape[0]
    n = cfg.ssm_state
    xi = jnp.einsum("btd,dc->btc", x, p["in_x"])[:, 0]    # [B,dil]
    z = jnp.einsum("btd,dc->btc", x, p["in_z"])[:, 0]
    k = p["conv"].shape[0]
    hist = jnp.concatenate([state["conv"], xi[:, None]], axis=1)  # [B,K,dil]
    xi = jax.nn.silu(jnp.einsum("bkc,kc->bc", hist, p["conv"]))
    new_conv = hist[:, 1:]
    dt = jax.nn.softplus(jnp.einsum("btd,dc->btc", x, p["w_dt"])
                         .astype(jnp.float32))[:, 0]      # [B,dil]
    Bm = jnp.einsum("btd,dn->btn", x, p["w_B"]).astype(jnp.float32)[:, 0]
    Cm = jnp.einsum("btd,dn->btn", x, p["w_C"]).astype(jnp.float32)[:, 0]
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt[..., None] * A)                        # [B,dil,n]
    h = state["h"] * a + (dt * xi.astype(jnp.float32))[..., None] \
        * Bm[:, None, :]
    y = jnp.einsum("bcn,bn->bc", h, Cm).astype(x.dtype) \
        + xi * p["D"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bc,cd->bd", y, p["out"])[:, None]
    return lax.psum(out, tp_axis), {"h": h, "conv": new_conv}


def mamba_init_state(b: int, cfg: ModelConfig, tp: int, dtype):
    dil = mamba_inner(cfg, tp)
    return {"h": jnp.zeros((b, dil, cfg.ssm_state), jnp.float32),
            "conv": jnp.zeros((b, cfg.ssm_conv - 1, dil), dtype)}


# ---------------------------------------------------------------------------
# mLSTM (chunkwise parallel linear attention with exp gating)
# ---------------------------------------------------------------------------

def mlstm_dims(cfg: ModelConfig, tp: int) -> Tuple[int, int, int]:
    h = cfg.n_heads
    dk = cfg.d_model // h
    dvl = max(1, dk // tp)          # value dim sharded over model axis
    return h, dk, dvl


def mlstm_params(key, cfg: ModelConfig, tp: int, dtype):
    h, dk, dvl = mlstm_dims(cfg, tp)
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], (d, h * dk), dtype=dtype),
        "wk": dense_init(ks[1], (d, h * dk), dtype=dtype),
        "wv": dense_init(ks[2], (d, h * dvl), dtype=dtype),
        "wi": dense_init(ks[3], (d, h), dtype=jnp.float32),
        "wf": dense_init(ks[4], (d, h), dtype=jnp.float32),
        "out": dense_init(ks[5], (h * dvl, d), dtype=dtype),
    }


def mlstm_train(p: Dict, x: jax.Array, cfg: ModelConfig, tp_axis: str,
                tp: int, return_state: bool = False):
    b, t, d = x.shape
    h, dk, dvl = mlstm_dims(cfg, tp)
    c = min(CHUNK, t)
    nc = t // c
    assert t % c == 0, f"seq {t} not divisible by chunk {c}"
    q = jnp.einsum("btd,dh->bth", x, p["wq"]).reshape(b, nc, c, h, dk)
    k = jnp.einsum("btd,dh->bth", x, p["wk"]).reshape(b, nc, c, h, dk) \
        / jnp.sqrt(jnp.float32(dk)).astype(x.dtype)
    v = jnp.einsum("btd,dh->bth", x, p["wv"]).reshape(b, nc, c, h, dvl)
    lf = jax.nn.log_sigmoid(jnp.einsum("btd,dh->bth", x.astype(jnp.float32),
                                       p["wf"])).reshape(b, nc, c, h)
    li = jnp.einsum("btd,dh->bth", x.astype(jnp.float32), p["wi"]) \
        .reshape(b, nc, c, h)
    clf = jnp.cumsum(lf, axis=2)                           # within-chunk
    total = clf[:, :, -1, :]                               # [b,nc,h]

    # intra-chunk: D_ij = exp(clf_i - clf_j + li_j), j <= i (stabilized)
    gate = clf[:, :, :, None, :] - clf[:, :, None, :, :] \
        + li[:, :, None, :, :]                             # [b,nc,i,j,h]
    ti = jnp.arange(c)
    causal = (ti[:, None] >= ti[None, :])[None, None, :, :, None]
    gate = jnp.where(causal, gate, -jnp.inf)
    # numerical stabilizer per (b,nc,i,h)
    mstab = jnp.maximum(jnp.max(gate, axis=3), 0.0)        # [b,nc,i,h]
    dmat = jnp.exp(gate - mstab[:, :, :, None, :])
    scores = jnp.einsum("bnihd,bnjhd->bnijh", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * dmat
    intra = jnp.einsum("bnijh,bnjhv->bnihv", scores, v.astype(jnp.float32))
    n_intra = jnp.einsum("bnijh,bnjhd->bnihd", scores, k.astype(jnp.float32))

    # inter-chunk recurrent state S [b,h,dk,dvl], normalizer N [b,h,dk]
    kv = jnp.einsum("bnjhd,bnjhv,bnjh->bnhdv", k.astype(jnp.float32),
                    v.astype(jnp.float32),
                    jnp.exp(total[:, :, None, :] - clf + li))
    ksum = jnp.einsum("bnjhd,bnjh->bnhd", k.astype(jnp.float32),
                      jnp.exp(total[:, :, None, :] - clf + li))

    def step(carry, inp):
        S, N = carry
        kv_c, ks_c, tot_c = inp
        outS, outN = S, N
        S = S * jnp.exp(tot_c)[..., None, None] + kv_c
        N = N * jnp.exp(tot_c)[..., None] + ks_c
        return (S, N), (outS, outN)

    S0 = jnp.zeros((b, h, dk, dvl), jnp.float32)
    N0 = jnp.zeros((b, h, dk), jnp.float32)
    (S_fin, N_fin), (S_hist, N_hist) = lax.scan(
        step, (S0, N0),
        (kv.transpose(1, 0, 2, 3, 4), ksum.transpose(1, 0, 2, 3),
         total.transpose(1, 0, 2)))
    S_hist = S_hist.transpose(1, 0, 2, 3, 4)               # [b,nc,h,dk,dvl]
    N_hist = N_hist.transpose(1, 0, 2, 3)

    qs = q.astype(jnp.float32) * jnp.exp(clf - mstab)[..., None]
    inter = jnp.einsum("bnihd,bnhdv->bnihv", qs, S_hist)
    n_inter = jnp.einsum("bnihd,bnhd->bnihd", qs, N_hist)

    num = intra + inter                                    # [b,nc,c,h,dvl]
    nq = jnp.sum((n_intra + n_inter)
                 * q.astype(jnp.float32), axis=-1)         # [b,nc,c,h]
    denom = jnp.maximum(jnp.abs(nq), jnp.exp(-mstab))[..., None]
    y = (num / denom).reshape(b, t, h * dvl).astype(x.dtype)
    out = jnp.einsum("bth,hd->btd", y, p["out"])
    out = lax.psum(out, tp_axis)
    if return_state:
        state = {"S": S_fin, "N": N_fin, "m": jnp.zeros((b, h), jnp.float32)}
        return out, state
    return out


def mlstm_decode(p: Dict, x: jax.Array, state: Dict, cfg: ModelConfig,
                 tp_axis: str, tp: int) -> Tuple[jax.Array, Dict]:
    """x [B,1,d]; state {"S": [B,H,dk,dvl], "N": [B,H,dk], "m": [B,H]}."""
    b = x.shape[0]
    h, dk, dvl = mlstm_dims(cfg, tp)
    q = jnp.einsum("btd,dh->bth", x, p["wq"])[:, 0].reshape(b, h, dk)
    k = (jnp.einsum("btd,dh->bth", x, p["wk"])[:, 0].reshape(b, h, dk)
         / jnp.sqrt(jnp.float32(dk)).astype(x.dtype))
    v = jnp.einsum("btd,dh->bth", x, p["wv"])[:, 0].reshape(b, h, dvl)
    lf = jax.nn.log_sigmoid(
        jnp.einsum("btd,dh->bth", x.astype(jnp.float32), p["wf"])[:, 0])
    li = jnp.einsum("btd,dh->bth", x.astype(jnp.float32), p["wi"])[:, 0]
    m_new = jnp.maximum(state["m"] + lf, li)               # [B,H]
    sc_old = jnp.exp(state["m"] + lf - m_new)
    sc_in = jnp.exp(li - m_new)
    S = state["S"] * sc_old[..., None, None] \
        + jnp.einsum("bhd,bhv->bhdv", k.astype(jnp.float32),
                     v.astype(jnp.float32)) * sc_in[..., None, None]
    N = state["N"] * sc_old[..., None] \
        + k.astype(jnp.float32) * sc_in[..., None]
    num = jnp.einsum("bhd,bhdv->bhv", q.astype(jnp.float32), S)
    nq = jnp.sum(N * q.astype(jnp.float32), axis=-1)       # [B,H]
    denom = jnp.maximum(jnp.abs(nq), jnp.exp(-m_new))[..., None]
    y = (num / denom).reshape(b, h * dvl).astype(x.dtype)
    out = jnp.einsum("bh,hd->bd", y, p["out"])[:, None]
    return lax.psum(out, tp_axis), {"S": S, "N": N, "m": m_new}


def mlstm_init_state(b: int, cfg: ModelConfig, tp: int):
    h, dk, dvl = mlstm_dims(cfg, tp)
    return {"S": jnp.zeros((b, h, dk, dvl), jnp.float32),
            "N": jnp.zeros((b, h, dk), jnp.float32),
            "m": jnp.zeros((b, h), jnp.float32)}


# ---------------------------------------------------------------------------
# sLSTM (sequential; replicated across model axis)
# ---------------------------------------------------------------------------

def slstm_params(key, cfg: ModelConfig, tp: int, dtype):
    d, h = cfg.d_model, cfg.n_heads
    dh = d // h
    ks = jax.random.split(key, 3)
    wx = dense_init(ks[0], (d, 4 * d), dtype=dtype)        # i,f,z,o
    wr = dense_init(ks[1], (h, dh, 4 * dh), dtype=dtype)   # block-diag recur
    out = dense_init(ks[2], (d, d), dtype=dtype)
    return {"wx": wx, "wr": wr, "out": out,
            "bias": jnp.zeros((4 * d,), jnp.float32)}


def _slstm_cell(p, xt, state, cfg: ModelConfig):
    """xt [B,d]; state (c,n,hprev,m) each [B,H,dh]-ish."""
    b = xt.shape[0]
    h_heads, d = cfg.n_heads, cfg.d_model
    dh = d // h_heads
    c, n, hprev, m = state
    zx = jnp.einsum("bd,dk->bk", xt, p["wx"]).astype(jnp.float32)
    zr = jnp.einsum("bhe,hek->bhk", hprev.astype(xt.dtype), p["wr"]) \
        .astype(jnp.float32)                               # [B,H,4dh]
    z = zx.reshape(b, h_heads, 4 * dh) + zr \
        + p["bias"].reshape(h_heads, 4 * dh)[None]
    zi, zf, zz, zo = jnp.split(z, 4, axis=-1)              # [B,H,dh]
    m_new = jnp.maximum(zf + m, zi)                        # exp-gate stabilizer
    i = jnp.exp(zi - m_new)
    f = jnp.exp(zf + m - m_new)
    c = f * c + i * jnp.tanh(zz)
    n = f * n + i
    o = jax.nn.sigmoid(zo)
    hnew = o * c / jnp.maximum(jnp.abs(n), 1.0)
    return (c, n, hnew, m_new), hnew


def slstm_train(p: Dict, x: jax.Array, cfg: ModelConfig, tp_axis: str,
                tp: int, return_state: bool = False):
    b, t, d = x.shape
    h_heads = cfg.n_heads
    dh = d // h_heads
    zeros = jnp.zeros((b, h_heads, dh), jnp.float32)
    state = (zeros, zeros, zeros, zeros)

    def step(carry, xt):
        return _slstm_cell(p, xt, carry, cfg)

    fin, hs = lax.scan(step, state, x.transpose(1, 0, 2))  # [T,B,H,dh]
    y = hs.transpose(1, 0, 2, 3).reshape(b, t, d).astype(x.dtype)
    out = jnp.einsum("btd,de->bte", y, p["out"])
    if return_state:
        return out, fin
    return out


def slstm_decode(p: Dict, x: jax.Array, state, cfg: ModelConfig,
                 tp_axis: str, tp: int):
    new_state, hnew = _slstm_cell(p, x[:, 0], state, cfg)
    b, d = x.shape[0], x.shape[2]
    y = hnew.reshape(b, d).astype(x.dtype)
    return jnp.einsum("bd,de->be", y, p["out"])[:, None], new_state


def slstm_init_state(b: int, cfg: ModelConfig):
    h_heads, dh = cfg.n_heads, cfg.d_model // cfg.n_heads
    z = jnp.zeros((b, h_heads, dh), jnp.float32)
    return (z, z, z, z)
