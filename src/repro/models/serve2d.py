"""2D weight-stationary decode for MoE and Mamba blocks (SPerf H4 cont.).

Same principle as attention.attn_decode_2d: decode is weight-bound, so the
FSDP shards are consumed in place — each (data, model) device contributes
the partial product of its d-row slice, summed with a psum over data —
instead of all-gathering 100s of MB of weights per layer per token.

MoE specifics: the router logits are psum'd (identical on every rank, so
top-k routing is deterministic); dispatch all_to_all carries d/dp token
SLICES (each data rank ships its slice of the same tokens), so dispatch
bytes also drop by dp.

Used for decode only; training keeps the gather/transpose path (grads need
the reduce-scatter the gather transpose provides).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .attention import (_batch_replicate, _batch_slice, _col_matmul_2d,
                        _row_matmul_2d)
from .common import ModelConfig, act_fn
from .moe import _group_by, router_topk


def _dp_index(dp_axes, mesh_sizes):
    idx = jnp.zeros((), jnp.int32)
    for a in dp_axes:
        idx = idx * mesh_sizes[a] + lax.axis_index(a)
    return idx


def moe_ffn_2d(p: Dict, x: jax.Array, cfg: ModelConfig, tp_axis: str,
               tp: int, dp_axes, mesh_sizes,
               batch_replicated: bool = False) -> jax.Array:
    """MoE with 2D-sharded expert weights; x [B_loc, 1, d] (decode).

    p: raw shards — router [d/dp, E], w1/w3 [el, d/dp, eff],
    w2 [el, eff, d/dp].
    """
    b_loc, _, d = x.shape
    el = cfg.experts_local(tp)
    k_top = cfg.top_k
    dpi = _dp_index(dp_axes, mesh_sizes)
    dl = p["router"].shape[0]                       # d/dp

    xf = x[:, 0] if batch_replicated else _batch_replicate(x[:, 0], dp_axes)
    n_full = xf.shape[0]

    # ---- route (replicated logits => identical top-k on every rank) -------
    _, wk, ek = router_topk(
        _col_matmul_2d(xf.astype(jnp.float32),
                       p["router"].astype(jnp.float32), dp_axes, dpi), cfg)

    # ---- token-shard over tp, dispatch d/dp slices -------------------------
    n = -(-n_full // tp)
    pad = n * tp - n_full
    x_rows = lax.dynamic_slice_in_dim(xf, dpi * dl, dl, 1)   # [N, d/dp]
    xp = jnp.pad(x_rows, ((0, pad), (0, 0)))
    ekp = jnp.pad(ek, ((0, pad), (0, 0)), constant_values=0)
    wkp = jnp.pad(wk, ((0, pad), (0, 0)))
    shard = lax.axis_index(tp_axis)
    xs = lax.dynamic_slice_in_dim(xp, shard * n, n, 0)       # [n, d/dp]
    es = lax.dynamic_slice_in_dim(ekp, shard * n, n, 0)      # [n, K]
    ws = lax.dynamic_slice_in_dim(wkp, shard * n, n, 0)

    flat_e = es.reshape(n * k_top)
    dest = flat_e // el
    cap = int(max(8, -(-n * k_top // tp) * cfg.moe_capacity))
    slot, keep = _group_by(dest, tp, cap)
    xk = jnp.repeat(xs, k_top, axis=0)
    buf = jnp.zeros((tp * cap + 1, dl), x.dtype).at[slot].set(
        jnp.where(keep[:, None], xk, 0))[:-1].reshape(tp, cap, dl)
    ebuf = jnp.full((tp * cap + 1,), -1, jnp.int32).at[slot].set(
        jnp.where(keep, flat_e % el, -1))[:-1].reshape(tp, cap)
    rbuf = lax.all_to_all(buf, tp_axis, split_axis=0, concat_axis=0)
    rebuf = lax.all_to_all(ebuf, tp_axis, split_axis=0, concat_axis=0)

    # ---- expert compute on d/dp slices + psum over data --------------------
    rx = rbuf.reshape(tp * cap, dl)
    re = rebuf.reshape(tp * cap)
    cap_e = int(min(max(8, -(-tp * cap // el) * 1.25), tp * cap))
    eslot, ekeep = _group_by(jnp.where(re >= 0, re, el), el, cap_e)
    exs = jnp.zeros((el * cap_e + 1, dl), x.dtype).at[eslot].set(
        jnp.where((ekeep & (re >= 0))[:, None], rx, 0))[:-1]
    exs = exs.reshape(el, cap_e, dl)
    h = jnp.einsum("ecd,edf->ecf", exs, p["w1"])
    h3 = jnp.einsum("ecd,edf->ecf", exs, p["w3"])
    for a in dp_axes:
        h = lax.psum(h, a)
        h3 = lax.psum(h3, a)
    h = act_fn(h, cfg.act) * h3
    ey = jnp.einsum("ecf,efd->ecd", h, p["w2"])              # [el, cap_e, d/dp]
    ry = ey.reshape(el * cap_e, dl)
    safe_es = jnp.minimum(eslot, el * cap_e - 1)
    y_slots = (ry[safe_es] * (ekeep & (re >= 0))[:, None]).reshape(tp, cap, dl)

    # ---- return + combine ---------------------------------------------------
    back = lax.all_to_all(y_slots, tp_axis, split_axis=0, concat_axis=0)
    backf = back.reshape(tp * cap, dl)
    safe_slot = jnp.minimum(slot, tp * cap - 1)
    per_assign = backf[safe_slot] * keep[:, None]
    y = jnp.sum(per_assign.reshape(n, k_top, dl)
                * ws[..., None].astype(x.dtype), axis=1)     # [n, d/dp]
    # reassemble: tokens over model, d over data
    y = lax.all_gather(y, tp_axis, axis=0, tiled=True)[:n_full]  # [N, d/dp]
    for a in dp_axes:
        y = lax.all_gather(y, a, axis=1, tiled=True)         # [N, d]
    if not batch_replicated:
        y = _batch_slice(y, b_loc, dp_axes, mesh_sizes)
    return y[:, None]


def mamba_decode_2d(p: Dict, x: jax.Array, state: Dict, cfg: ModelConfig,
                    tp_axis: str, tp: int, dp_axes, mesh_sizes,
                    batch_replicated: bool = False
                    ) -> Tuple[jax.Array, Dict]:
    """Mamba decode with 2D-sharded weights; x [B_loc, 1, d].

    in_x/in_z/w_dt [d/dp, dil_local], w_B/w_C [d/dp, n], out [dil_local, d/dp],
    conv [K, dil_local], A_log [dil_local, n], D [dil_local] (model-sharded,
    usable directly).  State stays batch-sharded ([B_loc, dil_local, n]).
    """
    b_loc = x.shape[0]
    dpi = _dp_index(dp_axes, mesh_sizes)
    xf = x[:, 0] if batch_replicated else _batch_replicate(x[:, 0], dp_axes)

    xi = _col_matmul_2d(xf, p["in_x"], dp_axes, dpi)         # [B, dil_l]
    z = _col_matmul_2d(xf, p["in_z"], dp_axes, dpi)
    dt = jax.nn.softplus(
        _col_matmul_2d(xf, p["w_dt"], dp_axes, dpi).astype(jnp.float32))
    Bm = _col_matmul_2d(xf, p["w_B"], dp_axes, dpi).astype(jnp.float32)
    Cm = _col_matmul_2d(xf, p["w_C"], dp_axes, dpi).astype(jnp.float32)
    if not batch_replicated:
        xi = _batch_slice(xi, b_loc, dp_axes, mesh_sizes)
        z = _batch_slice(z, b_loc, dp_axes, mesh_sizes)
        dt = _batch_slice(dt, b_loc, dp_axes, mesh_sizes)
        Bm = _batch_slice(Bm, b_loc, dp_axes, mesh_sizes)
        Cm = _batch_slice(Cm, b_loc, dp_axes, mesh_sizes)

    hist = jnp.concatenate([state["conv"], xi[:, None]], axis=1)
    xi = jax.nn.silu(jnp.einsum("bkc,kc->bc", hist, p["conv"]))
    new_conv = hist[:, 1:]
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt[..., None] * A)
    h = state["h"] * a + (dt * xi.astype(jnp.float32))[..., None] \
        * Bm[:, None, :]
    y = jnp.einsum("bcn,bn->bc", h, Cm).astype(x.dtype) \
        + xi * p["D"].astype(x.dtype)
    y = y * jax.nn.silu(z)                                   # [B_loc, dil_l]
    yf = y if batch_replicated else _batch_replicate(y, dp_axes)
    out_full = _row_matmul_2d(yf, p["out"], tp_axis, dp_axes)  # [B, d]
    out = out_full if batch_replicated else \
        _batch_slice(out_full, b_loc, dp_axes, mesh_sizes)
    return out[:, None], {"h": h, "conv": new_conv}
