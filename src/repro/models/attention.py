"""GQA attention under explicit tensor parallelism (heads on "model" axis).

Variants:
  * ``attn_train``            — full-sequence causal/bidirectional, optional
                                 sliding window (per-layer traced scalar so a
                                 gemma-style local:global pattern scans).
  * ``attn_decode``           — one token vs a [B, S, KV, hd] cache
                                 (batch sharded over data).
  * ``attn_decode_splitkv``   — one token vs a *sequence-sharded* cache:
                                 each data shard holds S/dp cache slots and
                                 contributes partial softmax stats combined
                                 with a psum log-sum-exp (flash-decoding,
                                 TPU-adapted) — this is what makes 500k-token
                                 decode feasible for attention archs.

Head padding: when n_heads % tp != 0 the per-device head count is rounded up
(cfg.heads_local); the padded heads are ordinary extra capacity (zero-init
wo rows) — they cost FLOPs, which the roofline bookkeeping charges honestly.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .common import ModelConfig, dense_init, rope

NEG = -1e30


def attn_params(key, cfg: ModelConfig, tp: int, dtype):
    hl, kvl, hd, d = cfg.heads_local(tp), cfg.kv_local(tp), cfg.hd, cfg.d_model
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, hl * hd), dtype=dtype),
        "wk": dense_init(ks[1], (d, kvl * hd), dtype=dtype),
        "wv": dense_init(ks[2], (d, kvl * hd), dtype=dtype),
        "wo": dense_init(ks[3], (hl * hd, d), dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hl * hd,), dtype)
        p["bk"] = jnp.zeros((kvl * hd,), dtype)
        p["bv"] = jnp.zeros((kvl * hd,), dtype)
    return p


def _project_qkv(p, x, cfg: ModelConfig, tp: int):
    b, t, _ = x.shape
    hl, kvl, hd = cfg.heads_local(tp), cfg.kv_local(tp), cfg.hd
    q = jnp.einsum("btd,dh->bth", x, p["wq"])
    k = jnp.einsum("btd,dh->bth", x, p["wk"])
    v = jnp.einsum("btd,dh->bth", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return (q.reshape(b, t, hl, hd), k.reshape(b, t, kvl, hd),
            v.reshape(b, t, kvl, hd))


def _group_scores_to_out(q, k, v, mask, cfg: ModelConfig, tp: int):
    """q [B,T,Hl,hd], k/v [B,S,KVl,hd], mask [T,S] or [B,T,S] -> [B,T,Hl*hd]."""
    b, t, hl, hd = q.shape
    kvl = k.shape[2]
    g = hl // kvl if hl % kvl == 0 else 0
    if g == 0:  # padded heads not divisible by kv: map head->kv by ratio
        qk_map = (jnp.arange(hl) * kvl) // hl
        k = jnp.take(k, qk_map, axis=2)          # [B,S,Hl,hd]
        v = jnp.take(v, qk_map, axis=2)
        scores = jnp.einsum("bthd,bshd->bhts", q, k).astype(jnp.float32)
        scores = scores / jnp.sqrt(jnp.float32(hd))
        scores = jnp.where(mask[..., None, :, :] if mask.ndim == 2 else
                           mask[:, None], scores, NEG)
        w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        out = jnp.einsum("bhts,bshd->bthd", w, v)
    else:
        qg = q.reshape(b, t, kvl, g, hd)
        scores = jnp.einsum("btkgd,bskd->bkgts", qg, k).astype(jnp.float32)
        scores = scores / jnp.sqrt(jnp.float32(hd))
        m = mask if mask.ndim == 3 else mask[None]
        scores = jnp.where(m[:, None, None], scores, NEG)
        w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        out = jnp.einsum("bkgts,bskd->btkgd", w, v).reshape(b, t, hl, hd)
    return out.reshape(b, t, hl * hd)


def attn_train(p, x: jax.Array, cfg: ModelConfig, tp_axis: str, tp: int,
               window, positions: Optional[jax.Array] = None,
               causal: bool = True, return_kv: bool = False):
    """Full-sequence attention.  window: traced scalar (0 = full)."""
    b, t, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg, tp)
    if positions is None:
        positions = jnp.arange(t, dtype=jnp.int32)[None].repeat(b, 0)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    ti = jnp.arange(t, dtype=jnp.int32)
    rel = ti[:, None] - ti[None, :]
    mask = jnp.ones((t, t), bool) if not causal else (rel >= 0)
    w_eff = jnp.where(window > 0, window, t + 1)
    if causal:
        mask = mask & (rel < w_eff)
    out = _group_scores_to_out(q, k, v, mask, cfg, tp)
    y = jnp.einsum("bth,hd->btd", out, p["wo"])
    y = lax.psum(y, tp_axis)
    if return_kv:
        return y, (k, v)
    return y


BLOCKED_ATTN_THRESHOLD = 8192
Q_CHUNK = 1024


def attn_train_blocked(p, x: jax.Array, cfg: ModelConfig, tp_axis: str,
                       tp: int, window, positions: Optional[jax.Array] = None,
                       causal: bool = True, return_kv: bool = False):
    """Query-chunked attention for long sequences (flash-style memory):
    scores materialize per q-chunk [B, heads, Q_CHUNK, S] instead of
    [B, heads, S, S].  Numerics identical to attn_train (full softmax row
    per chunk — no online rescaling needed)."""
    b, t, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg, tp)
    if positions is None:
        positions = jnp.arange(t, dtype=jnp.int32)[None].repeat(b, 0)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    qc = Q_CHUNK
    nchunk = t // qc
    assert t % qc == 0, f"seq {t} % {qc}"
    si = jnp.arange(t, dtype=jnp.int32)
    w_eff = jnp.where(window > 0, window, t + 1)
    qs = q.reshape(b, nchunk, qc, q.shape[2], q.shape[3]).transpose(1, 0, 2, 3, 4)

    def chunk(ci, qchunk):
        ti = ci * qc + jnp.arange(qc, dtype=jnp.int32)
        rel = ti[:, None] - si[None, :]
        mask = (rel >= 0) & (rel < w_eff) if causal else \
            jnp.ones((qc, t), bool)
        return _group_scores_to_out(qchunk, k, v, mask, cfg, tp)

    outs = lax.map(lambda args: chunk(*args),
                   (jnp.arange(nchunk), qs))              # [nc, B, qc, H*hd]
    out = outs.transpose(1, 0, 2, 3).reshape(b, t, -1)
    y = jnp.einsum("bth,hd->btd", out, p["wo"])
    y = lax.psum(y, tp_axis)
    if return_kv:
        return y, (k, v)
    return y


def attn_decode(p, x: jax.Array, cache_k: jax.Array, cache_v: jax.Array,
                pos: jax.Array, cfg: ModelConfig, tp_axis: str, tp: int,
                window) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One new token. x [B,1,d]; cache [B,S,KVl,hd]; pos [B] current length."""
    b, s = cache_k.shape[0], cache_k.shape[1]
    q, k, v = _project_qkv(p, x, cfg, tp)
    q = rope(q, pos[:, None], cfg.rope_theta)
    k = rope(k, pos[:, None], cfg.rope_theta)
    bi = jnp.arange(b)
    cache_k = cache_k.at[bi, pos].set(k[:, 0].astype(cache_k.dtype))
    cache_v = cache_v.at[bi, pos].set(v[:, 0].astype(cache_v.dtype))
    si = jnp.arange(s, dtype=jnp.int32)
    w_eff = jnp.where(window > 0, window, s + 1)
    mask = (si[None] <= pos[:, None]) & \
        (pos[:, None] - si[None] < w_eff)                     # [B, S]
    out = _group_scores_to_out(q, cache_k.astype(q.dtype),
                               cache_v.astype(q.dtype),
                               mask[:, None, :], cfg, tp)      # mask [B,1,S]
    y = jnp.einsum("bth,hd->btd", out, p["wo"])
    return lax.psum(y, tp_axis), cache_k, cache_v


def attn_decode_splitkv(p, x: jax.Array, cache_k: jax.Array,
                        cache_v: jax.Array, pos: jax.Array, cfg: ModelConfig,
                        tp_axis: str, tp: int, window, seq_axis: str,
                        seq_shards: int
                        ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Flash-decoding over a sequence-sharded cache.

    cache_k/v: [B, S_loc, KVl, hd] — this shard owns cache slots
    [shard*S_loc, (shard+1)*S_loc).  The new token's KV is written by the
    owning shard; softmax statistics combine across shards via psum/pmax.
    """
    b, s_loc = cache_k.shape[0], cache_k.shape[1]
    shard = lax.axis_index(seq_axis)
    q, k, v = _project_qkv(p, x, cfg, tp)
    q = rope(q, pos[:, None], cfg.rope_theta)
    k = rope(k, pos[:, None], cfg.rope_theta)
    # owner writes the new kv
    loc = pos - shard * s_loc                                 # [B]
    own = (loc >= 0) & (loc < s_loc)
    safe = jnp.clip(loc, 0, s_loc - 1)
    bi = jnp.arange(b)
    newk = jnp.where(own[:, None, None],
                     k[:, 0].astype(cache_k.dtype), cache_k[bi, safe])
    newv = jnp.where(own[:, None, None],
                     v[:, 0].astype(cache_v.dtype), cache_v[bi, safe])
    cache_k = cache_k.at[bi, safe].set(newk)
    cache_v = cache_v.at[bi, safe].set(newv)

    hl, kvl, hd = cfg.heads_local(tp), cfg.kv_local(tp), cfg.hd
    spos = shard * s_loc + jnp.arange(s_loc, dtype=jnp.int32)  # global slots
    w_eff = jnp.where(window > 0, window, pos.max() + s_loc * seq_shards + 1)
    mask = (spos[None] <= pos[:, None]) & \
        (pos[:, None] - spos[None] < w_eff)                    # [B, S_loc]
    qk_map = (jnp.arange(hl) * kvl) // hl
    kk = jnp.take(cache_k.astype(q.dtype), qk_map, axis=2)     # [B,S,Hl,hd]
    vv = jnp.take(cache_v.astype(q.dtype), qk_map, axis=2)
    scores = jnp.einsum("bhd,bshd->bhs", q[:, 0], kk).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    scores = jnp.where(mask[:, None], scores, NEG)
    m_loc = jnp.max(scores, axis=-1)                           # [B, Hl]
    m = lax.pmax(m_loc, seq_axis)
    z = jnp.exp(scores - m[..., None])
    l = lax.psum(jnp.sum(z, axis=-1), seq_axis)                # [B, Hl]
    o = lax.psum(jnp.einsum("bhs,bshd->bhd", z, vv.astype(jnp.float32)),
                 seq_axis)
    out = (o / jnp.maximum(l, 1e-30)[..., None]).astype(x.dtype)
    y = jnp.einsum("bh,hd->bd", out.reshape(b, hl * hd), p["wo"])[:, None]
    return lax.psum(y, tp_axis), cache_k, cache_v


# ---------------------------------------------------------------------------
# 2D weight-stationary decode (serve2d): no FSDP weight gathers.
# Weights stay sharded over (data=fsdp dim, model=tp dim); activations are
# batch-replicated around each projection (KBs) instead of gathering weight
# shards (100s of MBs per layer).  Decode-only: activation traffic ~0.
# ---------------------------------------------------------------------------

def _col_matmul_2d(x_full: jax.Array, w_local: jax.Array, dp_axes,
                   dp_index: jax.Array) -> jax.Array:
    """x_full [N, d] (replicated) @ w [d, out] sharded (d over data, out over
    model) -> [N, out_local] replicated over data."""
    dl = w_local.shape[0]
    x_rows = lax.dynamic_slice_in_dim(x_full, dp_index * dl, dl, 1)
    part = jnp.einsum("nd,dh->nh", x_rows, w_local)
    for a in dp_axes:
        part = lax.psum(part, a)
    return part


def _row_matmul_2d(h: jax.Array, w_local: jax.Array, tp_axis: str,
                   dp_axes) -> jax.Array:
    """h [N, in_local(model)] (replicated over data) @ w [in, d] sharded
    (in over model, d over data) -> [N, d] fully replicated."""
    part = jnp.einsum("nh,hd->nd", h, w_local)     # [N, d/dp]
    part = lax.psum(part, tp_axis)
    out = part
    for a in dp_axes:
        out = lax.all_gather(out, a, axis=1, tiled=True)
    return out


def _batch_replicate(x: jax.Array, dp_axes) -> jax.Array:
    for a in dp_axes:
        x = lax.all_gather(x, a, axis=0, tiled=True)
    return x


def _batch_slice(x: jax.Array, b_loc: int, dp_axes, mesh_sizes) -> jax.Array:
    idx = jnp.zeros((), jnp.int32)
    for a in dp_axes:
        idx = idx * mesh_sizes[a] + lax.axis_index(a)
    return lax.dynamic_slice_in_dim(x, idx * b_loc, b_loc, 0)


def attn_decode_2d(p_local, x: jax.Array, cache_k: jax.Array,
                   cache_v: jax.Array, pos: jax.Array, cfg: ModelConfig,
                   tp_axis: str, tp: int, window, dp_axes, mesh_sizes,
                   seq_axis: Optional[str] = None, seq_shards: int = 1
                   ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One decode token with 2D-sharded (ungathered) attention weights.

    p_local: raw FSDP shards — wq/wk/wv [d/dp, out_local], wo [hl*hd, d/dp].
    Batch-sharded cache mode (seq_axis=None): x [B_loc, 1, d] over data.
    Seq-sharded mode (seq_axis set, long context): batch is replicated
    (B_loc == B) and the cache holds S/seq_shards slots — projections stay
    2D, the attention core is split-KV (psum'd softmax stats).
    """
    b_loc = x.shape[0]
    batch_replicated = seq_axis is not None
    dp_index = jnp.zeros((), jnp.int32)
    for a in dp_axes:
        dp_index = dp_index * mesh_sizes[a] + lax.axis_index(a)
    xf = x[:, 0] if batch_replicated else _batch_replicate(x[:, 0], dp_axes)
    hl, kvl, hd = cfg.heads_local(tp), cfg.kv_local(tp), cfg.hd
    q = _col_matmul_2d(xf, p_local["wq"], dp_axes, dp_index)
    k = _col_matmul_2d(xf, p_local["wk"], dp_axes, dp_index)
    v = _col_matmul_2d(xf, p_local["wv"], dp_axes, dp_index)
    if cfg.qkv_bias:
        q = q + p_local["bq"]
        k = k + p_local["bk"]
        v = v + p_local["bv"]
    if cfg.n_kv < tp:  # kv weights replicated-in-model: slice my head
        idx = (lax.axis_index(tp_axis) * cfg.n_kv) // tp
        k = lax.dynamic_slice_in_dim(k, idx * kvl * hd, kvl * hd, 1)
        v = lax.dynamic_slice_in_dim(v, idx * kvl * hd, kvl * hd, 1)
    if not batch_replicated:
        q = _batch_slice(q, b_loc, dp_axes, mesh_sizes)
        k = _batch_slice(k, b_loc, dp_axes, mesh_sizes)
        v = _batch_slice(v, b_loc, dp_axes, mesh_sizes)
    q = q.reshape(b_loc, 1, hl, hd)
    k = k.reshape(b_loc, 1, kvl, hd)
    v = v.reshape(b_loc, 1, kvl, hd)
    q = rope(q, pos[:, None], cfg.rope_theta)
    k = rope(k, pos[:, None], cfg.rope_theta)

    if batch_replicated:
        out, cache_k, cache_v = _splitkv_core(
            q, k, v, cache_k, cache_v, pos, cfg, tp, window, seq_axis,
            seq_shards)                                     # [B, hl*hd]
        y_full = _row_matmul_2d(out, p_local["wo"], tp_axis, dp_axes)
        return y_full[:, None], cache_k, cache_v

    b, s = cache_k.shape[0], cache_k.shape[1]
    bi = jnp.arange(b)
    cache_k = cache_k.at[bi, pos].set(k[:, 0].astype(cache_k.dtype))
    cache_v = cache_v.at[bi, pos].set(v[:, 0].astype(cache_v.dtype))
    si = jnp.arange(s, dtype=jnp.int32)
    w_eff = jnp.where(window > 0, window, s + 1)
    mask = (si[None] <= pos[:, None]) & (pos[:, None] - si[None] < w_eff)
    out = _group_scores_to_out(q, cache_k.astype(q.dtype),
                               cache_v.astype(q.dtype), mask[:, None, :],
                               cfg, tp)                     # [B_loc,1,hl*hd]
    out_full = _batch_replicate(out[:, 0], dp_axes)         # [B, hl*hd]
    y_full = _row_matmul_2d(out_full, p_local["wo"], tp_axis, dp_axes)
    y = _batch_slice(y_full, b_loc, dp_axes, mesh_sizes)[:, None]
    return y, cache_k, cache_v


def _splitkv_core(q, k, v, cache_k, cache_v, pos, cfg: ModelConfig, tp: int,
                  window, seq_axis: str, seq_shards: int):
    """Split-KV attention core on projected q/k/v (shared by 2D decode)."""
    b, s_loc = cache_k.shape[0], cache_k.shape[1]
    hl, kvl, hd = cfg.heads_local(tp), cfg.kv_local(tp), cfg.hd
    shard = lax.axis_index(seq_axis)
    loc = pos - shard * s_loc
    own = (loc >= 0) & (loc < s_loc)
    safe = jnp.clip(loc, 0, s_loc - 1)
    bi = jnp.arange(b)
    newk = jnp.where(own[:, None, None], k[:, 0].astype(cache_k.dtype),
                     cache_k[bi, safe])
    newv = jnp.where(own[:, None, None], v[:, 0].astype(cache_v.dtype),
                     cache_v[bi, safe])
    cache_k = cache_k.at[bi, safe].set(newk)
    cache_v = cache_v.at[bi, safe].set(newv)
    spos = shard * s_loc + jnp.arange(s_loc, dtype=jnp.int32)
    w_eff = jnp.where(window > 0, window, pos.max() + s_loc * seq_shards + 1)
    mask = (spos[None] <= pos[:, None]) & (pos[:, None] - spos[None] < w_eff)
    qk_map = (jnp.arange(hl) * kvl) // hl
    kk = jnp.take(cache_k.astype(q.dtype), qk_map, axis=2)
    vv = jnp.take(cache_v.astype(q.dtype), qk_map, axis=2)
    scores = jnp.einsum("bhd,bshd->bhs", q[:, 0], kk).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    scores = jnp.where(mask[:, None], scores, NEG)
    m_loc = jnp.max(scores, axis=-1)
    m = lax.pmax(m_loc, seq_axis)
    z = jnp.exp(scores - m[..., None])
    l = lax.psum(jnp.sum(z, axis=-1), seq_axis)
    o = lax.psum(jnp.einsum("bhs,bshd->bhd", z, vv.astype(jnp.float32)),
                 seq_axis)
    out = (o / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
    return out.reshape(b, hl * hd), cache_k, cache_v


def ffn_2d(p_local, x: jax.Array, cfg: ModelConfig, tp_axis: str,
           dp_axes, mesh_sizes, batch_replicated: bool = False) -> jax.Array:
    """Dense FFN with 2D-sharded (ungathered) weights; x [B_loc, 1, d]."""
    from .common import act_fn
    b_loc = x.shape[0]
    dp_index = jnp.zeros((), jnp.int32)
    for a in dp_axes:
        dp_index = dp_index * mesh_sizes[a] + lax.axis_index(a)
    xf = x[:, 0] if batch_replicated else \
        _batch_replicate(x[:, 0], dp_axes)                  # [B, d]
    h = act_fn(_col_matmul_2d(xf, p_local["w1"], dp_axes, dp_index),
               cfg.act) * _col_matmul_2d(xf, p_local["w3"], dp_axes, dp_index)
    y_full = _row_matmul_2d(h, p_local["w2"], tp_axis, dp_axes)
    if batch_replicated:
        return y_full[:, None]
    return _batch_slice(y_full, b_loc, dp_axes, mesh_sizes)[:, None]


def cross_attn_params(key, cfg: ModelConfig, tp: int, dtype):
    return attn_params(key, cfg, tp, dtype)


def cross_attn(p, x: jax.Array, enc_k: jax.Array, enc_v: jax.Array,
               cfg: ModelConfig, tp_axis: str, tp: int) -> jax.Array:
    """Decoder cross-attention vs precomputed encoder KV [B,S,KVl,hd]."""
    b, t, _ = x.shape
    hl, kvl, hd = cfg.heads_local(tp), cfg.kv_local(tp), cfg.hd
    q = jnp.einsum("btd,dh->bth", x, p["wq"]).reshape(b, t, hl, hd)
    s = enc_k.shape[1]
    mask = jnp.ones((t, s), bool)
    out = _group_scores_to_out(q, enc_k.astype(q.dtype), enc_v.astype(q.dtype),
                               mask, cfg, tp)
    y = jnp.einsum("bth,hd->btd", out, p["wo"])
    return lax.psum(y, tp_axis)


def encode_kv(p, enc_out: jax.Array, cfg: ModelConfig, tp: int):
    """Precompute cross-attention K/V from encoder output."""
    b, s, _ = enc_out.shape
    kvl, hd = cfg.kv_local(tp), cfg.hd
    k = jnp.einsum("bsd,dh->bsh", enc_out, p["wk"]).reshape(b, s, kvl, hd)
    v = jnp.einsum("bsd,dh->bsh", enc_out, p["wv"]).reshape(b, s, kvl, hd)
    return k, v
