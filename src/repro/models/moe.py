"""Expert-parallel MoE with sort-based all_to_all dispatch.

Experts are sharded over the "model" mesh axis (arctic: 128/16 = 8 per
device).  Token→expert dispatch is *the* power-law sparse exchange of the
assigned MoE archs, and structurally identical to one layer of the paper's
butterfly: bucket tokens by destination range (here: expert-owning device),
exchange fixed-capacity buckets with ``all_to_all``, locally group + compute,
and return along the same route (nested, like the paper's up phase).

Static capacities with counted drops (same contract as the sparse allreduce
and every production MoE).  Router params are replicated across "model";
padded experts (when E % tp != 0) are masked to -inf in the router.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .common import ModelConfig, act_fn, dense_init


def moe_params(key, cfg: ModelConfig, tp: int, dtype):
    el, d, ff = cfg.experts_local(tp), cfg.d_model, cfg.expert_d_ff
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, cfg.n_experts_padded(tp)),
                             dtype=jnp.float32),
        "w1": dense_init(ks[1], (el, d, ff), scale_axis=1, dtype=dtype),
        "w3": dense_init(ks[2], (el, d, ff), scale_axis=1, dtype=dtype),
        "w2": dense_init(ks[3], (el, ff, d), scale_axis=1, dtype=dtype),
    }


def router_topk(logits: jax.Array, cfg: ModelConfig
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Shared routing decision: mask padded experts, softmax, top-k,
    renormalize the kept weights.

    ``logits``: [..., E_pad] raw router scores (fp32).  Returns
    ``(probs [..., E_pad], wk [..., K], ek [..., K])``.  One definition
    for the three places a token meets a router — the expert-parallel
    training block (:func:`moe_ffn`), the 2D weight-stationary decode
    block (``repro.models.serve2d.moe_ffn_2d``) and the serving tier's
    dispatch-load predictor (``repro.serve.dispatch``) — so the serving
    path's expert-load exchange counts exactly the experts the model
    would dispatch to."""
    e_pad = logits.shape[-1]
    logits = jnp.where(jnp.arange(e_pad) < cfg.n_experts, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    wk, ek = lax.top_k(probs, cfg.top_k)
    wk = wk / jnp.maximum(jnp.sum(wk, axis=-1, keepdims=True), 1e-9)
    return probs, wk, ek


def _group_by(dest: jax.Array, num_groups: int, cap: int):
    """Slot assignment: entry i -> (dest_i, rank of i within dest_i).

    Returns (slot flat index into [num_groups*cap] with overflow parked at
    num_groups*cap, keep mask).  Stable: earlier tokens win capacity.
    """
    n = dest.shape[0]
    order = jnp.argsort(dest, stable=True)
    sorted_dest = dest[order]
    # position within group = index - first index of that group
    first = jnp.searchsorted(sorted_dest, jnp.arange(num_groups))
    pos_sorted = jnp.arange(n, dtype=jnp.int32) - first[sorted_dest]
    pos = jnp.zeros((n,), jnp.int32).at[order].set(pos_sorted)
    keep = pos < cap
    slot = jnp.where(keep, dest * cap + pos, num_groups * cap)
    return slot, keep


def moe_ffn(p: Dict, x: jax.Array, cfg: ModelConfig, tp_axis: str, tp: int,
            capacity_factor: float = 2.0, token_shard: bool = True
            ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: [B, T, d] -> (y [B, T, d], aux_loss, dropped_fraction).

    token_shard=True (default): activations entering the MoE are replicated
    across the model axis (post-psum), so WITHOUT sharding every TP rank
    would route and dispatch the SAME tokens — 16x redundant expert compute
    and all_to_all traffic (found via the SPerf H2 roofline: jamba/arctic
    useful-compute ratio ~0.04).  Each rank handles its 1/tp token slice and
    the results are all_gathered at the end.
    """
    b, t, d = x.shape
    n_full = b * t
    el = cfg.experts_local(tp)
    e_pad = cfg.n_experts_padded(tp)
    k_top = cfg.top_k
    xf = x.reshape(n_full, d)
    if token_shard and tp > 1:
        n = -(-n_full // tp)                     # padded slice length
        pad = n * tp - n_full
        xp = jnp.pad(xf, ((0, pad), (0, 0)))
        shard = lax.axis_index(tp_axis)
        xf = lax.dynamic_slice_in_dim(xp, shard * n, n, 0)
    else:
        n = n_full

    # ---- route -------------------------------------------------------------
    probs, wk, ek = router_topk(
        jnp.einsum("nd,de->ne", xf.astype(jnp.float32), p["router"]), cfg)
    # switch-style load-balance aux
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(ek[:, 0], e_pad), axis=0)
    aux = jnp.sum(me * ce) * cfg.n_experts

    # ---- dispatch: bucket by owning device, all_to_all ----------------------
    flat_e = ek.reshape(n * k_top)                          # global expert id
    dest_dev = flat_e // el
    cap_dev = int(max(8, -(-n * k_top // tp) * capacity_factor))
    slot, keep = _group_by(dest_dev, tp, cap_dev)
    xk = jnp.repeat(xf, k_top, axis=0)                      # [N*K, d]
    buf = jnp.zeros((tp * cap_dev + 1, d), x.dtype).at[slot].set(
        jnp.where(keep[:, None], xk, 0))[:-1].reshape(tp, cap_dev, d)
    ebuf = jnp.full((tp * cap_dev + 1,), -1, jnp.int32).at[slot].set(
        jnp.where(keep, flat_e % el, -1))[:-1].reshape(tp, cap_dev)

    g = None  # full-axis all_to_all over the model axis
    rbuf = lax.all_to_all(buf, tp_axis, split_axis=0, concat_axis=0)
    rebuf = lax.all_to_all(ebuf, tp_axis, split_axis=0, concat_axis=0)

    # ---- local expert compute: group received tokens by local expert --------
    rx = rbuf.reshape(tp * cap_dev, d)
    re = rebuf.reshape(tp * cap_dev)
    # never more slots than tokens actually received (el=1 => exact)
    cap_e = int(min(max(8, -(-tp * cap_dev // el) * 1.25), tp * cap_dev))
    eslot, ekeep = _group_by(jnp.where(re >= 0, re, el), el, cap_e)
    ex = jnp.zeros((el * cap_e + 1, d), x.dtype).at[eslot].set(
        jnp.where((ekeep & (re >= 0))[:, None], rx, 0))[:-1]
    ex = ex.reshape(el, cap_e, d)
    h = jnp.einsum("ecd,edf->ecf", ex, p["w1"])
    h = act_fn(h, cfg.act) * jnp.einsum("ecd,edf->ecf", ex, p["w3"])
    ey = jnp.einsum("ecf,efd->ecd", h, p["w2"])             # [el, cap_e, d]
    # back to received-slot order
    ry = ey.reshape(el * cap_e, d)
    safe_es = jnp.minimum(eslot, el * cap_e - 1)
    y_slots = ry[safe_es] * (ekeep & (re >= 0))[:, None]
    y_slots = y_slots.reshape(tp, cap_dev, d)

    # ---- return route (all_to_all is its own inverse layout) ---------------
    back = lax.all_to_all(y_slots, tp_axis, split_axis=0, concat_axis=0)
    backf = back.reshape(tp * cap_dev, d)

    # ---- combine ------------------------------------------------------------
    safe_slot = jnp.minimum(slot, tp * cap_dev - 1)
    per_assign = backf[safe_slot] * keep[:, None]           # [N*K, d]
    y = jnp.sum(per_assign.reshape(n, k_top, d)
                * wk[..., None].astype(x.dtype), axis=1)
    dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
    if token_shard and tp > 1:
        y = lax.all_gather(y, tp_axis, axis=0, tiled=True)[:n_full]
    return y.reshape(b, t, d), aux.astype(jnp.float32), dropped
