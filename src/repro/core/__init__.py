"""Sparse Allreduce core (Zhao & Canny 2013) — paper contribution layer."""
from .api import SparseAllreduce
from .allreduce import (DevicePlan, Stage, dense_allreduce_binary,
                        dense_allreduce_hierarchical, dense_allreduce_ring,
                        make_device_plan, run_union_allreduce,
                        sparse_allreduce_union)
from .faults import (SCHEDULE_KINDS, FailureSchedule, completion_probability,
                     make_schedule)
from .netmodel import EC2_2013, TPU_DCN, TPU_ICI, Fabric
from .planned import PlannedSparseAllreduce, plan_sparse_allreduce
from .replication import (DeadLogicalNode, contribution_weights,
                          expected_tolerated_failures, first_alive_replicas,
                          replica_groups, simulate_random_failures)
from .simulator import ReduceStats, SimSparseAllreduce, dense_oracle
from .sparse_vec import (SENTINEL, HashPerm, SparseChunk, bucket_partition,
                         merge_add, merge_add_np, segment_compact, sort_chunk,
                         sort_coalesce_np, tree_sum, tree_sum_np)
from .topology import (ButterflyPlan, binary_plan, num_prime_factors,
                       ordered_factorizations, roundrobin_plan, tune)
from .autotune import (PlanCache, StageSample, TuneReport, calibrate_fabric,
                       calibrated_fabric, default_cache, fit_fabric,
                       measure_stage_samples, resolve_degrees, select_plan)
