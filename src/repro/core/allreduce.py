"""TPU-native Sparse Allreduce: nested heterogeneous butterfly over shard_map.

The paper's point-to-point socket schedule maps onto mesh collectives:

  * one butterfly layer of degree k  ==  ``lax.all_to_all`` within
    ``axis_index_groups`` of size k along the data-parallel mesh axis
    (down / scatter-reduce), and ``lax.all_gather`` within the same groups
    in reverse order (up / allgather) — the paper's *nested* pattern;
  * the hash-permuted sorted-range partition becomes a static-shape
    ``bucket_partition`` (contiguous slabs of the sorted chunk);
  * the tree-merge sum becomes sort + segment-compact (MXU-friendly
    one-hot-matmul kernel in kernels/segment_compact.py).

SPMD needs static shapes, so every stage has a capacity derived from the
requested output capacity plus a balance slack; overflow is *counted* and
returned (the same contract as MoE token dropping).  The paper's hash
permutation is exactly what makes these capacities safe.

Dense baselines (ring / binary butterfly / hierarchical heterogeneous
butterfly) live here too — they are the paper's §II comparison points and
the beyond-paper dense gradient path.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .sparse_vec import (SENTINEL, SparseChunk, bucket_partition,
                         concat_sorted_groups, segment_compact, sort_chunk)
from .topology import ButterflyPlan, check_wire


# ---------------------------------------------------------------------------
# Device-side plan: stages spanning one or more mesh axes
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Stage:
    """One butterfly layer bound to a mesh axis."""
    axis_name: str
    degree: int
    axis_index_groups: Tuple[Tuple[int, ...], ...]
    bucket_capacity: int
    merged_capacity: int


@dataclasses.dataclass(frozen=True)
class DevicePlan:
    """Butterfly plan bound to mesh axes, with static capacities.

    ``axes``: ordered [(axis_name, axis_size)], most-significant first
    (e.g. [("pod", 2), ("data", 16)]).  ``degrees_per_axis`` factorizes each
    axis; the concatenated degree sequence is the logical ButterflyPlan over
    prod(sizes) nodes.  Edges arrays are host-precomputed per logical node
    and passed into shard_map sharded over the same axes.

    ``replication`` > 1 marks the plan as r-way replicated (paper §V):
    the ``num_nodes`` physical devices host ``num_nodes / r`` logical
    shards, replica j of shard i at physical id ``i + j * num_logical``
    (``repro.core.replication.replica_groups``), and stage 0 is the
    replica-merge layer — node ids are mixed-radix with digit 0 most
    significant, so prepending degree r makes the stage-0 groups exactly
    the replica groups.
    """

    axes: Tuple[Tuple[str, int], ...]
    stages: Tuple[Stage, ...]
    logical: ButterflyPlan
    in_capacity: int
    out_capacity: int
    replication: int = 1

    @property
    def num_nodes(self) -> int:
        """Physical node count (= prod of the bound mesh-axis sizes)."""
        return self.logical.num_nodes

    @property
    def num_logical(self) -> int:
        """Logical shard count (== num_nodes unless replicated)."""
        return self.logical.num_nodes // self.replication

    def replica_groups(self):
        """[[physical ids] per logical shard] (see core.replication)."""
        from .replication import replica_groups
        return replica_groups(self.num_nodes, self.replication)

    def edges_arrays(self) -> List[np.ndarray]:
        """Per-stage [*axis_sizes, k_l + 1] uint32 range-edge tensors."""
        out = []
        shape = tuple(s for _, s in self.axes)
        for l, st in enumerate(self.stages):
            e = self.logical.all_edges(l)                       # [M, k+1] int64
            e = np.minimum(e, (1 << 32) - 1).astype(np.uint32)
            out.append(e.reshape(shape + (st.degree + 1,)))
        return out


def make_device_plan(axes: Sequence[Tuple[str, int]],
                     degrees_per_axis: dict,
                     in_capacity: int,
                     out_capacity: int,
                     slack: float = 2.0,
                     replication: int = 1) -> DevicePlan:
    """Bind a heterogeneous butterfly to mesh axes with static capacities.

    Capacity schedule: stage l buckets hold ``ceil(m_{l-1}/k * slack)``
    entries; merged chunks hold ``min(k*c_l, ceil(out_capacity * slack /
    prod(k_1..k_l)))`` — lossless when the hash permutation balances ranges
    (paper §III-A) and ``out_capacity`` covers the global union.

    ``replication=r`` builds the r-way replicated layout (paper §V):
    ``degrees_per_axis`` then gives the *logical* degree sequence (over
    ``size / r`` shards for the first axis) and the physical plan prepends
    a degree-r replica-merge stage to the first (most significant) axis,
    whose groups are ``replication.replica_groups(prod(sizes), r)``.
    Apply ``contribution_weights`` to the values fed in (``dead=`` on
    :func:`run_union_allreduce`) so each shard is counted exactly once.
    """
    if replication < 1:
        raise ValueError(f"replication must be >= 1, got {replication}")
    if replication > 1:
        name0, size0 = axes[0]
        if size0 % replication:
            raise ValueError(
                f"first axis {name0}={size0} not divisible by "
                f"r={replication}")
        base = tuple(degrees_per_axis.get(
            name0, (size0 // replication,) if size0 > replication else ()))
        degrees_per_axis = dict(degrees_per_axis)
        degrees_per_axis[name0] = (replication,) + base
    degrees: List[int] = []
    for name, size in axes:
        d = tuple(degrees_per_axis.get(name, (size,)))
        if math.prod(d) != size:
            raise ValueError(f"axis {name}: prod{d} != {size}")
        degrees.extend(d)
    m = math.prod(s for _, s in axes)
    logical = ButterflyPlan(m, tuple(degrees))

    # axis-local groups per stage
    stages: List[Stage] = []
    li = 0
    m_prev = in_capacity
    prod_k = 1
    for name, size in axes:
        sub = ButterflyPlan(size, tuple(degrees_per_axis.get(name, (size,))))
        for sl in range(sub.depth):
            k = sub.degrees[sl]
            groups = tuple(tuple(g) for g in sub.axis_index_groups(sl))
            cap = _round8(int(math.ceil(m_prev / k * slack)))
            prod_k *= k
            merged = min(k * cap,
                         _round8(int(math.ceil(out_capacity * slack / prod_k))))
            merged = max(merged, 8)
            stages.append(Stage(axis_name=name, degree=k,
                                axis_index_groups=groups,
                                bucket_capacity=cap, merged_capacity=merged))
            m_prev = merged
            li += 1
    return DevicePlan(axes=tuple(axes), stages=tuple(stages), logical=logical,
                      in_capacity=in_capacity, out_capacity=out_capacity,
                      replication=replication)


def _round8(x: int) -> int:
    return max(8, ((x + 7) // 8) * 8)


def shape_bucket(n: int, floor: int = 8) -> int:
    """Round a capacity up to the next power of two (at least ``floor``).

    Serving-tier plan resolution (``repro.serve.dispatch``): continuous
    batching churns the per-step unique-index count, and every distinct
    ``union_reduce`` capacity is a distinct compiled pipeline in
    ``SparseAllreduce._union_cache``.  Bucketing capacities to powers of
    two bounds the cache at O(log range) entries, so after warmup nearly
    every step is a plan-cache hit (benchmarks/bench_serve.py reports the
    hit rate; acceptance floor 0.8)."""
    if n < 0:
        raise ValueError(f"shape_bucket: capacity must be >= 0, got {n}")
    if floor < 1:
        raise ValueError(f"shape_bucket: floor must be >= 1, got {floor}")
    b = int(floor)
    while b < n:
        b <<= 1
    return b


# Per-layer merge strategies for the union allreduce (see
# sparse_allreduce_union docstring; "fused"/"banded" are the Pallas modes
# of repro.kernels.ops.merge_sorted_runs).
MERGE_MODES = ("sort", "fused", "banded")


# ---------------------------------------------------------------------------
# The primitive: fused config-reduce with gather-all (union) semantics.
# Runs INSIDE shard_map.  (The paper's mini-batch mode: dynamic indices.)
# ---------------------------------------------------------------------------

def sparse_allreduce_union(chunk: SparseChunk, plan: DevicePlan,
                           edges: Sequence[jax.Array],
                           use_kernel: bool = False,
                           merge: str = "sort",
                           weight: Optional[jax.Array] = None,
                           wire: str = "raw"
                           ) -> Tuple[SparseChunk, jax.Array]:
    """Nested butterfly sparse allreduce; every node gets the full union sum.

    ``chunk``: this device's sorted SparseChunk (hashed indices).
    ``edges``: per-stage range-edge arrays, each shaped [1,...,1, k_l+1]
    after shard_map slicing — i.e. this device's own edges.
    ``merge`` selects the per-layer merge of the k sorted runs arriving at
    each butterfly layer: ``"sort"`` concatenates and fully re-sorts before
    segment-compacting; ``"fused"`` rank-merges the already-sorted runs,
    compacts duplicates, and scatter-adds in one pass via the Pallas
    pipeline in ``repro.kernels.ops.merge_sorted_runs`` (interpret-mode
    fallback off-TPU); ``"banded"`` is the same pipeline with both kernels
    band-limited by the sortedness bound (frontier-only compare tiles,
    ceil(k*bm/bk)+1 scatter tiles per output tile — see
    ``kernels.costmodel``).  All three produce identical results.
    ``weight`` (r-way replicated plans, paper §V): this device's scalar
    ``contribution_weights`` entry — 1.0 on the first alive replica of each
    logical shard, 0.0 elsewhere — multiplied into the values before the
    first layer so every shard's sum is taken from exactly one replica.
    Indices still flow from every replica (zeros merge away bit-exactly),
    so the union is identical to the fault-free non-replicated result.
    ``wire`` picks the on-wire payload encoding (``topology.WIRE_MODES``;
    codecs in ``repro.kernels.wirecodec``): every collective then carries
    bit-packed index offsets instead of uint32 words, and — for the lossy
    modes — bf16 or per-row int8 values, decoded against the statically
    known stage subrange base on the receiving side (down: this device's
    bucket; up: gather row t covers subrange t).  ``"delta"`` is exactly
    lossless, so its result is bit-identical to ``"raw"``; for the fused
    merge modes the int8 dequantization rides inside the scatter kernel
    (``merge_sorted_runs(row_scale=...)``) so wire payloads are never
    widened in memory.
    Returns (union chunk of capacity ``out_capacity`` per device replica,
    overflow count — entries dropped to capacity anywhere in the network).
    """
    if merge not in MERGE_MODES:
        raise ValueError(f"merge must be one of {MERGE_MODES}, got {merge!r}")
    check_wire(wire)
    if weight is not None:
        w = weight.reshape(()).astype(chunk.val.dtype)
        chunk = SparseChunk(idx=chunk.idx, val=chunk.val * w)
    overflow = jnp.zeros((), jnp.int32)
    compute_dtype = chunk.val.dtype
    if wire != "raw":
        from repro.kernels import wirecodec as _wc
        widths = _wc.stage_index_bits(plan)
        strides = _wc.stage_strides(plan)

    # ---- down: scatter-reduce through the layers --------------------------
    for l, st in enumerate(plan.stages):
        e = edges[l].reshape((-1,))[-(st.degree + 1):]
        groups = list(map(list, st.axis_index_groups))
        buckets, ovf = bucket_partition(chunk, e, st.degree,
                                        st.bucket_capacity)
        overflow = overflow + ovf
        scale = None
        if wire == "raw":
            send_idx, send_val = buckets.idx, buckets.val
        else:
            # Bucket d covers [e[d], e[d+1]); ship offsets from e[d].
            send_idx = _wc.pack_indices(buckets.idx,
                                        e[:st.degree].astype(jnp.uint32),
                                        widths[l])
            send_val = buckets.val
            if wire == "delta+bf16":
                send_val = send_val.astype(jnp.bfloat16)
            elif wire == "delta+int8ef":
                send_val, scale = _wc.quant8_rows(send_val)
        r_idx = lax.all_to_all(send_idx, st.axis_name, split_axis=0,
                               concat_axis=0, axis_index_groups=groups)
        r_val = lax.all_to_all(send_val, st.axis_name, split_axis=0,
                               concat_axis=0, axis_index_groups=groups)
        r_scale = None
        if scale is not None:
            r_scale = lax.all_to_all(scale, st.axis_name, split_axis=0,
                                     concat_axis=0, axis_index_groups=groups)
        if wire != "raw":
            # Every received row is a bucket for *this* device's subrange,
            # whose base is e[j] with j = our position in the stage group
            # (group members share identical stage-l edges).
            j = (lax.axis_index(st.axis_name) // strides[l]) % st.degree
            base = jnp.broadcast_to(e[j].astype(jnp.uint32), (st.degree,))
            r_idx = _wc.unpack_indices(r_idx, base, st.bucket_capacity,
                                       widths[l])
        if merge in ("fused", "banded"):
            from repro.kernels import ops as _kops
            chunk, movf = _kops.merge_sorted_runs(
                r_idx, r_val, st.merged_capacity, mode=merge,
                row_scale=r_scale,
                out_dtype=compute_dtype if wire != "raw" else None)
            overflow = overflow + movf
        else:
            if r_scale is not None:
                r_val = _wc.dequant8_rows(r_val, r_scale)
            r_val = r_val.astype(compute_dtype)
            cat = concat_sorted_groups(r_idx, r_val)
            from .sparse_vec import compact_overflow
            overflow = overflow + compact_overflow(cat, st.merged_capacity)
            chunk = segment_compact(cat, st.merged_capacity,
                                    use_kernel=use_kernel)

    # ---- up: allgather back through the same nodes (nested) ---------------
    for li in range(len(plan.stages) - 1, -1, -1):
        st = plan.stages[li]
        g = list(map(list, st.axis_index_groups))
        if wire == "raw":
            idx = lax.all_gather(chunk.idx, st.axis_name, axis_index_groups=g,
                                 axis=0, tiled=True)
            val = lax.all_gather(chunk.val, st.axis_name, axis_index_groups=g,
                                 axis=0, tiled=True)
        else:
            # The sender's chunk covers its own stage-li subrange [e[j],
            # e[j+1]); after the gather, row t covers subrange t of the
            # group-shared edges, so both bases are static knowledge.
            k = st.degree
            e = edges[li].reshape((-1,))[-(k + 1):]
            j = (lax.axis_index(st.axis_name) // strides[li]) % k
            packed = _wc.pack_indices(chunk.idx[None, :],
                                      e[j].astype(jnp.uint32)[None],
                                      widths[li])[0]
            words = lax.all_gather(packed, st.axis_name, axis_index_groups=g,
                                   axis=0, tiled=True).reshape((k, -1))
            idx = _wc.unpack_indices(words, e[:k].astype(jnp.uint32),
                                     chunk.capacity, widths[li]
                                     ).reshape((-1,))
            if wire == "delta":
                val = lax.all_gather(chunk.val, st.axis_name,
                                     axis_index_groups=g, axis=0, tiled=True)
            elif wire == "delta+bf16":
                val = lax.all_gather(chunk.val.astype(jnp.bfloat16),
                                     st.axis_name, axis_index_groups=g,
                                     axis=0, tiled=True).astype(compute_dtype)
            else:
                q, s = _wc.quant8_rows(chunk.val[None])
                gq = lax.all_gather(q[0], st.axis_name, axis_index_groups=g,
                                    axis=0, tiled=True)
                gs = lax.all_gather(s, st.axis_name, axis_index_groups=g,
                                    axis=0, tiled=True)        # [k] row scales
                per = jnp.repeat(gs.astype(jnp.float32), chunk.capacity)
                val = (gq.astype(jnp.float32)
                       * per[(...,) + (None,) * (gq.ndim - 1)]
                       ).astype(compute_dtype)
        chunk = SparseChunk(idx=idx, val=val)  # concat of sorted disjoint ranges

    # Trim/pad to the advertised out capacity (sorted already).
    if chunk.capacity != plan.out_capacity:
        chunk = _trim_sorted(chunk, plan.out_capacity)
    return chunk, overflow


def _trim_sorted(chunk: SparseChunk, cap: int) -> SparseChunk:
    """Keep the first ``cap`` *valid* rows of a concat-of-sorted-ranges chunk.

    The concatenation of disjoint sorted ranges is globally sorted except for
    interleaved sentinel padding; compact valid rows to the front first.
    """
    valid = chunk.valid_mask()
    pos = jnp.cumsum(valid.astype(jnp.int32)) - 1
    c = chunk.capacity
    dest = jnp.where(valid, pos, c)
    out_idx = jnp.full((max(cap, 1),), SENTINEL, jnp.uint32)
    out_idx = out_idx.at[dest].set(chunk.idx, mode="drop")
    vshape = (cap,) + chunk.val.shape[1:]
    out_val = jnp.zeros(vshape, chunk.val.dtype)
    mask = valid[(...,) + (None,) * (chunk.val.ndim - 1)]
    out_val = out_val.at[dest].set(jnp.where(mask, chunk.val, 0), mode="drop")
    return SparseChunk(idx=out_idx, val=out_val)


# ---------------------------------------------------------------------------
# Dense baselines (paper §II) — run inside shard_map
# ---------------------------------------------------------------------------

def dense_allreduce_ring(x: jax.Array, axis_name) -> jax.Array:
    """Stock psum — XLA lowers to (bidirectional) ring; the round-robin
    analogue and the baseline every framework uses."""
    return lax.psum(x, axis_name)


def dense_allreduce_hierarchical(x: jax.Array, plan: DevicePlan) -> jax.Array:
    """Heterogeneous-degree hierarchical dense allreduce (beyond-paper dense
    path): reduce-scatter down the butterfly layers, all-gather back up.
    Requires x.shape[0] divisible by the total butterfly size."""
    for st in plan.stages:
        g = list(map(list, st.axis_index_groups))
        x = lax.psum_scatter(x, st.axis_name, scatter_dimension=0,
                             axis_index_groups=g, tiled=True)
    for st in reversed(plan.stages):
        g = list(map(list, st.axis_index_groups))
        x = lax.all_gather(x, st.axis_name, axis_index_groups=g, axis=0,
                           tiled=True)
    return x


def dense_allreduce_hierarchical_bucketed(
        xs: Sequence[jax.Array], plan: DevicePlan) -> List[jax.Array]:
    """:func:`dense_allreduce_hierarchical` over a list of buckets with a
    **stage-major** issue order: every bucket's stage-``l`` exchange is
    issued before any bucket's stage-``l+1`` (ARCHITECTURE.md "Overlap &
    scheduling").  With B buckets of depth D the lowered collective
    sequence is D runs of B ``reduce_scatter`` ops followed by D runs of B
    ``all_gather`` ops (reversed stage order) — the shape that lets XLA's
    latency-hiding scheduler slide independent compute between a bucket's
    issue and its consumption, instead of the one monolithic
    back-to-back chain the single-tensor path produces.

    Both collectives are elementwise across the vector dimension and sum
    contributions in fixed participant order, so reordering *which bucket*
    goes first never reorders any element's reduction: each bucket's
    result is bitwise identical to reducing it alone
    (tests/test_overlap.py).  Same per-bucket divisibility contract as the
    single-tensor path; collective count is ``2 * depth * len(xs)`` —
    exactly ``len(xs)`` monolithic reductions' worth, no extra phases
    (audited by ``repro.analysis.auditor.audit_overlap_sync``).
    """
    xs = list(xs)
    for st in plan.stages:
        g = list(map(list, st.axis_index_groups))
        xs = [lax.psum_scatter(x, st.axis_name, scatter_dimension=0,
                               axis_index_groups=g, tiled=True) for x in xs]
    for st in reversed(plan.stages):
        g = list(map(list, st.axis_index_groups))
        xs = [lax.all_gather(x, st.axis_name, axis_index_groups=g, axis=0,
                             tiled=True) for x in xs]
    return xs


def dense_allreduce_binary(x: jax.Array, axis_name: str, axis_size: int) -> jax.Array:
    """Degree-2 butterfly (hypercube) allreduce via paired psums."""
    plan = ButterflyPlan(axis_size, (2,) * int(math.log2(axis_size)))
    for l in range(plan.depth):
        g = [list(gr) for gr in plan.axis_index_groups(l)]
        x = lax.psum_scatter(x, axis_name, scatter_dimension=0,
                             axis_index_groups=g, tiled=True)
    for l in reversed(range(plan.depth)):
        g = [list(gr) for gr in plan.axis_index_groups(l)]
        x = lax.all_gather(x, axis_name, axis_index_groups=g, axis=0, tiled=True)
    return x


# ---------------------------------------------------------------------------
# Host-side helpers to run the primitive end to end (tests / examples)
# ---------------------------------------------------------------------------

def run_union_allreduce(mesh: jax.sharding.Mesh, plan: DevicePlan,
                        idx: jax.Array, val: jax.Array,
                        use_kernel: bool = False, merge: str = "sort",
                        dead=None, wire: str = "raw"):
    """Convenience wrapper: shard (idx, val) over the plan's axes and run.

    idx: uint32 [M, C] hashed *sorted* indices per node (SENTINEL padded)
    val: [M, C] or [M, C, W]
    ``merge``: per-layer merge strategy ("sort" | "fused" | "banded"); see
    :func:`sparse_allreduce_union`.
    ``wire``: on-wire payload encoding ("raw" | "delta" | "delta+bf16" |
    "delta+int8ef"); "delta" is bit-identical to "raw", the lossy modes
    trade bounded value error for bytes (see ``kernels.wirecodec``).
    ``dead``: set of dead *physical* node ids for r-way replicated plans
    (``make_device_plan(replication=r)``); the corresponding
    ``contribution_weights`` are applied inside shard_map so each logical
    shard is summed from its first alive replica.  Raises
    ``DeadLogicalNode`` if a whole replica group is dead — with
    ``replication=1`` any non-empty ``dead`` raises (no redundancy).
    Completion probability and overhead: benchmarks/bench_fault_tolerance.py.
    Returns (idx [M, out_cap], val [M, out_cap(,W)], overflow [M]).
    """
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    axis_names = tuple(n for n, _ in plan.axes)
    shape = tuple(s for _, s in plan.axes)
    edges = [jnp.asarray(e) for e in plan.edges_arrays()]
    idx_r = idx.reshape(shape + idx.shape[1:])
    val_r = val.reshape(shape + val.shape[1:])

    weights = None
    if plan.replication > 1 or dead:
        from .replication import contribution_weights
        weights = jnp.asarray(contribution_weights(
            plan.num_nodes, plan.replication, dead)).reshape(shape)

    data_specs = P(*axis_names)
    edge_specs = tuple(P(*axis_names, *([None])) for _ in edges)
    w_specs = (data_specs,) if weights is not None else ()
    w_args = (weights,) if weights is not None else ()

    def body(i, v, *rest):
        if weights is not None:
            w, e = rest[0], rest[1:]
        else:
            w, e = None, rest
        i = i.reshape(i.shape[len(shape):])
        v = v.reshape(v.shape[len(shape):])
        chunk, ovf = sparse_allreduce_union(SparseChunk(idx=i, val=v), plan,
                                            e, use_kernel=use_kernel,
                                            merge=merge, weight=w, wire=wire)
        pad = (1,) * len(shape)
        return (chunk.idx.reshape(pad + chunk.idx.shape),
                chunk.val.reshape(pad + chunk.val.shape),
                ovf.reshape(pad))

    fn = shard_map(body, mesh=mesh,
                   in_specs=(data_specs, data_specs) + w_specs + edge_specs,
                   out_specs=(data_specs, data_specs, data_specs),
                   check_vma=False)
    oi, ov, ovf = fn(idx_r, val_r, *w_args, *edges)
    m = math.prod(shape)
    return (oi.reshape((m,) + oi.shape[len(shape):]),
            ov.reshape((m,) + ov.shape[len(shape):]),
            ovf.reshape((m,)))
