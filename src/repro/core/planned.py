"""Planned Sparse Allreduce: host-side ``config``, device-side ``reduce``.

This is the paper's property #2 (§I-B): *"Index calculations (configuration)
can be separated from value calculations and only computed once for problems
where the indices are fixed (e.g. PageRank iterations)."*

``config`` runs the message-level routing ONCE on host (numpy, via the
simulator's data structures), then freezes every routing decision into
static, padded gather/scatter index tensors.  ``reduce`` is then a pure
static-shape device program — gathers, ``all_to_all`` exchanges, and
scatter-adds inside shard_map — jitted once and reused every iteration with
new values.  Indices are never re-communicated (paper §IV-A: "vertex indices
are already hard-coded in the maps").
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .allreduce import DevicePlan
from .sparse_vec import HashPerm
from .simulator import SimSparseAllreduce
from .topology import ButterflyPlan


def _pad_gather(rows: List[np.ndarray], width: int) -> np.ndarray:
    """Stack ragged position rows into [len(rows), width], -1 padded."""
    out = np.full((len(rows), width), -1, np.int32)
    for i, r in enumerate(rows):
        out[i, : len(r)] = r
    return out


@dataclasses.dataclass
class _LayerMaps:
    send_gather: np.ndarray    # [M, k, cap]  -> positions in current values
    merge_scatter: np.ndarray  # [M, k, cap]  -> positions in next values (or m_max)
    merged_size: int           # m_max (+1 slot used as drop bin)
    up_send_gather: np.ndarray  # [M, k, upcap] -> positions in my up array
    up_recv_scatter: np.ndarray  # [M, k, upcap] -> positions in my (layer-l) up array
    up_size: int


@dataclasses.dataclass
class PlannedSparseAllreduce:
    """Static-index sparse allreduce bound to a mesh (device backend only;
    the simulator analogue is ``SimSparseAllreduce``).

    Build with :func:`plan_sparse_allreduce` (the paper's ``config``) —
    host-side numpy, run once per index pattern.  Afterwards everything is
    static and reusable every iteration:

    * :meth:`reduce_on_device` — the shard_map *body*: per-device values
      ``[u_cap(,W)]`` in, per-device results ``[uin_cap(,W)]`` out.  Pure
      static-shape JAX, so it composes into larger jitted programs — in
      particular into a ``lax.scan`` iteration loop (see
      ``repro.graph.engine``, which fuses a local SpMV with this body to
      run k PageRank/HADI/spectral rounds in one dispatch).
    * :meth:`make_reduce_fn` — a standalone jitted host entry point
      (``[M, u_cap(,W)] -> [M, uin_cap(,W)]``) for per-call use.
    * :meth:`device_args` / :meth:`arg_specs` — the frozen routing tensors
      (and their PartitionSpecs) that ``reduce_on_device`` consumes; pass
      them through your own shard_map sharded over the plan axes.  They are
      iteration-invariant: hoist them out of any scan.

    Amortization contract: one ``plan_sparse_allreduce`` call amortizes
    over arbitrarily many ``reduce_on_device`` / ``reduce_fn`` invocations
    as long as the index pattern (and mesh) is unchanged; values may differ
    freely.  Width ``W`` (``value_width``) is frozen at plan time.
    """

    dplan: DevicePlan
    perm: HashPerm
    width: int
    # host-side padded routing tensors (converted lazily to device arrays)
    user_scatter: np.ndarray        # [M, u_cap] user slot -> sorted slot
    sorted_size: int
    layers: List[_LayerMaps]
    bottom_gather: np.ndarray       # [M, q_cap] positions into bottom values
    bottom_hit: np.ndarray          # [M, q_cap] bool
    user_gather: np.ndarray         # [M, uin_cap] sorted-in slot per user slot
    in_user_len: int
    # r-way replication (paper §V): per-physical-node contribution weight
    # (1.0 on each logical shard's first alive replica, 0.0 elsewhere),
    # applied to the values inside shard_map.  None when not replicated.
    weights: Optional[np.ndarray] = None
    # Trace-count regression hook: ``reduce_on_device`` runs only while a
    # surrounding program is being traced, so this counts (re)traces of the
    # reduce body.  The autotuner's plan memo (``repro.core.autotune``)
    # asserts it stays flat across plan-cache hits.
    trace_count: int = dataclasses.field(default=0, compare=False)

    # ---------------------------------------------------------------------
    @property
    def u_cap(self) -> int:
        """Per-device *outbound* value capacity: ``reduce_on_device`` takes
        ``[u_cap(,W)]`` (node n's first ``len(out_indices[n])`` slots are
        its user values, the rest zero padding)."""
        return int(self.user_scatter.shape[1])

    @property
    def uin_cap(self) -> int:
        """Per-device *inbound* capacity: ``reduce_on_device`` returns
        ``[uin_cap(,W)]`` (node n's first ``len(in_indices[n])`` slots are
        the reduced values in its requested order, the rest zeros)."""
        return int(self.in_user_len)

    @property
    def depth(self) -> int:
        """Butterfly depth — each reduce runs ``depth`` down + ``depth`` up
        ``all_to_all`` collectives (the per-round sync count)."""
        return len(self.layers)

    @property
    def q_cap(self) -> int:
        """Per-device *bottom* capacity: :meth:`reduce_down_on_device`
        returns (and :meth:`reduce_up_on_device` takes) ``[q_cap(,W)]`` —
        the root-layer partial sums each node owns between the two
        halves."""
        return int(self.bottom_gather.shape[1])

    # ---------------------------------------------------------------------
    def device_args(self):
        """Routing tensors as jnp arrays, ordered for reduce_on_device."""
        args = [jnp.asarray(self.user_scatter)]
        if self.weights is not None:
            args.insert(0, jnp.asarray(self.weights))
        for L in self.layers:
            args += [jnp.asarray(L.send_gather), jnp.asarray(L.merge_scatter),
                     jnp.asarray(L.up_send_gather), jnp.asarray(L.up_recv_scatter)]
        args += [jnp.asarray(self.bottom_gather), jnp.asarray(self.bottom_hit),
                 jnp.asarray(self.user_gather)]
        return args

    def arg_specs(self):
        """PartitionSpecs matching :meth:`device_args`, sharded over the
        plan axes (pass through your own shard_map's in_specs)."""
        from jax.sharding import PartitionSpec as P
        axes = tuple(n for n, _ in self.dplan.axes)
        n = len(self.device_args())
        return tuple(P(axes if len(axes) > 1 else axes[0]) for _ in range(n))

    # ---------------------------------------------------------------------
    def _routing_parts(self, routing):
        """Name + squeeze the flat ``routing`` tuple both halves consume.

        Routing tensors arrive sharded with a leading per-device dim of
        size 1 on each plan axis; returns ``(weights, user_scatter,
        per_layer, bottom_gather, bottom_hit, user_gather)`` with
        ``per_layer`` a list of ``(send_gather, merge_scatter,
        up_send_gather, up_recv_scatter)`` tuples."""
        nax = len(self.dplan.axes)

        def sq(a):
            return a.reshape(a.shape[nax:])

        it = iter(routing)
        weights = sq(next(it)) if self.weights is not None else None
        user_scatter = sq(next(it))
        per_layer = [tuple(sq(next(it)) for _ in range(4))
                     for _ in self.layers]
        return (weights, user_scatter, per_layer,
                sq(next(it)), sq(next(it)), sq(next(it)))

    def reduce_on_device(self, values: jax.Array, *routing) -> jax.Array:
        """shard_map body: values [u_cap(,W)] on this device -> [uin_cap(,W)].

        Composition of the two halves — ``depth`` down ``all_to_all``
        stages then ``depth`` up stages back-to-back (the bulk-synchronous
        schedule).  Overlapped callers (``repro.graph.engine`` with
        ``overlap=True``) call :meth:`reduce_down_on_device` /
        :meth:`reduce_up_on_device` directly so independent compute can sit
        between the halves; both schedules run the identical op sequence,
        so results are bitwise equal (tests/test_overlap.py).
        """
        return self.reduce_up_on_device(
            self.reduce_down_on_device(values, *routing), *routing)

    def reduce_down_on_device(self, values: jax.Array, *routing) -> jax.Array:
        """Bottom half of the reduce: user values ``[u_cap(,W)]`` ->
        root-layer partial sums ``[q_cap(,W)]`` (``depth`` down
        ``all_to_all`` stages + per-stage scatter-add merges).  Counts one
        reduce trace (``trace_count``); the up half does not, so a full
        reduce nets exactly one however it is scheduled."""
        self.trace_count += 1
        (weights, user_scatter, per_layer, bottom_gather, bottom_hit,
         _user_gather) = self._routing_parts(routing)
        if weights is not None:
            # replica contribution weight (scalar per device, paper §V)
            values = values * weights.astype(values.dtype)
        W = values.shape[-1] if values.ndim > 1 else None

        def zeros(n):
            return jnp.zeros((n,) if W is None else (n, W), values.dtype)

        # coalesce user values onto sorted slots (+1 drop bin for padding)
        cur = zeros(self.sorted_size + 1).at[user_scatter].add(values)[:-1]

        stages = self.dplan.stages
        for l, L in enumerate(self.layers):
            send_g, merge_s, _up_g, _up_s = per_layer[l]
            k, cap = send_g.shape[0], send_g.shape[1]
            safe = jnp.maximum(send_g, 0)
            picked = cur[safe] * (send_g >= 0)[(...,) + (None,) * (values.ndim - 1)]
            g = list(map(list, stages[l].axis_index_groups))
            recv = lax.all_to_all(picked, stages[l].axis_name, split_axis=0,
                                  concat_axis=0, axis_index_groups=g)
            nxt = zeros(L.merged_size + 1)
            nxt = nxt.at[merge_s.reshape((-1,))].add(
                recv.reshape((k * cap,) + recv.shape[2:]))
            cur = nxt[:-1]

        return cur[jnp.maximum(bottom_gather, 0)] \
            * bottom_hit[(...,) + (None,) * (values.ndim - 1)]

    def reduce_up_on_device(self, up: jax.Array, *routing) -> jax.Array:
        """Top half of the reduce: root-layer partials ``[q_cap(,W)]``
        (from :meth:`reduce_down_on_device`) -> requested values
        ``[uin_cap(,W)]`` (``depth`` up ``all_to_all`` return stages in
        reverse layer order + the final user gather)."""
        (_weights, _user_scatter, per_layer, _bottom_gather, _bottom_hit,
         user_gather) = self._routing_parts(routing)
        ndim = up.ndim
        W = up.shape[-1] if ndim > 1 else None

        def zeros(n):
            return jnp.zeros((n,) if W is None else (n, W), up.dtype)

        for l in reversed(range(len(self.layers))):
            _send_g, _merge_s, up_g, up_s = per_layer[l]
            k, cap = up_g.shape[0], up_g.shape[1]
            safe = jnp.maximum(up_g, 0)
            picked = up[safe] * (up_g >= 0)[(...,) + (None,) * (ndim - 1)]
            g = list(map(list, self.dplan.stages[l].axis_index_groups))
            recv = lax.all_to_all(picked, self.dplan.stages[l].axis_name,
                                  split_axis=0, concat_axis=0,
                                  axis_index_groups=g)
            nxt = zeros(self.layers[l].up_size + 1)
            nxt = nxt.at[up_s.reshape((-1,))].set(
                recv.reshape((k * cap,) + recv.shape[2:]), mode="drop")
            up = nxt[:-1]

        return up[jnp.maximum(user_gather, 0)] \
            * (user_gather >= 0)[(...,) + (None,) * (ndim - 1)]

    # ---------------------------------------------------------------------
    def with_dead(self, dead=None) -> "PlannedSparseAllreduce":
        """Incremental repair: the same frozen routing with a new dead set.

        Only the per-device contribution weights depend on ``dead`` — the
        gather/scatter routing tensors are dead-set-invariant (every device
        receives the full union, paper §V) — so repairing a plan after a
        replica-absorbed failure is a ``dataclasses.replace`` of the
        weights, not a host replan.  The result needs one retrace (weights
        are baked into the jitted body as constants), hence the fresh
        ``trace_count``.  Raises ``DeadLogicalNode`` when ``dead`` kills a
        whole replica group — callers wanting to continue must replan over
        survivors instead (``repro.resilience``).
        """
        from .replication import contribution_weights
        weights = contribution_weights(self.dplan.logical.num_nodes,
                                       self.dplan.replication, dead)
        return dataclasses.replace(self, weights=weights, trace_count=0)

    # ---------------------------------------------------------------------
    def make_reduce_fn(self, mesh: jax.sharding.Mesh):
        """Jitted host entry: values [M, u_cap(,W)] -> [M, uin_cap(,W)]."""
        from jax.sharding import PartitionSpec as P

        from repro.compat import shard_map
        shape = tuple(s for _, s in self.dplan.axes)
        axes = tuple(n for n, _ in self.dplan.axes)
        nax = len(shape)
        spec = P(*axes)
        routing = self.device_args()

        def body(v, *r):
            v = v.reshape(v.shape[nax:])
            out = self.reduce_on_device(v, *r)
            return out.reshape((1,) * nax + out.shape)

        fn = shard_map(body, mesh=mesh,
                       in_specs=(spec,) + tuple(spec for _ in routing),
                       out_specs=spec, check_vma=False)

        def run(values: jax.Array) -> jax.Array:
            v = values.reshape(shape + values.shape[1:])
            out = fn(v, *routing)
            m = math.prod(shape)
            return out.reshape((m,) + out.shape[nax:])

        return jax.jit(run)


# ---------------------------------------------------------------------------
# config: run host routing once, freeze into padded tensors
# ---------------------------------------------------------------------------

def plan_sparse_allreduce(dplan: DevicePlan,
                          out_indices: Sequence[np.ndarray],
                          in_indices: Sequence[np.ndarray],
                          perm: Optional[HashPerm] = None,
                          width: int = 1,
                          dead=None) -> PlannedSparseAllreduce:
    """The paper's ``config`` call: indices in, frozen routing out.

    For r-way replicated plans (``make_device_plan(replication=r)``,
    paper §V) ``out_indices`` / ``in_indices`` are the *logical* per-shard
    index lists (``dplan.num_logical`` of them); routing is frozen for all
    ``r * num_logical`` physical replicas and ``dead`` physical node ids
    are masked via ``contribution_weights`` applied to the values inside
    shard_map.  Raises ``DeadLogicalNode`` when a whole replica group is
    dead.  Cost curves: benchmarks/bench_fault_tolerance.py.
    """
    perm = perm if perm is not None else HashPerm.make(0)
    weights = None
    if dplan.replication > 1 or dead:
        from .replication import contribution_weights
        weights = contribution_weights(dplan.logical.num_nodes,
                                       dplan.replication, dead)
        if len(out_indices) != dplan.num_logical:
            raise ValueError(
                f"replicated plan expects {dplan.num_logical} logical index "
                f"lists, got {len(out_indices)}")
        out_indices = list(out_indices) * dplan.replication
        in_indices = list(in_indices) * dplan.replication
    sim = SimSparseAllreduce(dplan.logical, perm=perm, value_width=width)
    sim.config(out_indices, in_indices)
    plan, m = dplan.logical, dplan.logical.num_nodes
    didx = sim._down_idx_cache  # per-layer sorted idx arrays

    u_cap = max(len(u) for u in sim.out_user_to_sorted) or 1
    sorted_size = max(len(s) for s in sim.out_sorted) or 1
    user_scatter = np.full((m, u_cap), sorted_size, np.int32)  # drop bin
    for n in range(m):
        user_scatter[n, : len(sim.out_user_to_sorted[n])] = \
            sim.out_user_to_sorted[n]

    layers: List[_LayerMaps] = []
    for l in range(plan.depth):
        k = plan.degrees[l]
        # send pieces: node n -> digit t: slice cuts[t]:cuts[t+1] of cur
        send_rows, merge_rows = [], []
        cap = 0
        cuts_all = []
        for n in range(m):
            cuts = np.searchsorted(didx[l][n].astype(np.uint64),
                                   plan.edges_at(n, l).astype(np.uint64))
            cuts_all.append(cuts)
            cap = max(cap, int(np.max(cuts[1:] - cuts[:-1])))
        merged_size = max(len(didx[l + 1][n]) for n in range(m)) or 1
        send_gather = np.full((m, k, cap), -1, np.int32)
        merge_scatter = np.full((m, k, cap), merged_size, np.int32)
        for n in range(m):
            members = plan.group_members(n, l)
            t_self = members.index(n)
            cuts = cuts_all[n]
            for t in range(k):
                ln = cuts[t + 1] - cuts[t]
                send_gather[n, t, :ln] = np.arange(cuts[t], cuts[t + 1])
            # merge: received piece from member with digit t = that member's
            # slice at t_self; its position in my merged array = inv map
            src_slices, inv, uniq = sim.down_maps[l][n]
            for t in range(k):
                seg = inv[src_slices[t]:src_slices[t + 1]]
                merge_scatter[n, t, : len(seg)] = seg
        # up phase maps
        upcap = 0
        for n in range(m):
            for t in range(k):
                upcap = max(upcap, len(sim.ret_pos[l][n][t]))
        upcap = max(upcap, 1)
        up_size = max(len(sim.in_at[l][n]) for n in range(m)) or 1
        up_send_gather = np.full((m, k, upcap), -1, np.int32)
        up_recv_scatter = np.full((m, k, upcap), up_size, np.int32)
        for n in range(m):
            members = plan.group_members(n, l)
            digit_of = {mem: t for t, mem in enumerate(members)}
            t_self = digit_of[n]
            # as sender: to peer with digit t, send values for that peer's
            # request piece, positions in MY layer-(l+1) up array
            for t, mem in enumerate(members):
                pos = sim.ret_pos[l][mem][t_self]  # mem requested from me
                up_send_gather[n, t, : len(pos)] = pos
            # as receiver: piece from member with digit t lands at my cuts
            own_idx = sim.in_at[l][n]
            cuts = np.searchsorted(own_idx.astype(np.uint64),
                                   plan.edges_at(n, l).astype(np.uint64))
            for t in range(k):
                ln = cuts[t + 1] - cuts[t]
                up_recv_scatter[n, t, :ln] = np.arange(cuts[t], cuts[t + 1])
        layers.append(_LayerMaps(send_gather=send_gather,
                                 merge_scatter=merge_scatter,
                                 merged_size=merged_size,
                                 up_send_gather=up_send_gather,
                                 up_recv_scatter=up_recv_scatter,
                                 up_size=up_size))

    q_cap = max(len(p) for p in sim.bottom_pos) or 1
    bottom_gather = np.full((m, q_cap), -1, np.int32)
    bottom_hit = np.zeros((m, q_cap), bool)
    for n in range(m):
        bottom_gather[n, : len(sim.bottom_pos[n])] = sim.bottom_pos[n]
        bottom_hit[n, : len(sim.bottom_hit[n])] = sim.bottom_hit[n]

    uin_cap = max(len(u) for u in sim.in_sorted_to_user) or 1
    user_gather = np.full((m, uin_cap), -1, np.int32)
    for n in range(m):
        user_gather[n, : len(sim.in_sorted_to_user[n])] = \
            sim.in_sorted_to_user[n]

    # Normalize per-layer pad sizes: values arrays must have one static size
    # per layer across devices — we already took maxima; per-device shorter
    # content is padded with drop bins / -1.
    return PlannedSparseAllreduce(
        dplan=dplan, perm=perm, width=width,
        user_scatter=user_scatter, sorted_size=sorted_size, layers=layers,
        bottom_gather=bottom_gather, bottom_hit=bottom_hit,
        user_gather=user_gather, in_user_len=uin_cap, weights=weights)
