"""r-way replication for fault tolerance (paper §V).

Simulator path: fully faithful — replicated messages, first-alive-replica
selection, :class:`DeadLogicalNode` when a whole replica group is lost
(birthday bound ~sqrt(M) random failures for r=2).

Device path: SPMD collectives are deterministic, so *packet racing* (§V-B)
has no TPU analogue (documented in DESIGN.md §8).  What transfers is the
redundancy schedule: the physical data axis of size M_phys hosts
M_phys / r logical shards, each replicated r times; exactly one alive
replica per logical shard contributes its chunk (weight 1), the rest
contribute zeros.  Every device still receives the full union, so any
replica can stand in for a dead one — same completion guarantee as the
paper.  The device layout is a plain butterfly: physical degrees are
``(r,) + logical_degrees`` so stage 0's mixed-radix groups are exactly
:func:`replica_groups` and the replica merge is an ordinary layer
(``core.allreduce.make_device_plan(replication=r)``).  Cost and
completion-probability curves: ``benchmarks/bench_fault_tolerance.py``.

Failure-injection schedules (random / rack / rolling) shared by the tests,
the simulator, and the bench live in :mod:`repro.core.faults`.
"""
from __future__ import annotations

import math
from typing import List, Optional, Set

import numpy as np


class DeadLogicalNode(RuntimeError):
    """All replicas of a logical node are dead — protocol cannot complete
    (paper §V-A).  Raised identically by the simulator
    (``SimSparseAllreduce``) and the device backend
    (``contribution_weights`` at ``config``/``union_reduce`` time)."""


def replica_groups(m_physical: int, replication: int) -> List[List[int]]:
    """Logical shard i lives on physical nodes i, i+M, ..., i+(r-1)M."""
    if replication < 1:
        raise ValueError(f"replication must be >= 1, got {replication}")
    if m_physical % replication:
        raise ValueError(f"{m_physical} devices not divisible by r={replication}")
    m_logical = m_physical // replication
    return [[i + j * m_logical for j in range(replication)]
            for i in range(m_logical)]


def contribution_weights(m_physical: int, replication: int,
                         dead: Optional[Set[int]] = None) -> np.ndarray:
    """weight[d] = 1.0 iff d is the first alive replica of its logical shard.

    Raises :class:`DeadLogicalNode` if a whole replica group is dead (the
    protocol cannot complete — paper §V-A).  With ``replication=1`` every
    group is a single node, so any non-empty ``dead`` raises: no redundancy
    means no tolerated failures, matching the simulator.
    """
    dead = set(dead or ())
    bad = dead - set(range(m_physical))
    if bad:
        raise ValueError(
            f"dead ids {sorted(bad)} outside [0, {m_physical}) — failure "
            f"injection would silently be a no-op")
    w = np.zeros(m_physical, np.float32)
    for group in replica_groups(m_physical, replication):
        alive = [d for d in group if d not in dead]
        if not alive:
            raise DeadLogicalNode(
                f"replica group {group} entirely dead (r={replication})")
        w[alive[0]] = 1.0
    return w


def first_alive_replicas(m_physical: int, replication: int,
                         dead: Optional[Set[int]] = None) -> np.ndarray:
    """[m_logical] physical id of each logical shard's first alive replica
    (the replica whose :func:`contribution_weights` entry is 1)."""
    w = contribution_weights(m_physical, replication, dead)
    m_logical = m_physical // replication
    out = np.empty(m_logical, np.int64)
    for p in np.nonzero(w)[0]:
        out[p % m_logical] = p
    return out


def lost_logical_shards(m_physical: int, replication: int,
                        dead: Optional[Set[int]] = None) -> List[int]:
    """Logical shard ids whose replica group is *entirely* dead.

    The non-raising sibling of :func:`contribution_weights`: where that
    function raises :class:`DeadLogicalNode` at the first lost group, this
    enumerates them all so a supervisor (``repro.resilience``) can decide
    between absorb / replan / fail.  Out-of-range dead ids still raise
    ``ValueError`` — a typo'd failure injection must not read as healthy.
    """
    dead = set(dead or ())
    bad = dead - set(range(m_physical))
    if bad:
        raise ValueError(
            f"dead ids {sorted(bad)} outside [0, {m_physical}) — failure "
            f"injection would silently be a no-op")
    return [i for i, group in
            enumerate(replica_groups(m_physical, replication))
            if all(d in dead for d in group)]


def surviving_logical_shards(m_physical: int, replication: int,
                             dead: Optional[Set[int]] = None) -> List[int]:
    """Logical shard ids with at least one alive replica (complement of
    :func:`lost_logical_shards`, same validation)."""
    lost = set(lost_logical_shards(m_physical, replication, dead))
    return [i for i in range(m_physical // replication) if i not in lost]


def expected_tolerated_failures(m_logical: int, replication: int = 2) -> float:
    """Generalized birthday estimate of the expected number of random
    physical failures before some replica group is fully dead.

    Failures land in the M logical groups like balls in urns; a group dies
    at its r-th hit (sampling without replacement, r hits == all r replicas
    dead).  The Klamkin–Newman first-r-fold-collision asymptotic gives

        E[failures] ~ Gamma(1 + 1/r) * (r!)^(1/r) * M^(1 - 1/r)

    which at r=2 is exactly the paper's §V-A bound sqrt(pi*M/2), and at
    r=1 is 1 (the first failure is fatal without redundancy).
    """
    r = replication
    if r < 1:
        raise ValueError(f"replication must be >= 1, got {r}")
    return (math.gamma(1.0 + 1.0 / r) * math.factorial(r) ** (1.0 / r)
            * m_logical ** (1.0 - 1.0 / r))


def simulate_random_failures(m_logical: int, replication: int,
                             num_failures: int, trials: int = 1000,
                             seed: int = 0) -> float:
    """Empirical P[protocol completes] under ``num_failures`` random dead
    physical nodes (validates the sqrt(M) claim; see tests).

    Thin wrapper over :func:`repro.core.faults.completion_probability` with
    the ``"random"`` schedule; use that module directly for the correlated
    (rack) and rolling schedules swept by
    ``benchmarks/bench_fault_tolerance.py``.
    """
    from .faults import completion_probability
    return completion_probability(m_logical, replication, num_failures,
                                  trials=trials, kind="random", seed=seed)
