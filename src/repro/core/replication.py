"""r-way replication for fault tolerance (paper §V).

Simulator path: fully faithful — replicated messages, first-alive-replica
selection, DeadLogicalNode when a whole replica group is lost (birthday
bound ~sqrt(M) random failures for r=2).

Device path: SPMD collectives are deterministic, so *packet racing* (§V-B)
has no TPU analogue (documented in DESIGN.md §8).  What transfers is the
redundancy schedule: the physical data axis of size M_phys hosts
M_phys / r logical shards, each replicated r times; exactly one alive
replica per logical shard contributes its chunk (weight 1), the rest
contribute zeros.  Every device still receives the full union, so any
replica can stand in for a dead one — same completion guarantee as the
paper, costed in benchmarks/bench_fault_tolerance.py.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence, Set

import numpy as np

from .topology import ButterflyPlan


def replica_groups(m_physical: int, replication: int):
    """Logical shard i lives on physical nodes i, i+M, ..., i+(r-1)M."""
    if m_physical % replication:
        raise ValueError(f"{m_physical} devices not divisible by r={replication}")
    m_logical = m_physical // replication
    return [[i + j * m_logical for j in range(replication)]
            for i in range(m_logical)]


def contribution_weights(m_physical: int, replication: int,
                         dead: Optional[Set[int]] = None) -> np.ndarray:
    """weight[d] = 1.0 iff d is the first alive replica of its logical shard.

    Raises if a whole replica group is dead (protocol cannot complete —
    paper §V-A).
    """
    dead = set(dead or ())
    w = np.zeros(m_physical, np.float32)
    for group in replica_groups(m_physical, replication):
        alive = [d for d in group if d not in dead]
        if not alive:
            raise RuntimeError(f"replica group {group} entirely dead")
        w[alive[0]] = 1.0
    return w


def expected_tolerated_failures(m_logical: int, replication: int = 2) -> float:
    """Birthday-paradox estimate: ~sqrt(M) random failures before some
    replica pair collides (paper §V-A, r=2)."""
    if replication != 2:
        raise NotImplementedError("paper analyses r=2")
    return math.sqrt(math.pi * m_logical / 2)


def simulate_random_failures(m_logical: int, replication: int,
                             num_failures: int, trials: int = 1000,
                             seed: int = 0) -> float:
    """Empirical P[protocol completes] under ``num_failures`` random dead
    physical nodes (validates the sqrt(M) claim; see tests)."""
    rng = np.random.RandomState(seed)
    m_phys = m_logical * replication
    ok = 0
    for _ in range(trials):
        dead = set(rng.choice(m_phys, size=num_failures, replace=False).tolist())
        try:
            contribution_weights(m_phys, replication, dead)
            ok += 1
        except RuntimeError:
            pass
    return ok / trials
