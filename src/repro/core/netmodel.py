"""alpha-beta-floor(-gamma) network cost model.

The paper's central empirical fact (Fig 3): messages below an *effective
packet floor* (2-4 MB on 10 Gb/s EC2 with Java sockets) are latency-bound,
so per-node time grows with cluster size in a round-robin exchange.  The
model here is the classic alpha-beta model with an explicit floor plus a
per-fanout congestion term:

    t(msg bytes s, fanout f) = alpha + gamma * (f - 1) + max(s, floor) / beta

``gamma`` prices *concurrent-peer congestion*: when a node exchanges with
f peers in one butterfly stage, every message contends with the f-1 other
streams for the NIC / switch port (per-message CPU, queueing, incast).
It is what makes the degree-vs-depth tradeoff expressible — a single
degree-M round-robin stage pays O(M^2) congestion while a deep low-degree
butterfly pays almost none — and it is fit from measured stage timings by
``repro.core.autotune`` rather than guessed (the nominal fabrics below
ship with gamma = 0, preserving the paper's original alpha-beta-floor
numbers).

We parameterize it for three fabrics:

* EC2-2013 (paper's testbed): 10 Gb/s rated, ~2 Gb/s achieved via Java
  sockets, alpha ~ 1.6 ms => floor ~= alpha*beta ~= 0.4 MB effective; the
  paper reports 2-4 MB practical floor (extra per-message CPU cost), which
  we fold into alpha.
* TPU v5e ICI: ~50 GB/s/link, ~1 us per-hop latency => floor ~= 50 KB.
* DCN (pod-to-pod): ~25 GB/s/host aggregate, ~10 us.

All terms are per *message*; stage costs are computed by the topology
planner, which knows how many messages each node sends per stage.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class Fabric:
    """One interconnect's fitted (or nominal) cost-model parameters.

    Units and defaults:

    * ``beta_bytes_per_s`` — achieved point-to-point bandwidth per node
      (serial NIC) or per link (torus), in bytes/second.  *Achieved*, not
      rated: the paper's whole point is that the two differ by 5x.
    * ``alpha_s`` — per-message setup latency in seconds (socket/DMA setup,
      per-message CPU; the EC2 fabric folds the paper's packet-floor CPU
      cost in here).
    * ``floor_bytes`` — effective packet floor in bytes: payloads below it
      cost the same as ``floor_bytes`` (default 0 = pure alpha-beta).
      The floor applies to *on-wire* bytes — i.e. post-encoding sizes when
      a compressed wire format is in play — and it is applied exactly once,
      inside :meth:`msg_time`.  Callers (``topology.modeled_time``, the
      simulator's stage accounting, ``autotune.fit_fabric``) must pass
      un-floored encoded payload sizes and never pre-clamp.
    * ``gamma_s`` — congestion seconds added to *each* message per extra
      concurrent peer in the same stage (default 0 = classic model; fitted
      from measurement by ``repro.core.autotune.fit_fabric``).
    """
    name: str
    beta_bytes_per_s: float      # achieved bandwidth per node (or per link)
    alpha_s: float               # per-message setup latency
    floor_bytes: float = 0.0     # below this, transmission cost is flat
    gamma_s: float = 0.0         # per-message congestion per extra peer

    def msg_time(self, nbytes: float, fanout: int = 1) -> float:
        """Seconds to send one ``nbytes`` message while exchanging with
        ``fanout`` peers total (the fanout-1 others contribute congestion).

        ``nbytes`` is the *on-wire* (post-encoding) payload size; the
        packet floor is applied here, exactly once — callers must not
        clamp to ``floor_bytes`` themselves.
        """
        payload = max(float(nbytes), self.floor_bytes)
        congest = self.gamma_s * max(fanout - 1, 0)
        return self.alpha_s + congest + payload / self.beta_bytes_per_s

    def stage_time(self, nbytes_per_dest: float, fanout: int,
                   serial: bool = True) -> float:
        """Time for one node to exchange with ``fanout`` peers.

        serial=True models a single NIC (paper's EC2 nodes): messages
        serialize on the interface, so the stage costs ``fanout`` full
        message times (each inflated by the congestion term).
        serial=False models a torus with independent links per neighbour
        (TPU ICI) where transfers overlap and only the per-message alphas
        pipeline.
        """
        if fanout <= 0:
            return 0.0
        t_one = self.msg_time(nbytes_per_dest, fanout)
        if serial:
            return fanout * t_one
        return t_one + (fanout - 1) * self.alpha_s

    def stage_split(self, nbytes_per_dest: float, fanout: int,
                    serial: bool = True) -> tuple:
        """:meth:`stage_time` decomposed into ``(serial_s, bandwidth_s)``.

        ``serial_s`` is the per-message setup + congestion share that no
        scheduling trick removes; ``bandwidth_s`` is the wire-transmission
        share an overlapped schedule can hide behind independent compute
        (``ButterflyPlan.modeled_overlap_time``).  The two sum to
        :meth:`stage_time` exactly, so the overlap model degrades to the
        bulk-synchronous one when there is nothing to hide behind.
        """
        if fanout <= 0:
            return 0.0, 0.0
        payload = max(float(nbytes_per_dest), self.floor_bytes)
        per_msg_bw = payload / self.beta_bytes_per_s
        congest = self.gamma_s * max(fanout - 1, 0)
        if serial:
            return fanout * (self.alpha_s + congest), fanout * per_msg_bw
        return (self.alpha_s + congest + (fanout - 1) * self.alpha_s,
                per_msg_bw)

    def as_meta(self) -> dict:
        """JSON-able parameter dict (plan-cache / calibration persistence;
        inverse is :func:`repro.core.autotune.fabric_from_meta`)."""
        return {"name": self.name,
                "beta_bytes_per_s": self.beta_bytes_per_s,
                "alpha_s": self.alpha_s,
                "floor_bytes": self.floor_bytes,
                "gamma_s": self.gamma_s}


def rate_optimal_allreduce_s(nbytes: float, num_nodes: int,
                             fabric: Fabric) -> float:
    """Rate-optimal allreduce lower bound (seconds) for ``nbytes`` of
    payload per node over ``num_nodes`` nodes on ``fabric``.

    The bandwidth term is the classic ``2 (M-1)/M * N / beta`` bound every
    rate-optimal schedule attains asymptotically (*On the Computation Rate
    of All-Reduce*, PAPERS.md arXiv:2602.22482: each of N payload units
    must leave its source and reach every sink, and a node's NIC moves at
    most ``beta`` bytes/s); the latency term is the ``2 ceil(log2 M)``
    message-depth floor (reduce + broadcast trees cannot be shallower).
    No schedule — ours included — can beat this; dividing it by an
    achieved (modeled or measured) time gives the *rate fraction* the
    overlap benches report (``benchmarks/bench_overlap.py``).
    """
    m = max(int(num_nodes), 1)
    if m == 1:
        return 0.0
    bw = 2.0 * (m - 1) / m * float(nbytes) / fabric.beta_bytes_per_s
    lat = 2.0 * math.ceil(math.log2(m)) * fabric.alpha_s
    return lat + bw


def rate_fraction(achieved_s: float, nbytes: float, num_nodes: int,
                  fabric: Fabric) -> float:
    """``rate_optimal_allreduce_s / achieved_s`` — 1.0 means the achieved
    time meets the rate-optimal bound, smaller means headroom.  0.0 when
    ``achieved_s`` is non-positive (degenerate single-node case)."""
    if achieved_s <= 0.0:
        return 0.0
    return rate_optimal_allreduce_s(nbytes, num_nodes, fabric) / achieved_s


# Paper testbed: cc1.4xlarge, 10 Gb/s Ethernet, Java sockets achieve ~2 Gb/s
# (paper SVI-E).  alpha chosen so the effective floor (where latency ==
# transmission) sits at ~2 MB, matching the paper's reported 2-4 MB floor.
EC2_2013 = Fabric(name="ec2-2013", beta_bytes_per_s=2e9 / 8, alpha_s=8e-3,
                  floor_bytes=0.0)

# TPU v5e intra-pod ICI (per the brief: ~50 GB/s/link).
TPU_ICI = Fabric(name="tpu-v5e-ici", beta_bytes_per_s=50e9, alpha_s=1e-6,
                 floor_bytes=0.0)

# Cross-pod data-center network.
TPU_DCN = Fabric(name="tpu-dcn", beta_bytes_per_s=25e9, alpha_s=10e-6,
                 floor_bytes=0.0)

FABRICS = {f.name: f for f in (EC2_2013, TPU_ICI, TPU_DCN)}

# v5e chip constants used by the roofline module as well.
PEAK_FLOPS_BF16 = 197e12
HBM_BYTES_PER_S = 819e9
ICI_BYTES_PER_S = 50e9
