"""alpha-beta-floor network cost model.

The paper's central empirical fact (Fig 3): messages below an *effective
packet floor* (2-4 MB on 10 Gb/s EC2 with Java sockets) are latency-bound,
so per-node time grows with cluster size in a round-robin exchange.  The
model here is the classic alpha-beta model with an explicit floor:

    t(msg bytes s) = alpha + max(s, floor_bytes) / beta

We parameterize it for three fabrics:

* EC2-2013 (paper's testbed): 10 Gb/s rated, ~2 Gb/s achieved via Java
  sockets, alpha ~ 1.6 ms => floor ~= alpha*beta ~= 0.4 MB effective; the
  paper reports 2-4 MB practical floor (extra per-message CPU cost), which
  we fold into alpha.
* TPU v5e ICI: ~50 GB/s/link, ~1 us per-hop latency => floor ~= 50 KB.
* DCN (pod-to-pod): ~25 GB/s/host aggregate, ~10 us.

All terms are per *message*; stage costs are computed by the topology
planner, which knows how many messages each node sends per stage.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Fabric:
    name: str
    beta_bytes_per_s: float      # achieved bandwidth per node (or per link)
    alpha_s: float               # per-message setup latency
    floor_bytes: float = 0.0     # below this, transmission cost is flat

    def msg_time(self, nbytes: float) -> float:
        payload = max(float(nbytes), self.floor_bytes)
        return self.alpha_s + payload / self.beta_bytes_per_s

    def stage_time(self, nbytes_per_dest: float, fanout: int,
                   serial: bool = True) -> float:
        """Time for one node to exchange with ``fanout`` peers.

        serial=True models a single NIC (paper's EC2 nodes): messages
        serialize on the interface.  serial=False models a torus with
        independent links per neighbour (TPU ICI) where transfers overlap
        and only the per-message alphas pipeline.
        """
        if fanout <= 0:
            return 0.0
        t_one = self.msg_time(nbytes_per_dest)
        if serial:
            return fanout * t_one
        return t_one + (fanout - 1) * self.alpha_s


# Paper testbed: cc1.4xlarge, 10 Gb/s Ethernet, Java sockets achieve ~2 Gb/s
# (paper SVI-E).  alpha chosen so the effective floor (where latency ==
# transmission) sits at ~2 MB, matching the paper's reported 2-4 MB floor.
EC2_2013 = Fabric(name="ec2-2013", beta_bytes_per_s=2e9 / 8, alpha_s=8e-3,
                  floor_bytes=0.0)

# TPU v5e intra-pod ICI (per the brief: ~50 GB/s/link).
TPU_ICI = Fabric(name="tpu-v5e-ici", beta_bytes_per_s=50e9, alpha_s=1e-6,
                 floor_bytes=0.0)

# Cross-pod data-center network.
TPU_DCN = Fabric(name="tpu-dcn", beta_bytes_per_s=25e9, alpha_s=10e-6,
                 floor_bytes=0.0)

FABRICS = {f.name: f for f in (EC2_2013, TPU_ICI, TPU_DCN)}

# v5e chip constants used by the roofline module as well.
PEAK_FLOPS_BF16 = 197e12
HBM_BYTES_PER_S = 819e9
ICI_BYTES_PER_S = 50e9
