"""Sorted fixed-capacity sparse vectors + hash permutation.

The paper (Zhao & Canny §III-A) pre-randomizes vertex indices with a hash
permutation so that contiguous range-partitions are balanced, keeps indices
*sorted* thereafter, and computes sums by coherent merges of sorted streams
(~5x faster than hash tables on CPU; on TPU the analogue is one-hot-matmul
segment summation on the MXU — see kernels/segment_compact.py).

Two representations live here:

* host-side (numpy): variable-length sorted (idx, val) pairs used by the
  message-level simulator and by host-side ``config`` (index routing).
* device-side (jnp): fixed-capacity ``SparseChunk`` — ``idx: uint32[C]``
  (sorted, SENTINEL-padded at the tail) and ``val: f32[C]`` or ``f32[C, W]``.
  SPMD requires static shapes, so every stage has a capacity and overflow is
  counted (the same adaptation MoE dispatch makes on TPU).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Sentinel index: sorts after every real index (uint32 max).
SENTINEL = np.uint32(0xFFFFFFFF)
# Knuth multiplicative constant (odd => bijection on uint32).
_KNUTH = np.uint32(2654435761)


# ---------------------------------------------------------------------------
# Hash permutation (paper §III-A: "random hash to the vertex indices")
# ---------------------------------------------------------------------------

def _egcd_inv_u32(a: int) -> int:
    """Modular inverse of odd ``a`` modulo 2**32 (Newton iteration)."""
    assert a % 2 == 1
    x = a  # a^{-1} mod 2^4
    for _ in range(5):  # doubles correct bits each step: 4->8->16->32->64
        x = (x * (2 - a * x)) % (1 << 64)
    return x % (1 << 32)


@dataclasses.dataclass(frozen=True)
class HashPerm:
    """Bijective affine-xor permutation of the uint32 index space.

    ``fwd(i) = ((i ^ s) * m) mod 2^32`` with odd multiplier ``m`` — a
    bijection on [0, 2^32).  Real indices in [0, R) hash into the full
    uint32 space; butterfly stages partition the *hashed* space into
    contiguous ranges, which the multiplicative mix makes balanced.
    """

    mult: int
    xor: int

    @staticmethod
    def make(seed: int) -> "HashPerm":
        """Seeded random permutation (odd multiplier mixed with Knuth's)."""
        rng = np.random.RandomState(seed)
        m = int(rng.randint(0, 1 << 31)) * 2 + 1  # odd
        m = (m * int(_KNUTH)) % (1 << 32)
        if m % 2 == 0:  # paranoia; product of odds is odd
            m += 1
        s = int(rng.randint(0, 1 << 31))
        return HashPerm(mult=m, xor=s)

    # -- numpy ---------------------------------------------------------------
    def fwd_np(self, idx: np.ndarray) -> np.ndarray:
        """Hash uint32 indices into the permuted space (host numpy)."""
        i = idx.astype(np.uint64)
        out = ((i ^ np.uint64(self.xor)) * np.uint64(self.mult)) % (1 << 32)
        return out.astype(np.uint32)

    def inv_np(self, h: np.ndarray) -> np.ndarray:
        """Invert :meth:`fwd_np` (host numpy)."""
        minv = np.uint64(_egcd_inv_u32(self.mult))
        i = (h.astype(np.uint64) * minv) % (1 << 32)
        return (i.astype(np.uint32) ^ np.uint32(self.xor))

    # -- jax -----------------------------------------------------------------
    def fwd(self, idx: jax.Array) -> jax.Array:
        """Hash uint32 indices into the permuted space (traced)."""
        i = idx.astype(jnp.uint32)
        return (i ^ jnp.uint32(self.xor)) * jnp.uint32(self.mult)

    def inv(self, h: jax.Array) -> jax.Array:
        """Invert :meth:`fwd` (traced)."""
        minv = jnp.uint32(_egcd_inv_u32(self.mult))
        return (h.astype(jnp.uint32) * minv) ^ jnp.uint32(self.xor)


IDENTITY_PERM = HashPerm(mult=1, xor=0)


# ---------------------------------------------------------------------------
# Host-side variable-length sorted sparse vectors (simulator / config)
# ---------------------------------------------------------------------------

def sort_coalesce_np(idx: np.ndarray, val: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Sort by index and sum duplicates.  val: [N] or [N, W]."""
    if idx.size == 0:
        return idx.astype(np.uint32), val
    order = np.argsort(idx, kind="stable")
    idx = idx[order]
    val = val[order]
    uniq, inv = np.unique(idx, return_inverse=True)
    if val.ndim == 1:
        summed = np.zeros(uniq.shape[0], dtype=val.dtype)
        np.add.at(summed, inv, val)
    else:
        summed = np.zeros((uniq.shape[0],) + val.shape[1:], dtype=val.dtype)
        np.add.at(summed, inv, val)
    return uniq.astype(np.uint32), summed


def merge_add_np(a_idx, a_val, b_idx, b_val):
    """Merge two sorted sparse vectors, summing index collisions."""
    return sort_coalesce_np(np.concatenate([a_idx, b_idx]),
                            np.concatenate([a_val, b_val], axis=0))


def tree_sum_np(parts):
    """Paper §III-A tree summation: pairwise merge up to a root.

    ``parts``: list of (idx, val) sorted sparse vectors.  O(N log k) with
    collision compression (practically O(N) for power-law data).
    """
    parts = list(parts)
    if not parts:
        raise ValueError("tree_sum of zero parts")
    while len(parts) > 1:
        nxt = []
        for i in range(0, len(parts) - 1, 2):
            nxt.append(merge_add_np(*parts[i], *parts[i + 1]))
        if len(parts) % 2 == 1:
            nxt.append(parts[-1])
        parts = nxt
    return parts[0]


# ---------------------------------------------------------------------------
# Device-side fixed-capacity chunks
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SparseChunk:
    """Fixed-capacity sorted sparse vector.

    idx: uint32[C]   sorted ascending, SENTINEL padding at the tail
    val: f32[C] or f32[C, W]   rows beyond the valid prefix are zero
    """

    idx: jax.Array
    val: jax.Array

    # pytree plumbing ---------------------------------------------------------
    def tree_flatten(self):
        """jax pytree protocol: (children, aux) = ((idx, val), None)."""
        return (self.idx, self.val), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        """jax pytree protocol inverse of :meth:`tree_flatten`."""
        return cls(*children)

    # ------------------------------------------------------------------------
    @property
    def capacity(self) -> int:
        """Static slot count C (valid entries + SENTINEL padding)."""
        return self.idx.shape[0]

    @property
    def width(self) -> int:
        """Trailing value width W (1 for scalar-per-index chunks)."""
        return 1 if self.val.ndim == 1 else self.val.shape[1]

    def valid_mask(self) -> jax.Array:
        """bool[C]: True on non-padding slots."""
        return self.idx != jnp.uint32(SENTINEL)

    def count(self) -> jax.Array:
        """Number of valid (non-SENTINEL) entries, as a traced scalar."""
        return jnp.sum(self.valid_mask().astype(jnp.int32))

    @staticmethod
    def from_dense(dense: jax.Array, capacity: int) -> "SparseChunk":
        """Top-|capacity| nonzeros of a dense [R] or [R, W] array (tests)."""
        score = jnp.abs(dense) if dense.ndim == 1 else jnp.sum(jnp.abs(dense), axis=-1)
        nz = score > 0
        # Order: valid first (by index), then padding.
        key = jnp.where(nz, jnp.arange(score.shape[0], dtype=jnp.uint32),
                        jnp.uint32(SENTINEL))
        order = jnp.argsort(key)[:capacity]
        idx = key[order]
        val = dense[order]
        val = jnp.where((idx != jnp.uint32(SENTINEL))[(...,) + (None,) * (dense.ndim - 1)],
                        val, jnp.zeros_like(val))
        return SparseChunk(idx=idx, val=val)

    def to_dense(self, size: int) -> jax.Array:
        """Scatter-add the valid entries into a dense [size(,W)] array."""
        shape = (size,) if self.val.ndim == 1 else (size, self.val.shape[1])
        out = jnp.zeros(shape, self.val.dtype)
        safe = jnp.where(self.valid_mask(), self.idx, 0).astype(jnp.int32)
        contrib = jnp.where(self.valid_mask()[(...,) + (None,) * (self.val.ndim - 1)],
                            self.val, jnp.zeros_like(self.val))
        return out.at[safe].add(contrib)


def _mask_val(mask: jax.Array, val: jax.Array) -> jax.Array:
    return jnp.where(mask[(...,) + (None,) * (val.ndim - 1)], val, jnp.zeros_like(val))


def sort_chunk(idx: jax.Array, val: jax.Array) -> SparseChunk:
    """Sort (idx, val) rows ascending by idx (sentinels sink to tail)."""
    order = jnp.argsort(idx)
    return SparseChunk(idx=idx[order], val=val[order])


def segment_compact(chunk: SparseChunk, out_capacity: Optional[int] = None,
                    use_kernel: bool = False) -> SparseChunk:
    """Coalesce duplicate indices of a *sorted* chunk; pad to out_capacity.

    Pure-jnp path (the Pallas MXU kernel lives in kernels/segment_compact.py;
    ``use_kernel`` switches to it).
    """
    if use_kernel:
        from repro.kernels import ops as _kops
        return _kops.segment_compact(chunk, out_capacity)
    idx, val = chunk.idx, chunk.val
    c = idx.shape[0]
    out_capacity = out_capacity or c
    valid = idx != jnp.uint32(SENTINEL)
    is_head = jnp.concatenate([jnp.ones((1,), bool), idx[1:] != idx[:-1]]) & valid
    # Destination row for every input row = (# heads at or before it) - 1.
    pos = jnp.cumsum(is_head.astype(jnp.int32)) - 1
    pos = jnp.where(valid, pos, out_capacity)  # park invalid rows out of range
    out_idx = jnp.full((out_capacity,), SENTINEL, jnp.uint32)
    out_idx = out_idx.at[jnp.where(is_head, pos, out_capacity)].set(
        idx, mode="drop")
    vshape = (out_capacity,) if val.ndim == 1 else (out_capacity, val.shape[1])
    out_val = jnp.zeros(vshape, val.dtype)
    out_val = out_val.at[pos].add(_mask_val(valid, val), mode="drop")
    return SparseChunk(idx=out_idx, val=out_val)


def compact_overflow(chunk: SparseChunk, out_capacity: int) -> jax.Array:
    """Number of unique indices that do not fit in out_capacity (dropped)."""
    idx, c = chunk.idx, chunk.idx.shape[0]
    valid = idx != jnp.uint32(SENTINEL)
    is_head = jnp.concatenate([jnp.ones((1,), bool), idx[1:] != idx[:-1]]) & valid
    n_unique = jnp.sum(is_head.astype(jnp.int32))
    return jnp.maximum(n_unique - out_capacity, 0)


def merge_add(a: SparseChunk, b: SparseChunk, out_capacity: Optional[int] = None,
              use_kernel: bool = False) -> SparseChunk:
    """Merge-add two sorted chunks (paper's pairwise tree-merge step)."""
    if use_kernel:
        from repro.kernels import ops as _kops
        return _kops.merge_add(a, b, out_capacity)
    cat = SparseChunk(idx=jnp.concatenate([a.idx, b.idx]),
                      val=jnp.concatenate([a.val, b.val], axis=0))
    out_capacity = out_capacity or (a.capacity + b.capacity)
    return segment_compact(sort_chunk(cat.idx, cat.val), out_capacity)


def tree_sum(chunks, out_capacity: Optional[int] = None) -> SparseChunk:
    """Tree-sum a list of sorted chunks (device-side, static shapes)."""
    chunks = list(chunks)
    while len(chunks) > 1:
        nxt = []
        for i in range(0, len(chunks) - 1, 2):
            nxt.append(merge_add(chunks[i], chunks[i + 1]))
        if len(chunks) % 2 == 1:
            nxt.append(chunks[-1])
        chunks = nxt
    out = chunks[0]
    if out_capacity is not None and out_capacity != out.capacity:
        out = segment_compact(out, out_capacity)  # also trims/pads
    return out


def bucket_partition(chunk: SparseChunk, edges: jax.Array, k: int,
                     bucket_capacity: int) -> Tuple[SparseChunk, jax.Array]:
    """Split a sorted chunk into k range-buckets of fixed capacity.

    ``edges``: uint32[k+1] range boundaries over the hashed index space
    (edges[0]=0 implied position via searchsorted; pass k+1 monotone edges).
    Returns (buckets with idx [k, cap] / val [k, cap, ...], overflow count).

    Sorted input => each bucket is a contiguous slab; entry j of bucket b
    sits at offset j - start_b.  One scatter builds all buckets.
    """
    idx, val = chunk.idx, chunk.val
    c = idx.shape[0]
    valid = idx != jnp.uint32(SENTINEL)
    # searchsorted over uint32: compare as int64-safe by going via int64? On
    # device use uint32-compatible trick: shift to int32 order-preserving.
    bias = jnp.int32(-2147483648)
    idx_s = (idx.astype(jnp.int32) + bias)
    edges_s = (edges.astype(jnp.int32) + bias)
    start = jnp.searchsorted(idx_s, edges_s[:-1], side="left")   # [k]
    bucket = jnp.clip(jnp.searchsorted(edges_s[1:], idx_s, side="right"),
                      0, k - 1)                                   # [c]
    offset = jnp.arange(c, dtype=jnp.int32) - start[bucket]
    ok = valid & (offset < bucket_capacity)
    overflow = jnp.sum((valid & ~ok).astype(jnp.int32))
    dest = jnp.where(ok, bucket * bucket_capacity + offset, k * bucket_capacity)
    out_idx = jnp.full((k * bucket_capacity,), SENTINEL, jnp.uint32)
    out_idx = out_idx.at[dest].set(idx, mode="drop")
    vshape = (k * bucket_capacity,) + val.shape[1:]
    out_val = jnp.zeros(vshape, val.dtype)
    out_val = out_val.at[dest].set(_mask_val(ok, val), mode="drop")
    return (SparseChunk(idx=out_idx.reshape((k, bucket_capacity)),
                        val=out_val.reshape((k, bucket_capacity) + val.shape[1:])),
            overflow)


def concat_sorted_groups(idx: jax.Array, val: jax.Array) -> SparseChunk:
    """Flatten [k, cap(, W)] group buckets into one sorted chunk [k*cap]."""
    k, cap = idx.shape[0], idx.shape[1]
    flat_idx = idx.reshape((k * cap,))
    flat_val = val.reshape((k * cap,) + val.shape[2:])
    return sort_chunk(flat_idx, flat_val)


def lookup(chunk: SparseChunk, query_idx: jax.Array) -> jax.Array:
    """Gather values of ``query_idx`` from a sorted chunk (0 if missing)."""
    bias = jnp.int32(-2147483648)
    pos = jnp.searchsorted(chunk.idx.astype(jnp.int32) + bias,
                           query_idx.astype(jnp.int32) + bias, side="left")
    pos = jnp.clip(pos, 0, chunk.capacity - 1)
    hit = chunk.idx[pos] == query_idx
    vals = chunk.val[pos]
    return _mask_val(hit, vals)
