"""Heterogeneous-degree nested butterfly topology (paper §II-A.3, §IV-B).

A plan over M nodes is an ordered degree sequence ``[k_1, ..., k_D]`` with
``prod(k) == M``.  Node ids are mixed-radix numbers with digit 1 most
significant; the layer-l group of a node is the set of k_l nodes that differ
from it only in digit l.  The hashed index space [0, 2^32) is recursively
range-partitioned: at layer l each group splits its current range into k_l
contiguous sub-ranges, one per digit value — so after D layers node n owns
exactly the [n/M, (n+1)/M) slice of the hashed space.

Degenerate corners of the family (paper §II):
  * ``[M]``      -> round-robin (single all-to-all stage)
  * ``[2]*log M`` -> binary butterfly
  * anything else -> the paper's hybrid.

The planner also carries the paper's packet-size/compression model (Fig 5)
and an alpha-beta-floor cost estimate used by the tuner (Fig 6).
"""
from __future__ import annotations

import dataclasses
import math
import warnings
from functools import lru_cache
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .netmodel import EC2_2013, Fabric

SPACE = 1 << 32  # hashed index space size

# Union-path wire formats (device codecs in repro.kernels.wirecodec; here
# they only change the modeled bytes-per-entry).  "raw" ships uint32 index
# + fp32 value (4+4 B/entry); the "delta" family bit-packs indices as
# offsets from the stage subrange base (width shrinks with depth) and
# optionally narrows values to bf16 or per-row-scaled int8.
WIRE_MODES = ("raw", "delta", "delta+bf16", "delta+int8ef")

_WIRE_VALUE_BYTES = {"raw": 4.0, "delta": 4.0, "delta+bf16": 2.0,
                     "delta+int8ef": 1.0}


def check_wire(wire: str) -> str:
    """Validate a wire-format name; returns it for chaining."""
    if wire not in WIRE_MODES:
        raise ValueError(f"wire must be one of {WIRE_MODES}, got {wire!r}")
    return wire


def wire_entry_bytes(wire: str, index_bits: int = 32,
                     width: int = 1) -> float:
    """Modeled on-wire bytes per sparse entry under ``wire``.

    ``index_bits`` is the packed offset width at the stage in question
    (32 for "raw", which always ships whole uint32 words); ``width`` is
    the value vector width.  The int8ef per-row scale word is amortized
    across the row and priced separately by ``modeled_time``.
    """
    check_wire(wire)
    if wire == "raw":
        index_bits = 32
    return index_bits / 8.0 + _WIRE_VALUE_BYTES[wire] * width


def _check_degrees(num_nodes: int, degrees: Sequence[int]) -> None:
    if math.prod(degrees) != num_nodes:
        raise ValueError(f"prod({list(degrees)}) != {num_nodes}")
    if any(k < 2 for k in degrees) and list(degrees) != [1]:
        raise ValueError(f"degrees must be >= 2, got {list(degrees)}")


@dataclasses.dataclass(frozen=True)
class ButterflyPlan:
    """Mixed-radix nested butterfly over ``num_nodes`` nodes."""

    num_nodes: int
    degrees: Tuple[int, ...]

    def __post_init__(self):
        if self.num_nodes == 1:
            object.__setattr__(self, "degrees", tuple())
            return
        _check_degrees(self.num_nodes, self.degrees)

    # -- mixed-radix structure -------------------------------------------------
    @property
    def depth(self) -> int:
        """Number of butterfly layers D (= len(degrees))."""
        return len(self.degrees)

    def strides(self) -> List[int]:
        """stride[l] = prod of degrees *below* layer l (digit 1 most significant)."""
        out, s = [], 1
        for k in reversed(self.degrees):
            out.append(s)
            s *= k
        return list(reversed(out))

    def digits(self, node: int) -> List[int]:
        """Mixed-radix digits of ``node``, one per layer (digit 1 first)."""
        out = []
        for k, s in zip(self.degrees, self.strides()):
            out.append((node // s) % k)
        return out

    def group_members(self, node: int, layer: int) -> List[int]:
        """The k_l nodes (incl. ``node``) differing only in digit ``layer``."""
        k, s = self.degrees[layer], self.strides()[layer]
        base = node - ((node // s) % k) * s
        return [base + t * s for t in range(k)]

    def axis_index_groups(self, layer: int) -> List[List[int]]:
        """Partition of [0, M) into layer-l groups (for jax collectives)."""
        seen, groups = set(), []
        for n in range(self.num_nodes):
            if n in seen:
                continue
            g = self.group_members(n, layer)
            groups.append(g)
            seen.update(g)
        return groups

    # -- range partition ---------------------------------------------------------
    def range_at(self, node: int, layer: int) -> Tuple[int, int]:
        """Hashed-space range owned by ``node`` *after* ``layer`` layers.

        layer=0 -> full space; layer=D -> the node's final 1/M slice.
        """
        lo, hi = 0, SPACE
        digs = self.digits(node)
        for l in range(layer):
            k = self.degrees[l]
            span = (hi - lo) // k
            new_lo = lo + digs[l] * span
            # last sub-range absorbs the division remainder so the ranges
            # tile exactly (matches edges_at, which pins e[-1] to hi)
            hi = new_lo + span if digs[l] < k - 1 else hi
            lo = new_lo
        return lo, hi

    def edges_at(self, node: int, layer: int) -> np.ndarray:
        """uint-64 range boundaries [k_l + 1] splitting node's layer-l range."""
        lo, hi = self.range_at(node, layer)
        k = self.degrees[layer]
        span = (hi - lo) // k
        e = lo + span * np.arange(k + 1, dtype=np.int64)
        e[-1] = hi
        return e

    def all_edges(self, layer: int) -> np.ndarray:
        """[M, k_l + 1] per-node range edges at ``layer`` (device backend)."""
        return np.stack([self.edges_at(n, layer) for n in range(self.num_nodes)])

    # -- packet-size / compression model (Fig 5) ---------------------------------
    def expected_counts(self, n0: float, total_range: float) -> List[float]:
        """E[#unique indices] held per node after each layer.

        n0 uniform-hashed indices per node over ``total_range`` ids.  Union of
        k Bernoulli(p) subsets has density 1-(1-p)^k.
        """
        counts = [float(n0)]
        r = float(total_range)
        for k in self.degrees:
            p = min(counts[-1] / r, 1.0)
            r_next = r / k
            counts.append(r_next * (1.0 - (1.0 - p) ** k))
            r = r_next
        return counts

    def index_bits_per_layer(self) -> List[int]:
        """Modeled packed-offset width (bits) of the delta wire codec at
        each layer: ``ceil(log2(span + 1))`` for the layer-l subrange span
        ``SPACE / prod(k_1..k_l)``.  Matches the codec's edge-derived
        widths exactly for power-of-2 meshes (remainder-free splits); off
        by at most one bit otherwise.
        """
        bits, r = [], float(SPACE)
        for k in self.degrees:
            r = r / k
            bits.append(max(1, min(32, int(math.ceil(math.log2(r + 1.0))))))
        return bits

    def _layer_entry_bytes(self, bytes_per_entry: float, wire: str,
                           value_width: int) -> List[float]:
        """Per-layer bytes/entry: the caller's raw ``bytes_per_entry``
        scaled by the wire format's compression ratio at that layer."""
        if wire == "raw":
            return [bytes_per_entry] * self.depth
        raw = wire_entry_bytes("raw", 32, value_width)
        return [bytes_per_entry * wire_entry_bytes(wire, b, value_width) / raw
                for b in self.index_bits_per_layer()]

    def packet_bytes(self, n0: float, total_range: float,
                     bytes_per_entry: float = 12.0,
                     wire: str = "raw", value_width: int = 1) -> List[float]:
        """Modeled per-destination message size at each down layer (Fig 5),
        post-encoding when ``wire`` != "raw"."""
        check_wire(wire)
        counts = self.expected_counts(n0, total_range)
        bpe = self._layer_entry_bytes(bytes_per_entry, wire, value_width)
        return [counts[l] / self.degrees[l] * bpe[l]
                for l in range(self.depth)]

    # -- cost model (Fig 6) --------------------------------------------------------
    def modeled_time(self, n0: float, total_range: float,
                     fabric: Fabric = EC2_2013, bytes_per_entry: float = 12.0,
                     merge_ns_per_entry: float = 4.0,
                     serial_nic: bool = True, wire: str = "raw",
                     value_width: int = 1) -> float:
        """End-to-end modeled config+reduce time (s) for one allreduce.

        ``wire`` prices the *encoded* payload (delta index packing shrinks
        the per-entry bytes layer by layer; lossy value modes narrow the
        value stream; int8ef adds one scale word per message).  Stage
        times — and thus the fabric's packet floor — are computed from the
        post-encoding sizes, so compression can push a message under the
        floor and stop paying bandwidth for it.  ``wire="raw"`` reproduces
        the original model exactly.
        """
        check_wire(wire)
        counts = self.expected_counts(n0, total_range)
        bpe = self._layer_entry_bytes(bytes_per_entry, wire, value_width)
        scale_overhead = 4.0 if wire == "delta+int8ef" else 0.0
        t = 0.0
        for l, k in enumerate(self.degrees):
            down_bytes = counts[l] / k * bpe[l] + scale_overhead
            t += fabric.stage_time(down_bytes, k - 1, serial=serial_nic)
            # received k-1 buckets + own; merge cost ~ entries * log2(k)
            t += counts[l] * max(math.log2(k), 1.0) * merge_ns_per_entry * 1e-9
        for l in reversed(range(self.depth)):
            k = self.degrees[l]
            # Each node returns to each peer only the piece that peer asked
            # for (~ what the peer sent down): counts[l]/k entries, values only.
            up_bytes = counts[l] / k * bpe[l] + scale_overhead
            t += fabric.stage_time(up_bytes, k - 1, serial=serial_nic)
        return t

    def modeled_overlap_time(self, n0: float, total_range: float,
                             fabric: Fabric = EC2_2013,
                             bytes_per_entry: float = 12.0,
                             merge_ns_per_entry: float = 4.0,
                             serial_nic: bool = True, wire: str = "raw",
                             value_width: int = 1,
                             hidden_compute_s: float = 0.0) -> float:
        """Modeled makespan (s) of one allreduce *plus* ``hidden_compute_s``
        of independent compute under an overlapped schedule.

        The overlapped schedules (the bucketed stage-major gradient sync of
        ``repro.train.step`` and the graph engine's rotated scan,
        ARCHITECTURE.md "Overlap & scheduling") issue each stage's payload
        transmission early and consume it late, so the *bandwidth* share of
        every stage can proceed concurrently with compute that does not
        depend on it.  Per-message setup + congestion and the local merges
        stay serial (``Fabric.stage_split``):

            t = serial_total + max(bandwidth_total, hidden_compute_s)

        With ``hidden_compute_s=0`` this equals :meth:`modeled_time`
        exactly (the splits are exact decompositions), so the synchronous
        comparator is ``modeled_time(...) + hidden_compute_s`` and the
        modeled overlap win is their difference.  ``select_plan`` reranks
        degree sequences under this term via ``overlap_compute_s``
        (``repro.core.autotune``; TUNING.md) — once bandwidth hides, the
        residual serial-NIC cost is per-message setup, ``sum(k_l - 1)``
        messages per node, so the optimum shifts toward deeper,
        lower-degree factorizations (binary in the limit) — the opposite
        of the bandwidth-bound direction (benchmarks/bench_overlap.py
        ``model_rerank`` rows chart the shift).
        """
        check_wire(wire)
        counts = self.expected_counts(n0, total_range)
        bpe = self._layer_entry_bytes(bytes_per_entry, wire, value_width)
        scale_overhead = 4.0 if wire == "delta+int8ef" else 0.0
        serial_t = 0.0
        bw_t = 0.0
        for l, k in enumerate(self.degrees):
            down_bytes = counts[l] / k * bpe[l] + scale_overhead
            lat, bw = fabric.stage_split(down_bytes, k - 1, serial=serial_nic)
            serial_t += lat + counts[l] * max(math.log2(k), 1.0) \
                * merge_ns_per_entry * 1e-9
            bw_t += bw
        for l in reversed(range(self.depth)):
            k = self.degrees[l]
            up_bytes = counts[l] / k * bpe[l] + scale_overhead
            lat, bw = fabric.stage_split(up_bytes, k - 1, serial=serial_nic)
            serial_t += lat
            bw_t += bw
        return serial_t + max(bw_t, float(hidden_compute_s))

    def __str__(self):
        return "x".join(str(k) for k in self.degrees) or "1"


# ---------------------------------------------------------------------------
# Degree-sequence enumeration + tuner (paper Fig 6: optimum 16x4 at M=64)
# ---------------------------------------------------------------------------

@lru_cache(maxsize=None)
def ordered_factorizations(m: int, max_depth: int = 6) -> Tuple[Tuple[int, ...], ...]:
    """All ordered factorizations of m into factors >= 2, depth-limited.

    ``max_depth`` caps the sequence length to bound the sweep (the count of
    ordered factorizations grows super-polynomially).  The cap silently
    *excludes* factorizations needing more than ``max_depth`` factors —
    e.g. the full binary butterfly of ``m = 2**7`` at the default cap of 6.
    :func:`tune` detects that case (``Omega(m) > max_depth``, with Omega
    the number of prime factors counted with multiplicity) and re-runs the
    sweep with the cap lifted to ``Omega(m)`` so no shape is lost.
    """
    if m == 1:
        return ((),)
    out = []

    def rec(rem: int, prefix: Tuple[int, ...]):
        if rem == 1 and prefix:
            out.append(prefix)
            return
        if len(prefix) >= max_depth:
            return
        for k in range(2, rem + 1):
            if rem % k == 0:
                rec(rem // k, prefix + (k,))

    rec(m, ())
    return tuple(out)


def num_prime_factors(m: int) -> int:
    """Omega(m): prime factors counted with multiplicity (= the deepest
    possible butterfly over m nodes; 0 for m = 1)."""
    count, d = 0, 2
    while d * d <= m:
        while m % d == 0:
            m //= d
            count += 1
        d += 1
    return count + (1 if m > 1 else 0)


def tune(num_nodes: int, n0: float, total_range: float,
         fabric: Fabric = EC2_2013, bytes_per_entry: float = 12.0,
         serial_nic: bool = True, top: int = 0, max_depth: int = 6,
         wire: str = "raw", value_width: int = 1,
         hidden_compute_s: float = 0.0):
    """Rank all degree sequences by modeled time; return best (or top-n list).

    Model assumptions (documented, not measured — for a *calibrated* sweep
    use :mod:`repro.core.autotune`, which fits ``fabric`` from on-device
    stage timings and adds cache persistence):

    * payload compression follows :meth:`ButterflyPlan.expected_counts` —
      i.e. per-node indices are uniform-hashed samples, the Bernoulli-union
      curve the paper derives for power-law data after hashing (§III-A);
    * stage cost is ``fabric.stage_time`` (alpha-beta-floor + gamma
      congestion) with ``serial_nic`` picking NIC serialization vs
      per-link overlap, and the local k-way merge costs
      ``entries * log2(k)`` at a fixed ns/entry;
    * with ``hidden_compute_s=0`` (default) stages are bulk-synchronous:
      no cross-stage overlap (paper Fig 7's threading gains are *not*
      modeled); ``hidden_compute_s > 0`` scores candidates with
      :meth:`ButterflyPlan.modeled_overlap_time` instead — the bandwidth
      share of every stage is hidden behind that much independent compute,
      which is how the overlapped schedules re-rank degrees
      (``select_plan(overlap_compute_s=...)`` in ``repro.core.autotune``).

    Degenerate sweeps degrade gracefully instead of silently returning the
    flat plan: if ``num_nodes`` is prime (or 1) the round-robin plan
    ``(num_nodes,)`` is the *only* factorization, and a ``UserWarning``
    says so; if ``max_depth`` would truncate the sweep (``Omega(num_nodes)
    > max_depth``) the cap is lifted to ``Omega`` with a ``UserWarning``
    so deep low-degree plans still compete.
    """
    omega = num_prime_factors(num_nodes)
    if omega > max_depth:
        warnings.warn(
            f"tune(num_nodes={num_nodes}): max_depth={max_depth} would "
            f"truncate the factorization sweep (deepest butterfly needs "
            f"{omega} layers); lifting the cap to {omega}", UserWarning,
            stacklevel=2)
        max_depth = omega
    facs = ordered_factorizations(num_nodes, max_depth)
    if num_nodes > 1 and len(facs) == 1:
        warnings.warn(
            f"tune(num_nodes={num_nodes}): prime node count has no "
            f"nontrivial factorization — falling back to the flat "
            f"round-robin plan ({num_nodes},)", UserWarning, stacklevel=2)
    check_wire(wire)
    scored = []
    for degs in facs:
        plan = ButterflyPlan(num_nodes, degs)
        if hidden_compute_s > 0.0:
            t = plan.modeled_overlap_time(
                n0, total_range, fabric, bytes_per_entry,
                serial_nic=serial_nic, wire=wire, value_width=value_width,
                hidden_compute_s=hidden_compute_s)
        else:
            t = plan.modeled_time(n0, total_range, fabric, bytes_per_entry,
                                  serial_nic=serial_nic, wire=wire,
                                  value_width=value_width)
        scored.append((t, plan))
    scored.sort(key=lambda x: x[0])
    if top:
        return scored[:top]
    return scored[0][1]


def roundrobin_plan(num_nodes: int) -> ButterflyPlan:
    """The degree-M single-stage plan (paper §II's round-robin corner)."""
    return ButterflyPlan(num_nodes, (num_nodes,)) if num_nodes > 1 else ButterflyPlan(1, ())


def binary_plan(num_nodes: int) -> ButterflyPlan:
    """The degree-2 full-depth plan (paper §II's binary-butterfly corner);
    requires a power-of-2 node count."""
    d = int(math.log2(num_nodes))
    if 2 ** d != num_nodes:
        raise ValueError(f"binary butterfly needs power-of-2 nodes, got {num_nodes}")
    return ButterflyPlan(num_nodes, (2,) * d)
