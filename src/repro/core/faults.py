"""Deterministic failure-injection schedules (paper §V test harness).

One :class:`FailureSchedule` is a seeded, replayable sequence of dead
*physical* node sets, shared by three consumers so the simulator, the
device backend, and the benchmarks all see byte-identical failures:

  * tests — ``tests/test_fault_tolerance.py`` drives the device-vs-sim
    parity sweep and the birthday-bound regression from schedules;
  * the simulator — ``SimSparseAllreduce(dead=schedule.dead_at(t))`` and
    :func:`repro.core.replication.simulate_random_failures` (which wraps
    :func:`completion_probability` below);
  * ``benchmarks/bench_fault_tolerance.py`` — completion-probability
    curves r∈{1,2,3} against the §V-A generalized birthday bound, plus
    the r× message-cost overhead.

Four kinds:

  * ``"random"``  — ``num_failures`` nodes drawn uniformly without
    replacement, fresh per step (the paper's §V-A failure model);
  * ``"rack"``    — correlated failures: whole racks of ``rack_size``
    consecutive physical ids die together (replica groups stride the id
    space by M, so rack-local blast radii rarely kill a group — the
    reason the mixed-radix replica layout places replicas far apart);
  * ``"rolling"`` — a contiguous window of ``num_failures`` ids sliding
    deterministically with the step (rolling maintenance / upgrades);
  * ``"cascade"`` — monotonically accumulating failures that never heal:
    ``num_failures`` *new* nodes die each step, drawn from a single
    seeded permutation, so ``dead_at(t)`` ⊇ ``dead_at(t-1)`` always.
    The realistic soak-test model (churn without repair) driven by
    ``repro.launch.soak`` and ``repro.resilience``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterator, Set

import numpy as np

from .replication import DeadLogicalNode, contribution_weights

SCHEDULE_KINDS = ("random", "rack", "rolling", "cascade")


@dataclasses.dataclass(frozen=True)
class FailureSchedule:
    """Seeded deterministic sequence of dead physical-node sets."""

    kind: str
    m_physical: int
    num_failures: int
    seed: int = 0
    rack_size: int = 4

    def __post_init__(self):
        if self.kind not in SCHEDULE_KINDS:
            raise ValueError(
                f"kind must be one of {SCHEDULE_KINDS}, got {self.kind!r}")
        if not 0 <= self.num_failures <= self.m_physical:
            raise ValueError(
                f"num_failures={self.num_failures} outside "
                f"[0, {self.m_physical}]")
        if self.kind == "rack" and self.rack_size < 1:
            raise ValueError(f"rack_size must be >= 1, got {self.rack_size}")
        if self.kind == "rack" and self.rack_size > self.m_physical:
            raise ValueError(
                f"impossible rack schedule: rack_size={self.rack_size} "
                f"exceeds m_physical={self.m_physical} — one rack would "
                f"cover the whole fleet and then some")

    # ------------------------------------------------------------------
    def _rng(self, step: int) -> np.random.RandomState:
        # Distinct, replayable stream per (seed, step); constants are
        # arbitrary odd primes to decorrelate the two coordinates.
        return np.random.RandomState(
            (self.seed * 1_000_003 + step * 7919 + 0x5EED) % (2 ** 31 - 1))

    def dead_at(self, step: int = 0) -> Set[int]:
        """The dead set at ``step`` (same (kind, m, f, seed, step) -> same
        set, across processes and calls)."""
        f, m = self.num_failures, self.m_physical
        if f == 0:
            return set()
        if self.kind == "random":
            rng = self._rng(step)
            return set(rng.choice(m, size=f, replace=False).tolist())
        if self.kind == "rack":
            n_racks = -(-m // self.rack_size)
            order = self._rng(step).permutation(n_racks)
            dead: Set[int] = set()
            for rack in order:
                members = [d for d in range(rack * self.rack_size,
                                            min((rack + 1) * self.rack_size, m))]
                take = members[: f - len(dead)]
                dead.update(take)
                if len(dead) >= f:
                    break
            return dead
        if self.kind == "cascade":
            # Monotone accumulation: one seed-only permutation fixes the
            # death order; step t exposes its first (t+1)*f entries, so
            # dead sets are nested supersets and never heal.
            order = self._rng(0).permutation(m)
            return set(order[: min((step + 1) * f, m)].tolist())
        # rolling: contiguous window advancing one failure-width per step
        start = (self.seed + step * f) % m
        return {(start + i) % m for i in range(f)}

    def steps(self, n: int) -> Iterator[Set[int]]:
        """The first ``n`` dead sets of the schedule."""
        for t in range(n):
            yield self.dead_at(t)


def make_schedule(kind: str, m_physical: int, num_failures: int,
                  seed: int = 0, rack_size: int = 4) -> FailureSchedule:
    """Convenience constructor mirroring the dataclass."""
    return FailureSchedule(kind=kind, m_physical=m_physical,
                           num_failures=num_failures, seed=seed,
                           rack_size=rack_size)


def analytic_completion_probability(m_logical: int, replication: int,
                                    num_failures: int) -> float:
    """Poissonized generalized-birthday estimate of P[protocol completes]
    under ``num_failures`` random dead physical nodes.

    A specific group is fully dead with probability
    prod_{t<r} (f-t)/(m_phys-t) (all r replicas among the f failed nodes,
    sampling without replacement); the dead-group count is ~Poisson with
    mean lambda = M * that, so P[complete] ~ exp(-lambda).  Degenerate at
    r=1 where every failure is its own dead group (exact P is 0 for any
    f >= 1).
    """
    r, f = replication, num_failures
    if f < r:
        return 1.0
    m_phys = m_logical * r
    p_group = 1.0
    for t in range(r):
        p_group *= (f - t) / (m_phys - t)
    return math.exp(-m_logical * p_group)


def completion_probability(m_logical: int, replication: int,
                           num_failures: int, *, trials: int = 1000,
                           kind: str = "random", seed: int = 0,
                           rack_size: int = 4) -> float:
    """Empirical P[protocol completes] over ``trials`` schedule steps.

    A trial completes iff no replica group is entirely dead, i.e.
    :func:`repro.core.replication.contribution_weights` does not raise
    :class:`DeadLogicalNode` — exactly the condition under which both the
    simulator and the device backend accept the failure set.
    """
    m_phys = m_logical * replication
    sched = FailureSchedule(kind=kind, m_physical=m_phys,
                            num_failures=num_failures, seed=seed,
                            rack_size=rack_size)
    ok = 0
    for dead in sched.steps(trials):
        try:
            contribution_weights(m_phys, replication, dead)
            ok += 1
        except DeadLogicalNode:  # noqa: RA501 — counting, not swallowing
            pass
    return ok / trials
