"""Calibrated heterogeneous-degree autotuner with a persistent plan cache.

The paper's throughput claim (§IV) is that the optimal Sparse Allreduce
network is a nested butterfly of *heterogeneous degree decreasing with
depth*, chosen by a communication cost model.  ``core.topology.tune``
sweeps ``ordered_factorizations`` against that model — but a model is only
as good as its :class:`~repro.core.netmodel.Fabric` parameters, and nominal
specs are fiction (the paper's own testbed achieved 2 Gb/s of its rated
10 Gb/s).  This module closes the loop, in three parts (docs book chapter:
``TUNING.md``):

1. **Calibrate** — :func:`measure_stage_samples` times single butterfly
   stages (grouped ``all_to_all`` inside ``shard_map``) over a ragged
   payload x fanout sweep on the *actual* mesh, and :func:`fit_fabric`
   least-squares fits the alpha / beta / gamma terms of the extended
   alpha-beta-floor-gamma model (``netmodel.Fabric.gamma_s`` is the
   per-fanout congestion term that makes degree-vs-depth tradeoffs
   expressible).  :func:`measure_plan` times whole reduces for
   modeled-vs-measured validation.
2. **Select** — :func:`select_plan` reranks ``ordered_factorizations``
   under the calibrated fabric with the power-law ``expected_counts``
   sparsity curve, optionally confirms the top-k candidates by timed
   trial, and reports whether the paper's decreasing-degree structure
   holds (warns when it does not).
3. **Cache** — :class:`PlanCache` persists ``{mesh shape, nnz profile,
   merge mode, replication} -> degrees (+ frozen routing/staging
   metadata)`` via ``repro.checkpoint.store``, so
   ``make_train_step(dp_degrees="auto")``, ``GraphEngine`` and
   ``launch/train.py`` get cache hits instead of re-tuning; an in-process
   memo additionally dedupes ``SparseAllreduce.config`` plans so a cache
   hit performs **zero retraces** (same jitted reduce fn reused).

Entry point: :func:`resolve_degrees` (what ``degrees="auto"`` resolves
through).  Cache location: ``$REPRO_PLAN_CACHE`` or
``~/.cache/repro/plans``; ``retune=True`` (CLI ``--retune``) bypasses
reads and overwrites.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import os
import time
import warnings
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .netmodel import EC2_2013, Fabric
from .topology import ButterflyPlan, check_wire, num_prime_factors, tune

CACHE_ENV = "REPRO_PLAN_CACHE"
_KEY_VERSION = 1

# Dtypes staged through the calibration all_to_alls — the same streams the
# real union path ships per stage (uint32 index + fp32 value).  The sample
# byte accounting below derives from these itemsizes; keep them in sync.
STAGE_IDX_DTYPE = np.dtype(np.uint32)
STAGE_VAL_DTYPE = np.dtype(np.float32)


# ---------------------------------------------------------------------------
# 1. Calibration: stage microbenchmarks -> least-squares Fabric fit
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StageSample:
    """One observed butterfly-stage timing.

    ``nbytes``: payload bytes per destination; ``fanout``: peers exchanged
    with (``k - 1`` for a degree-k stage); ``time_s``: wall seconds for
    the stage.
    """
    nbytes: float
    fanout: int
    time_s: float


def synth_stage_samples(fabric: Fabric, payload_bytes: Sequence[float],
                        fanouts: Sequence[int], *, serial: bool = True,
                        noise: float = 0.0, seed: int = 0
                        ) -> List[StageSample]:
    """Stage samples generated *from* a known fabric (fit-recovery tests
    and the deterministic calibration rows of ``bench_autotune``).

    ``noise`` is a relative gaussian perturbation (0 = exact model times).
    """
    rng = np.random.RandomState(seed)
    out = []
    for b in payload_bytes:
        for f in fanouts:
            t = fabric.stage_time(b, f, serial=serial)
            if noise:
                t *= max(1.0 + noise * float(rng.randn()), 0.05)
            out.append(StageSample(float(b), int(f), max(t, 1e-12)))
    return out


def fit_fabric(samples: Sequence[StageSample], *, serial: bool = True,
               name: str = "calibrated", floor_bytes: float = 0.0) -> Fabric:
    """Least-squares fit of ``Fabric(alpha_s, beta_bytes_per_s, gamma_s)``
    from stage timings.

    The stage model (``Fabric.stage_time``) is linear in
    ``(alpha, gamma, 1/beta)`` once normalized:

    * serial NIC:  ``t / f = alpha + gamma * (f - 1) + b / beta``
    * per-link:    ``t = f * alpha + gamma * (f - 1) + b / beta``

    so a single ``lstsq`` (with column scaling for conditioning) recovers
    all three terms; they are clamped to physical ranges (alpha > 0,
    gamma >= 0, beta > 0).  The packet floor is *not* fit — feed payloads
    above the suspected floor, or pass ``floor_bytes`` through explicitly.
    Needs >= 3 samples spanning >= 2 distinct payload sizes (else beta is
    unidentifiable — ValueError) and >= 2 distinct fanouts; with a single
    fanout (e.g. a prime device count, whose only stage degree is M) the
    alpha and gamma columns are collinear, so gamma is pinned to 0 with a
    warning instead of letting lstsq split alpha+gamma arbitrarily.
    """
    if len(samples) < 3:
        raise ValueError(f"need >= 3 samples to fit 3 terms, got {len(samples)}")
    if len({float(s.nbytes) for s in samples}) < 2:
        raise ValueError("need >= 2 distinct payload sizes to identify beta")
    fit_gamma = len({int(s.fanout) for s in samples}) >= 2
    if not fit_gamma:
        warnings.warn(
            "fit_fabric: all samples share one fanout, so the congestion "
            "term is not identifiable from alpha — fitting gamma_s = 0 "
            "(sweep >= 2 stage degrees to calibrate congestion)",
            UserWarning, stacklevel=2)
    rows, ys = [], []
    for s in samples:
        f = max(int(s.fanout), 1)
        gcol = [float(f - 1)] if fit_gamma else []
        if serial:
            rows.append([1.0] + gcol + [float(s.nbytes)])
            ys.append(s.time_s / f)
        else:
            rows.append([float(f)] + gcol + [float(s.nbytes)])
            ys.append(s.time_s)
    a = np.asarray(rows, np.float64)
    y = np.asarray(ys, np.float64)
    scale = np.maximum(np.abs(a).max(axis=0), 1e-30)
    x, *_ = np.linalg.lstsq(a / scale, y, rcond=None)
    x = x / scale
    alpha = max(float(x[0]), 1e-12)
    gamma = max(float(x[1]), 0.0) if fit_gamma else 0.0
    inv_beta = max(float(x[-1]), 1e-18)
    return Fabric(name=name, beta_bytes_per_s=1.0 / inv_beta, alpha_s=alpha,
                  floor_bytes=float(floor_bytes), gamma_s=gamma)


def fit_error(fabric: Fabric, samples: Sequence[StageSample], *,
              serial: bool = True) -> float:
    """Mean relative |modeled - measured| / measured over ``samples``
    (the bench's modeled-vs-measured error column)."""
    errs = [abs(fabric.stage_time(s.nbytes, s.fanout, serial=serial)
                - s.time_s) / max(s.time_s, 1e-12) for s in samples]
    return float(np.mean(errs)) if errs else 0.0


def measure_stage_samples(mesh=None, *, payload_entries=(256, 4096, 32768),
                          degrees: Optional[Sequence[int]] = None,
                          repeats: int = 3, seed: int = 0
                          ) -> List[StageSample]:
    """Time single butterfly stages (grouped ``all_to_all`` in shard_map)
    on the actual mesh — the calibration microbenchmark.

    For each *stage degree* ``k`` in ``degrees`` (default: the divisors of
    the mesh size among {2, 4, 8, 16, 32, m}; every k must divide the mesh
    size so the groups tile it) and each payload size, one jitted
    shard_map program exchanges the two streams a real butterfly stage
    ships — ``[k, c]`` uint32 indices *and* ``[k, c]`` float32 values
    (``STAGE_IDX_DTYPE`` / ``STAGE_VAL_DTYPE``) — within
    ``axis_index_groups`` of size k; best-of-``repeats`` wall time becomes
    a :class:`StageSample` with ``fanout = k - 1`` peers and ``nbytes =
    c * (idx.itemsize + val.itemsize)`` per destination.  (Pricing values
    alone would under-count the wire ~2x and skew every fabric fit.)
    Off-TPU (host devices) this calibrates the XLA-CPU collective cost —
    noisy but *measured*, which is the point; perf claims belong on real
    fabrics.
    """
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map
    if mesh is None:
        mesh = jax.make_mesh((len(jax.devices()),), ("nodes",))
    axis = mesh.axis_names[0]
    m = int(mesh.shape[axis])
    if degrees is None:
        degrees = tuple(dict.fromkeys(
            k for k in (2, 4, 8, 16, 32, m) if 2 <= k <= m and m % k == 0))
    bad = [k for k in degrees if k < 2 or m % k]
    if bad:
        raise ValueError(
            f"stage degrees {bad} do not divide the mesh size {m}")
    rng = np.random.RandomState(seed)
    samples: List[StageSample] = []
    for k in degrees:
        groups = [list(range(g * k, (g + 1) * k)) for g in range(m // k)]

        def body(ib, vb):
            yi = lax.all_to_all(ib.reshape(ib.shape[1:]), axis,
                                split_axis=0, concat_axis=0,
                                axis_index_groups=groups)
            yv = lax.all_to_all(vb.reshape(vb.shape[1:]), axis,
                                split_axis=0, concat_axis=0,
                                axis_index_groups=groups)
            return (yi.reshape((1,) + yi.shape),
                    yv.reshape((1,) + yv.shape))

        fn = jax.jit(shard_map(body, mesh=mesh, in_specs=(P(axis), P(axis)),
                               out_specs=(P(axis), P(axis)),
                               check_vma=False))
        for c in payload_entries:
            xi = jnp.asarray(rng.randint(
                0, 1 << 31, size=(m, k, int(c))).astype(STAGE_IDX_DTYPE))
            xv = jnp.asarray(rng.rand(m, k, int(c)).astype(STAGE_VAL_DTYPE))
            jax.block_until_ready(fn(xi, xv))     # compile outside timing
            best = float("inf")
            for _ in range(repeats):
                t0 = time.perf_counter()
                jax.block_until_ready(fn(xi, xv))
                best = min(best, time.perf_counter() - t0)
            # Wire bytes per destination: both streams, actual itemsizes.
            nbytes = float(c) * float(STAGE_IDX_DTYPE.itemsize
                                      + STAGE_VAL_DTYPE.itemsize)
            samples.append(StageSample(nbytes=nbytes,
                                       fanout=k - 1, time_s=best))
    return samples


def calibrate_fabric(mesh=None, *, name: Optional[str] = None,
                     serial: bool = True, store: bool = False,
                     cache: Optional["PlanCache"] = None,
                     **measure_kw) -> Fabric:
    """Measure (:func:`measure_stage_samples`) + fit (:func:`fit_fabric`)
    in one call; ``store=True`` persists the fitted fabric in the plan
    cache for :func:`calibrated_fabric` lookups (keyed by backend and
    device count)."""
    import jax
    samples = measure_stage_samples(mesh, **measure_kw)
    ndev = len(jax.devices()) if mesh is None else math.prod(
        int(s) for s in mesh.devices.shape)
    name = name or f"calibrated-{jax.default_backend()}-{ndev}"
    fabric = fit_fabric(samples, serial=serial, name=name)
    if store:
        store_calibrated_fabric(fabric, backend=jax.default_backend(),
                                num_devices=ndev, cache=cache,
                                residual=fit_error(fabric, samples,
                                                   serial=serial))
    return fabric


def measure_plan(plan: ButterflyPlan, *, entries_per_node: int = 2048,
                 width: int = 1, mesh=None, merge: str = "sort",
                 repeats: int = 3, seed: int = 0) -> float:
    """Wall seconds for one full ``union_reduce`` under ``plan`` on the
    actual mesh — the timed-trial confirmation hook for
    :func:`select_plan` (``confirm=``) and the modeled-vs-measured rows of
    ``bench_autotune``."""
    import jax
    import jax.numpy as jnp

    from .api import SparseAllreduce
    m = plan.num_nodes
    ar = SparseAllreduce(m, plan.degrees, backend="device", mesh=mesh,
                         merge=merge)
    rng = np.random.RandomState(seed)
    idx = np.sort(rng.choice(1 << 20, size=(m, entries_per_node),
                             replace=True).astype(np.uint32), axis=1)
    shape = (m, entries_per_node) + ((width,) if width > 1 else ())
    val = rng.rand(*shape).astype(np.float32)
    cap = min(m * entries_per_node, 1 << 16)
    args = (jnp.asarray(idx), jnp.asarray(val))
    jax.block_until_ready(ar.union_reduce(*args, out_capacity=cap)[1])
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(ar.union_reduce(*args, out_capacity=cap)[1])
        best = min(best, time.perf_counter() - t0)
    return best


# ---------------------------------------------------------------------------
# 2. Selection: rerank factorizations under the calibrated model
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TuneReport:
    """Outcome of one :func:`select_plan` sweep.

    ``plan`` is the winner; ``modeled_s`` its modeled reduce seconds;
    ``decreasing`` whether the paper's §IV degree-decreasing-with-depth
    structure holds for it; ``fallback`` records degenerate sweeps
    (``"prime"`` = only the flat plan exists, ``"depth-extended"`` =
    ``max_depth`` was lifted to Omega(M)); ``candidates`` the top-k
    ``(modeled_s, degrees)`` ranking; ``measured_s`` the timed-trial
    seconds per candidate when confirmation ran (else None).

    Overlap-aware sweeps (``overlap_compute_s``) additionally report the
    achieved-vs-rate-optimal position: ``rate_optimal_s`` is the
    schedule-independent allreduce lower bound for the swept payload
    (``repro.core.netmodel.rate_optimal_allreduce_s``, per *On the
    Computation Rate of All-Reduce*) and ``rate_fraction`` is
    ``rate_optimal_s / modeled_s`` — 1.0 means the winner meets the bound,
    smaller means headroom a better schedule could still claim.  Both are
    populated on every sweep (overlapped or not) so the overlap benches
    can chart the gap; ``overlap_compute_s`` echoes the request (None =
    bulk-synchronous ranking).
    """
    plan: ButterflyPlan
    modeled_s: float
    decreasing: bool
    fallback: Optional[str]
    candidates: Tuple[Tuple[float, Tuple[int, ...]], ...]
    measured_s: Optional[Dict[str, float]] = None
    rate_optimal_s: Optional[float] = None
    rate_fraction: Optional[float] = None
    overlap_compute_s: Optional[float] = None


def select_plan(num_nodes: int, n0: float, total_range: float,
                fabric: Fabric = EC2_2013, *,
                bytes_per_entry: float = 12.0, serial_nic: bool = True,
                top_k: int = 5, max_depth: int = 6,
                wire: str = "raw", value_width: int = 1,
                confirm: Optional[Callable[[ButterflyPlan], float]] = None,
                overlap_compute_s: Optional[float] = None
                ) -> TuneReport:
    """Rank all degree sequences under ``fabric`` with the power-law
    ``expected_counts`` compression curve; return a :class:`TuneReport`.

    ``confirm`` (e.g. ``functools.partial(measure_plan, mesh=mesh)``)
    re-ranks the ``top_k`` model candidates by timed trial — the model
    proposes, the hardware disposes.  Degenerate sweeps (prime M,
    truncating ``max_depth``) follow ``topology.tune``'s documented
    fallback and are recorded in ``report.fallback``.  A winner violating
    the paper's decreasing-degree structure is reported (and warned) but
    not overridden.

    ``wire`` re-ranks under the *encoded* per-stage byte model
    (``topology.wire_entry_bytes``): compression shrinks the bandwidth
    term without touching latency/congestion, so the optimal degree
    factorization can genuinely shift — that re-ranking is the point of
    tuning per wire format (see ``benchmarks/bench_wire.py``).

    ``overlap_compute_s`` re-ranks under the *overlapped* stage model
    (``topology.ButterflyPlan.modeled_overlap_time``): candidates are
    scored as serial overheads + max(bandwidth, overlap_compute_s), i.e.
    each stage's wire time hides behind that much independent compute (the
    bucketed gradient sync / rotated engine scan of ARCHITECTURE.md
    "Overlap & scheduling").  Once bandwidth hides, the residual
    serial-NIC cost is per-message setup — ``sum(k_l - 1)`` messages per
    node — so the optimum shifts toward deeper, lower-degree
    factorizations (binary in the limit), the opposite of the
    bandwidth-bound direction (benchmarks/bench_overlap.py).
    Every report also carries ``rate_optimal_s`` / ``rate_fraction`` — the
    achieved-vs-rate-optimal gap ROADMAP item 2 asks the benches to chart.
    """
    check_wire(wire)
    hidden = float(overlap_compute_s or 0.0)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        scored = tune(num_nodes, n0, total_range, fabric, bytes_per_entry,
                      serial_nic=serial_nic, top=max(int(top_k), 1),
                      max_depth=max_depth, wire=wire,
                      value_width=value_width, hidden_compute_s=hidden)
    fallback = None
    for w in caught:
        msg = str(w.message)
        if "prime" in msg:
            fallback = "prime"
        elif "truncate" in msg and fallback is None:
            fallback = "depth-extended"
        warnings.warn_explicit(w.message, w.category, w.filename, w.lineno)
    candidates = tuple((float(t), p.degrees) for t, p in scored)
    best_t, best = scored[0]
    measured = None
    if confirm is not None and len(scored) > 1:
        measured = {str(p): float(confirm(p)) for _, p in scored}
        best_t, best = min(scored, key=lambda tp: measured[str(tp[1])])
    decreasing = all(a >= b for a, b in zip(best.degrees, best.degrees[1:]))
    if not decreasing:
        warnings.warn(
            f"select_plan: winner {best} violates the paper's "
            f"decreasing-degree structure (SIV) — trust it only if it "
            f"came from a timed trial", UserWarning, stacklevel=2)
    from .netmodel import rate_optimal_allreduce_s
    payload = float(n0) * float(bytes_per_entry)
    opt_s = rate_optimal_allreduce_s(payload, num_nodes, fabric)
    return TuneReport(plan=best, modeled_s=float(best_t),
                      decreasing=decreasing, fallback=fallback,
                      candidates=candidates, measured_s=measured,
                      rate_optimal_s=opt_s,
                      rate_fraction=(opt_s / float(best_t)
                                     if best_t > 0 else 0.0),
                      overlap_compute_s=overlap_compute_s)


# ---------------------------------------------------------------------------
# 3. Persistent plan cache (checkpoint/store.py artifacts)
# ---------------------------------------------------------------------------

def cache_root() -> str:
    """Cache directory: ``$REPRO_PLAN_CACHE`` or ``~/.cache/repro/plans``."""
    return os.environ.get(CACHE_ENV) or os.path.join(
        os.path.expanduser("~"), ".cache", "repro", "plans")


def _qlog(x: float) -> float:
    """Quantize to half-log2 buckets — the nnz-profile key granularity
    (plans are reused across <~ 1.4x workload-size drift; see TUNING.md
    invalidation rules)."""
    return round(2.0 * math.log2(max(float(x), 1.0))) / 2.0


def _digest(obj) -> str:
    return hashlib.sha1(
        json.dumps(obj, sort_keys=True).encode()).hexdigest()[:16]


def plan_cache_key(*, mesh: Sequence[Tuple[str, int]], nnz: float,
                   index_range: float, merge: str, replication: int,
                   width: int, fabric: Fabric,
                   serial_nic: bool = True,
                   shrunk_from: Optional[int] = None,
                   wire: str = "raw",
                   overlap_compute_s: float = 0.0) -> dict:
    """The cache key: mesh shape, quantized nnz profile, merge mode,
    replication, value width, fabric fingerprint, NIC serialization mode,
    key-schema version.  Any field changing = a different plan file
    (invalidation is purely key-miss; nothing is ever reused across these
    boundaries).

    ``shrunk_from`` marks survivor plans produced by ``repro.resilience``
    replanning a fleet that started at that logical size — keyed
    separately from native plans of equal size (the nnz profile carried
    over from the original fleet differs), and only added to the key when
    set, so every pre-existing digest is unchanged.

    ``wire`` keys plans per wire format: degrees tuned under compressed
    payloads are *not* valid answers for raw ones (the byte model differs),
    so a raw-tuned entry must never be served for e.g. ``delta+bf16``.
    Like ``shrunk_from`` it enters the key only when non-default, keeping
    every pre-existing "raw" digest stable.

    ``overlap_compute_s`` keys plans swept under the overlapped stage
    model (``select_plan(overlap_compute_s=...)``): degrees reranked with
    bandwidth hidden behind compute are not valid bulk-synchronous
    answers.  Quantized to half-log2 buckets like the nnz profile and —
    same convention again — only added when nonzero, so every
    pre-existing digest is unchanged."""
    key = {
        "kind": "plan", "version": _KEY_VERSION,
        "mesh": [[str(a), int(s)] for a, s in mesh],
        "nnz_bucket": _qlog(nnz), "range_bucket": _qlog(index_range),
        "merge": str(merge), "replication": int(replication),
        "width": int(width),
        "fabric": fabric.as_meta(),
        "serial_nic": bool(serial_nic),
    }
    if shrunk_from is not None:
        key["shrunk_from"] = int(shrunk_from)
    if check_wire(wire) != "raw":
        key["wire"] = str(wire)
    if overlap_compute_s:
        # seconds are fractional: bucket on the equivalent byte scale
        key["overlap_bucket"] = _qlog(
            float(overlap_compute_s) * fabric.beta_bytes_per_s)
    return key


def fabric_cache_key(*, backend: str, num_devices: int) -> dict:
    """Key for persisted calibrations: one fitted fabric per (backend,
    device count) — recalibrate with ``calibrate_fabric(store=True)``."""
    return {"kind": "fabric", "version": _KEY_VERSION,
            "backend": str(backend), "num_devices": int(num_devices)}


class PlanCache:
    """Directory of ``checkpoint/store.py`` artifacts keyed by digest.

    Each entry is ``<kind>-<digest>.npz`` (arrays: routing tensors for
    plan entries) + ``.meta.json`` (degrees, staging metadata, fabric
    parameters, the full key for debugging).  IO errors degrade to cache
    misses (counted in ``stats["errors"]``) — a broken cache can slow you
    down but never stop a run.
    """

    def __init__(self, root: Optional[str] = None):
        self._root = root
        self.stats = {"hits": 0, "misses": 0, "stores": 0, "errors": 0}

    @property
    def root(self) -> str:
        """Resolved cache directory (env var re-read when not pinned)."""
        return self._root or cache_root()

    def path(self, key: dict) -> str:
        """Extension-less artifact path for ``key``."""
        return os.path.join(self.root, f"{key['kind']}-{_digest(key)}")

    def load(self, key: dict):
        """``(meta, arrays)`` for ``key`` or ``None`` (counted miss)."""
        p = self.path(key)
        if not os.path.exists(p + ".meta.json"):
            self.stats["misses"] += 1
            return None
        try:
            from repro.checkpoint.store import load_flat
            if os.path.exists(p + ".npz"):
                arrays, meta = load_flat(p)
            else:
                arrays = {}
                with open(p + ".meta.json") as f:
                    meta = json.load(f)
            self.stats["hits"] += 1
            return meta, arrays
        except Exception:
            self.stats["errors"] += 1
            return None

    def store(self, key: dict, meta: dict,
              arrays: Optional[Dict[str, np.ndarray]] = None) -> None:
        """Persist ``meta`` (+ optional ``arrays``) under ``key``."""
        try:
            from repro.checkpoint.store import save
            save(self.path(key), arrays if arrays else
                 {"empty": np.zeros(0, np.int32)},
                 meta={**meta, "key": key})
            self.stats["stores"] += 1
        except OSError:
            self.stats["errors"] += 1

    def invalidate(self, key: dict) -> None:
        """Drop ``key``'s artifact (the ``--retune`` escape hatch)."""
        p = self.path(key)
        for ext in (".npz", ".meta.json"):
            try:
                os.remove(p + ext)
            except OSError:
                pass


_DEFAULT_CACHE: Optional[PlanCache] = None


def default_cache() -> PlanCache:
    """Process-wide :class:`PlanCache` rooted at :func:`cache_root`."""
    global _DEFAULT_CACHE
    if _DEFAULT_CACHE is None:
        _DEFAULT_CACHE = PlanCache()
    return _DEFAULT_CACHE


def fabric_from_meta(meta: dict) -> Fabric:
    """Inverse of ``Fabric.as_meta`` (calibration / plan-cache reads)."""
    return Fabric(name=str(meta["name"]),
                  beta_bytes_per_s=float(meta["beta_bytes_per_s"]),
                  alpha_s=float(meta["alpha_s"]),
                  floor_bytes=float(meta.get("floor_bytes", 0.0)),
                  gamma_s=float(meta.get("gamma_s", 0.0)))


def store_calibrated_fabric(fabric: Fabric, *, backend: str,
                            num_devices: int,
                            cache: Optional[PlanCache] = None,
                            residual: Optional[float] = None) -> None:
    """Persist a fitted fabric for :func:`calibrated_fabric` lookups."""
    cache = cache or default_cache()
    meta = {"fabric": fabric.as_meta()}
    if residual is not None:
        meta["fit_residual"] = float(residual)
    cache.store(fabric_cache_key(backend=backend,
                                 num_devices=num_devices), meta)


def calibrated_fabric(*, backend: str, num_devices: int,
                      cache: Optional[PlanCache] = None,
                      default: Optional[Fabric] = None) -> Optional[Fabric]:
    """The persisted calibration for (backend, device count), or
    ``default`` when none exists."""
    cache = cache or default_cache()
    hit = cache.load(fabric_cache_key(backend=backend,
                                      num_devices=num_devices))
    if hit is None:
        return default
    meta, _ = hit
    return fabric_from_meta(meta["fabric"])


# ---------------------------------------------------------------------------
# resolve_degrees: what degrees="auto" goes through
# ---------------------------------------------------------------------------

def resolve_degrees(num_nodes: int, *, n0: float, total_range: float,
                    fabric: Fabric = EC2_2013, merge: str = "sort",
                    replication: int = 1, width: int = 1,
                    serial_nic: bool = True,
                    mesh_sig: Optional[Sequence[Tuple[str, int]]] = None,
                    cache: Optional[PlanCache] = None,
                    retune: bool = False, top_k: int = 5,
                    confirm: Optional[Callable] = None,
                    shrunk_from: Optional[int] = None,
                    wire: str = "raw"
                    ) -> Tuple[Tuple[int, ...], str]:
    """Cached, calibrated degree selection — returns ``(degrees, source)``
    with ``source`` in ``{"cache", "tuned"}``.

    Consults the persistent :class:`PlanCache` first (unless ``retune``),
    else runs :func:`select_plan` under ``fabric`` and stores the result
    (degrees + tune report + fabric parameters) for the next process.
    ``mesh_sig`` defaults to ``(("nodes", num_nodes),)``; pass the real
    ``(axis, size)`` layout so per-axis plans key separately.
    ``shrunk_from`` keys survivor replans separately (see
    :func:`plan_cache_key`) — a repeat shrink to the same survivor count
    is then a cache hit, which is what keeps ``repro.resilience``
    recovery cheap.
    ``wire`` tunes under the encoded byte model and keys the cache entry
    per wire format (a raw-tuned plan is never served for a compressed
    wire, and vice versa).
    """
    cache = cache or default_cache()
    sig = tuple(mesh_sig) if mesh_sig else (("nodes", int(num_nodes)),)
    if math.prod(s for _, s in sig) != num_nodes:
        raise ValueError(f"mesh_sig {sig} does not cover {num_nodes} nodes")
    key = plan_cache_key(mesh=sig, nnz=n0, index_range=total_range,
                         merge=merge, replication=replication, width=width,
                         fabric=fabric, serial_nic=serial_nic,
                         shrunk_from=shrunk_from, wire=wire)
    if not retune:
        hit = cache.load(key)
        if hit is not None:
            meta, _ = hit
            degrees = tuple(int(d) for d in meta.get("degrees", ()))
            if math.prod(degrees) == num_nodes or (
                    num_nodes == 1 and degrees == ()):
                return degrees, "cache"
    report = select_plan(num_nodes, n0, total_range, fabric,
                         serial_nic=serial_nic, top_k=top_k,
                         wire=wire, value_width=width,
                         confirm=confirm)
    cache.store(key, {
        "degrees": [int(d) for d in report.plan.degrees],
        "num_nodes": int(num_nodes),
        "modeled_s": report.modeled_s,
        "decreasing": report.decreasing,
        "fallback": report.fallback,
        "candidates": [[t, list(d)] for t, d in report.candidates],
        "measured_s": report.measured_s,
        "n0": float(n0), "total_range": float(total_range),
        "serial_nic": bool(serial_nic), "wire": str(wire),
    })
    return report.plan.degrees, "tuned"


# ---------------------------------------------------------------------------
# Frozen-plan persistence + in-process memo (zero-retrace cache hits)
# ---------------------------------------------------------------------------

plan_memo_stats = {"hits": 0, "misses": 0, "disk_hits": 0}
_PLANNED_MEMO: Dict[str, tuple] = {}   # insertion-ordered: LRU via re-insert
# Frozen plans + compiled reduce fns are heavyweight; cap the memo so a
# long-running process whose index pattern evolves (re-config per epoch,
# many engines over different graphs) cannot grow without bound.
PLANNED_MEMO_MAX = 64


def planner_version() -> str:
    """Digest of every source module frozen routing depends on
    (``planned.py``, ``simulator.py``, ``topology.py``, ``sparse_vec.py``,
    ``replication.py``).  Part of every persisted planned artifact's key,
    so editing the planning/hashing/grouping code auto-invalidates frozen
    routing from older code instead of silently reusing it."""
    global _PLANNER_VERSION
    if _PLANNER_VERSION is None:
        h = hashlib.sha1()
        here = os.path.dirname(os.path.abspath(__file__))
        for fname in ("planned.py", "simulator.py", "topology.py",
                      "sparse_vec.py", "replication.py"):
            with open(os.path.join(here, fname), "rb") as f:
                h.update(f.read())
        _PLANNER_VERSION = h.hexdigest()[:12]
    return _PLANNER_VERSION


_PLANNER_VERSION: Optional[str] = None


def planned_cache_key(fingerprint: str) -> dict:
    """Disk-cache key for one frozen-config artifact (the fingerprint
    already embeds :func:`planner_version`)."""
    return {"kind": "planned", "version": _KEY_VERSION, "fp": fingerprint}


def clear_plan_memo() -> None:
    """Drop the in-process planned/reduce-fn memo (tests, mesh teardown)."""
    _PLANNED_MEMO.clear()
    plan_memo_stats.update(hits=0, misses=0, disk_hits=0)


def _mesh_fingerprint(mesh) -> tuple:
    return (tuple(mesh.axis_names),
            tuple(int(s) for s in mesh.devices.shape),
            tuple(int(d.id) for d in mesh.devices.flat))


def planned_fingerprint(mesh, degrees: Sequence[int], replication: int,
                        dead, width: int, perm,
                        out_indices: Sequence[np.ndarray],
                        in_indices: Sequence[np.ndarray],
                        fabric: Optional[Fabric] = None) -> str:
    """Digest identifying one frozen config: mesh devices + plan shape +
    planner-code version + the exact index pattern (+ the stats-model
    fabric, since the cached ``ReduceStats`` were modeled under it).
    Same fingerprint => the frozen routing (and its compiled reduce fn)
    is reusable with zero re-planning/retracing."""
    h = hashlib.sha1()
    h.update(repr((_mesh_fingerprint(mesh), tuple(int(d) for d in degrees),
                   int(replication), tuple(sorted(dead or ())), int(width),
                   int(perm.mult), int(perm.xor), planner_version(),
                   None if fabric is None else
                   sorted(fabric.as_meta().items()))).encode())
    for group in (out_indices, in_indices):
        h.update(b"|group|")
        for arr in group:
            a = np.ascontiguousarray(np.asarray(arr))
            h.update(str(a.dtype).encode())
            h.update(str(a.shape).encode())
            h.update(a.tobytes())
    return h.hexdigest()


def memo_lookup(fingerprint: str):
    """In-process planned-config memo read (None on miss); hits refresh
    LRU recency."""
    hit = _PLANNED_MEMO.pop(fingerprint, None)
    if hit is not None:
        _PLANNED_MEMO[fingerprint] = hit       # re-insert: most recent
    plan_memo_stats["hits" if hit is not None else "misses"] += 1
    return hit


def memo_store(fingerprint: str, value: tuple) -> None:
    """In-process planned-config memo write (LRU-evicts past
    ``PLANNED_MEMO_MAX`` entries)."""
    _PLANNED_MEMO[fingerprint] = value
    while len(_PLANNED_MEMO) > PLANNED_MEMO_MAX:
        _PLANNED_MEMO.pop(next(iter(_PLANNED_MEMO)))


def stats_to_meta(stats) -> dict:
    """``ReduceStats`` -> JSON-able dict (plan-cache persistence)."""
    return {"config_time_s": stats.config_time_s,
            "reduce_time_s": stats.reduce_time_s,
            "overflow": int(stats.overflow),
            "stages": [dataclasses.asdict(s) for s in stats.stages]}


def stats_from_meta(meta: dict):
    """Inverse of :func:`stats_to_meta`."""
    from .simulator import ReduceStats, StageStats
    return ReduceStats(
        config_time_s=float(meta.get("config_time_s", 0.0)),
        reduce_time_s=float(meta.get("reduce_time_s", 0.0)),
        overflow=int(meta.get("overflow", 0)),
        stages=[StageStats(**s) for s in meta.get("stages", [])])


def planned_to_artifact(planned) -> Tuple[Dict[str, np.ndarray], dict]:
    """Serialize a ``PlannedSparseAllreduce`` into ``(arrays, meta)`` for
    :class:`PlanCache` — every frozen routing tensor plus the scalars and
    ``make_device_plan`` arguments needed to rebuild it byte-identically
    in a fresh process (:func:`planned_from_artifact`)."""
    arrays = {"user_scatter": planned.user_scatter,
              "bottom_gather": planned.bottom_gather,
              "bottom_hit": planned.bottom_hit,
              "user_gather": planned.user_gather}
    if planned.weights is not None:
        arrays["weights"] = np.asarray(planned.weights)
    layer_meta = []
    for i, L in enumerate(planned.layers):
        arrays[f"layer{i}/send_gather"] = L.send_gather
        arrays[f"layer{i}/merge_scatter"] = L.merge_scatter
        arrays[f"layer{i}/up_send_gather"] = L.up_send_gather
        arrays[f"layer{i}/up_recv_scatter"] = L.up_recv_scatter
        layer_meta.append({"merged_size": int(L.merged_size),
                           "up_size": int(L.up_size)})
    dp = planned.dplan
    meta = {
        "sorted_size": int(planned.sorted_size),
        "in_user_len": int(planned.in_user_len),
        "width": int(planned.width),
        "perm": {"mult": int(planned.perm.mult),
                 "xor": int(planned.perm.xor)},
        "layers": layer_meta,
        "dplan": {
            "axes": [[a, int(s)] for a, s in dp.axes],
            # logical degrees per axis, exactly the make_device_plan input
            "in_capacity": int(dp.in_capacity),
            "out_capacity": int(dp.out_capacity),
            "replication": int(dp.replication),
        },
    }
    return arrays, meta


def planned_from_artifact(arrays: Dict[str, np.ndarray], meta: dict,
                          degrees_per_axis: Dict[str, Tuple[int, ...]]):
    """Rebuild a ``PlannedSparseAllreduce`` from a cache artifact.

    ``degrees_per_axis`` must be the same *logical* per-axis degree dict
    the original ``make_device_plan`` call used (the caller knows it — it
    is part of the plan key / its meta)."""
    from .allreduce import make_device_plan
    from .planned import PlannedSparseAllreduce, _LayerMaps
    from .sparse_vec import HashPerm
    dmeta = meta["dplan"]
    dplan = make_device_plan(
        [(a, int(s)) for a, s in dmeta["axes"]],
        {a: tuple(int(x) for x in d) for a, d in degrees_per_axis.items()},
        in_capacity=int(dmeta["in_capacity"]),
        out_capacity=int(dmeta["out_capacity"]),
        replication=int(dmeta["replication"]))
    layers = []
    for i, lm in enumerate(meta["layers"]):
        layers.append(_LayerMaps(
            send_gather=arrays[f"layer{i}/send_gather"],
            merge_scatter=arrays[f"layer{i}/merge_scatter"],
            merged_size=int(lm["merged_size"]),
            up_send_gather=arrays[f"layer{i}/up_send_gather"],
            up_recv_scatter=arrays[f"layer{i}/up_recv_scatter"],
            up_size=int(lm["up_size"])))
    weights = arrays.get("weights")
    return PlannedSparseAllreduce(
        dplan=dplan,
        perm=HashPerm(mult=int(meta["perm"]["mult"]),
                      xor=int(meta["perm"]["xor"])),
        width=int(meta["width"]),
        user_scatter=arrays["user_scatter"],
        sorted_size=int(meta["sorted_size"]),
        layers=layers,
        bottom_gather=arrays["bottom_gather"],
        bottom_hit=arrays["bottom_hit"],
        user_gather=arrays["user_gather"],
        in_user_len=int(meta["in_user_len"]),
        weights=weights)
