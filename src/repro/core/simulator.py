"""Message-level simulator of the nested heterogeneous-degree butterfly.

This is the *paper-faithful reference implementation* of Sparse Allreduce
(Zhao & Canny 2013): per-node mailboxes, hash-permuted sorted indices,
contiguous range partitioning per layer, tree-merge summation, a nested
up-phase through the same nodes, and r-way replication with failures.  It is
the correctness oracle for the TPU shard_map backend and the measurement
engine for the paper's experiment suite (Figs 3, 5, 6, 8; Tables I, II).

API mirrors the paper's two-call interface (§III-B):

    sim = SimSparseAllreduce(plan, num_logical, replication=r, dead=set())
    sim.config(out_indices, in_indices)      # once per index pattern
    in_values = sim.reduce(out_values)       # per iteration

Timing uses synchronized stages: T = sum over stages of the slowest node's
stage time (config/reduce measured separately, as in Table II).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from .netmodel import EC2_2013, Fabric
from .replication import DeadLogicalNode
from .sparse_vec import HashPerm, IDENTITY_PERM, sort_coalesce_np, tree_sum_np
from .topology import ButterflyPlan

BYTES_IDX = 4
BYTES_VAL = 4


@dataclasses.dataclass
class StageStats:
    """Modeled per-layer message accounting for one reduce phase."""
    layer: int
    phase: str                 # "down" | "up"
    max_msg_bytes: float = 0.0
    total_bytes: float = 0.0
    num_messages: int = 0
    time_s: float = 0.0


@dataclasses.dataclass
class ReduceStats:
    """Aggregated modeled cost of one config or reduce (all stages)."""
    config_time_s: float = 0.0
    reduce_time_s: float = 0.0
    stages: List[StageStats] = dataclasses.field(default_factory=list)
    overflow: int = 0

    @property
    def total_bytes(self):
        """Sum of modeled bytes moved across every stage."""
        return sum(s.total_bytes for s in self.stages)


# DeadLogicalNode lives in repro.core.replication (shared with the device
# backend's contribution_weights); re-exported here for back-compat.


class SimSparseAllreduce:
    """Reference Sparse Allreduce over ``num_logical`` logical nodes.

    replication=r mirrors logical node i onto physical nodes i, i+M, ...,
    i+(r-1)M (paper §V-A).  ``dead`` is a set of *physical* node ids; a
    logical node participates iff at least one replica is alive.  Messages
    are replicated r-fold (bytes/time accounting) and the first-alive
    replica's copy is used (deterministic stand-in for packet racing).
    """

    def __init__(self, plan: ButterflyPlan, *, replication: int = 1,
                 dead: Optional[Set[int]] = None,
                 perm: Optional[HashPerm] = None,
                 fabric: Fabric = EC2_2013,
                 merge_ns_per_entry: float = 4.0,
                 value_width: int = 1):
        self.plan = plan
        self.m = plan.num_nodes
        self.r = replication
        self.dead = set(dead or ())
        self.perm = perm if perm is not None else HashPerm.make(0)
        self.fabric = fabric
        self.merge_ns = merge_ns_per_entry
        self.w = value_width
        self._configured = False
        bad = self.dead - set(range(self.m * self.r))
        if bad:
            raise ValueError(
                f"dead ids {sorted(bad)} outside [0, {self.m * self.r}) — "
                f"failure injection would silently be a no-op")
        for n in range(self.m):
            if not self._alive(n):
                raise DeadLogicalNode(f"logical node {n}: all {self.r} replicas dead")

    # -- replication ---------------------------------------------------------
    def _alive(self, logical: int) -> bool:
        return any((logical + j * self.m) not in self.dead for j in range(self.r))

    def replica_ids(self, logical: int) -> List[int]:
        """Physical node ids hosting ``logical`` (paper §V layout)."""
        return [logical + j * self.m for j in range(self.r)]

    # -- config (paper §IV-A: index routing, computed once) -------------------
    def config(self, out_indices: Sequence[np.ndarray],
               in_indices: Sequence[np.ndarray]) -> ReduceStats:
        """The paper's ``config``: freeze all message routing (host numpy)
        for one index pattern and return its modeled :class:`ReduceStats`."""
        assert len(out_indices) == len(in_indices) == self.m
        plan, m = self.plan, self.m
        stats = ReduceStats()

        # Hash-permute and sort; remember maps back to user order.
        self.out_sorted: List[np.ndarray] = []
        self.out_user_to_sorted: List[np.ndarray] = []   # coalesce map
        self.in_sorted: List[np.ndarray] = []
        self.in_sorted_to_user: List[np.ndarray] = []
        for n in range(m):
            h = self.perm.fwd_np(np.asarray(out_indices[n], dtype=np.uint32))
            order = np.argsort(h, kind="stable")
            hs = h[order]
            uniq, inv = np.unique(hs, return_inverse=True)
            # user entry j contributes to sorted-unique slot:
            u2s = np.empty(len(h), dtype=np.int64)
            u2s[order] = inv
            self.out_sorted.append(uniq)
            self.out_user_to_sorted.append(u2s)

            hi = self.perm.fwd_np(np.asarray(in_indices[n], dtype=np.uint32))
            iuniq, iinv = np.unique(hi, return_inverse=True)
            self.in_sorted.append(iuniq)
            self.in_sorted_to_user.append(iinv)  # user j reads slot iinv[j]

        # Down-phase index routing. State per node per layer.
        #   down_idx[l][n]   : node n's sorted unique out-idx entering layer l
        #   down_maps[l][n]  : (src_slices, merge_inv) to rebuild sums at l+1
        #   req_idx[l][n][t] : in-idx piece node n requests from group member t
        #   req_pos[l][n][t] : positions of that piece in member's layer-(l+1)
        #                      in-idx array (filled as members learn them)
        self.down_maps: List[List[Tuple[List[np.ndarray], np.ndarray, np.ndarray]]] = []
        self.req_piece: List[List[List[np.ndarray]]] = []
        self.in_at: List[List[np.ndarray]] = [list(self.in_sorted)]
        cur_out = list(self.out_sorted)

        for l in range(plan.depth):
            k = plan.degrees[l]
            layer_maps: List = [None] * m
            layer_req: List = [None] * m
            nxt_out: List = [None] * m
            nxt_in: List = [None] * m
            st_down = StageStats(layer=l, phase="down")
            for n in range(m):
                members = plan.group_members(n, l)
                edges = plan.edges_at(n, l).astype(np.uint64)
                # split own out-idx and in-idx into k pieces by range
                cuts_o = np.searchsorted(cur_out[n].astype(np.uint64), edges)
                cuts_i = np.searchsorted(self.in_at[l][n].astype(np.uint64), edges)
                layer_req[n] = [self.in_at[l][n][cuts_i[t]:cuts_i[t + 1]]
                                for t in range(k)]
                # stats: k-1 outgoing messages (idx+val bytes modelled later)
                for t in range(k):
                    if members[t] == n:
                        continue
                    nbytes = (cuts_o[t + 1] - cuts_o[t]) * BYTES_IDX \
                        + (cuts_i[t + 1] - cuts_i[t]) * BYTES_IDX
                    nbytes *= self.r  # replicated messages
                    st_down.num_messages += self.r
                    st_down.total_bytes += nbytes
                    st_down.max_msg_bytes = max(st_down.max_msg_bytes, nbytes)
            # deliver: node n at digit t receives piece t from every member
            for n in range(m):
                members = plan.group_members(n, l)
                t_self = members.index(n)
                pieces_out, pieces_in = [], []
                for mem in members:
                    mcuts = np.searchsorted(
                        cur_out[mem].astype(np.uint64),
                        plan.edges_at(mem, l).astype(np.uint64))
                    pieces_out.append(
                        cur_out[mem][mcuts[t_self]:mcuts[t_self + 1]])
                    pieces_in.append(None)  # filled via layer_req below
                cat = np.concatenate(pieces_out) if pieces_out else \
                    np.zeros(0, np.uint32)
                uniq, inv = np.unique(cat, return_inverse=True)
                src_slices = np.cumsum([0] + [len(p) for p in pieces_out])
                layer_maps[n] = (src_slices, inv, uniq)
                nxt_out[n] = uniq
                # inbound requests targeted at n
                req_cat = np.concatenate(
                    [SimSparseAllreduce._req_of(layer_req, mem, plan, l, n)
                     for mem in members])
                nxt_in[n] = np.unique(
                    np.concatenate([req_cat]) if req_cat.size else req_cat)
            self.down_maps.append(layer_maps)
            self.req_piece.append(layer_req)
            self.in_at.append(nxt_in)
            cur_out = nxt_out
            # stage time: comms + merge
            tmax = 0.0
            for n in range(m):
                send_b = st_down.max_msg_bytes  # upper bound per message
                t_comm = self.fabric.stage_time(send_b, (k - 1) * self.r)
                n_merge = len(self.down_maps[-1][n][1])
                t_merge = n_merge * max(np.log2(max(k, 2)), 1.0) * self.merge_ns * 1e-9
                tmax = max(tmax, t_comm + t_merge)
            st_down.time_s = tmax
            stats.stages.append(st_down)

        self.bottom_idx = cur_out  # final summed unique idx per node
        # positions of each request piece in the *holder's* arrays, per layer
        self.ret_pos: List[List[List[np.ndarray]]] = []
        for l in range(plan.depth):
            k = plan.degrees[l]
            layer_pos: List = [None] * m
            for n in range(m):
                members = plan.group_members(n, l)
                per_member = []
                for t, mem in enumerate(members):
                    piece = self.req_piece[l][n][t]
                    holder_idx = self.in_at[l + 1][mem]
                    pos = np.searchsorted(holder_idx.astype(np.uint64),
                                          piece.astype(np.uint64))
                    per_member.append(pos)
                layer_pos[n] = per_member
            self.ret_pos.append(layer_pos)
        # bottom lookup: positions of in_at[D][n] in bottom_idx[n] (+hit mask)
        self.bottom_pos, self.bottom_hit = [], []
        for n in range(m):
            want = self.in_at[plan.depth][n].astype(np.uint64)
            have = self.bottom_idx[n].astype(np.uint64)
            pos = np.searchsorted(have, want)
            pos_c = np.clip(pos, 0, max(len(have) - 1, 0))
            hit = (len(have) > 0) and None
            hitmask = (have[pos_c] == want) if len(have) else \
                np.zeros(len(want), bool)
            self.bottom_pos.append(pos_c)
            self.bottom_hit.append(hitmask)

        stats.config_time_s = sum(s.time_s for s in stats.stages)
        self._configured = True
        self.config_stats = stats
        return stats

    @staticmethod
    def _req_of(layer_req, mem, plan, l, target):
        members = plan.group_members(mem, l)
        t = members.index(target)
        return layer_req[mem][t]

    # -- reduce (values only; indices hard-coded in maps, paper §IV-A) --------
    def reduce(self, out_values: Sequence[np.ndarray]) -> List[np.ndarray]:
        """The paper's ``reduce``: run the frozen schedule on new values,
        returning each node's requested rows (message-level reference)."""
        assert self._configured, "call config() first"
        plan, m, w = self.plan, self.m, self.w
        stats = ReduceStats()

        def vshape(n):
            return (n, w) if w > 1 else (n,)

        # coalesce user values onto sorted-unique slots
        cur: List[np.ndarray] = []
        for n in range(m):
            v = np.zeros(vshape(len(self.out_sorted[n])), np.float64)
            np.add.at(v, self.out_user_to_sorted[n],
                      np.asarray(out_values[n], np.float64))
            cur.append(v)

        # down: scatter-reduce through the layers
        for l in range(plan.depth):
            k = plan.degrees[l]
            st = StageStats(layer=l, phase="down")
            nxt: List = [None] * m
            for n in range(m):
                members = plan.group_members(n, l)
                t_self = members.index(n)
                src_slices, inv, uniq = self.down_maps[l][n]
                pieces = []
                for mem in members:
                    mcuts = np.searchsorted(
                        np.asarray(self._down_idx_cache[l][mem], np.uint64),
                        plan.edges_at(mem, l).astype(np.uint64))
                    pieces.append(cur[mem][mcuts[t_self]:mcuts[t_self + 1]])
                    if mem != n:
                        nb = (mcuts[t_self + 1] - mcuts[t_self]) * BYTES_VAL * w * self.r
                        st.num_messages += self.r
                        st.total_bytes += nb
                        st.max_msg_bytes = max(st.max_msg_bytes, nb)
                cat = np.concatenate(pieces, axis=0) if pieces else \
                    np.zeros(vshape(0), np.float64)
                summed = np.zeros(vshape(len(uniq)), np.float64)
                np.add.at(summed, inv, cat)
                nxt[n] = summed
            cur = nxt
            tmax = 0.0
            for n in range(m):
                t_comm = self.fabric.stage_time(st.max_msg_bytes, (k - 1) * self.r)
                t_merge = cur[n].shape[0] * max(np.log2(max(k, 2)), 1.0) \
                    * self.merge_ns * 1e-9
                tmax = max(tmax, t_comm + t_merge)
            st.time_s = tmax
            stats.stages.append(st)

        # bottom lookup: values for requested indices (0 where absent)
        up: List[np.ndarray] = []
        for n in range(m):
            want = self.in_at[plan.depth][n]
            v = np.zeros(vshape(len(want)), np.float64)
            if len(self.bottom_idx[n]):
                got = cur[n][self.bottom_pos[n]]
                mask = self.bottom_hit[n]
                v[mask] = got[mask]
            up.append(v)

        # up: allgather back through the same nodes (nested, paper §IV-A)
        for l in reversed(range(plan.depth)):
            k = plan.degrees[l]
            st = StageStats(layer=l, phase="up")
            nxt: List = [None] * m
            for n in range(m):
                members = plan.group_members(n, l)
                own_idx = self.in_at[l][n]
                v = np.zeros(vshape(len(own_idx)), np.float64)
                edges = plan.edges_at(n, l).astype(np.uint64)
                cuts = np.searchsorted(own_idx.astype(np.uint64), edges)
                for t, mem in enumerate(members):
                    pos = self.ret_pos[l][n][t]
                    piece_vals = up[mem][pos]
                    v[cuts[t]:cuts[t + 1]] = piece_vals
                    if mem != n:
                        nb = len(pos) * BYTES_VAL * w * self.r
                        st.num_messages += self.r
                        st.total_bytes += nb
                        st.max_msg_bytes = max(st.max_msg_bytes, nb)
                nxt[n] = v
            up = nxt
            st.time_s = self.fabric.stage_time(st.max_msg_bytes, (k - 1) * self.r)
            stats.stages.append(st)

        # back to user order
        out = []
        for n in range(m):
            out.append(np.asarray(up[n][self.in_sorted_to_user[n]]))
        stats.reduce_time_s = sum(s.time_s for s in stats.stages)
        self.reduce_stats = stats
        return out

    # cache of per-layer sorted out-idx (needed to re-slice values on reduce)
    @property
    def _down_idx_cache(self):
        if not hasattr(self, "_didx"):
            cache = [list(self.out_sorted)]
            for l in range(self.plan.depth):
                cache.append([self.down_maps[l][n][2] for n in range(self.m)])
            self._didx = cache
        return self._didx


def dense_oracle(out_indices, out_values, in_indices, perm: HashPerm,
                 space_total=None, width: int = 1):
    """Ground truth: dense sum over the hashed space, then gather."""
    all_h = [perm.fwd_np(np.asarray(i, np.uint32)) for i in out_indices]
    acc: Dict[int, np.ndarray] = {}
    for h, v in zip(all_h, out_values):
        v = np.asarray(v, np.float64)
        for j in range(len(h)):
            key = int(h[j])
            acc[key] = acc.get(key, 0) + v[j]
    outs = []
    for idx in in_indices:
        h = perm.fwd_np(np.asarray(idx, np.uint32))
        if width > 1:
            o = np.stack([np.asarray(acc.get(int(x), np.zeros(width)), np.float64)
                          for x in h]) if len(h) else np.zeros((0, width))
        else:
            o = np.array([acc.get(int(x), 0.0) for x in h], np.float64)
        outs.append(o)
    return outs
