"""Public Sparse Allreduce API — the paper's two-call interface (§III-B).

    ar = SparseAllreduce(num_nodes=64, degrees=(16, 4))       # or degrees="auto"
    ar.config(out_indices, in_indices)     # once per index pattern
    new_vals = ar.reduce(out_values)       # every iteration

Backends:
  * ``backend="sim"``     — message-level numpy reference (+ timing model,
    replication, failures).  Default; runs anywhere.
  * ``backend="device"``  — host config + jitted shard_map reduce on a JAX
    mesh (the production TPU path; works on any device count incl. forced
    host devices).

Both backends take ``replication=r`` + ``dead`` (paper §V): ``num_nodes``
logical shards are hosted r-way redundantly — on the device backend over
``r * num_nodes`` physical mesh devices laid out per
``repro.core.replication.replica_groups`` — and the reduce completes with
unchanged results for any failure set that leaves each replica group at
least one alive member, raising ``DeadLogicalNode`` otherwise.  Failure
schedules for tests/benches live in ``repro.core.faults``; cost and
completion-probability curves in ``benchmarks/bench_fault_tolerance.py``.

``degrees="auto"`` resolves through the calibrated autotuner
(:mod:`repro.core.autotune`): cached plans are read from the persistent
plan cache (``$REPRO_PLAN_CACHE`` or ``~/.cache/repro/plans``) before the
cost-model sweep runs, and on the device backend :meth:`config` both
memoizes the frozen plan in-process (a repeat config with the same index
pattern reuses the compiled reduce with **zero retraces**) and persists
the frozen routing tensors so a restarted process skips host re-planning.
See TUNING.md for the workflow, keying and invalidation rules.

The gather-all (union) device primitive used by the training framework is
exposed separately in :mod:`repro.core.allreduce`.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Set, Tuple

import numpy as np

from .netmodel import EC2_2013, Fabric
from .sparse_vec import HashPerm
from .simulator import ReduceStats, SimSparseAllreduce
from .topology import ButterflyPlan, check_wire, tune


class SparseAllreduce:
    """The paper's two-call primitive (module docstring): ``config`` once
    per index pattern, ``reduce`` every iteration, over a sim or device
    backend with optional r-way replication and autotuned degrees."""

    def __init__(self, num_nodes: int, degrees="auto", *,
                 backend: str = "sim",
                 replication: int = 1, dead: Optional[Set[int]] = None,
                 fabric: Fabric = EC2_2013, seed: int = 0,
                 value_width: int = 1, mesh=None,
                 expected_nnz: float = 1e5, index_range: float = 1e6,
                 merge: str = "sort", wire: str = "raw",
                 plan_cache=True, retune: bool = False):
        """``merge`` ("sort" | "fused" | "banded") picks the
        per-butterfly-layer merge used by the dynamic-index union path
        (:meth:`union_reduce`): concatenate-and-resort, the fused Pallas
        rank-merge pipeline (``repro.kernels.ops.merge_sorted_runs``), or
        its band-limited variant that exploits stream sortedness to cut
        the per-layer tile work to near-linear.  The planned ``reduce``
        path freezes routing at ``config`` time and has no merge stage, so
        the knob does not affect it.

        ``wire`` ("raw" | "delta" | "delta+bf16" | "delta+int8ef") picks
        the on-wire payload encoding of the union path (see
        ``repro.kernels.wirecodec``): raw ships uint32 indices + f32
        values; ``delta`` bit-packs the sorted index stream at each
        stage's residual width (bit-identical results); the lossy modes
        additionally quantize values to bf16 / per-row-scaled int8.  The
        knob re-ranks ``degrees="auto"`` under the encoded byte model and
        keys the plan cache per wire format.  The planned ``reduce`` path
        ships pre-routed values only (no index stream), so raw/delta are
        equivalent no-ops there and the lossy modes are rejected; the sim
        backend models bytes, not value precision, and rejects lossy modes
        at construction.

        ``plan_cache`` controls the autotuner's persistent cache
        (``repro.core.autotune``): ``True`` (default) uses the process
        cache at ``$REPRO_PLAN_CACHE`` / ``~/.cache/repro/plans``, a
        ``PlanCache`` instance pins a specific root, ``False`` disables
        persistence (``degrees="auto"`` still tunes, ``config`` still
        memoizes in-process).  ``retune=True`` bypasses cached degree
        reads and overwrites them (the ``--retune`` escape hatch)."""
        from .allreduce import MERGE_MODES
        if merge not in MERGE_MODES:
            raise ValueError(
                f"merge must be one of {MERGE_MODES}, got {merge!r}")
        from .autotune import PlanCache, default_cache
        if plan_cache is True:
            self.plan_cache = default_cache()
        elif plan_cache is False or plan_cache is None:
            self.plan_cache = None
        elif isinstance(plan_cache, PlanCache):
            self.plan_cache = plan_cache
        else:
            raise ValueError(
                f"plan_cache must be True, False or a PlanCache (to pin a "
                f"root, pass PlanCache(root=...)), got {plan_cache!r}")
        self.merge = merge
        self.wire = check_wire(wire)
        if backend == "sim" and self.wire in ("delta+bf16", "delta+int8ef"):
            raise NotImplementedError(
                f"backend='sim' models message bytes, not value precision; "
                f"wire={self.wire!r} has no sim semantics (use 'raw' or "
                f"'delta', or backend='device')")
        self.num_nodes = num_nodes
        self.degrees_source = "explicit"
        if degrees == "auto":
            from .autotune import resolve_degrees
            if self.plan_cache is not None:
                degrees, self.degrees_source = resolve_degrees(
                    num_nodes, n0=expected_nnz, total_range=index_range,
                    fabric=fabric, merge=merge, replication=replication,
                    width=value_width, cache=self.plan_cache, retune=retune,
                    wire=self.wire)
            else:
                plan = tune(num_nodes, n0=expected_nnz,
                            total_range=index_range, fabric=fabric,
                            wire=self.wire, value_width=value_width)
                degrees, self.degrees_source = plan.degrees, "tuned"
        self.plan = ButterflyPlan(num_nodes, tuple(degrees))
        self.backend = backend
        self.perm = HashPerm.make(seed)
        self.width = value_width
        self.fabric = fabric
        self.replication = replication
        self.dead = dead
        self.mesh = mesh
        self._mesh_used = None       # mesh bound at config (device backend)
        self._sim: Optional[SimSparseAllreduce] = None
        self._planned = None
        self._reduce_fn = None
        self._u_cap = None
        self._in_lens = None
        self._union_cache = {}
        # union-path plan resolution counters (serving tier / benches):
        # a "hit" reuses a compiled union pipeline from _union_cache, a
        # "miss" plans + traces a new one.  Cumulative over the instance
        # lifetime (reconfig_dead clears the cache, so calls after it
        # miss again until re-trace).
        self.union_plan_stats = {"hits": 0, "misses": 0}
        self._staging = None
        self._stage_rows = self._stage_cols = None
        self._first_alive = None
        # how the last config()/reconfig_dead() was satisfied on the device
        # backend: None (no config yet / sim) | "fresh" | "memo" | "disk"
        # | "repair" (dead-set swap without host replanning)
        self.config_cache = None

    @property
    def num_physical(self) -> int:
        """Physical device count: ``num_nodes`` logical shards × r."""
        return self.num_nodes * self.replication

    # ------------------------------------------------------------------
    def config(self, out_indices: Sequence[np.ndarray],
               in_indices: Sequence[np.ndarray]) -> ReduceStats:
        """The paper's ``config`` call — run once per index pattern.

        ``out_indices`` / ``in_indices``: one uint32 array per *logical*
        node (sorted-unique not required for out; in defines the order of
        the per-node result rows).  Freezes all routing: on ``sim`` it
        builds the message-level schedule; on ``device`` it plans the
        static gather/scatter tensors and jit-compiles the reduce
        (``plan_sparse_allreduce`` + ``make_reduce_fn``), binding the mesh
        (``self.mesh`` or a fresh one over all devices).  Returns modeled
        ``ReduceStats`` from a simulator shadow config on both backends.
        Amortization contract: every subsequent :meth:`reduce` (any number
        of iterations) reuses this plan; re-calling ``config`` re-plans.

        Device configs are additionally cached (``repro.core.autotune``):
        an identical (mesh, degrees, replication, dead, width, index
        pattern) config in the same process reuses the frozen plan AND its
        compiled reduce fn — zero host re-planning, zero retraces
        (``self.config_cache == "memo"``); across a process restart the
        frozen routing tensors + modeled stats are reloaded from the
        persistent plan cache, skipping the host planning pass
        (``"disk"``).  Set ``plan_cache=False`` at construction to opt
        out of the disk tier.
        """
        self._in_lens = [len(i) for i in in_indices]
        self._out_lens = [len(o) for o in out_indices]
        self._staging = None                  # re-config invalidates staging
        if self.backend == "sim":
            self._sim = SimSparseAllreduce(
                self.plan, replication=self.replication, dead=self.dead,
                perm=self.perm, fabric=self.fabric, value_width=self.width)
            return self._sim.config(out_indices, in_indices)
        elif self.backend == "device":
            if self.wire in ("delta+bf16", "delta+int8ef"):
                raise NotImplementedError(
                    f"the planned reduce path ships pre-routed values only "
                    f"(no index stream), and quantized planned payloads are "
                    f"not implemented; wire={self.wire!r} is only supported "
                    f"on the union path (union_reduce / train sync)")
            from .replication import first_alive_replicas
            r, m_phys = self.replication, self.num_physical
            # Validates the failure set before touching the mesh: raises
            # DeadLogicalNode when a whole replica group is dead, exactly
            # like SimSparseAllreduce (and with r=1, on any failure).
            self._first_alive = first_alive_replicas(m_phys, r, self.dead)
            import jax

            from . import autotune
            from .allreduce import make_device_plan
            from .planned import plan_sparse_allreduce
            mesh = self.mesh
            if mesh is None:
                n = len(jax.devices())
                if n % m_phys:
                    raise ValueError(
                        f"{n} devices for {m_phys} physical nodes "
                        f"({self.num_nodes} logical x r={r})")
                mesh = jax.make_mesh((m_phys,), ("nodes",))
            axis = mesh.axis_names[0]
            self._mesh_used = mesh
            fp = autotune.planned_fingerprint(
                mesh, self.plan.degrees, r, self.dead, self.width,
                self.perm, out_indices, in_indices, fabric=self.fabric)
            memo = autotune.memo_lookup(fp)
            if memo is not None:
                # zero-retrace hit: frozen plan AND compiled reduce reused
                self._planned, self._reduce_fn, stats = memo
                self._u_cap = self._planned.user_scatter.shape[1]
                self.config_cache = "memo"
                return stats
            planned = stats = None
            pkey = autotune.planned_cache_key(fp)
            if self.plan_cache is not None:
                hit = self.plan_cache.load(pkey)
                if hit is not None:
                    meta, arrays = hit
                    try:
                        planned = autotune.planned_from_artifact(
                            arrays, meta, {axis: self.plan.degrees})
                        stats = autotune.stats_from_meta(meta["stats"])
                        self.config_cache = "disk"
                    except Exception:
                        planned = stats = None   # corrupt entry -> replan
            if planned is None:
                dplan = make_device_plan(
                    [(axis, m_phys)], {axis: self.plan.degrees},
                    in_capacity=max(self._out_lens),
                    out_capacity=sum(self._out_lens), replication=r)
                planned = plan_sparse_allreduce(
                    dplan, out_indices, in_indices, perm=self.perm,
                    width=self.width, dead=self.dead)
                # stats come from a simulator shadow-config (same routing,
                # r-fold message accounting when replicated)
                shadow = SimSparseAllreduce(self.plan, replication=r,
                                            dead=self.dead, perm=self.perm,
                                            fabric=self.fabric,
                                            value_width=self.width)
                stats = shadow.config(out_indices, in_indices)
                self.config_cache = "fresh"
                if self.plan_cache is not None:
                    arrays, meta = autotune.planned_to_artifact(planned)
                    meta["stats"] = autotune.stats_to_meta(stats)
                    meta["staging"] = {
                        "u_cap": planned.u_cap, "uin_cap": planned.uin_cap,
                        "out_lens": list(self._out_lens),
                        "in_lens": list(self._in_lens),
                        "num_physical": m_phys,
                        "degrees": list(self.plan.degrees)}
                    self.plan_cache.store(pkey, meta, arrays)
            self._planned = planned
            self._reduce_fn = planned.make_reduce_fn(mesh)
            self._u_cap = planned.user_scatter.shape[1]
            autotune.memo_store(fp, (planned, self._reduce_fn, stats))
            return stats
        raise ValueError(f"unknown backend {self.backend!r}")

    # ------------------------------------------------------------------
    def reconfig_dead(self, dead: Optional[Set[int]]) -> None:
        """Incremental repair (device backend): swap the dead set without
        host re-planning.

        The frozen routing is dead-set-invariant — only the contribution
        weights and the first-alive read-back rows change — so this is
        ``PlannedSparseAllreduce.with_dead`` + one retrace of the reduce
        body, orders of magnitude cheaper than a fresh :meth:`config`
        (``benchmarks/bench_soak.py`` measures both).  Repaired plans are
        cached per dead set, so flip-flopping between failure sets (a
        supervisor's retry loop) retraces each at most once.

        Raises ``DeadLogicalNode`` when ``dead`` kills a whole replica
        group, *before* any state changes — the instance stays usable with
        its previous dead set, and the caller (``repro.resilience``) moves
        on to replan-over-survivors.  Afterwards ``config_cache`` reads
        ``"repair"``.
        """
        if self.backend != "device":
            raise ValueError("reconfig_dead() requires backend='device'")
        if self._planned is None:
            raise RuntimeError("call config() before reconfig_dead()")
        from .replication import first_alive_replicas
        # Validation first: a lost replica group must leave `self` intact.
        first_alive = first_alive_replicas(self.num_physical,
                                           self.replication, dead)
        key = frozenset(dead or ())
        cache = getattr(self, "_repair_cache", None)
        if cache is None:
            cache = self._repair_cache = {}
        hit = cache.get(key)
        if hit is None:
            planned = self._planned.with_dead(dead)
            hit = (planned, planned.make_reduce_fn(self._mesh_used))
            cache[key] = hit
        self._planned, self._reduce_fn = hit
        self._first_alive = first_alive
        self.dead = set(key) or None
        self._union_cache = {}       # union fns bake the dead set too
        self.config_cache = "repair"

    # ------------------------------------------------------------------
    def reduce(self, out_values: Sequence[np.ndarray]) -> List[np.ndarray]:
        """``out_values``: one array per *logical* node; with replication
        the values are staged onto every replica (dead / non-first replicas
        are zero-weighted on device) and each logical result is read back
        from its first alive replica."""
        if self.backend == "sim":
            return self._sim.reduce(out_values)
        import jax.numpy as jnp
        r, m_phys = self.replication, self.num_physical
        if self._staging is None:
            # Reusable host staging buffer + flat scatter coordinates
            # (precomputable: config froze the per-node lengths).  Repeated
            # same-shape reduces then pay one vectorized scatter instead of
            # a fresh np.zeros + per-node copy loop per call.
            vshape = (m_phys, self._u_cap) + \
                ((self.width,) if self.width > 1 else ())
            self._staging = np.zeros(vshape, np.float32)
            phys_lens = list(self._out_lens) * r
            self._stage_rows = np.repeat(np.arange(m_phys),
                                         np.asarray(phys_lens))
            self._stage_cols = np.concatenate(
                [np.arange(l, dtype=np.int64) for l in phys_lens])
        for n, v in enumerate(out_values):
            if len(v) != self._out_lens[n]:
                raise ValueError(
                    f"reduce: node {n} passed {len(v)} values, config "
                    f"declared {self._out_lens[n]}")
        flat = np.concatenate([np.asarray(v, np.float32).reshape(
            (-1,) + ((self.width,) if self.width > 1 else ()))
            for v in out_values], axis=0)
        if r > 1:
            flat = np.concatenate([flat] * r, axis=0)
        # cells beyond each node's out length stay zero across calls, so no
        # per-call clearing is needed either.
        self._staging[self._stage_rows, self._stage_cols] = flat
        out = np.asarray(self._reduce_fn(jnp.asarray(self._staging)))
        return [out[self._first_alive[n], : self._in_lens[n]]
                for n in range(self.num_nodes)]

    # ------------------------------------------------------------------
    def union_reduce(self, idx, val, out_capacity: int,
                     use_kernel: bool = False):
        """Gather-all union sum with dynamic indices (the paper's mini-batch
        mode) on a device mesh, honouring the ``merge`` and ``wire`` knobs
        (with ``wire="delta"`` results are bit-identical to ``"raw"``; the
        lossy modes trade bounded value error for wire bytes).

        idx: uint32 [num_nodes, C] *hashed, sorted*, SENTINEL-padded per-node
        indices; val: [num_nodes, C] or [num_nodes, C, W] — one chunk per
        *logical* node.  With ``replication=r`` the chunks are mirrored onto
        ``r * num_nodes`` physical mesh devices, ``contribution_weights``
        (for this instance's ``dead`` set) are applied inside shard_map, and
        the per-logical-node results are read back from each shard's first
        alive replica; raises ``DeadLogicalNode`` when a replica group is
        lost.  Returns (idx [num_nodes, out_capacity], val,
        overflow [num_nodes]) — every node gets the full union sum.
        Requires a mesh of ``num_nodes * replication`` devices.  The plan
        and compiled pipeline are cached per (shape, out_capacity,
        use_kernel, dead), so repeated same-shape calls pay tracing once.
        """
        import jax
        import jax.numpy as jnp

        from .allreduce import make_device_plan, run_union_allreduce
        from .replication import contribution_weights, first_alive_replicas
        r, m_phys = self.replication, self.num_physical
        if r != 1 or self.dead:
            contribution_weights(m_phys, r, self.dead)  # DeadLogicalNode
        idx = jnp.asarray(idx)
        val = jnp.asarray(val)
        if idx.shape[0] != self.num_nodes:
            raise ValueError(
                f"union_reduce: expected {self.num_nodes} logical chunks, "
                f"got {idx.shape[0]}")
        if r > 1:
            idx = jnp.tile(idx, (r,) + (1,) * (idx.ndim - 1))
            val = jnp.tile(val, (r,) + (1,) * (val.ndim - 1))
        key = (idx.shape, val.shape, val.dtype, out_capacity, use_kernel,
               frozenset(self.dead or ()), self.wire)
        fn = self._union_cache.get(key)
        if fn is not None:
            self.union_plan_stats["hits"] += 1
        else:
            self.union_plan_stats["misses"] += 1
            mesh = self.mesh
            if mesh is None:
                mesh = jax.make_mesh((m_phys,), ("nodes",))
            axis = mesh.axis_names[0]
            dplan = make_device_plan(
                [(axis, m_phys)], {axis: self.plan.degrees},
                in_capacity=idx.shape[1], out_capacity=out_capacity,
                replication=r)
            fn = jax.jit(lambda i, v: run_union_allreduce(
                mesh, dplan, i, v, use_kernel=use_kernel, merge=self.merge,
                dead=self.dead, wire=self.wire))
            self._union_cache[key] = fn
        oi, ov, ovf = fn(idx, val)
        if r > 1:
            fa = first_alive_replicas(m_phys, r, self.dead)
            oi, ov, ovf = oi[fa], ov[fa], ovf[fa]
        return oi, ov, ovf

    # ------------------------------------------------------------------
    # Plan-reuse hooks (device backend).  :meth:`reduce` pays one host
    # staging + one device dispatch per call; iterative workloads that can
    # keep their state on device should instead compose the frozen plan
    # into their own jitted loop via these hooks — ``repro.graph.engine``
    # does exactly that (k rounds, one dispatch).
    # ------------------------------------------------------------------

    def planned_parts(self) -> Tuple["object", "object"]:
        """``(PlannedSparseAllreduce, mesh)`` bound at :meth:`config` time.

        Device backend only, after ``config``.  ``planned.reduce_on_device``
        is the shard_map body (per-device ``[u_cap(,W)] -> [uin_cap(,W)]``),
        ``planned.device_args()`` the iteration-invariant routing tensors —
        everything needed to embed the reduce inside a caller-owned
        shard_map / ``lax.scan`` without re-planning or re-tracing.
        """
        if self.backend != "device":
            raise ValueError("planned_parts() requires backend='device'")
        if self._planned is None:
            raise RuntimeError("call config() before planned_parts()")
        return self._planned, self._mesh_used

    @property
    def reduce_fn(self):
        """The raw jitted reduce callable (device backend, after config):
        ``[num_physical, u_cap(,W)] jnp array -> [num_physical, uin_cap(,W)]``.
        This is what :meth:`reduce` invokes after host-side staging; callers
        holding device-resident staged values can call it directly and skip
        the numpy round-trip."""
        if self._reduce_fn is None:
            raise RuntimeError(
                "reduce_fn requires backend='device' and a prior config()")
        return self._reduce_fn

    def staging_metadata(self) -> dict:
        """Static staging layout frozen by :meth:`config` (device backend):
        ``u_cap`` / ``uin_cap`` (per-device value capacities),
        ``out_lens`` / ``in_lens`` (per-logical-node valid lengths inside
        those capacities), ``first_alive`` (physical replica each logical
        result is read from) and ``num_physical``.  Everything a caller
        needs to build ``reduce_fn`` inputs / slice its outputs without
        private attribute access."""
        if self._planned is None:
            raise RuntimeError("call config() before staging_metadata()")
        return {
            "u_cap": self._planned.u_cap,
            "uin_cap": self._planned.uin_cap,
            "out_lens": list(self._out_lens),
            "in_lens": list(self._in_lens),
            "first_alive": list(self._first_alive),
            "num_physical": self.num_physical,
        }

    @property
    def stats(self) -> Optional[ReduceStats]:
        """Message-level :class:`ReduceStats` of the last :meth:`reduce`
        (sim backend only; the device backend returns modeled stats from
        :meth:`config`'s shadow sim instead)."""
        if self.backend == "sim" and self._sim is not None:
            return getattr(self._sim, "reduce_stats", None)
        return None
