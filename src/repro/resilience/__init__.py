"""Supervision layer: fatal faults -> degraded-but-correct continuation.

Three pieces (see ARCHITECTURE.md "Resilience"):

  * :mod:`repro.resilience.events` — fault classification
    (replica-absorbed / group-lost / quorum-lost) shared by every
    detection path;
  * :mod:`repro.resilience.supervisor` — :class:`ResilientAllreduce`,
    the supervised two-call reduce with retry/backoff and
    replan-over-survivors;
  * :mod:`repro.resilience.engine` — :class:`SupervisedEngineLoop`,
    blocked+checkpointed ``GraphEngine`` runs with device remapping.

The exact-resume soak harness driving all of it end to end is
``repro.launch.soak``.
"""
from .events import (FaultEvent, QuorumLost, classify,  # noqa: F401
                     GROUP_LOST, NO_FAULT, QUORUM_LOST, REPLICA_ABSORBED)
from .supervisor import (DegradedPolicy, ReduceOutcome,  # noqa: F401
                         ResilientAllreduce, retry_until_alive)
from .engine import SupervisedEngineLoop  # noqa: F401
