"""Supervised sparse allreduce: faults in, degraded-but-correct results out.

:class:`ResilientAllreduce` wraps the device-backend
:class:`repro.core.api.SparseAllreduce` with the supervision loop the
paper's target systems (PowerGraph, Hadoop) run under churn:

  1. **Detect & classify** — before every dispatch the supervisor reads
     the active dead set (a ``probe`` callable, a
     :class:`repro.core.faults.FailureSchedule`, or a static set) and
     classifies it (:func:`repro.resilience.events.classify`); a
     ``DeadLogicalNode`` escaping the wrapped reduce is caught and
     re-classified the same way, so both detection paths agree.
  2. **Retry with bounded exponential backoff** — a *group-lost* event may
     be transient (network partition, restarting host), so the supervisor
     re-probes up to ``max_retries`` times, sleeping
     ``backoff_s * backoff_mult**attempt`` between probes
     (:func:`retry_until_alive`, host-testable with an injected clock).
  3. **Degrade per policy** — if the group stays lost:
     ``mode="shrink"`` replans over the surviving logical shards (keeping
     replication when enough devices survive), ``mode="drop_replication"``
     shrinks to r=1, ``mode="fail"`` re-raises.  Survivor results are
     bit-identical to a fresh fault-free reduce over the same surviving
     set — verified exhaustively in ``tests/test_resilience.py``.

Replans are cheap by construction: *replica-absorbed* events are a
weights-only repair (``SparseAllreduce.reconfig_dead`` — no host
replanning), and survivor replans key into the autotuner's plan cache and
in-process memo via ``shrunk_from`` (:mod:`repro.core.autotune`), so a
repeat shrink to the same survivor set reuses both the frozen plan and
the compiled reduce.  ``benchmarks/bench_soak.py`` measures all three
recovery tiers.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.api import SparseAllreduce
from repro.core.faults import FailureSchedule
from repro.core.netmodel import EC2_2013, Fabric
from repro.core.replication import DeadLogicalNode
from .events import (GROUP_LOST, NO_FAULT, QUORUM_LOST, REPLICA_ABSORBED,
                     FaultEvent, QuorumLost, classify)

#: Degraded-mode policies, in decreasing willingness to continue.
POLICY_MODES = ("shrink", "drop_replication", "fail")


@dataclasses.dataclass(frozen=True)
class DegradedPolicy:
    """What the supervisor does when a replica group stays dead.

    ``mode``: ``"shrink"`` (replan over survivors, keep replication when
    the surviving device count allows), ``"drop_replication"`` (replan
    over survivors at r=1 — maximum surviving capacity, no further fault
    tolerance), ``"fail"`` (re-raise ``DeadLogicalNode`` after retries —
    for jobs where partial results are worthless).  Retries and quorum
    apply to every mode.
    """

    mode: str = "shrink"
    max_retries: int = 3
    backoff_s: float = 0.05
    backoff_mult: float = 2.0
    quorum_frac: float = 0.5

    def __post_init__(self):
        if self.mode not in POLICY_MODES:
            raise ValueError(
                f"mode must be one of {POLICY_MODES}, got {self.mode!r}")
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got "
                             f"{self.max_retries}")
        if self.backoff_s < 0:
            raise ValueError(f"backoff_s must be >= 0, got {self.backoff_s}")
        if self.backoff_mult < 1.0:
            raise ValueError(
                f"backoff_mult must be >= 1, got {self.backoff_mult}")
        if not 0.0 < self.quorum_frac <= 1.0:
            raise ValueError(
                f"quorum_frac must be in (0, 1], got {self.quorum_frac}")


def retry_until_alive(dead_at: Callable[[int], Optional[Set[int]]],
                      policy: DegradedPolicy, m_physical: int,
                      replication: int, *,
                      step: int = 0,
                      sleep: Callable[[float], None] = time.sleep
                      ) -> Tuple[FaultEvent, List[FaultEvent]]:
    """Probe ``dead_at(attempt)`` until the fault clears or retries run out.

    Sleeps ``backoff_s * backoff_mult**attempt`` between probes (injected
    ``sleep`` makes this host-testable without wall-clock waits).  Returns
    ``(final_event, all_events)`` — the final event is the first
    non-*group-lost* classification, or the last *group-lost* one after
    ``max_retries`` extra probes; the caller applies the policy mode.
    """
    events: List[FaultEvent] = []
    for attempt in range(policy.max_retries + 1):
        ev = classify(m_physical, replication, dead_at(attempt),
                      quorum_frac=policy.quorum_frac,
                      step=step, attempt=attempt)
        events.append(ev)
        if ev.klass != GROUP_LOST:
            return ev, events
        if attempt < policy.max_retries:
            sleep(policy.backoff_s * policy.backoff_mult ** attempt)
    return events[-1], events


@dataclasses.dataclass
class ReduceOutcome:
    """One supervised reduce: per-*original*-logical-shard results plus
    provenance.  ``values[i]`` exists for every shard that survived
    (all of them when ``degraded`` is False); lost shards are absent —
    their contributions died with their replica group.  ``shrink`` is the
    :attr:`ResilientAllreduce.last_shrink` record when a replan happened.
    """

    values: Dict[int, np.ndarray]
    event: FaultEvent
    degraded: bool
    attempts: int
    shrink: Optional[dict] = None


class ResilientAllreduce:
    """Supervised two-call sparse allreduce (module docstring).

    Same ``config``/``reduce`` shape as :class:`SparseAllreduce`
    (device backend), plus a fault source: a ``schedule``
    (:class:`FailureSchedule`, consulted at ``dead_at(step)``), a
    ``probe`` callable ``(step, attempt) -> dead set`` (overrides the
    schedule — retries re-probe, so transient faults can heal), or a
    static ``dead`` set.  ``reduce``/``union_reduce`` return
    :class:`ReduceOutcome` — results keyed by original logical shard id.
    """

    def __init__(self, num_nodes: int, degrees="auto", *,
                 replication: int = 1,
                 schedule: Optional[FailureSchedule] = None,
                 probe: Optional[Callable] = None,
                 dead: Optional[Set[int]] = None,
                 policy: Optional[DegradedPolicy] = None,
                 sleep: Callable[[float], None] = time.sleep,
                 mesh=None, seed: int = 0, value_width: int = 1,
                 merge: str = "sort", fabric: Fabric = EC2_2013,
                 expected_nnz: float = 1e5, index_range: float = 1e6,
                 plan_cache=True, retune: bool = False):
        import jax
        self.policy = policy or DegradedPolicy()
        self.schedule = schedule
        self.probe = probe
        self.static_dead = set(dead or ())
        self.sleep = sleep
        self.num_nodes = num_nodes
        self.replication = replication
        self.seed = seed
        self.merge = merge
        self.fabric = fabric
        self.expected_nnz = expected_nnz
        self.index_range = index_range
        m_phys = num_nodes * replication
        if mesh is None:
            devs = jax.devices()
            if len(devs) < m_phys:
                raise ValueError(
                    f"{len(devs)} devices < {m_phys} physical nodes")
            mesh = jax.sharding.Mesh(np.array(devs[:m_phys]), ("nodes",))
        self.mesh = mesh
        # The base instance is always fault-free at config time; dead sets
        # are applied per-reduce via reconfig_dead (incremental repair).
        self.base = SparseAllreduce(
            num_nodes, degrees, backend="device", replication=replication,
            dead=None, fabric=fabric, seed=seed, value_width=value_width,
            mesh=mesh, expected_nnz=expected_nnz, index_range=index_range,
            merge=merge, plan_cache=plan_cache, retune=retune)
        self._out_indices = self._in_indices = None
        self._shrunk: Dict[Tuple[Tuple[int, ...], int], SparseAllreduce] = {}
        self.last_shrink: Optional[dict] = None
        self.events: List[FaultEvent] = []
        self.stats = {"reduces": 0, "absorbed": 0, "repairs": 0,
                      "retries": 0, "shrinks": 0, "shrink_reuses": 0,
                      "quorum_lost": 0}

    @property
    def num_physical(self) -> int:
        """Physical device count of the un-degraded fleet."""
        return self.num_nodes * self.replication

    # ------------------------------------------------------------------
    def config(self, out_indices: Sequence[np.ndarray],
               in_indices: Sequence[np.ndarray]):
        """The paper's ``config``: freeze routing for the fault-free fleet
        and keep the logical index lists for survivor replans."""
        self._out_indices = [np.asarray(o, np.uint32) for o in out_indices]
        self._in_indices = [np.asarray(i, np.uint32) for i in in_indices]
        return self.base.config(self._out_indices, self._in_indices)

    # ------------------------------------------------------------------
    def _dead_at(self, step: int, attempt: int) -> Set[int]:
        if self.probe is not None:
            return set(self.probe(step, attempt) or ())
        if self.schedule is not None:
            return set(self.schedule.dead_at(step))
        return set(self.static_dead)

    def _supervise(self, step: int) -> FaultEvent:
        """Run detection + retry/backoff; raise :class:`QuorumLost` or
        (mode="fail") ``DeadLogicalNode`` on unrecoverable events."""
        ev, evs = retry_until_alive(
            lambda a: self._dead_at(step, a), self.policy,
            self.num_physical, self.replication, step=step,
            sleep=self.sleep)
        self.events.extend(evs)
        self.stats["retries"] += len(evs) - 1
        if ev.klass == QUORUM_LOST:
            self.stats["quorum_lost"] += 1
            raise QuorumLost(
                f"step {step}: only {len(ev.survivors)} of "
                f"{self.num_nodes} logical shards survive "
                f"(quorum_frac={self.policy.quorum_frac}, "
                f"dead={sorted(ev.dead)})")
        if ev.klass == GROUP_LOST and self.policy.mode == "fail":
            raise DeadLogicalNode(
                f"step {step}: replica groups {list(ev.lost)} lost after "
                f"{self.policy.max_retries} retries and policy is "
                f"mode='fail' (dead={sorted(ev.dead)})")
        return ev

    # ------------------------------------------------------------------
    def _shrink_for(self, ev: FaultEvent) -> Tuple[SparseAllreduce,
                                                   Tuple[int, ...]]:
        """The survivor instance for ``ev`` (cached per survivor set)."""
        import jax
        survivors = ev.survivors
        m2 = len(survivors)
        alive = [i for i in range(self.num_physical) if i not in ev.dead]
        if self.policy.mode == "drop_replication":
            r2 = 1
        else:
            r2 = self.replication if m2 * self.replication <= len(alive) \
                else 1
        key = (survivors, r2)
        hit = self._shrunk.get(key)
        if hit is not None:
            self.stats["shrink_reuses"] += 1
            self.last_shrink = hit[1]
            return hit[0], survivors
        degrees, source = self._survivor_degrees(m2, r2)
        pool = list(self.mesh.devices.flat)
        mesh2 = jax.sharding.Mesh(
            np.array([pool[i] for i in alive[: m2 * r2]]), ("nodes",))
        ar2 = SparseAllreduce(
            m2, degrees, backend="device", replication=r2, dead=None,
            fabric=self.fabric, seed=self.seed, value_width=self.base.width,
            mesh=mesh2, expected_nnz=self.expected_nnz,
            index_range=self.index_range, merge=self.merge,
            plan_cache=self.base.plan_cache or False)
        if self._out_indices is not None:
            ar2.config([self._out_indices[i] for i in survivors],
                       [self._in_indices[i] for i in survivors])
        record = {"survivors": survivors, "degrees": tuple(degrees),
                  "replication": r2, "degrees_source": source,
                  "config_cache": ar2.config_cache}
        self._shrunk[key] = (ar2, record)
        self.last_shrink = record
        self.stats["shrinks"] += 1
        return ar2, survivors

    def _survivor_degrees(self, m2: int, r2: int):
        if m2 == 1:
            return (), "trivial"
        if self.base.degrees_source == "explicit" or \
                self.base.plan_cache is None:
            from repro.core.topology import tune
            return tune(m2, n0=self.expected_nnz,
                        total_range=self.index_range,
                        fabric=self.fabric).degrees, "tuned"
        from repro.core.autotune import resolve_degrees
        return resolve_degrees(
            m2, n0=self.expected_nnz, total_range=self.index_range,
            fabric=self.fabric, merge=self.merge, replication=r2,
            width=self.base.width, cache=self.base.plan_cache,
            shrunk_from=self.num_nodes)

    # ------------------------------------------------------------------
    def reduce(self, out_values: Sequence[np.ndarray],
               step: int = 0) -> ReduceOutcome:
        """Supervised planned reduce at ``step`` (module docstring)."""
        self.stats["reduces"] += 1
        ev = self._supervise(step)
        attempts = ev.attempt
        if ev.klass in (NO_FAULT, REPLICA_ABSORBED):
            try:
                if set(ev.dead) != set(self.base.dead or ()):
                    self.base.reconfig_dead(set(ev.dead) or None)
                    self.stats["repairs"] += 1
                if ev.klass == REPLICA_ABSORBED:
                    self.stats["absorbed"] += 1
                vals = self.base.reduce(out_values)
                return ReduceOutcome(
                    values=dict(enumerate(vals)), event=ev,
                    degraded=False, attempts=attempts)
            except DeadLogicalNode:
                # Fault raced past the probe: fall through to degraded.
                ev = dataclasses.replace(ev, klass=GROUP_LOST)
                if self.policy.mode == "fail":
                    raise
        ar2, survivors = self._shrink_for(ev)
        vals2 = ar2.reduce([out_values[i] for i in survivors])
        return ReduceOutcome(
            values={sid: vals2[k] for k, sid in enumerate(survivors)},
            event=ev, degraded=True, attempts=attempts,
            shrink=self.last_shrink)

    # ------------------------------------------------------------------
    def union_reduce(self, idx, val, out_capacity: int, step: int = 0,
                     use_kernel: bool = False) -> ReduceOutcome:
        """Supervised dynamic-index union reduce at ``step``.

        ``outcome.values[i]`` is the ``(idx, val, overflow)`` triple for
        surviving logical node ``i`` (full fleet when not degraded).
        """
        self.stats["reduces"] += 1
        ev = self._supervise(step)
        attempts = ev.attempt
        if ev.klass in (NO_FAULT, REPLICA_ABSORBED):
            try:
                if set(ev.dead) != set(self.base.dead or ()):
                    # union fns key (and bake) the dead set themselves;
                    # no planned-path repair needed when un-configured.
                    if self.base._planned is not None:
                        self.base.reconfig_dead(set(ev.dead) or None)
                    else:
                        self.base.dead = set(ev.dead) or None
                    self.stats["repairs"] += 1
                if ev.klass == REPLICA_ABSORBED:
                    self.stats["absorbed"] += 1
                oi, ov, ovf = self.base.union_reduce(
                    idx, val, out_capacity, use_kernel=use_kernel)
                values = {i: (np.asarray(oi[i]), np.asarray(ov[i]),
                              np.asarray(ovf[i]))
                          for i in range(self.num_nodes)}
                return ReduceOutcome(values=values, event=ev,
                                     degraded=False, attempts=attempts)
            except DeadLogicalNode:
                ev = dataclasses.replace(ev, klass=GROUP_LOST)
                if self.policy.mode == "fail":
                    raise
        ar2, survivors = self._shrink_for(ev)
        idx = np.asarray(idx)
        val = np.asarray(val)
        oi, ov, ovf = ar2.union_reduce(
            idx[list(survivors)], val[list(survivors)], out_capacity,
            use_kernel=use_kernel)
        values = {sid: (np.asarray(oi[k]), np.asarray(ov[k]),
                        np.asarray(ovf[k]))
                  for k, sid in enumerate(survivors)}
        return ReduceOutcome(values=values, event=ev, degraded=True,
                             attempts=attempts, shrink=self.last_shrink)
