"""Supervised graph-engine runs: faults + checkpoints around ``GraphEngine``.

:class:`SupervisedEngineLoop` chops an iterative run (PageRank / HADI /
spectral) into blocks, and between blocks does the three supervisor moves:

  1. **Consult the fault schedule** over a device *pool* larger than the
     engine's mesh (the spare-capacity model: an M-partition job on an
     N-device fleet, N >= M).  Dead pool devices that host no engine
     partition are *replica-absorbed*-style no-ops.
  2. **Remap on device loss** — when an engine device dies but >= M pool
     devices survive, :meth:`repro.graph.engine.GraphEngine.remesh`
     rebinds the identical program to the first M alive devices.  The
     partition, resolved degrees, and seed are unchanged, so the continued
     trajectory is **bit-identical** to an uninterrupted run — the
     engine-side analogue of the paper's §V "any replica can stand in"
     guarantee, with spare devices playing the replicas.
  3. **Checkpoint + exact resume** — after every block the state pytree is
     saved through the atomic :func:`repro.checkpoint.store.save`;
     :meth:`run` accepts ``start_round`` to continue a reloaded state.
     Blocks are the ``lax.scan`` unit, so a resumed run re-executes the
     same block structure and reproduces the baseline trajectory exactly
     (asserted by ``tests/test_resilience.py`` and the soak harness).

Without spare capacity the loop degrades per ``repartition`` (a caller
callback building a smaller job) or fails fast with :class:`QuorumLost`.
"""
from __future__ import annotations

from typing import Any, Callable, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.faults import FailureSchedule
from repro.core.netmodel import EC2_2013, Fabric
from repro.graph.engine import EngineApp, GraphEngine
from .events import (GROUP_LOST, REPLICA_ABSORBED, FaultEvent, QuorumLost)


class SupervisedEngineLoop:
    """Blocked, supervised, checkpointed ``GraphEngine`` run (module
    docstring).

    ``pool``: the physical device fleet (default ``jax.devices()``); the
    engine runs on the first ``M = len(out_sets)`` of it and remaps within
    it on failures.  ``schedule.dead_at(round)`` (gated by ``fault_at``)
    gives the dead *pool positions* per round.  ``ckpt_every`` is both the
    checkpoint interval and the scan block length — keep it fixed between
    a baseline and a faulted/resumed run to compare trajectories
    bit-for-bit.  ``on_block(round, state)`` runs after each completed
    block (the soak harness's kill hook).
    """

    def __init__(self, out_sets, in_sets, app: EngineApp, *,
                 degrees="auto", seed: int = 0, fabric: Fabric = EC2_2013,
                 schedule: Optional[FailureSchedule] = None,
                 fault_at: int = 0,
                 repartition: Optional[Callable] = None,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 0,
                 plan_cache=True, pool=None,
                 on_block: Optional[Callable] = None):
        import jax
        self.pool = list(pool) if pool is not None else list(jax.devices())
        m = len(out_sets)
        if len(self.pool) < m:
            raise ValueError(
                f"pool of {len(self.pool)} devices < {m} partitions")
        self.schedule = schedule
        self.fault_at = fault_at
        self.repartition = repartition
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.on_block = on_block
        self.assignment = list(range(m))   # partition -> pool position
        self._dead: Set[int] = set()
        self.events: List[FaultEvent] = []
        self.remaps = 0
        self.engine = GraphEngine(
            out_sets, in_sets, app, degrees=degrees,
            mesh=self._mesh(), seed=seed, fabric=fabric,
            plan_cache=plan_cache)

    def _mesh(self):
        import jax
        return jax.sharding.Mesh(
            np.array([self.pool[p] for p in self.assignment]), ("nodes",))

    # ------------------------------------------------------------------
    def _supervise(self, rnd: int) -> None:
        """Apply the dead set active at round ``rnd`` (remap or raise)."""
        if self.schedule is None or rnd < self.fault_at:
            return
        dead = set(self.schedule.dead_at(rnd))
        if dead == self._dead:
            return
        self._dead = dead
        m = len(self.assignment)
        hit = [i for i, p in enumerate(self.assignment) if p in dead]
        alive = [p for p in range(len(self.pool)) if p not in dead]
        if not hit:
            # spares died; the engine's devices are untouched
            self.events.append(FaultEvent(
                step=rnd, attempt=0, dead=frozenset(dead),
                klass=REPLICA_ABSORBED, lost=(),
                survivors=tuple(range(m))))
            return
        if len(alive) >= m:
            self.assignment = alive[:m]
            self.engine = self.engine.remesh(self._mesh())
            self.remaps += 1
            self.events.append(FaultEvent(
                step=rnd, attempt=0, dead=frozenset(dead),
                klass=GROUP_LOST, lost=tuple(hit),
                survivors=tuple(range(m))))
            return
        if self.repartition is not None:
            self.engine, self.assignment = self.repartition(self, alive)
            self.remaps += 1
            self.events.append(FaultEvent(
                step=rnd, attempt=0, dead=frozenset(dead),
                klass=GROUP_LOST, lost=tuple(hit),
                survivors=tuple(range(len(self.assignment)))))
            return
        raise QuorumLost(
            f"round {rnd}: {len(alive)} alive pool devices cannot host "
            f"{m} partitions and no repartition callback is set "
            f"(dead={sorted(dead)})")

    # ------------------------------------------------------------------
    def run(self, rounds: int, state, extras=None, *,
            start_round: int = 0) -> Tuple[Any, Any]:
        """Run ``rounds`` total rounds, continuing at ``start_round``
        (0 for a fresh run; a resumed caller passes the checkpointed
        round).  Returns ``(final_state, last_out)``; intermediate states
        land in ``ckpt_dir`` as ``ckpt-<round>`` artifacts.
        """
        from jax.tree_util import tree_map
        block = self.ckpt_every if self.ckpt_every > 0 else rounds
        rnd = start_round
        last_out = None
        while rnd < rounds:
            before = self.engine
            self._supervise(rnd)
            if self.engine is not before:
                # re-host the state: the new mesh places blocks on the
                # surviving devices, so hand numpy to the next dispatch
                state = tree_map(np.asarray, state)
            k = min(block, rounds - rnd)
            state, last_out, _ = self.engine.run(k, state, extras)
            rnd += k
            if self.ckpt_dir:
                from repro.checkpoint import store
                store.save(f"{self.ckpt_dir}/ckpt-{rnd}",
                           {"state": tree_map(np.asarray, state)},
                           meta={"round": rnd,
                                 "events": [e.klass for e in self.events]})
            if self.on_block is not None:
                self.on_block(rnd, state)
        return state, last_out
