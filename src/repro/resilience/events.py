"""Fault-event taxonomy for the supervision layer.

Every failure the supervisor (:mod:`repro.resilience.supervisor`) sees is
classified into exactly one of three severities, keyed off the paper's §V
replica layout (:func:`repro.core.replication.replica_groups`):

  * **replica-absorbed** — some physical nodes are dead but every replica
    group keeps at least one alive member.  The reduce completes with
    *unchanged* results after an incremental weight repair
    (``SparseAllreduce.reconfig_dead``) — the paper's designed-for case.
  * **group-lost** — at least one replica group is entirely dead.  The
    fault-free plan cannot complete (``DeadLogicalNode``); the supervisor
    replans over the surviving logical shards (degraded but correct).
  * **quorum-lost** — so many groups are gone that fewer than
    ``quorum_frac`` of the logical shards survive.  Continuing would be
    statistically meaningless; the supervisor fails fast with
    :class:`QuorumLost`.

``classify`` is pure and host-side — the supervisor calls it both before
dispatch (schedule consultation) and inside the ``DeadLogicalNode``
handler, so both paths agree on severity by construction.
"""
from __future__ import annotations

import dataclasses
import math
from typing import FrozenSet, Optional, Set, Tuple

from repro.core.replication import (DeadLogicalNode, lost_logical_shards,
                                    surviving_logical_shards)

#: Severity labels, mildest first.
NO_FAULT = "none"
REPLICA_ABSORBED = "replica-absorbed"
GROUP_LOST = "group-lost"
QUORUM_LOST = "quorum-lost"


class QuorumLost(DeadLogicalNode):
    """Too few logical shards survive to continue degraded — the
    supervisor's fail-fast terminal state.  Subclasses
    :class:`DeadLogicalNode` so unsupervised callers that already handle
    dead groups keep working."""


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One classified observation of a dead set (supervisor audit trail).

    ``lost`` / ``survivors`` are logical shard ids; ``dead`` is physical.
    ``attempt`` counts retries within one reduce (0 = first try).
    """

    step: int
    attempt: int
    dead: FrozenSet[int]
    klass: str
    lost: Tuple[int, ...]
    survivors: Tuple[int, ...]


def classify(m_physical: int, replication: int,
             dead: Optional[Set[int]] = None, *,
             quorum_frac: float = 0.5,
             step: int = 0, attempt: int = 0) -> FaultEvent:
    """Classify a dead physical-node set into a :class:`FaultEvent`.

    Quorum rule: the run continues degraded while at least
    ``max(1, ceil(quorum_frac * m_logical))`` logical shards survive;
    below that the event is :data:`QUORUM_LOST`.  Raises ``ValueError``
    for out-of-range dead ids (same contract as
    :func:`repro.core.replication.contribution_weights`).
    """
    dead = set(dead or ())
    lost = tuple(lost_logical_shards(m_physical, replication, dead))
    survivors = tuple(surviving_logical_shards(m_physical, replication, dead))
    m_logical = m_physical // replication
    if not dead:
        klass = NO_FAULT
    elif not lost:
        klass = REPLICA_ABSORBED
    elif len(survivors) < max(1, math.ceil(quorum_frac * m_logical)):
        klass = QUORUM_LOST
    else:
        klass = GROUP_LOST
    return FaultEvent(step=step, attempt=attempt, dead=frozenset(dead),
                      klass=klass, lost=lost, survivors=survivors)
