"""Collective-traffic extraction from compiled HLO text.

``cost_analysis`` has no collective-bytes term, so we parse the optimized
(post-SPMD) HLO.  Two subtleties:

1. Per-device bytes moved per op derive from result shape, op semantics and
   replica-group size k:
       all-reduce          2 * size * (k-1)/k      (ring)
       all-gather          size * (k-1)/k          (receives others' shards)
       reduce-scatter      size * (k-1)            (operand = k * result)
       all-to-all          size * (k-1)/k
       collective-permute  size

2. Our layer stacks run under lax.scan => collectives inside the while body
   appear ONCE in text but execute trip-count times.  We build the
   computation call graph, find while bodies, and multiply their collectives
   by the loop trip count (recovered from the while condition's comparison
   constant where possible, else the caller-supplied default).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, Optional, Tuple

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

_OP_RE = re.compile(
    r"=\s+(?:\()?((?:[a-z0-9]+)\[[0-9,]*\][^ ]*)\)?\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)")
_COMP_RE = re.compile(r"^(?:ENTRY )?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*\{\s*$")
_CALL_RE = re.compile(r"(?:to_apply|body|condition|branch_computations=\{)="
                      r"?%?([\w.\-]+)")
_WHILE_RE = re.compile(r"while\(.*?\)?.*?condition=%?([\w.\-]+),\s*"
                       r"body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r"compare\([^)]*\).*direction=LT")


def _shape_bytes(shape_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo_text: str) -> Dict[str, str]:
    """name -> body text, split on top-level '%name (...) -> ... {' blocks."""
    comps: Dict[str, str] = {}
    cur_name, cur_lines, depth = None, [], 0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if cur_name is None:
            m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\{\s*$", line)
            if m and not line.startswith(" "):
                cur_name = m.group(1)
                cur_lines = [line]
                depth = line.count("{") - line.count("}")
            continue
        cur_lines.append(line)
        depth += line.count("{") - line.count("}")
        if depth <= 0:
            comps[cur_name] = "\n".join(cur_lines)
            cur_name = None
    return comps


_KIND_RE = re.compile(
    r"\s(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")


def _line_bytes(line: str) -> Optional[Tuple[str, float]]:
    if "=" not in line:
        return None
    m = _KIND_RE.search(line)
    if not m or m.group(2) == "-done":
        return None
    kind = m.group(1)
    # result may be a tuple (all-to-all over k>1 groups): sum every shape
    # between '=' and the op keyword
    prefix = line[line.index("=") + 1: m.start()]
    size = _shape_bytes(prefix)
    k = 1
    g = _GROUPS_RE.search(line)
    if g:
        k = len(g.group(1).split(","))
    else:
        gi = _GROUPS_IOTA_RE.search(line)
        if gi:
            k = int(gi.group(2))
    k = max(k, 2)
    if kind == "all-reduce":
        moved = 2.0 * size * (k - 1) / k
    elif kind == "all-gather":
        moved = size * (k - 1) / k
    elif kind == "reduce-scatter":
        moved = size * (k - 1)
    elif kind == "all-to-all":
        moved = size * (k - 1) / k
    else:
        moved = size
    return kind, moved


def collective_stats(hlo_text: str, default_trip: int = 1
                     ) -> Dict[str, Dict[str, float]]:
    """Per-op-kind {count, bytes} per device, loop-aware.

    default_trip multiplies collectives inside while bodies whose trip count
    cannot be recovered from the HLO (pass the layer-scan length).
    """
    comps = _split_computations(hlo_text)
    if not comps:  # fallback: flat count
        comps = {"__all__": hlo_text}
    mult = _computation_multipliers(comps, default_trip)

    out: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"count": 0.0, "bytes": 0.0})
    for name, body in comps.items():
        m = mult.get(name, 1.0)
        for line in body.splitlines():
            r = _line_bytes(line)
            if r is None:
                continue
            kind, moved = r
            out[kind]["count"] += m
            out[kind]["bytes"] += moved * m
    return dict(out)


_DOT_LINE_RE = re.compile(
    r"%?([\w.\-]+)\s*=\s*\(?([a-z0-9]+\[[0-9,]*\])[^=]*?"
    r"dot\(\s*(?:[a-z0-9]+\[([0-9,]*)\][^%]*)?%([\w.\-]+)")
_DEF_RE = re.compile(r"^\s+%?([\w.\-]+)\s*=\s*\(?([a-z0-9]+)\[([0-9,]*)\]")
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _dims(s: str):
    return [int(d) for d in s.split(",") if d]


def _computation_multipliers(comps: Dict[str, str], default_trip: int
                             ) -> Dict[str, float]:
    """Trip-count multiplier per computation (while bodies + callees)."""
    mult: Dict[str, float] = {name: 1.0 for name in comps}
    for name, body in comps.items():
        for wm in re.finditer(r"condition=%?([\w.\-]+), body=%?([\w.\-]+)",
                              body):
            cond, wbody = wm.group(1), wm.group(2)
            trip = _recover_trip(comps.get(cond, ""), default_trip)
            for target in (wbody, cond):
                if target in mult:
                    mult[target] = max(mult[target], float(trip))
    changed, guard = True, 0
    while changed and guard < 30:
        changed, guard = False, guard + 1
        for name, body in comps.items():
            m = mult.get(name, 1.0)
            if m == 1.0:
                continue
            for cm in re.finditer(r"(?:to_apply|calls)=%?([\w.\-]+)", body):
                t = cm.group(1)
                if t in mult and mult[t] < m:
                    mult[t] = m
                    changed = True
            # nested while loops multiply
            for wm in re.finditer(
                    r"condition=%?([\w.\-]+), body=%?([\w.\-]+)", body):
                cond, wbody = wm.group(1), wm.group(2)
                trip = _recover_trip(comps.get(cond, ""), default_trip)
                for target in (wbody, cond):
                    if target in mult and mult[target] < m * trip:
                        mult[target] = m * trip
                        changed = True
    return mult


def dot_flops(hlo_text: str, default_trip: int = 1):
    """(loop-corrected, flat) matmul FLOPs parsed from HLO dots.

    XLA's cost_analysis counts while bodies ONCE (verified empirically);
    this walks computations with trip multipliers.  Operand shapes are
    resolved through each computation's instruction definitions (post-opt
    HLO references operands by name).  Elementwise FLOPs excluded (dots
    dominate).  The corrected/flat ratio is the loop-expansion factor.
    """
    comps = _split_computations(hlo_text)
    if not comps:
        comps = {"__all__": hlo_text}
    mult = _computation_multipliers(comps, default_trip)
    total = flat = 0.0
    for name, body in comps.items():
        m = mult.get(name, 1.0)
        shapes: Dict[str, list] = {}
        for line in body.splitlines():
            dm = _DEF_RE.match(line)
            if dm:
                shapes[dm.group(1)] = _dims(dm.group(3))
        for line in body.splitlines():
            if " dot(" not in line:
                continue
            dm = _DOT_LINE_RE.search(line)
            if not dm:
                continue
            res = _dims(re.search(r"\[([0-9,]*)\]", dm.group(2)).group(1))
            lhs = _dims(dm.group(3)) if dm.group(3) else \
                shapes.get(dm.group(4), [])
            cm = _LHS_C_RE.search(line)
            cdims = _dims(cm.group(1)) if cm else []
            k = 1
            for ci in cdims:
                if ci < len(lhs):
                    k *= lhs[ci]
            n = 1
            for d in res:
                n *= d
            total += 2.0 * n * k * m
            flat += 2.0 * n * k
    return total, flat


def _recover_trip(cond_text: str, default: int) -> int:
    """Trip count from 'compare(iter, constant), direction=LT' patterns."""
    consts = re.findall(r"constant\((\d+)\)", cond_text)
    cands = [int(c) for c in consts if 1 < int(c) <= 1_000_000]
    if len(cands) == 1:
        return cands[0]
    return default


def total_collective_bytes(stats: Dict[str, Dict[str, float]]) -> float:
    return sum(v["bytes"] for v in stats.values())
