"""Exact-resume soak harness: train / PageRank under fault schedules.

    PYTHONPATH=src python -m repro.launch.soak --job train --reduced \
        --steps 6 --ckpt-every 2 --faults rack --fault-at 3 \
        --num-failures 5 --rack-size 5 --out /tmp/soak

Runs a job to completion while a :mod:`repro.core.faults` schedule kills
devices mid-run, checkpointing every ``--ckpt-every`` steps through the
atomic :mod:`repro.checkpoint.store`.  ``--kill-at N`` hard-exits the
process (code 17) after step N — rerun with ``--resume`` to continue from
the newest valid checkpoint (corrupt ones are skipped) and finish with a
trajectory **step-identical** to an uninterrupted baseline: the batch
stream is replayed-and-skipped (``repro.launch.train.batch_stream``), the
checkpoint meta carries a ``train_fingerprint`` that must match, and
losses round-trip exactly through JSON.

Fault handling per step mirrors ``repro.resilience``:

  * dead devices that only hit spare capacity (or a redundant replica,
    ``--replication r``) are *absorbed* — the train step is rebuilt with
    the dead set masked via contribution weights, results unchanged;
  * a lost replica group with enough surviving pool devices triggers a
    *remap* — the same program re-bound to alive devices, bit-identical;
  * without spares the job *degrades* (drop to r=1 over survivors) or
    exits 3 on quorum loss.

The PageRank job drives :class:`repro.resilience.SupervisedEngineLoop`
over a power-law graph with the same checkpoint/kill/resume contract.
``benchmarks/bench_soak.py`` wraps this harness for the recovery-latency
and resume-overhead rows of BENCH_pr7.json; tier-1 runs it under a
subprocess kill-and-resume test (tests/test_resilience.py).
"""
from __future__ import annotations

import argparse
import sys

import numpy as np

from repro.checkpoint import store
from repro.core.faults import SCHEDULE_KINDS, make_schedule
from repro.resilience.events import (GROUP_LOST, QUORUM_LOST,
                                     REPLICA_ABSORBED, classify)

KILL_EXIT = 17      #: exit code of a --kill-at hard stop (not a failure)
QUORUM_EXIT = 3     #: exit code when too few devices survive


def parse_args(argv=None):
    """The soak CLI (flags shared by both jobs unless noted)."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--job", default="train", choices=["train", "pagerank"])
    ap.add_argument("--steps", type=int, default=6,
                    help="total train steps / PageRank rounds")
    ap.add_argument("--ckpt-every", type=int, default=2,
                    help="checkpoint (and scan-block) interval; keep it "
                         "fixed between a baseline and a resumed run to "
                         "compare trajectories bit-for-bit")
    ap.add_argument("--faults", default="none",
                    choices=("none",) + SCHEDULE_KINDS,
                    help="failure schedule kind over the device pool "
                         "(repro.core.faults; 'cascade' accumulates and "
                         "never heals)")
    ap.add_argument("--fault-at", type=int, default=0,
                    help="first step/round at which the schedule applies")
    ap.add_argument("--num-failures", type=int, default=1)
    ap.add_argument("--rack-size", type=int, default=4)
    ap.add_argument("--kill-at", type=int, default=0,
                    help="hard-exit (code 17) after this step completes "
                         "and checkpoints — simulates a crash; ignored "
                         "under --resume")
    ap.add_argument("--resume", action="store_true",
                    help="continue from the newest valid checkpoint in "
                         "--out (corrupt checkpoints are skipped; the "
                         "stored fingerprint must match this invocation)")
    ap.add_argument("--out", required=True,
                    help="checkpoint + final-state directory")
    ap.add_argument("--seed", type=int, default=0)
    # train job
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--sync", default="ring",
                    choices=["ring", "hier", "sparse"])
    ap.add_argument("--merge", default="sort",
                    choices=["sort", "fused", "banded"])
    ap.add_argument("--dp", type=int, default=4,
                    help="logical data-parallel shards (train job)")
    ap.add_argument("--replication", type=int, default=1,
                    help="r-way replica groups over dp*r device roles")
    # pagerank job
    ap.add_argument("--vertices", type=int, default=400)
    ap.add_argument("--edges", type=int, default=2000)
    ap.add_argument("--graph-nodes", type=int, default=4,
                    help="graph partitions M (PageRank job)")
    return ap.parse_args(argv)


def _latest_valid(out_dir: str):
    """Newest loadable checkpoint ``(step, arrays, meta)`` or ``None``,
    skipping corrupt artifacts (the atomic-save + CheckpointError
    contract makes 'corrupt' detectable instead of garbage)."""
    for step, base in store.list_checkpoints(out_dir):
        try:
            arrays, meta = store.load_flat(base)
            return step, arrays, meta
        except store.CheckpointError as e:
            print(f"skipping corrupt checkpoint {base}: {e}",
                  file=sys.stderr)
    return None


# ---------------------------------------------------------------------------
# train job
# ---------------------------------------------------------------------------

def run_train(args) -> int:
    import jax
    import jax.numpy as jnp
    from jax.tree_util import tree_map

    from repro.configs import get_config
    from repro.launch.train import batch_stream
    from repro.models import transformer as T
    from repro.optim.adamw import AdamW, AdamWState
    from repro.train.step import make_train_step, mesh_ctx, train_fingerprint

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    pool = jax.devices()
    dp, r = args.dp, args.replication
    m_roles = dp * r
    if len(pool) < m_roles:
        raise ValueError(f"{len(pool)} devices < {m_roles} roles")
    schedule = None
    if args.faults != "none":
        schedule = make_schedule(args.faults, len(pool), args.num_failures,
                                 seed=args.seed, rack_size=args.rack_size)
    fp = train_fingerprint(cfg, batch=args.batch, seq=args.seq, lr=args.lr,
                           sync=args.sync, merge=args.merge, dp=dp,
                           replication=r, seed=args.seed)

    # role -> pool position; sticky until a fault forces a remap/shrink
    assignment = list(range(m_roles))
    r_eff = r
    step_cache = {}

    def get_step(assign, dead_roles, r_now):
        key = (tuple(assign), frozenset(dead_roles), r_now)
        hit = step_cache.get(key)
        if hit is None:
            mesh = jax.sharding.Mesh(
                np.array([pool[p] for p in assign]).reshape(len(assign), 1),
                ("data", "model"))
            fn, _ = make_train_step(
                cfg, mesh, sync=args.sync, opt=AdamW(lr=args.lr),
                dp_degrees=None, sync_merge=args.merge,
                sparse_tokens_hint=max(8, args.batch * args.seq
                                       // len(assign)),
                replication=r_now, dead=set(dead_roles) or None)
            hit = step_cache[key] = (fn, mesh)
        return hit

    mesh0 = get_step(assignment, frozenset(), r_eff)[1]
    params = T.init_params(cfg, mesh_ctx(mesh0).tp, seed=args.seed)
    opt = AdamW(lr=args.lr)
    opt_state = opt.init(params)
    start, losses, events = 0, [], []
    if args.resume:
        hit = _latest_valid(args.out)
        if hit is not None:
            start, arrays, meta = hit
            if meta["fingerprint"] != fp:
                raise SystemExit(
                    f"checkpoint fingerprint {meta['fingerprint']} does not "
                    f"match this invocation ({fp}) — resuming would diverge")
            like = {"params": params, "opt_m": opt_state.m,
                    "opt_v": opt_state.v}
            tree = store.load(f"{args.out}/ckpt-{start}", like)
            params = tree["params"]
            opt_state = AdamWState(
                step=jnp.asarray(arrays["opt_step"]),
                m=tree["opt_m"], v=tree["opt_v"])
            losses = [float(x) for x in meta["losses"]]
            events = list(meta.get("events", []))
            print(f"resumed at step {start} from {args.out}/ckpt-{start}")

    stream = batch_stream(cfg, args.batch, args.seq, seed=args.seed)
    for _ in range(start):
        next(stream)       # exact resume: replay-and-skip the batch source

    def checkpoint(step_no):
        store.save(f"{args.out}/ckpt-{step_no}",
                   {"params": tree_map(np.asarray, params),
                    "opt_m": tree_map(np.asarray, opt_state.m),
                    "opt_v": tree_map(np.asarray, opt_state.v),
                    "opt_step": np.asarray(opt_state.step)},
                   meta={"step": step_no, "losses": losses,
                         "fingerprint": fp, "events": events})

    dead_roles = frozenset()
    for i in range(start, args.steps):
        dead_pool = set(schedule.dead_at(i)) \
            if schedule is not None and i >= args.fault_at else set()
        new_dead = frozenset(role for role, p in enumerate(assignment)
                             if p in dead_pool)
        ev = classify(len(assignment), r_eff, set(new_dead))
        if ev.klass == GROUP_LOST or \
                (ev.klass == QUORUM_LOST and r_eff > 1):
            alive = [p for p in range(len(pool)) if p not in dead_pool]
            if len(alive) >= len(assignment):
                # remap: same program on alive devices — bit-identical
                assignment = alive[: len(assignment)]
                new_dead = frozenset()
                events.append(f"remap@{i}")
            elif len(alive) >= dp:
                # degrade: drop replication, keep every logical shard
                assignment, r_eff = alive[:dp], 1
                new_dead = frozenset()
                events.append(f"drop-replication@{i}")
            else:
                print(f"QUORUM_LOST step {i}: {len(alive)} alive < dp={dp}")
                return QUORUM_EXIT
            # state buffers live on dead devices; re-host before re-binding
            params = tree_map(np.asarray, params)
            opt_state = tree_map(np.asarray, opt_state)
        elif ev.klass == QUORUM_LOST:
            print(f"QUORUM_LOST step {i}: dead roles {sorted(new_dead)}")
            return QUORUM_EXIT
        elif ev.klass == REPLICA_ABSORBED and new_dead != dead_roles:
            events.append(f"absorbed@{i}")
        dead_roles = new_dead

        step_fn, _ = get_step(assignment, dead_roles, r_eff)
        b = next(stream)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        if r_eff > 1:
            batch = {k: jnp.tile(v, (r_eff,) + (1,) * (v.ndim - 1))
                     for k, v in batch.items()}
        params, opt_state, m = step_fn(params, opt_state, batch)
        losses.append(float(m["loss"]))
        done = i + 1
        if args.ckpt_every and done % args.ckpt_every == 0:
            checkpoint(done)
        if args.kill_at and done == args.kill_at and not args.resume:
            print(f"KILL step {done} (simulated crash)")
            sys.stdout.flush()
            return KILL_EXIT

    store.save(f"{args.out}/final",
               {"params": tree_map(np.asarray, params),
                "opt_m": tree_map(np.asarray, opt_state.m),
                "opt_v": tree_map(np.asarray, opt_state.v),
                "opt_step": np.asarray(opt_state.step)},
               meta={"steps": args.steps, "losses": losses,
                     "fingerprint": fp, "events": events})
    print(f"SOAK_OK job=train steps={args.steps} "
          f"loss={losses[-1]:.6f} events={events}")
    return 0


# ---------------------------------------------------------------------------
# pagerank job
# ---------------------------------------------------------------------------

def run_pagerank(args) -> int:
    import jax

    from repro.data.pipeline import powerlaw_graph
    from repro.graph.pagerank import (assemble_pagerank_scores,
                                      build_partitions, make_pagerank_app,
                                      pagerank_state)
    from repro.resilience.engine import SupervisedEngineLoop

    pool = jax.devices()
    m = args.graph_nodes
    damping = 0.85
    edges = powerlaw_graph(args.vertices, args.edges, seed=args.seed)
    parts = build_partitions(edges, args.vertices, m, seed=args.seed)
    app, out_sets, in_sets = make_pagerank_app(parts, args.vertices, damping)
    schedule = None
    if args.faults != "none":
        schedule = make_schedule(args.faults, len(pool), args.num_failures,
                                 seed=args.seed, rack_size=args.rack_size)

    killed = {"flag": False}

    def on_block(rnd, state):
        if args.kill_at and rnd >= args.kill_at and not args.resume \
                and not killed["flag"]:
            killed["flag"] = True
            print(f"KILL round {rnd} (simulated crash)")
            sys.stdout.flush()
            sys.exit(KILL_EXIT)

    loop = SupervisedEngineLoop(
        out_sets, in_sets, app, degrees=(m,), seed=args.seed,
        schedule=schedule, fault_at=args.fault_at, ckpt_dir=args.out,
        ckpt_every=args.ckpt_every, pool=pool, on_block=on_block)
    extras, p0 = pagerank_state(parts, args.vertices,
                                loop.engine.u_cap, loop.engine.uin_cap)
    start, state = 0, p0
    if args.resume:
        hit = _latest_valid(args.out)
        if hit is not None:
            start, arrays, meta = hit
            state = arrays["state"]
            print(f"resumed at round {start} from {args.out}/ckpt-{start}")

    state, last_q = loop.run(args.steps, state, extras, start_round=start)
    scores = assemble_pagerank_scores(parts, np.asarray(last_q),
                                      args.vertices, damping)
    store.save(f"{args.out}/final",
               {"state": np.asarray(state), "last_q": np.asarray(last_q),
                "scores": scores},
               meta={"rounds": args.steps, "remaps": loop.remaps,
                     "events": [e.klass for e in loop.events]})
    print(f"SOAK_OK job=pagerank rounds={args.steps} remaps={loop.remaps} "
          f"events={[e.klass for e in loop.events]}")
    return 0


def main(argv=None) -> int:
    """Entry point; returns the process exit code (0 ok, 17 simulated
    crash, 3 quorum lost)."""
    args = parse_args(argv)
    rc = run_train(args) if args.job == "train" else run_pagerank(args)
    return rc


if __name__ == "__main__":
    sys.exit(main())
