"""Analytic per-device memory model for the TPU target.

``compiled.memory_analysis()`` on the CPU host backend schedules remat
regions for host parallelism, so sequential blocks' backward temporaries
co-live and temp_size grows ~linearly with depth (probes in EXPERIMENTS.md
§Dry-run) — an artifact of the measurement backend, not of the sharding.
This model computes what the TPU scheduler's peak would be:

  params   — exact: eval_shape leaves / their PartitionSpec divisors
  optimizer— exact: 2 x f32 params (AdamW m, v), same shards
  grads    — exact: f32 params (FSDP leaves: data-sharded)
  acts     — peak live set: period-scan residuals (block-boundary
             activations per layer) + one block's working set (attention
             scores / MoE dispatch buffers / SSM chunk tensors)
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import numpy as np

from repro.configs import InputShape
from repro.models.common import ModelConfig
from repro.models.sharding import full_model_pspec
from repro.train.step import mesh_ctx


def _pspec_divisor(spec, mesh) -> int:
    div = 1
    for entry in spec:
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        for a in axes:
            div *= mesh.shape[a]
    return div


def params_bytes_per_device(cfg: ModelConfig, mesh) -> float:
    mc = mesh_ctx(mesh)
    from repro.launch.specs import params_specs
    p = params_specs(cfg, mc.tp)
    spec = full_model_pspec(cfg, mc.tp, mc.dp_axes)
    total = 0.0

    def walk(t, s):
        nonlocal total
        if isinstance(t, dict):
            for k in t:
                walk(t[k], s[k])
        else:
            total += (np.prod(t.shape) * t.dtype.itemsize
                      / _pspec_divisor(s, mesh))
    walk(p, spec)
    return total


def modeled_memory(cfg: ModelConfig, shape: InputShape, mesh,
                   micro: int = 1) -> Dict[str, float]:
    mc = mesh_ctx(mesh)
    tp, dp = mc.tp, mc.dp
    pb = params_bytes_per_device(cfg, mesh)
    # grads/opt are f32 regardless of param dtype
    f32_params = pb * (4.0 / np.dtype(cfg.dtype).itemsize)

    out: Dict[str, float] = {"params": pb}
    if shape.kind == "train":
        out["optimizer"] = 2.0 * f32_params
        out["grads"] = 2.0 * f32_params  # accumulator + current
        b_loc = max(1, shape.global_batch // dp)
        tok_mb = (b_loc // micro) * shape.seq_len if shape.kind == "train" \
            else b_loc * shape.seq_len
        d = cfg.d_model
        # period residuals: one activation per block boundary per layer
        resid = cfg.n_layers * 2 * tok_mb * d * 2.0
        # one block's working set
        hl = cfg.heads_local(tp)
        qc = min(1024, shape.seq_len)  # blocked attention query chunk
        scores = (tok_mb // shape.seq_len) * hl * qc * shape.seq_len * 4.0
        ffl = max(cfg.d_ff // tp, cfg.expert_d_ff)
        ffn_ws = 3 * tok_mb * ffl * 2.0
        if cfg.n_experts:
            cap_dev = math.ceil(tok_mb * cfg.top_k / tp) * 2
            moe_ws = 4 * tp * cap_dev * d * 2.0
            ffn_ws = max(ffn_ws, moe_ws)
        ssm_ws = 6 * tok_mb * (2 * d // tp) * 4.0 if any(
            b in ("mamba", "mlstm", "slstm") for b in cfg.pattern) else 0.0
        out["activations"] = resid + max(scores, ffn_ws, ssm_ws) \
            + 8 * tok_mb * d * 2.0
        # vocab logits for one microbatch (f32, vocab-sharded)
        from repro.models.transformer import padded_vocab
        out["logits"] = tok_mb * (padded_vocab(cfg, tp) // tp) * 4.0
    else:
        b_loc = max(1, shape.global_batch // dp)
        kvg = cfg.kv_local(tp)
        n_attn = sum(1 for b in cfg.pattern if b == "attn") * cfg.n_periods
        s_loc = shape.seq_len // mesh.shape["data"] \
            if shape.kind == "decode_long" else shape.seq_len
        out["kv_cache"] = n_attn * b_loc * s_loc * kvg * cfg.hd * 2 * 2.0
        d = cfg.d_model
        tok = b_loc * (shape.seq_len if shape.kind == "prefill" else 1)
        out["activations"] = 12 * tok * d * 2.0
        from repro.models.transformer import padded_vocab
        out["logits"] = b_loc * (padded_vocab(cfg, tp) // tp) * 4.0
    out["total"] = sum(v for k, v in out.items())
    return out
