"""SPerf hillclimb driver — three studies on the three selected pairs.

H1 (paper-representative): gemma3-12b x train_minibatch — gradient-sync
   mode ring -> hier -> sparse (untied) on the 262k-vocab embedding; the
   paper's mini-batch regime (SI-A.1).  Metric: collective bytes.
H2 (most collective-bound): arctic-480b x train_4k — microbatch count
   (FSDP gathers scale with it) and MoE dispatch capacity factor.
   Metric: collective bytes vs modeled activation memory.
H3 (worst useful-compute): jamba-1.5-large-398b x train_4k — remat policy
   full-recompute -> save-dots.  Metric: corrected HLO FLOPs (compute term).

Each run re-lowers + re-compiles and records the roofline terms; results in
results/perf/*.json and summarized in EXPERIMENTS.md SPerf.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json

from repro.launch.dryrun import run_pair


def study_h1(outdir):
    runs = [
        ("h1_ring_tied", dict(sync="ring")),
        ("h1_ring_untied", dict(sync="ring",
                                overrides={"tie_embeddings": False})),
        ("h1_hier_untied", dict(sync="hier",
                                overrides={"tie_embeddings": False})),
        ("h1_sparse_untied", dict(sync="sparse",
                                  overrides={"tie_embeddings": False})),
        # iteration 4-5: butterfly degree ablation on-device (paper Fig 6
        # asked of the TPU backend): 16 = round-robin vs 4x4 vs 2x2x2x2
        ("h1_sparse_4x4", dict(sync="sparse",
                               overrides={"tie_embeddings": False},
                               dp_degrees={"data": (4, 4)})),
        ("h1_sparse_2222", dict(sync="sparse",
                                overrides={"tie_embeddings": False},
                                dp_degrees={"data": (2, 2, 2, 2)})),
    ]
    out = []
    for tag, kw in runs:
        r = run_pair("gemma3-12b", "train_minibatch", False,
                     kw.pop("sync"), outdir, overrides=kw.get("overrides"),
                     dp_degrees=kw.get("dp_degrees"),
                     tag_suffix="_" + tag)
        out.append((tag, r))
        _report(tag, r)
    return out


def study_h2(outdir):
    runs = [
        ("h2_micro8_cap2.0", dict(microbatch=8)),
        ("h2_micro4_cap2.0", dict(microbatch=4)),
        ("h2_micro2_cap2.0", dict(microbatch=2)),
        ("h2_micro4_cap1.25", dict(microbatch=4,
                                   overrides={"moe_capacity": 1.25})),
        # iteration 3: MoE token dedup across TP (activations are replicated
        # post-psum; without sharding every rank dispatches the same tokens)
        ("h2_micro4_cap1.25_noshard", dict(
            microbatch=4, overrides={"moe_capacity": 1.25,
                                     "moe_token_shard": False})),
    ]
    out = []
    for tag, kw in runs:
        r = run_pair("arctic-480b", "train_4k", False, "ring", outdir,
                     overrides=kw.get("overrides"),
                     microbatch=kw.get("microbatch"), tag_suffix="_" + tag)
        out.append((tag, r))
        _report(tag, r)
    return out


def study_h3(outdir):
    runs = [
        ("h3_remat_full", dict()),
        ("h3_remat_dots", dict(overrides={"remat_policy": "dots"})),
    ]
    out = []
    for tag, kw in runs:
        r = run_pair("jamba-1.5-large-398b", "train_4k", False, "ring",
                     outdir, overrides=kw.get("overrides"),
                     tag_suffix="_" + tag)
        out.append((tag, r))
        _report(tag, r)
    return out


def _report(tag, r):
    print(f"{tag:24s} coll {r.get('collective_bytes', 0)/1e9:9.1f} GB  "
          f"flops {r.get('hlo_flops_corrected', 0):.3g}  "
          f"t(comp/mem/coll) {r.get('t_compute_s', 0):.3f}/"
          f"{r.get('t_memory_s', 0):.3f}/{r.get('t_collective_s', 0):.3f} s  "
          f"actGB {r.get('modeled_memory', {}).get('activations', '?')}",
          flush=True)


def study_h4(outdir):
    """H4: 2D weight-stationary decode — drop the per-period FSDP weight
    gathers from the (weight-bound) decode step; batch-replicate KB-scale
    activations around each projection instead."""
    runs = [("h4_gather", "command-r-plus-104b", "decode_32k", False),
            ("h4_serve2d", "command-r-plus-104b", "decode_32k", True),
            ("h4_long_gather", "command-r-plus-104b", "long_500k", False),
            ("h4_long_serve2d", "command-r-plus-104b", "long_500k", True),
            # MoE / hybrid extensions (moe_ffn_2d + mamba_decode_2d)
            ("h4_arctic_gather", "arctic-480b", "decode_32k", False),
            ("h4_arctic_serve2d", "arctic-480b", "decode_32k", True),
            ("h4_jamba_gather", "jamba-1.5-large-398b", "decode_32k", False),
            ("h4_jamba_serve2d", "jamba-1.5-large-398b", "decode_32k", True),
            ("h4_jamba_long_g", "jamba-1.5-large-398b", "long_500k", False),
            ("h4_jamba_long_2d", "jamba-1.5-large-398b", "long_500k", True)]
    out = []
    for tag, arch, shape, s2d in runs:
        r = run_pair(arch, shape, False, "ring",
                     outdir, serve2d=s2d, tag_suffix="_" + tag)
        out.append((tag, r))
        _report(tag, r)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--study", default="all",
                    choices=["all", "h1", "h2", "h3", "h4"])
    ap.add_argument("--out", default="results/perf")
    args = ap.parse_args()
    if args.study in ("all", "h1"):
        print("== H1: gemma3 sync modes (paper technique) ==")
        study_h1(args.out)
    if args.study in ("all", "h2"):
        print("== H2: arctic microbatch/FSDP-gather + MoE capacity ==")
        study_h2(args.out)
    if args.study in ("all", "h3"):
        print("== H3: jamba remat policy ==")
        study_h3(args.out)
    if args.study in ("all", "h4"):
        print("== H4: 2D weight-stationary decode (command-r) ==")
        study_h4(args.out)


if __name__ == "__main__":
    main()
