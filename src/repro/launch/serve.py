"""Serving launcher: continuous-batching decode with admission control
(ARCHITECTURE.md "Serving tier").

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
        --reduced --requests 4 --prompt-len 32 --gen 16 --slots 4

Thin CLI over ``repro.serve``: a Zipf request stream
(``repro.serve.service.zipf_request_stream``) runs through the
:class:`~repro.serve.queue.AdmissionController` (``--rate`` /
``--burst`` / ``--queue-cap`` / ``--slo-steps``; rate 0 disables
admission) into the :class:`~repro.serve.scheduler.ContinuousBatchingScheduler`
(``--slots``), with the sparse exchange path enabled by
``--sparse-dispatch`` (``--wire`` / ``--head-size`` knobs; see
``repro.serve.dispatch``).  Token sampling is greedy *on device* — only
int32 ids cross to host (``repro.analysis.auditor.audit_serve_decode``).

Encoder/vision archs (whisper, internvl) have no per-request cross-state
isolation in the slot cache, so they serve through the legacy
fixed-batch loop below — also on the fused greedy steps.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config
from repro.models import transformer as T
from repro.train.step import (make_decode_greedy_step,
                              make_prefill_greedy_step, mesh_ctx)


def _fixed_batch_generate(cfg, mesh, params, args) -> np.ndarray:
    """Legacy fixed-batch prefill+decode for encoder/vision archs."""
    mc = mesh_ctx(mesh)
    max_seq = args.prompt_len + args.gen + (cfg.img_tokens or 0)
    prefill, _ = make_prefill_greedy_step(cfg, mesh, max_seq=max_seq)
    decode, _ = make_decode_greedy_step(cfg, mesh)

    rng = np.random.RandomState(args.seed)
    b = args.requests
    batch = {"tokens": jnp.asarray(
        rng.randint(0, cfg.vocab, (b, args.prompt_len)), jnp.int32)}
    if cfg.img_tokens:
        batch["img_embeds"] = jnp.asarray(
            rng.randn(b, cfg.img_tokens, cfg.d_model), jnp.float32)
    if cfg.enc_layers:
        batch["enc_frames"] = jnp.asarray(
            rng.randn(b, cfg.enc_seq, cfg.d_model), jnp.float32)

    tok, cache = prefill(params, batch)

    extra = ()
    if cfg.enc_layers:
        from jax.sharding import PartitionSpec as P

        from repro.compat import shard_map
        from repro.models.sharding import full_model_pspec
        ax = mc.axis_ctx(cfg)
        ccfn = shard_map(
            lambda p, f: T.build_cross_cache(p, f, cfg, ax), mesh=mesh,
            in_specs=(full_model_pspec(cfg, mc.tp, mc.dp_axes), P("data")),
            out_specs=(P(None, "data", None, "model", None),
                       P(None, "data", None, "model", None)),
            check_vma=False)
        extra = (ccfn(params, batch["enc_frames"]),)

    pos0 = args.prompt_len + (cfg.img_tokens or 0)
    outputs = [np.asarray(tok)]
    for i in range(args.gen - 1):
        pos = jnp.full((b,), pos0 + i, jnp.int32)
        tok, cache = decode(params, tok, pos, cache, *extra)
        outputs.append(np.asarray(tok))
    return np.stack(outputs, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=list(ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--slots", type=int, default=0,
                    help="continuous-batching slot count (0: auto — up to "
                         "8, rounded to the data-axis size)")
    ap.add_argument("--alpha", type=float, default=1.2,
                    help="Zipf exponent of the request-stream prompts")
    ap.add_argument("--rate", type=float, default=0.0,
                    help="token-bucket admit rate in requests/step "
                         "(0: admission control off)")
    ap.add_argument("--burst", type=float, default=4.0,
                    help="token-bucket burst capacity")
    ap.add_argument("--queue-cap", type=int, default=16,
                    help="bounded-queue capacity (beyond it: load shed)")
    ap.add_argument("--slo-steps", type=float, default=64.0,
                    help="latency SLO in decode steps (circuit breaker)")
    ap.add_argument("--breach-window", type=int, default=8,
                    help="consecutive SLO breaches before the breaker trips")
    ap.add_argument("--cooldown-steps", type=float, default=32.0,
                    help="breaker open->half-open cooldown in steps")
    ap.add_argument("--sparse-dispatch", action="store_true",
                    help="route token/expert statistics through "
                         "SparseAllreduce (repro.serve.dispatch)")
    ap.add_argument("--wire", default="raw",
                    help="wire codec for the dispatch tail union "
                         "(raw | delta | delta+bf16 | delta+int8ef)")
    ap.add_argument("--head-size", type=int, default=64,
                    help="Zipf hot-set size for the frozen dispatch plan")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    ndev = len(jax.devices())
    mesh = jax.make_mesh((ndev // args.model_axis, args.model_axis),
                         ("data", "model"))
    mc = mesh_ctx(mesh)
    params = T.init_params(cfg, mc.tp, seed=args.seed)

    if cfg.enc_layers or cfg.img_tokens:
        gen = _fixed_batch_generate(cfg, mesh, params, args)
        print(f"fixed-batch {args.arch}: {gen.shape[0]} requests x "
              f"{gen.shape[1]} tokens")
        print("generated ids[0]:", gen[0][:12])
        return gen

    from repro.serve import (AdmissionController,
                             ContinuousBatchingScheduler, DecodeService,
                             zipf_request_stream)
    slots = args.slots or max(mc.dp, min(args.requests, 8)
                              // mc.dp * mc.dp or mc.dp)
    max_seq = args.prompt_len + args.gen + 1
    dispatch = None
    if args.sparse_dispatch:
        from repro.serve.dispatch import SparseServeDispatch
        dispatch = SparseServeDispatch(
            mc.dp, vocab=cfg.vocab, n_experts=cfg.n_experts,
            wire=args.wire, seed=args.seed + 1)
    sched = ContinuousBatchingScheduler(
        cfg, mesh, params, slots=slots, max_seq=max_seq, dispatch=dispatch)
    admission = None
    if args.rate > 0:
        admission = AdmissionController(
            rate=args.rate, burst=args.burst, queue_cap=args.queue_cap,
            slo=args.slo_steps, breach_window=args.breach_window,
            cooldown=args.cooldown_steps)
    reqs = zipf_request_stream(
        args.requests, cfg.vocab, alpha=args.alpha,
        prompt_lens=(args.prompt_len,), max_new=(args.gen, args.gen),
        seed=args.seed)
    if dispatch is not None:
        warm = np.concatenate([np.asarray(r.prompt).reshape(-1)
                               for r in reqs])
        dispatch.fit_hot_set(warm, head_size=args.head_size)
    report = DecodeService(sched, admission).run(reqs)
    done = sorted(report.completed, key=lambda r: r.rid)
    gen = np.asarray([r.tokens for r in done], np.int32) if done \
        else np.zeros((0, args.gen), np.int32)
    print(f"served {len(done)}/{args.requests} requests in {report.steps} "
          f"steps ({report.tokens_per_s:.1f} tok/s wall); "
          f"p50={report.p50_steps:.0f} p99={report.p99_steps:.0f} steps")
    if admission is not None:
        s = admission.stats
        print(f"admission: offered={s.offered} admitted={s.admitted} "
              f"shed(rate/queue/breaker)="
              f"{s.shed_rate}/{s.shed_queue}/{s.shed_breaker}")
    if dispatch is not None:
        print(f"dispatch: plan hit rate {dispatch.plan_hit_rate:.2f} over "
              f"{dispatch.plan_resolutions} resolutions")
    if len(gen):
        print("generated ids[0]:", gen[0][:12])
    return gen


if __name__ == "__main__":
    main()
