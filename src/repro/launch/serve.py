"""Serving launcher: batched prefill + decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
        --reduced --requests 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config
from repro.models import transformer as T
from repro.train.step import (make_decode_step, make_prefill_step, mesh_ctx)


def greedy_token(local_logits: np.ndarray, mesh, vocab: int) -> np.ndarray:
    """argmax over the (model-sharded, gathered-by-jit-output) vocab."""
    lg = np.asarray(local_logits)[:, :vocab]
    return np.argmax(lg, axis=-1).astype(np.int32)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=list(ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    ndev = len(jax.devices())
    mesh = jax.make_mesh((ndev // args.model_axis, args.model_axis),
                         ("data", "model"))
    mc = mesh_ctx(mesh)
    max_seq = args.prompt_len + args.gen + (cfg.img_tokens or 0)
    params = T.init_params(cfg, mc.tp, seed=args.seed)
    prefill, _ = make_prefill_step(cfg, mesh, max_seq=max_seq)
    decode, _ = make_decode_step(cfg, mesh)

    rng = np.random.RandomState(args.seed)
    b = args.requests
    batch = {"tokens": jnp.asarray(
        rng.randint(0, cfg.vocab, (b, args.prompt_len)), jnp.int32)}
    if cfg.img_tokens:
        batch["img_embeds"] = jnp.asarray(
            rng.randn(b, cfg.img_tokens, cfg.d_model), jnp.float32)
    if cfg.enc_layers:
        batch["enc_frames"] = jnp.asarray(
            rng.randn(b, cfg.enc_seq, cfg.d_model), jnp.float32)

    t0 = time.time()
    logits, cache = prefill(params, batch)
    print(f"prefill {b}x{args.prompt_len}: {time.time()-t0:.2f}s")

    extra = ()
    if cfg.enc_layers:
        from jax.sharding import PartitionSpec as P

        from repro.compat import shard_map
        from repro.models.sharding import full_model_pspec
        ax = mc.axis_ctx(cfg)
        ccfn = shard_map(
            lambda p, f: T.build_cross_cache(p, f, cfg, ax), mesh=mesh,
            in_specs=(full_model_pspec(cfg, mc.tp, mc.dp_axes), P("data")),
            out_specs=(P(None, "data", None, "model", None),
                       P(None, "data", None, "model", None)),
            check_vma=False)
        extra = (ccfn(params, batch["enc_frames"]),)

    pos0 = args.prompt_len + (cfg.img_tokens or 0)
    tok = jnp.asarray(greedy_token(logits, mesh, cfg.vocab))
    outputs = [np.asarray(tok)]
    t0 = time.time()
    for i in range(args.gen - 1):
        pos = jnp.full((b,), pos0 + i, jnp.int32)
        logits, cache = decode(params, tok, pos, cache, *extra)
        tok = jnp.asarray(greedy_token(logits, mesh, cfg.vocab))
        outputs.append(np.asarray(tok))
    dt = time.time() - t0
    gen = np.stack(outputs, axis=1)
    print(f"decode {args.gen-1} steps: {dt:.2f}s "
          f"({b*(args.gen-1)/max(dt,1e-9):.1f} tok/s)")
    print("generated ids[0]:", gen[0][:12])
    return gen


if __name__ == "__main__":
    main()
