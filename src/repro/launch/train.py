"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --reduced --steps 50 --batch 8 --seq 256 --sync sparse

Builds a mesh over the available devices (data x model), streams synthetic
Zipf batches (repro.data), runs the shard_map train step with the selected
gradient-sync mode (ring | hier | sparse — the paper's primitive), logs
loss/throughput, and checkpoints.

For the paper's *iterative graph* workloads (PageRank / HADI / spectral)
the entry point is the device-resident engine instead:
``repro.graph.engine`` (used by ``repro.graph.pagerank`` et al. with
``backend="device"``) fuses k SpMV+reduce rounds into one dispatch.

``--dp-degrees auto`` goes through the calibrated autotuner with its
persistent plan cache (``repro.core.autotune``; cache at
``$REPRO_PLAN_CACHE`` or ``~/.cache/repro/plans``, ``--retune`` to force
a fresh sweep) — the full workflow is documented in TUNING.md.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import save as ckpt_save
from repro.configs import ARCHS, get_config
from repro.data.pipeline import Batcher
from repro.models import transformer as T
from repro.optim.adamw import AdamW
from repro.train.step import make_train_step, mesh_ctx


def batch_stream(cfg, batch: int, seq: int, seed: int = 0):
    """The launcher's deterministic synthetic batch source, as a reusable
    generator of numpy batch dicts (tokens / labels + per-arch extras).

    Exactly the sequence :func:`main` consumes: a seeded Zipf
    :class:`Batcher` plus one sequential ``RandomState(seed)`` for the
    multimodal tensors — so two streams with equal ``(cfg, batch, seq,
    seed)`` are byte-identical, and *exact resume* is "recreate the stream
    and skip the first k batches" (``repro.launch.soak`` relies on this
    for step-identical resumed trajectories)."""
    batcher = iter(Batcher(vocab=cfg.vocab, batch=batch, seq=seq, seed=seed))
    rng = np.random.RandomState(seed)
    while True:
        toks, labels = next(batcher)
        b = {"tokens": toks, "labels": labels}
        if cfg.img_tokens:
            b["img_embeds"] = rng.randn(
                batch, cfg.img_tokens, cfg.d_model).astype(np.float32)
        if cfg.enc_layers:
            b["enc_frames"] = rng.randn(
                batch, cfg.enc_seq, cfg.d_model).astype(np.float32)
        yield b


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=list(ARCHS))
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale variant of the arch (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--sync", default="ring", choices=["ring", "hier", "sparse"])
    ap.add_argument("--dp-degrees", default="auto",
                    help="butterfly degree sequence for the data axis, e.g. "
                         "'4,4'; 'auto' (default) resolves through the "
                         "calibrated autotuner (repro.core.autotune, built "
                         "on repro.core.topology.tune): the fabric is the "
                         "stored calibration for this backend when one "
                         "exists (else the nominal TPU fabric per axis) and "
                         "the chosen degrees are cached persistently in "
                         "$REPRO_PLAN_CACHE (default ~/.cache/repro/plans), "
                         "so repeat launches skip the sweep — see TUNING.md; "
                         "'rr' keeps one round-robin (degree = axis size) "
                         "stage per axis")
    ap.add_argument("--retune", action="store_true",
                    help="bypass the persistent plan cache for this launch: "
                         "re-run the degree sweep and overwrite the cached "
                         "plan (use after recalibrating the fabric or "
                         "changing the workload shape)")
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--merge", default="sort",
                    choices=["sort", "fused", "banded"],
                    help="per-butterfly-layer merge for sparse sync: full "
                         "re-sort, the fused Pallas rank-merge pipeline, or "
                         "its band-limited (near-linear tile work) variant")
    ap.add_argument("--wire", default="raw",
                    choices=["raw", "delta", "delta+bf16", "delta+int8ef"],
                    help="on-wire payload encoding for sparse sync "
                         "(repro.kernels.wirecodec): 'delta' bit-packs the "
                         "sorted index stream (bit-identical results); "
                         "'delta+bf16' / 'delta+int8ef' additionally "
                         "quantize values, the latter with an error-"
                         "feedback carry re-injected each step; requires "
                         "--sync sparse for non-raw values")
    ap.add_argument("--sync-overlap", default="off",
                    choices=["off", "bucketed"],
                    help="gradient-sync schedule (hier/sparse sync only): "
                         "'bucketed' splits the dense butterfly leaves into "
                         "byte-bounded buckets issued stage-major, so sync "
                         "collectives interleave with compute instead of "
                         "forming one monolithic chain; results are bitwise "
                         "identical to 'off' (tests/test_overlap.py)")
    ap.add_argument("--sync-bucket-kb", type=int, default=4096,
                    help="bucket byte budget (KiB) for --sync-overlap "
                         "bucketed; leaves above the budget get a bucket "
                         "of their own")
    ap.add_argument("--replication", type=int, default=1,
                    help="r-way replicated data parallelism (paper SV fault "
                         "tolerance): the data axis hosts dp/r logical batch "
                         "shards, each fed to r devices; gradient sync takes "
                         "each shard from its first alive replica")
    ap.add_argument("--dead", default="",
                    help="comma-separated dead data-slot ids to mask "
                         "(simulated failures; survivable iff every replica "
                         "group keeps an alive member, else DeadLogicalNode)")
    ap.add_argument("--data-axis", type=int, default=0,
                    help="data-parallel size (0 = all devices)")
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--untied", action="store_true",
                    help="untie embeddings (sparse sync acts on input emb)")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.untied:
        import dataclasses
        cfg = dataclasses.replace(cfg, tie_embeddings=False)

    ndev = len(jax.devices())
    dsize = args.data_axis or (ndev // args.model_axis)
    mesh = jax.make_mesh((dsize, args.model_axis), ("data", "model"))
    mc = mesh_ctx(mesh)
    dead = {int(x) for x in args.dead.split(",") if x} or None
    repl = ""
    if args.replication > 1 or dead:
        repl = (f" replication={args.replication}"
                f" dead={sorted(dead) if dead else []}")
    print(f"mesh data={dsize} model={args.model_axis}; arch={cfg.name} "
          f"({cfg.param_count()/1e6:.1f}M params) sync={args.sync}{repl}")

    if args.dp_degrees in ("rr", ""):
        dp_degrees = None                      # round-robin per axis
    elif args.dp_degrees == "auto":
        dp_degrees = "auto"
    else:
        degs = tuple(int(x) for x in args.dp_degrees.split(","))
        dp_degrees = {"data": degs}
    step, _ = make_train_step(cfg, mesh, sync=args.sync,
                              opt=AdamW(lr=args.lr),
                              microbatch=args.microbatch,
                              dp_degrees=dp_degrees,
                              sparse_tokens_hint=max(
                                  8, args.batch * args.seq // dsize),
                              sync_merge=args.merge, sync_wire=args.wire,
                              replication=args.replication, dead=dead,
                              retune=args.retune,
                              sync_overlap=args.sync_overlap,
                              sync_bucket_bytes=args.sync_bucket_kb * 1024)
    params = T.init_params(cfg, mc.tp, seed=args.seed)
    opt_state = AdamW().init(params)
    stream = batch_stream(cfg, args.batch, args.seq, seed=args.seed)

    t_start = time.time()
    r = args.replication
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(stream).items()}
        if r > 1:
            # mirror the logical batch onto every replica slab: device
            # i + j*(data/r) sees logical shard i's rows for all j
            batch = {k: jnp.tile(v, (r,) + (1,) * (v.ndim - 1))
                     for k, v in batch.items()}
        params, opt_state, m = step(params, opt_state, batch)
        if i % 10 == 0 or i == args.steps - 1:
            dt = time.time() - t_start
            tput = (i + 1) * args.batch * args.seq / dt
            print(f"step {i:5d} loss {float(m['loss']):.4f} "
                  f"gnorm {float(m['gnorm']):.3f} aux {float(m['aux']):.4f} "
                  f"tok/s {tput:.0f}")
    if args.ckpt:
        ckpt_save(args.ckpt, {"params": params},
                  meta={"arch": cfg.name, "steps": args.steps})
        print(f"checkpoint -> {args.ckpt}")
    return float(m["loss"])


if __name__ == "__main__":
    main()
