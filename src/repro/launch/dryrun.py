"""Dry-run launcher: trace assigned model/shape pairs on 512 fake host
devices and report modeled memory, collective bytes and step cost."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede every other import (jax locks device count on first init).
import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import (ARCHS, ASSIGNED_SHAPES, SHAPES, get_config, pair_plan)
from repro.core.netmodel import (HBM_BYTES_PER_S, ICI_BYTES_PER_S,
                                 PEAK_FLOPS_BF16)
from repro.launch.hlo_stats import (collective_stats, dot_flops,
                                    total_collective_bytes)
from repro.launch.memmodel import modeled_memory
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (decode_arg_specs, opt_specs, params_specs,
                                prefill_batch_specs, train_batch_specs)
from repro.train.step import (make_decode_step, make_prefill_step,
                              make_train_step, mesh_ctx)

HBM_PER_CHIP = 16e9  # v5e


def _auto_microbatch(global_batch: int, seq: int, mesh,
                     target_tokens: int = 8192) -> int:
    """Smallest divisor of the per-device batch whose microbatch holds
    <= target_tokens tokens (bounds activation / MoE-dispatch memory)."""
    dp = mesh.devices.size // mesh.shape["model"]
    b_loc = max(1, global_batch // dp)
    tokens_dev = b_loc * seq
    need = max(1, -(-tokens_dev // target_tokens))
    for micro in range(need, b_loc + 1):
        if b_loc % micro == 0:
            return micro
    return b_loc


def lower_pair(arch: str, shape_name: str, mesh, sync: str = "ring",
               overrides: Optional[Dict[str, Any]] = None,
               microbatch: Optional[int] = None,
               dp_degrees: Optional[Dict[str, tuple]] = None,
               serve2d: bool = False):
    """Lower (arch x shape) on mesh; returns (lowered, cfg, meta).

    ``overrides``: dataclasses.replace kwargs on the ModelConfig (perf
    hillclimb knobs: moe_capacity, remat_policy, tie_embeddings, ...).
    """
    import dataclasses as _dc
    variant = pair_plan(arch, shape_name)
    if variant is None:
        return None, None, {"skipped": "long_500k inapplicable (DESIGN.md)"}
    cfg = get_config(arch, variant)
    if overrides:
        cfg = _dc.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    mc = mesh_ctx(mesh)

    if shape.kind == "train":
        micro = microbatch or _auto_microbatch(shape.global_batch,
                                               shape.seq_len, mesh)
        dp = mesh.devices.size // mesh.shape["model"]
        hint = max(8, shape.global_batch * shape.seq_len // dp)
        step, _ = make_train_step(cfg, mesh, sync=sync, donate=True,
                                  microbatch=micro, sparse_tokens_hint=hint,
                                  dp_degrees=dp_degrees)
        lowered = step.lower(params_specs(cfg, mc.tp), opt_specs(cfg, mc.tp),
                             train_batch_specs(cfg, shape))
        tokens = shape.global_batch * shape.seq_len
        flops_factor = 6.0
    elif shape.kind == "prefill":
        step, _ = make_prefill_step(cfg, mesh, max_seq=shape.seq_len)
        lowered = step.lower(params_specs(cfg, mc.tp),
                             prefill_batch_specs(cfg, shape))
        tokens = shape.global_batch * shape.seq_len
        flops_factor = 2.0
    else:
        seq_sharded = shape.kind == "decode_long"
        shards = mesh.shape["data"] if seq_sharded else 1
        step, _ = make_decode_step(cfg, mesh, seq_sharded=seq_sharded,
                                   seq_shards=shards, serve2d=serve2d)
        token, pos, cache, extras = decode_arg_specs(cfg, shape, mesh,
                                                     seq_sharded)
        lowered = step.lower(params_specs(cfg, mc.tp), token, pos, cache,
                             *extras)
        tokens = shape.global_batch
        flops_factor = 2.0
    meta = {"variant": variant, "tokens": tokens,
            "flops_factor": flops_factor,
            "active_params": cfg.active_param_count(),
            "total_params": cfg.param_count(),
            "n_periods": cfg.n_periods,
            "microbatch": locals().get("micro", 1),
            "cfg_obj": cfg, "shape_obj": shape}
    return lowered, cfg, meta


def analyse(lowered, cfg, meta, mesh, parse_hlo: bool = True) -> Dict[str, Any]:
    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0
    chips = mesh.devices.size
    out: Dict[str, Any] = {k: v for k, v in meta.items()
                           if k not in ("cfg_obj", "shape_obj")}
    out.update({"cfg_obj": meta["cfg_obj"], "shape_obj": meta["shape_obj"]})
    out.update({"chips": int(chips), "compile_s": round(compile_s, 1),
                "mesh": "x".join(str(mesh.shape[a]) for a in mesh.axis_names)})

    try:
        mem = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                out[k] = int(v)
        live = (out.get("argument_size_in_bytes", 0)
                + out.get("temp_size_in_bytes", 0)
                - out.get("alias_size_in_bytes", 0))
        out["bytes_per_device"] = int(live)
    except Exception as e:  # pragma: no cover
        out["memory_analysis_error"] = str(e)

    # analytic TPU-target memory (CPU-backend temp_size over-schedules remat
    # regions — see EXPERIMENTS.md §Dry-run probes)
    try:
        mm = modeled_memory(meta["cfg_obj"], meta["shape_obj"], mesh,
                            meta.get("microbatch", 1))
        out["modeled_memory"] = {k: round(v / 1e9, 3) for k, v in mm.items()}
        out["fits_hbm"] = bool(mm["total"] < HBM_PER_CHIP)
    except Exception as e:  # pragma: no cover
        out["memmodel_error"] = str(e)

    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        out["hlo_flops"] = float(cost.get("flops", -1))
        out["hlo_bytes"] = float(cost.get("bytes accessed", -1))
    except Exception as e:  # pragma: no cover
        out["cost_analysis_error"] = str(e)

    if parse_hlo:
        try:
            text = compiled.as_text()
            stats = collective_stats(text, default_trip=meta["n_periods"])
            out["collectives"] = {k: {"count": v["count"],
                                      "bytes": round(v["bytes"])}
                                  for k, v in stats.items()}
            out["collective_bytes"] = float(total_collective_bytes(stats))
            out["hlo_text_bytes"] = len(text)
            corrected, flat = dot_flops(text, default_trip=meta["n_periods"])
            out["dot_flops_corrected"] = corrected
            out["dot_flops_flat"] = flat
            loop_factor = corrected / flat if flat > 0 else 1.0
            out["loop_expansion_factor"] = round(loop_factor, 2)
            # cost_analysis counts while bodies once; scale by the measured
            # loop expansion (dots dominate both flops and bytes)
            out["hlo_flops_corrected"] = out.get("hlo_flops", 0.0) * loop_factor
            out["hlo_bytes_corrected"] = out.get("hlo_bytes", 0.0) * loop_factor
        except Exception as e:  # pragma: no cover
            out["hlo_parse_error"] = str(e)

    # roofline terms (per-device / per-chip view)
    flops = out.get("hlo_flops_corrected", out.get("hlo_flops", 0.0))
    hbytes = out.get("hlo_bytes_corrected", out.get("hlo_bytes", 0.0))
    cbytes = out.get("collective_bytes", 0.0)
    out["t_compute_s"] = flops / PEAK_FLOPS_BF16
    out["t_memory_s"] = hbytes / HBM_BYTES_PER_S
    out["t_collective_s"] = cbytes / ICI_BYTES_PER_S
    terms = {"compute": out["t_compute_s"], "memory": out["t_memory_s"],
             "collective": out["t_collective_s"]}
    out["bottleneck"] = max(terms, key=terms.get)
    model_flops = (meta["flops_factor"] * meta["active_params"]
                   * meta["tokens"]) / chips
    out["model_flops_per_chip"] = model_flops
    out["useful_compute_ratio"] = (model_flops / flops) if flops > 0 else None
    return out


def run_pair(arch: str, shape_name: str, multi_pod: bool, sync: str,
             outdir: Optional[str], parse_hlo: bool = True,
             overrides: Optional[Dict[str, Any]] = None,
             microbatch: Optional[int] = None,
             dp_degrees: Optional[Dict[str, tuple]] = None,
             serve2d: bool = False,
             tag_suffix: str = "") -> Dict[str, Any]:
    mesh = make_production_mesh(multi_pod=multi_pod)
    lowered, cfg, meta = lower_pair(arch, shape_name, mesh, sync,
                                    overrides=overrides, microbatch=microbatch,
                                    dp_degrees=dp_degrees, serve2d=serve2d)
    if lowered is None:
        res = dict(meta)
        res.update({"arch": arch, "shape": shape_name,
                    "mesh": "2x16x16" if multi_pod else "16x16"})
    else:
        res = analyse(lowered, cfg, meta, mesh, parse_hlo)
        res.update({"arch": arch, "shape": shape_name, "sync": sync,
                    "overrides": overrides or {}})
    res.pop("cfg_obj", None)
    res.pop("shape_obj", None)
    if outdir:
        os.makedirs(outdir, exist_ok=True)
        tag = f"{arch}_{shape_name}_{res.get('mesh', 'skip')}_{sync}{tag_suffix}"
        with open(os.path.join(outdir, tag + ".json"), "w") as f:
            json.dump(res, f, indent=2, default=str)
    return res


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--sync", default="ring", choices=["ring", "hier", "sparse"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--no-hlo", action="store_true",
                    help="skip HLO text parsing (faster)")
    args = ap.parse_args()

    archs = list(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(ASSIGNED_SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch} x {shape} x {'2x16x16' if mp else '16x16'}"
                try:
                    r = run_pair(arch, shape, mp, args.sync, args.out,
                                 parse_hlo=not args.no_hlo)
                    if "skipped" in r:
                        print(f"SKIP {tag}: {r['skipped']}")
                        continue
                    print(f"OK   {tag}: compile {r['compile_s']}s "
                          f"mem/dev {r.get('bytes_per_device', 0)/1e9:.2f}GB "
                          f"flops {r.get('hlo_flops', 0):.3g} "
                          f"coll {r.get('collective_bytes', 0)/1e6:.1f}MB "
                          f"bottleneck={r.get('bottleneck')}")
                except Exception as e:
                    failures.append((tag, str(e)))
                    print(f"FAIL {tag}: {e}")
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES")
        raise SystemExit(1)
    print("\nall dry-runs green")


if __name__ == "__main__":
    main()
