"""ShapeDtypeStruct stand-ins for every (arch x input-shape) pair.

Weak-type-correct, shardable, zero allocation — the dry-run lowers and
compiles against these.  For [vlm]/[audio] archs the modality frontend is a
stub: ``input_specs`` hands the backbone precomputed patch/frame embeddings
of the right shape (the one sanctioned carve-out).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import InputShape
from repro.models.common import ModelConfig
from repro.optim.adamw import AdamW
from repro.train.step import init_cache_global, mesh_ctx


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def train_batch_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    b, t = shape.global_batch, shape.seq_len
    t_text = t - cfg.img_tokens if cfg.img_tokens else t
    out = {"tokens": sds((b, t_text), jnp.int32),
           "labels": sds((b, t_text), jnp.int32)}
    if cfg.img_tokens:
        out["img_embeds"] = sds((b, cfg.img_tokens, cfg.d_model), jnp.float32)
    if cfg.enc_layers:
        out["enc_frames"] = sds((b, cfg.enc_seq, cfg.d_model), jnp.float32)
    return out


def prefill_batch_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    b, t = shape.global_batch, shape.seq_len
    t_text = t - cfg.img_tokens if cfg.img_tokens else t
    out = {"tokens": sds((b, t_text), jnp.int32)}
    if cfg.img_tokens:
        out["img_embeds"] = sds((b, cfg.img_tokens, cfg.d_model), jnp.float32)
    if cfg.enc_layers:
        out["enc_frames"] = sds((b, cfg.enc_seq, cfg.d_model), jnp.float32)
    return out


def params_specs(cfg: ModelConfig, tp: int):
    from repro.models import transformer as T
    return jax.eval_shape(lambda: T.init_params(cfg, tp))


def opt_specs(cfg: ModelConfig, tp: int):
    p = params_specs(cfg, tp)
    return jax.eval_shape(lambda q: AdamW().init(q), p)


def decode_arg_specs(cfg: ModelConfig, shape: InputShape, mesh,
                     seq_sharded: bool):
    mc = mesh_ctx(mesh)
    b = shape.global_batch
    cache = jax.eval_shape(
        lambda: init_cache_global(cfg, mc, b, shape.seq_len, seq_sharded))
    token = sds((b,), jnp.int32)
    pos = sds((b,), jnp.int32)
    extras = ()
    if cfg.enc_layers:
        kvg = cfg.kv_local(mc.tp) * mc.tp
        cc = (sds((cfg.n_periods, b, cfg.enc_seq, kvg, cfg.hd), cfg.dtype),
              sds((cfg.n_periods, b, cfg.enc_seq, kvg, cfg.hd), cfg.dtype))
        extras = (cc,)
    return token, pos, cache, extras
