"""Roofline table generator: reads results/dryrun/*.json -> markdown.

    PYTHONPATH=src python -m repro.launch.roofline [--dir results/dryrun]

Per (arch x shape) on the single-pod 16x16 mesh: the three roofline terms
(compute / memory / collective, seconds per step per chip), the dominant
bottleneck, MODEL_FLOPS = 6*N_active*D (or 2*N*D for inference), and the
useful-compute ratio MODEL_FLOPS / corrected HLO FLOPs.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs import ARCHS, ASSIGNED_SHAPES


def load_results(dirname: str, mesh: str = "16x16", sync: str = "ring"):
    out = {}
    for f in glob.glob(os.path.join(dirname, f"*_{mesh}_{sync}.json")):
        d = json.load(open(f))
        out[(d["arch"], d["shape"])] = d
    return out


def fmt_row(arch, shape, d):
    if d is None or "skipped" in d:
        return f"| {arch} | {shape} | — | — | — | skip (DESIGN.md) | — | — |"
    tc, tm, tl = d.get("t_compute_s", 0), d.get("t_memory_s", 0), \
        d.get("t_collective_s", 0)
    ratio = d.get("useful_compute_ratio")
    rs = f"{ratio:.2f}" if ratio else "—"
    fits = "yes" if d.get("fits_hbm") else "NO"
    return (f"| {arch} | {shape} | {tc:.3f} | {tm:.3f} | {tl:.3f} | "
            f"**{d.get('bottleneck', '?')}** | {rs} | {fits} |")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    res = load_results(args.dir, args.mesh)
    print(f"### Roofline table — {args.mesh} mesh (per-chip seconds/step)\n")
    print("| arch | shape | compute s | memory s | collective s | "
          "bottleneck | useful ratio | fits 16GB |")
    print("|---|---|---|---|---|---|---|---|")
    for arch in ARCHS:
        for shape in ASSIGNED_SHAPES:
            print(fmt_row(arch, shape, res.get((arch, shape))))
    # summary
    bn = {}
    for d in res.values():
        bn[d.get("bottleneck")] = bn.get(d.get("bottleneck"), 0) + 1
    print(f"\nbottleneck distribution: {bn}")


if __name__ == "__main__":
    main()
