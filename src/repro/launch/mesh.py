"""Production mesh builders (functions, never module-level constants — jax
device state must not be touched at import time)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod (TPU v5e); 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_dev_mesh(data: int = 1, model: int = 1):
    """Small mesh for CPU smoke tests / examples."""
    return jax.make_mesh((data, model), ("data", "model"))
