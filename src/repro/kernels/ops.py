"""jit'd public wrappers over the Pallas kernels.

``segment_compact`` / ``merge_add`` here are drop-in, kernel-backed versions
of the pure-jnp ones in ``core.sparse_vec`` (which remain the oracles).
``INTERPRET`` switches Pallas to interpret mode off-TPU; on TPU hardware the
same BlockSpecs compile natively.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.sparse_vec import SENTINEL, SparseChunk
from .onehot_scatter import onehot_scatter_add
from .rank_merge import rank_counts
from .spmv_ell import spmv_ell

INTERPRET = jax.default_backend() != "tpu"


def _compact_positions(idx: jax.Array, out_capacity: int):
    """Destination row per entry of a sorted idx stream (+ head flags)."""
    valid = idx != jnp.uint32(SENTINEL)
    is_head = jnp.concatenate([jnp.ones((1,), bool), idx[1:] != idx[:-1]]) & valid
    pos = jnp.cumsum(is_head.astype(jnp.int32)) - 1
    pos = jnp.where(valid & (pos < out_capacity), pos, out_capacity)
    return pos, is_head


def segment_compact(chunk: SparseChunk, out_capacity: Optional[int] = None
                    ) -> SparseChunk:
    """Kernel-backed coalesce of a sorted chunk (MXU one-hot scatter-add)."""
    out_capacity = out_capacity or chunk.capacity
    pos, is_head = _compact_positions(chunk.idx, out_capacity)
    out_idx = jnp.full((out_capacity,), SENTINEL, jnp.uint32)
    out_idx = out_idx.at[jnp.where(is_head, pos, out_capacity)].set(
        chunk.idx, mode="drop")
    val = chunk.val if chunk.val.ndim == 2 else chunk.val[:, None]
    out_val = onehot_scatter_add(pos, val, out_capacity, interpret=INTERPRET)
    out_val = out_val.astype(chunk.val.dtype)
    if chunk.val.ndim == 1:
        out_val = out_val[:, 0]
    return SparseChunk(idx=out_idx, val=out_val)


def merge_add(a: SparseChunk, b: SparseChunk,
              out_capacity: Optional[int] = None) -> SparseChunk:
    """Kernel-backed merge of two sorted chunks with collision summation.

    1. merge ranks via the blocked compare kernel (no data-dependent loop)
    2. build the merged idx stream with one scatter
    3. coalesce values straight from the *inputs* with a single fused
       one-hot matmul: final_pos[e] = compact_pos[rank[e]].
    """
    ca, cb = a.capacity, b.capacity
    out_capacity = out_capacity or (ca + cb)
    rank_a = jnp.arange(ca, dtype=jnp.int32) + rank_counts(
        a.idx, b.idx, strict=True, interpret=INTERPRET)
    rank_b = jnp.arange(cb, dtype=jnp.int32) + rank_counts(
        b.idx, a.idx, strict=False, interpret=INTERPRET)
    merged_idx = jnp.zeros((ca + cb,), jnp.uint32)
    merged_idx = merged_idx.at[rank_a].set(a.idx)
    merged_idx = merged_idx.at[rank_b].set(b.idx)
    pos, is_head = _compact_positions(merged_idx, out_capacity)
    out_idx = jnp.full((out_capacity,), SENTINEL, jnp.uint32)
    out_idx = out_idx.at[jnp.where(is_head, pos, out_capacity)].set(
        merged_idx, mode="drop")
    # entry e of (a ++ b) lands at compact position pos[rank_e]
    ranks = jnp.concatenate([rank_a, rank_b])
    final_pos = pos[ranks]
    val_a = a.val if a.val.ndim == 2 else a.val[:, None]
    val_b = b.val if b.val.ndim == 2 else b.val[:, None]
    cat = jnp.concatenate([val_a, val_b], axis=0)
    out_val = onehot_scatter_add(final_pos, cat, out_capacity,
                                 interpret=INTERPRET).astype(a.val.dtype)
    if a.val.ndim == 1:
        out_val = out_val[:, 0]
    return SparseChunk(idx=out_idx, val=out_val)


def spmv(cols: jax.Array, weights: jax.Array, x: jax.Array) -> jax.Array:
    """ELL SpMV (PageRank hotspot)."""
    return spmv_ell(cols, weights, x, interpret=INTERPRET)
