"""jit'd public wrappers over the Pallas kernels.

``segment_compact`` / ``merge_add`` here are drop-in, kernel-backed versions
of the pure-jnp ones in ``core.sparse_vec`` (which remain the oracles).
``INTERPRET`` switches Pallas to interpret mode off-TPU; on TPU hardware the
same BlockSpecs compile natively.

Merge modes (``mode="fused" | "banded"``): both run the same rank-merge +
compact + one-hot scatter-add pipeline; ``banded`` additionally exploits
the monotonicity of the sorted streams to band-limit both kernels — the
rank compare planes collapse to frontier tiles and the scatter's inner grid
dimension to the static ``ceil(band*bm/bk)+1`` bound (see
``kernels.costmodel`` for the tile/FLOP accounting).  Banded results are
bit-identical to fused and to the sort-based oracle.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.sparse_vec import SENTINEL, SparseChunk
from .onehot_scatter import banded_onehot_scatter_add, onehot_scatter_add
from .rank_merge import rank_counts
from .spmv_ell import spmv_ell

INTERPRET = jax.default_backend() != "tpu"

MERGE_KERNEL_MODES = ("fused", "banded")


def _check_mode(mode: str) -> None:
    if mode not in MERGE_KERNEL_MODES:
        raise ValueError(
            f"mode must be one of {MERGE_KERNEL_MODES}, got {mode!r}")


def _compact_positions(idx: jax.Array, out_capacity: int):
    """Destination row per entry of a sorted idx stream (+ head flags)."""
    valid = idx != jnp.uint32(SENTINEL)
    is_head = jnp.concatenate([jnp.ones((1,), bool), idx[1:] != idx[:-1]]) & valid
    pos = jnp.cumsum(is_head.astype(jnp.int32)) - 1
    pos = jnp.where(valid & (pos < out_capacity), pos, out_capacity)
    return pos, is_head


def _compact_scatter_add(merged_idx: jax.Array, ranks: Optional[jax.Array],
                         val: jax.Array, out_capacity: int,
                         mode: str = "fused", band: Optional[int] = None,
                         scale: Optional[jax.Array] = None,
                         out_dtype=None
                         ) -> Tuple[SparseChunk, jax.Array]:
    """Shared tail of every compact pipeline: scatter the head index of each
    duplicate group, then coalesce values with a single one-hot MXU matmul.

    ``merged_idx``: sorted [C] uint32 stream; ``ranks``: position of value
    row e within that stream (None when the rows are already in stream
    order); ``val``: [C] or [C, W].  Rows whose compact position exceeds
    ``out_capacity`` fall off the one-hot tiles (drop semantics).

    ``mode="fused"`` feeds the scatter-add straight from the input layout
    (``final_pos[e] = pos[ranks[e]]`` — arbitrary order, so the kernel
    scans every input tile per output tile).  ``mode="banded"`` first
    permutes the values into merge order, making the destination stream
    ``pos`` non-decreasing with multiplicity <= ``band``, which lets the
    band-limited kernel visit only ceil(band*bm/bk)+1 input tiles per
    output tile.  Returns ``(chunk, n_unique)``.

    ``scale`` [C] f32: per-source-row dequantization factor fused into the
    one-hot matmul (wire-decode path — ``val`` stays in its on-wire dtype).
    ``out_dtype`` overrides the output value dtype (default: ``val``'s own
    dtype; a fused decode wants the compute dtype instead).
    """
    _check_mode(mode)
    out_dtype = out_dtype if out_dtype is not None else val.dtype
    pos, is_head = _compact_positions(merged_idx, out_capacity)
    out_idx = jnp.full((out_capacity,), SENTINEL, jnp.uint32)
    out_idx = out_idx.at[jnp.where(is_head, pos, out_capacity)].set(
        merged_idx, mode="drop")
    v2 = val if val.ndim == 2 else val[:, None]
    if mode == "banded":
        if band is None:
            raise ValueError("banded mode needs a source-multiplicity bound")
        if ranks is not None:                    # permute into merge order
            v2 = jnp.zeros_like(v2).at[ranks].set(v2)
            if scale is not None:
                scale = jnp.zeros_like(scale).at[ranks].set(scale)
        out_val = banded_onehot_scatter_add(
            pos, v2, out_capacity, band=band, scale=scale,
            interpret=INTERPRET).astype(out_dtype)
    else:
        final_pos = pos if ranks is None else pos[ranks]
        out_val = onehot_scatter_add(final_pos, v2, out_capacity, scale=scale,
                                     interpret=INTERPRET).astype(out_dtype)
    if val.ndim == 1:
        out_val = out_val[:, 0]
    return (SparseChunk(idx=out_idx, val=out_val),
            jnp.sum(is_head.astype(jnp.int32)))


def segment_compact(chunk: SparseChunk, out_capacity: Optional[int] = None,
                    max_dup: Optional[int] = None) -> SparseChunk:
    """Kernel-backed coalesce of a sorted chunk (MXU one-hot scatter-add).

    ``max_dup``: optional bound on how many times any index repeats in the
    chunk; when given, the band-limited kernel is used (a sorted chunk is
    already in stream order, so no permutation is needed).
    """
    out_capacity = out_capacity or chunk.capacity
    mode = "banded" if max_dup is not None else "fused"
    out, _ = _compact_scatter_add(chunk.idx, None, chunk.val, out_capacity,
                                  mode=mode, band=max_dup)
    return out


def merge_add(a: SparseChunk, b: SparseChunk,
              out_capacity: Optional[int] = None,
              mode: str = "fused") -> SparseChunk:
    """Kernel-backed merge of two sorted chunks with collision summation.

    1. merge ranks via the blocked compare kernel (no data-dependent loop)
    2. build the merged idx stream with one scatter
    3. coalesce values straight from the *inputs* with a single fused
       one-hot matmul: final_pos[e] = compact_pos[rank[e]].

    ``mode="banded"`` assumes each input chunk has unique valid indices
    (multiplicity <= 2 in the merge) and band-limits both kernels.
    """
    _check_mode(mode)
    banded = mode == "banded"
    ca, cb = a.capacity, b.capacity
    out_capacity = out_capacity or (ca + cb)
    rank_a = jnp.arange(ca, dtype=jnp.int32) + rank_counts(
        a.idx, b.idx, strict=True, interpret=INTERPRET, banded=banded)
    rank_b = jnp.arange(cb, dtype=jnp.int32) + rank_counts(
        b.idx, a.idx, strict=False, interpret=INTERPRET, banded=banded)
    merged_idx = jnp.zeros((ca + cb,), jnp.uint32)
    merged_idx = merged_idx.at[rank_a].set(a.idx)
    merged_idx = merged_idx.at[rank_b].set(b.idx)
    # entry e of (a ++ b) lands at compact position pos[rank_e]
    ranks = jnp.concatenate([rank_a, rank_b])
    cat = jnp.concatenate([a.val, b.val], axis=0)
    out, _ = _compact_scatter_add(merged_idx, ranks, cat, out_capacity,
                                  mode=mode, band=2)
    return out


def merge_sorted_runs(idx: jax.Array, val: jax.Array, out_capacity: int,
                      mode: str = "fused",
                      row_scale: Optional[jax.Array] = None,
                      out_dtype=None) -> Tuple[SparseChunk, jax.Array]:
    """Fused k-way merge: rank-merge sorted runs, compact duplicate indices,
    and scatter-add the values in one pass (no full re-sort).

    This is the per-layer hot path of the butterfly: after ``all_to_all``
    each device holds k *already sorted* runs (``idx`` [k, cap] uint32,
    SENTINEL-padded; ``val`` [k, cap] or [k, cap, W]).  The sort-based
    path re-sorts all k*cap rows from scratch; here the merge permutation
    is computed directly instead:

    1. rank of run r's element i in the merge =
       ``i + sum_s #{j : runs[s][j] (<= if s<r else <) runs[r][i]}``
       (non-strict against earlier runs keeps the merge stable) — k*(k-1)
       blocked compare-and-reduce kernels, no data-dependent loop;
    2. one scatter materializes the merged idx stream; head flags + cumsum
       give each entry its compacted destination row;
    3. values go straight from the input layout into the compacted output
       through a single one-hot MXU matmul: ``final_pos[e] = pos[rank[e]]``.

    ``mode="banded"`` band-limits both kernel families using the run
    structure: the rank compare planes resolve non-frontier tiles from
    scalar-prefetched block edges, and the scatter-add (on values permuted
    into merge order, where destinations are monotone with multiplicity
    <= k) visits only ceil(k*bm/bk)+1 input tiles per output tile.  It
    assumes each run's valid indices are unique — the butterfly invariant
    (runs are compacted chunks), giving merge multiplicity <= k.

    Returns ``(chunk, overflow)`` with the same contract as
    ``sparse_vec.segment_compact`` + ``compact_overflow`` on the sorted
    concatenation: ``overflow`` counts unique indices beyond
    ``out_capacity`` (dropped).  Sentinel padding sorts to the tail and is
    dropped by the compact step automatically.

    ``row_scale`` [k] f32: per-run dequantization scale (the int8 wire
    format ships one scale per all_to_all row); it is broadcast per entry
    and fused into the scatter-add kernel, so quantized values are widened
    only in-register.  ``out_dtype`` sets the output value dtype (wire
    decodes pass the compute dtype; default keeps ``val``'s dtype).
    """
    _check_mode(mode)
    banded = mode == "banded"
    k, cap = idx.shape
    total = k * cap
    ranks = []
    for r in range(k):
        rk = jnp.arange(cap, dtype=jnp.int32)
        for s in range(k):
            if s == r:
                continue
            rk = rk + rank_counts(idx[r], idx[s], strict=(s > r),
                                  interpret=INTERPRET, banded=banded)
        ranks.append(rk)
    rank = jnp.stack(ranks).reshape((total,))        # bijection on [0, total)
    flat_idx = idx.reshape((total,))
    merged_idx = jnp.zeros((total,), jnp.uint32).at[rank].set(flat_idx)
    scale = None
    if row_scale is not None:
        scale = jnp.repeat(row_scale.astype(jnp.float32), cap)
    out, n_unique = _compact_scatter_add(
        merged_idx, rank, val.reshape((total,) + val.shape[2:]), out_capacity,
        mode=mode, band=k, scale=scale, out_dtype=out_dtype)
    return out, jnp.maximum(n_unique - out_capacity, 0)


def spmv(cols: jax.Array, weights: jax.Array, x: jax.Array) -> jax.Array:
    """ELL SpMV (PageRank hotspot)."""
    return spmv_ell(cols, weights, x, interpret=INTERPRET)
