"""One-hot MXU scatter-add — the TPU-native form of the paper's merge-sum.

The paper's CPU insight (§III-A): summing sparse vectors by *coherent
addition of sorted index streams* is ~5x faster than hash tables because it
matches the memory system.  The TPU analogue: once destinations ``pos`` are
known (sorted indices make them a cheap cumsum), the scatter-add

    out[p, :] += sum_{i : pos_i = p} val[i, :]

is a matmul  ``out = OneHot(pos)^T @ val``  — which runs on the MXU at full
throughput instead of serializing through scatter hardware.  This kernel is
the workhorse behind ``segment_compact`` and ``merge_add``.

Tiling: grid (I, J, K) over (out-rows/bm, width/bn, in-rows/bk), K innermost
accumulating into the (bm, bn) VMEM out tile.  The one-hot tile (bk, bm) is
generated in-register from the pos block — it never touches HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.compat import CompilerParams


def _kernel(pos_ref, val_ref, out_ref, *, bm: int, bk: int):
    i = pl.program_id(0)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    pos = pos_ref[...]                                   # [bk] int32
    rows = i * bm + jax.lax.broadcasted_iota(jnp.int32, (bk, bm), 1)
    onehot = (pos[:, None] == rows).astype(jnp.float32)  # [bk, bm]
    out_ref[...] += jax.lax.dot_general(
        onehot, val_ref[...].astype(jnp.float32),
        (((0,), (0,)), ((), ())),                        # contract over bk
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("num_rows", "bm", "bn", "bk", "interpret"))
def onehot_scatter_add(pos: jax.Array, val: jax.Array, num_rows: int,
                       *, bm: int = 128, bn: int = 128, bk: int = 512,
                       interpret: bool = True) -> jax.Array:
    """out[num_rows, W] = scatter-add of val [C, W] at rows pos [C].

    Out-of-range pos (e.g. drop bins, padding parked at num_rows) fall off
    every one-hot tile and vanish — free drop semantics.
    """
    c, w = val.shape
    # pad to tile multiples
    cp = pl.cdiv(c, bk) * bk
    wp = pl.cdiv(w, bn) * bn
    rp = pl.cdiv(num_rows, bm) * bm
    pos_p = jnp.full((cp,), -1, jnp.int32).at[:c].set(pos.astype(jnp.int32))
    val_p = jnp.zeros((cp, wp), val.dtype).at[:c, :w].set(val)

    grid = (rp // bm, wp // bn, cp // bk)
    out = pl.pallas_call(
        functools.partial(_kernel, bm=bm, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk,), lambda i, j, k: (k,)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rp, wp), jnp.float32),
        compiler_params=CompilerParams(dimension_semantics=("parallel", "parallel",
                                       "arbitrary")),
        interpret=interpret,
    )(pos_p, val_p)
    return out[:num_rows, :w]
