"""One-hot MXU scatter-add — the TPU-native form of the paper's merge-sum.

The paper's CPU insight (§III-A): summing sparse vectors by *coherent
addition of sorted index streams* is ~5x faster than hash tables because it
matches the memory system.  The TPU analogue: once destinations ``pos`` are
known (sorted indices make them a cheap cumsum), the scatter-add

    out[p, :] += sum_{i : pos_i = p} val[i, :]

is a matmul  ``out = OneHot(pos)^T @ val``  — which runs on the MXU at full
throughput instead of serializing through scatter hardware.  This kernel is
the workhorse behind ``segment_compact`` and ``merge_add``.

Tiling: grid (I, J, K) over (out-rows/bm, width/bn, in-rows/bk), K innermost
accumulating into the (bm, bn) VMEM out tile.  The one-hot tile (bk, bm) is
generated in-register from the pos block — it never touches HBM.

``banded_onehot_scatter_add`` is the band-limited variant for *monotone*
``pos`` streams (merge order): when every destination row absorbs at most
``band`` sources, the sources of any bm-row output tile form a contiguous
window of at most band*bm rows, so a scalar-prefetched per-output-tile
start-block table shrinks the inner grid dimension from C/bk to the static
``band_inner_tiles(band, bm, bk) = ceil(band*bm/bk)+1`` — a C/(band*bm)-fold
cut of the MXU tile work.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.compat import CompilerParams, PrefetchScalarGridSpec

# default tile shapes (out-rows, width, in-rows) — shared with the
# costmodel so the instrumented tile/FLOP reports describe these kernels
BM, BN, BK = 128, 128, 512


def _kernel(pos_ref, val_ref, out_ref, *, bm: int, bk: int):
    i = pl.program_id(0)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    pos = pos_ref[...]                                   # [bk] int32
    rows = i * bm + jax.lax.broadcasted_iota(jnp.int32, (bk, bm), 1)
    onehot = (pos[:, None] == rows).astype(jnp.float32)  # [bk, bm]
    out_ref[...] += jax.lax.dot_general(
        onehot, val_ref[...].astype(jnp.float32),
        (((0,), (0,)), ((), ())),                        # contract over bk
        preferred_element_type=jnp.float32)


def _scaled_kernel(pos_ref, scale_ref, val_ref, out_ref, *, bm: int, bk: int):
    # Wire-decode fusion: val arrives in its on-wire dtype (int8/bf16) and
    # is dequantized in-register — scale_ref [bk] is the per-source-row
    # quantization scale — so the widened f32 form never touches HBM.
    i = pl.program_id(0)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    pos = pos_ref[...]                                   # [bk] int32
    rows = i * bm + jax.lax.broadcasted_iota(jnp.int32, (bk, bm), 1)
    onehot = (pos[:, None] == rows).astype(jnp.float32)  # [bk, bm]
    v = val_ref[...].astype(jnp.float32) * scale_ref[...][:, None]
    out_ref[...] += jax.lax.dot_general(
        onehot, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("num_rows", "bm", "bn", "bk", "interpret"))
def onehot_scatter_add(pos: jax.Array, val: jax.Array, num_rows: int,
                       *, scale: jax.Array | None = None,
                       bm: int = BM, bn: int = BN, bk: int = BK,
                       interpret: bool = True) -> jax.Array:
    """out[num_rows, W] = scatter-add of val [C, W] at rows pos [C].

    Out-of-range pos (e.g. drop bins, padding parked at num_rows) fall off
    every one-hot tile and vanish — free drop semantics.

    ``scale`` [C] f32, when given, multiplies each source row in-register
    before the one-hot matmul — the fused dequantization hook for the
    int8 wire format (``val`` stays in its on-wire dtype end to end).
    """
    c, w = val.shape
    # pad to tile multiples
    cp = pl.cdiv(c, bk) * bk
    wp = pl.cdiv(w, bn) * bn
    rp = pl.cdiv(num_rows, bm) * bm
    pos_p = jnp.full((cp,), -1, jnp.int32).at[:c].set(pos.astype(jnp.int32))
    val_p = jnp.zeros((cp, wp), val.dtype).at[:c, :w].set(val)

    grid = (rp // bm, wp // bn, cp // bk)
    pos_spec = pl.BlockSpec((bk,), lambda i, j, k: (k,))
    val_spec = pl.BlockSpec((bk, bn), lambda i, j, k: (k, j))
    if scale is None:
        kernel = functools.partial(_kernel, bm=bm, bk=bk)
        in_specs = [pos_spec, val_spec]
        operands = (pos_p, val_p)
    else:
        kernel = functools.partial(_scaled_kernel, bm=bm, bk=bk)
        in_specs = [pos_spec, pl.BlockSpec((bk,), lambda i, j, k: (k,)),
                    val_spec]
        scale_p = jnp.zeros((cp,), jnp.float32).at[:c].set(
            scale.astype(jnp.float32))
        operands = (pos_p, scale_p, val_p)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rp, wp), jnp.float32),
        compiler_params=CompilerParams(dimension_semantics=("parallel", "parallel",
                                       "arbitrary")),
        interpret=interpret,
    )(*operands)
    return out[:num_rows, :w]


# ---------------------------------------------------------------------------
# Band-limited variant for monotone pos streams
# ---------------------------------------------------------------------------

def band_inner_tiles(band: int, bm: int, bk: int) -> int:
    """Static bound on input tiles any output tile draws from: the <=band*bm
    source rows of a bm-row output tile are contiguous, so they span at most
    ceil(band*bm/bk) blocks plus one for start-of-window misalignment."""
    return -(-band * bm // bk) + 1


def _banded_kernel(starts_ref, pos_ref, val_ref, out_ref, *, bm: int, bk: int):
    i = pl.program_id(0)
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    pos = pos_ref[...]                                   # [bk] int32
    rows = i * bm + jax.lax.broadcasted_iota(jnp.int32, (bk, bm), 1)
    onehot = (pos[:, None] == rows).astype(jnp.float32)  # [bk, bm]
    out_ref[...] += jax.lax.dot_general(
        onehot, val_ref[...].astype(jnp.float32),
        (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def _banded_scaled_kernel(starts_ref, pos_ref, scale_ref, val_ref, out_ref,
                          *, bm: int, bk: int):
    # Banded twin of _scaled_kernel: fused per-source-row dequantization.
    i = pl.program_id(0)
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    pos = pos_ref[...]                                   # [bk] int32
    rows = i * bm + jax.lax.broadcasted_iota(jnp.int32, (bk, bm), 1)
    onehot = (pos[:, None] == rows).astype(jnp.float32)  # [bk, bm]
    v = val_ref[...].astype(jnp.float32) * scale_ref[...][:, None]
    out_ref[...] += jax.lax.dot_general(
        onehot, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("num_rows", "band", "bm", "bn",
                                             "bk", "interpret"))
def banded_onehot_scatter_add(pos: jax.Array, val: jax.Array, num_rows: int,
                              *, band: int, scale: jax.Array | None = None,
                              bm: int = BM, bn: int = BN,
                              bk: int = BK, interpret: bool = True
                              ) -> jax.Array:
    """Band-limited scatter-add: requires ``pos`` non-decreasing with at most
    ``band`` sources per destination row (rows parked at >= num_rows — drop
    bins / padding — must sit at the tail).

    A host-side searchsorted builds the per-output-tile start-block table;
    the kernel's BlockSpec index maps read it via scalar prefetch, so output
    tile i visits only input blocks [starts[i], starts[i] + KB) with the
    static KB = band_inner_tiles(band, bm, bk) — instead of all C/bk.
    Out-of-window rows load but never match the one-hot row range, and the
    window provably covers every in-range source, so the result is exactly
    ``onehot_scatter_add(pos, val, num_rows)``.

    ``scale`` [C] f32: fused per-source-row dequantization, as in
    :func:`onehot_scatter_add` (pad rows carry scale 0).
    """
    c, w = val.shape
    kb = band_inner_tiles(band, bm, bk)
    cp = pl.cdiv(c, bk) * bk
    wp = pl.cdiv(w, bn) * bn
    rp = pl.cdiv(num_rows, bm) * bm
    # pad (kb-1) extra blocks so starts[i]+t never reads out of bounds; the
    # pad rows are parked at -1 and never match any output row.
    cpad = cp + (kb - 1) * bk
    pos_i32 = pos.astype(jnp.int32)
    pos_p = jnp.full((cpad,), -1, jnp.int32).at[:c].set(pos_i32)
    val_p = jnp.zeros((cpad, wp), val.dtype).at[:c, :w].set(val)

    n_out_tiles = rp // bm
    first_src = jnp.searchsorted(pos_i32,
                                 jnp.arange(n_out_tiles, dtype=jnp.int32) * bm,
                                 side="left")
    # clamp: first_src == c on a c that is a block multiple would address
    # one block past the pad; shifting such (source-less) windows down one
    # block keeps every read in bounds without losing coverage.
    starts = jnp.minimum((first_src // bk).astype(jnp.int32),
                         jnp.int32(cpad // bk - kb))

    grid = (n_out_tiles, wp // bn, kb)
    pos_spec = pl.BlockSpec((bk,), lambda i, j, t, s: (s[i] + t,))
    val_spec = pl.BlockSpec((bk, bn), lambda i, j, t, s: (s[i] + t, j))
    if scale is None:
        kernel = functools.partial(_banded_kernel, bm=bm, bk=bk)
        in_specs = [pos_spec, val_spec]
        operands = (starts, pos_p, val_p)
    else:
        kernel = functools.partial(_banded_scaled_kernel, bm=bm, bk=bk)
        in_specs = [pos_spec,
                    pl.BlockSpec((bk,), lambda i, j, t, s: (s[i] + t,)),
                    val_spec]
        scale_p = jnp.zeros((cpad,), jnp.float32).at[:c].set(
            scale.astype(jnp.float32))
        operands = (starts, pos_p, scale_p, val_p)
    grid_spec = PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, t, s: (i, j)),
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((rp, wp), jnp.float32),
        compiler_params=CompilerParams(dimension_semantics=("parallel", "parallel",
                                       "arbitrary")),
        interpret=interpret,
    )(*operands)
    return out[:num_rows, :w]
