"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax.numpy as jnp


def onehot_scatter_add_ref(pos: jnp.ndarray, val: jnp.ndarray,
                           num_rows: int) -> jnp.ndarray:
    """out[p] = sum_{i: pos_i == p} val[i].  pos entries outside [0, num_rows)
    are dropped — including negatives (jnp's own .at[] would wrap them).
    val: [C, W] -> out [num_rows, W]."""
    pos = jnp.where(pos < 0, num_rows, pos)
    out = jnp.zeros((num_rows,) + val.shape[1:], jnp.float32)
    return out.at[pos].add(val.astype(jnp.float32), mode="drop")


def rank_counts_ref(a: jnp.ndarray, b: jnp.ndarray, side: str) -> jnp.ndarray:
    """counts[i] = #{j : b_j < a_i}  (side='left')  or <= (side='right').

    a, b: uint32 sorted.  Used to compute stable merge ranks:
      rank_a[i] = i + counts_left(a, b)[i]
      rank_b[j] = j + counts_right(b, a)[j]
    """
    bias = jnp.int64(-2**31) if a.dtype == jnp.int64 else jnp.int32(-2**31)
    ai = a.astype(jnp.int32) + jnp.int32(-2**31)
    bi = b.astype(jnp.int32) + jnp.int32(-2**31)
    if side == "left":
        return jnp.searchsorted(bi, ai, side="left").astype(jnp.int32)
    return jnp.searchsorted(bi, ai, side="right").astype(jnp.int32)


def spmv_ell_ref(cols: jnp.ndarray, weights: jnp.ndarray,
                 x: jnp.ndarray) -> jnp.ndarray:
    """ELL SpMV: y[r] = sum_k weights[r, k] * x[cols[r, k]].

    cols: int32 [R, K] (negative = padding), weights [R, K], x [N]."""
    safe = jnp.maximum(cols, 0)
    g = x[safe] * (cols >= 0)
    return jnp.sum(weights * g, axis=1)
