"""Merge-rank kernel: positions of two sorted streams in their merge.

The paper merges sorted sparse vectors pairwise (tree sum).  On TPU a
data-dependent two-pointer merge is hostile to the vector unit; instead the
merge *permutation* is computed directly:

    rank_a[i] = i + #{j : b_j <  a_i}       (stable: a before b on ties)
    rank_b[j] = j + #{i : a_i <= b_j}

The counting term is a blocked compare-and-reduce over the (Ca, Cb) plane —
pure VPU work with in-register iota tiles, no HBM intermediate.  Sentinel
padding (0xFFFFFFFF) sorts to the tail of the merge automatically.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.compat import CompilerParams

_BIAS = -(2 ** 31)


def _kernel(a_ref, b_ref, cnt_ref, *, strict: bool):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    bias = jnp.asarray(_BIAS, jnp.int32)
    a = a_ref[...].astype(jnp.int32) + bias      # [bm] order-preserving
    b = b_ref[...].astype(jnp.int32) + bias      # [bn]
    if strict:
        hits = (b[None, :] < a[:, None])
    else:
        hits = (b[None, :] <= a[:, None])
    cnt_ref[...] += jnp.sum(hits.astype(jnp.int32), axis=1)


@functools.partial(jax.jit, static_argnames=("strict", "bm", "bn", "interpret"))
def rank_counts(a: jax.Array, b: jax.Array, *, strict: bool = True,
                bm: int = 512, bn: int = 512,
                interpret: bool = True) -> jax.Array:
    """counts[i] = #{j : b_j < a_i} (strict) or <= (not strict); uint32 in."""
    ca, cb = a.shape[0], b.shape[0]
    cap = pl.cdiv(ca, bm) * bm
    cbp = pl.cdiv(cb, bn) * bn
    # pad a with MAX (counts for pads are garbage, sliced off), b with MAX
    # (never counted by '<' against real values; '<=' against MAX pads of a
    # is sliced off anyway).
    a_p = jnp.full((cap,), 0xFFFFFFFF, jnp.uint32).at[:ca].set(a)
    b_p = jnp.full((cbp,), 0xFFFFFFFF, jnp.uint32).at[:cb].set(b)

    grid = (cap // bm, cbp // bn)
    out = pl.pallas_call(
        functools.partial(_kernel, strict=strict),
        grid=grid,
        in_specs=[pl.BlockSpec((bm,), lambda i, j: (i,)),
                  pl.BlockSpec((bn,), lambda i, j: (j,))],
        out_specs=pl.BlockSpec((bm,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((cap,), jnp.int32),
        compiler_params=CompilerParams(dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(a_p, b_p)
    counts = out[:ca]
    # b's padding is MAX. strict '<': pads never count (nothing exceeds MAX).
    # non-strict '<=': pads DO count against queries that are themselves MAX
    # (sentinel rows of a are real array rows) — subtract them.
    if not strict and cbp != cb:
        counts = counts - jnp.where(a == jnp.uint32(0xFFFFFFFF),
                                    jnp.int32(cbp - cb), jnp.int32(0))
    return counts
