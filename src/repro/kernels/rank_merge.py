"""Merge-rank kernel: positions of two sorted streams in their merge.

The paper merges sorted sparse vectors pairwise (tree sum).  On TPU a
data-dependent two-pointer merge is hostile to the vector unit; instead the
merge *permutation* is computed directly:

    rank_a[i] = i + #{j : b_j <  a_i}       (stable: a before b on ties)
    rank_b[j] = j + #{i : a_i <= b_j}

The counting term is a blocked compare-and-reduce over the (Ca, Cb) plane —
pure VPU work with in-register iota tiles, no HBM intermediate.  Sentinel
padding (0xFFFFFFFF) sorts to the tail of the merge automatically.

``banded=True`` exploits the sortedness of *both* streams: per-block
min/max edges (scalar-prefetched) classify each (a-block, b-block) tile
against the merge frontier — tiles strictly below it contribute a constant
``bn`` per row, tiles strictly above contribute nothing, and only the
O(Ca/bm + Cb/bn) frontier tiles run the full compare-and-reduce.
``rank_tile_stats`` reports that classification (it is derived from the
same edge tables the kernel consumes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.compat import CompilerParams, PrefetchScalarGridSpec

_BIAS = -(2 ** 31)

# default compare-plane tile shape — shared with the costmodel
BM, BN = 512, 512


def _kernel(a_ref, b_ref, cnt_ref, *, strict: bool):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    bias = jnp.asarray(_BIAS, jnp.int32)
    a = a_ref[...].astype(jnp.int32) + bias      # [bm] order-preserving
    b = b_ref[...].astype(jnp.int32) + bias      # [bn]
    if strict:
        hits = (b[None, :] < a[:, None])
    else:
        hits = (b[None, :] <= a[:, None])
    cnt_ref[...] += jnp.sum(hits.astype(jnp.int32), axis=1)


# ---------------------------------------------------------------------------
# Banded variant: block-edge triage against the merge frontier
# ---------------------------------------------------------------------------

def _pad_sorted(x: jax.Array, block: int) -> jax.Array:
    """Pad a sorted uint32 stream with MAX to a block multiple."""
    n = x.shape[0]
    np_ = pl.cdiv(n, block) * block
    return jnp.full((np_,), 0xFFFFFFFF, jnp.uint32).at[:n].set(x)


def _block_edges(x_padded: jax.Array, block: int) -> jax.Array:
    """[2, nblocks] int32 (min, max) per block of a sorted padded stream,
    in the biased order-preserving int32 domain the kernels compare in."""
    b = (x_padded.astype(jnp.int32) + jnp.int32(_BIAS)).reshape((-1, block))
    return jnp.stack([b[:, 0], b[:, -1]])


def _tile_classes(a_edges: jax.Array, b_edges: jax.Array, strict: bool):
    """(full, skip) boolean [I, J] tables: b-block entirely below every row
    of the a-block (contributes bn per row), or entirely above (contributes
    nothing).  Everything else is a frontier tile.  Mirrors the kernel's
    ``pl.when`` conditions exactly — both consume the same edge tables."""
    a_lo, a_hi = a_edges[0][:, None], a_edges[1][:, None]
    b_lo, b_hi = b_edges[0][None, :], b_edges[1][None, :]
    if strict:
        full = b_hi < a_lo
        skip = b_lo >= a_hi
    else:
        full = b_hi <= a_lo
        skip = b_lo > a_hi
    return full, skip & ~full


def rank_tile_stats(a: jax.Array, b: jax.Array, *, strict: bool = True,
                    bm: int = BM, bn: int = BN) -> dict:
    """Tile-work counter for the banded kernel on concrete streams: how many
    (a-block, b-block) tiles run the full compare (frontier) vs are resolved
    from block edges alone.  The dense kernel runs the compare on all
    ``total`` tiles."""
    a_edges = _block_edges(_pad_sorted(jnp.asarray(a), bm), bm)
    b_edges = _block_edges(_pad_sorted(jnp.asarray(b), bn), bn)
    full, skip = _tile_classes(a_edges, b_edges, strict)
    n_full = int(jnp.sum(full))
    n_skip = int(jnp.sum(skip))
    total = int(full.shape[0] * full.shape[1])
    return {"total_tiles": total, "full_below_tiles": n_full,
            "skipped_tiles": n_skip,
            "frontier_tiles": total - n_full - n_skip}


def _banded_kernel(ae_ref, be_ref, a_ref, b_ref, cnt_ref, *, strict: bool,
                   bn: int):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    a_lo, a_hi = ae_ref[0, i], ae_ref[1, i]
    b_lo, b_hi = be_ref[0, j], be_ref[1, j]
    if strict:
        full = b_hi < a_lo
        skip = b_lo >= a_hi
    else:
        full = b_hi <= a_lo
        skip = b_lo > a_hi

    @pl.when(full)
    def _whole_block_below():                    # every b in block counts
        cnt_ref[...] += jnp.int32(bn)

    @pl.when(jnp.logical_not(full | skip))
    def _frontier():                             # straddles: full compare
        bias = jnp.asarray(_BIAS, jnp.int32)
        a = a_ref[...].astype(jnp.int32) + bias
        b = b_ref[...].astype(jnp.int32) + bias
        if strict:
            hits = (b[None, :] < a[:, None])
        else:
            hits = (b[None, :] <= a[:, None])
        cnt_ref[...] += jnp.sum(hits.astype(jnp.int32), axis=1)


@functools.partial(jax.jit, static_argnames=("strict", "bm", "bn",
                                             "interpret", "banded"))
def rank_counts(a: jax.Array, b: jax.Array, *, strict: bool = True,
                bm: int = BM, bn: int = BN,
                interpret: bool = True, banded: bool = False) -> jax.Array:
    """counts[i] = #{j : b_j < a_i} (strict) or <= (not strict); uint32 in."""
    ca, cb = a.shape[0], b.shape[0]
    # pad a with MAX (counts for pads are garbage, sliced off), b with MAX
    # (never counted by '<' against real values; '<=' against MAX pads of a
    # is sliced off anyway).
    a_p = _pad_sorted(a, bm)
    b_p = _pad_sorted(b, bn)
    cap, cbp = a_p.shape[0], b_p.shape[0]

    grid = (cap // bm, cbp // bn)
    if banded:
        a_edges = _block_edges(a_p, bm)
        b_edges = _block_edges(b_p, bn)
        grid_spec = PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[pl.BlockSpec((bm,), lambda i, j, ae, be: (i,)),
                      pl.BlockSpec((bn,), lambda i, j, ae, be: (j,))],
            out_specs=pl.BlockSpec((bm,), lambda i, j, ae, be: (i,)),
        )
        out = pl.pallas_call(
            functools.partial(_banded_kernel, strict=strict, bn=bn),
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((cap,), jnp.int32),
            compiler_params=CompilerParams(
                dimension_semantics=("parallel", "arbitrary")),
            interpret=interpret,
        )(a_edges, b_edges, a_p, b_p)
    else:
        out = pl.pallas_call(
            functools.partial(_kernel, strict=strict),
            grid=grid,
            in_specs=[pl.BlockSpec((bm,), lambda i, j: (i,)),
                      pl.BlockSpec((bn,), lambda i, j: (j,))],
            out_specs=pl.BlockSpec((bm,), lambda i, j: (i,)),
            out_shape=jax.ShapeDtypeStruct((cap,), jnp.int32),
            compiler_params=CompilerParams(
                dimension_semantics=("parallel", "arbitrary")),
            interpret=interpret,
        )(a_p, b_p)
    counts = out[:ca]
    # b's padding is MAX. strict '<': pads never count (nothing exceeds MAX).
    # non-strict '<=': pads DO count against queries that are themselves MAX
    # (sentinel rows of a are real array rows) — subtract them.
    if not strict and cbp != cb:
        counts = counts - jnp.where(a == jnp.uint32(0xFFFFFFFF),
                                    jnp.int32(cbp - cb), jnp.int32(0))
    return counts
