"""Pallas TPU kernels for the stack's compute hot-spots.

``onehot_scatter`` / ``rank_merge`` / ``spmv_ell`` are the custom
kernels (with ``ref.py`` pure-jnp references and ``ops.py`` dispatch
wrappers); ``costmodel.py`` prices them for the autotuner.
"""
