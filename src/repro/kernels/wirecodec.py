"""Compressed wire codecs for the union-path butterfly stages.

The paper's throughput argument (§IV) is entirely about bytes-on-wire per
stage, yet the raw union path ships 4-byte uint32 indices and 4-byte fp32
values through every ``all_to_all`` / ``all_gather``.  This module is the
device-side half of the ``wire=`` knob on :class:`repro.core.api
.SparseAllreduce` (model-side pricing: ``topology.wire_entry_bytes``):

* **Index stream ("delta" family)** — every stage payload is a sorted run
  confined to one contiguous subrange of the hashed space, and both ends
  of the wire know the subrange base (receiver j of a down-stage exchange
  owns bucket subrange j; up-stage gather row t covers subrange t).  So
  indices travel as *offsets from the range base*, bit-packed at the
  static per-stage width ``ceil(log2(max_span + 1))`` — the width shrinks
  by ``log2(k)`` bits per layer as the butterfly narrows the range.  SPMD
  static shapes rule out true variable-length gap coding, so this is the
  static-shape adaptation of delta coding: delta against the run base at
  the worst-case-gap width, exactly lossless.  The all-ones offset is the
  SENTINEL marker (``width`` is sized so real offsets never reach it),
  which lets packed rows carry interleaved padding with no count header.
* **Value stream** — ``delta`` keeps fp32 values (bit-identical to
  ``raw``); ``delta+bf16`` ships bfloat16 (the merge kernels consume it
  natively and accumulate in f32 in-register); ``delta+int8ef`` ships
  per-row-scaled int8 whose dequantization is *fused into the one-hot
  scatter kernels* (``ops.merge_sorted_runs(row_scale=...)``) — the
  packed payload is never widened on the wire path, and the train-step
  error-feedback carry (``train/step.py``) compensates the quantization
  residual across steps.

Everything here is shape-static: widths, word counts and group strides are
host-side ints derived from the :class:`~repro.core.allreduce.DevicePlan`,
so the packed buffers trace into fixed-shape collectives.
"""
from __future__ import annotations

import math
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sparse_vec import SENTINEL
from repro.core.topology import WIRE_MODES, check_wire  # noqa: F401  (re-export)

# Wire modes whose value stream loses precision (need bounded-error tests,
# refused by the planned reduce path).
LOSSY_WIRE = ("delta+bf16", "delta+int8ef")


# ---------------------------------------------------------------------------
# Host-side static metadata from the device plan
# ---------------------------------------------------------------------------

def stage_index_bits(plan) -> Tuple[int, ...]:
    """Per-stage offset width in bits: ``ceil(log2(max_span + 1))`` over the
    stage-l subrange spans of every node (host ints; the +1 reserves the
    all-ones marker for SENTINEL padding)."""
    bits = []
    for l in range(len(plan.stages)):
        e = plan.logical.all_edges(l)                    # [M, k+1] int64
        span = int(np.max(e[:, 1:] - e[:, :-1]))
        bits.append(max(1, min(32, int(math.ceil(math.log2(span + 1))))))
    return tuple(bits)


def stage_strides(plan) -> Tuple[int, ...]:
    """Per-stage mixed-radix stride *within the stage's mesh axis*: the
    position of a device in its stage-l group is
    ``(axis_index // stride_l) % degree_l`` (digit l of the axis index,
    most-significant first — matches ``ButterflyPlan.group_members``)."""
    per_axis: dict = {}
    for st in plan.stages:
        per_axis.setdefault(st.axis_name, []).append(st.degree)
    pos = {a: 0 for a in per_axis}
    out = []
    for st in plan.stages:
        ds = per_axis[st.axis_name]
        i = pos[st.axis_name]
        pos[st.axis_name] += 1
        out.append(int(np.prod(ds[i + 1:], dtype=np.int64)) if ds[i + 1:]
                   else 1)
    return tuple(out)


def index_words(cap: int, width: int) -> int:
    """uint32 words holding ``cap`` offsets of ``width`` bits each."""
    return max(1, -(-(cap * width) // 32))


def encoded_payload_bytes(wire: str, cap: int, index_bits: int,
                          width: int = 1) -> int:
    """Exact on-wire bytes of one encoded [cap(, width)] stage row
    (index words + value stream + the int8ef per-row scale).  This is what
    the packet floor applies to — *post*-compression sizes."""
    check_wire(wire)
    if wire == "raw":
        return cap * (4 + 4 * width)
    nbytes = 4 * index_words(cap, index_bits)
    nbytes += cap * width * {"delta": 4, "delta+bf16": 2,
                             "delta+int8ef": 1}[wire]
    if wire == "delta+int8ef":
        nbytes += 4                                     # f32 row scale
    return nbytes


# ---------------------------------------------------------------------------
# Index stream: offset-from-base bit packing (traced, uint32-only)
# ---------------------------------------------------------------------------

def pack_indices(idx: jax.Array, base: jax.Array,  # analysis: hot
                 width: int) -> jax.Array:
    """Pack sorted uint32 rows [R, cap] into offset words [R, n_words].

    ``base`` [R] uint32 is each row's subrange start; SENTINEL entries
    become the all-ones marker.  Entry i occupies bits
    [i*width, (i+1)*width) little-endian; word spills use a double shift
    (no shift-by-32) and land via disjoint-bit scatter-adds (== OR).
    """
    r, cap = idx.shape
    nw = index_words(cap, width)
    marker = jnp.uint32((1 << width) - 1)
    offs = jnp.where(idx == jnp.uint32(SENTINEL), marker,
                     idx - base[:, None].astype(jnp.uint32))
    # host-static bit layout (cap/width are Python ints)
    bitpos = np.arange(cap, dtype=np.int64) * width  # noqa: RA202
    word = jnp.asarray((bitpos // 32).astype(np.int32))
    shift = jnp.asarray((bitpos % 32).astype(np.uint32))
    lo = offs << shift
    hi = (offs >> (jnp.uint32(31) - shift)) >> jnp.uint32(1)
    words = jnp.zeros((r, nw), jnp.uint32)
    words = words.at[:, word].add(lo, mode="drop")
    words = words.at[:, word + 1].add(hi, mode="drop")
    return words


def unpack_indices(words: jax.Array, base: jax.Array,  # analysis: hot
                   cap: int, width: int) -> jax.Array:
    """Inverse of :func:`pack_indices`: words [R, n_words] + ``base`` [R]
    -> uint32 [R, cap] with marker offsets restored to SENTINEL."""
    r, nw = words.shape
    marker = jnp.uint32((1 << width) - 1)
    # host-static bit layout + gather coordinates (cap/width Python ints)
    bitpos = np.arange(cap, dtype=np.int64) * width  # noqa: RA202
    word = (bitpos // 32).astype(np.int32)
    shift = jnp.asarray((bitpos % 32).astype(np.uint32))
    w_lo = words[:, word]
    w_hi = words[:, np.minimum(word + 1, nw - 1)]  # noqa: RA202
    lo = w_lo >> shift
    hi = (w_hi << (jnp.uint32(31) - shift)) << jnp.uint32(1)
    offs = (lo | hi) & marker
    return jnp.where(offs == marker, jnp.uint32(SENTINEL),
                     base[:, None].astype(jnp.uint32) + offs)


# ---------------------------------------------------------------------------
# Value stream: per-row int8 quantization (bf16 is a plain astype)
# ---------------------------------------------------------------------------

def quant8_rows(val: jax.Array) -> Tuple[jax.Array, jax.Array]:  # analysis: hot
    """Per-row symmetric int8 quantization of [R, ...] values.

    Returns ``(q int8 [R, ...], scale f32 [R])`` with
    ``scale = max|row| / 127`` — the wire payload of ``delta+int8ef``
    (the scale travels alongside, one f32 per row).
    """
    red = tuple(range(1, val.ndim))
    amax = jnp.max(jnp.abs(val.astype(jnp.float32)), axis=red)
    scale = jnp.maximum(amax / 127.0, jnp.float32(1e-30))
    s = scale.reshape((-1,) + (1,) * (val.ndim - 1))
    q = jnp.clip(jnp.round(val.astype(jnp.float32) / s),
                 -127.0, 127.0).astype(jnp.int8)
    return q, scale


def dequant8_rows(q: jax.Array, scale: jax.Array) -> jax.Array:  # analysis: hot
    """Inverse of :func:`quant8_rows` (jnp path; the kernel path fuses this
    multiply into the one-hot scatter via ``row_scale``)."""
    s = scale.astype(jnp.float32).reshape((-1,) + (1,) * (q.ndim - 1))
    return q.astype(jnp.float32) * s
