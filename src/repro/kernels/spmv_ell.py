"""Blocked ELL SpMV kernel — PageRank's G @ P product (paper §I-A.2).

Edge-partitioned PageRank computes Q_i = G_i P_i per node; after the hash
permutation the column structure is uniform, so ELL (fixed nonzeros/row,
padded) is a natural TPU layout: dense [R, K] index / weight tiles, aligned
loads, and the gather from the (VMEM-resident) input slice.

Tiling: grid over row blocks; the dense input vector x lives in VMEM whole
(the per-node inbound slice after the sparse allreduce is small — that is
the point of the primitive).  Gather + multiply + row-sum per block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.compat import CompilerParams


def _kernel(cols_ref, w_ref, x_ref, y_ref):
    cols = cols_ref[...]                       # [bm, K] int32, -1 padding
    w = w_ref[...]                             # [bm, K]
    x = x_ref[...]                             # [N] whole vector in VMEM
    safe = jnp.maximum(cols, 0)
    g = jnp.take(x, safe.reshape(-1), axis=0).reshape(cols.shape)
    g = jnp.where(cols >= 0, g, 0.0)
    y_ref[...] = jnp.sum(w * g, axis=1)


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def spmv_ell(cols: jax.Array, weights: jax.Array, x: jax.Array,
             *, bm: int = 256, interpret: bool = True) -> jax.Array:
    """y[r] = sum_k weights[r,k] * x[cols[r,k]];  cols<0 are padding."""
    r, k = cols.shape
    rp = pl.cdiv(r, bm) * bm
    cols_p = jnp.full((rp, k), -1, jnp.int32).at[:r].set(cols.astype(jnp.int32))
    w_p = jnp.zeros((rp, k), weights.dtype).at[:r].set(weights)

    out = pl.pallas_call(
        _kernel,
        grid=(rp // bm,),
        in_specs=[pl.BlockSpec((bm, k), lambda i: (i, 0)),
                  pl.BlockSpec((bm, k), lambda i: (i, 0)),
                  pl.BlockSpec(x.shape, lambda i: (0,))],
        out_specs=pl.BlockSpec((bm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((rp,), jnp.float32),
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(cols_p, w_p, x.astype(jnp.float32))
    return out[:r]
