"""Tile-work / FLOP model for the per-layer merge kernels.

Three ways to merge the k sorted runs arriving at a butterfly layer
(``merge="sort" | "fused" | "banded"``); this module prices each one in
tiles and FLOPs so benchmarks report *work*, not just interpret-mode wall
time (which is meaningless off-TPU):

* ``sort``   — concat + full argsort of all C = k*cap rows, then a jnp
  segment sum.  No Pallas tiles; cost ~ C*log2(C) compare-swaps.
* ``fused``  — rank-merge (k*(k-1) dense compare planes of cap^2/(bm*bn)
  tiles each) + one-hot scatter-add whose inner grid dimension scans ALL
  C/bk input tiles for every output tile: O(cap^2) per layer.
* ``banded`` — same pipeline, band-limited: compare tiles off the merge
  frontier are resolved from scalar-prefetched block edges (cheap), and the
  scatter's inner dimension is the static ``band_inner_tiles(k, bm, bk) =
  ceil(k*bm/bk)+1`` — near-linear tile work.

``merge_tile_report`` instruments a concrete workload: the rank-merge
frontier counts come from the very edge tables the banded kernel prefetches
(``rank_merge.rank_tile_stats``), and the scatter counts are the static
grid shapes of the kernels in ``onehot_scatter``.

``wire_bytes_report`` prices the *on-wire* side of a layer under the
``wire=`` codecs (``kernels.wirecodec``): exact encoded index+value bytes
per stage row, dtype-aware, with the fabric's packet floor applied to the
post-compression size — the byte model the autotuner re-ranks degree
factorizations under.
"""
from __future__ import annotations

import math
from typing import Optional

from . import onehot_scatter, rank_merge
from .onehot_scatter import band_inner_tiles
from .rank_merge import rank_tile_stats

# tile shapes imported from the kernels themselves, so the reports always
# describe the kernels actually run
SCATTER_BM, SCATTER_BN, SCATTER_BK = (onehot_scatter.BM, onehot_scatter.BN,
                                      onehot_scatter.BK)
RANK_BM, RANK_BN = rank_merge.BM, rank_merge.BN


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def scatter_tile_report(c: int, width: int, out_rows: int, *, mode: str,
                        band: Optional[int] = None,
                        bm: int = SCATTER_BM, bn: int = SCATTER_BN,
                        bk: int = SCATTER_BK) -> dict:
    """Tile/FLOP count of the one-hot scatter-add for C input rows ->
    ``out_rows`` destinations of ``width`` columns.

    Each (out-tile, width-tile, in-tile) visit is one [bk,bm]^T @ [bk,bn]
    MXU contraction = 2*bk*bm*bn FLOPs.  ``fused`` scans all input tiles
    per output tile; ``banded`` scans the static band bound.
    """
    n_out = _cdiv(out_rows, bm)
    n_w = _cdiv(max(width, 1), bn)
    if mode == "banded":
        if band is None:
            raise ValueError("banded scatter report needs the band bound")
        inner = band_inner_tiles(band, bm, bk)
    else:
        inner = _cdiv(c, bk)
    tiles = n_out * n_w * inner
    return {"inner_tiles_per_out_tile": inner, "out_tiles": n_out * n_w,
            "tiles": tiles, "mxu_flops": tiles * 2 * bk * bm * bn}


def merge_tile_report(idx, out_capacity: int, *, mode: str, width: int = 1,
                      bm: int = SCATTER_BM, bn: int = SCATTER_BN,
                      bk: int = SCATTER_BK, rank_bm: int = RANK_BM,
                      rank_bn: int = RANK_BN) -> dict:
    """Instrumented tile-work count of one butterfly-layer merge on a
    concrete [k, cap] idx workload (uint32, SENTINEL-padded sorted runs).

    Returns compare-tile counts for the k*(k-1) rank-merge kernels (with
    the banded frontier classification measured on the actual streams) and
    the scatter-add tile counts, plus a FLOP-model total.  For ``sort`` the
    cost is the argsort compare estimate — no Pallas tiles.
    """
    k, cap = int(idx.shape[0]), int(idx.shape[1])
    c = k * cap
    if mode == "sort":
        comparisons = int(c * max(1.0, math.log2(max(c, 2))))
        return {"mode": mode, "k": k, "cap": cap,
                "rank_compare_tiles": 0, "rank_cheap_tiles": 0,
                "scatter_inner_tiles_per_out_tile": 0, "scatter_tiles": 0,
                "flops": comparisons}
    per_pair_tiles = _cdiv(cap, rank_bm) * _cdiv(cap, rank_bn)
    pairs = k * (k - 1)
    if mode == "banded":
        compare = cheap = 0
        for r in range(k):
            for s in range(k):
                if s == r:
                    continue
                st = rank_tile_stats(idx[r], idx[s], strict=(s > r),
                                     bm=rank_bm, bn=rank_bn)
                compare += st["frontier_tiles"]
                cheap += st["full_below_tiles"] + st["skipped_tiles"]
    elif mode == "fused":
        compare, cheap = pairs * per_pair_tiles, 0
    else:
        raise ValueError(f"unknown merge mode {mode!r}")
    sc = scatter_tile_report(c, width, out_capacity, mode=mode, band=k,
                             bm=bm, bn=bn, bk=bk)
    rank_flops = compare * rank_bm * rank_bn      # one compare+add per cell
    return {"mode": mode, "k": k, "cap": cap,
            "rank_compare_tiles": compare, "rank_cheap_tiles": cheap,
            "rank_total_tiles": pairs * per_pair_tiles,
            "scatter_inner_tiles_per_out_tile":
                sc["inner_tiles_per_out_tile"],
            "scatter_tiles": sc["tiles"],
            "flops": rank_flops + sc["mxu_flops"]}


def wire_bytes_report(cap: int, index_bits: int, *, wire: str = "raw",
                      value_width: int = 1, fabric=None,
                      fanout: int = 1) -> dict:
    """Encoded on-wire cost of one stage row of ``cap`` entries.

    ``index_bits`` is the stage's static offset width
    (``wirecodec.stage_index_bits``); raw ignores it and ships 32-bit
    indices.  Returns exact byte counts (bit-packed index words + value
    stream + the int8ef row scale), the compression ratio vs raw, and —
    when a :class:`repro.core.netmodel.Fabric` is given — the modeled
    message time with the packet floor applied to the *post-compression*
    size (the floor lives inside ``Fabric.msg_time`` and is applied
    exactly once, there).
    """
    from repro.core.topology import check_wire
    from .wirecodec import encoded_payload_bytes
    check_wire(wire)
    raw = encoded_payload_bytes("raw", cap, 32, value_width)
    enc = encoded_payload_bytes(wire, cap, index_bits, value_width)
    rep = {"wire": wire, "cap": cap,
           "index_bits": 32 if wire == "raw" else index_bits,
           "value_width": value_width,
           "raw_bytes": raw, "encoded_bytes": enc,
           "compression": raw / enc}
    if fabric is not None:
        rep["msg_time_s"] = fabric.msg_time(float(enc), fanout)
        rep["raw_msg_time_s"] = fabric.msg_time(float(raw), fanout)
        rep["floor_bound"] = float(enc) < float(fabric.floor_bytes)
    return rep
