"""Checkpointing: flat-namespace .npz store with pytree round-trip.

Host-gathered (fine for the CPU/dev path; on a real pod this would stream
per-shard with a distributed filesystem — the serialization format and
pytree flattening here are the reusable parts).

Crash safety: :func:`save` is **atomic** — the payload is written to a
tempfile in the target directory, fsynced, then ``os.replace``d over the
final name, so a kill mid-save can never leave a corrupt or partial
checkpoint behind (the previous complete artifact, if any, survives).
The ``.npz`` is replaced *before* its ``.meta.json`` sidecar, so a
visible meta always describes a complete payload (the autotuner's
``PlanCache`` relies on exactly this ordering).  Artifacts damaged by
other means (disk truncation, partial copies) surface as a
:class:`CheckpointError` from the loaders rather than a cryptic zipfile
traceback — the exact-resume soak harness (``repro.launch.soak``) uses
that to skip a bad checkpoint and fall back to an older one.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


class CheckpointError(RuntimeError):
    """A checkpoint artifact exists but cannot be decoded (truncated,
    corrupt, or not a :func:`save` product).  Distinct from
    ``FileNotFoundError`` — the caller can fall back to an older
    checkpoint (``repro.launch.soak`` does) instead of crashing on
    garbage."""


def _flatten(tree: Any, prefix: str = "") -> Dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (tuple, list)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}#{i}/"))
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def _atomic_write(final_path: str, write_fn) -> None:
    """Write via tempfile-in-target-dir + fsync + ``os.replace``."""
    d = os.path.dirname(final_path) or "."
    fd, tmp = tempfile.mkstemp(
        dir=d, prefix=os.path.basename(final_path) + ".", suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            write_fn(f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, final_path)
    except BaseException:
        try:
            os.remove(tmp)
        except OSError:
            pass
        raise


def save(path: str, tree: Any, meta: Dict[str, Any] | None = None) -> None:
    """Atomically persist ``tree`` (pytree of arrays) at ``path``.

    Writes ``<path>.npz`` (payload) then ``<path>.meta.json`` (sidecar,
    when ``meta`` is given), each through a fsynced tempfile +
    ``os.replace`` in the target directory — see the module docstring for
    the crash-safety contract.
    """
    flat = _flatten(tree)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    base = path[: -len(".npz")] if path.endswith(".npz") else path
    _atomic_write(base + ".npz", lambda f: np.savez(f, **flat))
    if meta is not None:
        payload = json.dumps(meta, indent=2, default=str).encode()
        _atomic_write(base + ".meta.json", lambda f: f.write(payload))


def load_flat(path: str) -> Tuple[Dict[str, np.ndarray], Dict[str, Any] | None]:
    """Load a :func:`save` artifact without a ``like`` template.

    Returns ``(arrays, meta)`` — the flat ``name -> ndarray`` mapping from
    the ``.npz`` plus the sidecar ``.meta.json`` dict (``None`` when no
    meta was written).  This is the read path for consumers whose payload
    *is* a flat namespace (e.g. the autotuner's plan cache,
    ``repro.core.autotune``) rather than a pytree with a known template.

    Raises ``FileNotFoundError`` when no artifact exists and
    :class:`CheckpointError` when one exists but is corrupt/truncated.
    """
    base = path[: -len(".npz")] if path.endswith(".npz") else path
    if not os.path.exists(base + ".npz"):
        raise FileNotFoundError(f"no checkpoint at {base}.npz")
    try:
        with np.load(base + ".npz") as data:
            arrays = {k: data[k] for k in data.files}
    except Exception as e:
        raise CheckpointError(
            f"corrupt or truncated checkpoint {base}.npz "
            f"({type(e).__name__}: {e}); it is not a complete "
            f"repro.checkpoint.store artifact") from e
    meta = None
    if os.path.exists(base + ".meta.json"):
        try:
            with open(base + ".meta.json") as f:
                meta = json.load(f)
        except (json.JSONDecodeError, UnicodeDecodeError, OSError) as e:
            raise CheckpointError(
                f"corrupt checkpoint sidecar {base}.meta.json "
                f"({type(e).__name__}: {e})") from e
    return arrays, meta


def load(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (shape/dtype checked).

    Raises :class:`CheckpointError` on a corrupt artifact (see
    :func:`load_flat`)."""
    arrays, _ = load_flat(path)

    def rebuild(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: rebuild(v, f"{prefix}{k}/") for k, v in tree.items()}
        if isinstance(tree, (tuple, list)):
            vals = [rebuild(v, f"{prefix}#{i}/") for i, v in enumerate(tree)]
            return type(tree)(vals)
        arr = arrays[prefix[:-1]]
        want = jax.eval_shape(lambda: tree) if callable(tree) else tree
        assert arr.shape == tuple(want.shape), \
            f"{prefix}: {arr.shape} != {want.shape}"
        return arr
    return rebuild(like)


def list_checkpoints(directory: str, prefix: str = "ckpt-"
                     ) -> List[Tuple[int, str]]:
    """Step-numbered :func:`save` artifacts in ``directory``, newest first.

    Matches ``<prefix><step>.npz`` with an integer ``step`` and returns
    ``[(step, extension-less base path), ...]`` sorted descending by step.
    Existence only — pair with :func:`load_flat`/:func:`load` and catch
    :class:`CheckpointError` to skip damaged entries (the soak harness's
    resume loop does)."""
    out: List[Tuple[int, str]] = []
    if not os.path.isdir(directory):
        return out
    for name in os.listdir(directory):
        if not (name.startswith(prefix) and name.endswith(".npz")):
            continue
        stem = name[len(prefix):-len(".npz")]
        if stem.isdigit():
            out.append((int(stem), os.path.join(directory, name[:-4])))
    return sorted(out, reverse=True)


def latest_checkpoint(directory: str, prefix: str = "ckpt-"
                      ) -> Optional[Tuple[int, str]]:
    """Newest ``(step, base path)`` per :func:`list_checkpoints`, or
    ``None`` when the directory holds no step-numbered checkpoints."""
    cks = list_checkpoints(directory, prefix)
    return cks[0] if cks else None
