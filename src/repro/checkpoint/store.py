"""Checkpointing: flat-namespace .npz store with pytree round-trip.

Host-gathered (fine for the CPU/dev path; on a real pod this would stream
per-shard with a distributed filesystem — the serialization format and
pytree flattening here are the reusable parts).
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import numpy as np


def _flatten(tree: Any, prefix: str = "") -> Dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (tuple, list)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}#{i}/"))
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def save(path: str, tree: Any, meta: Dict[str, Any] | None = None) -> None:
    flat = _flatten(tree)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, **flat)
    if meta is not None:
        with open(path + ".meta.json", "w") as f:
            json.dump(meta, f, indent=2, default=str)


def load_flat(path: str) -> Tuple[Dict[str, np.ndarray], Dict[str, Any] | None]:
    """Load a :func:`save` artifact without a ``like`` template.

    Returns ``(arrays, meta)`` — the flat ``name -> ndarray`` mapping from
    the ``.npz`` plus the sidecar ``.meta.json`` dict (``None`` when no
    meta was written).  This is the read path for consumers whose payload
    *is* a flat namespace (e.g. the autotuner's plan cache,
    ``repro.core.autotune``) rather than a pytree with a known template.
    """
    base = path[: -len(".npz")] if path.endswith(".npz") else path
    with np.load(base + ".npz") as data:
        arrays = {k: data[k] for k in data.files}
    meta = None
    if os.path.exists(base + ".meta.json"):
        with open(base + ".meta.json") as f:
            meta = json.load(f)
    return arrays, meta


def load(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (shape/dtype checked)."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")

    def rebuild(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: rebuild(v, f"{prefix}{k}/") for k, v in tree.items()}
        if isinstance(tree, (tuple, list)):
            vals = [rebuild(v, f"{prefix}#{i}/") for i, v in enumerate(tree)]
            return type(tree)(vals)
        arr = data[prefix[:-1]]
        want = jax.eval_shape(lambda: tree) if callable(tree) else tree
        assert arr.shape == tuple(want.shape), \
            f"{prefix}: {arr.shape} != {want.shape}"
        return arr
    return rebuild(like)
