"""Synthetic power-law data pipeline.

The paper's datasets (Twitter follower graph, Yahoo web graph, tweet
bag-of-words) are power-law; language-model token streams are too (Zipf).
This pipeline generates deterministic, seedable batches:

  * ``zipf_tokens``    — Zipf(alpha) token ids over a vocab (LM training);
    exercises exactly the index-collision statistics the paper's compression
    argument relies on.
  * ``powerlaw_graph`` — Chung-Lu style power-law graph in edge-partitioned
    form (PageRank / HADI / spectral inputs) with the paper's random edge
    partition (§II-B).
  * ``Batcher``        — deterministic infinite minibatch iterator.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import numpy as np


def zipf_tokens(rng: np.random.RandomState, shape, vocab: int,
                alpha: float = 1.2) -> np.ndarray:
    """Zipf-distributed token ids in [0, vocab)."""
    # inverse-CDF sampling over ranks (vectorized, exact)
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    w = ranks ** (-alpha)
    cdf = np.cumsum(w) / np.sum(w)
    u = rng.random_sample(int(np.prod(shape)))
    ids = np.searchsorted(cdf, u).astype(np.int32)
    # random permutation so "frequent" ids are spread over the id space
    perm = rng.permutation(vocab).astype(np.int32)
    return perm[ids].reshape(shape)


@dataclasses.dataclass
class Batcher:
    vocab: int
    batch: int
    seq: int
    alpha: float = 1.2
    seed: int = 0

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        rng = np.random.RandomState(self.seed)
        while True:
            toks = zipf_tokens(rng, (self.batch, self.seq + 1), self.vocab,
                               self.alpha)
            yield toks[:, :-1], toks[:, 1:]


def powerlaw_graph(n_vertices: int, n_edges: int, alpha: float = 2.0,
                   seed: int = 0) -> np.ndarray:
    """Edge list [E, 2] with power-law degree distribution (Chung-Lu)."""
    rng = np.random.RandomState(seed)
    w = (np.arange(1, n_vertices + 1, dtype=np.float64)) ** (-1.0 / (alpha - 1))
    p = w / w.sum()
    src = rng.choice(n_vertices, size=n_edges, p=p).astype(np.int64)
    dst = rng.choice(n_vertices, size=n_edges, p=p).astype(np.int64)
    keep = src != dst
    edges = np.stack([src[keep], dst[keep]], axis=1)
    # spread hubs over the id space (paper applies a hash permutation later
    # anyway, but raw ids should not be degree-sorted)
    perm = rng.permutation(n_vertices).astype(np.int64)
    return perm[edges]


def random_edge_partition(edges: np.ndarray, num_parts: int,
                          seed: int = 0) -> list:
    """Paper §II-B: random edge partition across machines."""
    rng = np.random.RandomState(seed)
    part = rng.randint(0, num_parts, size=len(edges))
    return [edges[part == i] for i in range(num_parts)]
