"""AdamW with the same sharding as the params (runs inside shard_map).

Optimizer state is a pytree mirroring the params; under FSDP the m/v leaves
inherit the param shards, giving ZeRO-style partitioned optimizer state for
free (the pjit in_shardings reuse the param spec tree).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0

    def init(self, params) -> AdamWState:
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                          v=jax.tree.map(jnp.copy, zeros))

    def update(self, grads, state: AdamWState, params, gnorm=None):
        step = state.step + 1
        if gnorm is None:
            # local-view global-norm (callers under shard_map pass the
            # sharding-aware norm instead)
            sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                     for g in jax.tree.leaves(grads))
            gnorm = jnp.sqrt(sq)
        scale = jnp.minimum(1.0, self.grad_clip / jnp.maximum(gnorm, 1e-9))
        b1c = 1.0 - self.b1 ** step.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * g * g
            mh = m / b1c
            vh = v / b2c
            delta = mh / (jnp.sqrt(vh) + self.eps)
            if p.ndim >= 2:  # decay matrices only (standard)
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - self.lr * delta).astype(p.dtype), m, v

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_m = jax.tree.leaves(state.m)
        flat_v = jax.tree.leaves(state.v)
        out = [upd(p, g, m, v) for p, g, m, v
               in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
        new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
        new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
        return new_p, AdamWState(step=step, m=new_m, v=new_v), gnorm
