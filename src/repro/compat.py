"""JAX runtime-compatibility layer — the ONLY place version-sensitive
symbols are resolved.

Two APIs moved across the JAX versions this repo supports (>= 0.4.30):

  * ``shard_map`` — lives at ``jax.experimental.shard_map.shard_map`` on
    0.4.x (replication check kwarg: ``check_rep``) and was promoted to
    ``jax.shard_map`` on newer releases (kwarg renamed to ``check_vma``).
  * Pallas TPU compiler params — ``pltpu.TPUCompilerParams`` on 0.4.x,
    renamed to ``pltpu.CompilerParams`` later.

Policy (see README "JAX compatibility"): every module under ``repro``
imports these names from here — never from ``jax`` directly (enforced by
``tests/test_compat.py::test_no_version_sensitive_imports_outside_compat``).
To add a new shim: write a ``resolve_*`` pure function that takes the
module(s) to probe (so both branches stay unit-testable against fakes),
call it once at module scope below, and re-export the resolved name.
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax


# ---------------------------------------------------------------------------
# shard_map
# ---------------------------------------------------------------------------

def _check_kwarg_of(fn: Callable, default: str) -> str:
    """Which replication-check kwarg ``fn`` takes (by signature, not by
    where the symbol lives — some releases promoted ``jax.shard_map``
    before renaming ``check_rep`` to ``check_vma``)."""
    import inspect
    try:
        params = inspect.signature(fn).parameters
    except (TypeError, ValueError):
        return default
    if "check_vma" in params:
        return "check_vma"
    if "check_rep" in params:
        return "check_rep"
    return default


def resolve_shard_map(jax_module: Any, experimental_module: Any = None
                      ) -> Tuple[Callable, str]:
    """Return ``(raw_shard_map, replication_check_kwarg_name)``.

    Newer JAX exposes ``jax.shard_map``; 0.4.x only has
    ``jax.experimental.shard_map.shard_map`` (kwarg ``check_rep``).
    """
    fn = getattr(jax_module, "shard_map", None)
    if fn is not None:
        return fn, _check_kwarg_of(fn, "check_vma")
    if experimental_module is None:
        from jax.experimental import shard_map as experimental_module
    fn = experimental_module.shard_map
    return fn, _check_kwarg_of(fn, "check_rep")


def make_shard_map(raw: Callable, check_kwarg: str) -> Callable:
    """Wrap a raw shard_map so call sites can always pass ``check_vma=``.

    The wrapper translates ``check_vma`` to whatever replication-check
    kwarg the resolved implementation actually takes and forwards
    everything else untouched.
    """

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kwargs):
        if check_vma is not None:
            kwargs.setdefault(check_kwarg, check_vma)
        return raw(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   **kwargs)

    return shard_map


_RAW_SHARD_MAP, _CHECK_KWARG = resolve_shard_map(jax)
shard_map = make_shard_map(_RAW_SHARD_MAP, _CHECK_KWARG)


# ---------------------------------------------------------------------------
# Pallas TPU compiler params
# ---------------------------------------------------------------------------

def resolve_compiler_params(pltpu_module: Any) -> Any:
    """Pick ``CompilerParams`` (new name) or ``TPUCompilerParams`` (0.4.x)."""
    cls = getattr(pltpu_module, "CompilerParams", None)
    if cls is None:
        cls = pltpu_module.TPUCompilerParams
    return cls


from jax.experimental.pallas import tpu as _pltpu  # noqa: E402

CompilerParams = resolve_compiler_params(_pltpu)


# ---------------------------------------------------------------------------
# Pallas scalar-prefetch grid spec
# ---------------------------------------------------------------------------

def resolve_prefetch_grid_spec(pltpu_module: Any) -> Any:
    """``pltpu.PrefetchScalarGridSpec`` under its historical or promoted
    name.  Scalar prefetch is what lets a kernel's BlockSpec index maps read
    a host-computed table (the banded kernels' per-tile start blocks and
    block-edge tables) before the body runs."""
    for name in ("PrefetchScalarGridSpec", "PrefetchGridSpec"):
        cls = getattr(pltpu_module, name, None)
        if cls is not None:
            return cls
    raise ImportError(
        "Pallas TPU module exposes no scalar-prefetch grid spec; banded "
        "kernels need PrefetchScalarGridSpec (jax >= 0.4.30)")


PrefetchScalarGridSpec = resolve_prefetch_grid_spec(_pltpu)

__all__ = ["shard_map", "CompilerParams", "PrefetchScalarGridSpec",
           "resolve_shard_map", "make_shard_map", "resolve_compiler_params",
           "resolve_prefetch_grid_spec"]
