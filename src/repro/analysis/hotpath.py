"""Hot-region inference: which functions of a module run under tracing.

A *hot region* is a function whose body executes inside a JAX trace —
``@jit``-decorated, passed to ``jit`` / ``shard_map`` / ``lax.scan`` /
``pl.pallas_call`` / ``grad`` / ``cond`` …, registered as an
``EngineApp`` per-round callback, or (transitively) called from any of
those within the same module.  Host-sync / numpy / float64 / device-loop
rules (``repro.analysis.rules``) only fire inside hot regions, so the
linter stays quiet on legitimately host-side code (simulator oracles,
``config`` planning, benchmarks).

Inference is purely syntactic (no imports, no jax): seeds are matched on
the *last attribute component* of the wrapping callee (``jax.jit``,
``api.jit`` and bare ``jit`` all match), then hotness propagates to
same-module functions referenced by name from hot bodies, to a fixpoint.
Over-approximation is deliberate — a false-positive hot region costs a
reviewable finding (suppressible with ``# noqa: RAxxx``), a false
negative hides a silent per-round host sync.

Force a function hot with a ``# analysis: hot`` comment on its ``def``
line when it is only reached through dynamic dispatch the inference
cannot see.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from typing import Dict, Iterator, List, Optional, Set, Tuple

# Callables whose function-valued arguments are traced.  Matched on the
# final attribute component: ``jax.jit``, ``lax.scan``, ``pl.pallas_call``
# and their bare-name imports all resolve to one entry here.
WRAPPER_NAMES: Set[str] = {
    "jit", "pjit", "shard_map", "scan", "pallas_call", "fori_loop",
    "while_loop", "cond", "switch", "grad", "value_and_grad", "vmap",
    "pmap", "remat", "checkpoint", "custom_vjp", "custom_jvp", "make_jaxpr",
    "eval_shape",
}

# Constructor kwargs whose values are per-round traced callbacks — the
# graph engine's app protocol (repro.graph.engine.EngineApp).
CALLBACK_KWARGS: Dict[str, Tuple[str, ...]] = {
    "EngineApp": ("out_fn", "update_fn"),
}

_FORCE_HOT_RE = re.compile(r"#\s*analysis:\s*hot\b")

_FuncNode = (ast.FunctionDef, ast.AsyncFunctionDef)


@dataclasses.dataclass(frozen=True)
class HotRegion:
    """One top-level hot function: its qualname, AST node and why it is
    considered hot (seed kind or the propagation chain)."""

    qualname: str
    node: ast.AST
    reason: str

    def walk(self) -> Iterator[ast.AST]:
        """Every AST node inside the region (nested defs included — a
        closure defined in a traced body is traced when called)."""
        return ast.walk(self.node)


def _last_attr(node: ast.AST) -> Optional[str]:
    """Final dotted component of a Name/Attribute callee, else None."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def _callable_refs(node: ast.AST) -> List[str]:
    """Names a function-valued argument might resolve to: a bare Name,
    the inner function of ``partial(f, ...)``, or attribute tails like
    ``self.f`` (resolved against same-module defs by final component)."""
    if isinstance(node, ast.Name):
        return [node.id]
    if isinstance(node, ast.Attribute):
        return [node.attr]
    if isinstance(node, ast.Call):
        tail = _last_attr(node.func)
        if tail == "partial" and node.args:
            return _callable_refs(node.args[0])
    return []


class _Collector(ast.NodeVisitor):
    """One pass: index every def by name, record seeds and call edges."""

    def __init__(self, source_lines: List[str]):
        self.lines = source_lines
        self.defs: Dict[str, List[ast.AST]] = {}     # name -> def nodes
        self.node_index: Dict[int, ast.AST] = {}     # id -> def/lambda node
        self.qualname: Dict[int, str] = {}           # id(node) -> qualname
        self.parents: Dict[int, Optional[ast.AST]] = {}
        self.seeds: Dict[int, str] = {}              # id(node) -> reason
        # id(def node) -> names referenced anywhere in its body
        self.refs: Dict[int, Set[str]] = {}
        self._stack: List[ast.AST] = []
        self._qual: List[str] = []

    # -- defs ------------------------------------------------------------
    def _visit_def(self, node) -> None:
        name = getattr(node, "name", "<lambda>")
        self.defs.setdefault(name, []).append(node)
        self.node_index[id(node)] = node
        self.qualname[id(node)] = ".".join(self._qual + [name])
        self.parents[id(node)] = self._stack[-1] if self._stack else None
        for dec in getattr(node, "decorator_list", []):
            if self._is_tracing_decorator(dec):
                self.seeds[id(node)] = "decorated @%s" % ast.unparse(dec)
        if self._line_forces_hot(node):
            self.seeds[id(node)] = "forced by '# analysis: hot'"
        self.refs[id(node)] = set()
        self._stack.append(node)
        self._qual.append(name)
        self.generic_visit(node)
        self._qual.pop()
        self._stack.pop()

    visit_FunctionDef = visit_AsyncFunctionDef = _visit_def

    def visit_Lambda(self, node: ast.Lambda) -> None:
        self.parents[id(node)] = self._stack[-1] if self._stack else None
        self.node_index[id(node)] = node
        self.qualname[id(node)] = ".".join(self._qual + ["<lambda>"])
        self.refs[id(node)] = set()
        self._stack.append(node)
        self.generic_visit(node)
        self._stack.pop()

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self._qual.append(node.name)
        self.generic_visit(node)
        self._qual.pop()

    def _line_forces_hot(self, node) -> bool:
        line = self.lines[node.lineno - 1] if \
            0 < node.lineno <= len(self.lines) else ""
        return bool(_FORCE_HOT_RE.search(line))

    def _is_tracing_decorator(self, dec: ast.AST) -> bool:
        tail = _last_attr(dec)
        if tail in WRAPPER_NAMES:
            return True
        if isinstance(dec, ast.Call):
            # @partial(jax.jit, ...) / @jax.jit(static_argnums=...)
            inner = _last_attr(dec.func)
            if inner in WRAPPER_NAMES:
                return True
            if inner == "partial" and dec.args:
                return _last_attr(dec.args[0]) in WRAPPER_NAMES
        return False

    # -- seeds from call sites + reference edges -------------------------
    def visit_Call(self, node: ast.Call) -> None:
        tail = _last_attr(node.func)
        if tail in WRAPPER_NAMES:
            for arg in node.args:
                if isinstance(arg, ast.Lambda):
                    self.seeds[id(arg)] = f"lambda passed to {tail}()"
                for ref in _callable_refs(arg):
                    # resolved after the walk — defs may appear after use
                    self._mark_ref_seed(ref, f"passed to {tail}()")
        for ctor, kwargs in CALLBACK_KWARGS.items():
            if tail == ctor:
                for kw in node.keywords:
                    if kw.arg in kwargs:
                        for ref in _callable_refs(kw.value):
                            self._mark_ref_seed(
                                ref, f"{ctor}({kw.arg}=...) callback")
        self.generic_visit(node)

    def _mark_ref_seed(self, name: str, reason: str) -> None:
        self.seed_names = getattr(self, "seed_names", [])
        self.seed_names.append((name, reason))

    def visit_Name(self, node: ast.Name) -> None:
        if self._stack:
            self.refs[id(self._stack[-1])].add(node.id)
        self.generic_visit(node)

    def visit_Attribute(self, node: ast.Attribute) -> None:
        if self._stack:
            self.refs[id(self._stack[-1])].add(node.attr)
        self.generic_visit(node)


def build_hot_map(tree: ast.AST, source: str = "") -> List[HotRegion]:
    """Infer the hot regions of a module (see module docstring).

    Returns the *maximal* hot functions — nested hot defs inside an
    already-hot ancestor are folded into the ancestor's region, so every
    hot AST node is covered exactly once.
    """
    lines = source.splitlines()
    col = _Collector(lines)
    col.visit(tree)

    hot: Dict[int, str] = dict(col.seeds)
    node_by_id = col.node_index

    # seeds referenced by name at wrap call sites
    for name, reason in getattr(col, "seed_names", []):
        for d in col.defs.get(name, []):
            hot.setdefault(id(d), reason)

    # propagate: any def whose name is referenced from a hot body is hot
    changed = True
    guard = 0
    while changed and guard < 100:
        changed, guard = False, guard + 1
        for nid, reason in list(hot.items()):
            for ref in col.refs.get(nid, ()):
                for d in col.defs.get(ref, []):
                    if id(d) not in hot:
                        src = col.qualname.get(nid, "?")
                        hot[id(d)] = f"called from hot {src}"
                        changed = True

    # nested defs of a hot function are hot by construction; keep maximal
    # regions only
    def _covered_by_hot_ancestor(nid: int) -> bool:
        p = col.parents.get(nid)
        while p is not None:
            if id(p) in hot:
                return True
            p = col.parents.get(id(p))
        return False

    regions = []
    for nid, reason in hot.items():
        if _covered_by_hot_ancestor(nid):
            continue
        node = node_by_id.get(nid)
        if node is None:
            continue
        regions.append(HotRegion(qualname=col.qualname.get(nid, "?"),
                                 node=node, reason=reason))
    regions.sort(key=lambda r: r.node.lineno)
    return regions
