"""The lint rule catalog — every repo invariant the linter enforces.

Naming: ``RA1xx`` compat layering, ``RA2xx`` hot-region (traced code)
hazards, ``RA3xx`` jit hygiene, ``RA4xx`` documentation, ``RA5xx``
resilience invariants (fault handling + checkpoint safety).  Each rule has
positive + negative fixtures under ``tests/fixtures/analysis/`` (file
name prefixed with the lower-cased rule id) and is regression-tested by
``tests/test_analysis.py``; the whole catalog must pass over
``src/repro`` at HEAD (``python -m repro.analysis src --strict``).

Hot-region rules (RA2xx) only inspect code inferred to run under a JAX
trace (:mod:`repro.analysis.hotpath`) — a host sync there is paid every
round and silently erases the paper's nested-stage wins (§III-IV), which
is exactly why these are linted instead of hoped-for.
"""
from __future__ import annotations

import ast
import re
from typing import Iterable, List, Optional, Set

from .engine import ModuleContext, Rule, register
from .violations import Severity, Violation

# numpy attributes that are harmless as *references* inside traced code
# (dtype tags, constants) — only calls moving values are host syncs.
_NP_MODULES = {"np", "numpy", "onp"}
_DEVICEISH_RE = re.compile(
    r"num_nodes|num_devices|num_physical|m_phys|\bdevices\b|mesh\.shape"
    r"|mesh\.size|axis_size|local_device_count|device_count")


def _tail(node: ast.AST) -> Optional[str]:
    """Last dotted component of a Name/Attribute, else None."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def _base_name(node: ast.AST) -> Optional[str]:
    """Leftmost Name of an attribute chain (``np`` of ``np.asarray``)."""
    while isinstance(node, ast.Attribute):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def _all_literal(args: List[ast.AST]) -> bool:
    """True when every argument is a compile-time constant expression —
    a host call on literals folds at trace time and never touches a
    traced value."""
    def lit(a: ast.AST) -> bool:
        if isinstance(a, ast.Constant):
            return True
        if isinstance(a, (ast.Tuple, ast.List)):
            return all(lit(e) for e in a.elts)
        if isinstance(a, ast.UnaryOp):
            return lit(a.operand)
        return False
    return all(lit(a) for a in args)


# ---------------------------------------------------------------------------
# RA1xx — compat layering (port of tests/test_compat.py's grep lint)
# ---------------------------------------------------------------------------

@register
class CompatShardMapRule(Rule):
    """RA101: ``shard_map`` must be imported from ``repro.compat``.

    The symbol moved across JAX releases (``jax.experimental.shard_map``
    -> ``jax.shard_map``, kwarg ``check_rep`` -> ``check_vma``);
    ``compat.py`` resolves it exactly once for the supported range.
    """

    rule_id = "RA101"
    severity = Severity.ERROR
    title = "version-sensitive shard_map import outside repro.compat"
    rationale = ("shard_map moved between JAX releases; repro.compat is "
                 "the single resolution point (README 'JAX compatibility')")
    exclude = ("compat.py",)

    def check(self, ctx: ModuleContext) -> Iterable[Violation]:
        """Flag shard_map imports/attributes that bypass repro.compat."""
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ImportFrom):
                mod = node.module or ""
                if mod == "repro.compat" or mod.endswith(".compat"):
                    continue
                if mod.startswith("jax") and (
                        "shard_map" in mod
                        or any(a.name == "shard_map" for a in node.names)):
                    yield self.violation(
                        ctx, node, f"import of shard_map from {mod!r}; use "
                        f"'from repro.compat import shard_map'")
            elif isinstance(node, ast.Import):
                for a in node.names:
                    if a.name.startswith("jax.experimental.shard_map"):
                        yield self.violation(
                            ctx, node, "import jax.experimental.shard_map; "
                            "use repro.compat.shard_map")
            elif isinstance(node, ast.Attribute) and node.attr == "shard_map":
                base = _base_name(node)
                if base == "jax":
                    yield self.violation(
                        ctx, node, "jax.shard_map attribute access; use "
                        "repro.compat.shard_map")


@register
class CompatPallasParamsRule(Rule):
    """RA102: Pallas TPU compiler params / prefetch grid specs resolve
    only in ``repro.compat`` (``TPUCompilerParams`` vs ``CompilerParams``,
    ``PrefetchScalarGridSpec`` naming moved across releases)."""

    rule_id = "RA102"
    severity = Severity.ERROR
    title = "version-sensitive Pallas TPU symbol outside repro.compat"
    rationale = ("pltpu.CompilerParams / TPUCompilerParams / "
                 "PrefetchScalarGridSpec are renamed across JAX versions; "
                 "repro.compat resolves them once")
    exclude = ("compat.py",)

    _MOVED = {"CompilerParams", "PrefetchScalarGridSpec", "PrefetchGridSpec"}

    def check(self, ctx: ModuleContext) -> Iterable[Violation]:
        """Flag direct pltpu symbol use that bypasses repro.compat."""
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.Name, ast.Attribute)) and \
                    _tail(node) == "TPUCompilerParams":
                yield self.violation(
                    ctx, node, "TPUCompilerParams is version-specific; use "
                    "repro.compat.CompilerParams")
            elif isinstance(node, ast.Attribute) and node.attr in self._MOVED:
                if _base_name(node) == "pltpu":
                    yield self.violation(
                        ctx, node, f"pltpu.{node.attr} is version-specific; "
                        f"use repro.compat.{node.attr}")
            elif isinstance(node, ast.ImportFrom):
                mod = node.module or ""
                if mod.startswith("jax.experimental.pallas"):
                    for a in node.names:
                        if a.name in self._MOVED or \
                                a.name == "TPUCompilerParams":
                            yield self.violation(
                                ctx, node, f"import of {a.name} from {mod}; "
                                f"use repro.compat")


# ---------------------------------------------------------------------------
# RA2xx — hot-region hazards
# ---------------------------------------------------------------------------

class HotRule(Rule):
    """Base for rules that only inspect inferred hot (traced) regions."""

    def check(self, ctx: ModuleContext) -> Iterable[Violation]:
        """Fan out to :meth:`check_hot_node` over every hot AST node."""
        for region, node in ctx.iter_hot_nodes():
            yield from self.check_hot_node(ctx, region, node)

    def check_hot_node(self, ctx: ModuleContext, region, node: ast.AST
                       ) -> Iterable[Violation]:
        """Yield violations for one node inside a hot region (override)."""
        raise NotImplementedError


@register
class HostSyncRule(HotRule):
    """RA201: no host synchronization inside traced code.

    ``block_until_ready`` / ``.item()`` / ``jax.device_get`` /
    ``np.asarray`` / ``np.array`` on a traced value force a device->host
    transfer per call — inside a k-round fused dispatch that reintroduces
    the per-round sync the engine exists to remove.
    """

    rule_id = "RA201"
    severity = Severity.ERROR
    title = "host sync inside a traced (jit/shard_map) region"
    rationale = ("one stray sync inside a fused k-round dispatch erases "
                 "the nested-stage wins of paper §III-IV")

    _SYNC_ATTRS = {"block_until_ready", "item"}
    _SYNC_JAX = {"device_get", "device_put"}
    _SYNC_NP = {"asarray", "array", "copyto", "save", "savez"}

    def check_hot_node(self, ctx, region, node):
        """Flag explicit sync calls in hot code."""
        if not isinstance(node, ast.Call):
            return
        fn = node.func
        tail = _tail(fn)
        if isinstance(fn, ast.Attribute):
            if tail in self._SYNC_ATTRS:
                yield self.violation(
                    ctx, node, f".{tail}() in hot region "
                    f"{region.qualname!r} forces a device sync")
            elif tail in self._SYNC_JAX and _base_name(fn) == "jax":
                yield self.violation(
                    ctx, node, f"jax.{tail} in hot region "
                    f"{region.qualname!r} is a host transfer")
            elif tail in self._SYNC_NP and _base_name(fn) in _NP_MODULES \
                    and not _all_literal(node.args):
                yield self.violation(
                    ctx, node, f"np.{tail} on a traced value in hot region "
                    f"{region.qualname!r} transfers to host; use jnp")


@register
class NumpyInHotRule(HotRule):
    """RA202: no numpy *computation* inside traced code.

    ``np.*`` calls on traced values either sync to host or fail at trace
    time; dtype references (``np.float32`` as an argument) and literal-
    only constant folding are allowed.  RA201 owns the conversion calls
    (``asarray``/``array``); this rule owns everything else.
    """

    rule_id = "RA202"
    severity = Severity.ERROR
    title = "numpy call inside a traced region"
    rationale = "numpy computes on host; traced values must stay in jnp/lax"

    _EXEMPT = HostSyncRule._SYNC_NP  # RA201's findings, not duplicated here

    def check_hot_node(self, ctx, region, node):
        """Flag non-literal np.* calls in hot code."""
        if not isinstance(node, ast.Call):
            return
        fn = node.func
        if isinstance(fn, ast.Attribute) and _base_name(fn) in _NP_MODULES \
                and fn.attr not in self._EXEMPT \
                and not _all_literal(node.args):
            yield self.violation(
                ctx, node, f"np.{fn.attr}(...) in hot region "
                f"{region.qualname!r} runs on host; use jnp.{fn.attr}")


@register
class ImplicitCastRule(HotRule):
    """RA203: no ``float()``/``int()``/``bool()`` on array expressions in
    traced code — they call ``__float__`` on the tracer, which is a
    concretization (host sync) or a trace error.  Heuristic: only flagged
    when the argument contains a call or subscript (casting a static
    Python scalar like ``float(num_nodes)`` is fine)."""

    rule_id = "RA203"
    severity = Severity.ERROR
    title = "implicit scalar cast of a traced value"
    rationale = ("float()/int() on a tracer concretizes it — host sync or "
                 "ConcretizationTypeError")

    _CASTS = {"float", "int", "bool"}

    def check_hot_node(self, ctx, region, node):
        """Flag float()/int()/bool() over computed expressions."""
        if not (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
                and node.func.id in self._CASTS and len(node.args) == 1):
            return
        arg = node.args[0]
        if any(isinstance(n, (ast.Call, ast.Subscript))
               for n in ast.walk(arg)):
            yield self.violation(
                ctx, node, f"{node.func.id}() over a computed expression in "
                f"hot region {region.qualname!r} concretizes a traced value")


@register
class DeviceLoopRule(HotRule):
    """RA204: no Python ``for`` over devices/nodes inside traced code —
    it unrolls the mesh into the program (one copy of the body per
    device), defeating SPMD and exploding compile time.  Loops over plan
    *layers* (depth) are the intended unrolling and are not flagged."""

    rule_id = "RA204"
    severity = Severity.ERROR
    title = "Python loop over devices inside a traced region"
    rationale = ("for-over-devices inside jit unrolls the mesh; device "
                 "parallelism belongs to shard_map/collectives")

    def check_hot_node(self, ctx, region, node):
        """Flag for-loops whose iterable is device-shaped."""
        if not isinstance(node, ast.For):
            return
        it = node.iter
        src = ast.unparse(it)
        if isinstance(it, ast.Call):
            tail = _tail(it.func)
            if tail in ("devices", "local_devices"):
                yield self.violation(
                    ctx, node, f"iterating {src!r} in hot region "
                    f"{region.qualname!r}")
                return
            if tail == "range" and _DEVICEISH_RE.search(src):
                yield self.violation(
                    ctx, node, f"for over {src!r} in hot region "
                    f"{region.qualname!r} unrolls per-device work")


@register
class Float64Rule(HotRule):
    """RA205: no float64 on device paths.  TPUs emulate f64 (slow) and
    the stack's wire/merge formats are f32; the f64 oracles (simulator,
    sim graph loops) are host code and stay exempt because this rule only
    fires inside traced regions."""

    rule_id = "RA205"
    severity = Severity.ERROR
    title = "float64 dtype inside a traced region"
    rationale = ("device paths are fp32 end-to-end (kernels, wire format); "
                 "f64 silently deoptimizes and breaks parity with benches")

    _F64 = {"float64", "double", "f64", "complex128"}

    def check_hot_node(self, ctx, region, node):
        """Flag f64 dtype references in hot code."""
        if isinstance(node, ast.Attribute) and node.attr in self._F64 and \
                _base_name(node) in (_NP_MODULES | {"jnp", "jax"}):
            yield self.violation(
                ctx, node, f"{ast.unparse(node)} in hot region "
                f"{region.qualname!r}; device paths are fp32")
        elif isinstance(node, ast.Constant) and node.value in self._F64:
            yield self.violation(
                ctx, node, f"dtype string {node.value!r} in hot region "
                f"{region.qualname!r}; device paths are fp32")


@register
class DebugInHotRule(HotRule):
    """RA206: no ``print`` / ``breakpoint`` / ``pdb`` inside traced code.
    A bare ``print`` runs once at trace time (misleading) and pins host
    objects; use ``jax.debug.print`` (which is allowed) for runtime
    values."""

    rule_id = "RA206"
    severity = Severity.WARNING
    title = "host debug call inside a traced region"
    rationale = ("print in a traced fn fires at trace time, not run time; "
                 "jax.debug.print is the traced-safe spelling")

    def check_hot_node(self, ctx, region, node):
        """Flag print()/breakpoint()/pdb.set_trace() in hot code."""
        if not isinstance(node, ast.Call):
            return
        fn = node.func
        if isinstance(fn, ast.Name) and fn.id in ("print", "breakpoint"):
            yield self.violation(
                ctx, node, f"{fn.id}() in hot region {region.qualname!r}; "
                f"use jax.debug.print for runtime values")
        elif isinstance(fn, ast.Attribute) and fn.attr == "set_trace" and \
                _base_name(fn) in ("pdb", "ipdb"):
            yield self.violation(
                ctx, node, f"debugger entry in hot region "
                f"{region.qualname!r}")


@register
class WirePathWideningCastRule(HotRule):
    """RA207: no widening dtype casts on packed wire buffers inside hot
    regions of the wire path.

    The compressed wire formats (``kernels.wirecodec``) exist so bit-packed
    index words and quantized values traverse the butterfly *without* a
    widened intermediate — decode is fused into the merge kernels
    (``ops.merge_sorted_runs(row_scale=...)``).  An ``astype(jnp.float32)``
    / ``jnp.uint32(...)`` on a packed buffer (identifier matching
    ``packed|words|wire|payload``) inside traced wire-path code
    materializes the 4-byte form the codec was built to avoid, silently
    restoring raw-size HBM traffic right where the compression win lives.
    Widening a *decoded* value (``base``, ``val``, ``scale`` …) is fine —
    the receiver-name gate keeps those out of scope.
    """

    rule_id = "RA207"
    severity = Severity.ERROR
    title = "widening cast on a packed wire buffer in the wire path"
    rationale = ("the wire codecs keep payloads packed end-to-end (decode "
                 "fuses into the merge kernels); widening a packed buffer "
                 "in traced code re-materializes the raw-size intermediate "
                 "the compression exists to avoid")
    scope = ("kernels/*.py", "core/allreduce.py")

    # >= 4-byte element types: casting a packed buffer to any of these
    # re-materializes (at least) the raw wire width.
    _WIDE = {"float32", "float64", "uint32", "int32", "uint64", "int64",
             "complex64", "complex128"}
    _PACKED_RE = re.compile(r"packed|words|wire|payload", re.IGNORECASE)

    def _wide_dtype(self, node: ast.AST) -> Optional[str]:
        """Dtype name when ``node`` denotes a >= 4-byte dtype, else None."""
        if isinstance(node, ast.Attribute) and node.attr in self._WIDE and \
                _base_name(node) in (_NP_MODULES | {"jnp", "jax"}):
            return node.attr
        if isinstance(node, ast.Constant) and isinstance(node.value, str) \
                and node.value in self._WIDE:
            return node.value
        if isinstance(node, ast.keyword):
            return self._wide_dtype(node.value)
        return None

    @staticmethod
    def _receiver_root(node: ast.AST) -> Optional[str]:
        """Leftmost Name through subscript/attribute chains
        (``words[:, w].astype`` roots at ``words``)."""
        while isinstance(node, (ast.Attribute, ast.Subscript)):
            node = node.value
        return node.id if isinstance(node, ast.Name) else None

    def check_hot_node(self, ctx, region, node):
        """Flag astype/constructor widening of packed-buffer receivers."""
        if not isinstance(node, ast.Call):
            return
        fn = node.func
        if isinstance(fn, ast.Attribute) and fn.attr == "astype":
            dt = None
            if node.args:
                dt = self._wide_dtype(node.args[0])
            for kw in node.keywords:
                dt = dt or self._wide_dtype(kw)
            root = self._receiver_root(fn.value)
            if dt and root and self._PACKED_RE.search(root):
                yield self.violation(
                    ctx, node, f"{root}.astype({dt}) widens a packed wire "
                    f"buffer in hot region {region.qualname!r}; keep the "
                    f"payload packed (decode fuses into the merge kernels)")
        elif isinstance(fn, ast.Attribute) and fn.attr in self._WIDE and \
                _base_name(fn) in (_NP_MODULES | {"jnp"}) and node.args:
            root = self._receiver_root(node.args[0])
            if root and self._PACKED_RE.search(root):
                yield self.violation(
                    ctx, node, f"jnp.{fn.attr}({root}) widens a packed "
                    f"wire buffer in hot region {region.qualname!r}; keep "
                    f"the payload packed")


# ---------------------------------------------------------------------------
# RA3xx — jit hygiene
# ---------------------------------------------------------------------------

@register
class StaticArgHashableRule(Rule):
    """RA301: parameters declared static to ``jit`` must be hashable.

    A list/dict/set default on a ``static_argnums``/``static_argnames``
    parameter raises ``TypeError: unhashable type`` on the first call
    that relies on the default — typically in a rarely-exercised branch,
    long after the jit was written.
    """

    rule_id = "RA301"
    severity = Severity.ERROR
    title = "unhashable default on a static jit argument"
    rationale = ("jit static args are dict keys of the compilation cache; "
                 "unhashable defaults explode at call time")

    _JIT = {"jit", "pjit"}

    def check(self, ctx: ModuleContext) -> Iterable[Violation]:
        """Correlate jit static-arg declarations with target defaults."""
        defs = {}
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                defs.setdefault(node.name, node)
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    kw = self._static_kwargs(dec)
                    if kw:
                        yield from self._check_target(ctx, node, kw)
            elif isinstance(node, ast.Call):
                kw = self._static_kwargs(node)
                if kw and node.args:
                    target = defs.get(_tail(node.args[0]) or "")
                    if target is not None:
                        yield from self._check_target(ctx, target, kw)

    def _static_kwargs(self, node: ast.AST) -> dict:
        """{'static_argnums': node, ...} when ``node`` is a jit(...) or
        partial(jit, ...) call carrying static-arg declarations."""
        if not isinstance(node, ast.Call):
            return {}
        tail = _tail(node.func)
        if tail == "partial" and node.args and \
                _tail(node.args[0]) in self._JIT:
            tail = _tail(node.args[0])
        if tail not in self._JIT:
            return {}
        return {k.arg: k.value for k in node.keywords
                if k.arg in ("static_argnums", "static_argnames")}

    def _check_target(self, ctx, fn, static_kw):
        """Flag unhashable defaults on the declared-static params."""
        args = fn.args.posonlyargs + fn.args.args
        names: Set[str] = set()
        nums = static_kw.get("static_argnums")
        if nums is not None:
            for idx in self._int_values(nums):
                if 0 <= idx < len(args):
                    names.add(args[idx].arg)
        argnames = static_kw.get("static_argnames")
        if argnames is not None:
            names |= set(self._str_values(argnames))
        defaults = dict(zip([a.arg for a in args[len(args)
                                                 - len(fn.args.defaults):]],
                            fn.args.defaults))
        defaults.update(
            {a.arg: d for a, d in zip(fn.args.kwonlyargs,
                                      fn.args.kw_defaults) if d is not None})
        for name in sorted(names):
            d = defaults.get(name)
            if d is not None and isinstance(
                    d, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                        ast.DictComp, ast.SetComp)):
                yield self.violation(
                    ctx, d, f"static jit arg {name!r} of {fn.name!r} has an "
                    f"unhashable {type(d).__name__.lower()} default; use a "
                    f"tuple/frozenset")

    @staticmethod
    def _int_values(node: ast.AST) -> List[int]:
        """Constant ints inside a static_argnums expression."""
        return [n.value for n in ast.walk(node)
                if isinstance(n, ast.Constant) and isinstance(n.value, int)]

    @staticmethod
    def _str_values(node: ast.AST) -> List[str]:
        """Constant strs inside a static_argnames expression."""
        return [n.value for n in ast.walk(node)
                if isinstance(n, ast.Constant) and isinstance(n.value, str)]


# ---------------------------------------------------------------------------
# RA4xx — documentation (port of tests/test_docs.py's ast docstring lint)
# ---------------------------------------------------------------------------

def _public_defs(tree: ast.AST):
    """(qualname, node) for public module-level functions/classes and
    public methods of public classes (the shape the old
    tests/test_docs.py lint checked)."""
    out = []
    for n in tree.body:
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                          ast.ClassDef)) and not n.name.startswith("_"):
            out.append((n.name, n))
            if isinstance(n, ast.ClassDef):
                out.extend((f"{n.name}.{m.name}", m) for m in n.body
                           if isinstance(m, (ast.FunctionDef,
                                             ast.AsyncFunctionDef))
                           and not m.name.startswith("_"))
    return out


@register
class PublicDocstringRule(Rule):
    """RA401: every public function/class/method in the documented
    surface (``core/*``, ``analysis/*``) carries a docstring — the
    tuner/cache PR made core the documented API layer; the analysis layer
    holds itself to the same bar."""

    rule_id = "RA401"
    severity = Severity.ERROR
    title = "public symbol without a docstring"
    rationale = ("core/ and analysis/ are the documented surface "
                 "(ARCHITECTURE.md); undocumented publics rot first")
    scope = ("core/*.py", "analysis/*.py")

    def check(self, ctx: ModuleContext) -> Iterable[Violation]:
        """Flag public defs missing docstrings."""
        for qual, node in _public_defs(ctx.tree):
            if ast.get_docstring(node) is None:
                yield self.violation(
                    ctx, node, f"public symbol {qual!r} has no docstring")


@register
class ModuleDocstringRule(Rule):
    """RA402: every module under ``src/repro`` opens with a docstring
    saying what it is — the repo's modules are the unit of navigation in
    ARCHITECTURE.md's module map."""

    rule_id = "RA402"
    severity = Severity.WARNING
    title = "module without a docstring"
    rationale = "ARCHITECTURE.md's module map is built from these"

    def check(self, ctx: ModuleContext) -> Iterable[Violation]:
        """Flag modules whose first statement is not a docstring."""
        if ast.get_docstring(ctx.tree) is None:
            yield self.violation(
                ctx, ctx.tree.body[0] if getattr(ctx.tree, "body", None)
                else ctx.tree, "module has no docstring")


# ---------------------------------------------------------------------------
# RA5xx — resilience invariants (fault handling + checkpoint safety)
# ---------------------------------------------------------------------------

def _silent_body(body: List[ast.stmt]) -> bool:
    """True when an except body does nothing: only ``pass``, ``...``/
    constant expressions, or ``continue`` — the handler observes a fault
    and drops it on the floor."""
    for st in body:
        if isinstance(st, (ast.Pass, ast.Continue)):
            continue
        if isinstance(st, ast.Expr) and isinstance(st.value, ast.Constant):
            continue
        return False
    return True


def _handler_names(h: ast.ExceptHandler) -> Set[str]:
    """Exception type names a handler catches (tails of dotted names)."""
    types = h.type.elts if isinstance(h.type, ast.Tuple) else [h.type]
    return {t for t in (_tail(n) for n in types) if t}


@register
class DeadNodeSwallowRule(Rule):
    """RA501: outside ``repro.resilience`` nothing may swallow a
    :class:`DeadLogicalNode` — bare ``except:`` handlers and handlers
    that catch ``DeadLogicalNode`` just to ``pass`` hide a fatal fault
    from the supervision layer, turning a survivable failure into a
    silently wrong reduction (paper §V's guarantee only holds when the
    dead set reaches the replanner)."""

    rule_id = "RA501"
    severity = Severity.ERROR
    title = "fault swallowed outside the resilience layer"
    rationale = ("DeadLogicalNode is the supervisor's only detection "
                 "signal; swallowing it bypasses replan-over-survivors "
                 "(repro.resilience) and corrupts results")
    exclude = ("resilience/*",)

    def check(self, ctx: ModuleContext) -> Iterable[Violation]:
        """Flag bare excepts and pass-only DeadLogicalNode handlers."""
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if node.type is None:
                yield self.violation(
                    ctx, node, "bare 'except:' can swallow "
                    "DeadLogicalNode (and everything else); catch "
                    "specific exceptions")
            elif "DeadLogicalNode" in _handler_names(node) and \
                    _silent_body(node.body):
                yield self.violation(
                    ctx, node, "DeadLogicalNode caught and silently "
                    "dropped; route faults through repro.resilience "
                    "(ResilientAllreduce / SupervisedEngineLoop) or "
                    "re-raise")


@register
class AtomicCheckpointRule(Rule):
    """RA502: checkpoint payloads must be written through the atomic
    :func:`repro.checkpoint.store.save` (tempfile + fsync +
    ``os.replace``) — a direct ``np.savez``/``np.save`` can be killed
    mid-write and leave a truncated artifact that the exact-resume path
    then trips over."""

    rule_id = "RA502"
    severity = Severity.ERROR
    title = "non-atomic checkpoint write"
    rationale = ("kill-and-resume (repro.launch.soak) relies on every "
                 "on-disk artifact being complete-or-absent; only "
                 "checkpoint/store.py may call the raw numpy writers")
    exclude = ("checkpoint/store.py",)

    _WRITERS = {"save", "savez", "savez_compressed"}

    def check(self, ctx: ModuleContext) -> Iterable[Violation]:
        """Flag direct numpy array-writer calls."""
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            fn = node.func
            if isinstance(fn, ast.Attribute) and \
                    fn.attr in self._WRITERS and \
                    _base_name(fn) in _NP_MODULES:
                yield self.violation(
                    ctx, node, f"direct numpy '{fn.attr}' write; persist "
                    "through repro.checkpoint.store.save for "
                    "atomic crash-safe artifacts")
