"""Command-line front end: ``python -m repro.analysis [paths] [flags]``.

Default run lints the given paths (ERROR severity fails; add ``--strict``
to fail on warnings too).  ``--audit`` additionally runs the jaxpr
dispatch auditor's self-contained sweep (traces real SparseAllreduce /
GraphEngine entry points on forced host devices — needs jax, a few
seconds).  ``--json`` writes the combined machine-readable report,
``--list-rules`` prints the catalog, ``--select`` restricts to given
rule ids.

Exit codes: 0 clean, 1 findings/audit failures, 2 usage or internal
error.  The console entry ``repro-analysis`` (pyproject) is the same
main.
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import List, Optional, Sequence

from .engine import all_rules, lint_paths
from .violations import AnalysisReport

_AUDIT_DEVICES = 8  # host-device count forced for the --audit sweep


def _build_parser() -> argparse.ArgumentParser:
    """The argparse surface (flags documented in README 'Static checks')."""
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="AST lint + jaxpr dispatch audit for the repro stack")
    p.add_argument("paths", nargs="*", default=["src"],
                   help="files/directories to lint (default: src)")
    p.add_argument("--strict", action="store_true",
                   help="fail on warnings too, not just errors")
    p.add_argument("--select", action="append", default=None,
                   metavar="RULE", help="only run these rule ids "
                   "(repeatable, e.g. --select RA201)")
    p.add_argument("--json", metavar="PATH", default=None,
                   help="write the machine-readable report to PATH "
                   "('-' for stdout)")
    p.add_argument("--audit", action="store_true",
                   help="also run the jaxpr dispatch auditor sweep "
                   "(imports jax, forces host devices)")
    p.add_argument("--list-rules", action="store_true",
                   help="print the rule catalog and exit")
    return p


def _list_rules() -> None:
    """Print the catalog: id, severity, scope, title."""
    for cls in all_rules():
        scope = ",".join(cls.scope)
        print(f"{cls.rule_id}  {cls.severity:7s}  [{scope}]  {cls.title}")


def _audit_sweep() -> List:
    """Self-contained auditor run: real entry points, small shapes.

    Covers degrees {(4,), (2,2)} x replication {1, 2} for the reduce path
    and a (4,2) PageRank engine for the k-round dispatch contract — all
    within 8 forced host devices.
    """
    # must precede the first jax import in this process
    os.environ.setdefault(
        "XLA_FLAGS",
        f"--xla_force_host_platform_device_count={_AUDIT_DEVICES}")
    import jax
    import numpy as np

    from repro.core.api import SparseAllreduce
    from .auditor import audit_engine, audit_reduce

    reports = []
    for degs in [(4,), (2, 2)]:
        m = int(np.prod(degs))
        rng = np.random.RandomState(m)
        out_idx = [rng.choice(4096, rng.randint(5, 16),
                              replace=False).astype(np.uint32)
                   for _ in range(m)]
        in_idx = [rng.choice(4096, rng.randint(5, 16),
                             replace=False).astype(np.uint32)
                  for _ in range(m)]
        for r in (1, 2):
            ar = SparseAllreduce(m, degs, backend="device", replication=r,
                                 mesh=jax.make_mesh((m * r,), ("d",)),
                                 seed=m)
            ar.config(out_idx, in_idx)
            reports.append(audit_reduce(ar))

    from repro.data.pipeline import powerlaw_graph
    from repro.graph.pagerank import build_partitions, make_pagerank_engine
    edges = powerlaw_graph(300, 1200, seed=1)
    parts = build_partitions(edges, 300, _AUDIT_DEVICES)
    engine, extras, p0 = make_pagerank_engine(
        parts, 300, degrees=(4, 2),
        mesh=jax.make_mesh((_AUDIT_DEVICES,), ("d",)))
    reports.append(audit_engine(engine, 5, p0, extras))

    # overlap schedules: the double-buffered engine rotation and the
    # bucketed stage-major dense sync (pure-reordering contract)
    from repro.graph.engine import GraphEngine
    import numpy as _np
    ov_engine = GraphEngine(
        [_np.asarray(o) for o in engine.out_sets],
        [_np.asarray(i) for i in engine.in_sets],
        engine.app, degrees=(4, 2),
        mesh=jax.make_mesh((_AUDIT_DEVICES,), ("d",)), overlap=True)
    reports.append(audit_engine(ov_engine, 5, p0, extras))

    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    from .auditor import audit_overlap_sync
    from repro.core.allreduce import (dense_allreduce_hierarchical,
                                      dense_allreduce_hierarchical_bucketed,
                                      make_device_plan)
    plan = make_device_plan([("d", _AUDIT_DEVICES)], {"d": (4, 2)}, 8, 8)
    mesh = jax.make_mesh((_AUDIT_DEVICES,), ("d",))
    sizes = (64, 32, 96)

    def _mk(schedule):
        def body(*xs):
            xs = [x.reshape(x.shape[1:]) for x in xs]
            if schedule == "stage_major":
                outs = dense_allreduce_hierarchical_bucketed(xs, plan)
            else:
                outs = [dense_allreduce_hierarchical(x, plan) for x in xs]
            return tuple(o[None] for o in outs)
        return shard_map(body, mesh=mesh,
                         in_specs=(P("d"),) * len(sizes),
                         out_specs=(P("d"),) * len(sizes), check_vma=False)

    args = tuple(jnp.zeros((_AUDIT_DEVICES, n), jnp.float32) for n in sizes)
    reports.append(audit_overlap_sync(
        "dense_allreduce_hierarchical_bucketed", _mk("stage_major"),
        _mk("sequential"), *args, depth=plan.logical.depth,
        n_buckets=len(sizes)))
    return reports


def main(argv: Optional[Sequence[str]] = None) -> int:
    """Entry point; returns the process exit code."""
    args = _build_parser().parse_args(argv)
    if args.list_rules:
        _list_rules()
        return 0

    report = AnalysisReport()
    try:
        report.violations, report.files_checked = lint_paths(
            args.paths, select=args.select)
    except (FileNotFoundError, SyntaxError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    if args.audit:
        report.audits = _audit_sweep()

    for v in report.violations:
        print(v)
    for a in report.audits:
        status = "ok" if a.ok else "FAIL"
        print(f"audit [{status}] {a.target}")
        for c in a.failures():
            print(f"    {c}")

    if args.json:
        text = report.to_json(None if args.json == "-" else args.json)
        if args.json == "-":
            print(text)

    ok = report.ok(strict=args.strict)
    n_err, n_all = len(report.errors), len(report.violations)
    print(f"{report.files_checked} files checked: {n_all} finding(s) "
          f"({n_err} error(s))"
          + (f", {len(report.audits)} audit(s)" if report.audits else "")
          + f" -> {'clean' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":  # pragma: no cover - exercised via __main__.py
    sys.exit(main())
