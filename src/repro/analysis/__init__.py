"""Static-analysis layer: AST lint rules + jaxpr dispatch auditor.

Two layers statically enforce the stack's performance and correctness
invariants (ARCHITECTURE.md "Static analysis"):

* **Layer 1 — AST lint** (:mod:`repro.analysis.rules` on the engine in
  :mod:`repro.analysis.engine`): a rule catalog over ``src/repro/**``
  source — compat-layering (version-sensitive JAX symbols only via
  ``repro.compat``), no host syncs / numpy / implicit casts / float64 /
  device loops / prints inside jit-traced hot regions (inferred by
  :mod:`repro.analysis.hotpath`), hashable static-argnum hygiene, and
  public-docstring coverage.  Pure ``ast`` — linting never imports the
  linted code (or jax).
* **Layer 2 — jaxpr dispatch auditor** (:mod:`repro.analysis.auditor`):
  traces the real public entry points (``SparseAllreduce.reduce``,
  ``GraphEngine`` runs, ``make_train_step``) to jaxprs and verifies the
  collective count equals the plan depth, k-round engine runs stay one
  dispatch (all collectives inside a single ``scan``), no callback /
  transfer primitives on hot paths, and dtype stability across scan
  carries.

CLI: ``python -m repro.analysis src --strict`` (see README "Static
checks"); both layers are regression-tested by ``tests/test_analysis.py``
and timed by ``benchmarks/bench_analysis.py``.
"""
from .violations import (AnalysisReport, AuditReport, CheckResult,  # noqa: F401
                         Severity, Violation)
from .engine import ModuleContext, Rule, all_rules, lint_paths  # noqa: F401
from .hotpath import HotRegion, build_hot_map  # noqa: F401
from . import rules as _rules  # noqa: F401  (registers the catalog)

__all__ = [
    "AnalysisReport", "AuditReport", "CheckResult", "Severity", "Violation",
    "ModuleContext", "Rule", "all_rules", "lint_paths",
    "HotRegion", "build_hot_map",
]
