"""Lint rule engine: contexts, the Rule base class, registry and runner.

Rules are classes (one instance per run) with an ``id``, ``severity``,
``scope`` (fnmatch patterns against the repro-package-relative path) and
a ``check(ctx)`` generator of :class:`~repro.analysis.violations.Violation`.
The engine parses each file once into a :class:`ModuleContext` (source +
AST + lazily-inferred hot regions + ``# noqa`` map) and fans it out to
every in-scope rule.  Linting never imports the linted code.

Suppression: ``# noqa: RA201`` on the offending line silences that rule
there (a bare ``# noqa`` silences all rules on the line).  Repo policy
(ISSUE 6): every suppression carries the rule id so intent is grep-able.
"""
from __future__ import annotations

import ast
import dataclasses
import fnmatch
import functools
import os
import re
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Set, Type

from .hotpath import HotRegion, build_hot_map
from .violations import Severity, Violation

_NOQA_RE = re.compile(r"#\s*noqa(?::\s*(?P<ids>[A-Z]+\d+(?:\s*,\s*[A-Z]+\d+)*))?",
                      re.IGNORECASE)


@dataclasses.dataclass
class ModuleContext:
    """Everything a rule may inspect about one source file."""

    path: str                       # absolute path on disk
    display: str                    # path as reported in violations
    pkg_rel: str                    # path relative to the repro package
    source: str
    tree: ast.AST

    @classmethod
    def from_file(cls, path: str, display: Optional[str] = None
                  ) -> "ModuleContext":
        """Parse ``path`` into a context; raises SyntaxError on bad code."""
        with open(path) as f:
            source = f.read()
        ap = os.path.abspath(path)
        return cls(path=ap, display=display or os.path.relpath(ap),
                   pkg_rel=package_relpath(ap), source=source,
                   tree=ast.parse(source, filename=path))

    @functools.cached_property
    def lines(self) -> List[str]:
        """Source split into lines (1-based access via ``lines[n-1]``)."""
        return self.source.splitlines()

    @functools.cached_property
    def hot_regions(self) -> List[HotRegion]:
        """Inferred traced regions (see :mod:`repro.analysis.hotpath`)."""
        return build_hot_map(self.tree, self.source)

    @functools.cached_property
    def noqa(self) -> Dict[int, Optional[Set[str]]]:
        """line -> suppressed rule-id set (None = all rules suppressed)."""
        out: Dict[int, Optional[Set[str]]] = {}
        for i, line in enumerate(self.lines, start=1):
            m = _NOQA_RE.search(line)
            if not m:
                continue
            ids = m.group("ids")
            out[i] = ({s.strip().upper() for s in ids.split(",")}
                      if ids else None)
        return out

    def suppressed(self, rule_id: str, line: int) -> bool:
        """True when a ``# noqa`` on ``line`` covers ``rule_id``."""
        if line not in self.noqa:
            return False
        ids = self.noqa[line]
        return ids is None or rule_id in ids

    def iter_hot_nodes(self) -> Iterator[tuple]:
        """Yield ``(region, node)`` for every AST node in a hot region."""
        for region in self.hot_regions:
            for node in region.walk():
                yield region, node


def package_relpath(path: str) -> str:
    """Path relative to the innermost ``repro`` package dir (so rule
    scopes read ``core/planned.py``), else the basename."""
    parts = os.path.abspath(path).split(os.sep)
    for i in range(len(parts) - 1, -1, -1):
        if parts[i] == "repro":
            return "/".join(parts[i + 1:])
    return parts[-1]


class Rule:
    """Base class for one lint rule.

    Subclasses set ``rule_id`` / ``severity`` / ``title`` / ``rationale``
    and implement :meth:`check`.  ``scope`` / ``exclude`` are fnmatch
    patterns over the package-relative path (``core/planned.py``).
    """

    rule_id: str = "RA000"
    severity: str = Severity.ERROR
    title: str = ""
    rationale: str = ""
    scope: Sequence[str] = ("*",)
    exclude: Sequence[str] = ()

    def applies_to(self, ctx: ModuleContext) -> bool:
        """Scope gate: pkg-relative path must match ``scope`` and miss
        ``exclude``."""
        rel = ctx.pkg_rel
        if not any(fnmatch.fnmatch(rel, p) for p in self.scope):
            return False
        return not any(fnmatch.fnmatch(rel, p) for p in self.exclude)

    def check(self, ctx: ModuleContext) -> Iterable[Violation]:
        """Yield violations found in ``ctx`` (override)."""
        raise NotImplementedError

    def violation(self, ctx: ModuleContext, node: ast.AST, message: str
                  ) -> Violation:
        """Build a Violation anchored at ``node``."""
        return Violation(rule_id=self.rule_id, severity=self.severity,
                         path=ctx.display,
                         line=getattr(node, "lineno", 1),
                         col=getattr(node, "col_offset", 0),
                         message=message)


_REGISTRY: List[Type[Rule]] = []


def register(cls: Type[Rule]) -> Type[Rule]:
    """Class decorator adding a rule to the global catalog (id-unique)."""
    if any(r.rule_id == cls.rule_id for r in _REGISTRY):
        raise ValueError(f"duplicate rule id {cls.rule_id}")
    _REGISTRY.append(cls)
    return cls


def all_rules() -> List[Type[Rule]]:
    """The registered rule catalog, in registration order."""
    from . import rules  # noqa: F401  (ensure catalog is registered)
    return list(_REGISTRY)


def discover_files(paths: Sequence[str]) -> List[str]:
    """Expand files/dirs into a sorted list of ``.py`` files."""
    out: List[str] = []
    for p in paths:
        if os.path.isfile(p):
            out.append(p)
        elif os.path.isdir(p):
            for dirpath, dirnames, files in os.walk(p):
                dirnames[:] = [d for d in dirnames
                               if d not in ("__pycache__", ".git")]
                out.extend(os.path.join(dirpath, f)
                           for f in files if f.endswith(".py"))
        else:
            raise FileNotFoundError(p)
    return sorted(set(out))


def lint_paths(paths: Sequence[str],
               select: Optional[Iterable[str]] = None,
               ) -> tuple:
    """Run the catalog over ``paths`` (files or directories).

    ``select``: optional rule-id whitelist.  Returns
    ``(violations, files_checked)`` with violations ordered by
    (path, line, rule id); ``# noqa``-suppressed findings are dropped.
    """
    wanted = {s.upper() for s in select} if select else None
    rules = [cls() for cls in all_rules()
             if wanted is None or cls.rule_id in wanted]
    violations: List[Violation] = []
    files = discover_files(paths)
    for path in files:
        ctx = ModuleContext.from_file(path)
        for rule in rules:
            if not rule.applies_to(ctx):
                continue
            for v in rule.check(ctx):
                if not ctx.suppressed(v.rule_id, v.line):
                    violations.append(v)
    violations.sort(key=lambda v: (v.path, v.line, v.rule_id))
    return violations, len(files)
