"""Result datatypes shared by the lint engine and the jaxpr auditor.

Everything here is plain data with a ``to_dict`` — the CLI's ``--json``
output and the regression tests consume the same machine-readable shape.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional


class Severity:
    """Violation severity levels (plain strings, ordered ERROR > WARNING).

    ``ERROR`` fails the default CLI run; ``WARNING`` only fails under
    ``--strict`` (which treats every finding as fatal).
    """

    ERROR = "error"
    WARNING = "warning"


@dataclasses.dataclass(frozen=True)
class Violation:
    """One lint finding: a rule fired at a source location."""

    rule_id: str
    severity: str
    path: str            # display path of the offending file
    line: int            # 1-based line of the offending node
    col: int             # 0-based column
    message: str

    def to_dict(self) -> Dict[str, Any]:
        """JSON-ready mapping (the ``--json`` record shape)."""
        return dataclasses.asdict(self)

    def __str__(self) -> str:
        """``path:line:col: RULE severity: message`` (editor-clickable)."""
        return (f"{self.path}:{self.line}:{self.col}: {self.rule_id} "
                f"{self.severity}: {self.message}")


@dataclasses.dataclass(frozen=True)
class CheckResult:
    """One auditor assertion over a traced jaxpr."""

    check_id: str
    ok: bool
    expected: Any
    actual: Any
    detail: str = ""

    def to_dict(self) -> Dict[str, Any]:
        """JSON-ready mapping."""
        return dataclasses.asdict(self)

    def __str__(self) -> str:
        """Single-line pass/fail summary."""
        mark = "ok" if self.ok else "FAIL"
        return (f"[{mark}] {self.check_id}: expected {self.expected!r}, "
                f"actual {self.actual!r}"
                + (f" ({self.detail})" if self.detail else ""))


@dataclasses.dataclass
class AuditReport:
    """All checks run against one traced entry point."""

    target: str
    checks: List[CheckResult] = dataclasses.field(default_factory=list)

    @property
    def ok(self) -> bool:
        """True iff every check passed."""
        return all(c.ok for c in self.checks)

    def failures(self) -> List[CheckResult]:
        """The failing checks only."""
        return [c for c in self.checks if not c.ok]

    def to_dict(self) -> Dict[str, Any]:
        """JSON-ready mapping."""
        return {"target": self.target, "ok": self.ok,
                "checks": [c.to_dict() for c in self.checks]}


@dataclasses.dataclass
class AnalysisReport:
    """Combined lint + audit outcome (what ``--json`` serializes)."""

    violations: List[Violation] = dataclasses.field(default_factory=list)
    audits: List[AuditReport] = dataclasses.field(default_factory=list)
    files_checked: int = 0

    @property
    def errors(self) -> List[Violation]:
        """Violations at ERROR severity."""
        return [v for v in self.violations if v.severity == Severity.ERROR]

    def ok(self, strict: bool = False) -> bool:
        """Clean under the given strictness: no audit failures, no errors,
        and (``strict``) no warnings either."""
        if any(not a.ok for a in self.audits):
            return False
        bad = self.violations if strict else self.errors
        return not bad

    def to_dict(self) -> Dict[str, Any]:
        """JSON-ready mapping."""
        return {
            "files_checked": self.files_checked,
            "violations": [v.to_dict() for v in self.violations],
            "audits": [a.to_dict() for a in self.audits],
        }

    def to_json(self, path: Optional[str] = None, indent: int = 1) -> str:
        """Serialize (and optionally write) the report as JSON."""
        text = json.dumps(self.to_dict(), indent=indent)
        if path:
            with open(path, "w") as f:
                f.write(text)
        return text
