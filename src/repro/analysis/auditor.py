"""Layer 2 — jaxpr dispatch auditor over the real public entry points.

Where the lint layer reads source, this layer reads what JAX will
actually run: it traces ``SparseAllreduce.reduce_fn``,
``GraphEngine.run_fn(k)`` and ``make_train_step`` step functions to
jaxprs (``jax.make_jaxpr`` — tracing only, nothing executes) and asserts
the invariants the stack's performance story rests on:

* **collectives == plan structure** — one reduce lowers to exactly
  ``2 * plan.depth`` ``all_to_all`` phases (``depth`` down + ``depth``
  up; with ``replication=r>1`` the plan prepends a replica-merge stage,
  already counted in ``planned.depth``).
* **one dispatch per k-round engine run** — the whole block is a single
  top-level ``lax.scan`` whose body carries the per-round reduce; zero
  collectives outside the scan, ``2 * depth`` (+ the app's own declared
  collectives) per round inside it.
* **no host leaks on hot paths** — no callback / infeed / transfer
  primitives anywhere in the traced program.
* **dtype stability** — scan carries keep their dtypes across rounds
  (a widening carry re-allocates every round), and no float64 anywhere
  on device paths.
* **overlap schedules are pure reorderings** — the bucketed stage-major
  gradient sync moves exactly the sequential schedule's collective
  multiset, 2·depth per bucket, interleaved stage-major with mirrored up
  groups and zero barrier fences (:func:`audit_overlap_sync`); the
  double-buffered engine keeps its per-round 2·depth budget with exactly
  ``depth`` prologue collectives before the scan and ``depth`` epilogue
  after (:func:`audit_engine` with ``overlap=True`` engines).

Every audit returns a machine-readable
:class:`~repro.analysis.violations.AuditReport`; ``tests/test_analysis.py``
regression-tests the counts across degree schedules x replication and the
CLI's ``--audit`` flag runs a self-contained sweep.
"""
from __future__ import annotations

from collections import Counter
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

from .violations import AuditReport, CheckResult

# Cross-device communication primitives (jaxpr primitive names).
COLLECTIVE_PRIMS = {
    "all_to_all", "psum", "psum2", "all_gather", "reduce_scatter",
    "ppermute", "pmin", "pmax", "allreduce",
}

# Primitives that must never appear on a hot path: host callbacks stall
# the device per invocation, infeed/outfeed and device_put are transfers.
FORBIDDEN_PRIMS = {
    "pure_callback", "io_callback", "debug_callback", "callback",
    "infeed", "outfeed", "device_put", "copy_to_host_async",
}

# Primitives that open a sub-jaxpr we treat as "one dispatch region".
_SCAN_PRIMS = {"scan"}

# The dense butterfly's collective pair: psum_scatter lowers to
# ``reduce_scatter`` on the way down, ``all_gather`` on the way up.
_BUTTERFLY_PRIMS = ("reduce_scatter", "all_gather")

# Scheduling fences.  A correct overlap schedule needs none: it is a pure
# reordering of data-independent collectives, so any barrier in the traced
# program means the schedule is forcing order instead of exposing it.
_BARRIER_PRIMS = {"optimization_barrier"}


def _sub_jaxprs(eqn) -> Iterator[Any]:
    """Inner jaxprs of one equation (scan/cond/pjit/shard_map/custom_*
    bodies), wherever they hide in ``eqn.params``."""
    for v in eqn.params.values():
        for item in (v if isinstance(v, (list, tuple)) else (v,)):
            inner = getattr(item, "jaxpr", None)
            if inner is not None and hasattr(inner, "eqns"):
                yield inner            # ClosedJaxpr -> jaxpr
            elif hasattr(item, "eqns"):
                yield item             # bare jaxpr


def iter_eqns(jaxpr, _in_scan: bool = False) -> Iterator[Tuple[Any, bool]]:
    """Yield ``(eqn, inside_scan)`` for every equation, recursing into
    all sub-jaxprs.  ``inside_scan`` is True once any enclosing equation
    is a ``scan`` — the per-round region of an engine dispatch."""
    for eqn in jaxpr.eqns:
        yield eqn, _in_scan
        scoped = _in_scan or eqn.primitive.name in _SCAN_PRIMS
        for sub in _sub_jaxprs(eqn):
            yield from iter_eqns(sub, scoped)


def collective_counts(jaxpr, inside_scan: Optional[bool] = None) -> Counter:
    """Multiset of collective primitive names in ``jaxpr``; restrict to
    equations inside/outside scans with ``inside_scan=True/False``."""
    c: Counter = Counter()
    for eqn, in_scan in iter_eqns(jaxpr):
        if inside_scan is not None and in_scan != inside_scan:
            continue
        if eqn.primitive.name in COLLECTIVE_PRIMS:
            c[eqn.primitive.name] += 1
    return c


def _all_avals(jaxpr) -> Iterator[Any]:
    """Every abstract value in the program: top-level in/out plus each
    equation's operands and results, recursively."""
    for v in list(jaxpr.invars) + list(jaxpr.outvars):
        aval = getattr(v, "aval", None)
        if aval is not None:
            yield aval
    for eqn, _ in iter_eqns(jaxpr):
        for v in list(eqn.invars) + list(eqn.outvars):
            aval = getattr(v, "aval", None)
            if aval is not None:
                yield aval


def _f64_avals(jaxpr) -> List[str]:
    """Names of 64-bit float/complex avals found anywhere (should be
    empty: device paths are fp32 end-to-end)."""
    bad = []
    for aval in _all_avals(jaxpr):
        dt = str(getattr(aval, "dtype", ""))
        if dt in ("float64", "complex128"):
            bad.append(dt)
    return bad


def _scan_carry_mismatches(jaxpr) -> List[str]:
    """Scan carries whose input dtype != output dtype — each mismatch
    re-converts (and may re-allocate) the carry every round."""
    bad = []
    for eqn, _ in iter_eqns(jaxpr):
        if eqn.primitive.name not in _SCAN_PRIMS:
            continue
        body = eqn.params["jaxpr"].jaxpr
        nc = eqn.params.get("num_consts", 0)
        ncar = eqn.params.get("num_carry", 0)
        ins = body.invars[nc:nc + ncar]
        outs = body.outvars[:ncar]
        for i, (a, b) in enumerate(zip(ins, outs)):
            da = getattr(getattr(a, "aval", None), "dtype", None)
            db = getattr(getattr(b, "aval", None), "dtype", None)
            if da is not None and db is not None and da != db:
                bad.append(f"carry[{i}]: {da} -> {db}")
    return bad


def _forbidden_hits(jaxpr) -> List[str]:
    """Forbidden primitive names present in the program."""
    return sorted({eqn.primitive.name for eqn, _ in iter_eqns(jaxpr)
                   if eqn.primitive.name in FORBIDDEN_PRIMS})


def base_checks(jaxpr, prefix: str = "") -> List[CheckResult]:
    """Invariants every audited entry point must satisfy: no forbidden
    primitives, no f64, dtype-stable scan carries."""
    forb = _forbidden_hits(jaxpr)
    f64 = _f64_avals(jaxpr)
    carries = _scan_carry_mismatches(jaxpr)
    return [
        CheckResult(f"{prefix}no_forbidden_primitives", not forb,
                    expected=[], actual=forb,
                    detail="host callbacks / transfers on a hot path"),
        CheckResult(f"{prefix}no_float64", not f64,
                    expected=0, actual=len(f64),
                    detail="device paths are fp32 end-to-end"),
        CheckResult(f"{prefix}scan_carry_dtypes_stable", not carries,
                    expected=[], actual=carries,
                    detail="a widening carry re-converts every round"),
    ]


def trace_jaxpr(fn, *example_args):
    """``jax.make_jaxpr`` the callable on example args (trace only — no
    execution, no compile)."""
    import jax
    return jax.make_jaxpr(fn)(*example_args).jaxpr


def butterfly_sequence(jaxpr) -> List[Tuple[str, str]]:
    """Program-ordered ``(prim, group_signature)`` stream of the dense
    butterfly collectives (``reduce_scatter`` / ``all_gather``).  The
    signature is the repr of the equation's ``axis_index_groups`` — two
    collectives share one iff they exchange within the same stage groups,
    which is what identifies a butterfly stage in the lowered program.
    ``iter_eqns`` recurses sub-jaxprs in place, so the stream preserves
    issue order through pjit / shard_map wrappers."""
    return [(eqn.primitive.name,
             repr(eqn.params.get("axis_index_groups")))
            for eqn, _ in iter_eqns(jaxpr)
            if eqn.primitive.name in _BUTTERFLY_PRIMS]


def _contiguous_runs(seq: Sequence) -> List[Tuple[Any, int]]:
    """Collapse a sequence into ``(item, run_length)`` maximal runs."""
    runs: List[Tuple[Any, int]] = []
    for item in seq:
        if runs and runs[-1][0] == item:
            runs[-1] = (item, runs[-1][1] + 1)
        else:
            runs.append((item, 1))
    return runs


def _barrier_hits(jaxpr) -> int:
    return sum(1 for eqn, _ in iter_eqns(jaxpr)
               if eqn.primitive.name in _BARRIER_PRIMS)


def outside_scan_split(jaxpr) -> Tuple[Counter, Counter]:
    """Outside-scan collective counts split at the first top-level scan:
    ``(prologue, epilogue)``.  The double-buffered engine build issues
    round 1's bottom half before its scan and round k's top half after it
    (``GraphEngine._build_overlap``); this is the census that verifies
    the split."""
    before: Counter = Counter()
    after: Counter = Counter()
    seen_scan = False
    for eqn, in_scan in iter_eqns(jaxpr):
        if eqn.primitive.name in _SCAN_PRIMS and not in_scan:
            seen_scan = True
        if in_scan:
            continue
        if eqn.primitive.name in COLLECTIVE_PRIMS:
            (after if seen_scan else before)[eqn.primitive.name] += 1
    return before, after


# ---------------------------------------------------------------------------
# entry-point audits
# ---------------------------------------------------------------------------

def audit_reduce(sa, width: Optional[int] = None) -> AuditReport:
    """Audit one configured ``SparseAllreduce`` (device backend).

    Traces the public ``sa.reduce_fn`` on a zeros input of the staged
    shape and checks the collective count equals ``2 * planned.depth``
    (the butterfly's ``depth`` down + ``depth`` up ``all_to_all`` phases;
    ``planned.depth`` already includes the replica-merge stage prepended
    when ``replication=r>1``), plus the :func:`base_checks`.
    """
    import jax.numpy as jnp
    planned, _mesh = sa.planned_parts()
    meta = sa.staging_metadata()
    w = width if width is not None else getattr(sa, "width", 1)
    shape = (meta["num_physical"], meta["u_cap"]) + ((w,) if w > 1 else ())
    jaxpr = trace_jaxpr(sa.reduce_fn, jnp.zeros(shape, jnp.float32))

    counts = collective_counts(jaxpr)
    a2a = counts.get("all_to_all", 0)
    expected = 2 * planned.depth
    checks = [
        CheckResult("collectives_equal_plan_depth", a2a == expected,
                    expected=expected, actual=a2a,
                    detail=f"depth={planned.depth} (down+up all_to_all); "
                           f"all collectives: {dict(counts)}"),
    ]
    checks += base_checks(jaxpr)
    return AuditReport(
        target=f"SparseAllreduce.reduce_fn[depth={planned.depth}, "
               f"r={getattr(sa, 'replication', 1)}]", checks=checks)


def audit_engine(engine, k: int, state, extras=None, *,
                 collect: str = "last",
                 extra_collectives_per_round: int = 0) -> AuditReport:
    """Audit a ``GraphEngine``'s k-round dispatch.

    Traces the public ``engine.run_fn(k, collect)`` on the given example
    ``state`` / ``extras`` (shapes only matter) and checks the
    one-dispatch contract: exactly one top-level ``lax.scan``, zero
    collectives outside it, and ``2 * depth + extra_collectives_per_round``
    collectives per round inside it (apps whose ``update_fn`` runs its own
    collective — e.g. a psum normalizer — declare it via
    ``extra_collectives_per_round``).

    For a double-buffered engine (``overlap=True``, k >= 2) the contract
    rotates instead of vanishing: the scan still must be unique and the
    interior round still costs ``2 * depth + extra``, but the prologue is
    expected to issue exactly ``depth`` collectives (round 1's bottom
    half) *before* the scan and the epilogue ``depth + extra`` (round k's
    top half + update) *after* it — same per-dispatch total
    ``k * (2 * depth + extra)``, reordered, with the split position
    verified via :func:`outside_scan_split`.
    """
    import jax.numpy as jnp
    from jax.tree_util import tree_map
    fn = engine.run_fn(k, collect)
    state = tree_map(jnp.asarray, state)
    extras = tree_map(jnp.asarray, extras if extras is not None else {})
    jaxpr = trace_jaxpr(fn, state, extras, *engine.routing_args())

    n_scans = sum(1 for eqn, in_scan in iter_eqns(jaxpr)
                  if eqn.primitive.name in _SCAN_PRIMS and not in_scan)
    outside = collective_counts(jaxpr, inside_scan=False)
    inside = collective_counts(jaxpr, inside_scan=True)
    per_round = sum(inside.values())
    depth = engine.planned.depth
    expected_round = 2 * depth + extra_collectives_per_round
    overlapped = bool(getattr(engine, "overlap", False)) and k >= 2

    checks = [
        CheckResult("one_scan_dispatch", n_scans == 1,
                    expected=1, actual=n_scans,
                    detail="k rounds must fuse into a single lax.scan"),
    ]
    if overlapped:
        before, after = outside_scan_split(jaxpr)
        exp_after = depth + extra_collectives_per_round
        checks.append(CheckResult(
            "prologue_epilogue_split",
            sum(before.values()) == depth
            and sum(after.values()) == exp_after,
            expected={"before_scan": depth, "after_scan": exp_after},
            actual={"before_scan": dict(before), "after_scan": dict(after)},
            detail="double-buffered rotation: round 1's bottom half "
                   "(depth collectives) before the scan, round k's top "
                   "half + update after it — nothing else outside"))
    else:
        checks.append(CheckResult(
            "no_collectives_outside_scan", sum(outside.values()) == 0,
            expected={}, actual=dict(outside),
            detail="a collective outside the scan runs once per "
                   "dispatch instead of per round"))
    checks.append(CheckResult(
        "per_round_collectives_equal_plan_depth",
        per_round == expected_round,
        expected=expected_round, actual=per_round,
        detail=f"2*depth={2 * depth} reduce + "
               f"{extra_collectives_per_round} app-declared; "
               f"inside-scan: {dict(inside)}"))
    checks += base_checks(jaxpr)
    return AuditReport(
        target=f"GraphEngine.run_fn[k={k}, collect={collect}, "
               f"depth={depth}, overlap={overlapped}]", checks=checks)


def audit_overlap_sync(name: str, overlapped_fn, sequential_fn,
                       *example_args, depth: int,
                       n_buckets: int) -> AuditReport:
    """Audit a bucketed stage-major sync schedule against its bucket-major
    sequential twin (same buckets, one full 2·depth chain per bucket).

    The overlap story rests on the schedule being a *pure reordering*:
    the overlapped program must move exactly the same collective multiset
    as the sequential one — no hidden extra reduction smuggled in to fix
    up results (the injection test plants one and this audit must fail),
    no phase dropped.  Checks, all on traced jaxprs (nothing executes):

    * ``same_total_collectives`` — full collective census equality
      between the two programs (every collective primitive, not just the
      butterfly pair, so a hidden full-tree ``psum`` is caught).
    * ``bucket_collective_count`` — ``depth * n_buckets`` each of
      ``reduce_scatter`` and ``all_gather`` in the overlapped program
      (2·depth per bucket total).
    * ``stage_major_interleaving`` — the ordered butterfly stream is
      exactly ``2 * depth`` contiguous runs of ``n_buckets`` same-stage
      collectives: ``depth`` reduce_scatter runs (stage order) then
      ``depth`` all_gather runs whose group signatures mirror the
      reduce_scatter runs in reverse — the nested-butterfly up phase
      retracing the down phase.
    * ``no_barriers`` — zero scheduling fences: a correct overlap
      schedule exposes reorderable work, it never forces order.
    * :func:`base_checks` on the overlapped program.
    """
    jx_o = trace_jaxpr(overlapped_fn, *example_args)
    jx_s = trace_jaxpr(sequential_fn, *example_args)
    c_o = collective_counts(jx_o)
    c_s = collective_counts(jx_s)

    seq = butterfly_sequence(jx_o)
    runs = _contiguous_runs(seq)
    run_shape_ok = (len(runs) == 2 * depth
                    and all(n == n_buckets for _, n in runs))
    rs_runs = [sig for (prim, sig), _ in runs if prim == "reduce_scatter"]
    ag_runs = [sig for (prim, sig), _ in runs if prim == "all_gather"]
    phase_ok = (all(p == "reduce_scatter" for (p, _), _ in runs[:depth])
                and all(p == "all_gather" for (p, _), _ in runs[depth:]))
    mirror_ok = ag_runs == rs_runs[::-1]
    barriers = _barrier_hits(jx_o)

    checks = [
        CheckResult("same_total_collectives", c_o == c_s,
                    expected=dict(c_s), actual=dict(c_o),
                    detail="overlap must be a pure reordering of the "
                           "sequential schedule's collective multiset"),
        CheckResult("bucket_collective_count",
                    c_o.get("reduce_scatter", 0) == depth * n_buckets
                    and c_o.get("all_gather", 0) == depth * n_buckets,
                    expected={"reduce_scatter": depth * n_buckets,
                              "all_gather": depth * n_buckets},
                    actual={p: c_o.get(p, 0) for p in _BUTTERFLY_PRIMS},
                    detail=f"2*depth={2 * depth} collectives per bucket, "
                           f"{n_buckets} buckets"),
        CheckResult("stage_major_interleaving",
                    run_shape_ok and phase_ok and mirror_ok,
                    expected=f"{depth} runs of {n_buckets} reduce_scatter "
                             f"then {depth} runs of {n_buckets} all_gather "
                             f"(mirrored stage groups)",
                    actual=[(p, n) for (p, _), n in runs],
                    detail="every bucket's stage-l exchange must issue "
                           "before any bucket's stage-l+1"),
        CheckResult("no_barriers", barriers == 0,
                    expected=0, actual=barriers,
                    detail="scheduling fences would force the order the "
                           "overlap schedule is supposed to free"),
    ]
    checks += base_checks(jx_o, prefix="overlap_")
    return AuditReport(
        target=f"{name}[depth={depth}, buckets={n_buckets}]", checks=checks)


def audit_callable(name: str, fn, *example_args,
                   expected_all_to_all: Optional[int] = None) -> AuditReport:
    """Audit an arbitrary jit-able entry point (e.g. a ``make_train_step``
    step function): :func:`base_checks` plus an informational collective
    census, and — when ``expected_all_to_all`` is given — an exact
    ``all_to_all`` count check."""
    jaxpr = trace_jaxpr(fn, *example_args)
    counts = collective_counts(jaxpr)
    checks = []
    if expected_all_to_all is not None:
        a2a = counts.get("all_to_all", 0)
        checks.append(CheckResult(
            "all_to_all_count", a2a == expected_all_to_all,
            expected=expected_all_to_all, actual=a2a,
            detail=f"all collectives: {dict(counts)}"))
    else:
        checks.append(CheckResult(
            "collective_census", True, expected=None, actual=dict(counts),
            detail="informational"))
    checks += base_checks(jaxpr)
    return AuditReport(target=name, checks=checks)


def audit_serve_decode(name: str, fn, *example_args,
                       vocab: int) -> AuditReport:
    """The serving tier's no-vocab-transfer contract (ISSUE 10 bugfix).

    The decode loop's only host transfers are the jitted step's outputs,
    so the contract "transfer token ids, never logits" is exactly a
    property of the traced output signature: trace ``fn`` (a fused
    decode+greedy step from ``make_decode_greedy_step`` /
    ``make_prefill_greedy_step``) and assert

    * **no vocab-sized float output** — no floating output aval of rank
      <= 2 whose trailing axis is >= ``vocab``.  Gathered logits are
      ``[B, V_pad]`` (rank 2, trailing >= vocab); cache/state leaves are
      rank >= 3 with a leading periods axis, so they can legitimately
      contain vocab-sized inner dims (e.g. a mamba conv tail of width
      2*d) without tripping this.
    * **token ids are integers** — at least one integer output exists
      (the ids the host loop is supposed to consume).
    * :func:`base_checks` — no host callbacks / transfers hidden inside
      the program, no f64, stable scan carries.
    """
    import jax
    closed = jax.make_jaxpr(fn)(*example_args)
    jaxpr = closed.jaxpr
    bad = []
    has_int_out = False
    for v in jaxpr.outvars:
        aval = getattr(v, "aval", None)
        shape = tuple(getattr(aval, "shape", ()))
        dt = str(getattr(aval, "dtype", ""))
        if dt.startswith("int") or dt.startswith("uint"):
            has_int_out = True
        if dt.startswith("float") and 1 <= len(shape) <= 2 \
                and shape[-1] >= vocab:
            bad.append(f"float[{','.join(map(str, shape))}]")
    checks = [
        CheckResult("no_vocab_sized_float_output", not bad,
                    expected=[], actual=bad,
                    detail="the decode loop must transfer token ids, "
                           "never (padded-)vocab logits"),
        CheckResult("token_ids_output_is_integer", has_int_out,
                    expected=True, actual=has_int_out,
                    detail="greedy sampling happens on device"),
    ]
    checks += base_checks(jaxpr)
    return AuditReport(target=name, checks=checks)
