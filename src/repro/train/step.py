"""Train / serve step builders: shard_map bodies + pjit wrappers.

This is where the paper's primitive becomes a first-class training feature.
Gradient sync over the data axes supports three modes:

  * ``ring``   — stock ``lax.psum`` (XLA ring): every framework's baseline.
  * ``hier``   — the paper's *heterogeneous-degree nested butterfly*, dense:
    reduce-scatter down the degree sequence, all-gather back up
    (core.allreduce.dense_allreduce_hierarchical), degrees tunable.
  * ``sparse`` — the paper's Sparse Allreduce for the input-embedding
    gradient (rows touched by the batch; the paper's mini-batch use case,
    §I-A.1) + hier for everything else.  NOTE: with tied embeddings the
    softmax-head contribution makes the emb grad dense in vocab, so sparse
    mode is exercised on untied variants (DESIGN.md §sync); tied configs
    fall back to hier for that leaf.

FSDP leaves need no explicit sync: the per-period all_gather's transpose IS
the reduce-scatter (sum over data) — they are only rescaled by 1/dp.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.core.allreduce import (DevicePlan, dense_allreduce_hierarchical,
                                  dense_allreduce_hierarchical_bucketed,
                                  make_device_plan, sparse_allreduce_union)
from repro.core.sparse_vec import SENTINEL, HashPerm, SparseChunk
from repro.models import transformer as T
from repro.models.common import ModelConfig
from repro.models.sharding import (full_model_pspec, full_model_spec_tuples,
                                   to_pspec)
from repro.optim.adamw import AdamW, AdamWState

SYNC_PERM = HashPerm.make(1234)


# ---------------------------------------------------------------------------
# Mesh bookkeeping
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MeshCtx:
    mesh: Mesh
    tp_axis: str
    dp_axes: Tuple[str, ...]

    @property
    def tp(self) -> int:
        return self.mesh.shape[self.tp_axis]

    @property
    def dp(self) -> int:
        return math.prod(self.mesh.shape[a] for a in self.dp_axes)

    def axis_ctx(self, cfg: ModelConfig) -> T.AxisCtx:
        return T.AxisCtx(tp_axis=self.tp_axis, tp=self.tp,
                         dp_axes=self.dp_axes,
                         fsdp_axes=self.dp_axes if cfg.fsdp else None)


def mesh_ctx(mesh: Mesh) -> MeshCtx:
    names = mesh.axis_names
    dp = tuple(n for n in names if n != "model")
    return MeshCtx(mesh=mesh, tp_axis="model", dp_axes=dp)


def tuned_dp_degrees(mc: MeshCtx, in_capacity: int, out_capacity: int,
                     retune: bool = False) -> Dict[str, Tuple[int, ...]]:
    """Per-axis degree sequences from the *calibrated, cached* autotuner
    (``repro.core.autotune``; TUNING.md).  An EC2-tuned 16x4 is NOT
    optimal on a ~1 us-alpha fabric — see EXPERIMENTS H1 iterations 4-5.
    This is what ``dp_degrees="auto"`` resolves to, for both the
    hierarchical-dense and sparse sync plans.

    Per axis: the fabric is the persisted calibration for this backend
    (``autotune.calibrate_fabric(store=True)``) when one exists, else the
    nominal TPU fabric (``pod`` axis -> DCN, others -> ICI); the degree
    sweep result is read from / written to the persistent plan cache, so
    repeat launches skip the sweep entirely.  ``retune=True`` (CLI
    ``--retune``) bypasses cached reads and overwrites."""
    import jax

    from repro.core import autotune
    from repro.core.netmodel import TPU_DCN, TPU_ICI
    backend = jax.default_backend()
    ndev = len(jax.devices())
    degrees = {}
    for a in mc.dp_axes:
        s = mc.mesh.shape[a]
        nominal = TPU_DCN if a == "pod" else TPU_ICI
        fabric = autotune.calibrated_fabric(
            backend=backend, num_devices=ndev, default=nominal)
        degs, _src = autotune.resolve_degrees(
            s, n0=max(in_capacity, 1), total_range=max(out_capacity, 2) * 4,
            fabric=fabric, serial_nic=False, mesh_sig=((a, s),),
            retune=retune)
        degrees[a] = degs
    return degrees


def default_dp_plan(mc: MeshCtx, in_capacity: int, out_capacity: int,
                    degrees=None, retune: bool = False) -> DevicePlan:
    """Butterfly plan over the data axes (pod stage first — slowest link
    gets the outermost layer, per the paper's degree-ordering argument).

    degrees="auto" runs :func:`tuned_dp_degrees` (calibrated + cached);
    ``None`` keeps one round-robin stage per axis."""
    axes = [(a, mc.mesh.shape[a]) for a in mc.dp_axes]
    if degrees == "auto":
        degrees = tuned_dp_degrees(mc, in_capacity, out_capacity,
                                   retune=retune)
    elif degrees is None:
        degrees = {a: (s,) for a, s in axes}   # round-robin per axis
    return make_device_plan(axes, degrees, in_capacity=in_capacity,
                            out_capacity=out_capacity)


# ---------------------------------------------------------------------------
# Gradient sync (inside shard_map)
# ---------------------------------------------------------------------------

def _hier_allreduce_leaf(g: jax.Array, plan: DevicePlan) -> jax.Array:
    m = plan.num_nodes
    n = g.size
    pad = (-n) % m
    flat = jnp.pad(g.astype(jnp.float32).reshape(-1), (0, pad))
    out = dense_allreduce_hierarchical(flat, plan)
    return out[:n].reshape(g.shape).astype(g.dtype)


# Default bucket byte budget for the overlapped sync schedule: 4 MB sits
# just above the paper's 2-4 MB effective packet floor, so every bucket's
# messages stay bandwidth-bound while still yielding several independent
# buckets on the reduced configs the tests sweep.
DEFAULT_BUCKET_BYTES = 4 << 20

SYNC_OVERLAP_MODES = ("off", "bucketed")


def plan_grad_buckets(sizes: Sequence[int], bucket_bytes: int,
                      bytes_per_elem: int = 4) -> list:
    """Greedy contiguous partition of leaf indices into byte-bounded buckets.

    ``sizes``: element count per gradient leaf, in sync order.  Returns a
    list of index lists such that (a) their concatenation is exactly
    ``range(len(sizes))`` — an order-preserving exact cover, every leaf in
    exactly one bucket; (b) each bucket's total bytes is at most
    ``bucket_bytes`` unless the bucket is a single oversized leaf (a leaf
    larger than the budget gets a bucket of its own rather than being
    split — splitting would change the per-leaf pad-to-num_nodes layout
    and break bitwise parity with the unbucketed path).  Both properties
    hold for every permutation of ``sizes`` (hypothesis-checked in
    tests/test_overlap.py).

    Greedy-contiguous rather than bin-packed on purpose: leaves arrive in
    reverse-backward order, so contiguity is what lets early buckets'
    collectives issue while later grads are still being produced.
    """
    if bucket_bytes <= 0:
        raise ValueError(f"bucket_bytes must be positive, got {bucket_bytes}")
    if bytes_per_elem <= 0:
        raise ValueError(
            f"bytes_per_elem must be positive, got {bytes_per_elem}")
    buckets: list = []
    cur: list = []
    cur_bytes = 0
    for i, n in enumerate(sizes):
        if n < 0:
            raise ValueError(f"leaf size must be >= 0, got sizes[{i}]={n}")
        nb = int(n) * bytes_per_elem
        if cur and cur_bytes + nb > bucket_bytes:
            buckets.append(cur)
            cur, cur_bytes = [], 0
        cur.append(i)
        cur_bytes += nb
    if cur:
        buckets.append(cur)
    return buckets


def _bucketed_hier_leaves(gs: Sequence[jax.Array], plan: DevicePlan,
                          bucket_bytes: int) -> list:
    """Hier-allreduce a list of gradient leaves through the bucketed
    stage-major schedule; returns per-leaf reduced arrays in order.

    Each leaf gets exactly the :func:`_hier_allreduce_leaf` treatment —
    f32 flatten, pad to a ``num_nodes`` multiple, hierarchical allreduce,
    slice, reshape, cast back — except that padded flats are concatenated
    into :func:`plan_grad_buckets` buckets and all buckets traverse the
    butterfly together, stage-major
    (:func:`repro.core.allreduce.dense_allreduce_hierarchical_bucketed`).
    The collectives are elementwise, so the concat + reorder is a pure
    schedule change: every leaf's result is bitwise identical to the
    unbucketed path's (tests/test_overlap.py parity sweep).
    """
    m = plan.num_nodes
    flats = []
    for g in gs:
        pad = (-g.size) % m
        flats.append(jnp.pad(g.astype(jnp.float32).reshape(-1), (0, pad)))
    sizes = [f.size for f in flats]
    buckets = plan_grad_buckets(sizes, bucket_bytes)
    cats = [flats[b[0]] if len(b) == 1
            else jnp.concatenate([flats[i] for i in b])
            for b in buckets]
    reduced = dense_allreduce_hierarchical_bucketed(cats, plan)
    out = [None] * len(gs)
    for b, r in zip(buckets, reduced):
        off = 0
        for i in b:
            out[i] = (r[off:off + gs[i].size]
                      .reshape(gs[i].shape).astype(gs[i].dtype))
            off += sizes[i]
    return out


def sparse_sync_rows(grad: jax.Array, ids: jax.Array, mc: MeshCtx,
                     dplan: DevicePlan, edges: Sequence[jax.Array],
                     merge: str = "sort", wire: str = "raw",
                     ef: Optional[jax.Array] = None
                     ) -> Tuple[jax.Array, jax.Array, Optional[jax.Array]]:
    """Sparse Allreduce of a row-sparse gradient table over the data axes.

    grad: [V_local, d] this device's vocab-shard gradient (model-sharded).
    ids:  [N] global token ids appearing in the local batch.
    Returns (synced grad, overflow count, new error-feedback carry).
    config+reduce fused — dynamic indices, the paper's mini-batch mode.

    ``wire`` selects the on-wire payload encoding of the union butterfly
    (``repro.kernels.wirecodec``; ``"delta"`` is bit-identical to raw).
    ``ef`` [V_local, d] f32 is this device's error-feedback carry for
    ``wire="delta+int8ef"``: it is added to the rows *sent* this step, and
    the residual of quantizing the sent payload is stored back, so the
    quantization error of each step's contribution is re-injected (not
    lost) on the next step.  The residual uses one per-row int8
    quantization of the sender payload — a bounded proxy for the per-stage
    re-quantization the payload actually undergoes inside the butterfly
    (each stage's merge re-quantizes, so the true end-to-end error is a
    sum of per-stage residuals; carrying the first hop's residual already
    removes the sender-side bias, which dominates).  The returned carry is
    ``None`` when ``ef`` is None.
    """
    v_l, d = grad.shape
    v_start = lax.axis_index(mc.tp_axis) * v_l
    loc = ids.reshape(-1).astype(jnp.int32) - v_start
    mine = (loc >= 0) & (loc < v_l)
    hashed = jnp.where(mine, SYNC_PERM.fwd(ids.reshape(-1).astype(jnp.uint32)),
                       jnp.uint32(SENTINEL))
    hsorted = jnp.sort(hashed)
    n = hsorted.shape[0]
    cap_in = dplan.in_capacity
    valid = hsorted != jnp.uint32(SENTINEL)
    is_head = jnp.concatenate([jnp.ones((1,), bool),
                               hsorted[1:] != hsorted[:-1]]) & valid
    pos = jnp.cumsum(is_head.astype(jnp.int32)) - 1
    uniq = jnp.full((cap_in,), SENTINEL, jnp.uint32)
    uniq = uniq.at[jnp.where(is_head & (pos < cap_in), pos, cap_in)].set(
        hsorted, mode="drop")
    rows = (SYNC_PERM.inv(uniq).astype(jnp.int32) - v_start)
    okr = uniq != jnp.uint32(SENTINEL)
    safe_rows = jnp.clip(rows, 0, v_l - 1)
    vals = grad[safe_rows].astype(jnp.float32) * okr[:, None]
    new_ef = None
    if ef is not None:
        vals = vals + ef[safe_rows].astype(jnp.float32) * okr[:, None]
        from repro.kernels.wirecodec import dequant8_rows, quant8_rows
        q, s = quant8_rows(vals)
        resid = (vals - dequant8_rows(q, s)) * okr[:, None]
        ef_dest = jnp.where(okr, safe_rows, v_l)
        new_ef = (jnp.zeros((v_l + 1, d), jnp.float32)
                  .at[:v_l].set(ef.astype(jnp.float32))
                  .at[ef_dest].set(resid, mode="drop")[:v_l])
    chunk, ovf = sparse_allreduce_union(
        SparseChunk(idx=uniq, val=vals), dplan, edges, merge=merge,
        wire=wire)
    out_rows = (SYNC_PERM.inv(chunk.idx).astype(jnp.int32) - v_start)
    ok = chunk.idx != jnp.uint32(SENTINEL)
    dest = jnp.where(ok, out_rows, v_l)
    synced = jnp.zeros((v_l + 1, d), jnp.float32).at[dest].set(
        chunk.val * ok[:, None], mode="drop")[:-1]
    return synced.astype(grad.dtype), ovf, new_ef


def sync_grads(grads, cfg: ModelConfig, mc: MeshCtx, mode: str,
               hier_plan: Optional[DevicePlan],
               sparse_plan: Optional[DevicePlan],
               sparse_edges, token_ids,
               merge: str = "sort",
               wire: str = "raw",
               ef: Optional[jax.Array] = None,
               repl_weight: Optional[jax.Array] = None,
               dp_logical: Optional[int] = None,
               overlap: str = "off",
               bucket_bytes: int = DEFAULT_BUCKET_BYTES
               ) -> Tuple[Any, jax.Array, Optional[jax.Array]]:
    """Combine per-device grads into the grad of the global mean loss.

    ``repl_weight`` (r-way replicated data parallelism, paper §V): this
    device's scalar ``contribution_weights`` entry.  Replica groups hold
    identical batch shards, so scaling every gradient leaf by the weight
    before the data-axis sum counts each logical shard exactly once — from
    its first alive replica — and the mean divides by ``dp_logical``
    (= dp / r) instead of dp.

    ``wire`` / ``ef`` thread the sparse leaf's on-wire encoding and
    error-feedback carry (:func:`sparse_sync_rows`); the updated carry is
    returned as the third element (``ef`` unchanged when the sparse leaf
    was not synced this step, ``None`` when error feedback is off).

    ``overlap="bucketed"`` reschedules the hierarchical-butterfly leaves:
    instead of one monolithic 2·depth collective chain per leaf, leaves
    are concatenated into ``bucket_bytes``-bounded buckets
    (:func:`plan_grad_buckets`) and all buckets traverse the butterfly
    **stage-major** — every bucket's stage-l exchange issues before any
    stage-l+1 — so early buckets' collectives overlap the remaining
    backward compute and later buckets' sends (ARCHITECTURE.md "Overlap &
    scheduling").  A pure schedule permutation of elementwise collectives:
    results are bitwise identical to ``"off"``, collective totals are
    unchanged, and the sparse / fsdp / psum leaves (including the merge /
    wire / replication machinery) are untouched.
    """
    if overlap not in SYNC_OVERLAP_MODES:
        raise ValueError(
            f"overlap must be one of {SYNC_OVERLAP_MODES}, got {overlap!r}")
    spec = full_model_spec_tuples(cfg, mc.tp)
    dp = float(dp_logical if dp_logical is not None else mc.dp)
    overflow = jnp.zeros((), jnp.int32)
    new_ef = ef
    deferred = []          # (path, weighted grad) awaiting the bucketed pass

    def leaf_sync(path, g, s):
        nonlocal overflow, new_ef
        if cfg.fsdp and any(d == "fsdp" for d in s):
            return g / dp          # transpose already summed over data
        if repl_weight is not None:
            g = g * repl_weight.astype(g.dtype)
        if mode == "sparse" and path == ("emb",) and not cfg.tie_embeddings:
            synced, ovf, nef = sparse_sync_rows(
                g, token_ids, mc, sparse_plan, sparse_edges, merge=merge,
                wire=wire, ef=ef)
            overflow = overflow + ovf
            if nef is not None:
                new_ef = nef
            return synced / dp
        if mode in ("hier", "sparse") and hier_plan is not None and g.size >= mc.dp:
            if overlap == "bucketed":
                deferred.append((path, g))
                return None        # resolved by the bucketed pass below
            return _hier_allreduce_leaf(g, hier_plan) / dp
        out = g
        for a in mc.dp_axes:
            out = lax.psum(out, a)
        return out / dp

    flat = _flatten_with_path(grads)
    sflat = dict(_flatten_with_path(spec))
    synced = [(p, leaf_sync(p, g, sflat[p])) for p, g in flat]
    if deferred:
        reduced = _bucketed_hier_leaves([g for _, g in deferred], hier_plan,
                                        bucket_bytes)
        by_path = {p: r / dp for (p, _), r in zip(deferred, reduced)}
        synced = [(p, by_path[p] if v is None else v) for p, v in synced]
    return _unflatten_from_path(grads, synced), overflow, new_ef


def _flatten_with_path(tree, prefix=()):
    """Dict-structured flatten; non-dict values (arrays OR spec tuples) are
    leaves — param/grad/spec trees here are dicts all the way down."""
    out = []
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.extend(_flatten_with_path(tree[k], prefix + (k,)))
    else:
        out.append((prefix, tree))
    return out


def _unflatten_from_path(like, items):
    d = dict(items)

    def rb(t, prefix=()):
        if isinstance(t, dict):
            return {k: rb(v, prefix + (k,)) for k, v in t.items()}
        return d[prefix]
    return rb(like)


def _sharded_grad_norm(grads, cfg: ModelConfig, mc: MeshCtx) -> jax.Array:
    """Global grad norm with sharding-aware reduction (each distinct param
    element counted exactly once; grads are already data-synced)."""
    spec = full_model_spec_tuples(cfg, mc.tp)
    sflat = dict(_flatten_with_path(spec))
    total = jnp.zeros((), jnp.float32)
    for path, g in _flatten_with_path(grads):
        sq = jnp.sum(jnp.square(g.astype(jnp.float32)))
        dims = sflat[path]
        if any(d == "model" for d in dims):
            sq = lax.psum(sq, mc.tp_axis)
        if cfg.fsdp and any(d == "fsdp" for d in dims):
            for a in mc.dp_axes:
                sq = lax.psum(sq, a)
        total = total + sq
    return jnp.sqrt(total)


def init_cache_global(cfg: ModelConfig, mc: MeshCtx, b: int, max_seq: int,
                      seq_sharded: bool = False):
    """Global-shape cache pytree matching cache_pspec (host allocation)."""
    from repro.models import ssm as SSM
    tp = mc.tp
    kvg = cfg.kv_local(tp) * tp
    hd, npd = cfg.hd, cfg.n_periods
    per = {}
    for j, blk in enumerate(cfg.pattern):
        if blk == "attn":
            per[f"b{j}"] = {
                "k": jnp.zeros((npd, b, max_seq, kvg, hd), cfg.dtype),
                "v": jnp.zeros((npd, b, max_seq, kvg, hd), cfg.dtype)}
        elif blk == "mamba":
            dig = SSM.mamba_inner(cfg, tp) * tp
            per[f"b{j}"] = {
                "h": jnp.zeros((npd, b, dig, cfg.ssm_state), jnp.float32),
                "conv": jnp.zeros((npd, b, cfg.ssm_conv - 1, dig), cfg.dtype)}
        elif blk == "mlstm":
            h, dk, dvl = SSM.mlstm_dims(cfg, tp)
            per[f"b{j}"] = {
                "S": jnp.zeros((npd, b, h, dk, dvl * tp), jnp.float32),
                "N": jnp.zeros((npd, b, h, dk), jnp.float32),
                "m": jnp.zeros((npd, b, h), jnp.float32)}
        elif blk == "slstm":
            dh = cfg.d_model // cfg.n_heads
            per[f"b{j}"] = tuple(
                jnp.zeros((npd, b, cfg.n_heads, dh), jnp.float32)
                for _ in range(4))
    return per


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------

def _build_sync_plans(cfg: ModelConfig, mc: MeshCtx, mesh: Mesh, sync: str,
                      dp_degrees, sparse_tokens_hint: Optional[int],
                      retune: bool):
    """The gradient-sync plan set for one (cfg, mesh, sync) combination:
    ``(hier_plan, sparse_plan, sparse_edges)`` — shared by
    :func:`make_train_step` and the model-free :func:`make_sync_fn`
    harness so both paths sync through identical routing."""
    sparse_plan = sparse_edges = None
    hier_plan = None
    if sync in ("hier", "sparse"):
        hier_plan = default_dp_plan(mc, 8, 8, dp_degrees, retune=retune)
    if sync == "sparse":
        v_l = T.padded_vocab(cfg, mc.tp) // mc.tp
        # in capacity: unique local rows <= min(tokens/device, vocab shard).
        # Sizing to the actual batch sparsity is what makes the sparse path
        # win (SPerf H1: worst-case capacities moved MORE bytes than ring).
        cin = int(min(v_l, sparse_tokens_hint or (1 << 16)))
        cin = (cin + 7) // 8 * 8
        cout = (min(v_l, cin * mc.dp) + 7) // 8 * 8
        sp_degrees = dp_degrees
        if dp_degrees == "auto":
            sp_degrees = tuned_dp_degrees(mc, cin, cout, retune=retune)
        sparse_plan = make_device_plan(
            [(a, mesh.shape[a]) for a in mc.dp_axes],
            sp_degrees or {a: (mesh.shape[a],) for a in mc.dp_axes},
            in_capacity=cin, out_capacity=cout)
        sparse_edges = [jnp.asarray(e) for e in sparse_plan.edges_arrays()]
    return hier_plan, sparse_plan, sparse_edges


def _check_sync_settings(sync: str, sync_merge: str, sync_wire: str,
                         sync_overlap: str):
    """Shared make_train_step / make_sync_fn validation (fires before any
    mesh work; tests/test_overlap.py, tests/test_wire.py)."""
    from repro.core.allreduce import MERGE_MODES
    from repro.core.topology import check_wire
    if sync_merge not in MERGE_MODES:
        raise ValueError(
            f"sync_merge must be one of {MERGE_MODES}, got {sync_merge!r}")
    check_wire(sync_wire)
    if sync_wire != "raw" and sync != "sparse":
        raise ValueError(
            f"sync_wire={sync_wire!r} only applies to the sparse sync path "
            f"(got sync={sync!r}); ring/hier sync is dense and unencoded")
    if sync_overlap not in SYNC_OVERLAP_MODES:
        raise ValueError(f"sync_overlap must be one of {SYNC_OVERLAP_MODES}, "
                         f"got {sync_overlap!r}")
    if sync_overlap == "bucketed" and sync not in ("hier", "sparse"):
        raise ValueError(
            f"sync_overlap='bucketed' requires sync in ('hier', 'sparse') "
            f"(got sync={sync!r}): ring sync is a single psum per leaf with "
            f"no butterfly stages to interleave")


def make_sync_fn(cfg: ModelConfig, mesh: Mesh, *, sync: str = "hier",
                 dp_degrees=None,
                 sync_merge: str = "sort",
                 sync_wire: str = "raw",
                 replication: int = 1,
                 dead: Optional[set] = None,
                 sync_overlap: str = "off",
                 sync_bucket_bytes: int = DEFAULT_BUCKET_BYTES,
                 sparse_tokens_hint: Optional[int] = None,
                 retune: bool = False,
                 salt_shards: bool = True):
    """The gradient-sync stage of :func:`make_train_step` as a standalone
    jitted callable — no model forward/backward attached.

    Returns ``(fn, pspec)``: ``fn(grads, token_ids) -> (synced_grads,
    overflow)`` where ``grads`` is a global (fully addressable) param-tree
    of gradients laid out per ``full_model_pspec`` and ``token_ids`` is
    the ``[B, S]`` token batch the sparse leaf's row union is built from
    (dp-sharded like the train batch; ignored unless ``sync="sparse"``).

    This is the bit-exactness harness entry (tests/test_overlap.py): the
    parity sweep runs the *same* plan / merge / wire / replication /
    overlap machinery as the full train step — through the shared
    :func:`_build_sync_plans` and :func:`sync_grads` — while dispatching
    only the sync collectives, so a 36-combination 16-device sweep stays
    tractable.  Error feedback is not threaded (``wire="delta+int8ef"``
    syncs with a zero carry); use the full step for EF semantics.

    ``salt_shards`` (default on — this is a harness): non-fsdp gradient
    leaves arrive data-replicated under ``full_model_pspec``, which would
    let contribution-routing bugs cancel symmetrically; the body therefore
    scales each *logical* data shard's gradients by a distinct power-of-two
    factor before syncing.  Dyadic factors keep dyadic-lattice test values
    exactly representable, and salting by logical (not physical) shard
    keeps r-way replicas identical, so replicated results stay invariant
    to any survivable ``dead`` set.
    """
    _check_sync_settings(sync, sync_merge, sync_wire, sync_overlap)
    mc = mesh_ctx(mesh)
    repl_weights = None
    dp_logical = mc.dp
    if replication > 1 or dead:
        from repro.core.replication import contribution_weights
        if mc.dp % replication:
            raise ValueError(f"dp={mc.dp} not divisible by r={replication}")
        repl_weights = contribution_weights(mc.dp, replication, dead)
        dp_logical = mc.dp // replication
    hier_plan, sparse_plan, sparse_edges = _build_sync_plans(
        cfg, mc, mesh, sync, dp_degrees, sparse_tokens_hint, retune)
    pspec = full_model_pspec(cfg, mc.tp, mc.dp_axes)
    dspec = P(mc.dp_axes if len(mc.dp_axes) > 1 else mc.dp_axes[0])
    edge_specs = tuple(P(*mc.dp_axes, None) for _ in (sparse_edges or ()))

    def body(grads, tokens, *edges):
        flat = jnp.zeros((), jnp.int32)
        for a in mc.dp_axes:
            flat = flat * mesh.shape[a] + lax.axis_index(a)
        if salt_shards:
            salt = jnp.exp2(-((flat % dp_logical) % 4).astype(jnp.float32))
            grads = jax.tree.map(lambda g: g * salt.astype(g.dtype), grads)
        repl_w = None
        if repl_weights is not None:
            repl_w = jnp.asarray(repl_weights)[flat]
        synced, overflow, _ = sync_grads(
            grads, cfg, mc, sync, hier_plan, sparse_plan, edges, tokens,
            merge=sync_merge, wire=sync_wire, ef=None, repl_weight=repl_w,
            dp_logical=dp_logical, overlap=sync_overlap,
            bucket_bytes=sync_bucket_bytes)
        return synced, overflow

    sm = shard_map(body, mesh=mesh,
                   in_specs=(pspec, dspec) + edge_specs,
                   out_specs=(pspec, P()), check_vma=False)

    def fn(grads, token_ids):
        return sm(grads, token_ids, *(sparse_edges or ()))

    return fn, pspec


def train_fingerprint(cfg: ModelConfig, **settings) -> str:
    """Digest of everything that must match for a checkpoint to resume
    *exactly*: the model config plus caller-provided run settings (batch,
    seq, seed, sync mode, ...).  Stored in checkpoint meta by
    ``repro.launch.soak`` and compared on resume — a mismatch means the
    resumed trajectory could silently diverge from the original run, so
    the harness refuses it rather than producing not-quite-identical
    steps."""
    import hashlib
    import json
    payload = {"cfg": dataclasses.asdict(cfg),
               "settings": {k: settings[k] for k in sorted(settings)}}
    return hashlib.sha1(
        json.dumps(payload, sort_keys=True, default=str).encode()
    ).hexdigest()[:16]


def make_train_step(cfg: ModelConfig, mesh: Mesh, *, sync: str = "ring",
                    opt: Optional[AdamW] = None,
                    dp_degrees=None,
                    aux_weight: float = 0.01, donate: bool = True,
                    microbatch: int = 1,
                    sparse_tokens_hint: Optional[int] = None,
                    sync_merge: str = "sort",
                    sync_wire: str = "raw",
                    replication: int = 1,
                    dead: Optional[set] = None,
                    retune: bool = False,
                    sync_overlap: str = "off",
                    sync_bucket_bytes: int = DEFAULT_BUCKET_BYTES):
    """Returns (step_fn, specs) — step_fn is jit-compiled with shardings.

    step_fn(params, opt_state, batch) -> (params, opt_state, metrics)
    batch dict: tokens, labels [+ img_embeds / enc_frames].

    ``dp_degrees``: per-data-axis butterfly degree dict for the hier /
    sparse sync plans, the string ``"auto"`` to resolve per axis through
    the calibrated, plan-cached autotuner (:func:`tuned_dp_degrees`;
    ``retune=True`` forces a fresh sweep past the cache), or ``None`` for
    one round-robin stage per axis.

    ``sync_merge`` ("sort" | "fused" | "banded") selects the
    per-butterfly-layer merge of the sparse embedding-grad allreduce
    (core.allreduce docstring; "banded" is the band-limited Pallas
    pipeline with near-linear per-layer tile work).

    ``sync_wire`` ("raw" | "delta" | "delta+bf16" | "delta+int8ef")
    selects the on-wire payload encoding of that same sparse allreduce
    (``repro.kernels.wirecodec``; sparse sync only — other modes raise).
    ``"delta"`` bit-packs indices and is bit-identical to raw;
    ``"delta+int8ef"`` additionally quantizes values to per-row int8 with
    an *error-feedback carry*: the returned step fn transparently wraps
    the optimizer state as ``{"adamw": opt_state, "ef": carry}`` on first
    call (pass a bare AdamWState the first step; thereafter pass the dict
    the step returned) and the per-device quantization residual is
    re-injected into the next step's sent gradient
    (:func:`sparse_sync_rows`).

    microbatch > 1 splits the per-device batch into that many accumulation
    steps (lax.scan) — bounds activation / MoE-dispatch memory; gradients
    are synced once per step, after accumulation (so the paper's allreduce
    sees the full-batch sparsity union, as in its mini-batch use case).

    ``replication=r`` (paper §V fault tolerance) treats the flattened data
    axes (size dp) as dp/r logical batch shards hosted r-way redundantly
    per ``repro.core.replication.replica_groups`` — the launcher feeds each
    replica group the same batch shard (train.py tiles the logical batch r
    times) and gradient sync takes every logical contribution from its
    first alive replica via ``contribution_weights``, so step results are
    unchanged by any ``dead`` set that leaves each group one alive member.
    Raises ``DeadLogicalNode`` otherwise (with r=1, on any failure).

    ``sync_overlap="bucketed"`` (hier / sparse sync only) reschedules the
    dense butterfly leaves into ``sync_bucket_bytes``-bounded buckets
    issued stage-major, so gradient sync interleaves with the surrounding
    compute instead of forming one monolithic collective chain — bitwise
    identical results, same collective totals (see :func:`sync_grads`;
    ARCHITECTURE.md "Overlap & scheduling"; CLI ``--sync-overlap``).
    """
    _check_sync_settings(sync, sync_merge, sync_wire, sync_overlap)
    mc = mesh_ctx(mesh)
    ax = mc.axis_ctx(cfg)
    opt = opt or AdamW()
    repl_weights = None
    dp_logical = mc.dp
    if replication > 1 or dead:
        from repro.core.replication import contribution_weights
        if cfg.fsdp and replication > 1:
            raise ValueError(
                "replication>1 is unsupported with fsdp: the per-period "
                "all_gather transpose sums FSDP leaf grads over data before "
                "contribution weights could mask replicas")
        if mc.dp % replication:
            raise ValueError(f"dp={mc.dp} not divisible by r={replication}")
        # raises DeadLogicalNode if a whole replica group is dead
        repl_weights = contribution_weights(mc.dp, replication, dead)
        dp_logical = mc.dp // replication
    pspec = full_model_pspec(cfg, mc.tp, mc.dp_axes)
    dspec = P(mc.dp_axes if len(mc.dp_axes) > 1 else mc.dp_axes[0])

    hier_plan, sparse_plan, sparse_edges = _build_sync_plans(
        cfg, mc, mesh, sync, dp_degrees, sparse_tokens_hint, retune)

    # int8ef error-feedback carry: per-device sender state over the vocab
    # shard, [dp, V_pad, d] globally so every (data, model) device owns one
    # [V_local, d] slab (leading dp dim = one carry per sender).
    ef_shape = None
    ef_spec = None
    if sync == "sparse" and sync_wire == "delta+int8ef":
        ef_shape = (mc.dp, T.padded_vocab(cfg, mc.tp), cfg.d_model)
        ef_spec = P(mc.dp_axes if len(mc.dp_axes) > 1 else mc.dp_axes[0],
                    "model", None)

    opt_pspec = AdamWState(step=P(), m=pspec, v=pspec)
    if ef_spec is not None:
        opt_pspec = {"adamw": opt_pspec, "ef": ef_spec}
    batch_specs = {"tokens": dspec, "labels": dspec}
    if cfg.img_tokens:
        batch_specs["img_embeds"] = dspec
    if cfg.enc_layers:
        batch_specs["enc_frames"] = dspec

    edge_specs = tuple(P(*mc.dp_axes, None) for _ in (sparse_edges or ()))

    def body(params, opt_state, batch, *edges):
        tokens, labels = batch["tokens"], batch["labels"]
        ef = None
        if ef_spec is not None:
            ef = opt_state["ef"][0]          # local slab [V_local, d]
            opt_state = opt_state["adamw"]

        def loss_fn(p, mb):
            loss, aux = T.forward_loss(
                p, mb["tokens"], mb["labels"], cfg, ax,
                extra_embeds=mb.get("img_embeds"),
                enc_frames=mb.get("enc_frames"))
            return loss + aux_weight * aux, (loss, aux)

        if microbatch == 1:
            grads, (loss, aux) = jax.grad(loss_fn, has_aux=True)(
                params, batch)
        else:
            mb_batch = {k: v.reshape((microbatch, v.shape[0] // microbatch)
                                     + v.shape[1:])
                        for k, v in batch.items()}

            def acc_step(carry, mb):
                g_acc, l_acc, a_acc = carry
                g, (l, a) = jax.grad(loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree.map(
                    lambda x, y: x + y.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + l, a_acc + a), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss, aux), _ = lax.scan(
                acc_step, (g0, jnp.zeros((), jnp.float32),
                           jnp.zeros((), jnp.float32)), mb_batch)
            grads = jax.tree.map(lambda g: g / microbatch, grads)
            loss, aux = loss / microbatch, aux / microbatch
        repl_w = None
        if repl_weights is not None:
            # flat data-parallel index, row-major over the dp axes (the
            # same order batch rows shard), selects this device's weight
            flat = jnp.zeros((), jnp.int32)
            for a in mc.dp_axes:
                flat = flat * mesh.shape[a] + lax.axis_index(a)
            repl_w = jnp.asarray(repl_weights)[flat]
        grads, overflow, new_ef = sync_grads(
            grads, cfg, mc, sync, hier_plan, sparse_plan, edges, tokens,
            merge=sync_merge, wire=sync_wire, ef=ef, repl_weight=repl_w,
            dp_logical=dp_logical, overlap=sync_overlap,
            bucket_bytes=sync_bucket_bytes)
        gnorm = _sharded_grad_norm(grads, cfg, mc)
        new_params, new_opt, _ = opt.update(grads, opt_state, params,
                                            gnorm=gnorm)
        if ef_spec is not None:
            new_opt = {"adamw": new_opt, "ef": new_ef[None]}
        metrics = {"loss": lax.pmean(loss, mc.dp_axes),
                   "aux": lax.pmean(aux, mc.dp_axes), "gnorm": gnorm,
                   "sync_overflow": overflow}
        return new_params, new_opt, metrics

    sm = shard_map(
        body, mesh=mesh,
        in_specs=(pspec, opt_pspec, batch_specs) + edge_specs,
        out_specs=(pspec, opt_pspec,
                   {"loss": P(), "aux": P(), "gnorm": P(),
                    "sync_overflow": P()}),
        check_vma=False)

    def step(params, opt_state, batch):
        args = (params, opt_state, batch) + tuple(sparse_edges or ())
        return sm(*args)

    mspec = {"loss": P(), "aux": P(), "gnorm": P(), "sync_overflow": P()}
    jit_kw = dict(
        in_shardings=(_ns(mesh, pspec), _ns(mesh, opt_pspec),
                      _ns(mesh, batch_specs)),
        out_shardings=(_ns(mesh, pspec), _ns(mesh, opt_pspec),
                       _ns(mesh, mspec)))
    if donate:
        jit_kw["donate_argnums"] = (0, 1)
    jitted = jax.jit(step, **jit_kw)
    specs = dict(params=pspec, opt=opt_pspec, batch=batch_specs)
    if ef_shape is None:
        return jitted, specs

    def step_with_ef(params, opt_state, batch):
        # Transparent first-call wrap: a bare optimizer state gets a zero
        # error-feedback carry attached; thereafter callers pass the
        # {"adamw": ..., "ef": ...} dict the step returned.
        if not (isinstance(opt_state, dict) and "ef" in opt_state):
            opt_state = {"adamw": opt_state,
                         "ef": jnp.zeros(ef_shape, jnp.float32)}
        return jitted(params, opt_state, batch)

    return step_with_ef, specs


def _ns(mesh: Mesh, spec_tree):
    from jax.sharding import NamedSharding
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Serve steps
# ---------------------------------------------------------------------------

def cache_pspec(cfg: ModelConfig, mc: MeshCtx, seq_sharded: bool):
    """PartitionSpec tree mirroring transformer.init_cache."""
    dp = mc.dp_axes if len(mc.dp_axes) > 1 else mc.dp_axes[0]
    bspec = None if seq_sharded else dp
    sspec = "data" if seq_sharded else None
    per = {}
    for j, blk in enumerate(cfg.pattern):
        if blk == "attn":
            kv = "model" if cfg.n_kv >= mc.tp else "model"
            per[f"b{j}"] = {"k": P(None, bspec, sspec, "model", None),
                            "v": P(None, bspec, sspec, "model", None)}
        elif blk == "mamba":
            per[f"b{j}"] = {"h": P(None, bspec, "model", None),
                            "conv": P(None, bspec, None, "model")}
        elif blk == "mlstm":
            per[f"b{j}"] = {"S": P(None, bspec, None, None, "model"),
                            "N": P(None, bspec, None, None),
                            "m": P(None, bspec, None)}
        elif blk == "slstm":
            per[f"b{j}"] = tuple(P(None, bspec, None, None) for _ in range(4))
    return per


def make_prefill_step(cfg: ModelConfig, mesh: Mesh, max_seq: int):
    """serve prefill: (params, batch) -> (local logits, cache)."""
    mc = mesh_ctx(mesh)
    ax = mc.axis_ctx(cfg)
    pspec = full_model_pspec(cfg, mc.tp, mc.dp_axes)
    dp = mc.dp_axes if len(mc.dp_axes) > 1 else mc.dp_axes[0]
    dspec = P(dp)
    batch_specs = {"tokens": dspec}
    if cfg.img_tokens:
        batch_specs["img_embeds"] = dspec
    if cfg.enc_layers:
        batch_specs["enc_frames"] = dspec

    def body(params, batch):
        return T.forward_prefill(params, batch["tokens"], cfg, ax, max_seq,
                                 enc_frames=batch.get("enc_frames"),
                                 extra_embeds=batch.get("img_embeds"))

    cspec = cache_pspec(cfg, mc, False)
    sm = shard_map(body, mesh=mesh, in_specs=(pspec, batch_specs),
                   out_specs=(P(dp, "model"), cspec),
                   check_vma=False)
    jit_kw = dict(in_shardings=(_ns(mesh, pspec), _ns(mesh, batch_specs)),
                  out_shardings=(_ns(mesh, P(dp, "model")), _ns(mesh, cspec)))
    return jax.jit(sm, **jit_kw), dict(params=pspec, batch=batch_specs)


def make_decode_step(cfg: ModelConfig, mesh: Mesh, *, seq_sharded: bool = False,
                     seq_shards: int = 1, serve2d: bool = False):
    """serve decode: (params, token, pos, cache[, cross_cache]) ->
    (local logits, new cache)."""
    mc = mesh_ctx(mesh)
    ax = mc.axis_ctx(cfg)
    pspec = full_model_pspec(cfg, mc.tp, mc.dp_axes)
    dp = mc.dp_axes if len(mc.dp_axes) > 1 else mc.dp_axes[0]
    bspec = P(None) if seq_sharded else P(dp)
    cspec = cache_pspec(cfg, mc, seq_sharded)
    lspec = P(None, "model") if seq_sharded else P(dp, "model")

    cross_spec = None
    if cfg.enc_layers:
        cross_spec = (P(None, dp, None, "model", None),
                      P(None, dp, None, "model", None))

    mesh_sizes = dict(mesh.shape)

    def body(params, token, pos, cache, *cross):
        cc = cross[0] if cross else None
        return T.forward_decode(
            params, token, pos, cache, cfg, ax,
            seq_axis="data" if seq_sharded else None,
            seq_shards=seq_shards, cross_cache=cc,
            serve2d=serve2d, mesh_sizes=mesh_sizes)

    in_specs = (pspec, bspec, bspec, cspec)
    if cfg.enc_layers:
        in_specs = in_specs + (cross_spec,)
    sm = shard_map(body, mesh=mesh, in_specs=in_specs,
                   out_specs=(lspec, cspec), check_vma=False)
    jit_kw = dict(in_shardings=tuple(_ns(mesh, s) for s in in_specs),
                  out_shardings=(_ns(mesh, lspec), _ns(mesh, cspec)))
    return jax.jit(sm, **jit_kw), dict(params=pspec, cache=cspec)


def _greedy_ids(logits: jax.Array, vocab: int) -> jax.Array:
    """On-device greedy sampling over gathered ``[B, V_pad]`` logits.

    The padding columns (``vocab <= j < V_pad``) are exactly zero under
    tied embeddings (zero-initialized pad rows), which can beat
    all-negative real logits — so they are masked to ``-inf`` before the
    argmax, not sliced on host.  Returns int32 ``[B]`` token ids: the
    only thing the serving loop ever transfers
    (``repro.analysis.auditor.audit_serve_decode`` pins this)."""
    v_pad = logits.shape[-1]
    masked = jnp.where(jnp.arange(v_pad) < vocab,
                       logits.astype(jnp.float32), -jnp.inf)
    return jnp.argmax(masked, axis=-1).astype(jnp.int32)


def make_prefill_greedy_step(cfg: ModelConfig, mesh: Mesh, max_seq: int):
    """Fused prefill + on-device greedy: (params, batch) -> (ids, cache).

    ``ids`` is int32 ``[B]`` — the greedy next token after the prompt.
    Same trace as :func:`make_prefill_step` with :func:`_greedy_ids`
    fused at the jit level, so the vocab-sized logits never leave the
    device (the serving tier's fix for the per-step host logits copy)."""
    mc = mesh_ctx(mesh)
    ax = mc.axis_ctx(cfg)
    pspec = full_model_pspec(cfg, mc.tp, mc.dp_axes)
    dp = mc.dp_axes if len(mc.dp_axes) > 1 else mc.dp_axes[0]
    dspec = P(dp)
    batch_specs = {"tokens": dspec}
    if cfg.img_tokens:
        batch_specs["img_embeds"] = dspec
    if cfg.enc_layers:
        batch_specs["enc_frames"] = dspec

    def body(params, batch):
        return T.forward_prefill(params, batch["tokens"], cfg, ax, max_seq,
                                 enc_frames=batch.get("enc_frames"),
                                 extra_embeds=batch.get("img_embeds"))

    cspec = cache_pspec(cfg, mc, False)
    sm = shard_map(body, mesh=mesh, in_specs=(pspec, batch_specs),
                   out_specs=(P(dp, "model"), cspec), check_vma=False)

    def fn(params, batch):
        logits, cache = sm(params, batch)
        return _greedy_ids(logits, cfg.vocab), cache

    jit_kw = dict(in_shardings=(_ns(mesh, pspec), _ns(mesh, batch_specs)),
                  out_shardings=(_ns(mesh, P(dp)), _ns(mesh, cspec)))
    return jax.jit(fn, **jit_kw), dict(params=pspec, batch=batch_specs)


def make_decode_greedy_step(cfg: ModelConfig, mesh: Mesh, *,
                            seq_sharded: bool = False, seq_shards: int = 1,
                            serve2d: bool = False):
    """Fused decode + on-device greedy: (params, token, pos, cache
    [, cross_cache]) -> (ids, new cache).

    The continuous-batching scheduler's step function
    (``repro.serve.scheduler``): one jitted program per slot-count
    bucket, int32 ``[B]`` ids out — no vocab-sized aval in the output
    signature (audited by ``audit_serve_decode``)."""
    mc = mesh_ctx(mesh)
    ax = mc.axis_ctx(cfg)
    pspec = full_model_pspec(cfg, mc.tp, mc.dp_axes)
    dp = mc.dp_axes if len(mc.dp_axes) > 1 else mc.dp_axes[0]
    bspec = P(None) if seq_sharded else P(dp)
    cspec = cache_pspec(cfg, mc, seq_sharded)
    lspec = P(None, "model") if seq_sharded else P(dp, "model")

    cross_spec = None
    if cfg.enc_layers:
        cross_spec = (P(None, dp, None, "model", None),
                      P(None, dp, None, "model", None))

    mesh_sizes = dict(mesh.shape)

    def body(params, token, pos, cache, *cross):
        cc = cross[0] if cross else None
        return T.forward_decode(
            params, token, pos, cache, cfg, ax,
            seq_axis="data" if seq_sharded else None,
            seq_shards=seq_shards, cross_cache=cc,
            serve2d=serve2d, mesh_sizes=mesh_sizes)

    in_specs = (pspec, bspec, bspec, cspec)
    if cfg.enc_layers:
        in_specs = in_specs + (cross_spec,)
    sm = shard_map(body, mesh=mesh, in_specs=in_specs,
                   out_specs=(lspec, cspec), check_vma=False)

    def fn(params, token, pos, cache, *cross):
        logits, new_cache = sm(params, token, pos, cache, *cross)
        return _greedy_ids(logits, cfg.vocab), new_cache

    jit_kw = dict(in_shardings=tuple(_ns(mesh, s) for s in in_specs),
                  out_shardings=(_ns(mesh, bspec), _ns(mesh, cspec)))
    return jax.jit(fn, **jit_kw), dict(params=pspec, cache=cspec)
