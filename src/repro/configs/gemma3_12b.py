"""gemma3-12b [dense]: 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144 — 5:1 local:global sliding window, 128k context
[hf:google/gemma-3-1b-pt family].

Period of 6 layers: five local (window 1024) + one global (full attention).
The 262k vocabulary is the flagship sparse-embedding-gradient-sync case for
the paper's primitive.  long_500k decode runs: local layers use the window,
the global layer uses sequence-sharded split-KV decode.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b", family="dense",
    n_layers=48, d_model=3840, n_heads=16, n_kv=8, d_ff=15360,
    vocab=262144, head_dim=256,
    pattern=("attn",) * 6, ffn_pattern=("dense",) * 6,
    window=1024, window_pattern=(1024, 1024, 1024, 1024, 1024, 0),
    rope_theta=1e6, act="gelu", tie_embeddings=True,
)
