"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8), MoE 40e
top-8, expert d_ff=512, vocab=49155 [hf:ibm-granite family].

High top-k (8 of 40) => much denser expert traffic than arctic's 2 of 128 —
the contrasting point on the expert-exchange sparsity curve.  40 experts pad
to 48 for TP=16 (3 per device; router masks the pads).  24 heads pad to 32.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv=8, d_ff=512,
    vocab=49155, head_dim=64,
    pattern=("attn",), ffn_pattern=("moe",),
    n_experts=40, top_k=8, expert_d_ff=512,
    rope_theta=1e4, act="silu", tie_embeddings=True,
)
