"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, MoE 16e top-2 — Mamba+attn 1:7 interleave, MoE
[arXiv:2403.19887].

Period of 8 layers: one attention layer (position 3) among seven Mamba
layers; MoE replaces the dense FFN on every other layer (jamba's e/2).
FSDP over the data axes (398B params cannot replicate).
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv=8, d_ff=24576,
    vocab=65536, head_dim=128,
    pattern=("mamba", "mamba", "mamba", "attn",
             "mamba", "mamba", "mamba", "mamba"),
    ffn_pattern=("dense", "moe", "dense", "moe",
                 "dense", "moe", "dense", "moe"),
    n_experts=16, top_k=2, expert_d_ff=24576,
    ssm_state=16, ssm_conv=4,
    rope_theta=1e6, act="silu", tie_embeddings=True, fsdp=True,
)
