"""command-r-plus-104b [dense]: 64L d_model=12288 96H (GQA kv=8)
d_ff=33792 vocab=256000 — GQA, no-bias [hf:CohereForAI/c4ai-command-r].

Largest dense assigned arch: TP-dominant, the collective-bound roofline
case.  FSDP over the data axes (104B params cannot replicate).
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b", family="dense",
    n_layers=64, d_model=12288, n_heads=96, n_kv=8, d_ff=33792,
    vocab=256000, head_dim=128,
    pattern=("attn",), ffn_pattern=("dense",),
    rope_theta=75e5, act="silu", tie_embeddings=True, fsdp=True,
)
