"""arctic-480b [moe]: 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128e top-2 + dense residual [hf:Snowflake/snowflake-arctic-base].

Dense-MoE hybrid: every layer has a (small) dense residual FFN *in parallel*
with a 128-expert top-2 MoE.  128 experts top-2 is the most extreme
power-law token->expert exchange in the pool — the all_to_all dispatch is
structurally one butterfly layer of the paper's network.  56 heads pad to 64
for TP=16 (4 per device; padding FLOPs charged in the roofline).
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv=8, d_ff=4864,
    vocab=32000, head_dim=128,
    pattern=("attn",), ffn_pattern=("moe+dense",),
    n_experts=128, top_k=2, expert_d_ff=4864,
    rope_theta=1e4, act="silu", tie_embeddings=True, fsdp=True,
)
