"""xlstm-1.3b [ssm]: 48L d_model=2048 4H d_ff=0 vocab=50304 — sLSTM + mLSTM
blocks [arXiv:2405.04517].

Period of 8: seven mLSTM (chunkwise-parallel matrix memory) + one sLSTM
(sequential scalar memory with true recurrence).  d_ff=0 per the
assignment: blocks carry their own projections, no separate FFN.
mLSTM value dim shards over "model"; sLSTM runs replicated (DESIGN §ssm).
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv=4, d_ff=0,
    vocab=50304, head_dim=512,
    pattern=("mlstm",) * 7 + ("slstm",),
    ffn_pattern=("none",) * 8,
    act="gelu", tie_embeddings=True,
)
