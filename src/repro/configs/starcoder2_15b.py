"""starcoder2-15b [dense]: 40L d_model=6144 48H (GQA kv=4) d_ff=24576
vocab=49152 — GQA, RoPE [arXiv:2402.19173].

Deviation note: starcoder2 uses an ungated gelu MLP; our FFN substrate is
gated (w1*w3), so this config is geglu with the same d_ff (params +50% on
the up-projection; recorded in DESIGN.md deviations).
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b", family="dense",
    n_layers=40, d_model=6144, n_heads=48, n_kv=4, d_ff=24576,
    vocab=49152, head_dim=128,
    pattern=("attn",), ffn_pattern=("dense",),
    rope_theta=1e5, act="gelu", tie_embeddings=True,
)
