"""internvl2-26b [vlm]: 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92553 — InternViT + InternLM2 [arXiv:2404.16821].

Per the brief, the vision frontend (InternViT-6B + MLP projector) is a STUB:
``input_specs`` provides 1024 precomputed patch embeddings at d_model; this
config is the InternLM2-20B language backbone that consumes them.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv=8, d_ff=16384,
    vocab=92553, head_dim=128, img_tokens=1024,
    pattern=("attn",), ffn_pattern=("dense",),
    rope_theta=1e6, act="silu", tie_embeddings=True, fsdp=True,
)
