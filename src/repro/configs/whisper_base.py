"""whisper-base [audio]: 6L d_model=512 8H d_ff=2048 vocab=51865 — enc-dec,
conv frontend STUB [arXiv:2212.04356].

Per the brief the mel-spectrogram + conv feature extractor is stubbed:
``input_specs`` provides 1500 precomputed frame embeddings at d_model.  This
config is the transformer backbone: 6 encoder + 6 decoder layers with
cross-attention.  Deviation: positions extend past the model card's 448
decoder slots to honor the assigned 32k decode shape; long_500k is SKIPPED
(full-attention enc-dec, no sub-quadratic variant in the family).
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="audio",
    n_layers=6, d_model=512, n_heads=8, n_kv=8, d_ff=2048,
    vocab=51865, head_dim=64,
    pattern=("attn",), ffn_pattern=("dense",),
    enc_layers=6, enc_seq=1500,
    rope_theta=1e4, act="gelu", tie_embeddings=True,
)
