"""Config registry: the 10 assigned architectures + input shapes + skips."""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.models.common import ModelConfig

from .arctic_480b import CONFIG as _arctic
from .command_r_plus_104b import CONFIG as _commandr
from .gemma3_12b import CONFIG as _gemma3
from .granite_moe_3b import CONFIG as _granite
from .internvl2_26b import CONFIG as _internvl
from .jamba_1_5_large import CONFIG as _jamba
from .qwen1_5_0_5b import CONFIG as _qwen
from .starcoder2_15b import CONFIG as _starcoder
from .whisper_base import CONFIG as _whisper
from .xlstm_1_3b import CONFIG as _xlstm

ARCHS: Dict[str, ModelConfig] = {
    "starcoder2-15b": _starcoder,
    "jamba-1.5-large-398b": _jamba,
    "gemma3-12b": _gemma3,
    "qwen1.5-0.5b": _qwen,
    "internvl2-26b": _internvl,
    "arctic-480b": _arctic,
    "xlstm-1.3b": _xlstm,
    "granite-moe-3b-a800m": _granite,
    "command-r-plus-104b": _commandr,
    "whisper-base": _whisper,
}


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode" | "decode_long"


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode_long"),
    # extra shape for SPerf H1 only (the paper's mini-batch regime, SI-A.1):
    # small batches are where embedding gradients are actually sparse.
    "train_minibatch": InputShape("train_minibatch", 64, 16, "train"),
}

# long_500k policy (DESIGN.md §shape-skips):
#   native  — sub-quadratic family (SSM/hybrid) or built-in sliding window
#   swa     — dense arch runs via the explicit sliding-window variant
#   skip    — full-attention family with no sub-quadratic variant
LONG_CTX = {
    "starcoder2-15b": "swa",
    "jamba-1.5-large-398b": "native",
    "gemma3-12b": "native",
    "qwen1.5-0.5b": "swa",
    "internvl2-26b": "skip",     # LM context undefined past 32k; full attn
    "arctic-480b": "swa",
    "xlstm-1.3b": "native",
    "granite-moe-3b-a800m": "swa",
    "command-r-plus-104b": "swa",
    "whisper-base": "skip",      # enc-dec, 448-token decoder family
}

SWA_WINDOW = 4096


def get_config(name: str, variant: Optional[str] = None) -> ModelConfig:
    cfg = ARCHS[name]
    if variant == "swa":
        cfg = dataclasses.replace(
            cfg, window=SWA_WINDOW,
            window_pattern=tuple(SWA_WINDOW for _ in cfg.pattern))
    elif variant == "untied":
        # sparse embedding-grad sync acts on the input table (DESIGN Ssync)
        cfg = dataclasses.replace(cfg, tie_embeddings=False)
    elif variant not in (None, "base"):
        raise ValueError(f"unknown variant {variant!r}")
    return cfg


def pair_plan(arch: str, shape: str) -> Optional[str]:
    """Variant to use for this (arch, shape) pair, or None if skipped."""
    if shape != "long_500k":
        return "base"
    mode = LONG_CTX[arch]
    if mode == "skip":
        return None
    return "swa" if mode == "swa" else "base"


ASSIGNED_SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def all_pairs():
    out = []
    for a in ARCHS:
        for s in ASSIGNED_SHAPES:
            out.append((a, s, pair_plan(a, s)))
    return out
