"""qwen1.5-0.5b [dense]: 24L d_model=1024 16H (GQA kv=16) d_ff=2816
vocab=151936 — QKV bias [hf:Qwen/Qwen1.5-0.5B].

Smallest assigned model: gradient sync is latency-dominated, which is
exactly the paper's heterogeneous-degree tuning regime (packet floor).
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b", family="dense",
    n_layers=24, d_model=1024, n_heads=16, n_kv=16, d_ff=2816,
    vocab=151936, head_dim=64,
    pattern=("attn",), ffn_pattern=("dense",),
    qkv_bias=True, rope_theta=1e6, act="silu", tie_embeddings=True,
)
