"""Wire-format benchmarks (paper §IV bytes-on-wire, PR 8).

Four row families, all under ``--only wire``:

* ``wire/codec_*`` — device codec microbenches: bit-packed index
  round-trip and int8 row quantization wall time, with the static
  compression ratio each achieves.
* ``wire/calib_bytes_*`` — the corrected calibration byte accounting:
  ``measure_stage_samples`` prices each staged exchange as index + value
  stream (``STAGE_IDX_DTYPE`` + ``STAGE_VAL_DTYPE`` = 8 B/entry, not the
  old fp32-only 4 B/entry), and ``costmodel.wire_bytes_report`` prices
  the encoded payloads the floor applies to.
* ``wire/rerank_*`` — the tentpole claim: re-ranking degree
  factorizations under the encoded byte model shifts the optimum.
  Compression shrinks the bandwidth term but not latency/congestion, so
  under a congested fabric the tuner trades stage width for depth.
* ``wire/measured_*`` — host-mesh union_reduce wall with ``wire="delta"``
  vs ``"raw"``, asserting bit-identical outputs while reporting the
  encoded/raw byte ratio the model prices.

Wall times are host-dependent as usual; the derived columns carry the
reproducible quantities (byte formulas, degree picks, modeled seconds).
"""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from repro.core.autotune import (STAGE_IDX_DTYPE, STAGE_VAL_DTYPE,
                                 fit_fabric, measure_stage_samples,
                                 select_plan, synth_stage_samples)
from repro.core.netmodel import EC2_2013, Fabric
from repro.core.topology import ButterflyPlan
from repro.kernels.costmodel import wire_bytes_report
from repro.kernels.wirecodec import (encoded_payload_bytes, index_words,
                                     pack_indices, quant8_rows,
                                     unpack_indices)

Row = Tuple[str, float, str]

# Paper-scale workload constants (Twitter followers' graph, Table I)
TW_N0, TW_RANGE = 12.1e6, 60e6

# Ground truth for the deterministic rerank rows: the EC2 fabric plus a
# congestion term — congestion is what makes the wire format move the
# optimum (bandwidth shrinks, incast cost does not).
GT = Fabric("ec2-2013-congested", beta_bytes_per_s=EC2_2013.beta_bytes_per_s,
            alpha_s=EC2_2013.alpha_s, gamma_s=2e-4)


def _calibrated() -> Fabric:
    samples = synth_stage_samples(GT, [1e4, 1e5, 1e6, 4e6], [1, 3, 7, 15, 31])
    return fit_fabric(samples, name="calibrated-ec2-congested")


def bench_wire_codec() -> List[Row]:
    import jax
    import jax.numpy as jnp

    rows = []
    r, cap, width = 8, 4096, 13
    rng = np.random.RandomState(0)
    base = np.arange(r, dtype=np.uint32) * np.uint32(1 << width)
    offs = np.sort(rng.randint(0, (1 << width) - 1, size=(r, cap)), axis=1)
    idx = jnp.asarray(base[:, None] + offs.astype(np.uint32))
    b = jnp.asarray(base)

    pack = jax.jit(lambda i: pack_indices(i, b, width))
    unpack = jax.jit(lambda w: unpack_indices(w, b, cap, width))
    words = pack(idx).block_until_ready()
    back = unpack(words).block_until_ready()
    assert bool(jnp.all(back == idx))
    t0 = time.perf_counter()
    for _ in range(20):
        unpack(pack(idx)).block_until_ready()
    dt = (time.perf_counter() - t0) / 20 * 1e6
    packed_b = 4 * index_words(cap, width)
    rows.append((f"wire/codec_roundtrip_cap{cap}_w{width}", dt,
                 f"words={index_words(cap, width)} "
                 f"packed_bytes={packed_b} raw_bytes={4 * cap} "
                 f"ratio={4 * cap / packed_b:.2f} exact=1"))

    val = jnp.asarray(rng.randn(r, cap).astype(np.float32))
    quant = jax.jit(quant8_rows)
    q, s = quant(val)
    q.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(20):
        quant(val)[0].block_until_ready()
    dt = (time.perf_counter() - t0) / 20 * 1e6
    err = float(jnp.max(jnp.abs(q.astype(jnp.float32)
                                * s[:, None] - val))
                / jnp.max(jnp.abs(val)))
    rows.append((f"wire/codec_quant8_cap{cap}", dt,
                 f"value_bytes=1 raw=4 rel_err={err:.1e}"))
    return rows


def bench_wire_calibrated_bytes() -> List[Row]:
    rows = []
    # The satellite-1 regression, benchmarked: every staged sample is
    # priced at idx+val itemsize (8 B/entry).  Fit the host-mesh fabric
    # from samples carrying the corrected accounting.
    entry_b = STAGE_IDX_DTYPE.itemsize + STAGE_VAL_DTYPE.itemsize
    t0 = time.perf_counter()
    measured = measure_stage_samples(payload_entries=(256, 4096, 16384),
                                     repeats=3)
    fit = fit_fabric(measured, name="calib-host-wire")
    dt = (time.perf_counter() - t0) * 1e6
    c = 4096
    got = next(s.nbytes for s in measured
               if abs(s.nbytes - c * entry_b) < entry_b)
    rows.append(("wire/calib_bytes_measured_host", dt,
                 f"entry_bytes={entry_b} nbytes_at_c4096={got:.0f} "
                 f"formula=c*(idx4+val4) alpha_us={fit.alpha_s * 1e6:.1f} "
                 f"beta_GBps={fit.beta_bytes_per_s / 1e9:.2f}"))

    # Encoded-payload pricing the packet floor applies to, per wire mode.
    cap, bits = 4096, 13
    for wire in ("raw", "delta", "delta+bf16", "delta+int8ef"):
        t0 = time.perf_counter()
        rep = wire_bytes_report(cap, bits, wire=wire, fabric=GT)
        dt = (time.perf_counter() - t0) * 1e6
        assert rep["encoded_bytes"] == encoded_payload_bytes(wire, cap, bits)
        rows.append((f"wire/calib_bytes_{wire.replace('+', '_')}", dt,
                     f"cap={cap} bits={rep['index_bits']} "
                     f"encoded={rep['encoded_bytes']} raw={rep['raw_bytes']} "
                     f"compression={rep['compression']:.2f} "
                     f"msg_ms={rep['msg_time_s'] * 1e3:.3f}"))
    return rows


def bench_wire_rerank() -> List[Row]:
    fit = _calibrated()
    rows = []
    for m in (64, 256):
        t0 = time.perf_counter()
        rep_raw = select_plan(m, TW_N0, TW_RANGE, fit, wire="raw")
        rep_bf16 = select_plan(m, TW_N0, TW_RANGE, fit, wire="delta+bf16")
        dt = (time.perf_counter() - t0) * 1e6
        # what keeping the raw-tuned plan would cost on the bf16 wire —
        # the stage-time win of retuning per wire format
        cross = rep_raw.plan.modeled_time(TW_N0, TW_RANGE, fit,
                                          wire="delta+bf16")
        shifted = rep_raw.plan.degrees != rep_bf16.plan.degrees
        rows.append((f"wire/rerank_M{m}", dt,
                     f"raw={rep_raw.plan} t={rep_raw.modeled_s:.3f}s "
                     f"bf16={rep_bf16.plan} t={rep_bf16.modeled_s:.3f}s "
                     f"raw_plan_on_bf16={cross:.3f}s shifted={int(shifted)} "
                     f"retune_speedup={cross / rep_bf16.modeled_s:.3f}"))
    return rows


def bench_wire_measured_stage() -> List[Row]:
    import jax
    import jax.numpy as jnp

    from repro.core import SparseAllreduce

    rows = []
    m = len(jax.devices())
    if m < 8:
        return rows
    from repro.core.sparse_vec import HashPerm

    M, C = 8, 1024
    rng = np.random.RandomState(7)
    perm = HashPerm.make(9)
    idx = np.stack([
        np.sort(perm.fwd_np(
            rng.choice(1 << 20, C, replace=False).astype(np.uint32)))
        for _ in range(M)])
    val = (rng.randint(-128, 129, size=(M, C)) / 64.0).astype(np.float32)

    outs = {}
    for wire in ("raw", "delta"):
        ar = SparseAllreduce(M, (4, 2), backend="device", seed=3, wire=wire)
        t0 = time.perf_counter()
        oi, ov, ovf = ar.union_reduce(jnp.asarray(idx), jnp.asarray(val),
                                      out_capacity=M * C)
        jax.block_until_ready((oi, ov))
        cold = (time.perf_counter() - t0) * 1e6
        t0 = time.perf_counter()
        for _ in range(5):
            oi, ov, ovf = ar.union_reduce(jnp.asarray(idx),
                                          jnp.asarray(val),
                                          out_capacity=M * C)
            jax.block_until_ready((oi, ov))
        warm = (time.perf_counter() - t0) / 5 * 1e6
        assert int(np.asarray(ovf).sum()) == 0
        outs[wire] = (np.asarray(oi), np.asarray(ov))
        bits = ButterflyPlan(M, (4, 2)).index_bits_per_layer()[0]
        enc = encoded_payload_bytes(wire, C, bits)
        rows.append((f"wire/measured_union_M{M}_{wire}", warm,
                     f"cold_us={cold:.0f} stage0_bytes={enc} "
                     f"raw_bytes={encoded_payload_bytes('raw', C, bits)} "
                     f"host_mesh=1"))
    assert np.array_equal(outs["raw"][0], outs["delta"][0])
    assert np.array_equal(outs["raw"][1], outs["delta"][1])
    rows.append(("wire/measured_union_bit_identity", 0.0,
                 "delta_eq_raw=1 indices_and_values=1"))
    return rows


ALL_BENCHES = [
    bench_wire_codec,
    bench_wire_calibrated_bytes,
    bench_wire_rerank,
    bench_wire_measured_stage,
]
