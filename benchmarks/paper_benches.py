"""One benchmark per paper table/figure (Zhao & Canny 2013).

Each function returns a list of CSV rows (name, us_per_call, derived).
Network times are produced by the calibrated alpha-beta-floor model
(core.netmodel: EC2-2013 / TPU fabrics); merge/compute times are measured
on this host.  See EXPERIMENTS.md for the mapping to the paper's numbers.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from repro.core.netmodel import EC2_2013, TPU_ICI
from repro.core.simulator import SimSparseAllreduce
from repro.core.sparse_vec import HashPerm
from repro.core.topology import ButterflyPlan, binary_plan, roundrobin_plan, tune
from repro.data.pipeline import powerlaw_graph, random_edge_partition
from repro.graph.pagerank import (build_partitions, pagerank,
                                  pagerank_dense_reference)

Row = Tuple[str, float, str]

# Paper-scale workload constants (Twitter followers' graph, Table I)
TW_N0, TW_RANGE = 12.1e6, 60e6
YH_N0, YH_RANGE = 48e6, 1.6e9


def _timeit(fn, repeat=3):
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6  # us


# ---------------------------------------------------------------------------
# Fig 3: round-robin scaling — per-node time vs cluster size
# ---------------------------------------------------------------------------

def bench_fig3_roundrobin_scaling() -> List[Row]:
    rows = []
    total_bytes = TW_N0 * 64 * 8     # dataset bytes (whole cluster)
    for m in (8, 16, 32, 64, 128, 256):
        pkt = total_bytes / m / m    # C/M^2 per message
        plan = roundrobin_plan(m)
        t = plan.modeled_time(total_bytes / m / 8, TW_RANGE)
        rows.append((f"fig3/roundrobin_M{m}", t * 1e6,
                     f"packet_MB={pkt/1e6:.2f}"))
    return rows


# ---------------------------------------------------------------------------
# Table I: sparsity of partitioned datasets
# ---------------------------------------------------------------------------

def bench_table1_sparsity() -> List[Row]:
    rows = []
    n, e = 60_000, 1_500_000          # 1/1000-scale twitter
    edges = powerlaw_graph(n, e, alpha=2.0, seed=0)
    t0 = time.perf_counter()
    parts = random_edge_partition(edges, 64, seed=0)
    dt = (time.perf_counter() - t0) * 1e6
    fracs = [len(np.unique(p)) / n for p in parts]
    rows.append(("table1/twitter_scale_partition64", dt,
                 f"vertex_frac={np.mean(fracs):.3f} (paper: 0.21)"))
    n2, e2 = 160_000, 600_000        # 1/10000-scale yahoo (sparser)
    edges2 = powerlaw_graph(n2, e2, alpha=2.2, seed=1)
    t0 = time.perf_counter()
    parts2 = random_edge_partition(edges2, 64, seed=1)
    dt2 = (time.perf_counter() - t0) * 1e6
    fracs2 = [len(np.unique(p)) / n2 for p in parts2]
    rows.append(("table1/yahoo_scale_partition64", dt2,
                 f"vertex_frac={np.mean(fracs2):.3f} (paper: 0.03)"))
    return rows


# ---------------------------------------------------------------------------
# Fig 5: packet size per butterfly layer
# ---------------------------------------------------------------------------

def bench_fig5_packet_sizes() -> List[Row]:
    rows = []
    for degs in [(64,), (16, 4), (8, 8), (4, 4, 4), (2,) * 6]:
        plan = ButterflyPlan(64, degs)
        pkts = plan.packet_bytes(TW_N0, TW_RANGE, bytes_per_entry=8.0)
        rows.append((f"fig5/packets_{plan}", 0.0,
                     "layers_MB=" + "|".join(f"{p/1e6:.1f}" for p in pkts)))
    return rows


# ---------------------------------------------------------------------------
# Fig 6: topology sweep — reduce time + throughput, twitter & yahoo
# ---------------------------------------------------------------------------

def bench_fig6_topology_sweep() -> List[Row]:
    rows = []
    for tag, n0, rng_ in [("twitter", TW_N0, TW_RANGE),
                          ("yahoo", YH_N0, YH_RANGE)]:
        scored = []
        for degs in [(64,), (32, 2), (16, 4), (8, 8), (4, 4, 4), (16, 2, 2),
                     (2,) * 6]:
            plan = ButterflyPlan(64, degs)
            t = plan.modeled_time(n0, rng_, bytes_per_entry=4.0)
            scored.append((t, plan))
            tput = n0 * 64 / t / 1e9
            rows.append((f"fig6/{tag}_{plan}", t * 1e6,
                         f"throughput_Gvals={tput:.2f}"))
        best = min(scored)[1]
        rows.append((f"fig6/{tag}_best", min(scored)[0] * 1e6,
                     f"best={best} (paper: 16x4)"))
    return rows


# ---------------------------------------------------------------------------
# Fig 7: thread sweep -> TPU adaptation: NIC serialization vs overlap
# ---------------------------------------------------------------------------

def bench_fig7_overlap() -> List[Row]:
    """The paper's thread count tunes how well socket sends overlap; the
    TPU analogue is per-link concurrency (serial NIC vs parallel ICI)."""
    rows = []
    plan = ButterflyPlan(64, (16, 4))
    for tag, serial, fabric in [("1thread_serialNIC", True, EC2_2013),
                                ("8threads_overlapNIC", False, EC2_2013),
                                ("tpu_ici_parallel_links", False, TPU_ICI)]:
        t = plan.modeled_time(TW_N0, TW_RANGE, fabric=fabric,
                              serial_nic=serial)
        rows.append((f"fig7/{tag}", t * 1e6, f"plan={plan}"))
    return rows


# ---------------------------------------------------------------------------
# Table II: cost of fault tolerance (replication)
# ---------------------------------------------------------------------------

def bench_table2_fault_tolerance() -> List[Row]:
    rows = []
    rng = np.random.RandomState(0)
    m = 32
    scale = 2000  # per-node nnz (downscaled 64-node workload)
    out_i = [(rng.zipf(1.4, scale) % 200_000).astype(np.uint32)
             for _ in range(m)]
    out_v = [rng.randn(scale) for _ in range(m)]
    in_i = [rng.choice(200_000, scale // 2, replace=False).astype(np.uint32)
            for _ in range(m)]
    cases = [("16x4_r0", (16, 2), 1, set()),
             ("8x4_r0", (8, 4), 1, set()),
             ("8x4_r1_dead0", (8, 4), 2, set()),
             ("8x4_r1_dead1", (8, 4), 2, {5}),
             ("8x4_r1_dead2", (8, 4), 2, {5, 40}),
             ("8x4_r1_dead3", (8, 4), 2, {5, 40, 17})]
    for tag, degs, r, dead in cases:
        sim = SimSparseAllreduce(ButterflyPlan(m, degs), replication=r,
                                 dead=dead, perm=HashPerm.make(0))
        t0 = time.perf_counter()
        cstats = sim.config(out_i, in_i)
        wall_config = (time.perf_counter() - t0) * 1e6
        sim.reduce(out_v)
        rows.append((f"table2/{tag}", wall_config,
                     f"config_s={cstats.config_time_s:.3f},"
                     f"reduce_s={sim.reduce_stats.reduce_time_s:.3f}"))
    return rows


# ---------------------------------------------------------------------------
# Fig 8: scaling + compute/comm breakdown (PageRank, 10 iters)
# ---------------------------------------------------------------------------

def bench_fig8_scaling() -> List[Row]:
    rows = []
    n, e = 30_000, 600_000
    edges = powerlaw_graph(n, e, alpha=2.0, seed=0)
    for m in (4, 16, 64):
        degs = tune(m, n0=e / m, total_range=n).degrees
        t0 = time.perf_counter()
        scores, stats = pagerank(edges, n, m=m, degrees=degs, iters=10)
        wall = (time.perf_counter() - t0) * 1e6
        comm = stats["reduce_time_s"]
        rows.append((f"fig8/pagerank_M{m}", wall,
                     f"modeled_comm_s={comm:.3f},plan={'x'.join(map(str,degs))}"))
    return rows


# ---------------------------------------------------------------------------
# Fig 9: PageRank system comparison — sparse vs dense allreduce baselines
# ---------------------------------------------------------------------------

def bench_fig9_pagerank_comparison() -> List[Row]:
    """Paper compares against Hadoop/GraphX/PowerGraph.  Offline analogue:
    the same PageRank with (a) our Sparse Allreduce, (b) a dense allreduce
    (every node ships the full vertex vector — what a generic framework
    does), (c) round-robin sparse.  Modeled EC2 comm time, 10 iterations."""
    rows = []
    n, e, m = 60_000, 1_200_000, 64
    edges = powerlaw_graph(n, e, alpha=2.0, seed=0)
    parts = build_partitions(edges, n, m)
    avg_nnz = np.mean([len(p.out_idx) for p in parts])
    for tag, degs in [("sparse_hybrid", tune(m, avg_nnz, n).degrees),
                      ("sparse_roundrobin", (m,)),
                      ("sparse_binary", (2,) * 6)]:
        plan = ButterflyPlan(m, degs)
        t = plan.modeled_time(avg_nnz, n, bytes_per_entry=4.0) * 10
        rows.append((f"fig9/{tag}", t * 1e6, f"plan={plan}"))
    # dense baseline: full vector both ways through a ring
    dense_bytes = n * 4.0
    t_dense = (2 * dense_bytes * (m - 1) / m / EC2_2013.beta_bytes_per_s
               + 2 * (m - 1) * EC2_2013.alpha_s) * 10
    rows.append(("fig9/dense_allreduce_ring", t_dense * 1e6,
                 "full-vector baseline"))
    # correctness anchor: our sparse == dense reference
    ref = pagerank_dense_reference(edges, n, iters=3)
    got, _ = pagerank(edges, n, m=8, iters=3)
    err = float(np.max(np.abs(ref - got)))
    rows.append(("fig9/correctness_max_err", 0.0, f"{err:.2e}"))
    return rows


# ---------------------------------------------------------------------------
# Fig 8/9 on the device backend: the iterative graph engine
# ---------------------------------------------------------------------------

def bench_fig8_fig9_device_engine() -> List[Row]:
    """Device-backed fig8/fig9 rows: k PageRank rounds through the
    device-resident engine (``repro.graph.engine`` — ONE jitted dispatch
    and host round-trip per ``run(k)``) vs the per-iteration device path
    (host staging + one ``SparseAllreduce.reduce`` dispatch per round —
    the pre-engine way).  The derived column is the per-round sync-count
    report: dispatches / host round-trips per k-round run and the
    butterfly ``all_to_all`` phases each round pays on-network.

    Off-TPU both paths run on forced host devices (benchmarks/run.py sets
    XLA_FLAGS), SpMV on the jnp ELL path — wall times are amortization
    evidence (dispatch/staging overhead), not TPU perf; the graph is kept
    small because interpret-mode and ELL hub padding dominate at scale.
    Sizes beyond the available device count emit a ``skipped`` row."""
    import jax

    from repro.core import SparseAllreduce
    from repro.graph.pagerank import (assemble_pagerank_scores,
                                      make_pagerank_engine)

    rows = []
    n, e, iters, damping = 3000, 24000, 10, 0.85
    edges = powerlaw_graph(n, e, alpha=2.0, seed=0)
    ref = pagerank_dense_reference(edges, n, iters=iters)
    t_engine8 = t_periter8 = None
    for m in (4, 8):
        if len(jax.devices()) < m:
            rows.append((f"fig8/pagerank_device_M{m}", -1.0,
                         f"skipped: needs {m} devices"))
            continue
        mesh = jax.sharding.Mesh(np.array(jax.devices())[:m], ("nodes",))
        degs = tune(m, n0=e / m, total_range=n).degrees
        parts = build_partitions(edges, n, m)
        engine, extras, p0 = make_pagerank_engine(parts, n, degs,
                                                  damping=damping, mesh=mesh)
        engine.run(iters, p0, extras)                 # compile once
        t_eng = _timeit(lambda: engine.run(iters, p0, extras))
        rep = engine.sync_report()
        _, last_q, _ = engine.run(iters, p0, extras)
        scores = assemble_pagerank_scores(parts, last_q, n, damping)
        err = float(np.max(np.abs(scores - ref)))
        rows.append((
            f"fig8/pagerank_device_M{m}", t_eng,
            f"rounds={iters},dispatches_per_run=1,host_roundtrips_per_run=1,"
            f"collectives_per_round={rep['reduce_collectives_per_round']},"
            f"max_err={err:.1e},plan={'x'.join(map(str, degs))}"))

        # per-iteration device baseline: one reduce dispatch per round
        ar = SparseAllreduce(m, degs, backend="device", mesh=mesh)
        ar.config([p.out_idx.astype(np.uint32) for p in parts],
                  [p.in_idx.astype(np.uint32) for p in parts])

        def per_iter(parts=parts, ar=ar):
            p_in = [np.full(len(p.in_idx), 1.0 / n) for p in parts]
            for _ in range(iters):
                q = [p.spmv(p_in[i]) for i, p in enumerate(parts)]
                ins = ar.reduce(q)
                p_in = [(1 - damping) / n + damping * ins[i]
                        for i in range(m)]

        per_iter()                                    # compile once
        t_per = _timeit(per_iter)
        rows.append((
            f"fig8/pagerank_device_periter_M{m}", t_per,
            f"rounds={iters},dispatches_per_run={iters},"
            f"host_roundtrips_per_run={iters},"
            f"collectives_per_round={rep['reduce_collectives_per_round']}"))
        if m == 8:
            t_engine8, t_periter8 = t_eng, t_per
    if t_engine8 is not None:
        rows.append((
            "fig9/pagerank_engine_vs_periter_M8", t_engine8,
            f"periter_us={t_periter8:.1f},"
            f"amortization_win={t_periter8 / max(t_engine8, 1e-9):.2f}x,"
            "one_dispatch_per_10_rounds"))
    return rows


# ---------------------------------------------------------------------------
# beyond paper: kernel microbenches + grad-sync crossover
# ---------------------------------------------------------------------------

def bench_kernels() -> List[Row]:
    import jax.numpy as jnp
    from repro.core.sparse_vec import SparseChunk
    from repro.core import sparse_vec as sv
    from repro.kernels import ops
    rows = []
    rng = np.random.RandomState(0)
    idx = np.sort(rng.randint(0, 100_000, 4096).astype(np.uint32))
    val = rng.randn(4096, 8).astype(np.float32)
    ch = SparseChunk(idx=jnp.asarray(idx), val=jnp.asarray(val))
    f_ref = lambda: sv.segment_compact(ch, 4096).idx.block_until_ready()
    f_ker = lambda: ops.segment_compact(ch, 4096).idx.block_until_ready()
    f_ref(); f_ker()  # compile
    rows.append(("kernels/segment_compact_jnp", _timeit(f_ref), "oracle"))
    rows.append(("kernels/segment_compact_pallas_interp", _timeit(f_ker),
                 "interpret=True (correctness mode; perf is TPU-only)"))
    return rows


def bench_merge_modes() -> List[Row]:
    """Per-layer merge-stage timing + instrumented tile work, all three
    ``merge`` modes of the union allreduce: ``sort`` (concat + full argsort
    + segment-compact), ``fused`` (Pallas rank-merge + compact + one-hot
    scatter-add in one pass — kernels.ops.merge_sorted_runs), and
    ``banded`` (same pipeline band-limited by stream sortedness:
    frontier-only compare tiles, ceil(k*bm/bk)+1 scatter tiles per output
    tile).  Workload: k sorted power-law runs, exactly what arrives at a
    butterfly layer after all_to_all.  The derived column carries the
    kernels.costmodel tile/FLOP report — the hardware-independent measure
    of the win; on CPU the Pallas paths run in interpret mode (wall times
    there are correctness numbers, perf is TPU-only)."""
    import jax
    import jax.numpy as jnp
    from repro.core import sparse_vec as sv
    from repro.kernels import costmodel, ops
    rows = []
    rng = np.random.RandomState(0)
    perm = HashPerm.make(3)
    for k, cap in [(2, 2048), (4, 1024), (8, 512), (16, 256)]:
        idx = np.full((k, cap), 0xFFFFFFFF, np.uint32)
        val = np.zeros((k, cap), np.float32)
        for r in range(k):
            raw = (rng.zipf(1.5, cap * 2) % 100_000).astype(np.uint32)
            h = np.unique(perm.fwd_np(raw))
            n = min(len(h), cap - rng.randint(0, cap // 4))
            idx[r, :n] = h[:n]
            # dyadic-lattice values: any summation order gives identical
            # bits, so the three modes' parity guard can be exact
            val[r, :n] = rng.randint(-128, 129, n) / 64.0
        j_idx, j_val = jnp.asarray(idx), jnp.asarray(val)
        out_cap = k * cap

        # return BOTH outputs or jit dead-code-eliminates the value merge
        def chunk_pair(c):
            return c.idx, c.val

        fns = {
            "sort": jax.jit(lambda i, v: chunk_pair(sv.segment_compact(
                sv.concat_sorted_groups(i, v), out_cap))),
            "fused": jax.jit(lambda i, v: chunk_pair(ops.merge_sorted_runs(
                i, v, out_cap, mode="fused")[0])),
            "banded": jax.jit(lambda i, v: chunk_pair(ops.merge_sorted_runs(
                i, v, out_cap, mode="banded")[0])),
        }

        def run(fn):
            oi, ov = fn(j_idx, j_val)
            oi.block_until_ready(), ov.block_until_ready()

        outs = {}
        for mode, fn in fns.items():
            run(fn)                                   # compile
            outs[mode] = tuple(np.asarray(x) for x in fn(j_idx, j_val))
            rep = costmodel.merge_tile_report(j_idx, out_cap, mode=mode)
            derived = (f"merge={mode},flops={rep['flops']},"
                       f"rank_compare_tiles={rep['rank_compare_tiles']},"
                       f"rank_cheap_tiles={rep['rank_cheap_tiles']},"
                       f"scatter_inner_tiles={rep['scatter_inner_tiles_per_out_tile']},"
                       f"scatter_tiles={rep['scatter_tiles']}")
            rows.append((f"merge/{mode}_k{k}_cap{cap}",
                         _timeit(lambda fn=fn: run(fn)), derived))
        for mode in ("fused", "banded"):              # parity guard
            for a, b in zip(outs["sort"], outs[mode]):
                np.testing.assert_array_equal(a, b)
    return rows


def bench_grad_sync_crossover() -> List[Row]:
    """Sparse vs dense embedding-grad sync bytes vs batch size (the paper's
    mini-batch sparsity argument, on gemma3's 262k vocab)."""
    rows = []
    vocab, d, dp = 262_144, 3840, 16
    dense_bytes = vocab * d * 4 * 2 * (dp - 1) / dp     # ring allreduce
    for tokens in (512, 2048, 8192, 32768, 131072):
        # expected unique rows per device then union across dp
        uniq_dev = vocab / 16 * (1 - (1 - 1 / (vocab / 16)) ** (tokens / 16))
        union = vocab / 16 * (1 - (1 - 1 / (vocab / 16)) ** (tokens * dp / 16))
        sparse_bytes = (uniq_dev * (4 + d * 4)          # down (idx+val)
                        + union * d * 4)                 # up (allgather union)
        rows.append((f"gradsync/tokens{tokens}", 0.0,
                     f"sparse_MB={sparse_bytes/1e6:.1f},"
                     f"dense_MB={dense_bytes/1e6:.1f},"
                     f"win={dense_bytes/max(sparse_bytes,1):.1f}x"))
    return rows


ALL_BENCHES = [
    bench_fig3_roundrobin_scaling,
    bench_table1_sparsity,
    bench_fig5_packet_sizes,
    bench_fig6_topology_sweep,
    bench_fig7_overlap,
    bench_table2_fault_tolerance,
    bench_fig8_scaling,
    bench_fig9_pagerank_comparison,
    bench_fig8_fig9_device_engine,
    bench_kernels,
    bench_merge_modes,
    bench_grad_sync_crossover,
]
