"""Autotuner benchmarks (paper Fig 6/7 degree-vs-depth analogue).

Three row families, all under ``--only autotune``:

* ``autotune/calib_*`` — calibration quality: synthetic fit recovery
  (exact-model samples -> parameter error), measured host-mesh fit
  residual, and whole-reduce modeled-vs-measured error under the
  calibrated fabric (the honesty check for everything below).
* ``autotune/tuned_vs_fixed_*`` — the paper's §IV claim on >= 2 mesh
  shapes: degrees picked by the calibrated model vs the best *fixed
  homogeneous-degree* plan (k, k, ..., k), modeled time speedup.
* ``autotune/cache_*`` — plan-cache economics: cold sweep vs cache-hit
  resolution, and device ``config`` cost fresh vs in-process memo hit vs
  disk (restart) hit, with the retrace count on hits (must be 0).

Wall times are host-dependent as usual; the derived columns carry the
reproducible quantities (see EXPERIMENTS.md row).
"""
from __future__ import annotations

import math
import shutil
import tempfile
import time
from typing import List, Tuple

import numpy as np

from repro.core import autotune
from repro.core.autotune import (PlanCache, fit_error, fit_fabric,
                                 measure_stage_samples, resolve_degrees,
                                 select_plan, synth_stage_samples)
from repro.core.netmodel import EC2_2013, Fabric
from repro.core.topology import (ButterflyPlan, num_prime_factors,
                                 ordered_factorizations)

Row = Tuple[str, float, str]

# Paper-scale workload constants (Twitter followers' graph, Table I)
TW_N0, TW_RANGE = 12.1e6, 60e6

# Ground truth for the deterministic calibration rows: the EC2 fabric
# plus a congestion term (what a measured incast-prone fabric looks like).
GT = Fabric("ec2-2013-congested", beta_bytes_per_s=EC2_2013.beta_bytes_per_s,
            alpha_s=EC2_2013.alpha_s, gamma_s=2e-4)


def _calibrated() -> Fabric:
    """The fabric every row below tunes against: least-squares fit from
    (synthetic, exact-model) GT stage samples — deterministic."""
    samples = synth_stage_samples(GT, [1e4, 1e5, 1e6, 4e6], [1, 3, 7, 15, 31])
    return fit_fabric(samples, name="calibrated-ec2-congested")


def bench_autotune_calibration() -> List[Row]:
    rows = []
    t0 = time.perf_counter()
    samples = synth_stage_samples(GT, [1e4, 1e5, 1e6, 4e6],
                                  [1, 3, 7, 15, 31])
    fit = fit_fabric(samples, name="calib")
    dt = (time.perf_counter() - t0) * 1e6
    err = max(abs(fit.alpha_s - GT.alpha_s) / GT.alpha_s,
              abs(fit.beta_bytes_per_s - GT.beta_bytes_per_s)
              / GT.beta_bytes_per_s,
              abs(fit.gamma_s - GT.gamma_s) / max(GT.gamma_s, 1e-30))
    rows.append(("autotune/calib_synthetic_fit", dt,
                 f"max_param_rel_err={err:.2e} "
                 f"residual={fit_error(fit, samples):.2e}"))

    # measured on the actual (forced-host) mesh: fit the XLA-CPU
    # collective cost and report how well the model explains it
    t0 = time.perf_counter()
    measured = measure_stage_samples(payload_entries=(256, 4096, 16384),
                                     repeats=3)
    mfit = fit_fabric(measured, name="calib-host")
    dt = (time.perf_counter() - t0) * 1e6
    rows.append(("autotune/calib_measured_host", dt,
                 f"samples={len(measured)} "
                 f"alpha_us={mfit.alpha_s*1e6:.1f} "
                 f"beta_GBps={mfit.beta_bytes_per_s/1e9:.2f} "
                 f"gamma_us={mfit.gamma_s*1e6:.2f} "
                 f"modeled_vs_measured_err={fit_error(mfit, measured):.3f}"))

    # whole-reduce validation: modeled (calibrated fabric, stage model)
    # vs measured union_reduce wall for a 2-layer plan on the host mesh
    import jax
    m = len(jax.devices())
    if m >= 4:
        degs = (m // 2, 2)
        plan = ButterflyPlan(m, degs)
        t0 = time.perf_counter()
        wall = autotune.measure_plan(plan, entries_per_node=2048, repeats=3)
        dt = (time.perf_counter() - t0) * 1e6
        modeled = plan.modeled_time(2048, 1 << 20, mfit, serial_nic=True)
        rows.append((f"autotune/calib_reduce_M{m}_{plan}", dt,
                     f"measured_ms={wall*1e3:.2f} "
                     f"modeled_ms={modeled*1e3:.2f} "
                     f"ratio={modeled/max(wall,1e-12):.2f}"))
    return rows


def bench_autotune_tuned_vs_fixed() -> List[Row]:
    fit = _calibrated()
    rows = []
    for m in (64, 256):
        t0 = time.perf_counter()
        rep = select_plan(m, TW_N0, TW_RANGE, fit)
        dt = (time.perf_counter() - t0) * 1e6
        homog = [d for d in ordered_factorizations(m, num_prime_factors(m))
                 if len(set(d)) == 1]
        th = {d: ButterflyPlan(m, d).modeled_time(TW_N0, TW_RANGE, fit)
              for d in homog}
        best_h = min(th, key=th.get)
        speedup = th[best_h] / rep.modeled_s
        rows.append((f"autotune/tuned_vs_fixed_M{m}", dt,
                     f"tuned={rep.plan} t={rep.modeled_s:.3f}s "
                     f"best_fixed={'x'.join(map(str, best_h))} "
                     f"t={th[best_h]:.3f}s speedup={speedup:.2f} "
                     f"decreasing={rep.decreasing}"))
    return rows


def bench_autotune_cache() -> List[Row]:
    rows = []
    tmp = tempfile.mkdtemp(prefix="repro-plan-cache-")
    try:
        cache = PlanCache(root=tmp)
        kw = dict(n0=TW_N0, total_range=TW_RANGE, fabric=_calibrated(),
                  cache=cache)
        t0 = time.perf_counter()
        d_cold, src_cold = resolve_degrees(256, **kw)
        cold = (time.perf_counter() - t0) * 1e6
        t0 = time.perf_counter()
        d_hit, src_hit = resolve_degrees(256, **kw)
        hit = (time.perf_counter() - t0) * 1e6
        assert (src_cold, src_hit) == ("tuned", "cache") and d_cold == d_hit
        rows.append(("autotune/cache_resolve_cold_M256", cold,
                     f"degrees={'x'.join(map(str, d_cold))} source=tuned"))
        rows.append(("autotune/cache_resolve_hit_M256", hit,
                     f"source=cache sweep_skipped=1 "
                     f"speedup={cold/max(hit,1e-9):.0f}x"))

        # device config tiers: fresh plan+compile vs memo vs disk
        import jax
        m = len(jax.devices())
        if m >= 4:
            from repro.core import SparseAllreduce
            rng = np.random.RandomState(0)
            outs = [np.unique(rng.choice(4000, 400).astype(np.uint32))
                    for _ in range(m)]
            ins = [np.unique(rng.choice(4000, 250).astype(np.uint32))
                   for _ in range(m)]
            autotune.clear_plan_memo()

            def config_once():
                ar = SparseAllreduce(m, (m // 2, 2), backend="device",
                                     plan_cache=cache)
                ar.config(outs, ins)
                return ar

            t0 = time.perf_counter()
            ar = config_once()
            fresh = (time.perf_counter() - t0) * 1e6
            traces0 = ar._planned.trace_count
            t0 = time.perf_counter()
            ar2 = config_once()
            memo = (time.perf_counter() - t0) * 1e6
            retr = ar2._planned.trace_count - traces0
            autotune.clear_plan_memo()
            t0 = time.perf_counter()
            ar3 = config_once()
            disk = (time.perf_counter() - t0) * 1e6
            rows.append((f"autotune/cache_config_fresh_M{m}", fresh,
                         f"tier={ar.config_cache}"))
            rows.append((f"autotune/cache_config_memo_M{m}", memo,
                         f"tier={ar2.config_cache} retraces_on_hit={retr} "
                         f"speedup={fresh/max(memo,1e-9):.0f}x"))
            rows.append((f"autotune/cache_config_disk_M{m}", disk,
                         f"tier={ar3.config_cache} "
                         f"speedup={fresh/max(disk,1e-9):.1f}x"))
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    return rows


ALL_BENCHES = [
    bench_autotune_calibration,
    bench_autotune_tuned_vs_fixed,
    bench_autotune_cache,
]
