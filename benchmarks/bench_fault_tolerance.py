"""Fault tolerance benches (paper §V): completion probability + r× cost.

Promised by ``repro.core.replication``'s docstring, wired into
``benchmarks/run.py`` (``--only fault``).  Three row families:

* ``fault/completion_*`` — empirical P[protocol completes] under the
  seeded ``"random"`` failure schedule (``repro.core.faults``), swept over
  r ∈ {1, 2, 3} × failure counts scaled around the §V-A generalized
  birthday bound ``expected_tolerated_failures`` (= sqrt(pi*M/2) at r=2,
  the paper's number), with the Poissonized analytic curve alongside.
* ``fault/schedule_*`` — the same completion probability under the
  correlated (rack) and rolling schedules: replicas sit M apart in the
  physical id space, so contiguous blast radii almost never kill a group
  — the measured argument for the mixed-radix replica layout.
* ``fault/overhead_*`` — the r× message-cost overhead of replication on a
  downscaled Table-II workload: the simulator's byte accounting is the
  cost model of the device path's redundancy schedule.  Messages are
  replicated r-fold so bandwidth scales exactly r×; the modeled time
  multiplier only drops below r on fabrics with per-message floors, and
  the EC2-2013 calibration is bandwidth-dominated at this packet size
  (see EXPERIMENTS.md), so the committed baseline reports time_x == r.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from repro.core.faults import (analytic_completion_probability,
                               completion_probability)
from repro.core.replication import expected_tolerated_failures
from repro.core.simulator import SimSparseAllreduce
from repro.core.sparse_vec import HashPerm
from repro.core.topology import ButterflyPlan

Row = Tuple[str, float, str]

M_LOGICAL = 64          # paper-scale cluster (Fig 6 / Table II setting)
TRIALS = 300


def bench_fault_tolerance_completion() -> List[Row]:
    rows = []
    for r in (1, 2, 3):
        bound = expected_tolerated_failures(M_LOGICAL, r)
        rows.append((f"fault/bound_M{M_LOGICAL}_r{r}", 0.0,
                     f"expected_tolerated_failures={bound:.2f}"))
        fs = sorted({1, int(round(bound * 0.5)), int(round(bound)),
                     min(int(round(bound * 2)), M_LOGICAL * r)} - {0})
        for f in fs:
            t0 = time.perf_counter()
            p = completion_probability(M_LOGICAL, r, f, trials=TRIALS,
                                       kind="random", seed=0)
            dt = (time.perf_counter() - t0) * 1e6
            pa = analytic_completion_probability(M_LOGICAL, r, f)
            rows.append((f"fault/completion_M{M_LOGICAL}_r{r}_f{f}", dt,
                         f"p_complete={p:.3f},analytic={pa:.3f}"))
    return rows


def bench_fault_tolerance_schedules() -> List[Row]:
    rows = []
    r = 2
    f = int(round(expected_tolerated_failures(M_LOGICAL, r)))
    for kind in ("random", "rack", "rolling"):
        t0 = time.perf_counter()
        p = completion_probability(M_LOGICAL, r, f, trials=TRIALS,
                                   kind=kind, seed=0)
        dt = (time.perf_counter() - t0) * 1e6
        rows.append((f"fault/schedule_{kind}_M{M_LOGICAL}_r{r}_f{f}", dt,
                     f"p_complete={p:.3f}"))
    return rows


def bench_fault_tolerance_overhead() -> List[Row]:
    rows = []
    rng = np.random.RandomState(0)
    m, scale = 16, 1500
    out_i = [(rng.zipf(1.4, scale) % 100_000).astype(np.uint32)
             for _ in range(m)]
    out_v = [rng.randn(scale) for _ in range(m)]
    in_i = [rng.choice(100_000, scale // 2, replace=False).astype(np.uint32)
            for _ in range(m)]
    base_bytes = base_time = None
    for r in (1, 2, 3):
        sim = SimSparseAllreduce(ButterflyPlan(m, (4, 4)), replication=r,
                                 perm=HashPerm.make(0))
        t0 = time.perf_counter()
        sim.config(out_i, in_i)
        sim.reduce(out_v)
        dt = (time.perf_counter() - t0) * 1e6
        st = sim.reduce_stats
        if r == 1:
            base_bytes, base_time = st.total_bytes, st.reduce_time_s
        rows.append((f"fault/overhead_M{m}_r{r}", dt,
                     f"reduce_MB={st.total_bytes/1e6:.2f},"
                     f"bytes_x={st.total_bytes/base_bytes:.2f},"
                     f"time_x={st.reduce_time_s/base_time:.2f}"))
    return rows


ALL_BENCHES = [
    bench_fault_tolerance_completion,
    bench_fault_tolerance_schedules,
    bench_fault_tolerance_overhead,
]
