"""Static-analysis smoke benchmark (``--only analysis``).

Times the two layers of ``repro.analysis`` over the real repo: the AST
lint pass on ``src/repro`` (pure ast, no jax) and one jaxpr audit of a
configured device reduce.  The derived column carries the invariants the
timing is worthless without: files linted / violations found (must stay
0) and audit checks passed.  Keeping the lint pass cheap matters — it
runs inside tier-1 pytest on every change.
"""
from __future__ import annotations

import os
import time
from typing import List, Tuple

Row = Tuple[str, float, str]

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def bench_analysis_lint() -> List[Row]:
    """Full-catalog lint of src/repro: wall time + clean-repo invariant."""
    from repro.analysis import lint_paths
    src = os.path.join(_REPO, "src", "repro")
    lint_paths([src])                       # warm (fs cache, rule imports)
    reps = 3
    t0 = time.perf_counter()
    for _ in range(reps):
        violations, files = lint_paths([src])
    us = (time.perf_counter() - t0) / reps * 1e6
    return [("analysis/lint_src", us,
             f"files={files} violations={len(violations)}")]


def bench_analysis_audit() -> List[Row]:
    """One jaxpr audit of a configured (2,2) device reduce (trace only)."""
    import jax
    import numpy as np

    from repro.analysis.auditor import audit_reduce
    from repro.core.api import SparseAllreduce

    m = 4
    rng = np.random.RandomState(m)
    out_idx = [rng.choice(4096, rng.randint(5, 16),
                          replace=False).astype(np.uint32) for _ in range(m)]
    in_idx = [rng.choice(4096, rng.randint(5, 16),
                         replace=False).astype(np.uint32) for _ in range(m)]
    ar = SparseAllreduce(m, (2, 2), backend="device",
                         mesh=jax.make_mesh((m,), ("d",)), seed=m)
    ar.config(out_idx, in_idx)
    audit_reduce(ar)                        # warm (first trace)
    reps = 3
    t0 = time.perf_counter()
    for _ in range(reps):
        report = audit_reduce(ar)
    us = (time.perf_counter() - t0) / reps * 1e6
    n_ok = sum(1 for c in report.checks if c.ok)
    return [("analysis/audit_reduce_2x2", us,
             f"ok={report.ok} checks={n_ok}/{len(report.checks)}")]


ALL_BENCHES = [bench_analysis_lint, bench_analysis_audit]
