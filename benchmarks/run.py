# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
import sys
import traceback


def main() -> None:
    sys.path.insert(0, "src")
    from benchmarks.paper_benches import ALL_BENCHES
    print("name,us_per_call,derived")
    failures = 0
    for bench in ALL_BENCHES:
        try:
            for name, us, derived in bench():
                print(f"{name},{us:.1f},{derived}")
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"{bench.__name__},-1,ERROR:{e}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
