# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV; ``--json PATH`` additionally writes a machine-readable artifact so
# successive PRs accumulate a perf trajectory (see BENCH_pr2.json for the
# first committed baseline and EXPERIMENTS.md for the bench -> figure map).
import argparse
import json
import os
import sys
import traceback


def main(argv=None) -> None:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, os.path.join(root, "src"))
    sys.path.insert(0, root)
    # The device-backed graph-engine rows (fig8/fig9) need a multi-device
    # mesh; off-TPU, force host devices BEFORE jax is first imported (the
    # benchmark modules below pull it in).  Respect a caller-set flag.
    if "--xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + " --xla_force_host_platform_device_count=8").strip()
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="",
                    help="also write results as a JSON list of "
                         "{name, us_per_call, derived} records")
    ap.add_argument("--only", default="",
                    help="comma-separated bench-function name substrings "
                         "to run (default: all)")
    args = ap.parse_args(argv)

    from benchmarks.bench_analysis import ALL_BENCHES as ANALYSIS_BENCHES
    from benchmarks.bench_autotune import ALL_BENCHES as AUTOTUNE_BENCHES
    from benchmarks.bench_fault_tolerance import ALL_BENCHES as FAULT_BENCHES
    from benchmarks.bench_overlap import ALL_BENCHES as OVERLAP_BENCHES
    from benchmarks.bench_serve import ALL_BENCHES as SERVE_BENCHES
    from benchmarks.bench_soak import ALL_BENCHES as SOAK_BENCHES
    from benchmarks.bench_wire import ALL_BENCHES as WIRE_BENCHES
    from benchmarks.paper_benches import ALL_BENCHES
    wanted = [s for s in args.only.split(",") if s]
    benches = [b for b in ALL_BENCHES + FAULT_BENCHES + AUTOTUNE_BENCHES
               + ANALYSIS_BENCHES + SOAK_BENCHES + WIRE_BENCHES
               + OVERLAP_BENCHES + SERVE_BENCHES
               if not wanted or any(s in b.__name__ for s in wanted)]
    print("name,us_per_call,derived")
    records = []
    failures = 0
    for bench in benches:
        try:
            for name, us, derived in bench():
                print(f"{name},{us:.1f},{derived}")
                records.append({"name": name, "us_per_call": round(us, 1),
                                "derived": derived})
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"{bench.__name__},-1,ERROR:{e}")
            traceback.print_exc(file=sys.stderr)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(records, f, indent=1)
        print(f"# wrote {len(records)} records to {args.json}",
              file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
