"""Resilience soak benchmarks (``--only soak``; PR 7).

Three row families quantifying the cost of surviving:

* ``soak/recovery_*`` — recovery latency per fault tier: *absorbed*
  faults (weights-only ``reconfig_dead`` repair), a *cold* shrink
  (replan + config over survivors) and a *warm* shrink (same survivor
  set again — supervisor instance cache), against the fault-free reduce
  as the baseline; plus supervised reduce latency vs fault rate under a
  live random schedule.
* ``soak/replan_cache_*`` — replan cache-hit rate over repeated shrinks
  (flip-flopping dead sets must not re-trace).
* ``soak/resume_*`` — checkpoint + exact-resume overhead per interval:
  atomic ``store.save`` + ``load_flat`` round-trip for a train-sized
  state tree, amortized per step for intervals {1, 2, 4}.

Wall times are host-dependent; the derived columns carry the
reproducible quantities (event classes, hit rates, artifact bytes).
"""
from __future__ import annotations

import shutil
import tempfile
import time
from typing import List, Tuple

import numpy as np

Row = Tuple[str, float, str]

M, R, RANGE, NNZ = 4, 2, 300, 40


def _fleet(dead=None, probe=None, schedule=None, **kw):
    from repro.resilience import DegradedPolicy, ResilientAllreduce
    rng = np.random.RandomState(3)
    outs = [np.sort(rng.choice(RANGE, NNZ, replace=False)).astype(np.uint32)
            for _ in range(M)]
    ins = [np.sort(rng.choice(RANGE, NNZ, replace=False)).astype(np.uint32)
           for _ in range(M)]
    vals = [(rng.randint(-128, 129, NNZ) / 64.0).astype(np.float32)
            for _ in range(M)]
    ra = ResilientAllreduce(M, (2, 2), replication=R, dead=dead,
                            probe=probe, schedule=schedule,
                            policy=DegradedPolicy(max_retries=0), seed=0,
                            expected_nnz=NNZ, index_range=RANGE, **kw)
    ra.config(outs, ins)
    return ra, vals


def bench_soak_recovery_latency() -> List[Row]:
    """Latency of each recovery tier, one supervised reduce per row."""
    rows: List[Row] = []
    deads = {"baseline": None, "absorbed_repair": {5},
             "shrink_cold": {1, 5}, "shrink_warm": {1, 5}}
    probe_box = {"dead": None}
    ra, vals = _fleet(probe=lambda s, a: probe_box["dead"])
    ra.reduce(vals)                       # warm the fault-free compile
    for name, dead in deads.items():
        probe_box["dead"] = dead
        t0 = time.perf_counter()
        out = ra.reduce(vals)
        dt = (time.perf_counter() - t0) * 1e6
        rows.append((f"soak/recovery_{name}", dt,
                     f"klass={out.event.klass} degraded={out.degraded}"))
    # supervised reduce latency vs fault rate (live random schedule)
    from repro.core.faults import make_schedule
    for f in (0, 1, 2):
        sched = make_schedule("random", M * R, f, seed=9)
        ra, vals = _fleet(schedule=sched)
        t0 = time.perf_counter()
        steps = 6
        for s in range(steps):
            ra.reduce(vals, step=s)
        dt = (time.perf_counter() - t0) * 1e6 / steps
        st = ra.stats
        rows.append((f"soak/recovery_vs_rate_f{f}", dt,
                     f"absorbed={st['absorbed']} shrinks={st['shrinks']} "
                     f"reuses={st['shrink_reuses']}"))
    return rows


def bench_soak_replan_cache_hits() -> List[Row]:
    """Repeated shrinks to a previously seen survivor set must be
    supervisor-cache hits (no replan, no retrace)."""
    flip = [None, {1, 5}, None, {1, 5}, {2, 6}, {1, 5}, {2, 6}]
    ra, vals = _fleet(probe=lambda s, a: flip[s % len(flip)])
    t0 = time.perf_counter()
    for s in range(len(flip) * 2):
        ra.reduce(vals, step=s)
    dt = (time.perf_counter() - t0) * 1e6 / (len(flip) * 2)
    st = ra.stats
    hits = st["shrink_reuses"] / max(1, st["shrinks"] + st["shrink_reuses"])
    return [("soak/replan_cache_hit_rate", dt,
             f"hit_rate={hits:.2f} shrinks={st['shrinks']} "
             f"reuses={st['shrink_reuses']} repairs={st['repairs']}")]


def bench_soak_resume_overhead() -> List[Row]:
    """Atomic checkpoint save + load round-trip for a train-sized tree,
    amortized per step for checkpoint intervals {1, 2, 4}."""
    from repro.checkpoint import store
    rng = np.random.RandomState(0)
    tree = {"params": {f"layer{i}": rng.randn(64, 256).astype(np.float32)
                       for i in range(8)},
            "opt_m": {f"layer{i}": rng.randn(64, 256).astype(np.float32)
                      for i in range(8)},
            "opt_step": np.int32(7)}
    nbytes = sum(v.nbytes for d in ("params", "opt_m")
                 for v in tree[d].values()) + 4
    rows: List[Row] = []
    d = tempfile.mkdtemp(prefix="bench_soak_")
    try:
        base = f"{d}/ckpt-1"
        store.save(base, tree, meta={"step": 1})    # warm the fs path
        reps = 5
        t0 = time.perf_counter()
        for _ in range(reps):
            store.save(base, tree, meta={"step": 1})
            store.load_flat(base)
        per_ckpt = (time.perf_counter() - t0) * 1e6 / reps
        for interval in (1, 2, 4):
            rows.append((f"soak/resume_overhead_every{interval}",
                         per_ckpt / interval,
                         f"bytes={nbytes} save+load_us={per_ckpt:.0f} "
                         f"interval={interval}"))
    finally:
        shutil.rmtree(d, ignore_errors=True)
    return rows


ALL_BENCHES = [bench_soak_recovery_latency, bench_soak_replan_cache_hits,
               bench_soak_resume_overhead]
