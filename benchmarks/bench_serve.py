"""Serving-tier benchmarks (``--only serve``; PR 10).

Three row families over the continuous-batching decode service
(``repro.serve``), all on the deterministic virtual clock so the derived
columns are reproducible (wall time feeds only tokens/s):

* ``serve/load_*`` — tokens/s and p50/p99 request latency (in decode
  steps) vs offered load at 0.5x / 1x / 2x the sustainable rate
  (``slots / mean_new_tokens`` requests per step), with admission
  control on.  The 2x row is the saturation contract: the service
  *sheds* (``shed > 0``) instead of queueing unboundedly, and the p99
  of **admitted** requests stays within the SLO.
* ``serve/plan_cache_churn`` — sparse-dispatch plan-cache hit rate over
  batch-shape churn (joins/evictions vary the per-step tail size; the
  power-of-two ``shape_bucket`` keys keep the compiled-pipeline cache
  small).  The committed floor is 0.8.
* ``serve/dispatch_wire_*`` — per-step cost of the hot/cold sparse
  exchange with the tail union on ``raw`` vs a PR-8 compressed codec.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

Row = Tuple[str, float, str]

SLOTS = 4
PROMPT_LENS = (4, 8, 6)
MAX_NEW = (3, 9)          # mean 6 -> sustainable ~ SLOTS/6 req per step
SLO_STEPS = 64.0


def _scheduler(dispatch=None):
    import jax

    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.serve import ContinuousBatchingScheduler

    cfg = get_config("qwen1.5-0.5b").reduced()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params = T.init_params(cfg, tp=1, seed=0)
    sched = ContinuousBatchingScheduler(
        cfg, mesh, params, slots=SLOTS,
        max_seq=max(PROMPT_LENS) + MAX_NEW[1] + 1, dispatch=dispatch)
    return cfg, sched


def _stream(cfg, n, rate, seed, eos=None):
    from repro.serve import zipf_request_stream
    return zipf_request_stream(n, cfg.vocab, prompt_lens=PROMPT_LENS,
                               max_new=MAX_NEW, arrival_rate=rate,
                               eos_id=eos, seed=seed)


def bench_serve_load_latency() -> List[Row]:
    """tokens/s + p50/p99 vs offered load; shed-not-queue at saturation."""
    from repro.serve import AdmissionController, DecodeService

    cfg, sched = _scheduler()
    sustainable = SLOTS / (0.5 * (MAX_NEW[0] + MAX_NEW[1]))
    # warm the per-prompt-length prefill and decode compiles so the row
    # wall times compare service throughput, not XLA compilation
    DecodeService(sched).run(_stream(cfg, n=6, rate=None, seed=99))
    rows: List[Row] = []
    for factor in (0.5, 1.0, 2.0):
        sched.reset()
        adm = AdmissionController(
            rate=sustainable, burst=float(SLOTS), queue_cap=2 * SLOTS,
            slo=SLO_STEPS, breach_window=8, cooldown=32.0)
        reqs = _stream(cfg, n=40, rate=factor * sustainable,
                       seed=int(10 * factor))
        report = DecodeService(sched, adm).run(reqs)
        s = report.admission
        us_per_step = report.wall_s * 1e6 / max(report.steps, 1)
        within = report.p99_steps <= SLO_STEPS
        rows.append((
            f"serve/load_{factor:g}x", us_per_step,
            f"tok_s={report.tokens_per_s:.0f} p50={report.p50_steps:.0f} "
            f"p99={report.p99_steps:.0f} offered={s.offered} "
            f"admitted={s.admitted} shed={s.shed} "
            f"admitted_p99_within_slo={within}"))
        if factor >= 2.0:
            assert s.shed > 0, "2x load must shed, not queue unboundedly"
            assert within, (
                f"admitted p99 {report.p99_steps} exceeds SLO {SLO_STEPS}")
    return rows


def bench_serve_plan_cache_churn() -> List[Row]:
    """Plan-cache hit rate across batch-shape churn (floor 0.8)."""
    from repro.serve import DecodeService
    from repro.serve.dispatch import SparseServeDispatch

    disp = SparseServeDispatch(1, vocab=512, seed=7)
    cfg, sched = _scheduler(dispatch=disp)
    reqs = _stream(cfg, n=32, rate=0.8, seed=5)
    disp.fit_hot_set(np.concatenate([r.prompt for r in reqs]), head_size=8)
    t0 = time.perf_counter()
    report = DecodeService(sched).run(reqs)
    dt_us = (time.perf_counter() - t0) * 1e6
    hit = report.plan_hit_rate
    u = disp._tail_ar.union_plan_stats
    assert hit is not None and hit >= 0.8, f"plan hit rate {hit} < 0.8"
    return [(
        "serve/plan_cache_churn", dt_us / max(disp.steps, 1),
        f"hit_rate={hit:.3f} frozen={disp.frozen_reduces} "
        f"union_hits={u['hits']} union_misses={u['misses']} "
        f"steps={disp.steps}")]


def bench_serve_dispatch_wire() -> List[Row]:
    """Per-step hot/cold exchange cost, tail union raw vs compressed."""
    from repro.data.pipeline import zipf_tokens
    from repro.serve.dispatch import SparseServeDispatch

    rows: List[Row] = []
    rng = np.random.RandomState(11)
    warm = zipf_tokens(rng, (1, 4096), 4096, alpha=1.2)[0]
    for wire in ("raw", "delta+int8ef"):
        disp = SparseServeDispatch(1, vocab=4096, wire=wire, seed=3)
        disp.fit_hot_set(warm, head_size=64)
        shards = [zipf_tokens(rng, (1, SLOTS), 4096, alpha=1.2)[0]
                  for _ in range(12)]
        disp.on_step([shards[0]])          # warm the union compile
        t0 = time.perf_counter()
        for s in shards[1:]:
            disp.on_step([s])
        dt_us = (time.perf_counter() - t0) * 1e6 / (len(shards) - 1)
        ex = disp.last
        rows.append((
            f"serve/dispatch_wire_{wire}", dt_us,
            f"head={len(ex.head_ids)} tail={len(ex.tail_ids)} "
            f"hit_rate={disp.plan_hit_rate:.3f}"))
    return rows


ALL_BENCHES = [bench_serve_load_latency, bench_serve_plan_cache_churn,
               bench_serve_dispatch_wire]
