"""Overlap-schedule benchmarks (ROADMAP item 2: achieved vs rate-optimal).

Row families, all under ``--only overlap``:

* ``overlap/model_rerank_M64`` — the paper-scale (Table I workload,
  M=64) degree sweep re-ranked under the overlapped stage model for a
  ladder of hidden-compute budgets: how the winning factorization and
  its modeled makespan move as bandwidth hides behind compute.
* ``overlap/rate_position_M*`` — achieved (modeled) time vs the
  rate-optimal allreduce bound (PAPERS.md arXiv:2602.22482: ``2 ceil(log2
  M) alpha + 2 (M-1)/M N/beta``), as a fraction: synchronous against the
  bare bound, overlapped makespan against ``max(bound, hidden)`` (no
  schedule finishes before either the hidden compute or the allreduce
  bound).
* ``overlap/sync_step_*`` / ``overlap/engine_*`` — measured wall per
  dispatch on the forced-host mesh, ``sync_overlap=off`` vs ``bucketed``
  and engine ``overlap`` False vs True.  Host-CPU collectives are
  scheduler no-ops (every "message" is a memcpy on one machine), so
  these rows document *parity at comparable dispatch cost* — the overlap
  win is a network effect the cost-model rows quantify; what the
  measured rows pin down is that the rescheduled programs produce
  bitwise/allclose-equal results, with the wall ratio recorded so a real
  fabric run can chart the actual win.

Wall times are host-dependent as usual; the derived columns carry the
reproducible quantities (see EXPERIMENTS.md row).
"""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from repro.core.autotune import select_plan
from repro.core.netmodel import EC2_2013, rate_optimal_allreduce_s

Row = Tuple[str, float, str]

# Paper-scale workload constants (Twitter followers' graph, Table I)
TW_N0, TW_RANGE = 12.1e6, 60e6
BYTES_PER_ENTRY = 12.0


def bench_overlap_model_rerank() -> List[Row]:
    """select_plan at M=64 under a hidden-compute ladder: winner degrees,
    modeled makespan, and the modeled win over running the same hidden
    compute after a bulk-synchronous sync."""
    import warnings
    rows = []
    m = 64
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        t0 = time.perf_counter()
        sync = select_plan(m, TW_N0, TW_RANGE, EC2_2013,
                           bytes_per_entry=BYTES_PER_ENTRY)
        dt = (time.perf_counter() - t0) * 1e6
        for hidden in (0.5 * sync.modeled_s, sync.modeled_s,
                       2.0 * sync.modeled_s):
            t0 = time.perf_counter()
            ov = select_plan(m, TW_N0, TW_RANGE, EC2_2013,
                             bytes_per_entry=BYTES_PER_ENTRY,
                             overlap_compute_s=hidden)
            dt = (time.perf_counter() - t0) * 1e6
            win = (sync.modeled_s + hidden) / ov.modeled_s
            rows.append((
                f"overlap/model_rerank_M{m}_h{hidden / sync.modeled_s:.1f}x",
                dt,
                f"sync={sync.plan} t={sync.modeled_s:.3f}s "
                f"overlap={ov.plan} t={ov.modeled_s:.3f}s "
                f"hidden={hidden:.3f}s modeled_win={win:.2f}x"))
    return rows


def bench_overlap_rate_position() -> List[Row]:
    """Achieved (modeled) vs rate-optimal, sync and overlapped."""
    import warnings
    rows = []
    payload = TW_N0 * BYTES_PER_ENTRY
    for m in (8, 64, 256):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            t0 = time.perf_counter()
            sync = select_plan(m, TW_N0, TW_RANGE, EC2_2013,
                               bytes_per_entry=BYTES_PER_ENTRY)
            ov = select_plan(m, TW_N0, TW_RANGE, EC2_2013,
                             bytes_per_entry=BYTES_PER_ENTRY,
                             overlap_compute_s=sync.modeled_s)
            dt = (time.perf_counter() - t0) * 1e6
        opt = rate_optimal_allreduce_s(payload, m, EC2_2013)
        # overlapped lower bound: the makespan cannot beat the hidden
        # compute OR the allreduce bound, whichever is larger
        hidden = sync.modeled_s
        frac_ov = max(opt, hidden) / ov.modeled_s
        rows.append((
            f"overlap/rate_position_M{m}", dt,
            f"rate_optimal={opt:.3f}s sync={sync.modeled_s:.3f}s "
            f"frac_sync={sync.rate_fraction:.3f} "
            f"overlap_makespan={ov.modeled_s:.3f}s (hidden={hidden:.3f}s) "
            f"frac_overlap={frac_ov:.3f}"))
    return rows


def _tiny_cfg():
    import dataclasses

    from repro.configs import get_config
    return dataclasses.replace(
        get_config("qwen1.5-0.5b").reduced(d_model=64, d_ff=128, vocab=256,
                                           n_heads=2, n_kv=1, head_dim=32),
        tie_embeddings=False)


def bench_overlap_sync_step() -> List[Row]:
    """Measured hier gradient sync, monolithic vs bucketed stage-major,
    on the forced-host mesh (parity documented, see module docstring)."""
    import jax
    import jax.numpy as jnp

    from repro.models import transformer as T
    from repro.train.step import make_sync_fn

    if len(jax.devices()) < 8:
        return [("overlap/sync_step_skipped", 0.0, "needs 8 devices")]
    cfg = _tiny_cfg()
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    params = T.init_params(cfg, 2, seed=0)
    rng = np.random.RandomState(0)
    grads = jax.tree.map(
        lambda p: jnp.asarray(
            rng.randint(-128, 129, p.shape).astype(np.float32) / 64
        ).astype(p.dtype), params)
    tokens = jnp.asarray(rng.randint(0, cfg.vocab, (8, 16)), jnp.int32)

    rows = []
    walls = {}
    outs = {}
    for overlap in ("off", "bucketed"):
        fn, _ = make_sync_fn(cfg, mesh, sync="hier",
                             dp_degrees={"data": (2, 2)},
                             sync_overlap=overlap,
                             sync_bucket_bytes=48 << 10)
        jfn = jax.jit(fn)
        out = jax.block_until_ready(jfn(grads, tokens))   # compile
        reps = 20
        t0 = time.perf_counter()
        for _ in range(reps):
            out = jax.block_until_ready(jfn(grads, tokens))
        walls[overlap] = (time.perf_counter() - t0) / reps
        outs[overlap] = [np.asarray(l) for l in jax.tree.leaves(out[0])]
    bitwise = all(np.array_equal(a, b)
                  for a, b in zip(outs["off"], outs["bucketed"]))
    rows.append((
        "overlap/sync_step_hier_M4x2", walls["bucketed"] * 1e6,
        f"off_us={walls['off'] * 1e6:.0f} "
        f"bucketed_us={walls['bucketed'] * 1e6:.0f} "
        f"ratio={walls['off'] / max(walls['bucketed'], 1e-12):.2f} "
        f"bitwise_equal={bitwise}"))
    return rows


def bench_overlap_engine() -> List[Row]:
    """Measured PageRank engine dispatch, synchronous vs double-buffered
    scan (parity documented, see module docstring)."""
    import jax

    from repro.data.pipeline import powerlaw_graph
    from repro.graph.engine import GraphEngine
    from repro.graph.pagerank import build_partitions, make_pagerank_engine

    m = min(len(jax.devices()), 8)
    mesh = jax.make_mesh((m,), ("d",))
    edges = powerlaw_graph(2000, 12000, seed=1)
    parts = build_partitions(edges, 2000, m)
    base, extras, p0 = make_pagerank_engine(parts, 2000, degrees=(4, 2),
                                            mesh=mesh)
    k = 8
    walls = {}
    finals = {}
    for overlap in (False, True):
        eng = base if not overlap else GraphEngine(
            [np.asarray(o) for o in base.out_sets],
            [np.asarray(i) for i in base.in_sets],
            base.app, degrees=(4, 2), mesh=mesh, overlap=True)
        final, _, _ = eng.run(k, p0, extras)            # compile
        reps = 5
        t0 = time.perf_counter()
        for _ in range(reps):
            final, _, _ = eng.run(k, p0, extras)
            jax.block_until_ready(final)
        walls[overlap] = (time.perf_counter() - t0) / reps
        finals[overlap] = np.asarray(jax.tree.leaves(final)[0])
    close = bool(np.allclose(finals[False], finals[True], rtol=1e-6))
    return [(
        f"overlap/engine_pagerank_M{m}_k{k}", walls[True] * 1e6,
        f"sync_us={walls[False] * 1e6:.0f} "
        f"overlap_us={walls[True] * 1e6:.0f} "
        f"ratio={walls[False] / max(walls[True], 1e-12):.2f} "
        f"allclose={close}")]


ALL_BENCHES = [bench_overlap_model_rerank, bench_overlap_rate_position,
               bench_overlap_sync_step, bench_overlap_engine]
