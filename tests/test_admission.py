"""Property tests for the serving tier's admission control
(``repro.serve.queue``), on a deterministic fake clock — no sleeps, no
wall time, every example replayable.

The three contracts pinned here are the ones the service loop and the
load benches assume:

* **Token bucket**: over any window ``(t0, t1]`` of the call trace it
  admits at most ``burst + rate * (t1 - t0)`` unit-cost requests — the
  saturation bound the 2x-load bench relies on.
* **Bounded queue**: FIFO is preserved, ``admitted + shed == offered``,
  and occupancy never exceeds capacity.
* **Circuit breaker**: trips only after ``breach_window`` *consecutive*
  SLO breaches, always half-opens ``cooldown`` after a trip, and can
  never deadlock refusing (the probe-loss re-arm makes ``allow`` return
  True again within two cooldowns of any state whatsoever).
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.serve.queue import (AdmissionController, BoundedQueue,
                               CircuitBreaker, Request, TokenBucket)


def _req(rid: int) -> Request:
    return Request(rid=rid, prompt=np.zeros(2, np.int32), max_new=2)


# ---------------------------------------------------------------------------
# Token bucket
# ---------------------------------------------------------------------------

@given(st.floats(min_value=0.05, max_value=8.0),
       st.floats(min_value=1.0, max_value=10.0),
       st.lists(st.floats(min_value=0.0, max_value=3.0),
                min_size=1, max_size=60))
@settings(max_examples=60)
def test_token_bucket_window_bound(rate, burst, gaps):
    """Admits inside any window (t0, t1] never exceed burst + rate*dt."""
    tb = TokenBucket(rate, burst)
    times = np.cumsum(np.asarray(gaps, np.float64))
    admitted = [t for t in times if tb.admit(float(t))]
    for i, t0 in enumerate(times):
        for t1 in times[i:]:
            n = sum(1 for t in admitted if t0 < t <= t1)
            assert n <= burst + rate * (t1 - t0) + 1e-6, \
                f"window ({t0}, {t1}]: {n} admits"


@given(st.floats(min_value=0.1, max_value=4.0),
       st.floats(min_value=1.0, max_value=6.0))
def test_token_bucket_burst_then_starve_then_refill(rate, burst):
    """At one instant only floor(burst) admits succeed; refill restores
    rate*dt more, capped at burst."""
    tb = TokenBucket(rate, burst)
    first = sum(tb.admit(0.0) for _ in range(int(burst) + 5))
    assert first == int(burst + 1e-9)
    dt = 2.0 / rate  # two tokens of refill (before the burst cap)
    later = sum(tb.admit(dt) for _ in range(10))
    frac = burst - int(burst + 1e-9)         # tokens left after the burst
    assert later == int(min(burst, frac + 2.0) + 1e-9)


def test_token_bucket_clock_never_runs_backwards():
    tb = TokenBucket(1.0, 1.0)
    assert tb.admit(10.0)
    # a stale clock must not mint tokens or crash
    assert not tb.admit(5.0)
    assert tb.admit(11.0)


def test_token_bucket_validates():
    with pytest.raises(ValueError):
        TokenBucket(0.0, 4.0)
    with pytest.raises(ValueError):
        TokenBucket(1.0, 0.5)


# ---------------------------------------------------------------------------
# Bounded queue
# ---------------------------------------------------------------------------

@given(st.integers(min_value=1, max_value=8),
       st.lists(st.booleans(), min_size=1, max_size=80))
@settings(max_examples=60)
def test_bounded_queue_fifo_and_accounting(cap, ops):
    """True op = offer, False = pop: popped order is exactly admitted
    order, admitted + shed == offered, occupancy <= capacity."""
    q = BoundedQueue(cap)
    seq = 0
    accepted, popped = [], []
    for is_offer in ops:
        if is_offer:
            if q.offer(seq):
                accepted.append(seq)
            seq += 1
        else:
            item = q.pop()
            if item is not None:
                popped.append(item)
        assert len(q) <= cap
        assert q.admitted + q.shed == q.offered == seq
    assert popped == accepted[:len(popped)]
    assert len(accepted) - len(popped) == len(q)


# ---------------------------------------------------------------------------
# Circuit breaker
# ---------------------------------------------------------------------------

@given(st.integers(min_value=1, max_value=6),
       st.lists(st.integers(min_value=0, max_value=1),
                min_size=1, max_size=60))
@settings(max_examples=60)
def test_breaker_trips_only_on_consecutive_breaches(window, pattern):
    """The breaker trips iff the trace contains `window` consecutive
    breaches while closed; a single good completion resets the streak."""
    br = CircuitBreaker(slo=10.0, breach_window=window, cooldown=5.0)
    streak, should_trip = 0, False
    for i, breach in enumerate(pattern):
        br.record(float(i), 20.0 if breach else 1.0)
        streak = streak + 1 if breach else 0
        if streak >= window:
            should_trip = True
            break
    assert (br.state == CircuitBreaker.OPEN) == should_trip
    assert br.trips == int(should_trip)


@given(st.floats(min_value=0.5, max_value=20.0))
def test_breaker_always_half_opens_after_cooldown(cooldown):
    br = CircuitBreaker(slo=1.0, breach_window=1, cooldown=cooldown)
    br.record(0.0, 2.0)
    assert br.state == CircuitBreaker.OPEN
    assert not br.allow(cooldown * 0.5)          # still cooling
    assert br.allow(cooldown + 1e-6)             # probe admitted
    assert br.state == CircuitBreaker.HALF_OPEN


def test_breaker_probe_successes_close_probe_breach_reopens():
    br = CircuitBreaker(slo=1.0, breach_window=1, cooldown=4.0, probes=2)
    br.record(0.0, 2.0)
    assert br.allow(5.0) and br.allow(5.0)       # both probe slots
    assert not br.allow(5.0)                     # budget spent
    br.record(6.0, 0.5)
    br.record(6.0, 0.5)
    assert br.state == CircuitBreaker.CLOSED
    # a breaching probe re-trips instead
    br2 = CircuitBreaker(slo=1.0, breach_window=1, cooldown=4.0, probes=2)
    br2.record(0.0, 2.0)
    assert br2.allow(5.0)
    br2.record(6.0, 3.0)
    assert br2.state == CircuitBreaker.OPEN and br2.trips == 2


@given(st.lists(st.tuples(st.integers(min_value=0, max_value=2),
                          st.floats(min_value=0.0, max_value=4.0),
                          st.floats(min_value=0.0, max_value=30.0)),
                min_size=0, max_size=60))
@settings(max_examples=60)
def test_breaker_never_deadlocks_closed(ops):
    """Liveness: after ANY op trace, allow() returns True within two
    cooldowns of the last event (lost probes re-arm; nothing wedges)."""
    cooldown = 6.0
    br = CircuitBreaker(slo=5.0, breach_window=2, cooldown=cooldown,
                        probes=2)
    t = 0.0
    for kind, dt, lat in ops:
        t += dt
        if kind == 0:
            br.allow(t)
        else:
            br.record(t, lat)
    t1 = t + cooldown + 1e-3
    ok = br.allow(t1) or br.allow(t1 + cooldown + 1e-3)
    assert ok, f"breaker wedged in state {br.state}"


# ---------------------------------------------------------------------------
# The composed controller
# ---------------------------------------------------------------------------

@given(st.lists(st.floats(min_value=0.0, max_value=2.0),
                min_size=1, max_size=80))
@settings(max_examples=40)
def test_controller_accounting_is_total(gaps):
    """Every offer lands in exactly one bucket of the stats."""
    adm = AdmissionController(rate=0.7, burst=2.0, queue_cap=3, slo=8.0)
    t = 0.0
    for i, dt in enumerate(gaps):
        t += dt
        reason = adm.offer(_req(i), t)
        assert reason in ("admitted", "shed_rate", "shed_queue",
                          "shed_breaker")
    s = adm.stats
    assert s.offered == len(gaps)
    assert s.admitted + s.shed == s.offered
    assert adm.pending() <= 3


def test_controller_checks_breaker_before_spending_tokens():
    """An open breaker sheds without consuming rate tokens: once it
    half-opens, the full burst is still available."""
    adm = AdmissionController(rate=0.001, burst=2.0, queue_cap=8,
                              slo=1.0, breach_window=1, cooldown=10.0)
    adm.breaker.record(0.0, 5.0)             # trip immediately
    for i in range(4):
        assert adm.offer(_req(i), 1.0) == "shed_breaker"
    # cooldown passed: probe admitted, and the bucket still holds its
    # burst (negligible refill at rate=0.001) — breaker ran first.
    assert adm.offer(_req(10), 11.0) == "admitted"
    assert adm.offer(_req(11), 11.0) == "admitted"
    assert adm.stats.shed_rate == 0


def test_controller_full_queue_sheds_with_reason():
    adm = AdmissionController(rate=100.0, burst=100.0, queue_cap=2,
                              slo=8.0)
    assert adm.offer(_req(0), 0.0) == "admitted"
    assert adm.offer(_req(1), 0.0) == "admitted"
    assert adm.offer(_req(2), 0.0) == "shed_queue"
    assert adm.next_request().rid == 0       # FIFO out
    assert adm.offer(_req(3), 0.0) == "admitted"
