"""Prefill->decode must agree with a longer prefill (cache correctness).

For each family: logits(decode(prefill(t[:S]), t[S])) == logits(prefill(t[:S+1])).
This catches cache-layout, position, rope, window, and state-handoff bugs
across attention / mamba / mlstm+slstm / moe blocks.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.train.step import make_decode_step, make_prefill_step

warnings.filterwarnings("ignore")

S, MAX, B = 24, 32, 2


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


@pytest.mark.parametrize("arch,tol", [
    ("qwen1.5-0.5b", 2e-3),          # dense GQA + bias
    ("gemma3-12b", 2e-3),            # sliding-window pattern
    ("xlstm-1.3b", 5e-2),            # mLSTM state handoff (m=0 stabilizer)
    ("granite-moe-3b-a800m", 5e-2),  # MoE routing (capacity order effects)
    ("jamba-1.5-large-398b", 5e-2),  # mamba conv tail + ssm state
])
def test_decode_matches_prefill(arch, tol, mesh):
    cfg = get_config(arch).reduced()
    rng = np.random.RandomState(0)
    params = T.init_params(cfg, tp=1, seed=0)
    toks = rng.randint(0, cfg.vocab, (B, S + 1)).astype(np.int32)

    prefill, _ = make_prefill_step(cfg, mesh, max_seq=MAX)
    decode, _ = make_decode_step(cfg, mesh)

    batch_s = {"tokens": jnp.asarray(toks[:, :S])}
    batch_s1 = {"tokens": jnp.asarray(toks)}
    if cfg.img_tokens:
        img = jnp.asarray(rng.randn(B, cfg.img_tokens, cfg.d_model),
                          jnp.float32)
        batch_s["img_embeds"] = img
        batch_s1["img_embeds"] = img

    _, cache = prefill(params, batch_s)
    pos = jnp.full((B,), S + (cfg.img_tokens or 0), jnp.int32)
    lg_decode, _ = decode(params, jnp.asarray(toks[:, S]), pos, cache)

    lg_full, _ = prefill(params, batch_s1)

    a = np.asarray(lg_decode)[:, :cfg.vocab]
    b = np.asarray(lg_full)[:, :cfg.vocab]
    # compare post-softmax (logits can differ by shared constants)
    pa = jax.nn.softmax(jnp.asarray(a), axis=-1)
    pb = jax.nn.softmax(jnp.asarray(b), axis=-1)
    err = float(jnp.max(jnp.abs(pa - pb)))
    assert err < tol, f"{arch}: softmax mismatch {err}"
    if tol < 1e-2:
        # greedy-decode invariance (loose-tol archs: near-uniform random-init
        # logits make argmax flip on float-order noise, not on cache bugs)
        assert np.array_equal(np.argmax(a, -1), np.argmax(b, -1)), arch


# ---------------------------------------------------------------------------
# Serving-tier cache-shape invariants: --model-axis x reduced archs
# ---------------------------------------------------------------------------

import os
import subprocess
import sys

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")
_ENV8 = dict(os.environ,
             XLA_FLAGS="--xla_force_host_platform_device_count=8",
             PYTHONPATH=_SRC + os.pathsep + os.environ.get("PYTHONPATH", ""))

_CACHE_SHAPE_CODE = r"""
import sys
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models import transformer as T
from repro.models.transformer import padded_vocab
from repro.train.step import (init_cache_global, make_decode_greedy_step,
                              make_prefill_greedy_step, mesh_ctx)

MA = int(sys.argv[1])
MAX = 16
for arch in ("qwen1.5-0.5b", "granite-moe-3b-a800m", "jamba-1.5-large-398b"):
    cfg = get_config(arch).reduced()
    mesh = jax.make_mesh((8 // MA, MA), ("data", "model"))
    mc = mesh_ctx(mesh)
    b = mc.dp
    params = T.init_params(cfg, tp=MA, seed=0)
    ref = init_cache_global(cfg, mc, b, MAX)
    want = jax.tree.map(lambda x: (x.shape, x.dtype), ref)

    prefill, _ = make_prefill_greedy_step(cfg, mesh, MAX)
    toks = jnp.zeros((b, 6), jnp.int32)
    ids, cache = prefill(params, {"tokens": toks})
    got = jax.tree.map(lambda x: (x.shape, x.dtype), cache)
    assert got == want, (arch, "prefill cache", got, want)
    assert ids.shape == (b,) and ids.dtype == jnp.int32, (arch, ids.aval)

    decode, _ = make_decode_greedy_step(cfg, mesh)
    ids2, cache2 = decode(params, ids, jnp.full((b,), 6, jnp.int32), cache)
    got2 = jax.tree.map(lambda x: (x.shape, x.dtype), cache2)
    assert got2 == want, (arch, "decode cache", got2, want)
    assert ids2.shape == (b,) and ids2.dtype == jnp.int32
    assert int(np.asarray(ids2).max()) < cfg.vocab, arch
    # the padded tail [vocab, V_pad) must never win the greedy argmax
    assert padded_vocab(cfg, MA) % (MA * 16) == 0
print("CACHE_OK", MA)
"""


@pytest.mark.slow
@pytest.mark.parametrize("ma", [1, 2])
def test_serve_cache_shape_invariants_across_model_axis(ma):
    """The fused greedy prefill/decode steps preserve the exact cache
    tree (shapes + dtypes) that ``init_cache_global`` declares, for every
    reduced cache family (attention / MoE / mamba), under tensor
    parallelism ``--model-axis`` 1 and 2 — and their ids outputs are
    int32 in ``[0, vocab)`` (the padded-vocab tail never leaks out)."""
    r = subprocess.run([sys.executable, "-c", _CACHE_SHAPE_CODE, str(ma)],
                       env=_ENV8, capture_output=True, text=True,
                       timeout=560)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert f"CACHE_OK {ma}" in r.stdout
