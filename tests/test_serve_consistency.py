"""Prefill->decode must agree with a longer prefill (cache correctness).

For each family: logits(decode(prefill(t[:S]), t[S])) == logits(prefill(t[:S+1])).
This catches cache-layout, position, rope, window, and state-handoff bugs
across attention / mamba / mlstm+slstm / moe blocks.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.train.step import make_decode_step, make_prefill_step

warnings.filterwarnings("ignore")

S, MAX, B = 24, 32, 2


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


@pytest.mark.parametrize("arch,tol", [
    ("qwen1.5-0.5b", 2e-3),          # dense GQA + bias
    ("gemma3-12b", 2e-3),            # sliding-window pattern
    ("xlstm-1.3b", 5e-2),            # mLSTM state handoff (m=0 stabilizer)
    ("granite-moe-3b-a800m", 5e-2),  # MoE routing (capacity order effects)
    ("jamba-1.5-large-398b", 5e-2),  # mamba conv tail + ssm state
])
def test_decode_matches_prefill(arch, tol, mesh):
    cfg = get_config(arch).reduced()
    rng = np.random.RandomState(0)
    params = T.init_params(cfg, tp=1, seed=0)
    toks = rng.randint(0, cfg.vocab, (B, S + 1)).astype(np.int32)

    prefill, _ = make_prefill_step(cfg, mesh, max_seq=MAX)
    decode, _ = make_decode_step(cfg, mesh)

    batch_s = {"tokens": jnp.asarray(toks[:, :S])}
    batch_s1 = {"tokens": jnp.asarray(toks)}
    if cfg.img_tokens:
        img = jnp.asarray(rng.randn(B, cfg.img_tokens, cfg.d_model),
                          jnp.float32)
        batch_s["img_embeds"] = img
        batch_s1["img_embeds"] = img

    _, cache = prefill(params, batch_s)
    pos = jnp.full((B,), S + (cfg.img_tokens or 0), jnp.int32)
    lg_decode, _ = decode(params, jnp.asarray(toks[:, S]), pos, cache)

    lg_full, _ = prefill(params, batch_s1)

    a = np.asarray(lg_decode)[:, :cfg.vocab]
    b = np.asarray(lg_full)[:, :cfg.vocab]
    # compare post-softmax (logits can differ by shared constants)
    pa = jax.nn.softmax(jnp.asarray(a), axis=-1)
    pb = jax.nn.softmax(jnp.asarray(b), axis=-1)
    err = float(jnp.max(jnp.abs(pa - pb)))
    assert err < tol, f"{arch}: softmax mismatch {err}"
    if tol < 1e-2:
        # greedy-decode invariance (loose-tol archs: near-uniform random-init
        # logits make argmax flip on float-order noise, not on cache bugs)
        assert np.array_equal(np.argmax(a, -1), np.argmax(b, -1)), arch
