"""Fused rank-merge pipeline (kernels.ops.merge_sorted_runs) parity.

The fused path must be bit-identical to the sort-based per-layer merge
(concat + argsort + segment_compact) and to a reference merge assembled
from the pure-jnp oracles in kernels/ref.py, on power-law (Zipf-drawn,
hash-permuted) chunks — the paper's workload shape.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sparse_vec as sv
from repro.core.sparse_vec import SENTINEL, HashPerm
from repro.kernels import ops
from repro.kernels.ref import onehot_scatter_add_ref, rank_counts_ref


def _powerlaw_runs(k, cap, width, seed):
    """k sorted SENTINEL-padded runs of hash-permuted Zipf indices."""
    rng = np.random.RandomState(seed)
    perm = HashPerm.make(seed + 1)
    idx = np.full((k, cap), 0xFFFFFFFF, np.uint32)
    vshape = (k, cap) if width == 0 else (k, cap, width)
    val = np.zeros(vshape, np.float32)
    for r in range(k):
        raw = (rng.zipf(1.6, cap * 2) % 50_000).astype(np.uint32)
        h = np.unique(perm.fwd_np(raw))
        n = min(len(h), rng.randint(1, cap + 1))
        idx[r, :n] = h[:n]
        shape = (n,) if width == 0 else (n, width)
        val[r, :n] = rng.randn(*shape).astype(np.float32)
    return jnp.asarray(idx), jnp.asarray(val)


def _sort_path(idx, val, out_cap):
    cat = sv.concat_sorted_groups(idx, val)
    return sv.segment_compact(cat, out_cap), sv.compact_overflow(cat, out_cap)


@pytest.mark.parametrize("k,cap,width", [(1, 32, 0), (2, 64, 0), (2, 33, 2),
                                         (4, 48, 3), (8, 32, 1), (3, 40, 0)])
def test_fused_bit_identical_to_sort_path(k, cap, width):
    idx, val = _powerlaw_runs(k, cap, width, seed=k * 100 + cap)
    out_cap = k * cap
    want, want_ovf = _sort_path(idx, val, out_cap)
    got, ovf = ops.merge_sorted_runs(idx, val, out_cap)
    np.testing.assert_array_equal(np.asarray(got.idx), np.asarray(want.idx))
    np.testing.assert_array_equal(np.asarray(got.val), np.asarray(want.val))
    assert int(ovf) == int(want_ovf) == 0


@pytest.mark.parametrize("k,cap", [(2, 64), (4, 32)])
def test_fused_overflow_matches_sort_path(k, cap):
    """Undersized output: both paths keep the same prefix and count the
    same number of dropped unique indices."""
    idx, val = _powerlaw_runs(k, cap, 0, seed=7)
    out_cap = max(8, (k * cap) // 4)
    want, want_ovf = _sort_path(idx, val, out_cap)
    got, ovf = ops.merge_sorted_runs(idx, val, out_cap)
    np.testing.assert_array_equal(np.asarray(got.idx), np.asarray(want.idx))
    np.testing.assert_array_equal(np.asarray(got.val), np.asarray(want.val))
    assert int(ovf) == int(want_ovf) > 0


def test_fused_all_sentinel_runs():
    idx = jnp.full((4, 16), SENTINEL, jnp.uint32)
    val = jnp.zeros((4, 16), jnp.float32)
    got, ovf = ops.merge_sorted_runs(idx, val, 64)
    assert int(got.count()) == 0
    assert int(ovf) == 0


def _ref_merge(idx, val, out_cap):
    """The same pipeline assembled from the kernels/ref.py oracles."""
    k, cap = idx.shape
    total = k * cap
    ranks = []
    for r in range(k):
        rk = np.arange(cap, dtype=np.int32)
        for s in range(k):
            if s == r:
                continue
            side = "left" if s > r else "right"   # strict vs stable non-strict
            rk = rk + np.asarray(rank_counts_ref(idx[r], idx[s], side))
        ranks.append(rk)
    rank = np.stack(ranks).reshape(-1)
    flat_idx = np.asarray(idx).reshape(-1)
    merged = np.zeros(total, np.uint32)
    merged[rank] = flat_idx
    valid = merged != np.uint32(0xFFFFFFFF)
    is_head = np.concatenate([[True], merged[1:] != merged[:-1]]) & valid
    pos = np.cumsum(is_head.astype(np.int32)) - 1
    pos = np.where(valid & (pos < out_cap), pos, out_cap)
    out_idx = np.full(out_cap, 0xFFFFFFFF, np.uint32)
    heads = pos[is_head]
    out_idx[heads[heads < out_cap]] = merged[is_head][heads < out_cap]
    final_pos = pos[rank]
    v = np.asarray(val).reshape(total, -1)
    out_val = np.asarray(onehot_scatter_add_ref(
        jnp.asarray(final_pos), jnp.asarray(v), out_cap))
    if np.asarray(val).ndim == 2:
        out_val = out_val[:, 0]
    return out_idx, out_val


@pytest.mark.parametrize("k,cap,width", [(2, 48, 0), (4, 32, 2)])
def test_fused_matches_ref_oracle(k, cap, width):
    idx, val = _powerlaw_runs(k, cap, width, seed=13)
    out_cap = k * cap
    ref_idx, ref_val = _ref_merge(idx, val, out_cap)
    got, _ = ops.merge_sorted_runs(idx, val, out_cap)
    np.testing.assert_array_equal(np.asarray(got.idx), ref_idx)
    np.testing.assert_allclose(np.asarray(got.val), ref_val,
                               rtol=1e-6, atol=1e-6)


def test_merge_knob_validation():
    from repro.core.api import SparseAllreduce
    with pytest.raises(ValueError):
        SparseAllreduce(8, (4, 2), merge="bogus")
    ar = SparseAllreduce(8, (4, 2), merge="fused")
    assert ar.merge == "fused"

    from repro.core.allreduce import make_device_plan, sparse_allreduce_union
    from repro.core.sparse_vec import SparseChunk
    plan = make_device_plan([("d", 8)], {"d": (4, 2)}, 16, 64)
    chunk = SparseChunk(idx=jnp.full((16,), SENTINEL, jnp.uint32),
                        val=jnp.zeros((16,), jnp.float32))
    with pytest.raises(ValueError):
        sparse_allreduce_union(chunk, plan, [], merge="bogus")
