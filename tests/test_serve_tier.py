"""Request-level consistency harness for the serving tier
(ARCHITECTURE.md "Serving tier").

The claim under test: continuous-batched decode returns **token-for-token
exactly** what each request would get served alone.  The oracle is the
same scheduler instance run one-request-at-a-time
(``run_sequential_oracle``) — same compiled slot geometry, so equality
isolates request isolation (slot writes, position tracking, join/evict
bookkeeping) from XLA's batch-size-dependent reduction order, which is
*not* bitwise across different compiled batch sizes.

Tiers:

* always-on: the consistency sweep over slot counts {1, 2, 8} with
  seeded Zipf streams (mixed prompt lengths, staggered arrivals,
  ``max_new`` churn incl. join-completes), EOS eviction, dispatch
  non-perturbation + numpy-oracle agreement of the sparse exchange, the
  expert-load path, scheduler validation, and the ``audit_serve_decode``
  pinned regression (the fused greedy steps pass; the raw logits-
  returning decode step must *fail* — the check has teeth).
* ``@pytest.mark.slow`` subprocess: the 16-device case — mesh (8, 2),
  granite-moe reduced, slots=8 over dp=8 shards, sparse dispatch on —
  batched == oracle and every step's exchange equals a dense bincount.
"""
import os
import subprocess
import sys
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serve import (ContinuousBatchingScheduler, DecodeService,
                         zipf_request_stream)
from repro.serve.service import run_sequential_oracle

warnings.filterwarnings("ignore")

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")
_ENV16 = dict(os.environ,
              XLA_FLAGS="--xla_force_host_platform_device_count=16",
              PYTHONPATH=_SRC + os.pathsep + os.environ.get("PYTHONPATH", ""))

MAX_SEQ = 24


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


@pytest.fixture(scope="module")
def qwen():
    cfg = get_config("qwen1.5-0.5b").reduced()
    return cfg, T.init_params(cfg, tp=1, seed=0)


def _stream(cfg, n, seed, eos_id=None):
    """Mixed prompt lengths, staggered arrivals, max_new down to 1 (a
    request that completes at join, exercising the no-decode path)."""
    return zipf_request_stream(
        n, cfg.vocab, prompt_lens=(4, 8, 6), max_new=(1, 7),
        arrival_rate=0.6, eos_id=eos_id, seed=seed)


def _serve_and_compare(cfg, mesh, params, slots, reqs, dispatch=None):
    sched = ContinuousBatchingScheduler(
        cfg, mesh, params, slots=slots, max_seq=MAX_SEQ, dispatch=dispatch)
    report = DecodeService(sched).run(reqs)
    assert len(report.completed) == len(reqs)
    batched = {r.rid: list(r.tokens) for r in report.completed}
    sched.reset()
    oracle = run_sequential_oracle(sched, reqs)
    for i, req in enumerate(reqs):
        assert batched[req.rid] == oracle[i], \
            f"rid {req.rid} (slots={slots}): {batched[req.rid]} != {oracle[i]}"
    return batched, report


# ---------------------------------------------------------------------------
# The consistency sweep
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("slots", [1, 2, 8])
def test_continuous_batching_matches_sequential_oracle(qwen, mesh, slots):
    cfg, params = qwen
    reqs = _stream(cfg, n=7, seed=100 + slots)
    batched, report = _serve_and_compare(cfg, mesh, params, slots, reqs)
    # every request generated something and respected its budget
    for req in reqs:
        assert 1 <= len(batched[req.rid]) <= req.max_new
    assert report.tokens_out == sum(len(t) for t in batched.values())


def test_eos_evicts_early_and_stays_consistent(qwen, mesh):
    """Pick a token the model actually emits mid-request, declare it EOS,
    and re-serve: the request must stop at it (strictly early), and the
    batched run must still match the oracle token-for-token."""
    cfg, params = qwen
    probe = _stream(cfg, n=5, seed=7)
    sched = ContinuousBatchingScheduler(cfg, mesh, params, slots=2,
                                        max_seq=MAX_SEQ)
    DecodeService(sched).run(probe)
    eos = next((r.tokens[1] for r in probe if len(r.tokens) >= 3), None)
    assert eos is not None, "probe stream produced no 3-token request"

    reqs = _stream(cfg, n=5, seed=7, eos_id=int(eos))
    sched.reset()
    report = DecodeService(sched).run(reqs)
    batched = {r.rid: list(r.tokens) for r in report.completed}
    sched.reset()
    oracle = run_sequential_oracle(sched, reqs)
    stopped_early = 0
    for i, req in enumerate(reqs):
        assert batched[req.rid] == oracle[i]
        if len(batched[req.rid]) < req.max_new:
            assert batched[req.rid][-1] == eos
            stopped_early += 1
    assert stopped_early >= 1, "EOS never fired — eviction path untested"


def test_scheduler_validation_and_slot_bookkeeping(qwen, mesh):
    cfg, params = qwen
    with pytest.raises(ValueError, match="decoder-only"):
        ContinuousBatchingScheduler(get_config("whisper-base").reduced(),
                                    mesh, params, slots=2, max_seq=MAX_SEQ)
    with pytest.raises(ValueError, match="multiple"):
        ContinuousBatchingScheduler(cfg, mesh, params, slots=0,
                                    max_seq=MAX_SEQ)
    sched = ContinuousBatchingScheduler(cfg, mesh, params, slots=2,
                                        max_seq=MAX_SEQ)
    with pytest.raises(ValueError, match="exceeds max_seq"):
        sched.join(zipf_request_stream(1, cfg.vocab,
                                       prompt_lens=(MAX_SEQ,),
                                       max_new=(4, 4), seed=0)[0])
    reqs = zipf_request_stream(3, cfg.vocab, prompt_lens=(4,),
                               max_new=(3, 3), seed=1)
    assert sched.join(reqs[0]) == 0 and sched.join(reqs[1]) == 1
    assert sched.free_slots() == [] and sched.active == 2
    with pytest.raises(RuntimeError, match="no free slot"):
        sched.join(reqs[2])
    while sched.active:
        sched.step()
    done = sched.pop_completed()
    assert sorted(r.rid for r in done) == [0, 1]
    assert sched.free_slots() == [0, 1]
    assert sched.metrics.joins == 2 and sched.metrics.evictions == 2


# ---------------------------------------------------------------------------
# Sparse dispatch: non-perturbation + exchange correctness
# ---------------------------------------------------------------------------

class _RecordingDispatch:
    """Wraps SparseServeDispatch to capture (input shards, exchange)."""

    def __init__(self, inner):
        self._inner = inner
        self.trace = []

    def __getattr__(self, name):
        return getattr(self._inner, name)

    def on_step(self, tok_shards):
        ex = self._inner.on_step(tok_shards)
        self.trace.append(([np.array(s) for s in tok_shards], ex))
        return ex


def test_dispatch_observes_without_perturbing_and_matches_bincount(
        qwen, mesh):
    from repro.serve.dispatch import SparseServeDispatch
    cfg, params = qwen
    reqs = _stream(cfg, n=6, seed=21)
    base, _ = _serve_and_compare(cfg, mesh, params, 2, reqs)

    disp = SparseServeDispatch(1, vocab=cfg.vocab, seed=5)
    disp.fit_hot_set(np.concatenate([r.prompt for r in reqs]), head_size=8)
    rec = _RecordingDispatch(disp)
    reqs2 = _stream(cfg, n=6, seed=21)
    sched = ContinuousBatchingScheduler(cfg, mesh, params, slots=2,
                                        max_seq=MAX_SEQ, dispatch=rec)
    report = DecodeService(sched).run(reqs2)
    withd = {r.rid: list(r.tokens) for r in report.completed}
    assert withd == base, "enabling dispatch changed generated tokens"

    assert rec.trace, "dispatch never invoked"
    for shards, ex in rec.trace:
        toks = np.concatenate(shards).astype(np.int64)
        want = np.bincount(toks, minlength=cfg.vocab)
        got = np.zeros(cfg.vocab, np.int64)
        got[ex.head_ids.astype(np.int64)] += ex.head_counts.astype(np.int64)
        if len(ex.tail_ids):
            got[ex.tail_ids.astype(np.int64)] += \
                ex.tail_counts.astype(np.int64)
        assert ex.overflow == 0
        assert np.array_equal(got, want), "exchange != dense bincount"
        for t in toks[:4]:
            assert ex.count_of(int(t)) == want[t]
    assert report.plan_hit_rate is not None
    assert report.plan_hit_rate >= 0.5  # union cache warm after step 1


def test_expert_load_matches_predictor_oracle(mesh):
    from repro.serve.dispatch import (SparseServeDispatch, first_moe_router,
                                      make_expert_predictor)
    cfg = get_config("granite-moe-3b-a800m").reduced()
    params = T.init_params(cfg, tp=1, seed=0)
    router = first_moe_router(params)
    assert router is not None
    pred = make_expert_predictor(cfg)
    rng = np.random.RandomState(3)
    emb = params["emb"]
    ids = rng.randint(0, cfg.vocab, (10,))
    ek_shards = [np.asarray(pred(emb, router, jnp.asarray(ids)))]
    # single shard here (one host device); the 16-device subprocess case
    # exercises the 8-shard combine.
    disp = SparseServeDispatch(1, vocab=cfg.vocab, n_experts=cfg.n_experts,
                               seed=9)
    load = disp.expert_load(ek_shards)
    want = np.zeros(cfg.n_experts, np.float32)
    for ek in ek_shards:
        want += np.bincount(ek.reshape(-1),
                            minlength=cfg.n_experts).astype(np.float32)
    assert np.array_equal(load, want)
    assert load.sum() == sum(e.size for e in ek_shards)
    assert disp.plan_hit_rate == 1.0  # frozen plan only: no replanning


def test_dispatch_requires_hot_set_and_shard_agreement(qwen, mesh):
    from repro.serve.dispatch import SparseServeDispatch
    cfg, params = qwen
    disp = SparseServeDispatch(1, vocab=cfg.vocab, seed=5)
    with pytest.raises(RuntimeError, match="fit_hot_set"):
        disp.on_step([np.zeros(1, np.int32)])
    disp2 = SparseServeDispatch(2, vocab=cfg.vocab, seed=5)
    with pytest.raises(ValueError, match="shards"):
        ContinuousBatchingScheduler(cfg, mesh, params, slots=2,
                                    max_seq=MAX_SEQ, dispatch=disp2)


# ---------------------------------------------------------------------------
# Auditor pinned regression: ids-only host traffic in the decode loop
# ---------------------------------------------------------------------------

def test_audit_serve_decode_passes_fused_rejects_raw(qwen, mesh):
    from repro.analysis.auditor import audit_serve_decode
    from repro.train.step import (init_cache_global, make_decode_greedy_step,
                                  make_decode_step,
                                  make_prefill_greedy_step, mesh_ctx)
    cfg, params = qwen
    cache = init_cache_global(cfg, mesh_ctx(mesh), 2, MAX_SEQ)
    tok = jnp.zeros((2,), jnp.int32)
    pos = jnp.zeros((2,), jnp.int32)

    fused, _ = make_decode_greedy_step(cfg, mesh)
    assert audit_serve_decode("decode_greedy", fused, params, tok, pos,
                              cache, vocab=cfg.vocab).ok
    prefill, _ = make_prefill_greedy_step(cfg, mesh, MAX_SEQ)
    assert audit_serve_decode(
        "prefill_greedy", prefill, params,
        {"tokens": jnp.zeros((2, 6), jnp.int32)}, vocab=cfg.vocab).ok

    # injection: the raw decode step returns [B, V_pad] float logits —
    # serving on it would ship vocab-sized avals to host every step, and
    # the audit must refuse it on both checks.
    raw, _ = make_decode_step(cfg, mesh)
    rep = audit_serve_decode("decode_raw", raw, params, tok, pos, cache,
                             vocab=cfg.vocab)
    assert not rep.ok
    failed = {c.check_id for c in rep.failures()}
    assert "no_vocab_sized_float_output" in failed
    assert "token_ids_output_is_integer" in failed


def test_greedy_masks_padded_vocab_columns(qwen, mesh):
    """Padded logit columns are exactly 0 under tied embeddings and can
    beat all-negative real logits; the fused argmax must never pick one
    and never emit an id >= vocab."""
    cfg, params = qwen
    reqs = _stream(cfg, n=5, seed=33)
    sched = ContinuousBatchingScheduler(cfg, mesh, params, slots=2,
                                        max_seq=MAX_SEQ)
    report = DecodeService(sched).run(reqs)
    for r in report.completed:
        assert all(0 <= t < cfg.vocab for t in r.tokens), r.tokens


# ---------------------------------------------------------------------------
# 16 forced host devices: dp=8 x tp=2, sparse dispatch over 8 shards
# ---------------------------------------------------------------------------

_CODE16 = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.models import transformer as T
from repro.serve import ContinuousBatchingScheduler, DecodeService
from repro.serve import zipf_request_stream
from repro.serve.dispatch import SparseServeDispatch
from repro.serve.service import run_sequential_oracle

cfg = get_config("granite-moe-3b-a800m").reduced()
mesh = jax.make_mesh((8, 2), ("data", "model"))
params = T.init_params(cfg, tp=2, seed=0)
reqs = zipf_request_stream(10, cfg.vocab, prompt_lens=(4, 6),
                           max_new=(1, 5), arrival_rate=0.8, seed=4)
disp = SparseServeDispatch(8, vocab=cfg.vocab, n_experts=cfg.n_experts,
                           seed=11)
disp.fit_hot_set(np.concatenate([r.prompt for r in reqs]), head_size=16)
sched = ContinuousBatchingScheduler(cfg, mesh, params, slots=8,
                                    max_seq=16, dispatch=disp)
report = DecodeService(sched).run(reqs)
assert len(report.completed) == len(reqs)
batched = {r.rid: list(r.tokens) for r in report.completed}
sched.reset()
oracle = run_sequential_oracle(sched, reqs)
for i, r in enumerate(reqs):
    assert batched[r.rid] == oracle[i], (r.rid, batched[r.rid], oracle[i])
assert disp.steps > 0 and disp.plan_hit_rate > 0.0
ex = disp.last
total = float(ex.head_counts.sum() + ex.tail_counts.sum())
assert total > 0
from repro.serve.dispatch import first_moe_router, make_expert_predictor
rng = np.random.RandomState(2)
pred = make_expert_predictor(cfg)
router = first_moe_router(params)
eks = [np.asarray(pred(params["emb"], router,
                       jnp.asarray(rng.randint(0, cfg.vocab, (4,)))))
       for _ in range(8)]
load = disp.expert_load(eks)
want = sum(np.bincount(e.reshape(-1), minlength=cfg.n_experts)
           for e in eks).astype(np.float32)
assert np.array_equal(load, want), (load, want)
print("OK16", len(reqs), disp.steps, round(disp.plan_hit_rate, 3))
"""


@pytest.mark.slow
def test_serve_tier_16dev_sparse_dispatch():
    r = subprocess.run([sys.executable, "-c", _CODE16], env=_ENV16,
                       capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "OK16" in r.stdout
