"""HLO parsing units: collective classification, loop trip recovery, dots."""
import textwrap

from repro.launch.hlo_stats import (_shape_bytes, collective_stats, dot_flops,
                                    total_collective_bytes)


def test_shape_bytes():
    assert _shape_bytes("f32[16,128]") == 16 * 128 * 4
    assert _shape_bytes("bf16[8]{0}") == 16
    assert _shape_bytes("(f32[4,4], bf16[2,2])") == 64 + 8
    assert _shape_bytes("pred[10]") == 10


HLO = textwrap.dedent("""\
ENTRY %main (p0: f32[64]) -> f32[64] {
  %p0 = f32[64]{0} parameter(0)
  %ar = f32[64]{0} all-reduce(%p0), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = f32[64]{0} all-gather(%slice), replica_groups=[4,4]<=[16], dimensions={0}
  %rs = f32[16]{0} reduce-scatter(%ar), replica_groups={{0,1,2,3}}, to_apply=%add
  %a2a = (f32[16]{0}, f32[16]{0}, f32[16]{0}, f32[16]{0}) all-to-all(%x, %y, %z, %w), replica_groups={{0,1,2,3}}
  %cp = f32[64]{0} collective-permute(%p0), source_target_pairs={{0,1}}
}
""")


def test_collective_classification():
    st = collective_stats(HLO)
    assert st["all-reduce"]["bytes"] == 2 * 256 * 3 / 4
    assert st["all-gather"]["bytes"] == 256 * 3 / 4
    assert st["reduce-scatter"]["bytes"] == 64 * 3
    assert st["all-to-all"]["bytes"] == 4 * 64 * 3 / 4   # tuple summed
    assert st["collective-permute"]["bytes"] == 256
    assert total_collective_bytes(st) > 0


LOOP_HLO = textwrap.dedent("""\
%cond (s: (s32[], f32[64])) -> pred[] {
  %s = (s32[], f32[64]) parameter(0)
  %i = s32[] get-tuple-element(%s), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

%bodyfn (s: (s32[], f32[64])) -> (s32[], f32[64]) {
  %s = (s32[], f32[64]) parameter(0)
  %v = f32[64]{0} get-tuple-element(%s), index=1
  %ar = f32[64]{0} all-reduce(%v), replica_groups={{0,1}}, to_apply=%add
  %w = f32[8,8]{1,0} parameter(1)
  %d = f32[8,8]{1,0} dot(%w, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}

ENTRY %main (p0: (s32[], f32[64])) -> (s32[], f32[64]) {
  %p0 = (s32[], f32[64]) parameter(0)
  ROOT %wh = (s32[], f32[64]) while(%p0), condition=%cond, body=%bodyfn
}
""")


def test_loop_trip_multiplier():
    st = collective_stats(LOOP_HLO, default_trip=99)
    # trip recovered from the condition constant (7), not the default
    assert st["all-reduce"]["count"] == 7
    assert st["all-reduce"]["bytes"] == 7 * 2 * 256 * 1 / 2
    corrected, flat = dot_flops(LOOP_HLO, default_trip=99)
    assert flat == 2 * 8 * 8 * 8
    assert corrected == 7 * flat


def test_done_ops_not_double_counted():
    hlo = ("ENTRY %e (p: f32[8]) -> f32[8] {\n"
           "  %s = f32[8]{0} all-reduce-start(%p), replica_groups={{0,1}}\n"
           "  %d = f32[8]{0} all-reduce-done(%s)\n}\n")
    st = collective_stats(hlo)
    assert st["all-reduce"]["count"] == 1
