"""Message-level simulator vs dense oracle; replication; property tests."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.replication import (contribution_weights,
                                    expected_tolerated_failures,
                                    simulate_random_failures)
from repro.core.simulator import (DeadLogicalNode, SimSparseAllreduce,
                                  dense_oracle)
from repro.core.sparse_vec import HashPerm
from repro.core.topology import ButterflyPlan, ordered_factorizations


def _workload(rng, m, r=2000, alpha=1.5, max_n=120):
    """Power-law-ish out/in sets per node."""
    out_i, out_v, in_i = [], [], []
    for _ in range(m):
        n = rng.randint(5, max_n)
        # zipf-distributed indices (duplicates allowed: multiple updates)
        oi = (rng.zipf(alpha, n) % r).astype(np.uint32)
        out_i.append(oi)
        out_v.append(rng.randn(n))
        ni = rng.randint(5, max_n)
        in_i.append(rng.choice(r, ni, replace=False).astype(np.uint32))
    return out_i, out_v, in_i


@pytest.mark.parametrize("m,degs", [(8, (4, 2)), (8, (2, 2, 2)), (8, (8,)),
                                    (12, (3, 2, 2)), (16, (4, 4)),
                                    (6, (6,)), (6, (2, 3))])
def test_sim_matches_oracle(m, degs):
    rng = np.random.RandomState(m * 100 + len(degs))
    out_i, out_v, in_i = _workload(rng, m)
    sim = SimSparseAllreduce(ButterflyPlan(m, degs), perm=HashPerm.make(1))
    sim.config(out_i, in_i)
    got = sim.reduce(out_v)
    want = dense_oracle(out_i, out_v, in_i, sim.perm)
    for n in range(m):
        np.testing.assert_allclose(got[n], want[n], rtol=1e-9, atol=1e-12)


def test_config_once_reduce_many():
    """Paper property #2: one config, many reduces with fresh values."""
    rng = np.random.RandomState(0)
    out_i, out_v, in_i = _workload(rng, 8)
    sim = SimSparseAllreduce(ButterflyPlan(8, (4, 2)), perm=HashPerm.make(2))
    sim.config(out_i, in_i)
    for it in range(3):
        vals = [rng.randn(len(o)) for o in out_i]
        got = sim.reduce(vals)
        want = dense_oracle(out_i, vals, in_i, sim.perm)
        for n in range(8):
            np.testing.assert_allclose(got[n], want[n], rtol=1e-9)


@given(st.integers(0, 10_000),
       st.sampled_from([(m, d) for m in (4, 8, 12)
                        for d in ordered_factorizations(m)]),
       st.floats(1.1, 3.0))
@settings(max_examples=25, deadline=None)
def test_sim_oracle_property(seed, md, alpha):
    m, degs = md
    rng = np.random.RandomState(seed)
    out_i, out_v, in_i = _workload(rng, m, alpha=alpha, max_n=60)
    sim = SimSparseAllreduce(ButterflyPlan(m, degs),
                             perm=HashPerm.make(seed))
    sim.config(out_i, in_i)
    got = sim.reduce(out_v)
    want = dense_oracle(out_i, out_v, in_i, sim.perm)
    for n in range(m):
        np.testing.assert_allclose(got[n], want[n], rtol=1e-9, atol=1e-12)


def test_value_width():
    rng = np.random.RandomState(3)
    out_i, _, in_i = _workload(rng, 8)
    out_v = [rng.randn(len(o), 5) for o in out_i]
    sim = SimSparseAllreduce(ButterflyPlan(8, (4, 2)), perm=HashPerm.make(4),
                             value_width=5)
    sim.config(out_i, in_i)
    got = sim.reduce(out_v)
    want = dense_oracle(out_i, out_v, in_i, sim.perm, width=5)
    for n in range(8):
        np.testing.assert_allclose(got[n], want[n], rtol=1e-9)


@pytest.mark.parametrize("dead", [set(), {0}, {9}, {2, 11}, {0, 1, 2}])
def test_replication_tolerates_failures(dead):
    rng = np.random.RandomState(5)
    out_i, out_v, in_i = _workload(rng, 8)
    sim = SimSparseAllreduce(ButterflyPlan(8, (2, 4)), replication=2,
                             dead=dead, perm=HashPerm.make(5))
    sim.config(out_i, in_i)
    got = sim.reduce(out_v)
    want = dense_oracle(out_i, out_v, in_i, sim.perm)
    for n in range(8):
        np.testing.assert_allclose(got[n], want[n], rtol=1e-9)


def test_whole_replica_group_dead_raises():
    with pytest.raises(DeadLogicalNode):
        SimSparseAllreduce(ButterflyPlan(8, (4, 2)), replication=2,
                           dead={3, 11})


def test_replication_costs_more_but_not_rx(recwarn):
    """Table II: replication ~doubles traffic; runtime hit is moderate."""
    rng = np.random.RandomState(6)
    out_i, out_v, in_i = _workload(rng, 8)
    t = {}
    for r in (1, 2):
        sim = SimSparseAllreduce(ButterflyPlan(8, (4, 2)), replication=r,
                                 perm=HashPerm.make(6))
        sim.config(out_i, in_i)
        sim.reduce(out_v)
        t[r] = (sim.reduce_stats.reduce_time_s, sim.reduce_stats.total_bytes)
    assert t[2][1] == pytest.approx(2 * t[1][1])
    assert t[2][0] < 4 * t[1][0]


def test_birthday_bound():
    m = 64
    bound = expected_tolerated_failures(m, 2)
    assert 8 < bound < 13          # ~sqrt(pi*64/2) ~ 10
    p_ok = simulate_random_failures(m, 2, num_failures=int(bound), trials=400)
    assert 0.2 < p_ok < 0.8        # the bound is the ~50% point
    assert simulate_random_failures(m, 2, 1, trials=200) == 1.0


def test_contribution_weights():
    w = contribution_weights(8, 2, dead={1})
    assert w.sum() == 4 and w[5] == 1.0 and w[1] == 0.0
