"""Docs lint (grep-enforced, in the spirit of the PR 1 compat grep test):
code references in README / EXPERIMENTS / ARCHITECTURE must name real
files, modules and CLI flags, so the docs can't rot silently when code
moves.  Scope is deliberately narrow — repo-relative paths, dotted
``repro.*`` references, and ``--flag`` tokens; prose is untouched."""
import os
import re

import pytest

ROOT = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))
DOCS = ["README.md", "EXPERIMENTS.md", "ARCHITECTURE.md", "TUNING.md"]

PATH_RE = re.compile(
    r"\b(?:src|tests|benchmarks|examples)/[\w/.-]+\.(?:py|md|json|txt)\b")
MOD_RE = re.compile(r"\brepro(?:\.[A-Za-z_]\w*)+")
FLAG_RE = re.compile(r"(?<![\w-])--[a-z][a-z0-9_-]*\b")

# flags that are real but not argparse-declared in this repo
FLAG_ALLOW = {"--xla_force_host_platform_device_count"}


def _read(name: str) -> str:
    with open(os.path.join(ROOT, name)) as f:
        return f.read()


def _module_ref_ok(ref: str) -> bool:
    """Resolve ``repro.a.b[.attr]``: the longest dotted prefix must be a
    module file / package dir under src/, and the next component (if any)
    must appear as a word in that module (def/class/assignment/import —
    a plain grep keeps this robust to how the name is bound)."""
    parts = ref.split(".")
    for k in range(len(parts), 0, -1):
        base = os.path.join(ROOT, "src", *parts[:k])
        mod_file = None
        if os.path.isdir(base):
            if k == len(parts):
                return True
            mod_file = os.path.join(base, "__init__.py")
        elif os.path.isfile(base + ".py"):
            if k == len(parts):
                return True
            mod_file = base + ".py"
        if mod_file is not None:
            if not os.path.isfile(mod_file):
                return False
            return re.search(r"\b%s\b" % re.escape(parts[k]),
                             _read(os.path.relpath(mod_file, ROOT))) \
                is not None
    return False


def _declared_flags() -> set:
    flags = set()
    for top in ("src", "benchmarks"):
        for dirpath, _, files in os.walk(os.path.join(ROOT, top)):
            for f in files:
                if f.endswith(".py"):
                    text = _read(os.path.relpath(
                        os.path.join(dirpath, f), ROOT))
                    flags |= set(re.findall(
                        r"add_argument\(\s*[\"'](--[\w-]+)", text))
    return flags


@pytest.mark.parametrize("doc", DOCS)
def test_doc_exists(doc):
    assert os.path.isfile(os.path.join(ROOT, doc)), f"{doc} missing"


@pytest.mark.parametrize("doc", DOCS)
def test_doc_paths_exist(doc):
    bad = [p for p in sorted(set(PATH_RE.findall(_read(doc))))
           if not os.path.exists(os.path.join(ROOT, p))]
    assert not bad, f"{doc} references missing files: {bad}"


@pytest.mark.parametrize("doc", DOCS)
def test_doc_module_refs_resolve(doc):
    bad = [m for m in sorted(set(MOD_RE.findall(_read(doc))))
           if not _module_ref_ok(m)]
    assert not bad, f"{doc} references unresolvable modules/attrs: {bad}"


@pytest.mark.parametrize("doc", DOCS)
def test_doc_flags_are_declared(doc):
    declared = _declared_flags() | FLAG_ALLOW
    bad = [f for f in sorted(set(FLAG_RE.findall(_read(doc))))
           if f not in declared]
    assert not bad, f"{doc} references undeclared CLI flags: {bad}"


def test_readme_links_architecture():
    assert "ARCHITECTURE.md" in _read("README.md"), \
        "README must link the architecture doc"


def test_docs_link_tuning_book():
    """The tuning chapter is part of the docs book: README and
    ARCHITECTURE must link it."""
    assert "TUNING.md" in _read("README.md")
    assert "TUNING.md" in _read("ARCHITECTURE.md")


def test_tuning_doc_covers_cache_contract():
    """TUNING.md must document the pieces users actually need: the cache
    env var / default location, the --retune escape hatch, and the
    calibration + selection entry points."""
    text = _read("TUNING.md")
    for needle in ("REPRO_PLAN_CACHE", ".cache/repro/plans", "--retune",
                   "repro.core.autotune", "--dp-degrees"):
        assert needle in text, f"TUNING.md must mention {needle}"


def test_wire_flag_declared_and_documented():
    """The --wire knob is argparse-declared (so the flag lint accepts the
    docs' mentions of it) and the tuning/architecture chapters cover the
    wire formats: encode attach points, per-wire plan caching, and the
    degree re-ranking it exists for."""
    assert "--wire" in _declared_flags()
    for doc, needles in (
            ("TUNING.md", ("--wire", "delta+bf16", "re-rank")),
            ("ARCHITECTURE.md", ("--wire", "repro.kernels.wirecodec",
                                 "RA207"))):
        text = _read(doc)
        for needle in needles:
            assert needle in text, f"{doc} must mention {needle}"


def test_overlap_flag_declared_and_documented():
    """The overlap knobs are argparse-declared and the docs book covers
    the schedules: the bucketed stage-major sync + double-buffered engine
    section in ARCHITECTURE, and the overlap-aware re-ranking (with its
    direction-flip caveat and per-budget plan caching) in TUNING."""
    declared = _declared_flags()
    assert "--sync-overlap" in declared
    assert "--sync-bucket-kb" in declared
    for doc, needles in (
            ("ARCHITECTURE.md", ("Overlap & scheduling", "--sync-overlap",
                                 "plan_grad_buckets", "stage-major",
                                 "audit_overlap_sync", "reduce_up_on_device",
                                 "tests/test_overlap.py")),
            ("TUNING.md", ("overlap_compute_s", "overlap_bucket",
                           "rate_optimal_s", "--sync-overlap",
                           "modeled_overlap_time"))):
        text = _read(doc)
        for needle in needles:
            assert needle in text, f"{doc} must mention {needle}"


def test_train_help_mentions_auto_and_engine():
    """The launcher's user-facing text must match reality: --dp-degrees
    documents the calibrated+cached 'auto' default (not the stale 'single
    round-robin stage'), --retune exists, and the module docstring points
    iterative graph workloads at the engine entry point."""
    text = _read("src/repro/launch/train.py")
    assert "repro.core.topology.tune" in text
    assert "repro.core.autotune" in text
    assert "repro.graph.engine" in text
    assert "default: single round-robin stage" not in text
    assert '"--retune"' in text
    assert "TUNING.md" in text
    for needle in ("calibrat", "cache"):
        assert needle in text, f"--dp-degrees help must mention {needle}"

def test_serve_flags_declared_and_documented():
    """The serving-tier knobs are argparse-declared (so the flag lint
    accepts the docs' mentions) and the docs book covers the tier: the
    dataflow + consistency-oracle section in ARCHITECTURE, the quickstart
    in README, and the bench → figure row in EXPERIMENTS."""
    declared = _declared_flags()
    for flag in ("--slots", "--rate", "--burst", "--queue-cap",
                 "--slo-steps", "--breach-window", "--cooldown-steps",
                 "--sparse-dispatch", "--head-size"):
        assert flag in declared, f"{flag} not argparse-declared"
    for doc, needles in (
            ("ARCHITECTURE.md", ("Serving tier", "--sparse-dispatch",
                                 "audit_serve_decode", "shape_bucket",
                                 "tests/test_serve_tier.py",
                                 "tests/test_admission.py",
                                 "repro.serve.scheduler",
                                 "repro.serve.dispatch")),
            ("README.md", ("repro.serve", "--sparse-dispatch",
                           "tests/test_serve_tier.py")),
            ("EXPERIMENTS.md", ("benchmarks/bench_serve.py",
                                "BENCH_pr10.json", "plan-cache hit rate"))):
        text = _read(doc)
        for needle in needles:
            assert needle in text, f"{doc} must mention {needle}"


# The public-docstring ast lint moved onto the rule engine: RA401 in
# repro.analysis.rules, enforced repo-wide by tests/test_analysis.py.
