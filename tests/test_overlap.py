"""Overlap-schedule bit-exactness harness (ARCHITECTURE.md "Overlap &
scheduling").

Proves the async paths safe in three tiers:

* single-process property tests (always on): the bucket partition is an
  order-preserving exact cover under its byte bound for *every* input,
  the overlap cost model degrades exactly to the bulk-synchronous one,
  the rate-optimal bound / rate-fraction algebra holds, and the knob
  validation fires before any mesh work.
* ``@pytest.mark.slow`` subprocess tests (default tier-1): bitwise
  parity of the bucketed hier gradient sync and of the double-buffered
  graph engine on 8 forced host devices, plus the jaxpr auditor's
  positive fixtures *and* injection tests — a hidden full-tree ``psum``
  smuggled into the overlapped program, or a rotation the engine never
  performed, must make the audit fail (the checks have teeth).
* ``@pytest.mark.overlap`` sweep (excluded from default runs via
  pyproject ``addopts``; run standalone with ``pytest -m overlap``): the
  16-device degree x merge x replication x wire parity cross, sparse-sync
  combos (minutes of XLA compile each — that cost is why the marker
  exists), and full-train-step composition.

Bitwise assertions use dyadic-lattice gradients (``randint/64``) so
every sum is exactly representable: equality then isolates the
*schedule* — any reordering bug shows up as a wrong bit, never as
tolerable float noise.
"""
import math
import os
import subprocess
import sys

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.netmodel import (EC2_2013, TPU_ICI, Fabric,
                                 rate_fraction, rate_optimal_allreduce_s)
from repro.core.topology import ButterflyPlan
from repro.train.step import plan_grad_buckets

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")
_ENV8 = dict(os.environ,
             XLA_FLAGS="--xla_force_host_platform_device_count=8",
             PYTHONPATH=_SRC + os.pathsep + os.environ.get("PYTHONPATH", ""))
_ENV16 = dict(_ENV8, XLA_FLAGS="--xla_force_host_platform_device_count=16")


def _run(code: str, env=_ENV8):
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


# ---------------------------------------------------------------------------
# Bucket partition properties (single process)
# ---------------------------------------------------------------------------

@given(st.lists(st.integers(min_value=0, max_value=5000),
                min_size=0, max_size=40),
       st.integers(min_value=1, max_value=4000))
@settings(max_examples=60, deadline=None)
def test_buckets_exact_cover_and_byte_bound(sizes, bucket_bytes):
    buckets = plan_grad_buckets(sizes, bucket_bytes)
    # order-preserving exact cover: concatenation is range(len(sizes))
    flat = [i for b in buckets for i in b]
    assert flat == list(range(len(sizes)))
    assert all(b for b in buckets)
    for b in buckets:
        nbytes = sum(sizes[i] * 4 for i in b)
        # byte bound, except a single oversized leaf in its own bucket
        assert nbytes <= bucket_bytes or len(b) == 1


@given(st.lists(st.integers(min_value=0, max_value=5000),
                min_size=1, max_size=20),
       st.integers(min_value=1, max_value=4000),
       st.integers(min_value=0, max_value=10 ** 9))
@settings(max_examples=40, deadline=None)
def test_buckets_cover_under_permutation(sizes, bucket_bytes, seed):
    """The exact-cover + bound contract holds for every leaf order (the
    sync order is reverse-backward, not sorted — nothing may rely on
    monotone sizes)."""
    import numpy as np
    perm = np.random.RandomState(seed).permutation(len(sizes))
    shuffled = [sizes[p] for p in perm]
    buckets = plan_grad_buckets(shuffled, bucket_bytes)
    assert [i for b in buckets for i in b] == list(range(len(shuffled)))
    for b in buckets:
        assert sum(shuffled[i] * 4 for i in b) <= bucket_bytes or len(b) == 1


def test_buckets_deterministic_cases():
    # greedy contiguous fill: 3 x 40-byte leaves under an 80-byte budget
    assert plan_grad_buckets([10, 10, 10], 80) == [[0, 1], [2]]
    # exact fit is allowed (strict > comparison), crossing it splits
    assert plan_grad_buckets([10, 10], 80) == [[0, 1]]
    assert plan_grad_buckets([10, 11], 80) == [[0], [1]]
    # an oversized leaf gets a bucket of its own, neighbours unaffected
    assert plan_grad_buckets([2, 100, 2], 16) == [[0], [1], [2]]
    # zero-size leaves ride along without opening buckets
    assert plan_grad_buckets([0, 0, 4], 16) == [[0, 1, 2]]
    assert plan_grad_buckets([], 16) == []


def test_buckets_validation():
    with pytest.raises(ValueError, match="bucket_bytes"):
        plan_grad_buckets([1], 0)
    with pytest.raises(ValueError, match="bucket_bytes"):
        plan_grad_buckets([1], -4)
    with pytest.raises(ValueError, match="bytes_per_elem"):
        plan_grad_buckets([1], 64, bytes_per_elem=0)
    with pytest.raises(ValueError, match="leaf size"):
        plan_grad_buckets([4, -1], 64)


def test_sync_overlap_knob_validation():
    """The settings check fires before any mesh/plan work — None stands
    in for cfg/mesh and must never be touched."""
    from repro.train.step import make_sync_fn, make_train_step
    with pytest.raises(ValueError, match="ring sync is a single psum"):
        make_train_step(None, None, sync="ring", sync_overlap="bucketed")
    with pytest.raises(ValueError, match="ring sync is a single psum"):
        make_sync_fn(None, None, sync="ring", sync_overlap="bucketed")
    with pytest.raises(ValueError, match="sync_overlap must be one of"):
        make_train_step(None, None, sync="hier", sync_overlap="eager")
    with pytest.raises(ValueError, match="only applies to the sparse"):
        make_train_step(None, None, sync="hier", sync_wire="delta",
                        sync_overlap="bucketed")


# ---------------------------------------------------------------------------
# Overlap cost model (single process)
# ---------------------------------------------------------------------------

_FABRICS = [EC2_2013, TPU_ICI,
            Fabric(name="floored", beta_bytes_per_s=1e9, alpha_s=1e-4,
                   floor_bytes=4096.0, gamma_s=2e-5)]


@given(st.floats(min_value=0.0, max_value=1e9),
       st.integers(min_value=0, max_value=16),
       st.booleans(), st.sampled_from(_FABRICS))
@settings(max_examples=60, deadline=None)
def test_stage_split_sums_to_stage_time(nbytes, fanout, serial, fabric):
    lat, bw = fabric.stage_split(nbytes, fanout, serial=serial)
    assert lat >= 0.0 and bw >= 0.0
    assert math.isclose(lat + bw, fabric.stage_time(nbytes, fanout,
                                                    serial=serial),
                        rel_tol=1e-12, abs_tol=1e-18)


@given(st.sampled_from([(4,), (2, 2), (4, 2), (16, 4), (2, 2, 2)]),
       st.floats(min_value=1.0, max_value=1e6),
       st.sampled_from(_FABRICS), st.booleans(),
       st.floats(min_value=0.0, max_value=10.0))
@settings(max_examples=60, deadline=None)
def test_overlap_model_degrades_to_sync(degrees, n0, fabric, serial, hidden):
    """t_ov = serial + max(bw, hidden): equals the bulk-synchronous model
    at hidden=0, is monotone in hidden, and is bracketed by
    [max(t_sync_parts, hidden), t_sync + hidden]."""
    plan = ButterflyPlan(int(math.prod(degrees)), degrees)
    t_sync = plan.modeled_time(n0, 10.0 * n0, fabric, serial_nic=serial)
    t0 = plan.modeled_overlap_time(n0, 10.0 * n0, fabric, serial_nic=serial,
                                   hidden_compute_s=0.0)
    th = plan.modeled_overlap_time(n0, 10.0 * n0, fabric, serial_nic=serial,
                                   hidden_compute_s=hidden)
    assert math.isclose(t0, t_sync, rel_tol=1e-9, abs_tol=1e-15)
    assert th >= t0 - 1e-15 and th >= hidden
    assert th <= t_sync + hidden + 1e-12
    th2 = plan.modeled_overlap_time(n0, 10.0 * n0, fabric, serial_nic=serial,
                                    hidden_compute_s=2.0 * hidden)
    assert th2 >= th - 1e-15


@given(st.floats(min_value=0.0, max_value=1e9),
       st.integers(min_value=1, max_value=1024),
       st.sampled_from(_FABRICS))
@settings(max_examples=60, deadline=None)
def test_rate_bound_properties(nbytes, m, fabric):
    opt = rate_optimal_allreduce_s(nbytes, m, fabric)
    if m == 1:
        assert opt == 0.0
        return
    # latency floor + bandwidth term, monotone in payload
    assert opt >= 2.0 * math.ceil(math.log2(m)) * fabric.alpha_s
    assert rate_optimal_allreduce_s(2.0 * nbytes, m, fabric) >= opt
    # the fraction of the bound itself is exactly 1; degenerate guard
    if opt > 0.0:
        assert math.isclose(rate_fraction(opt, nbytes, m, fabric), 1.0,
                            rel_tol=1e-12)
    assert rate_fraction(0.0, nbytes, m, fabric) == 0.0


def test_select_plan_reports_rate_position():
    import warnings
    from repro.core.autotune import select_plan
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        sync = select_plan(64, 1e5, 1e6, EC2_2013)
        ov = select_plan(64, 1e5, 1e6, EC2_2013,
                         overlap_compute_s=0.5)
    for rep in (sync, ov):
        assert rep.rate_optimal_s is not None and rep.rate_optimal_s > 0.0
        assert math.isclose(rep.rate_fraction,
                            rep.rate_optimal_s / rep.modeled_s,
                            rel_tol=1e-12)
        assert 0.0 < rep.rate_fraction <= 1.0 + 1e-9
    assert sync.overlap_compute_s is None
    assert ov.overlap_compute_s == 0.5
    # hiding bandwidth can only help the makespan beyond the hidden work
    assert ov.modeled_s <= sync.modeled_s + 0.5 + 1e-9
    assert ov.modeled_s >= 0.5


def test_plan_cache_key_overlap_compat():
    """overlap_compute_s=0 must leave every pre-existing digest unchanged;
    nonzero values key separately (an overlap-reranked plan is not a valid
    bulk-synchronous answer)."""
    from repro.core.autotune import plan_cache_key
    base = dict(mesh=[("data", 8)], nnz=1e4, index_range=1e5, merge="sort",
                replication=1, width=1, fabric=EC2_2013)
    k0 = plan_cache_key(**base)
    k0b = plan_cache_key(**base, overlap_compute_s=0.0)
    kov = plan_cache_key(**base, overlap_compute_s=1e-3)
    assert k0 == k0b and "overlap_bucket" not in k0
    assert "overlap_bucket" in kov and kov != k0


# ---------------------------------------------------------------------------
# Auditor fixtures + injection (subprocess, trace-only: fast)
# ---------------------------------------------------------------------------

AUDIT_SYNC_CODE = r"""
import jax, jax.numpy as jnp, numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.analysis.auditor import audit_overlap_sync
from repro.core.allreduce import (dense_allreduce_hierarchical,
                                  dense_allreduce_hierarchical_bucketed,
                                  make_device_plan)

plan = make_device_plan([("d", 8)], {"d": (4, 2)}, 8, 8)
mesh = jax.make_mesh((8,), ("d",))
sizes = (64, 32, 96)

def mk(schedule):
    def body(*xs):
        xs = [x.reshape(x.shape[1:]) for x in xs]
        if schedule == "stage_major":
            outs = dense_allreduce_hierarchical_bucketed(xs, plan)
        elif schedule == "sequential":
            outs = [dense_allreduce_hierarchical(x, plan) for x in xs]
        elif schedule == "injected_psum":
            # the attack the audit must catch: correct butterfly plus a
            # hidden full-tree reduction patching the result
            outs = dense_allreduce_hierarchical_bucketed(xs, plan)
            fix = lax.psum(outs[0].sum(), "d")
            outs = [outs[0] + 0.0 * fix] + outs[1:]
        return tuple(o[None] for o in outs)
    return shard_map(body, mesh=mesh, in_specs=(P("d"),) * len(sizes),
                     out_specs=(P("d"),) * len(sizes), check_vma=False)

args = tuple(jnp.zeros((8, n), jnp.float32) for n in sizes)
dep = plan.logical.depth

rep = audit_overlap_sync("bucketed", mk("stage_major"), mk("sequential"),
                         *args, depth=dep, n_buckets=3)
assert rep.ok, [str(c) for c in rep.failures()]

rep = audit_overlap_sync("hidden-psum", mk("injected_psum"),
                         mk("sequential"), *args, depth=dep, n_buckets=3)
assert not rep.ok
assert "same_total_collectives" in [c.check_id for c in rep.failures()], \
    [c.check_id for c in rep.failures()]

rep = audit_overlap_sync("bucket-major", mk("sequential"), mk("sequential"),
                         *args, depth=dep, n_buckets=3)
assert not rep.ok
failed = [c.check_id for c in rep.failures()]
assert "stage_major_interleaving" in failed, failed
assert "same_total_collectives" not in failed, failed

rep = audit_overlap_sync("wrong-buckets", mk("stage_major"),
                         mk("sequential"), *args, depth=dep, n_buckets=2)
assert not rep.ok
print("AUDIT_SYNC_OK")
"""


AUDIT_ENGINE_CODE = r"""
import jax, numpy as np
from repro.analysis.auditor import audit_engine
from repro.data.pipeline import powerlaw_graph
from repro.graph.engine import GraphEngine
from repro.graph.pagerank import build_partitions, make_pagerank_engine

edges = powerlaw_graph(300, 1200, seed=1)
parts = build_partitions(edges, 300, 8)
mesh = jax.make_mesh((8,), ("d",))
engine, extras, p0 = make_pagerank_engine(parts, 300, degrees=(4, 2),
                                          mesh=mesh)
ov = GraphEngine([np.asarray(o) for o in engine.out_sets],
                 [np.asarray(i) for i in engine.in_sets],
                 engine.app, degrees=(4, 2), mesh=mesh, overlap=True)

rep = audit_engine(ov, 5, p0, extras)
assert rep.ok, [str(c) for c in rep.failures()]
assert "overlap=True" in rep.target

# k=1 has nothing to rotate: the synchronous contract must apply
rep = audit_engine(ov, 1, p0, extras)
assert rep.ok, [str(c) for c in rep.failures()]
assert "overlap=False" in rep.target

# injection: claim a rotation the program never performed -- pin the
# synchronous build in the run-fn cache FIRST (flipping the flag before
# tracing would genuinely switch schedules), then audit: the auditor
# expects depth collectives before the scan and must fail on 0
engine.run_fn(5, "last")
engine.overlap = True
rep = audit_engine(engine, 5, p0, extras)
assert not rep.ok
assert "prologue_epilogue_split" in [c.check_id for c in rep.failures()], \
    [c.check_id for c in rep.failures()]

# inverse injection: deny the rotation of a genuinely overlapped program
# (its k=5 build is already cached from the positive audit above)
ov.overlap = False
rep = audit_engine(ov, 5, p0, extras)
assert not rep.ok
assert "no_collectives_outside_scan" in [c.check_id for c in rep.failures()], \
    [c.check_id for c in rep.failures()]
print("AUDIT_ENGINE_OK")
"""


@pytest.mark.slow
def test_audit_overlap_sync_fixtures_and_injection():
    assert "AUDIT_SYNC_OK" in _run(AUDIT_SYNC_CODE)


@pytest.mark.slow
def test_audit_engine_overlap_and_injection():
    assert "AUDIT_ENGINE_OK" in _run(AUDIT_ENGINE_CODE)


# ---------------------------------------------------------------------------
# Bitwise parity: bucketed hier sync (subprocess, 8 devices)
# ---------------------------------------------------------------------------

_SYNC_PRELUDE = r"""
import dataclasses, numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.models import transformer as T
from repro.train.step import make_sync_fn

cfg = dataclasses.replace(
    get_config("qwen1.5-0.5b").reduced(d_model=64, d_ff=128, vocab=256,
                                       n_heads=2, n_kv=1, head_dim=32),
    tie_embeddings=False)

def dyadic_grads(params, seed):
    rng = np.random.RandomState(seed)
    return jax.tree.map(
        lambda p: jnp.asarray(
            rng.randint(-128, 129, p.shape).astype(np.float32) / 64
        ).astype(p.dtype), params)

def check_pair(mesh, tp, seed, **kw):
    params = T.init_params(cfg, tp, seed=0)
    grads = dyadic_grads(params, seed)
    rng = np.random.RandomState(seed + 1)
    dp = mesh.shape["data"]
    tokens = jnp.asarray(rng.randint(0, cfg.vocab, (2 * dp, 16)), jnp.int32)
    outs = {}
    for overlap in ("off", "bucketed"):
        fn, _ = make_sync_fn(cfg, mesh, sync_overlap=overlap,
                             sync_bucket_bytes=48 << 10, **kw)
        outs[overlap] = jax.jit(fn)(grads, tokens)
    a, ovf_a = jax.tree.map(np.asarray, outs["off"])
    b, ovf_b = jax.tree.map(np.asarray, outs["bucketed"])
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert all(np.array_equal(x, y) for x, y in zip(la, lb)), kw
    assert int(np.asarray(ovf_a)) == 0 and int(np.asarray(ovf_b)) == 0, kw
    return b
"""


HIER_PARITY_CODE = _SYNC_PRELUDE + r"""
mesh = jax.make_mesh((4, 2), ("data", "model"))
check_pair(mesh, 2, 7, sync="hier", dp_degrees={"data": (2, 2)})
check_pair(mesh, 2, 11, sync="hier", dp_degrees={"data": (4,)},
           replication=2)

# degenerate bucket budgets: everything in one bucket / one leaf per
# bucket must still be bitwise (schedule changes, math never does)
params = T.init_params(cfg, 2, seed=0)
grads = dyadic_grads(params, 3)
tokens = jnp.zeros((8, 16), jnp.int32)
ref = None
for bb in (1, 48 << 10, 1 << 30):
    fn, _ = make_sync_fn(cfg, mesh, sync="hier",
                         dp_degrees={"data": (2, 2)},
                         sync_overlap="bucketed", sync_bucket_bytes=bb)
    out, ovf = jax.jit(fn)(grads, tokens)
    leaves = [np.asarray(l) for l in jax.tree.leaves(out)]
    assert int(np.asarray(ovf)) == 0
    if ref is None:
        ref = leaves
    else:
        assert all(np.array_equal(x, y) for x, y in zip(ref, leaves)), bb
print("HIER_PARITY_OK")
"""


@pytest.mark.slow
def test_sync_parity_hier_bucketed():
    assert "HIER_PARITY_OK" in _run(HIER_PARITY_CODE)


# ---------------------------------------------------------------------------
# Bitwise parity: double-buffered engine (subprocess, 8 devices)
# ---------------------------------------------------------------------------

ENGINE_PARITY_CODE = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.data.pipeline import powerlaw_graph
from repro.graph.engine import EngineApp, GraphEngine
from repro.graph.pagerank import build_partitions, make_pagerank_engine

mesh = jax.make_mesh((8,), ("d",))

def leaves(x):
    return [np.asarray(l) for l in jax.tree.leaves(x)]

def run_pair(mk_engine, state, extras, k, collect="last"):
    res = []
    for overlap in (False, True):
        eng = mk_engine(overlap)
        final, last_out, traj = eng.run(k, state, extras, collect=collect)
        res.append((leaves(final) + leaves(last_out)
                    + (leaves(traj) if collect == "trajectory" else [])))
        rep = eng.sync_report()
        assert rep["overlap"] is overlap
        assert eng.report["dispatches"] == 1 and eng.report["rounds"] == k
    return res

# dyadic app: gather + halving update keeps every value on the binary
# lattice, so overlap-vs-sync equality must hold to the last bit at ANY k
# (including k=2, where the scan shrinks to length 1 and XLA fuses most
# aggressively)
rng = np.random.RandomState(5)
M, R = 8, 4096
out_idx = [rng.choice(R, rng.randint(5, 16), replace=False).astype(np.uint32)
           for _ in range(M)]
in_idx = [rng.choice(R, rng.randint(5, 16), replace=False).astype(np.uint32)
          for _ in range(M)]

def mk_dyadic(overlap):
    app = EngineApp(
        out_fn=lambda s, e: s[e["sel"]],
        update_fn=lambda s, inr, e, ax: 0.5 * s + inr,
        name="dyadic")
    return GraphEngine(out_idx, in_idx, app, degrees=(4, 2), mesh=mesh,
                       overlap=overlap)

probe = mk_dyadic(False)
sel = rng.randint(0, probe.uin_cap, (M, probe.u_cap)).astype(np.int32)
state = (rng.randint(-128, 129, (M, probe.uin_cap))
         .astype(np.float32) / 64)
extras = {"sel": jnp.asarray(sel)}
for k in (1, 2, 3, 6):
    a, b = run_pair(mk_dyadic, jnp.asarray(state), extras, k)
    assert all(np.array_equal(x, y) for x, y in zip(a, b)), k
a, b = run_pair(mk_dyadic, jnp.asarray(state), extras, 4,
                collect="trajectory")
assert all(np.array_equal(x, y) for x, y in zip(a, b)), "trajectory"
print("DYADIC_OK")

# PageRank (non-dyadic 1/deg weights): the schedule itself is a pure
# reordering (the dyadic app above proves it to the last bit), but the
# rotated program gives XLA different fusion opportunities around the
# ELL matvec, whose reassociated sums of non-representable values drift
# by an ulp -- so the non-lattice contract is tight allclose, not
# equality
edges = powerlaw_graph(300, 1200, seed=1)
parts = build_partitions(edges, 300, 8)

def mk_pr(overlap):
    eng, extras, p0 = make_pagerank_engine(parts, 300, degrees=(4, 2),
                                           mesh=mesh)
    if overlap:
        eng = GraphEngine([np.asarray(o) for o in eng.out_sets],
                          [np.asarray(i) for i in eng.in_sets],
                          eng.app, degrees=(4, 2), mesh=mesh, overlap=True)
    mk_pr.extras, mk_pr.p0 = extras, p0
    return eng

mk_pr(False)
for k in (2, 3, 6):
    a, b = run_pair(mk_pr, mk_pr.p0, mk_pr.extras, k)
    for x, y in zip(a, b):
        np.testing.assert_allclose(x, y, rtol=1e-6, atol=1e-12)
print("ENGINE_PARITY_OK")
"""


@pytest.mark.slow
def test_engine_overlap_parity():
    out = _run(ENGINE_PARITY_CODE)
    assert "DYADIC_OK" in out and "ENGINE_PARITY_OK" in out


# ---------------------------------------------------------------------------
# The full sweep (pytest -m overlap; excluded from default runs --
# sparse-mode XLA compiles run minutes per combination)
# ---------------------------------------------------------------------------

SWEEP_HIER_16_CODE = _SYNC_PRELUDE + r"""
mesh = jax.make_mesh((8, 2), ("data", "model"))
for degs in [(4, 2), (2, 2, 2), (8,)]:
    for r in (1, 2):
        check_pair(mesh, 2, 13 + r, sync="hier", dp_degrees={"data": degs},
                   replication=r)
        print("hier", degs, "r", r, "ok", flush=True)
print("SWEEP_HIER_16_OK")
"""

SWEEP_SPARSE_SORT_CODE = _SYNC_PRELUDE + r"""
mesh = jax.make_mesh((4, 2), ("data", "model"))
check_pair(mesh, 2, 17, sync="sparse", dp_degrees={"data": (2, 2)},
           sync_merge="sort", sync_wire="raw", sparse_tokens_hint=32)
print("SWEEP_SPARSE_SORT_OK")
"""

SWEEP_SPARSE_FUSED_CODE = _SYNC_PRELUDE + r"""
mesh = jax.make_mesh((4, 2), ("data", "model"))
check_pair(mesh, 2, 19, sync="sparse", dp_degrees={"data": (4,)},
           sync_merge="fused", sync_wire="delta", replication=2,
           sparse_tokens_hint=32)
print("SWEEP_SPARSE_FUSED_OK")
"""

SWEEP_SPARSE_BANDED_CODE = _SYNC_PRELUDE + r"""
mesh = jax.make_mesh((4, 2), ("data", "model"))
check_pair(mesh, 2, 23, sync="sparse", dp_degrees={"data": (2, 2)},
           sync_merge="banded", sync_wire="delta", sparse_tokens_hint=32)
print("SWEEP_SPARSE_BANDED_OK")
"""

TRAIN_STEP_CODE = r"""
import dataclasses, numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.models import transformer as T
from repro.optim.adamw import AdamW
from repro.train.step import make_train_step

cfg = dataclasses.replace(
    get_config("qwen1.5-0.5b").reduced(d_model=64, d_ff=128, vocab=256,
                                       n_heads=2, n_kv=1, head_dim=32),
    tie_embeddings=False)
mesh = jax.make_mesh((4, 2), ("data", "model"))
rng = np.random.RandomState(0)
batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab, (8, 32)), jnp.int32),
         "labels": jnp.asarray(rng.randint(0, cfg.vocab, (8, 32)), jnp.int32)}
outs = {}
for overlap in ("off", "bucketed"):
    step, _ = make_train_step(cfg, mesh, sync="hier",
                              dp_degrees={"data": (2, 2)},
                              opt=AdamW(lr=1e-3), sync_overlap=overlap,
                              sync_bucket_bytes=48 << 10)
    params = T.init_params(cfg, 2, seed=0)
    opt_state = AdamW().init(params)
    for _ in range(2):
        params, opt_state, m = step(params, opt_state, batch)
    outs[overlap] = (params, float(m["loss"]))
pa, la = outs["off"]
pb, lb = outs["bucketed"]
# end-to-end the two step programs differ outside the sync too (XLA may
# fuse the backward differently around the rescheduled collectives), so
# composition is checked to tight tolerance, not bitwise -- the bitwise
# claim is the sync-only harness's
assert np.isclose(la, lb, rtol=1e-5), (la, lb)
for x, y in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
    np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                               rtol=2e-5, atol=1e-7)
print("TRAIN_STEP_OK")
"""


@pytest.mark.overlap
@pytest.mark.slow
def test_sweep_hier_degrees_16dev():
    assert "SWEEP_HIER_16_OK" in _run(SWEEP_HIER_16_CODE, env=_ENV16)


@pytest.mark.overlap
@pytest.mark.slow
def test_sweep_sparse_sort_raw():
    assert "SWEEP_SPARSE_SORT_OK" in _run(SWEEP_SPARSE_SORT_CODE)


@pytest.mark.overlap
@pytest.mark.slow
def test_sweep_sparse_fused_delta_replicated():
    assert "SWEEP_SPARSE_FUSED_OK" in _run(SWEEP_SPARSE_FUSED_CODE)


@pytest.mark.overlap
@pytest.mark.slow
def test_sweep_sparse_banded_delta():
    assert "SWEEP_SPARSE_BANDED_OK" in _run(SWEEP_SPARSE_BANDED_CODE)


@pytest.mark.overlap
@pytest.mark.slow
def test_train_step_composition():
    assert "TRAIN_STEP_OK" in _run(TRAIN_STEP_CODE)
