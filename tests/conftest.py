"""Suite-wide configuration.

The property tests depend on `hypothesis` (declared in requirements-dev.txt
and the pyproject `[test]` extra).  When the real package is importable it
is used untouched; in hermetic environments without it, the deterministic
shim vendored under tests/_vendor is placed on sys.path instead so the
whole suite still collects and runs.
"""
import os
import sys
import tempfile

try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "_vendor"))

# Isolate the autotuner's persistent plan cache (repro.core.autotune) from
# the developer's real ~/.cache/repro/plans: device-backed tests write
# frozen-plan artifacts on every config, and cross-run reuse of those is a
# behavior under test, not a side effect to leak.  Subprocess tests
# inherit the env, so the whole suite shares one throwaway root; tests
# that exercise the cache explicitly pin their own tmp_path over this.
if "REPRO_PLAN_CACHE" not in os.environ:
    os.environ["REPRO_PLAN_CACHE"] = tempfile.mkdtemp(
        prefix="repro-test-plan-cache-")
