"""Suite-wide configuration.

The property tests depend on `hypothesis` (declared in requirements-dev.txt
and the pyproject `[test]` extra).  When the real package is importable it
is used untouched; in hermetic environments without it, the deterministic
shim vendored under tests/_vendor is placed on sys.path instead so the
whole suite still collects and runs.
"""
import os
import sys

try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "_vendor"))
