"""Wire-format tests (paper §IV bytes-on-wire): codec round trips, exact
encoded-byte accounting, the packet-floor boundary contract, the corrected
calibration byte formula, and the device parity sweep — ``wire="delta"``
bit-identical to ``"raw"`` across degrees x merge modes x replication,
lossy modes within bounded error (subprocess: 16 forced host devices)."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.netmodel import EC2_2013, Fabric
from repro.core.topology import ButterflyPlan, check_wire, wire_entry_bytes
from repro.kernels.wirecodec import (LOSSY_WIRE, encoded_payload_bytes,
                                     index_words)

_ENV = dict(os.environ,
            XLA_FLAGS="--xla_force_host_platform_device_count=16",
            PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src")
            + os.pathsep + os.environ.get("PYTHONPATH", ""))


def _run(code: str):
    r = subprocess.run([sys.executable, "-c", code], env=_ENV,
                       capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


# ---------------------------------------------------------------------------
# Codec round trips (host-side, no mesh)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("width", [1, 3, 7, 13, 28, 31, 32])
def test_pack_unpack_roundtrip_with_sentinels(width):
    """Bit-packed offsets survive the round trip exactly at every width,
    including interleaved SENTINEL padding (the all-ones marker)."""
    import jax.numpy as jnp

    from repro.kernels import wirecodec as wc
    rng = np.random.RandomState(width)
    r, cap = 4, 37
    base = rng.randint(0, 2 ** 31, size=r).astype(np.uint32)
    span = (1 << width) - 1                     # marker value is reserved
    offs = rng.randint(0, max(span, 1), size=(r, cap)).astype(np.uint64)
    idx = (base[:, None].astype(np.uint64) + offs).astype(np.uint32)
    idx.sort(axis=1)
    mask = rng.rand(r, cap) < 0.3
    idx = np.where(mask, np.uint32(0xFFFFFFFF), idx)
    words = wc.pack_indices(jnp.asarray(idx), jnp.asarray(base), width)
    assert words.dtype == jnp.uint32
    assert words.shape == (r, index_words(cap, width))
    out = wc.unpack_indices(words, jnp.asarray(base), cap, width)
    np.testing.assert_array_equal(np.asarray(out), idx)


def test_quant8_roundtrip_bounded_and_zero_safe():
    """Per-row int8 quantization: relative error <= 1/254 per row max, and
    all-zero rows survive (scale clamp, no NaN/inf)."""
    import jax.numpy as jnp

    from repro.kernels import wirecodec as wc
    rng = np.random.RandomState(0)
    val = rng.randn(5, 33).astype(np.float32) * 100.0
    val[3] = 0.0
    q, s = wc.quant8_rows(jnp.asarray(val))
    assert q.dtype == jnp.int8 and s.shape == (5,)
    back = np.asarray(wc.dequant8_rows(q, s))
    assert np.isfinite(back).all()
    np.testing.assert_array_equal(back[3], 0.0)
    amax = np.abs(val).max(axis=1, keepdims=True)
    assert (np.abs(back - val) <= amax / 254.0 + 1e-7).all()


def test_encoded_payload_bytes_exact():
    """The byte accounting is exact: index words + value stream + the
    int8ef scale word; ``raw`` ships whole uint32/f32 words."""
    cap, bits = 100, 13
    words = -(-(cap * bits) // 32)
    assert index_words(cap, bits) == words
    assert encoded_payload_bytes("raw", cap, bits) == cap * 8
    assert encoded_payload_bytes("delta", cap, bits) == 4 * words + cap * 4
    assert encoded_payload_bytes("delta+bf16", cap, bits) == \
        4 * words + cap * 2
    assert encoded_payload_bytes("delta+int8ef", cap, bits) == \
        4 * words + cap * 1 + 4
    # vector values: W value lanes per entry, raw keeps index cost fixed
    assert encoded_payload_bytes("raw", cap, bits, width=4) == cap * 20
    assert encoded_payload_bytes("delta+bf16", cap, bits, width=4) == \
        4 * words + cap * 2 * 4
    with pytest.raises(ValueError):
        encoded_payload_bytes("gzip", cap, bits)


def test_wire_entry_bytes_model_matches_codec():
    """The model-side per-entry pricing agrees with the exact codec bytes
    in the large-cap limit (packing quantization amortizes away)."""
    cap = 1 << 16
    for wire in ("raw", "delta", "delta+bf16", "delta+int8ef"):
        for bits in (9, 13, 21):
            exact = encoded_payload_bytes(wire, cap, bits) / cap
            model = wire_entry_bytes(wire, bits)
            assert abs(exact - model) < 0.01, (wire, bits)
    assert check_wire("raw") == "raw"
    assert set(LOSSY_WIRE) == {"delta+bf16", "delta+int8ef"}


def test_index_bits_per_layer_shrinks_with_depth():
    """Modeled offset widths lose log2(k) bits per layer — the reason the
    delta stream compresses harder as the butterfly narrows."""
    bits = ButterflyPlan(64, (16, 2, 2)).index_bits_per_layer()
    assert bits == [29, 28, 27]    # span 2^28, +1 reserves the marker


# ---------------------------------------------------------------------------
# Packet floor: applied exactly once, to post-encoding bytes (satellite 2)
# ---------------------------------------------------------------------------

def test_msg_time_floor_boundary():
    """``msg_time`` is flat below ``floor_bytes`` and strictly increasing
    above it; the boundary sample costs exactly the floor."""
    f = Fabric("floor", beta_bytes_per_s=1e9, alpha_s=1e-3,
               floor_bytes=4096.0)
    at = f.msg_time(4096.0)
    assert f.msg_time(4095.0) == at == f.msg_time(0.0)
    assert f.msg_time(4097.0) > at
    assert at == pytest.approx(1e-3 + 4096.0 / 1e9)
    # applied once: stage_time must not re-floor (serial = fanout * one)
    assert f.stage_time(4095.0, 3) == pytest.approx(3 * f.msg_time(4095.0, 3))


def test_floor_prices_encoded_bytes():
    """Compression can push a payload under the floor: the modeled stage
    then stops paying bandwidth for the saved bytes (floor applied to the
    *encoded* size, not the raw one)."""
    from repro.kernels.costmodel import wire_bytes_report
    cap, bits = 1024, 13
    enc = encoded_payload_bytes("delta+bf16", cap, bits)
    raw = encoded_payload_bytes("raw", cap, bits)
    f = Fabric("floor", beta_bytes_per_s=1e9, alpha_s=1e-3,
               floor_bytes=float(enc + 1))
    rep = wire_bytes_report(cap, bits, wire="delta+bf16", fabric=f)
    assert rep["floor_bound"] is True
    assert rep["msg_time_s"] == pytest.approx(f.msg_time(enc))
    assert rep["raw_msg_time_s"] == pytest.approx(f.msg_time(raw))
    assert f.msg_time(enc) < f.msg_time(raw)


# ---------------------------------------------------------------------------
# Calibration byte accounting (satellite 1 regression, subprocess mesh)
# ---------------------------------------------------------------------------

CALIB_BYTES_CODE = r"""
import numpy as np
from repro.core.autotune import (STAGE_IDX_DTYPE, STAGE_VAL_DTYPE,
                                 measure_stage_samples)

assert STAGE_IDX_DTYPE.itemsize == 4 and STAGE_VAL_DTYPE.itemsize == 4
samples = measure_stage_samples(payload_entries=(256, 1024), repeats=2)
assert samples
for s in samples:
    entries = s.nbytes / (STAGE_IDX_DTYPE.itemsize + STAGE_VAL_DTYPE.itemsize)
    assert entries in (256.0, 1024.0), (s.nbytes, entries)
print("CALIB_BYTES_OK", sorted({s.nbytes for s in samples}))
"""


@pytest.mark.slow
def test_measure_stage_samples_prices_index_and_value_stream():
    """Regression: each staged exchange ships a uint32 index row AND an
    fp32 value row — nbytes must be entries * 8, not the old fp32-only
    entries * 4 (which under-counted every calibration fit 2x)."""
    out = _run(CALIB_BYTES_CODE)
    assert "CALIB_BYTES_OK [2048.0, 8192.0]" in out


# ---------------------------------------------------------------------------
# Device parity sweep (satellite 4): delta == raw bit-identically,
# lossy modes bounded, across degrees x merge x replication
# ---------------------------------------------------------------------------

WIRE_PARITY_CODE = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core.api import SparseAllreduce
from repro.core.sparse_vec import HashPerm

merge = "%(merge)s"
DEVS = np.array(jax.devices())
def mesh_of(n):
    return jax.sharding.Mesh(DEVS[:n], ("nodes",))

C = 24
for degs in [(4,), (2, 2), (4, 2)]:
    M = int(np.prod(degs))
    rng = np.random.RandomState(M)
    perm = HashPerm.make(M)
    idx = np.full((M, C), 0xFFFFFFFF, np.uint32)
    val = np.zeros((M, C), np.float32)
    for n in range(M):
        raw = rng.choice(400, rng.randint(8, C),
                         replace=False).astype(np.uint32)
        # dyadic-lattice values: fp32 sums are order-independent, so the
        # delta wire can demand bit identity
        v = (rng.randint(-128, 129, len(raw)) / 64.0).astype(np.float32)
        h = perm.fwd_np(raw); o = np.argsort(h)
        idx[n, :len(raw)] = h[o]; val[n, :len(raw)] = v[o]
    base = SparseAllreduce(M, degs, backend="device", mesh=mesh_of(M),
                           seed=M, merge=merge)
    bi, bv, bovf = (np.asarray(x) for x in
                    base.union_reduce(idx, val, out_capacity=M * C))
    assert bovf.sum() == 0
    ref_amax = max(float(np.abs(bv[bi != 0xFFFFFFFF]).max()), 1e-9)
    for r in (1, 2):
        for wire in ("delta", "delta+bf16", "delta+int8ef"):
            ar = SparseAllreduce(M, degs, backend="device", replication=r,
                                 mesh=mesh_of(M * r), seed=M, merge=merge,
                                 wire=wire)
            oi, ov, ovf = (np.asarray(x) for x in
                           ar.union_reduce(idx, val, out_capacity=M * C))
            assert ovf.sum() == 0, (degs, r, wire)
            np.testing.assert_array_equal(oi, bi,
                                          err_msg=f"{degs} r={r} {wire}")
            if wire == "delta":
                np.testing.assert_array_equal(ov, bv,
                                              err_msg=f"{degs} r={r}")
            else:
                err = float(np.abs(ov - bv).max()) / ref_amax
                assert err < 0.05, (degs, r, wire, err)
print("WIRE_PARITY_OK_" + merge)
"""


@pytest.mark.slow
@pytest.mark.parametrize("merge", ["sort", "fused", "banded"])
def test_union_wire_parity(merge):
    """``wire="delta"`` is bit-identical to ``"raw"`` (indices and values)
    across degrees x replication for every merge mode; the lossy modes
    agree on indices and keep max-abs value error under 5%% of the union's
    max magnitude (fixed seeds)."""
    assert ("WIRE_PARITY_OK_" + merge) in _run(
        WIRE_PARITY_CODE % {"merge": merge})


# ---------------------------------------------------------------------------
# API guards
# ---------------------------------------------------------------------------

def test_bad_wire_rejected_and_lossy_gated():
    from repro.core.api import SparseAllreduce
    with pytest.raises(ValueError, match="wire"):
        SparseAllreduce(4, (4,), backend="sim", wire="zstd")
    with pytest.raises(NotImplementedError):
        SparseAllreduce(4, (4,), backend="sim", wire="delta+bf16")


def test_train_step_wire_requires_sparse_sync():
    """Non-raw sync_wire only applies to the sparse gradient sync — dense
    ring/hier paths never encode, so asking is an error, not a no-op
    (guards fire before any mesh work)."""
    from repro.train.step import make_train_step
    with pytest.raises(ValueError, match="sparse"):
        make_train_step(None, None, sync="ring", sync_wire="delta")
    with pytest.raises(ValueError, match="wire"):
        make_train_step(None, None, sync="sparse", sync_wire="zstd")
