"""Butterfly topology: mixed-radix structure, packet model, tuner."""
import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.netmodel import EC2_2013, TPU_ICI
from repro.core.topology import (SPACE, ButterflyPlan, binary_plan,
                                 ordered_factorizations, roundrobin_plan,
                                 tune)


def factorization_strategy():
    return st.sampled_from(
        [(m, degs) for m in (4, 8, 12, 16, 24, 64)
         for degs in ordered_factorizations(m)])


@given(factorization_strategy())
@settings(max_examples=60, deadline=None)
def test_groups_partition_and_ranges_nest(md):
    m, degs = md
    plan = ButterflyPlan(m, degs)
    for l in range(plan.depth):
        groups = plan.axis_index_groups(l)
        flat = sorted(x for g in groups for x in g)
        assert flat == list(range(m))               # partition of nodes
        for g in groups:
            assert len(g) == plan.degrees[l]
    # final ranges tile the space in node order
    finals = [plan.range_at(n, plan.depth) for n in range(m)]
    assert finals[0][0] == 0 and finals[-1][1] == SPACE
    for a, b in zip(finals, finals[1:]):
        assert a[1] == b[0]
    # each node's range nests down the layers
    for n in range(m):
        prev = (0, SPACE)
        for l in range(plan.depth + 1):
            lo, hi = plan.range_at(n, l)
            assert prev[0] <= lo and hi <= prev[1]
            prev = (lo, hi)


def test_group_member_ranges_are_the_split():
    plan = ButterflyPlan(12, (3, 4))
    for n in range(12):
        for l in range(2):
            edges = plan.edges_at(n, l)
            members = plan.group_members(n, l)
            for t, mem in enumerate(members):
                lo, hi = plan.range_at(mem, l + 1)
                assert lo == edges[t] and hi == edges[t + 1]


def test_degenerate_plans():
    assert roundrobin_plan(8).degrees == (8,)
    assert binary_plan(8).degrees == (2, 2, 2)
    with pytest.raises(ValueError):
        binary_plan(12)
    with pytest.raises(ValueError):
        ButterflyPlan(8, (3, 3))


def test_packet_model_compression_monotone():
    """Fig 5: packet sizes decay with depth (index collisions compress)."""
    plan = ButterflyPlan(64, (2,) * 6)
    counts = plan.expected_counts(12.1e6, 60e6)
    assert all(a >= b for a, b in zip(counts, counts[1:]))
    pkts = plan.packet_bytes(12.1e6, 60e6)
    assert all(a >= b for a, b in zip(pkts, pkts[1:]))


def test_tuner_reproduces_paper_fig6():
    """Twitter graph @64 nodes: hybrid (16x4-family) beats round-robin and
    binary butterfly; web graph: round-robin competitive (paper SVI-B)."""
    t = {str(p): p.modeled_time(12.1e6, 60e6)
         for p in [ButterflyPlan(64, d)
                   for d in [(16, 4), (64,), (2,) * 6, (8, 8)]]}
    assert t["16x4"] < t["64"]
    assert t["16x4"] < t["2x2x2x2x2x2"]
    best = tune(64, 12.1e6, 60e6)
    assert 2 <= len(best.degrees) <= 4          # heterogeneous hybrid wins
    assert best.degrees[0] >= best.degrees[-1]  # degree decreases with depth
    # yahoo: bigger data => round-robin closer to optimal
    ty = {str(p): p.modeled_time(48e6, 1.6e9)
          for p in [ButterflyPlan(64, d) for d in [(16, 4), (64,), (2,) * 6]]}
    assert ty["64"] < ty["2x2x2x2x2x2"]


def test_tuner_tpu_fabric_prefers_fewer_layers_for_big_payloads():
    best = tune(16, 1e7, 1e8, fabric=TPU_ICI, serial_nic=False)
    assert math.prod(best.degrees) == 16


@given(st.sampled_from([4, 8, 16, 32, 64]))
@settings(max_examples=10, deadline=None)
def test_ordered_factorizations_complete(m):
    facs = ordered_factorizations(m)
    assert all(math.prod(f) == m for f in facs)
    assert len(set(facs)) == len(facs)
    assert (m,) in facs
