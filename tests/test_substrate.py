"""Optimizer / data pipeline / checkpoint substrates."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import load, load_flat, save
from repro.data.pipeline import Batcher, powerlaw_graph, zipf_tokens
from repro.optim.adamw import AdamW


def test_adamw_converges_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray(np.random.RandomState(0).randn(8))}
    target = jnp.arange(8.0)
    state = opt.init(params)
    for _ in range(300):
        g = {"w": 2 * (params["w"] - target)}
        params, state, _ = opt.update(g, state, params)
    np.testing.assert_allclose(np.asarray(params["w"]), np.arange(8.0),
                               atol=1e-2)


def test_adamw_grad_clip():
    opt = AdamW(lr=0.1, grad_clip=1.0)
    params = {"w": jnp.zeros(4)}
    state = opt.init(params)
    _, _, gnorm = opt.update({"w": jnp.full((4,), 100.0)}, state, params)
    assert float(gnorm) == pytest.approx(200.0)


def test_zipf_tokens_power_law():
    rng = np.random.RandomState(0)
    toks = zipf_tokens(rng, (50_000,), vocab=1000, alpha=1.5)
    assert toks.min() >= 0 and toks.max() < 1000
    counts = np.sort(np.bincount(toks, minlength=1000))[::-1]
    # heavy head: top-1% of types covers a large share of tokens
    assert counts[:10].sum() / counts.sum() > 0.3
    # deterministic
    toks2 = zipf_tokens(np.random.RandomState(0), (50_000,), 1000, 1.5)
    np.testing.assert_array_equal(toks, toks2)


def test_batcher_shapes_and_shift():
    it = iter(Batcher(vocab=100, batch=4, seq=16, seed=1))
    x, y = next(it)
    assert x.shape == (4, 16) and y.shape == (4, 16)
    np.testing.assert_array_equal(x[:, 1:], y[:, :-1])


def test_powerlaw_graph_degree_tail():
    edges = powerlaw_graph(5000, 50000, alpha=2.0, seed=0)
    deg = np.bincount(edges[:, 1], minlength=5000)
    assert deg.max() > 30 * max(np.median(deg), 1)   # heavy tail


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": np.arange(6).reshape(2, 3).astype(np.float32),
            "b": {"c": np.ones(4), "d": (np.zeros(2), np.full(3, 7.0))}}
    path = str(tmp_path / "ckpt.npz")
    save(path, tree, meta={"step": 3})
    back = load(path, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(a, b)
    # sidecar sits next to the extension-less base (same name whether the
    # caller passed "ckpt" or "ckpt.npz"), so load_flat can find it back
    assert os.path.exists(str(tmp_path / "ckpt.meta.json"))
    _, meta = load_flat(path)
    assert meta == {"step": 3}
