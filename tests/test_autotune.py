"""Calibrated autotuner (repro.core.autotune): fabric fit recovery, plan
selection structure, persistent plan cache, zero-retrace config hits.

Device-backend pieces run in subprocesses with forced host devices (the
main pytest process stays single-device, same pattern as
test_device_allreduce.py); everything else is host numpy.
"""
import json
import math
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import autotune
from repro.core.autotune import (PlanCache, StageSample, fit_error,
                                 fit_fabric, plan_cache_key, resolve_degrees,
                                 select_plan, synth_stage_samples)
from repro.core.netmodel import EC2_2013, TPU_ICI, Fabric
from repro.core.topology import (ButterflyPlan, num_prime_factors,
                                 ordered_factorizations, tune)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.fixture
def cache(tmp_path):
    return PlanCache(root=str(tmp_path / "plans"))


# ---------------------------------------------------------------------------
# Calibration fit
# ---------------------------------------------------------------------------

GT = Fabric("gt", beta_bytes_per_s=2e8, alpha_s=5e-3, gamma_s=2e-4)


@pytest.mark.parametrize("serial", [True, False])
def test_fit_recovers_synthetic_fabric(serial):
    samples = synth_stage_samples(GT, [1e4, 1e5, 1e6, 4e6], [1, 3, 7, 15],
                                  serial=serial)
    fit = fit_fabric(samples, serial=serial)
    assert abs(fit.alpha_s - GT.alpha_s) / GT.alpha_s < 1e-6
    assert abs(fit.beta_bytes_per_s - GT.beta_bytes_per_s) \
        / GT.beta_bytes_per_s < 1e-6
    assert abs(fit.gamma_s - GT.gamma_s) / GT.gamma_s < 1e-6
    assert fit_error(fit, samples, serial=serial) < 1e-9


def test_fit_recovers_zero_congestion():
    flat = Fabric("flat", beta_bytes_per_s=1e9, alpha_s=1e-3)
    fit = fit_fabric(synth_stage_samples(flat, [1e4, 1e6], [1, 3, 7]))
    assert fit.gamma_s < 1e-9 * flat.alpha_s + 1e-12
    assert abs(fit.alpha_s - flat.alpha_s) / flat.alpha_s < 1e-6


def test_fit_with_noise_stays_close():
    samples = synth_stage_samples(GT, [1e4, 1e5, 1e6, 4e6],
                                  [1, 3, 7, 15, 31], noise=0.03, seed=3)
    fit = fit_fabric(samples)
    assert abs(fit.alpha_s - GT.alpha_s) / GT.alpha_s < 0.25
    assert abs(fit.beta_bytes_per_s - GT.beta_bytes_per_s) \
        / GT.beta_bytes_per_s < 0.25
    # and the fitted model explains the noisy data to ~noise level
    assert fit_error(fit, samples) < 0.1


def test_fit_requires_three_samples():
    with pytest.raises(ValueError):
        fit_fabric([StageSample(1e4, 1, 1e-3)])


def test_fit_degenerate_sweeps():
    """Single payload size -> beta unidentifiable (ValueError); single
    fanout (prime device count) -> alpha/gamma collinear, so gamma is
    pinned to 0 with a warning instead of an arbitrary lstsq split."""
    with pytest.raises(ValueError, match="payload"):
        fit_fabric(synth_stage_samples(GT, [1e5], [1, 3, 7]))
    one_fanout = synth_stage_samples(GT, [1e4, 1e5, 1e6], [2])
    with pytest.warns(UserWarning, match="one fanout"):
        fit = fit_fabric(one_fanout)
    assert fit.gamma_s == 0.0
    # alpha absorbs the (unidentifiable) congestion of the lone fanout
    assert abs(fit.alpha_s - (GT.alpha_s + GT.gamma_s)) \
        / GT.alpha_s < 1e-6
    assert abs(fit.beta_bytes_per_s - GT.beta_bytes_per_s) \
        / GT.beta_bytes_per_s < 1e-6


def test_fabric_congestion_term_backward_compatible():
    """gamma_s=0 reproduces the original alpha-beta-floor stage cost
    exactly; gamma_s>0 adds a superlinear-in-fanout congestion penalty."""
    f0 = Fabric("f0", beta_bytes_per_s=1e9, alpha_s=1e-3)
    for b, k in [(1e3, 1), (1e6, 7), (4e6, 63)]:
        assert f0.stage_time(b, k) == pytest.approx(
            k * (f0.alpha_s + b / f0.beta_bytes_per_s))
    fg = Fabric("fg", beta_bytes_per_s=1e9, alpha_s=1e-3, gamma_s=1e-4)
    # congestion grows the *per-message* time linearly in extra peers, so
    # the serial stage cost picks up a quadratic fanout term
    assert fg.stage_time(1e3, 8) - f0.stage_time(1e3, 8) == \
        pytest.approx(8 * 7 * fg.gamma_s)
    assert fg.msg_time(1e3, fanout=4) > fg.msg_time(1e3, fanout=1)


# ---------------------------------------------------------------------------
# Selection
# ---------------------------------------------------------------------------

def test_select_plan_powerlaw_nonincreasing_degrees():
    """Paper §IV structure: on the power-law (twitter-scale) sparsity
    curve the winner is a valid factorization with degree non-increasing
    in depth, under both the nominal and a calibrated (gamma>0) fabric."""
    for fabric in (EC2_2013, GT):
        for m in (16, 64, 256):
            rep = select_plan(m, 12.1e6, 60e6, fabric)
            assert math.prod(rep.plan.degrees) == m
            assert rep.plan.degrees == tuple(
                sorted(rep.plan.degrees, reverse=True))
            assert rep.decreasing
            assert rep.fallback in (None, "depth-extended")
            assert rep.candidates[0][1] == rep.plan.degrees


def test_calibrated_tuned_beats_best_fixed_homogeneous():
    """Acceptance: on >= 2 mesh shapes the calibrated-tuned heterogeneous
    degrees beat the best fixed homogeneous-degree plan (k, ..., k) under
    the calibrated model (bench_autotune reports the same numbers)."""
    fit = fit_fabric(synth_stage_samples(
        Fabric("gt-ec2", beta_bytes_per_s=EC2_2013.beta_bytes_per_s,
               alpha_s=EC2_2013.alpha_s, gamma_s=2e-4),
        [1e4, 1e5, 1e6, 4e6], [1, 3, 7, 15, 31]))
    for m in (64, 256):
        rep = select_plan(m, 12.1e6, 60e6, fit)
        homog = [d for d in ordered_factorizations(m, num_prime_factors(m))
                 if len(set(d)) == 1]
        best_h = min(ButterflyPlan(m, d).modeled_time(12.1e6, 60e6, fit)
                     for d in homog)
        assert len(set(rep.plan.degrees)) > 1      # actually heterogeneous
        assert rep.modeled_s < best_h


def test_select_plan_confirm_reranks_by_measurement():
    """Timed-trial confirmation overrides the model ranking."""
    rep0 = select_plan(64, 12.1e6, 60e6, top_k=3)
    target = rep0.candidates[1][1]      # model's second choice

    def confirm(plan):
        return 0.1 if plan.degrees == target else 1.0

    rep = select_plan(64, 12.1e6, 60e6, top_k=3, confirm=confirm)
    assert rep.plan.degrees == target
    assert rep.measured_s is not None and len(rep.measured_s) == 3


def test_tune_prime_falls_back_with_warning():
    with pytest.warns(UserWarning, match="prime"):
        plan = tune(7, 1e5, 1e6)
    assert plan.degrees == (7,)
    with pytest.warns(UserWarning, match="prime"):
        rep = select_plan(13, 1e5, 1e6)
    assert rep.fallback == "prime" and rep.plan.degrees == (13,)


def test_tune_lifts_truncating_max_depth():
    """Omega(128)=7 > default cap 6: the sweep is extended (warned), so
    the full binary butterfly still competes instead of being silently
    dropped."""
    assert (2,) * 7 not in ordered_factorizations(128)          # the cap
    assert (2,) * 7 in ordered_factorizations(128, 7)
    assert num_prime_factors(128) == 7
    with pytest.warns(UserWarning, match="truncate"):
        scored = tune(128, 1e5, 1e6, top=10_000)
    assert any(p.degrees == (2,) * 7 for _, p in scored)


# ---------------------------------------------------------------------------
# Persistent plan cache
# ---------------------------------------------------------------------------

def test_resolve_degrees_cache_roundtrip(cache):
    kw = dict(n0=12.1e6, total_range=60e6, fabric=GT, cache=cache)
    d1, src1 = resolve_degrees(64, **kw)
    d2, src2 = resolve_degrees(64, **kw)
    assert src1 == "tuned" and src2 == "cache" and d1 == d2
    assert cache.stats["stores"] == 1 and cache.stats["hits"] == 1
    # the artifact is a checkpoint/store.py entry with inspectable meta
    key = plan_cache_key(mesh=(("nodes", 64),), nnz=12.1e6,
                         index_range=60e6, merge="sort", replication=1,
                         width=1, fabric=GT)
    with open(cache.path(key) + ".meta.json") as f:
        meta = json.load(f)
    assert tuple(meta["degrees"]) == d1
    assert meta["decreasing"] is True
    assert meta["key"]["fabric"]["gamma_s"] == GT.gamma_s
    # retune bypasses the read and overwrites
    d3, src3 = resolve_degrees(64, retune=True, **kw)
    assert src3 == "tuned" and d3 == d1
    assert cache.stats["stores"] == 2


def test_cache_key_boundaries(cache):
    """Every key field is an invalidation boundary; nnz quantizes to
    half-log2 buckets so ~equal workloads share a plan."""
    base = dict(mesh=(("nodes", 64),), nnz=1e5, index_range=1e6,
                merge="sort", replication=1, width=1, fabric=EC2_2013,
                serial_nic=True)
    k0 = plan_cache_key(**base)
    assert plan_cache_key(**{**base, "nnz": 1.05e5}) == k0    # same bucket
    for change in ({"nnz": 4e5}, {"merge": "banded"}, {"replication": 2},
                   {"width": 4}, {"fabric": GT}, {"serial_nic": False},
                   {"mesh": (("data", 64),)}, {"wire": "delta"},
                   {"wire": "delta+bf16"}):
        assert plan_cache_key(**{**base, **change}) != k0
    # wire enters the key only when non-default: raw digests are stable
    assert plan_cache_key(**{**base, "wire": "raw"}) == k0
    assert "wire" not in plan_cache_key(**base)
    assert plan_cache_key(**{**base, "wire": "delta"}) != \
        plan_cache_key(**{**base, "wire": "delta+bf16"})


def test_cache_keyed_per_wire_no_stale_hit(cache):
    """A raw-tuned plan must never be served for a compressed wire format:
    the byte models differ, so each wire tunes (and caches) separately."""
    kw = dict(n0=12.1e6, total_range=60e6, fabric=GT, cache=cache)
    d_raw, src_raw = resolve_degrees(64, **kw)
    assert src_raw == "tuned"
    d_bf16, src_bf16 = resolve_degrees(64, wire="delta+bf16", **kw)
    assert src_bf16 == "tuned"          # cache miss, not a stale raw hit
    assert cache.stats["stores"] == 2
    # both entries hit independently on re-resolution
    assert resolve_degrees(64, **kw) == (d_raw, "cache")
    assert resolve_degrees(64, wire="delta+bf16", **kw) == (d_bf16, "cache")


def test_resolve_degrees_rejects_bad_mesh_sig(cache):
    with pytest.raises(ValueError, match="mesh_sig"):
        resolve_degrees(64, n0=1e5, total_range=1e6,
                        mesh_sig=(("nodes", 32),), cache=cache)


def test_corrupt_cache_entry_degrades_to_retune(cache):
    kw = dict(n0=1e5, total_range=1e6, cache=cache)
    d1, _ = resolve_degrees(16, **kw)
    key = plan_cache_key(mesh=(("nodes", 16),), nnz=1e5, index_range=1e6,
                         merge="sort", replication=1, width=1,
                         fabric=EC2_2013)
    with open(cache.path(key) + ".meta.json", "w") as f:
        f.write("{ not json")
    d2, src2 = resolve_degrees(16, **kw)
    assert d2 == d1 and src2 == "tuned"
    assert cache.stats["errors"] >= 1


def test_fabric_calibration_roundtrip(cache):
    assert autotune.calibrated_fabric(backend="cpu", num_devices=8,
                                      cache=cache, default=TPU_ICI) is TPU_ICI
    autotune.store_calibrated_fabric(GT, backend="cpu", num_devices=8,
                                     cache=cache, residual=0.02)
    back = autotune.calibrated_fabric(backend="cpu", num_devices=8,
                                      cache=cache)
    assert back == GT


def test_planned_artifact_roundtrip():
    """Frozen routing tensors survive serialize->deserialize byte-exactly
    (host-side; the device parity across a restart is the subprocess test
    below)."""
    from repro.core.allreduce import make_device_plan
    from repro.core.planned import plan_sparse_allreduce
    rng = np.random.RandomState(0)
    m, degrees = 8, (4, 2)
    outs = [np.unique(rng.choice(4000, 500).astype(np.uint32))
            for _ in range(m)]
    ins = [np.unique(rng.choice(4000, 300).astype(np.uint32))
           for _ in range(m)]
    dplan = make_device_plan([("nodes", m)], {"nodes": degrees},
                             in_capacity=max(len(o) for o in outs),
                             out_capacity=sum(len(o) for o in outs))
    planned = plan_sparse_allreduce(dplan, outs, ins)
    arrays, meta = autotune.planned_to_artifact(planned)
    rebuilt = autotune.planned_from_artifact(arrays, meta,
                                             {"nodes": degrees})
    assert rebuilt.sorted_size == planned.sorted_size
    assert rebuilt.in_user_len == planned.in_user_len
    assert rebuilt.perm == planned.perm
    np.testing.assert_array_equal(rebuilt.user_scatter, planned.user_scatter)
    np.testing.assert_array_equal(rebuilt.user_gather, planned.user_gather)
    np.testing.assert_array_equal(rebuilt.bottom_hit, planned.bottom_hit)
    assert len(rebuilt.layers) == len(planned.layers)
    for a, b in zip(rebuilt.layers, planned.layers):
        np.testing.assert_array_equal(a.send_gather, b.send_gather)
        np.testing.assert_array_equal(a.merge_scatter, b.merge_scatter)
        np.testing.assert_array_equal(a.up_send_gather, b.up_send_gather)
        np.testing.assert_array_equal(a.up_recv_scatter, b.up_recv_scatter)
        assert (a.merged_size, a.up_size) == (b.merged_size, b.up_size)
    assert rebuilt.dplan.stages[0].axis_index_groups == \
        planned.dplan.stages[0].axis_index_groups


def test_plan_memo_is_lru_bounded(monkeypatch):
    """The in-process frozen-plan memo cannot grow without bound; hits
    refresh recency."""
    autotune.clear_plan_memo()
    monkeypatch.setattr(autotune, "PLANNED_MEMO_MAX", 3)
    try:
        for i in range(4):
            autotune.memo_store(f"fp{i}", (i,))
        assert autotune.memo_lookup("fp0") is None      # evicted (oldest)
        assert autotune.memo_lookup("fp1") == (1,)      # refreshed
        autotune.memo_store("fp4", (4,))                # evicts fp2 now
        assert autotune.memo_lookup("fp2") is None
        assert autotune.memo_lookup("fp1") == (1,)
        assert len(autotune._PLANNED_MEMO) == 3
    finally:
        autotune.clear_plan_memo()


def test_stats_meta_roundtrip():
    from repro.core.simulator import ReduceStats, StageStats
    st = ReduceStats(config_time_s=1.5, reduce_time_s=0.25, overflow=3,
                     stages=[StageStats(layer=0, phase="down",
                                        max_msg_bytes=10.0, total_bytes=99.0,
                                        num_messages=7, time_s=0.5)])
    back = autotune.stats_from_meta(autotune.stats_to_meta(st))
    assert back == st and back.total_bytes == st.total_bytes


def test_tuned_dp_degrees_uses_cache(tmp_path, monkeypatch):
    """make_train_step(dp_degrees="auto") resolves through the persistent
    cache: the second resolution must not re-run the sweep."""
    import types

    from repro.train.step import tuned_dp_degrees
    monkeypatch.setenv(autotune.CACHE_ENV, str(tmp_path / "plans"))
    mc = types.SimpleNamespace(
        dp_axes=("data",),
        mesh=types.SimpleNamespace(shape={"data": 8}))
    d1 = tuned_dp_degrees(mc, 1024, 4096)
    calls = []
    real = autotune.select_plan
    monkeypatch.setattr(autotune, "select_plan",
                        lambda *a, **k: calls.append(a) or real(*a, **k))
    d2 = tuned_dp_degrees(mc, 1024, 4096)
    assert d2 == d1 and not calls          # pure cache hit
    d3 = tuned_dp_degrees(mc, 1024, 4096, retune=True)
    assert d3 == d1 and len(calls) == 1    # escape hatch re-tunes


# ---------------------------------------------------------------------------
# Cross-process cache hits + zero-retrace regression (subprocess, devices)
# ---------------------------------------------------------------------------

def _env(tmp_path, devices=8):
    return dict(
        os.environ,
        XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
        REPRO_PLAN_CACHE=str(tmp_path / "plans"),
        PYTHONPATH=SRC + os.pathsep + os.environ.get("PYTHONPATH", ""))


def _run(code, env):
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_resolve_degrees_hits_across_subprocess_restart(tmp_path,
                                                        monkeypatch):
    """A plan tuned in another process is a cache hit here: the sweep is
    not re-run (select_plan is stubbed to explode)."""
    out = _run(
        "from repro.core.autotune import resolve_degrees\n"
        "print(resolve_degrees(64, n0=12.1e6, total_range=60e6))\n",
        _env(tmp_path, devices=1))
    assert "tuned" in out
    monkeypatch.setenv(autotune.CACHE_ENV, str(tmp_path / "plans"))
    monkeypatch.setattr(
        autotune, "select_plan",
        lambda *a, **k: (_ for _ in ()).throw(AssertionError("re-tuned")))
    degrees, src = resolve_degrees(64, n0=12.1e6, total_range=60e6)
    assert src == "cache" and math.prod(degrees) == 64
    assert f"{degrees}" in out             # same plan both processes


def test_raw_tuned_plan_not_served_for_compressed_wire(tmp_path,
                                                       monkeypatch):
    """Stale-hit regression across a restart: a plan tuned under
    ``wire="raw"`` in another process is NOT a cache hit for
    ``wire="delta+bf16"`` — the encoded byte model re-tunes."""
    out = _run(
        "from repro.core.autotune import resolve_degrees\n"
        "print(resolve_degrees(64, n0=12.1e6, total_range=60e6))\n",
        _env(tmp_path, devices=1))
    assert "tuned" in out
    monkeypatch.setenv(autotune.CACHE_ENV, str(tmp_path / "plans"))
    degrees, src = resolve_degrees(64, n0=12.1e6, total_range=60e6,
                                   wire="delta+bf16")
    assert src == "tuned" and math.prod(degrees) == 64
    # and the raw entry is still served to raw callers
    _, src_raw = resolve_degrees(64, n0=12.1e6, total_range=60e6)
    assert src_raw == "cache"


CONFIG_CACHE_CODE = r"""
import numpy as np
from repro.core import SparseAllreduce
from repro.core import autotune

rng = np.random.RandomState(0)
M = 8
outs = [np.unique(rng.choice(4000, 400).astype(np.uint32)) for _ in range(M)]
ins = [np.unique(rng.choice(4000, 250).astype(np.uint32)) for _ in range(M)]
vals = [rng.rand(len(o)).astype(np.float32) for o in outs]

ar1 = SparseAllreduce(M, (4, 2), backend="device")
ar1.config(outs, ins)
r1 = ar1.reduce(vals)
first_cache, traces = ar1.config_cache, ar1._planned.trace_count
assert traces >= 1

# in-process re-config: same frozen plan object, same compiled reduce,
# ZERO additional traces
ar2 = SparseAllreduce(M, (4, 2), backend="device")
ar2.config(outs, ins)
assert ar2.config_cache == "memo", ar2.config_cache
assert ar2._planned is ar1._planned
r2 = ar2.reduce(vals)
assert ar2._planned.trace_count == traces, "cache hit retraced!"
for a, b in zip(r1, r2):
    np.testing.assert_array_equal(a, b)

# simulated restart: drop the in-process memo -> the persistent artifact
# is rebuilt without re-running host planning, results bit-identical
autotune.clear_plan_memo()
ar3 = SparseAllreduce(M, (4, 2), backend="device")
ar3.config(outs, ins)
assert ar3.config_cache == "disk", ar3.config_cache
r3 = ar3.reduce(vals)
for a, b in zip(r1, r3):
    np.testing.assert_array_equal(a, b)
print("FIRST=%s RETRACES=%d" % (first_cache, ar2._planned.trace_count))
"""


def test_config_cache_zero_retrace_and_disk_tier(tmp_path):
    out1 = _run(CONFIG_CACHE_CODE, _env(tmp_path))
    assert "FIRST=fresh" in out1
    # process 2 starts cold but finds the persisted plan: its FIRST config
    # is already a disk hit (cross-restart cache hit), and the memo/disk
    # assertions inside the script all hold again
    out2 = _run(CONFIG_CACHE_CODE, _env(tmp_path))
    assert "FIRST=disk" in out2
