"""Band-limited merge kernels (merge="banded") parity + tile-work bounds.

The banded pipeline must be bit-identical to the ``"sort"`` oracle (concat
+ argsort + segment_compact) on every workload the butterfly can hand it,
and its instrumented tile counts must meet the band bounds the kernels are
built on: the one-hot scatter-add visits at most ceil(k*bm/bk)+1 input
tiles per output tile (vs C/bk for fused), and the rank-merge compare runs
only on merge-frontier tiles.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sparse_vec as sv
from repro.core.sparse_vec import SENTINEL, HashPerm, SparseChunk
from repro.kernels import costmodel, ops
from repro.kernels.onehot_scatter import (band_inner_tiles,
                                          banded_onehot_scatter_add,
                                          onehot_scatter_add)
from repro.kernels.rank_merge import rank_counts, rank_tile_stats
from repro.kernels.ref import rank_counts_ref


def _powerlaw_runs(k, cap, width, seed):
    """k sorted SENTINEL-padded runs of hash-permuted Zipf indices, each
    run's valid indices unique (the butterfly invariant banded relies on).

    Values are drawn on a dyadic lattice (multiples of 1/64 in [-2, 2]): a
    sum of up to ~64 such values is exactly representable in f32, so every
    summation order produces the same bits — bit-identity assertions then
    test the *merge logic*, not accumulation-association luck.
    """
    rng = np.random.RandomState(seed)
    perm = HashPerm.make(seed + 1)
    idx = np.full((k, cap), 0xFFFFFFFF, np.uint32)
    vshape = (k, cap) if width == 0 else (k, cap, width)
    val = np.zeros(vshape, np.float32)
    for r in range(k):
        raw = (rng.zipf(1.6, cap * 2) % 50_000).astype(np.uint32)
        h = np.unique(perm.fwd_np(raw))
        n = min(len(h), rng.randint(1, cap + 1))
        idx[r, :n] = h[:n]
        shape = (n,) if width == 0 else (n, width)
        val[r, :n] = (rng.randint(-128, 129, shape) / 64.0).astype(np.float32)
    return jnp.asarray(idx), jnp.asarray(val)


def _sort_path(idx, val, out_cap):
    cat = sv.concat_sorted_groups(idx, val)
    return sv.segment_compact(cat, out_cap), sv.compact_overflow(cat, out_cap)


def _assert_chunks_equal(got, want):
    np.testing.assert_array_equal(np.asarray(got.idx), np.asarray(want.idx))
    np.testing.assert_array_equal(np.asarray(got.val), np.asarray(want.val))


# ---------------------------------------------------------------------------
# Parity vs the sort oracle: k sweep x widths {1, W}
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [2, 4, 8, 16])
@pytest.mark.parametrize("width", [0, 3])
def test_banded_bit_identical_to_sort_path(k, width):
    cap = 96 if k <= 4 else 48
    idx, val = _powerlaw_runs(k, cap, width, seed=k * 10 + width)
    out_cap = k * cap
    want, want_ovf = _sort_path(idx, val, out_cap)
    got, ovf = ops.merge_sorted_runs(idx, val, out_cap, mode="banded")
    _assert_chunks_equal(got, want)
    assert int(ovf) == int(want_ovf) == 0


@pytest.mark.parametrize("k,cap", [(2, 64), (4, 32)])
def test_banded_overflow_matches_sort_path(k, cap):
    idx, val = _powerlaw_runs(k, cap, 0, seed=7)
    out_cap = max(8, (k * cap) // 4)
    want, want_ovf = _sort_path(idx, val, out_cap)
    got, ovf = ops.merge_sorted_runs(idx, val, out_cap, mode="banded")
    _assert_chunks_equal(got, want)
    assert int(ovf) == int(want_ovf) > 0


def test_banded_matches_fused():
    idx, val = _powerlaw_runs(4, 64, 2, seed=21)
    got_f, ovf_f = ops.merge_sorted_runs(idx, val, 256, mode="fused")
    got_b, ovf_b = ops.merge_sorted_runs(idx, val, 256, mode="banded")
    _assert_chunks_equal(got_b, got_f)
    assert int(ovf_f) == int(ovf_b)


# ---------------------------------------------------------------------------
# Degenerate streams
# ---------------------------------------------------------------------------

def test_banded_all_duplicate_runs():
    """Every run identical => every index has the maximal multiplicity k."""
    k, cap = 8, 32
    one = np.sort(HashPerm.make(5).fwd_np(
        np.arange(cap, dtype=np.uint32)))
    idx = jnp.asarray(np.tile(one, (k, 1)))
    val = jnp.asarray((np.random.RandomState(0).randint(-128, 129, (k, cap))
                       / 64.0).astype(np.float32))
    want, _ = _sort_path(idx, val, k * cap)
    got, ovf = ops.merge_sorted_runs(idx, val, k * cap, mode="banded")
    _assert_chunks_equal(got, want)
    assert int(ovf) == 0


def test_banded_all_sentinel_runs():
    idx = jnp.full((4, 16), SENTINEL, jnp.uint32)
    val = jnp.zeros((4, 16), jnp.float32)
    got, ovf = ops.merge_sorted_runs(idx, val, 64, mode="banded")
    assert int(got.count()) == 0
    assert int(ovf) == 0


def test_banded_single_valid_row():
    k, cap = 4, 16
    idx = np.full((k, cap), 0xFFFFFFFF, np.uint32)
    val = np.zeros((k, cap), np.float32)
    idx[2, 0] = 1234
    val[2, 0] = 7.5
    got, ovf = ops.merge_sorted_runs(jnp.asarray(idx), jnp.asarray(val),
                                     k * cap, mode="banded")
    want, _ = _sort_path(jnp.asarray(idx), jnp.asarray(val), k * cap)
    _assert_chunks_equal(got, want)
    assert int(got.count()) == 1 and int(ovf) == 0


# ---------------------------------------------------------------------------
# merge_add / segment_compact banded entry points
# ---------------------------------------------------------------------------

def test_merge_add_banded_parity():
    idx, val = _powerlaw_runs(2, 80, 0, seed=11)
    a = SparseChunk(idx=idx[0], val=val[0])
    b = SparseChunk(idx=idx[1], val=val[1])
    want = sv.merge_add(a, b, 160)
    got = ops.merge_add(a, b, 160, mode="banded")
    _assert_chunks_equal(got, want)


def test_segment_compact_banded_with_max_dup():
    """A sorted chunk whose indices repeat at most max_dup times."""
    rng = np.random.RandomState(4)
    base = np.sort(rng.choice(10_000, 40, replace=False).astype(np.uint32))
    reps = rng.randint(1, 4, 40)                  # multiplicity <= 3
    idx_np = np.repeat(base, reps)
    c = 160
    idx = np.full(c, 0xFFFFFFFF, np.uint32)
    idx[:len(idx_np)] = idx_np
    val = rng.randn(c).astype(np.float32)
    val[len(idx_np):] = 0.0
    ch = SparseChunk(idx=jnp.asarray(idx), val=jnp.asarray(val))
    want = sv.segment_compact(ch, c)
    got = ops.segment_compact(ch, c, max_dup=3)
    _assert_chunks_equal(got, want)


def test_mode_validation():
    idx, val = _powerlaw_runs(2, 16, 0, seed=1)
    with pytest.raises(ValueError):
        ops.merge_sorted_runs(idx, val, 32, mode="bogus")
    from repro.core.api import SparseAllreduce
    ar = SparseAllreduce(8, (4, 2), merge="banded")
    assert ar.merge == "banded"
    with pytest.raises(ValueError):
        SparseAllreduce(8, (4, 2), merge="bandit")
    from repro.train.step import make_train_step
    from repro.configs import get_config
    import jax
    with pytest.raises(ValueError):
        make_train_step(get_config("qwen1.5-0.5b").reduced(),
                        jax.make_mesh((1, 1), ("data", "model")),
                        sync_merge="bogus")


# ---------------------------------------------------------------------------
# Banded rank_counts parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strict", [True, False])
@pytest.mark.parametrize("bm,bn", [(512, 512), (32, 64), (8, 8)])
def test_banded_rank_counts_parity(strict, bm, bn):
    rng = np.random.RandomState(bm + bn + strict)
    for _ in range(5):
        ca, cb = rng.randint(1, 200), rng.randint(1, 200)
        a = np.sort(rng.randint(0, 5000, ca).astype(np.uint32))
        b = np.sort(rng.randint(0, 5000, cb).astype(np.uint32))
        a[-max(1, ca // 5):] = 0xFFFFFFFF      # sentinel tails
        got = rank_counts(jnp.asarray(a), jnp.asarray(b), strict=strict,
                          bm=bm, bn=bn, banded=True)
        ref = rank_counts_ref(jnp.asarray(a), jnp.asarray(b),
                              "left" if strict else "right")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_banded_rank_counts_all_equal_streams():
    a = jnp.asarray(np.full(64, 9, np.uint32))
    for strict in (True, False):
        got = rank_counts(a, a, strict=strict, bm=16, bn=16, banded=True)
        want = np.full(64, 0 if strict else 64, np.int32)
        np.testing.assert_array_equal(np.asarray(got), want)


# ---------------------------------------------------------------------------
# Tile-work bounds (the point of the banded mode)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k,cap", [(2, 2048), (4, 1024), (8, 512), (16, 256)])
def test_scatter_band_bound(k, cap):
    """Banded one-hot scatter visits <= ceil(k*bm/bk)+1 input tiles per
    output tile; fused scans all C/bk."""
    bm, bk = costmodel.SCATTER_BM, costmodel.SCATTER_BK
    c = k * cap
    rep_b = costmodel.scatter_tile_report(c, 1, c, mode="banded", band=k)
    rep_f = costmodel.scatter_tile_report(c, 1, c, mode="fused")
    bound = -(-k * bm // bk) + 1
    assert rep_b["inner_tiles_per_out_tile"] == band_inner_tiles(k, bm, bk) \
        == bound
    assert rep_b["inner_tiles_per_out_tile"] <= bound
    assert rep_f["inner_tiles_per_out_tile"] == -(-c // bk)
    assert rep_b["tiles"] < rep_f["tiles"]


def test_banded_scatter_kernel_parity_monotone_pos():
    """The banded kernel == dense kernel on a monotone pos stream.  Same
    tile shapes on both sides: identical bk partitions make the partial-sum
    groupings identical (out-of-window tiles contribute exact zeros), so
    even randn values must match bitwise."""
    rng = np.random.RandomState(0)
    band, rows = 4, 300
    mult = rng.randint(1, band + 1, rows)
    pos_np = np.repeat(np.arange(rows), mult)
    c = len(pos_np)
    val = rng.randn(c, 5).astype(np.float32)
    pos = jnp.asarray(pos_np.astype(np.int32))
    got = banded_onehot_scatter_add(pos, jnp.asarray(val), rows, band=band,
                                    bm=64, bk=128)
    ref = onehot_scatter_add(pos, jnp.asarray(val), rows, bm=64, bk=128)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_banded_scatter_block_multiple_boundary():
    """c an exact multiple of bk with source-less output tiles beyond the
    last destination: the start-block table must stay within the padded
    input (regression for an off-the-end block index)."""
    band, rows, bk = 8, 64, 512
    pos_np = np.repeat(np.arange(rows), band)          # c = 512 == bk
    val = np.arange(len(pos_np), dtype=np.float32)[:, None]
    got = banded_onehot_scatter_add(jnp.asarray(pos_np.astype(np.int32)),
                                    jnp.asarray(val), 1024, band=band,
                                    bk=bk)
    ref = onehot_scatter_add(jnp.asarray(pos_np.astype(np.int32)),
                             jnp.asarray(val), 1024, bk=bk)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_rank_frontier_only_tiles():
    """On hash-unique sorted streams, the banded rank kernel's compare work
    is confined to the merge frontier: O(Ca/bm + Cb/bn) tiles, not the full
    (Ca/bm)*(Cb/bn) plane."""
    perm = HashPerm.make(2)
    a = np.sort(perm.fwd_np(np.arange(4096, dtype=np.uint32)))
    b = np.sort(perm.fwd_np(np.arange(4096, 8192, dtype=np.uint32)))
    bm = bn = 128
    st = rank_tile_stats(a, b, strict=True, bm=bm, bn=bn)
    n_a, n_b = len(a) // bm, len(b) // bn
    assert st["total_tiles"] == n_a * n_b
    assert st["frontier_tiles"] <= n_a + n_b
    assert st["frontier_tiles"] + st["full_below_tiles"] \
        + st["skipped_tiles"] == st["total_tiles"]
    # the cheap classification must agree with actual counts: checked by
    # parity tests above; here assert the instrumented report plumbs through
    rep = costmodel.merge_tile_report(
        jnp.asarray(np.stack([a, b])), 8192, mode="banded",
        rank_bm=bm, rank_bn=bn)
    assert rep["rank_compare_tiles"] <= 2 * (n_a + n_b)
    assert rep["rank_compare_tiles"] + rep["rank_cheap_tiles"] \
        == rep["rank_total_tiles"]
    assert rep["scatter_inner_tiles_per_out_tile"] == band_inner_tiles(
        2, costmodel.SCATTER_BM, costmodel.SCATTER_BK)
