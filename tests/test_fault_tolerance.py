"""Fault tolerance (paper §V): replication properties, failure schedules,
the generalized birthday bound, and device-vs-sim parity under identical
failure schedules (subprocess: up to 16 forced host devices)."""
import math
import os
import subprocess
import sys

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.faults import (SCHEDULE_KINDS, FailureSchedule,
                               analytic_completion_probability,
                               completion_probability, make_schedule)
from repro.core.replication import (DeadLogicalNode, contribution_weights,
                                    expected_tolerated_failures,
                                    first_alive_replicas, replica_groups,
                                    simulate_random_failures)

_ENV = dict(os.environ,
            XLA_FLAGS="--xla_force_host_platform_device_count=16",
            PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src")
            + os.pathsep + os.environ.get("PYTHONPATH", ""))


def _run(code: str):
    r = subprocess.run([sys.executable, "-c", code], env=_ENV,
                       capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def _draw_dead(m_phys: int, seed: int, frac: float):
    rng = np.random.RandomState(seed)
    k = int(round(frac * m_phys))
    return set(rng.choice(m_phys, size=k, replace=False).tolist())


# ---------------------------------------------------------------------------
# contribution_weights / replica_groups properties
# ---------------------------------------------------------------------------

@given(st.integers(1, 10), st.integers(1, 3), st.integers(0, 10_000),
       st.floats(0.0, 0.9))
@settings(max_examples=60, deadline=None)
def test_weights_one_unit_per_group_property(m_logical, r, seed, frac):
    """Exactly one unit weight per replica group, on an alive member, for
    every (M, r, dead); raises DeadLogicalNode iff some group <= dead."""
    m_phys = m_logical * r
    dead = _draw_dead(m_phys, seed, frac)
    groups = replica_groups(m_phys, r)
    assert sorted(d for g in groups for d in g) == list(range(m_phys))
    assert all(len(g) == r for g in groups)
    some_group_lost = any(all(d in dead for d in g) for g in groups)
    if some_group_lost:
        with pytest.raises(DeadLogicalNode):
            contribution_weights(m_phys, r, dead)
        return
    w = contribution_weights(m_phys, r, dead)
    assert w.shape == (m_phys,) and w.dtype == np.float32
    assert set(np.unique(w)) <= {0.0, 1.0}
    for g in groups:
        ws = [w[d] for d in g]
        assert sum(ws) == 1.0
        chosen = g[ws.index(1.0)]
        assert chosen not in dead
        # first *alive* member of the group carries the weight
        assert chosen == next(d for d in g if d not in dead)
    fa = first_alive_replicas(m_phys, r, dead)
    assert [w[p] for p in fa] == [1.0] * m_logical
    assert [p % m_logical for p in fa] == list(range(m_logical))


@given(st.integers(2, 10), st.integers(1, 3), st.integers(0, 10_000),
       st.floats(0.0, 0.6))
@settings(max_examples=40, deadline=None)
def test_weights_permutation_equivariant(m_logical, r, seed, frac):
    """Relabeling logical shards commutes with the weight computation:
    for pi(i + j*M) = sigma(i) + j*M, weights(pi(dead))[pi(p)] ==
    weights(dead)[p] — the weights depend on the dead set only through
    the replica-group structure, not on shard identities."""
    m_phys = m_logical * r
    dead = _draw_dead(m_phys, seed, frac)
    groups = replica_groups(m_phys, r)
    if any(all(d in dead for d in g) for g in groups):
        return  # raise case covered by the other property
    sigma = np.random.RandomState(seed + 1).permutation(m_logical)

    def pi(p):
        return int(sigma[p % m_logical]) + (p // m_logical) * m_logical

    w = contribution_weights(m_phys, r, dead)
    w2 = contribution_weights(m_phys, r, {pi(d) for d in dead})
    assert all(w2[pi(p)] == w[p] for p in range(m_phys))


def test_replica_groups_validation():
    with pytest.raises(ValueError):
        replica_groups(8, 3)
    with pytest.raises(ValueError):
        replica_groups(8, 0)


def test_out_of_range_dead_ids_rejected():
    """Dead ids beyond the physical id space would silently inject no
    failure at all — both backends reject them instead."""
    from repro.core.simulator import SimSparseAllreduce
    from repro.core.topology import ButterflyPlan
    with pytest.raises(ValueError):
        contribution_weights(8, 2, dead={3, 8})
    with pytest.raises(ValueError):
        SimSparseAllreduce(ButterflyPlan(4, (4,)), replication=2, dead={99})


def test_device_plan_stage0_is_replica_merge():
    """make_device_plan(replication=r) prepends a stage whose mixed-radix
    groups are exactly replica_groups (digit 0 most significant)."""
    from repro.core.allreduce import make_device_plan
    for degs, r in [((4,), 2), ((2, 2), 2), ((4, 2), 2), ((2, 2), 3)]:
        m_log = math.prod(degs)
        m_phys = m_log * r
        plan = make_device_plan([("d", m_phys)], {"d": degs}, 32, 128,
                                replication=r)
        assert plan.replication == r and plan.num_logical == m_log
        assert plan.logical.degrees == (r,) + degs
        got = [sorted(g) for g in plan.stages[0].axis_index_groups]
        assert got == replica_groups(m_phys, r)
        assert plan.replica_groups() == replica_groups(m_phys, r)
    with pytest.raises(ValueError):
        make_device_plan([("d", 8)], {"d": (4,)}, 8, 8, replication=3)


# ---------------------------------------------------------------------------
# failure schedules
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", SCHEDULE_KINDS)
def test_schedule_deterministic_and_sized(kind):
    a = make_schedule(kind, 24, 7, seed=5)
    b = FailureSchedule(kind=kind, m_physical=24, num_failures=7, seed=5)
    for t in range(6):
        da, db = a.dead_at(t), b.dead_at(t)
        assert da == db
        # cascade accumulates f *new* failures per step (capped at m);
        # every other kind has exactly f dead per step
        want = min(7 * (t + 1), 24) if kind == "cascade" else 7
        assert len(da) == want and all(0 <= d < 24 for d in da)
    assert list(a.steps(3)) == [a.dead_at(0), a.dead_at(1), a.dead_at(2)]
    assert make_schedule(kind, 24, 0).dead_at(3) == set()
    # different seeds / steps decorrelate (deterministically checkable)
    assert make_schedule(kind, 24, 7, seed=6).dead_at(0) != a.dead_at(0)


def test_schedule_rolling_is_contiguous_window():
    s = make_schedule("rolling", 20, 6, seed=3)
    for t in range(5):
        dead = sorted(s.dead_at(t))
        start = (3 + t * 6) % 20
        assert set(dead) == {(start + i) % 20 for i in range(6)}


def test_schedule_rack_is_rack_correlated():
    s = make_schedule("rack", 32, 10, seed=1, rack_size=4)
    for t in range(4):
        dead = s.dead_at(t)
        racks = {d // 4 for d in dead}
        assert len(racks) <= -(-10 // 4)  # at most ceil(f/rack) racks hit
        # all but (at most) one rack are fully dead
        partial = [rk for rk in racks
                   if not all(4 * rk + i in dead for i in range(4))]
        assert len(partial) <= 1


def test_schedule_validation():
    with pytest.raises(ValueError):
        make_schedule("cosmic", 8, 1)
    with pytest.raises(ValueError):
        make_schedule("random", 8, 9)
    with pytest.raises(ValueError):
        FailureSchedule(kind="rack", m_physical=8, num_failures=2,
                        rack_size=0)


# ---------------------------------------------------------------------------
# generalized birthday bound (§V-A)
# ---------------------------------------------------------------------------

def test_generalized_bound_closed_forms():
    # r=2 is exactly the paper's sqrt(pi*M/2); r=1 means the first failure
    # is fatal; higher r tolerates more (M^(1-1/r) scaling), capped by M*r.
    for m in (16, 64, 256):
        assert expected_tolerated_failures(m, 2) == \
            pytest.approx(math.sqrt(math.pi * m / 2))
        assert expected_tolerated_failures(m, 1) == pytest.approx(1.0)
        b = [expected_tolerated_failures(m, r) for r in (1, 2, 3, 4)]
        assert all(x < y for x, y in zip(b, b[1:]))
        assert b[-1] < 4 * m
    with pytest.raises(ValueError):
        expected_tolerated_failures(8, 0)


def test_birthday_regression_smoke():
    """Fast fixed-seed check that the empirical completion probability
    tracks the §V-A analytic curve around the bound."""
    m, r = 36, 2
    f = int(round(expected_tolerated_failures(m, r)))
    p = simulate_random_failures(m, r, f, trials=200, seed=0)
    assert abs(p - analytic_completion_probability(m, r, f)) < 0.12
    assert simulate_random_failures(m, r, 1, trials=100) == 1.0
    assert simulate_random_failures(m, r, 2 * f, trials=200) < p


@pytest.mark.slow
def test_birthday_regression_analytic_tolerance():
    """simulate_random_failures at ~sqrt(M) (and the r=3 analogue) stays
    within the generalized birthday bound's analytic tolerance."""
    m, r = 256, 2
    f = int(round(expected_tolerated_failures(m, r)))   # ~20 ~ 1.25*sqrt(M)
    p = simulate_random_failures(m, r, f, trials=2000, seed=0)
    assert abs(p - analytic_completion_probability(m, r, f)) < 0.06
    # sweep is monotone decreasing in failure count
    ps = [simulate_random_failures(m, r, k, trials=600, seed=1)
          for k in (f // 2, f, 2 * f)]
    assert ps[0] > ps[1] > ps[2]
    # r=3: M^(2/3) scaling
    m3, r3 = 64, 3
    f3 = int(round(expected_tolerated_failures(m3, r3)))
    p3 = completion_probability(m3, r3, f3, trials=1500, seed=0)
    assert abs(p3 - analytic_completion_probability(m3, r3, f3)) < 0.06


# ---------------------------------------------------------------------------
# DeadLogicalNode parity (host-side: raises before any mesh is touched)
# ---------------------------------------------------------------------------

def test_dead_group_raises_on_both_backends():
    from repro.core.api import SparseAllreduce
    from repro.core.simulator import SimSparseAllreduce
    from repro.core.topology import ButterflyPlan
    lost = {0, 4}                       # whole replica group of shard 0
    with pytest.raises(DeadLogicalNode):
        SimSparseAllreduce(ButterflyPlan(4, (4,)), replication=2, dead=lost)
    ar = SparseAllreduce(4, (4,), backend="device", replication=2, dead=lost)
    out = [np.arange(3, dtype=np.uint32)] * 4
    with pytest.raises(DeadLogicalNode):
        ar.config(out, out)
    with pytest.raises(DeadLogicalNode):
        ar.union_reduce(np.zeros((4, 8), np.uint32),
                        np.zeros((4, 8), np.float32), 32)
    # r=1: no redundancy, any failure is fatal — on both backends
    with pytest.raises(DeadLogicalNode):
        SimSparseAllreduce(ButterflyPlan(4, (4,)), dead={2})
    with pytest.raises(DeadLogicalNode):
        SparseAllreduce(4, (4,), backend="device", dead={2}).config(out, out)


# ---------------------------------------------------------------------------
# device-vs-sim parity under identical failure schedules (subprocess)
# ---------------------------------------------------------------------------

PARITY_PRELUDE = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core.api import SparseAllreduce
from repro.core.faults import make_schedule
from repro.core.replication import DeadLogicalNode, replica_groups
from repro.core.simulator import SimSparseAllreduce
from repro.core.sparse_vec import HashPerm
from repro.core.topology import ButterflyPlan

DEVS = np.array(jax.devices())
def mesh_of(n):
    return jax.sharding.Mesh(DEVS[:n], ("nodes",))

def survivable(m_phys, r, dead):
    return all(any(d not in dead for d in g)
               for g in replica_groups(m_phys, r))

def dead_sets(m_phys, r, seed):
    # identical deterministic schedule on both backends: empty, the first
    # survivable random-1 steps, and r-1 dead replicas of shard 0
    out = [set()]
    if r > 1:
        sched = make_schedule("random", m_phys, 1, seed=seed)
        out += [d for d in sched.steps(4) if survivable(m_phys, r, d)][:2]
        out.append(set(replica_groups(m_phys, r)[0][: r - 1]))
    return out

R_IDX = 400
def workload(M, seed):
    rng = np.random.RandomState(seed)
    out_idx = [rng.choice(R_IDX, rng.randint(8, 24),
                          replace=False).astype(np.uint32) for _ in range(M)]
    # dyadic-lattice values: any summation order is bit-exact in fp32, so
    # replicated-vs-baseline-vs-sim comparisons can demand bit identity
    out_val = [(rng.randint(-128, 129, len(o)) / 64.0).astype(np.float32)
               for o in out_idx]
    return out_idx, out_val
"""


PLANNED_PARITY_CODE = PARITY_PRELUDE + r"""
for degs in [(4,), (2, 2), (4, 2)]:
    M = int(np.prod(degs))
    out_idx, out_val = workload(M, seed=M)
    rng = np.random.RandomState(M + 1)
    in_idx = [rng.choice(R_IDX, rng.randint(5, 16),
                         replace=False).astype(np.uint32) for _ in range(M)]
    base = SparseAllreduce(M, degs, backend="device", mesh=mesh_of(M), seed=M)
    base.config(out_idx, in_idx)
    want = base.reduce(out_val)
    for r in (1, 2):
        m_phys = M * r
        for dead in dead_sets(m_phys, r, seed=M):
            ar = SparseAllreduce(M, degs, backend="device", replication=r,
                                 dead=dead or None, mesh=mesh_of(m_phys),
                                 seed=M)
            ar.config(out_idx, in_idx)
            got = ar.reduce(out_val)
            sim = SimSparseAllreduce(ButterflyPlan(M, degs), replication=r,
                                     dead=dead or None, perm=HashPerm.make(M))
            sim.config(out_idx, in_idx)
            sgot = sim.reduce(out_val)
            for n in range(M):
                # bit-identical to the fault-free non-replicated reduce...
                np.testing.assert_array_equal(got[n], want[n],
                                              err_msg=f"{degs} r={r} {dead}")
                # ...and to the simulator under the identical schedule
                np.testing.assert_array_equal(
                    got[n], np.asarray(sgot[n], np.float32),
                    err_msg=f"sim {degs} r={r} {dead}")
        if r > 1:
            lost = set(replica_groups(m_phys, r)[1])
            try:
                SimSparseAllreduce(ButterflyPlan(M, degs), replication=r,
                                   dead=lost)
                raise SystemExit(f"sim accepted lost group {degs}")
            except DeadLogicalNode:
                pass
            try:
                ar = SparseAllreduce(M, degs, backend="device", replication=r,
                                     dead=lost, mesh=mesh_of(m_phys), seed=M)
                ar.config(out_idx, in_idx)
                raise SystemExit(f"device accepted lost group {degs}")
            except DeadLogicalNode:
                pass
print("PLANNED_PARITY_OK")
"""


UNION_PARITY_CODE = PARITY_PRELUDE + r"""
merge = "%(merge)s"
C = 24
for degs in [(4,), (2, 2), (4, 2)]:
    M = int(np.prod(degs))
    out_idx, out_val = workload(M, seed=M)
    perm = HashPerm.make(M)
    idx = np.full((M, C), 0xFFFFFFFF, np.uint32)
    val = np.zeros((M, C), np.float32)
    for n in range(M):
        h = perm.fwd_np(out_idx[n]); o = np.argsort(h)
        idx[n, :len(h)] = h[o]; val[n, :len(h)] = out_val[n][o]
    # the union in user space, ordered by hash — the sim's request list
    uraw = np.unique(np.concatenate(out_idx))
    uraw = uraw[np.argsort(perm.fwd_np(uraw))]
    nu = len(uraw)
    base = SparseAllreduce(M, degs, backend="device", mesh=mesh_of(M),
                           seed=M, merge=merge)
    bi, bv, bovf = (np.asarray(x) for x in
                    base.union_reduce(idx, val, out_capacity=M * C))
    assert bovf.sum() == 0
    for r in (1, 2):
        m_phys = M * r
        for dead in dead_sets(m_phys, r, seed=M):
            ar = SparseAllreduce(M, degs, backend="device", replication=r,
                                 dead=dead or None, mesh=mesh_of(m_phys),
                                 seed=M, merge=merge)
            oi, ov, ovf = (np.asarray(x) for x in
                           ar.union_reduce(idx, val, out_capacity=M * C))
            assert ovf.sum() == 0, (degs, r, dead)
            # bit-identical unions (indices AND values) vs the fault-free
            # non-replicated run, for every node
            np.testing.assert_array_equal(oi, bi)
            np.testing.assert_array_equal(ov, bv)
            # sim with the identical schedule, requesting the full union
            sim = SimSparseAllreduce(ButterflyPlan(M, degs), replication=r,
                                     dead=dead or None, perm=perm)
            sim.config(out_idx, [uraw] * M)
            sgot = sim.reduce(out_val)
            for n in range(M):
                assert np.array_equal(oi[n][:nu], perm.fwd_np(uraw))
                assert (oi[n][nu:] == 0xFFFFFFFF).all()
                np.testing.assert_array_equal(
                    ov[n][:nu], np.asarray(sgot[n], np.float32),
                    err_msg=f"sim {degs} r={r} {dead}")
        if r > 1:
            lost = set(replica_groups(m_phys, r)[0])
            try:
                ar = SparseAllreduce(M, degs, backend="device", replication=r,
                                     dead=lost, mesh=mesh_of(m_phys),
                                     seed=M, merge=merge)
                ar.union_reduce(idx, val, out_capacity=M * C)
                raise SystemExit(f"device union accepted lost group {degs}")
            except DeadLogicalNode:
                pass
print("UNION_PARITY_OK_" + merge)
"""


@pytest.mark.slow
def test_planned_parity_device_vs_sim():
    """Replicated device config/reduce == fault-free non-replicated device
    reduce == simulator, bit-identically, under identical failure
    schedules, swept over degrees x r."""
    assert "PLANNED_PARITY_OK" in _run(PLANNED_PARITY_CODE)


@pytest.mark.slow
@pytest.mark.parametrize("merge", ["sort", "fused", "banded"])
def test_union_parity_device_vs_sim(merge):
    """Replicated union allreduce: bit-identical unions and sums vs the
    fault-free non-replicated run and vs the simulator, for every merge
    mode, under identical failure schedules."""
    assert ("UNION_PARITY_OK_" + merge) in _run(
        UNION_PARITY_CODE % {"merge": merge})
