"""Fixture: RA301 positive — unhashable defaults on static jit args."""
from functools import partial

import jax


@partial(jax.jit, static_argnums=(1,))
def step(x, cfg=[4, 2]):  # expect: RA301
    return x * len(cfg)


def run(x, opts={}):  # expect: RA301
    return x


run_jit = jax.jit(run, static_argnames=("opts",))
