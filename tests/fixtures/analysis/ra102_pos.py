"""Fixture: RA102 positive — Pallas TPU symbols resolved around compat."""
from jax.experimental.pallas import tpu as pltpu
from jax.experimental.pallas.tpu import TPUCompilerParams  # expect: RA102


def make_params():
    return pltpu.CompilerParams(  # expect: RA102
        dimension_semantics=("parallel",))


def make_grid_spec(n):
    return pltpu.PrefetchScalarGridSpec(  # expect: RA102
        num_scalar_prefetch=1, grid=(n,))
