import os  # expect: RA402

SEP = os.sep
