"""Fixture: RA206 positive — host debug calls inside traced code."""
import jax
import pdb


@jax.jit
def step(x):
    print("tracing with", x)  # expect: RA206
    pdb.set_trace()  # expect: RA206
    return x * 2
