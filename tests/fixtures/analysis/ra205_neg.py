"""Fixture: RA205 negative — fp32 device path, f64 host oracle."""
import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def step(x):
    return x.astype(jnp.float32) + jnp.zeros((4,), dtype="float32")


def oracle(x):
    # host-side reference computation keeps full precision
    return np.asarray(x, np.float64).sum()
