"""Fixture: RA204 positive — Python loops over devices in traced code."""
import jax


@jax.jit
def step(x, num_devices):
    acc = x
    for i in range(num_devices):  # expect: RA204
        acc = acc + i
    for dev in jax.devices():  # expect: RA204
        acc = acc * 1
    return acc
