"""Fixture: RA101 negative — the compat import and innocent near-misses."""
from repro.compat import shard_map


def wrap(body, mesh, spec):
    # bare name resolved through compat: fine
    return shard_map(body, mesh=mesh, in_specs=spec, out_specs=spec)


SHARD_MAP_DOC = "strings mentioning jax.experimental.shard_map are fine"


def uses_own_attr(obj):
    # shard_map attribute on a non-jax object is not the moved symbol
    return obj.helper.run(obj)
