"""Fixture: RA205 positive — float64 on a traced device path."""
import jax
import jax.numpy as jnp


@jax.jit
def step(x):
    wide = x.astype(jnp.float64)  # expect: RA205
    zeros = jnp.zeros((4,), dtype="float64")  # expect: RA205
    return wide + zeros
