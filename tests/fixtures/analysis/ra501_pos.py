"""Fixture: RA501 positive — faults swallowed outside the resilience
layer (bare excepts and pass-only DeadLogicalNode handlers)."""
from repro.core.replication import DeadLogicalNode


def lossy_reduce(ar, values):
    try:
        return ar.reduce(values)
    except:  # expect: RA501
        return values


def ignore_dead(ar, values):
    try:
        return ar.reduce(values)
    except DeadLogicalNode:  # expect: RA501
        pass


def ignore_dead_dotted(ar, values, replication):
    try:
        return ar.reduce(values)
    except replication.DeadLogicalNode:  # expect: RA501
        ...


def ignore_dead_in_tuple(ar, values):
    for v in values:
        try:
            ar.reduce(v)
        except (ValueError, DeadLogicalNode):  # expect: RA501
            continue
