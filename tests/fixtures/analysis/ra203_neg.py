"""Fixture: RA203 negative — casts of static Python scalars are fine."""
import jax


@jax.jit
def step(x, num_nodes, flag):
    # static config scalars (no call/subscript in the argument)
    scale = float(num_nodes)
    on = bool(flag)
    return x * scale if on else x


def host_cast(arr):
    # host side: concretization is the point
    return float(arr.sum())
