"""Fixture: RA401 negative (scope) — undocumented publics OUTSIDE the
core/analysis surface are not this rule's business."""


def free_helper(x):
    return x


class Scratch:
    def poke(self):
        return None
