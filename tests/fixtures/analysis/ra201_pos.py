"""Fixture: RA201 positive — host syncs inside a jitted region."""
import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def step(x):
    y = x * 2
    host = np.asarray(y)  # expect: RA201
    y.block_until_ready()  # expect: RA201
    moved = jax.device_get(y)  # expect: RA201
    return y + jnp.float32(host.sum() + moved.sum() + y.item())  # expect: RA201
