"""Fixture: RA102 negative — compat-resolved params and near-misses."""
from repro.compat import CompilerParams, PrefetchScalarGridSpec


def make_params():
    # resolved once in repro.compat: fine
    return CompilerParams(dimension_semantics=("parallel",))


def make_grid_spec(n):
    return PrefetchScalarGridSpec(num_scalar_prefetch=1, grid=(n,))


def own_namespace(cfg):
    # CompilerParams attribute on a non-pltpu object is unrelated
    return cfg.CompilerParams
