"""Fixture: RA203 positive — scalar casts concretizing traced values."""
import jax
import jax.numpy as jnp


@jax.jit
def step(x):
    total = float(jnp.sum(x))  # expect: RA203
    first = int(x[0])  # expect: RA203
    return x / total + first
