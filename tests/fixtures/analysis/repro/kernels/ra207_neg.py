"""Fixture: RA207 negative — near-miss casts that must stay clean."""
import jax
import jax.numpy as jnp


@jax.jit
def decode(packed, base, val, scale):
    b = base.astype(jnp.uint32)          # decoded quantity, not a buffer
    v = val.astype(jnp.float32)          # plain value widening is fine
    s = scale.astype(jnp.float32)
    narrow = packed.astype(jnp.int8)     # narrowing is the codec's job
    half = packed.astype(jnp.bfloat16)   # < 4 bytes: still compressed
    return b + v + s, narrow, half


def host_decode(packed):
    # cold (host-side) code may widen packed buffers freely — debugging,
    # oracles and tests do this on purpose.
    return packed.astype(jnp.float32)
