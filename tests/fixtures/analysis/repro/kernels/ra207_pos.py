"""Fixture: RA207 positive — widening casts on packed wire buffers."""
import jax
import jax.numpy as jnp


@jax.jit
def decode(packed, base, nw):
    words = packed + 0
    wide = words.astype(jnp.uint32)  # expect: RA207
    vals = packed[:, 0].astype(jnp.float32)  # expect: RA207
    named = packed.astype("float32")  # expect: RA207
    wire_buf = words[:1]
    kwarg = wire_buf.astype(dtype=jnp.int32)  # expect: RA207
    ctor = jnp.float32(packed)  # expect: RA207
    return wide + vals + named + kwarg + ctor
