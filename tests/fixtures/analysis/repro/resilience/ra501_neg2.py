"""Fixture: RA501 negative — this fixture path maps into the
``resilience/`` scope, where absorbing DeadLogicalNode is the whole
point (the supervisor catches it to classify and replan)."""
from repro.core.replication import DeadLogicalNode


def probe_is_dead(ar, values):
    try:
        ar.reduce(values)
    except DeadLogicalNode:
        pass
    return True
