"""Fixture: RA401 positive — undocumented publics in the documented
surface (this file's fixture path maps to ``core/`` scope)."""


def reduce_all(values):  # expect: RA401
    return values


class Planner:  # expect: RA401
    def plan(self):  # expect: RA401
        return None

    def _internal(self):
        return None
