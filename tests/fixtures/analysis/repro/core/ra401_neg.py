"""Fixture: RA401 negative — documented publics, undocumented privates."""


def reduce_all(values):
    """Sum the values."""
    return values


class Planner:
    """Plans things."""

    def plan(self):
        """Return the plan."""
        return None

    def _internal(self):
        return None


def _helper():
    return 0
