"""Fixture: RA101 positive — shard_map resolved around repro.compat."""
import jax

from jax.experimental.shard_map import shard_map  # expect: RA101
from jax.experimental import shard_map as smap  # expect: RA101
import jax.experimental.shard_map as sm_mod  # expect: RA101


def wrap(body, mesh, spec):
    return jax.shard_map(body, mesh=mesh, in_specs=spec,  # expect: RA101
                         out_specs=spec)
