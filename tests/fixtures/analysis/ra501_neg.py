"""Fixture: RA501 negative — legitimate fault handling: re-raise,
route to the supervisor, or genuinely handle; specific non-fault
exceptions may pass."""
from repro.core.replication import DeadLogicalNode


def reraise(ar, values):
    try:
        return ar.reduce(values)
    except DeadLogicalNode:
        raise


def route_to_supervisor(ar, values, supervisor):
    try:
        return ar.reduce(values)
    except DeadLogicalNode as e:
        return supervisor.replan_and_retry(e, values)


def count_faults(ar, values, stats):
    try:
        return ar.reduce(values)
    except DeadLogicalNode:
        stats["faults"] += 1
        raise


def unrelated_pass_is_fine(path):
    import os
    try:
        os.remove(path)
    except FileNotFoundError:
        pass
