"""Fixture: RA201 negative — syncs on the host side of the dispatch."""
import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def step(x):
    # literal-only conversion folds at trace time: fine
    scale = np.asarray((0.5, 2.0))
    return x * jnp.asarray(scale)[0]


def host_driver(x):
    # host code around the dispatch syncs legitimately
    out = step(x)
    out.block_until_ready()
    return np.asarray(out)
