"""Fixture: RA201 negative, serving-tier shaped — the scheduler's real
pattern: greedy argmax fused on device, host code syncs only the int32
ids *after* the dispatch returns."""
import jax
import jax.numpy as jnp
import numpy as np


def _decode_body(params, tok, pos, cache):
    logits = params["emb"][tok] * jnp.float32(pos)
    ids = jnp.argmax(logits, -1).astype(jnp.int32)
    return ids, cache


decode = jax.jit(_decode_body)


def serve_loop(params, cache, steps):
    # host-side driver: syncing the [slots] ids out here is the design
    tok = jnp.zeros((2,), jnp.int32)
    out = []
    for i in range(steps):
        tok, cache = decode(params, tok, jnp.int32(i), cache)
        out.append(np.asarray(tok))
    return np.stack(out)
