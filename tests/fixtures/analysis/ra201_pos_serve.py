"""Fixture: RA201 positive, serving-tier shaped — host syncs inside the
continuous-batching decode step (the inferred-hot region is the function
handed to ``jax.jit`` at the call site, the scheduler's idiom)."""
import jax
import jax.numpy as jnp
import numpy as np


def _decode_body(params, tok, pos, cache):
    logits = params["emb"][tok] * jnp.float32(pos)
    host_logits = np.asarray(logits)  # expect: RA201
    best = int(jnp.argmax(logits, -1).item())  # expect: RA201
    jax.device_get(cache)  # expect: RA201
    return jnp.int32(best + host_logits.shape[0]), cache


decode = jax.jit(_decode_body)


def serve_loop(params, cache, steps):
    tok = jnp.zeros((2,), jnp.int32)
    for i in range(steps):
        tok, cache = decode(params, tok, jnp.int32(i), cache)
    return tok
