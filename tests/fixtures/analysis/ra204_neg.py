"""Fixture: RA204 negative — plan-depth unrolls and host-side device
enumeration."""
import jax


@jax.jit
def step(x, layers):
    acc = x
    # unrolling over butterfly layers (plan depth) is the intended shape
    for scale in layers:
        acc = acc * scale
    for _ in range(len(layers)):
        acc = acc + 1
    return acc


def host_topology():
    return [d.id for d in jax.devices()]
