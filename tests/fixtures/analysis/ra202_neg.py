"""Fixture: RA202 negative — dtype tags and literal-only numpy in traced
code, real numpy on the host."""
import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def step(x):
    # dtype attribute reference (no call) and literal-only constants fold
    mask = np.zeros((4, 4))
    return jnp.mean(x.astype(np.float32)) + jnp.asarray(mask)


def host_stats(x):
    return np.mean(np.asarray(x))
