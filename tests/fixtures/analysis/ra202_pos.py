"""Fixture: RA202 positive — numpy computation inside a jitted region."""
import jax
import numpy as np


@jax.jit
def step(x):
    m = np.mean(x)  # expect: RA202
    clipped = np.clip(x, -1.0, 1.0)  # expect: RA202
    return (x - m) + clipped
