"""Fixture: RA402 negative — the module says what it is."""
import os

SEP = os.sep
