"""Fixture: RA206 negative — jax.debug.print in traced code, print on
the host."""
import jax


@jax.jit
def step(x):
    jax.debug.print("x = {}", x)
    return x * 2


def host_report(out):
    print("result:", out)
