"""Fixture: RA301 negative — hashable static defaults; unhashable
defaults on non-static args."""
from functools import partial

import jax


@partial(jax.jit, static_argnums=(1,))
def step(x, cfg=(4, 2)):  # tuple default: hashable
    return x * len(cfg)


def plain(x, opts=[1]):  # never declared static: list default is fine
    return x


@partial(jax.jit, static_argnames=("mode",))
def other(x, mode="sort", buf=[0]):  # buf is traced, not static
    return x
