"""Fixture: RA502 negative — persistence through the atomic store (and
numpy *readers*, which are unaffected)."""
import numpy as np

from repro.checkpoint import store


def checkpoint(path, params, step):
    store.save(path, {"params": params}, meta={"step": step})


def restore(path, like):
    return store.load(path, like)


def read_side_is_fine(path):
    with np.load(path) as data:
        return {k: data[k] for k in data.files}
