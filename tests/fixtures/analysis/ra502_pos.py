"""Fixture: RA502 positive — raw numpy array writers used for
checkpoint-style persistence (killable mid-write, non-atomic)."""
import numpy as np


def save_state(path, params, opt):
    np.savez(path, **params)  # expect: RA502
    np.savez_compressed(path + ".z", **opt)  # expect: RA502


def save_single(path, arr):
    np.save(path, arr)  # expect: RA502
