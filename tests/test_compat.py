"""repro.compat: version-shim resolution (both branches).

The resolvers are pure functions over module objects, so both the
0.4.x branch and the promoted-API branch are testable on any installed
JAX by handing them fakes.  The layering rule (these symbols resolve
only in compat.py) lives in the lint engine now — rules RA101/RA102 in
``repro.analysis.rules``, enforced repo-wide by tests/test_analysis.py.
"""
import types

import pytest

from repro import compat


# ---------------------------------------------------------------------------
# shard_map resolution
# ---------------------------------------------------------------------------

def test_resolve_shard_map_new_api():
    marker = object()
    fake_jax = types.SimpleNamespace(shard_map=marker)
    fn, kwarg = compat.resolve_shard_map(fake_jax)
    assert fn is marker
    assert kwarg == "check_vma"


def test_resolve_shard_map_old_api():
    marker = object()
    fake_jax = types.SimpleNamespace()                  # no jax.shard_map
    fake_exp = types.SimpleNamespace(shard_map=marker)
    fn, kwarg = compat.resolve_shard_map(fake_jax, fake_exp)
    assert fn is marker
    assert kwarg == "check_rep"


def test_resolve_shard_map_promoted_name_old_kwarg():
    """Some releases promoted jax.shard_map before renaming check_rep to
    check_vma — the kwarg must be detected from the signature, not from
    where the symbol lives."""

    def promoted(f, *, mesh, in_specs, out_specs, check_rep=True):
        pass

    fake_jax = types.SimpleNamespace(shard_map=promoted)
    fn, kwarg = compat.resolve_shard_map(fake_jax)
    assert fn is promoted
    assert kwarg == "check_rep"


def test_resolve_shard_map_new_signature():
    def new_style(f, *, mesh, in_specs, out_specs, check_vma=True):
        pass

    fake_jax = types.SimpleNamespace(shard_map=new_style)
    assert compat.resolve_shard_map(fake_jax)[1] == "check_vma"


def test_resolve_shard_map_on_installed_jax():
    import jax
    fn, kwarg = compat.resolve_shard_map(jax)
    assert callable(fn)
    assert kwarg in ("check_vma", "check_rep")


@pytest.mark.parametrize("kwarg", ["check_vma", "check_rep"])
def test_make_shard_map_translates_check_kwarg(kwarg):
    seen = {}

    def raw(f, *, mesh, in_specs, out_specs, **kwargs):
        seen.update(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                    **kwargs)
        return "ok"

    wrapped = compat.make_shard_map(raw, kwarg)
    out = wrapped(lambda: None, mesh="m", in_specs="i", out_specs="o",
                  check_vma=False)
    assert out == "ok"
    assert seen[kwarg] is False                 # renamed (or passed through)
    other = "check_rep" if kwarg == "check_vma" else "check_vma"
    assert other not in seen


def test_make_shard_map_omits_check_when_unset():
    seen = {}

    def raw(f, *, mesh, in_specs, out_specs, **kwargs):
        seen.update(kwargs)

    compat.make_shard_map(raw, "check_rep")(
        lambda: None, mesh=1, in_specs=2, out_specs=3)
    assert "check_rep" not in seen and "check_vma" not in seen


# ---------------------------------------------------------------------------
# Pallas compiler params resolution
# ---------------------------------------------------------------------------

def test_resolve_compiler_params_new_name():
    class NewCP:
        pass
    fake = types.SimpleNamespace(CompilerParams=NewCP)
    assert compat.resolve_compiler_params(fake) is NewCP


def test_resolve_compiler_params_old_name():
    class OldCP:
        pass
    fake = types.SimpleNamespace(TPUCompilerParams=OldCP)
    assert compat.resolve_compiler_params(fake) is OldCP


def test_compiler_params_usable_on_installed_jax():
    cp = compat.CompilerParams(dimension_semantics=("parallel",))
    assert cp.dimension_semantics == ("parallel",)


# ---------------------------------------------------------------------------
# Pallas scalar-prefetch grid spec resolution
# ---------------------------------------------------------------------------

def test_resolve_prefetch_grid_spec_historical_name():
    class GS:
        pass
    fake = types.SimpleNamespace(PrefetchScalarGridSpec=GS)
    assert compat.resolve_prefetch_grid_spec(fake) is GS


def test_resolve_prefetch_grid_spec_missing_raises():
    with pytest.raises(ImportError):
        compat.resolve_prefetch_grid_spec(types.SimpleNamespace())


def test_prefetch_grid_spec_usable_on_installed_jax():
    gs = compat.PrefetchScalarGridSpec(num_scalar_prefetch=1, grid=(2,))
    assert gs.grid == (2,)
