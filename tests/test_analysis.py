"""repro.analysis regression tests: rule catalog, fixtures, CLI, auditor.

Layer 1 (lint) tests run in-process — the engine is pure ``ast`` and
never imports jax.  Layer 2 (auditor) tests follow the repo convention
of one subprocess per multi-device scenario with
XLA_FLAGS=--xla_force_host_platform_device_count=N.

The repo-clean test (``test_repo_src_is_strict_clean``) is the tier-1
gate: ``src/repro`` must hold zero findings at HEAD — fix the code or
carry a ``# noqa: RAxxx`` with the rule id, never loosen a rule to pass.
"""
import glob
import json
import os
import re
import shutil
import subprocess
import sys
import textwrap

import pytest

from repro.analysis import Severity, all_rules, lint_paths

ROOT = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))
SRC = os.path.join(ROOT, "src", "repro")
FIXTURES = os.path.join(ROOT, "tests", "fixtures", "analysis")

_ENV16 = dict(os.environ,
              XLA_FLAGS="--xla_force_host_platform_device_count=16",
              PYTHONPATH=os.path.join(ROOT, "src")
              + os.pathsep + os.environ.get("PYTHONPATH", ""))

_EXPECT_RE = re.compile(r"#\s*expect:\s*([A-Z]+\d+(?:\s*,\s*[A-Z]+\d+)*)")


def _run(code: str, env=_ENV16):
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def _expected_lines(path: str, rule_id: str):
    """Lines carrying a ``# expect: <rule_id>`` marker."""
    out = set()
    with open(path) as f:
        for i, line in enumerate(f.read().splitlines(), 1):
            m = _EXPECT_RE.search(line)
            if m and rule_id in {s.strip() for s in m.group(1).split(",")}:
                out.add(i)
    return out


def _fixture_files(rule_id: str, kind: str):
    return sorted(glob.glob(
        os.path.join(FIXTURES, "**", f"{rule_id.lower()}_{kind}*.py"),
        recursive=True))


# ---------------------------------------------------------------------------
# catalog sanity + per-rule fixtures
# ---------------------------------------------------------------------------

def test_rule_catalog_sane():
    """>= 8 distinct rules, unique ids, metadata filled in, and a
    positive + negative fixture pair for every rule."""
    rules = all_rules()
    ids = [r.rule_id for r in rules]
    assert len(rules) >= 8
    assert len(set(ids)) == len(ids)
    for cls in rules:
        assert re.fullmatch(r"RA\d{3}", cls.rule_id), cls
        assert cls.severity in (Severity.ERROR, Severity.WARNING)
        assert cls.title and cls.rationale, f"{cls.rule_id} missing metadata"
        assert _fixture_files(cls.rule_id, "pos"), \
            f"{cls.rule_id}: no positive fixture"
        assert _fixture_files(cls.rule_id, "neg"), \
            f"{cls.rule_id}: no negative fixture"


@pytest.mark.parametrize("rule_id", [r.rule_id for r in all_rules()])
def test_rule_fixtures(rule_id):
    """Positives flag exactly the ``# expect`` lines; negatives (near-miss
    code) stay clean."""
    for path in _fixture_files(rule_id, "pos"):
        want = _expected_lines(path, rule_id)
        assert want, f"{path}: positive fixture has no expect markers"
        vs, _ = lint_paths([path], select=[rule_id])
        got = {v.line for v in vs}
        assert got == want, (f"{rule_id} on {os.path.basename(path)}: "
                             f"flagged {sorted(got)}, marked {sorted(want)}")
    for path in _fixture_files(rule_id, "neg"):
        vs, _ = lint_paths([path], select=[rule_id])
        assert not vs, (f"{rule_id} false positives on "
                        f"{os.path.basename(path)}: {[str(v) for v in vs]}")


def test_repo_src_is_strict_clean():
    """Tier-1 gate: zero findings (warnings included) over src/repro."""
    violations, files = lint_paths([SRC])
    assert files > 50, f"suspiciously few files linted: {files}"
    assert not violations, "src/repro must lint clean:\n" + \
        "\n".join(str(v) for v in violations)


def test_noqa_requires_rule_id_scoping(tmp_path):
    """``# noqa: RA205`` silences exactly that rule on that line."""
    bad = tmp_path / "hot64.py"
    bad.write_text(textwrap.dedent("""\
        '''tmp module.'''
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(x):
            return x.astype(jnp.float64)  # noqa: RA205
    """))
    vs, _ = lint_paths([str(bad)])
    assert not vs, [str(v) for v in vs]
    # a different rule id on the comment must NOT silence RA205
    bad.write_text(bad.read_text().replace("RA205", "RA201"))
    vs, _ = lint_paths([str(bad)], select=["RA205"])
    assert len(vs) == 1 and vs[0].rule_id == "RA205"


def test_hot_region_force_comment(tmp_path):
    """`# analysis: hot` pulls a dynamically-dispatched fn into scope."""
    mod = tmp_path / "dyn.py"
    mod.write_text(textwrap.dedent("""\
        '''tmp module.'''
        import numpy as np

        def cold(x):
            return np.mean(x)

        def dispatched(x):  # analysis: hot
            return np.mean(x)
    """))
    vs, _ = lint_paths([str(mod)], select=["RA202"])
    assert len(vs) == 1
    assert "dispatched" in vs[0].message


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_src_strict_exits_zero():
    """Acceptance: `python -m repro.analysis src --strict` is clean at
    HEAD (the console entry point runs the same main)."""
    r = subprocess.run([sys.executable, "-m", "repro.analysis", "src",
                        "--strict"], cwd=ROOT, env=_ENV16,
                       capture_output=True, text=True, timeout=120)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "clean" in r.stdout


def test_cli_flags_injected_violation(tmp_path):
    """A host sync dropped into a linted file turns the CLI red, and the
    --json report carries the machine-readable finding."""
    bad = tmp_path / "leaky.py"
    bad.write_text(textwrap.dedent("""\
        '''tmp module.'''
        import jax
        import numpy as np

        @jax.jit
        def f(x):
            return np.asarray(x) * 2
    """))
    out = tmp_path / "report.json"
    r = subprocess.run([sys.executable, "-m", "repro.analysis", str(bad),
                        "--json", str(out)], cwd=ROOT, env=_ENV16,
                       capture_output=True, text=True, timeout=120)
    assert r.returncode == 1, r.stdout
    report = json.loads(out.read_text())
    assert report["files_checked"] == 1
    ids = {v["rule_id"] for v in report["violations"]}
    assert "RA201" in ids, report


def test_cli_list_rules():
    r = subprocess.run([sys.executable, "-m", "repro.analysis",
                        "--list-rules"], cwd=ROOT, env=_ENV16,
                       capture_output=True, text=True, timeout=60)
    assert r.returncode == 0
    for cls in all_rules():
        assert cls.rule_id in r.stdout


def test_ruff_config_matches_if_available():
    """pyproject carries the ruff config; run it when the binary exists
    (not in the pinned container — config still must parse)."""
    with open(os.path.join(ROOT, "pyproject.toml")) as f:
        cfg = f.read()
    assert "[tool.ruff]" in cfg and "tests/fixtures" in cfg
    ruff = shutil.which("ruff")
    if ruff is None:
        pytest.skip("ruff not installed in this environment")
    r = subprocess.run([ruff, "check", "src"], cwd=ROOT,
                       capture_output=True, text=True, timeout=120)
    assert r.returncode == 0, r.stdout + r.stderr


# ---------------------------------------------------------------------------
# Layer 2: jaxpr dispatch auditor (subprocess, forced host devices)
# ---------------------------------------------------------------------------

AUDIT_PRELUDE = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core.api import SparseAllreduce
from repro.analysis.auditor import (audit_callable, audit_engine,
                                    audit_reduce, collective_counts,
                                    trace_jaxpr)

def configured(degs, r, seed=None):
    m = int(np.prod(degs))
    rng = np.random.RandomState(seed if seed is not None else m)
    out_idx = [rng.choice(4096, rng.randint(5, 16), replace=False)
               .astype(np.uint32) for _ in range(m)]
    in_idx = [rng.choice(4096, rng.randint(5, 16), replace=False)
              .astype(np.uint32) for _ in range(m)]
    ar = SparseAllreduce(m, degs, backend="device", replication=r,
                         mesh=jax.make_mesh((m * r,), ("d",)), seed=m)
    ar.config(out_idx, in_idx)
    return ar
"""

REDUCE_AUDIT_CODE = AUDIT_PRELUDE + r"""
# acceptance sweep: collective count == 2 * plan depth for every degree
# schedule x replication (r=2 prepends the replica-merge stage: depth+1)
for degs in [(4,), (2, 2), (4, 2)]:
    for r in (1, 2):
        ar = configured(degs, r)
        planned, _ = ar.planned_parts()
        want_depth = len(degs) + (1 if r > 1 else 0)
        assert planned.depth == want_depth, (degs, r, planned.depth)
        rep = audit_reduce(ar)
        assert rep.ok, rep.to_dict()
        d = {c.check_id: c for c in rep.checks}
        c = d["collectives_equal_plan_depth"]
        assert c.expected == 2 * want_depth == c.actual, (degs, r, c)
print("REDUCE_AUDIT_OK")
"""


@pytest.mark.slow
def test_audit_reduce_collectives_equal_plan_depth():
    """Traced all_to_all count == 2*depth across degrees x replication."""
    assert "REDUCE_AUDIT_OK" in _run(REDUCE_AUDIT_CODE)


REDUCE_INJECT_CODE = AUDIT_PRELUDE + r"""
# injection: a second reduce doubles the collectives -> count check fails
ar = configured((2, 2), 1)
planned, _ = ar.planned_parts()
meta = ar.staging_metadata()
f = ar.reduce_fn

def doubled(v):
    return f(v) + f(v * 2.0)

rep = audit_callable("doubled-reduce", doubled,
                     jnp.zeros((meta["num_physical"], meta["u_cap"]),
                               jnp.float32),
                     expected_all_to_all=2 * planned.depth)
bad = {c.check_id: c for c in rep.checks}["all_to_all_count"]
assert not bad.ok and bad.actual == 4 * planned.depth, bad

# injection: a host callback on the hot path -> forbidden-primitive check
def leaky(v):
    jax.debug.callback(lambda x: None, v[0, 0])
    return f(v)

rep2 = audit_callable("leaky-reduce", leaky,
                      jnp.zeros((meta["num_physical"], meta["u_cap"]),
                                jnp.float32))
forb = {c.check_id: c for c in rep2.checks}["no_forbidden_primitives"]
assert not forb.ok and "debug_callback" in forb.actual, forb
print("REDUCE_INJECT_OK")
"""


@pytest.mark.slow
def test_audit_catches_injected_extra_collective_and_callback():
    """Acceptance: deliberately injecting an extra collective or a host
    callback makes the corresponding check fail."""
    assert "REDUCE_INJECT_OK" in _run(REDUCE_INJECT_CODE)


ENGINE_AUDIT_CODE = r"""
import numpy as np, jax
from repro.data.pipeline import powerlaw_graph
from repro.graph.pagerank import build_partitions, make_pagerank_engine
from repro.analysis.auditor import audit_engine, collective_counts, \
    iter_eqns, trace_jaxpr

edges = powerlaw_graph(300, 1200, seed=1)
parts = build_partitions(edges, 300, 8)
engine, extras, p0 = make_pagerank_engine(
    parts, 300, degrees=(4, 2), mesh=jax.make_mesh((8,), ("d",)))

for k in (1, 7):
    rep = audit_engine(engine, k, p0, extras)
    assert rep.ok, rep.to_dict()

# negative: k python-loop single-round dispatches instead of one fused
# scan -> the one-dispatch and per-round checks both fail
class LoopyEngine:
    '''Anti-pattern shim: re-dispatches a 1-round run k times.'''
    def __init__(self, e):
        self.e = e
        self.planned = e.planned
    def routing_args(self):
        return self.e.routing_args()
    def run_fn(self, k, collect="last"):
        one = self.e.run_fn(1, collect)
        def loopy(state, extras, *routing):
            out = traj = None
            for _ in range(k):
                state, out, traj = one(state, extras, *routing)
            return state, out, traj
        return loopy

bad = audit_engine(LoopyEngine(engine), 3, p0, extras)
d = {c.check_id: c for c in bad.checks}
assert not d["one_scan_dispatch"].ok and \
    d["one_scan_dispatch"].actual == 3, d["one_scan_dispatch"]
assert not d["per_round_collectives_equal_plan_depth"].ok
print("ENGINE_AUDIT_OK")
"""


@pytest.mark.slow
def test_audit_engine_one_dispatch_per_run():
    """k-round engine run is one scan with all collectives inside; an
    unfused k-loop fails the dispatch-count check."""
    assert "ENGINE_AUDIT_OK" in _run(ENGINE_AUDIT_CODE)


TRAIN_AUDIT_CODE = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.configs import ARCHS, get_config
from repro.models import transformer as T
from repro.optim.adamw import AdamW
from repro.train.step import make_train_step
from repro.analysis.auditor import audit_callable

cfg = get_config(sorted(ARCHS)[0]).reduced()
mesh = jax.make_mesh((4, 1), ("data", "model"))
step, _ = make_train_step(cfg, mesh, sync="sparse", donate=False)
params = T.init_params(cfg, tp=1, seed=0)
opt = AdamW().init(params)
B, S = 4, 16
rng = np.random.RandomState(0)
batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab, (B, S)), jnp.int32),
         "labels": jnp.asarray(rng.randint(0, cfg.vocab, (B, S)), jnp.int32)}
if cfg.img_tokens:
    batch["img_embeds"] = jnp.asarray(
        rng.randn(B, cfg.img_tokens, cfg.d_model), jnp.float32)
if cfg.enc_layers:
    batch["enc_frames"] = jnp.asarray(
        rng.randn(B, cfg.enc_seq, cfg.d_model), jnp.float32)

rep = audit_callable("make_train_step[sync=sparse]", step,
                     params, opt, batch)
assert rep.ok, rep.to_dict()
census = {c.check_id: c for c in rep.checks}["collective_census"]
assert sum(census.actual.values()) > 0, census  # sync really traced
print("TRAIN_AUDIT_OK", census.actual)
"""


@pytest.mark.slow
def test_audit_train_step_hot_path_clean():
    """A real make_train_step trace has no callbacks/transfers/f64 and
    dtype-stable scan carries."""
    out = _run(TRAIN_AUDIT_CODE)
    assert "TRAIN_AUDIT_OK" in out
