"""Chunked SSM formulations vs naive per-step recurrences.

The train-time mamba/mLSTM paths use chunked scans (TPU-friendly, SPerf);
these tests pin them against literal step-by-step recurrences, with
sequence lengths spanning multiple chunks (inter-chunk handoff is where
the algebra can silently break).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import ssm as SSM
from repro.models.ssm import (_chunked_selective_scan, mamba_params,
                              mlstm_params)


def test_chunked_selective_scan_vs_naive():
    rng = np.random.RandomState(0)
    B, T, dil, n = 2, SSM.SCAN_CHUNK * 2, 8, 4      # spans 2 chunks
    dt = jnp.asarray(np.abs(rng.randn(B, T, dil)) * 0.1, jnp.float32)
    xi = jnp.asarray(rng.randn(B, T, dil), jnp.float32)
    Bm = jnp.asarray(rng.randn(B, T, n), jnp.float32)
    Cm = jnp.asarray(rng.randn(B, T, n), jnp.float32)
    A = jnp.asarray(-np.abs(rng.randn(dil, n)), jnp.float32)

    ys, h_fin = _chunked_selective_scan(dt, xi, Bm, Cm, A)

    # naive recurrence
    h = np.zeros((B, dil, n))
    ys_ref = np.zeros((B, T, dil))
    dtn, xin, Bn, Cn, An = (np.asarray(x, np.float64)
                            for x in (dt, xi, Bm, Cm, A))
    for t in range(T):
        a = np.exp(dtn[:, t][..., None] * An)
        b = (dtn[:, t] * xin[:, t])[..., None] * Bn[:, t][:, None, :]
        h = h * a + b
        ys_ref[:, t] = np.einsum("bcn,bn->bc", h, Cn[:, t])
    np.testing.assert_allclose(np.asarray(ys), ys_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_fin), h, rtol=1e-4, atol=1e-4)


def test_mamba_train_vs_stepwise_decode(monkeypatch):
    """Full-sequence mamba_train output == feeding tokens one-by-one through
    mamba_decode (exercises conv tail, gates, and the chunked scan across
    3 chunk boundaries — chunk size shrunk for the test)."""
    monkeypatch.setattr(SSM, "SCAN_CHUNK", 16)
    cfg = get_config("jamba-1.5-large-398b").reduced()
    import dataclasses
    cfg = dataclasses.replace(cfg, d_model=64)
    key = jax.random.PRNGKey(0)
    p = mamba_params(key, cfg, tp=1, dtype=jnp.float32)
    rng = np.random.RandomState(1)
    B, T = 2, 48

    # dummy axis context: run under a 1-device shard_map-free trace by
    # wrapping psum axes with a single-device mesh
    from repro.compat import shard_map
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1,), ("model",))
    x = jnp.asarray(rng.randn(B, T, cfg.d_model), jnp.float32)

    def full(p, x):
        return SSM.mamba_train(p, x, cfg, "model", 1)

    def steps(p, x):
        st = SSM.mamba_init_state(B, cfg, 1, jnp.float32)
        outs = []
        for t in range(T):
            y, st = SSM.mamba_decode(p, x[:, t:t + 1], st, cfg, "model", 1)
            outs.append(y)
        return jnp.concatenate(outs, axis=1)

    f1 = shard_map(full, mesh=mesh, in_specs=(P(), P()), out_specs=P(),
                   check_vma=False)
    f2 = shard_map(steps, mesh=mesh, in_specs=(P(), P()), out_specs=P(),
                   check_vma=False)
    y1, y2 = np.asarray(f1(p, x)), np.asarray(f2(p, x))
    np.testing.assert_allclose(y1, y2, rtol=2e-3, atol=2e-3)


def test_mlstm_train_vs_stepwise_decode(monkeypatch):
    """Chunked mLSTM == step-by-step decode recurrence (modulo the running
    max-stabilizer, which rescales numerator and denominator identically);
    chunk size shrunk so the sequence spans multiple chunk handoffs."""
    monkeypatch.setattr(SSM, "CHUNK", 16)
    cfg = get_config("xlstm-1.3b").reduced()
    import dataclasses
    cfg = dataclasses.replace(cfg, d_model=64, n_heads=2, head_dim=32)
    key = jax.random.PRNGKey(0)
    p = mlstm_params(key, cfg, tp=1, dtype=jnp.float32)
    rng = np.random.RandomState(2)
    B, T = 2, 48

    from repro.compat import shard_map
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1,), ("model",))
    x = jnp.asarray(rng.randn(B, T, cfg.d_model) * 0.3, jnp.float32)

    def full(p, x):
        return SSM.mlstm_train(p, x, cfg, "model", 1)

    def steps(p, x):
        st = SSM.mlstm_init_state(B, cfg, 1)
        outs = []
        for t in range(T):
            y, st = SSM.mlstm_decode(p, x[:, t:t + 1], st, cfg, "model", 1)
            outs.append(y)
        return jnp.concatenate(outs, axis=1)

    f1 = shard_map(full, mesh=mesh, in_specs=(P(), P()), out_specs=P(),
                   check_vma=False)
    f2 = shard_map(steps, mesh=mesh, in_specs=(P(), P()), out_specs=P(),
                   check_vma=False)
    y1, y2 = np.asarray(f1(p, x)), np.asarray(f2(p, x))
    np.testing.assert_allclose(y1, y2, rtol=5e-3, atol=5e-3)
