"""Per-arch REDUCED smoke tests: one forward/train step + prefill/decode on
CPU, asserting output shapes and finiteness (full configs only via dry-run).
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, get_config, pair_plan, all_pairs
from repro.models import transformer as T
from repro.optim.adamw import AdamW
from repro.train.step import (make_decode_step, make_prefill_step,
                              make_train_step, mesh_ctx)

warnings.filterwarnings("ignore")
B, S, MAX = 2, 32, 48


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def _batch(cfg, rng, with_labels=True):
    out = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab, (B, S)), jnp.int32)}
    if with_labels:
        out["labels"] = jnp.asarray(rng.randint(0, cfg.vocab, (B, S)),
                                    jnp.int32)
    if cfg.img_tokens:
        out["img_embeds"] = jnp.asarray(
            rng.randn(B, cfg.img_tokens, cfg.d_model), jnp.float32)
    if cfg.enc_layers:
        out["enc_frames"] = jnp.asarray(
            rng.randn(B, cfg.enc_seq, cfg.d_model), jnp.float32)
    return out


@pytest.mark.parametrize("arch", list(ARCHS))
def test_reduced_train_step(arch, mesh):
    cfg = get_config(arch).reduced()
    assert cfg.n_layers <= 2 * len(cfg.pattern) and cfg.d_model <= 512
    assert (cfg.n_experts or 0) <= 4
    rng = np.random.RandomState(0)
    step, _ = make_train_step(cfg, mesh, donate=False)
    params = T.init_params(cfg, tp=1, seed=0)
    opt = AdamW().init(params)
    p1, o1, m1 = step(params, opt, _batch(cfg, rng))
    assert np.isfinite(float(m1["loss"]))
    assert float(m1["loss"]) > 0
    p2, o2, m2 = step(p1, o1, _batch(cfg, rng))
    assert np.isfinite(float(m2["loss"]))
    # params actually changed
    delta = sum(float(jnp.sum(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p1)))
    assert delta > 0


@pytest.mark.parametrize("arch", list(ARCHS))
def test_reduced_prefill_decode(arch, mesh):
    cfg = get_config(arch).reduced()
    mc = mesh_ctx(mesh)
    rng = np.random.RandomState(1)
    params = T.init_params(cfg, tp=1, seed=0)
    prefill, _ = make_prefill_step(cfg, mesh, max_seq=MAX)
    logits, cache = prefill(params, _batch(cfg, rng, with_labels=False))
    vp = T.padded_vocab(cfg, 1)
    assert logits.shape == (B, vp)
    assert np.all(np.isfinite(np.asarray(logits)))

    decode, _ = make_decode_step(cfg, mesh)
    extra = ()
    if cfg.enc_layers:
        from repro.compat import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.models.sharding import full_model_pspec
        ax = mc.axis_ctx(cfg)
        frames = _batch(cfg, rng, with_labels=False)["enc_frames"]
        ccfn = shard_map(
            lambda p, f: T.build_cross_cache(p, f, cfg, ax), mesh=mesh,
            in_specs=(full_model_pspec(cfg, mc.tp, mc.dp_axes), P("data")),
            out_specs=(P(None, "data", None, "model", None),
                       P(None, "data", None, "model", None)),
            check_vma=False)
        extra = (ccfn(params, frames),)
    tok = jnp.asarray(np.argmax(np.asarray(logits)[:, :cfg.vocab], -1),
                      jnp.int32)
    pos = jnp.full((B,), S + (cfg.img_tokens or 0), jnp.int32)
    lg, cache2 = decode(params, tok, pos, cache, *extra)
    assert lg.shape == (B, vp)
    assert np.all(np.isfinite(np.asarray(lg)))
    # cache must have been written
    changed = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                        - b.astype(jnp.float32))))
                  for a, b in zip(jax.tree.leaves(cache),
                                  jax.tree.leaves(cache2)))
    assert changed > 0


def test_pair_plan_covers_40():
    pairs = all_pairs()
    assert len(pairs) == 40
    skips = [(a, s) for a, s, v in pairs if v is None]
    assert skips == [("internvl2-26b", "long_500k"),
                     ("whisper-base", "long_500k")]
    swa = [a for a, s, v in pairs if v == "swa"]
    assert "command-r-plus-104b" in swa and "qwen1.5-0.5b" in swa


def test_param_counts_in_expected_range():
    expect = {"starcoder2-15b": (13e9, 22e9),
              "jamba-1.5-large-398b": (300e9, 480e9),
              "gemma3-12b": (8e9, 16e9),
              "qwen1.5-0.5b": (0.4e9, 0.8e9),
              "arctic-480b": (380e9, 560e9),
              "command-r-plus-104b": (85e9, 135e9),
              "xlstm-1.3b": (0.8e9, 2.0e9),
              "whisper-base": (0.05e9, 0.12e9)}
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, f"{arch}: {n/1e9:.1f}B outside [{lo/1e9},{hi/1e9}]"
