"""Resilience layer (PR 7): atomic checkpoints, fault classification,
retry/backoff, replan-over-survivors parity, engine remapping, and the
exact-resume soak harness (subprocess: 16 forced host devices)."""
import json
import math
import os
import subprocess
import sys

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import store
from repro.core.faults import FailureSchedule, make_schedule
from repro.core.replication import (expected_tolerated_failures,
                                    lost_logical_shards, replica_groups,
                                    surviving_logical_shards)
from repro.resilience import (GROUP_LOST, NO_FAULT, QUORUM_LOST,
                              REPLICA_ABSORBED, DegradedPolicy, classify,
                              retry_until_alive)

_ENV = dict(os.environ,
            XLA_FLAGS="--xla_force_host_platform_device_count=16",
            PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src")
            + os.pathsep + os.environ.get("PYTHONPATH", ""))


def _run(code: str):
    r = subprocess.run([sys.executable, "-c", code], env=_ENV,
                       capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


# ---------------------------------------------------------------------------
# atomic checkpoint store
# ---------------------------------------------------------------------------

def test_save_then_load_roundtrip(tmp_path):
    tree = {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": {"c": np.ones(4, np.int32)}}
    base = str(tmp_path / "ckpt-1")
    store.save(base, tree, meta={"step": 1})
    arrays, meta = store.load_flat(base)
    assert meta == {"step": 1}
    np.testing.assert_array_equal(arrays["a"], tree["a"])
    np.testing.assert_array_equal(arrays["b/c"], tree["b"]["c"])


def test_save_leaves_no_tempfiles(tmp_path):
    store.save(str(tmp_path / "ckpt-2"), {"x": np.zeros(3)},
               meta={"step": 2})
    leftovers = [n for n in os.listdir(tmp_path) if n.endswith(".tmp")]
    assert not leftovers
    assert sorted(os.listdir(tmp_path)) == ["ckpt-2.meta.json", "ckpt-2.npz"]


def test_truncated_checkpoint_raises_checkpoint_error(tmp_path):
    """Crash-mid-save emulation: a truncated .npz surfaces as a clear
    CheckpointError, not a cryptic zipfile traceback."""
    base = str(tmp_path / "ckpt-3")
    store.save(base, {"x": np.arange(1000, dtype=np.float64)})
    with open(base + ".npz", "r+b") as f:
        f.truncate(os.path.getsize(base + ".npz") // 2)
    with pytest.raises(store.CheckpointError, match="corrupt or truncated"):
        store.load_flat(base)
    with pytest.raises(store.CheckpointError):
        store.load(base, {"x": np.zeros(1000)})


def test_missing_checkpoint_raises_filenotfound(tmp_path):
    with pytest.raises(FileNotFoundError):
        store.load_flat(str(tmp_path / "nope"))


def test_corrupt_sidecar_raises_checkpoint_error(tmp_path):
    base = str(tmp_path / "ckpt-4")
    store.save(base, {"x": np.zeros(2)}, meta={"step": 4})
    with open(base + ".meta.json", "w") as f:
        f.write('{"step": 4')          # truncated json
    with pytest.raises(store.CheckpointError, match="sidecar"):
        store.load_flat(base)


def test_crash_mid_save_preserves_previous_artifact(tmp_path, monkeypatch):
    """A writer dying mid-save must leave the previous complete
    checkpoint untouched and no visible partial file."""
    base = str(tmp_path / "ckpt-5")
    store.save(base, {"x": np.full(8, 1.0)}, meta={"v": 1})

    def boom(f, **kw):
        f.write(b"partial garbage")
        raise RuntimeError("disk died")

    monkeypatch.setattr(np, "savez", boom)
    with pytest.raises(RuntimeError, match="disk died"):
        store.save(base, {"x": np.full(8, 2.0)}, meta={"v": 2})
    monkeypatch.undo()
    arrays, meta = store.load_flat(base)
    np.testing.assert_array_equal(arrays["x"], np.full(8, 1.0))
    assert meta == {"v": 1}
    assert not [n for n in os.listdir(tmp_path) if n.endswith(".tmp")]


def test_list_and_latest_checkpoints(tmp_path):
    for step in (2, 10, 6):
        store.save(str(tmp_path / f"ckpt-{step}"), {"x": np.zeros(1)})
    store.save(str(tmp_path / "final"), {"x": np.zeros(1)})
    (tmp_path / "ckpt-bogus.npz").write_bytes(b"junk")
    got = store.list_checkpoints(str(tmp_path))
    assert [s for s, _ in got] == [10, 6, 2]
    step, base = store.latest_checkpoint(str(tmp_path))
    assert step == 10 and base.endswith("ckpt-10")
    assert store.latest_checkpoint(str(tmp_path / "empty")) is None


def test_soak_resume_skips_corrupt_latest(tmp_path):
    """The harness's resume scan falls back past a damaged newest
    checkpoint to the newest loadable one."""
    from repro.launch.soak import _latest_valid
    store.save(str(tmp_path / "ckpt-2"), {"x": np.full(3, 2.0)},
               meta={"step": 2})
    store.save(str(tmp_path / "ckpt-4"), {"x": np.full(3, 4.0)},
               meta={"step": 4})
    with open(tmp_path / "ckpt-4.npz", "r+b") as f:
        f.truncate(10)
    step, arrays, meta = _latest_valid(str(tmp_path))
    assert step == 2 and meta["step"] == 2
    np.testing.assert_array_equal(arrays["x"], np.full(3, 2.0))


# ---------------------------------------------------------------------------
# cascade schedules + rack validation
# ---------------------------------------------------------------------------

def test_cascade_accumulates_and_never_heals():
    s = make_schedule("cascade", 32, 5, seed=3)
    prev = set()
    for t in range(10):
        dead = s.dead_at(t)
        assert prev <= dead, f"cascade healed at step {t}"
        assert len(dead) == min(5 * (t + 1), 32)
        prev = dead
    assert s.dead_at(4) == s.dead_at(4)   # deterministic


@given(st.integers(2, 40), st.integers(1, 6), st.integers(0, 1000))
@settings(max_examples=40, deadline=None)
def test_cascade_monotone_property(m, f, seed):
    s = FailureSchedule(kind="cascade", m_physical=m,
                        num_failures=min(f, m), seed=seed)
    steps = [s.dead_at(t) for t in range(8)]
    for a, b in zip(steps, steps[1:]):
        assert a <= b
    assert steps[-1] == s.dead_at(7)


def test_impossible_rack_schedule_raises_at_construction():
    with pytest.raises(ValueError, match="impossible rack schedule"):
        FailureSchedule(kind="rack", m_physical=8, num_failures=2,
                        rack_size=9)
    # partial tail racks stay legal (rack 4 over 10 devices)
    FailureSchedule(kind="rack", m_physical=10, num_failures=4, rack_size=4)


# ---------------------------------------------------------------------------
# classification
# ---------------------------------------------------------------------------

def test_classify_severities():
    ev = classify(8, 2, None)
    assert ev.klass == NO_FAULT and ev.survivors == (0, 1, 2, 3)
    ev = classify(8, 2, {5})                       # shard 1 keeps replica 1
    assert ev.klass == REPLICA_ABSORBED and ev.lost == ()
    ev = classify(8, 2, {1, 5})                    # shard 1's group gone
    assert ev.klass == GROUP_LOST
    assert ev.lost == (1,) and ev.survivors == (0, 2, 3)
    ev = classify(8, 2, {0, 4, 1, 5, 2, 6})        # 1 of 4 shards left
    assert ev.klass == QUORUM_LOST
    with pytest.raises(ValueError):
        classify(8, 2, {8})


def test_classify_quorum_frac_is_configurable():
    dead = {1, 5, 2, 6}                            # 2 of 4 shards left
    assert classify(8, 2, dead, quorum_frac=0.5).klass == GROUP_LOST
    assert classify(8, 2, dead, quorum_frac=0.75).klass == QUORUM_LOST


@given(st.integers(1, 10), st.integers(1, 3), st.integers(0, 10_000),
       st.floats(0.0, 1.0))
@settings(max_examples=80, deadline=None)
def test_classify_matches_bruteforce_groups(m_logical, r, seed, frac):
    """classify's lost/survivor split agrees with a brute-force scan of
    the §V replica layout for every (M, r, dead)."""
    m_phys = m_logical * r
    rng = np.random.RandomState(seed)
    k = int(round(frac * m_phys))
    dead = set(rng.choice(m_phys, size=k, replace=False).tolist())
    groups = replica_groups(m_phys, r)
    lost_bf = tuple(i for i, g in enumerate(groups)
                    if all(d in dead for d in g))
    ev = classify(m_phys, r, dead)
    assert ev.lost == lost_bf
    assert ev.survivors == tuple(i for i in range(m_logical)
                                 if i not in lost_bf)
    assert tuple(lost_logical_shards(m_phys, r, dead)) == lost_bf
    assert tuple(surviving_logical_shards(m_phys, r, dead)) == ev.survivors
    if not dead:
        assert ev.klass == NO_FAULT
    elif not lost_bf:
        assert ev.klass == REPLICA_ABSORBED
    elif len(ev.survivors) < max(1, math.ceil(0.5 * m_logical)):
        assert ev.klass == QUORUM_LOST
    else:
        assert ev.klass == GROUP_LOST


def test_tolerated_failures_bound_survives_shrink():
    """Satellite (c): the §V birthday bound is monotone in M, so a
    shrunken (M', r) fleet never promises more tolerated failures than
    the original (M, r) fleet did."""
    for r in (1, 2, 3):
        for m2, m in ((1, 4), (2, 4), (3, 4), (4, 8), (6, 8)):
            assert expected_tolerated_failures(m2, r) <= \
                expected_tolerated_failures(m, r) + 1e-12


# ---------------------------------------------------------------------------
# retry / backoff + policy validation
# ---------------------------------------------------------------------------

def test_retry_backoff_heals_transient_fault():
    """Probe sees a lost group on attempts 0-1, healed (absorbed) on 2:
    two exponential-backoff sleeps, final event is the healed one."""
    seen = [{1, 5}, {1, 5}, {5}]
    sleeps = []
    pol = DegradedPolicy(max_retries=3, backoff_s=0.05, backoff_mult=2.0)
    ev, evs = retry_until_alive(lambda a: seen[a], pol, 8, 2,
                                sleep=sleeps.append)
    assert ev.klass == REPLICA_ABSORBED and ev.attempt == 2
    assert [e.klass for e in evs] == [GROUP_LOST, GROUP_LOST,
                                      REPLICA_ABSORBED]
    assert sleeps == [0.05, 0.1]


def test_retry_exhaustion_returns_last_group_lost():
    sleeps = []
    pol = DegradedPolicy(max_retries=3, backoff_s=0.05, backoff_mult=2.0)
    ev, evs = retry_until_alive(lambda a: {1, 5}, pol, 8, 2,
                                sleep=sleeps.append)
    assert ev.klass == GROUP_LOST and ev.attempt == 3
    assert len(evs) == 4
    assert sleeps == [0.05, 0.1, 0.2]      # no sleep after the last probe


def test_retry_zero_retries_probes_once():
    ev, evs = retry_until_alive(lambda a: {1, 5},
                                DegradedPolicy(max_retries=0), 8, 2,
                                sleep=lambda s: pytest.fail("slept"))
    assert ev.klass == GROUP_LOST and len(evs) == 1


@pytest.mark.parametrize("kw", [{"mode": "limp"}, {"max_retries": -1},
                                {"backoff_s": -0.1}, {"backoff_mult": 0.5},
                                {"quorum_frac": 0.0},
                                {"quorum_frac": 1.5}])
def test_degraded_policy_validation(kw):
    with pytest.raises(ValueError):
        DegradedPolicy(**kw)


# ---------------------------------------------------------------------------
# replan-over-survivors == fresh reduce over survivors (subprocess sweep)
# ---------------------------------------------------------------------------

_PARITY_SWEEP = r"""
import numpy as np, jax
from repro.core.api import SparseAllreduce
from repro.resilience import ResilientAllreduce, DegradedPolicy

rng = np.random.RandomState(7)
RANGE = 300

def dyadic(n):
    return (rng.randint(-128, 129, n) / 64.0).astype(np.float32)

def make_sets(m):
    outs = [np.sort(rng.choice(RANGE, 40, replace=False)).astype(np.uint32)
            for _ in range(m)]
    ins = [np.sort(rng.choice(RANGE, 40, replace=False)).astype(np.uint32)
           for _ in range(m)]
    return outs, ins, [dyadic(len(o)) for o in outs]

# planned-path parity: degrees x replication (kill shard 1's group);
# M = prod(degrees), so (4,2) exercises an 8-shard fleet
for degrees in [(4,), (2, 2), (4, 2)]:
    M = int(np.prod(degrees))
    for r in (1, 2):
        outs, ins, vals = make_sets(M)
        dead = {1} if r == 1 else {1, 1 + M}
        ra = ResilientAllreduce(M, degrees, replication=r, dead=dead,
                                policy=DegradedPolicy(max_retries=0),
                                seed=0, expected_nnz=40, index_range=RANGE)
        ra.config(outs, ins)
        out = ra.reduce(vals)
        assert out.degraded and out.event.klass == "group-lost"
        surv = out.event.survivors
        assert surv == tuple(i for i in range(M) if i != 1)
        sh = ra.last_shrink
        m2, r2 = len(surv), sh["replication"]
        mesh = jax.sharding.Mesh(np.array(jax.devices()[: m2 * r2]),
                                 ("nodes",))
        fresh = SparseAllreduce(m2, sh["degrees"], backend="device",
                                replication=r2, seed=0, mesh=mesh,
                                expected_nnz=40, index_range=RANGE)
        fresh.config([outs[i] for i in surv], [ins[i] for i in surv])
        want = fresh.reduce([vals[i] for i in surv])
        for k, sid in enumerate(surv):
            assert np.array_equal(np.asarray(out.values[sid]),
                                  np.asarray(want[k])), (degrees, r, sid)
        print(f"PLANNED_OK degrees={degrees} r={r} "
              f"shrunk_degrees={sh['degrees']} r2={r2}")

# union-path parity: merge modes x replication
CAP, M = 24, 4
idx = np.stack([np.sort(rng.choice(RANGE, CAP, replace=False))
                for _ in range(M)]).astype(np.uint32)
uval = np.stack([dyadic(CAP) for _ in range(M)])
for merge in ("sort", "fused", "banded"):
    for r in (1, 2):
        dead = {2} if r == 1 else {2, 2 + M}
        ra = ResilientAllreduce(M, (2, 2), replication=r, dead=dead,
                                policy=DegradedPolicy(max_retries=0),
                                seed=0, merge=merge,
                                expected_nnz=CAP, index_range=RANGE)
        out = ra.union_reduce(idx, uval, 4 * CAP)
        assert out.degraded
        surv = out.event.survivors
        assert surv == (0, 1, 3)
        sh = ra.last_shrink
        m2, r2 = len(surv), sh["replication"]
        mesh = jax.sharding.Mesh(np.array(jax.devices()[: m2 * r2]),
                                 ("nodes",))
        fresh = SparseAllreduce(m2, sh["degrees"], backend="device",
                                replication=r2, seed=0, merge=merge, mesh=mesh,
                                expected_nnz=CAP, index_range=RANGE)
        oi, ov, ovf = fresh.union_reduce(idx[list(surv)], uval[list(surv)],
                                         4 * CAP)
        for k, sid in enumerate(surv):
            gi, gv, gf = out.values[sid]
            assert np.array_equal(gi, np.asarray(oi[k])), (merge, r, sid)
            assert np.array_equal(gv, np.asarray(ov[k])), (merge, r, sid)
            assert int(gf) == int(ovf[k])
        print(f"UNION_OK merge={merge} r={r}")
print("SWEEP_DONE")
"""


def test_replan_equals_fresh_reduce_over_survivors():
    """Tentpole acceptance: for every (degrees, r) and every merge mode,
    the supervisor's replan-over-survivors output is bit-for-bit equal to
    a fresh fault-free reduce configured over the same surviving set."""
    out = _run(_PARITY_SWEEP)
    assert out.count("PLANNED_OK") == 6
    assert out.count("UNION_OK") == 6
    assert "SWEEP_DONE" in out


_ABSORBED_AND_LIFECYCLE = r"""
import numpy as np
from repro.core.replication import DeadLogicalNode
from repro.resilience import (DegradedPolicy, QuorumLost,
                              ResilientAllreduce)

rng = np.random.RandomState(11)
M, RANGE = 4, 200
outs = [np.sort(rng.choice(RANGE, 30, replace=False)).astype(np.uint32)
        for _ in range(M)]
ins = [np.sort(rng.choice(RANGE, 30, replace=False)).astype(np.uint32)
       for _ in range(M)]
vals = [(rng.randint(-128, 129, len(o)) / 64.0).astype(np.float32)
        for o in outs]

# absorbed faults repair incrementally and change nothing
deads = [None, {5}, {5, 6}, {5}]      # flip-flop: repeat -> repair cache
ra = ResilientAllreduce(M, (2, 2), replication=2,
                        probe=lambda s, a: deads[s],
                        policy=DegradedPolicy(max_retries=0), seed=0,
                        expected_nnz=30, index_range=RANGE)
ra.config(outs, ins)
base = ra.reduce(vals, step=0)
for s in range(1, 4):
    out = ra.reduce(vals, step=s)
    assert not out.degraded
    assert out.event.klass == "replica-absorbed"
    for i in range(M):
        assert np.array_equal(np.asarray(out.values[i]),
                              np.asarray(base.values[i])), (s, i)
assert ra.stats["absorbed"] == 3
assert ra.base.config_cache == "repair"
print("ABSORBED_OK", ra.stats["repairs"])

# repeat shrinks to the same survivor set are cache hits
ra2 = ResilientAllreduce(M, (2, 2), replication=2,
                         probe=lambda s, a: {1, 5} if s % 2 else None,
                         policy=DegradedPolicy(max_retries=0), seed=0,
                         expected_nnz=30, index_range=RANGE)
ra2.config(outs, ins)
for s in range(4):
    ra2.reduce(vals, step=s)
assert ra2.stats["shrinks"] == 1 and ra2.stats["shrink_reuses"] == 1
print("SHRINK_CACHE_OK")

# mode="fail" re-raises; deep faults raise QuorumLost for every mode
ra3 = ResilientAllreduce(M, (2, 2), replication=2, dead={1, 5},
                         policy=DegradedPolicy(mode="fail", max_retries=0),
                         seed=0, expected_nnz=30, index_range=RANGE)
ra3.config(outs, ins)
try:
    ra3.reduce(vals)
    raise SystemExit("expected DeadLogicalNode")
except DeadLogicalNode:
    print("FAIL_MODE_OK")
ra4 = ResilientAllreduce(M, (2, 2), replication=2,
                         dead={0, 4, 1, 5, 2, 6},
                         policy=DegradedPolicy(max_retries=0), seed=0,
                         expected_nnz=30, index_range=RANGE)
ra4.config(outs, ins)
try:
    ra4.reduce(vals)
    raise SystemExit("expected QuorumLost")
except QuorumLost:
    print("QUORUM_OK")
"""


def test_absorbed_repair_shrink_cache_and_policies():
    out = _run(_ABSORBED_AND_LIFECYCLE)
    for tag in ("ABSORBED_OK", "SHRINK_CACHE_OK", "FAIL_MODE_OK",
                "QUORUM_OK"):
        assert tag in out, out


# ---------------------------------------------------------------------------
# supervised engine loop: remap mid-run is bit-identical
# ---------------------------------------------------------------------------

_ENGINE_REMAP = r"""
import numpy as np
from repro.core.faults import make_schedule
from repro.data.pipeline import powerlaw_graph
from repro.graph.pagerank import (build_partitions, make_pagerank_app,
                                  pagerank_state)
from repro.resilience import SupervisedEngineLoop

N, M = 300, 4
edges = powerlaw_graph(N, 1500, seed=0)
parts = build_partitions(edges, N, M, seed=0)
app, out_sets, in_sets = make_pagerank_app(parts, N)

def run(schedule):
    loop = SupervisedEngineLoop(out_sets, in_sets, app, degrees=(M,),
                                seed=0, schedule=schedule, fault_at=2,
                                ckpt_every=2)
    extras, p0 = pagerank_state(parts, N, loop.engine.u_cap,
                                loop.engine.uin_cap)
    state, last_q = loop.run(8, p0, extras)
    return np.asarray(state), np.asarray(last_q), loop

s0, q0, _ = run(None)
sched = make_schedule("rack", 16, 5, seed=1, rack_size=5)
s1, q1, loop = run(sched)
assert loop.remaps >= 1, "schedule never hit an engine device"
assert np.array_equal(s0, s1) and np.array_equal(q0, q1)
print("REMAP_OK remaps=", loop.remaps,
      "events=", [e.klass for e in loop.events])
"""


def test_engine_remap_bit_identical_to_uninterrupted():
    """A GraphEngine run that loses devices mid-run and remaps onto
    spares finishes bit-identical to the fault-free run."""
    out = _run(_ENGINE_REMAP)
    assert "REMAP_OK" in out, out


# ---------------------------------------------------------------------------
# soak harness: subprocess kill-and-resume, both jobs
# ---------------------------------------------------------------------------

def _soak(out_dir, *extra, expect_rc=0):
    cmd = [sys.executable, "-m", "repro.launch.soak",
           "--out", str(out_dir), *map(str, extra)]
    r = subprocess.run(cmd, env=_ENV, capture_output=True, text=True,
                       timeout=560)
    assert r.returncode == expect_rc, \
        f"rc={r.returncode}\nstdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def _assert_same_npz(a_path, b_path):
    with np.load(a_path) as a, np.load(b_path) as b:
        assert set(a.files) == set(b.files)
        for k in a.files:
            assert np.array_equal(a[k], b[k]), f"{k} differs"


_TRAIN_ARGS = ("--job", "train", "--reduced", "--steps", 6,
               "--ckpt-every", 2, "--batch", 4, "--seq", 32,
               "--dp", 4, "--replication", 2, "--seed", 0)
_RACK = ("--faults", "rack", "--fault-at", 3, "--num-failures", 5,
         "--rack-size", 5)


def test_soak_train_kill_and_resume_bit_identical(tmp_path):
    """Acceptance: a training run under a mid-run rack fault schedule,
    killed at step 4 and resumed, finishes with final params/optimizer
    state bit-identical to the uninterrupted fault-free baseline."""
    base, faulted = tmp_path / "base", tmp_path / "faulted"
    out = _soak(base, *_TRAIN_ARGS)
    assert "SOAK_OK job=train" in out
    out = _soak(faulted, *_TRAIN_ARGS, *_RACK, "--kill-at", 4,
                expect_rc=17)
    assert "KILL step 4" in out
    out = _soak(faulted, *_TRAIN_ARGS, *_RACK, "--resume")
    assert "resumed at step 4" in out and "SOAK_OK job=train" in out
    _assert_same_npz(base / "final.npz", faulted / "final.npz")
    ma = json.loads((base / "final.meta.json").read_text())
    mb = json.loads((faulted / "final.meta.json").read_text())
    assert ma["losses"] == mb["losses"]
    assert ma["events"] == [] and mb["events"] != []


def test_soak_pagerank_kill_and_resume_bit_identical(tmp_path):
    """Acceptance: same contract for the PageRank engine job."""
    args = ("--job", "pagerank", "--steps", 8, "--ckpt-every", 2,
            "--vertices", 200, "--edges", 800, "--graph-nodes", 4,
            "--seed", 0)
    base, faulted = tmp_path / "base", tmp_path / "faulted"
    _soak(base, *args)
    out = _soak(faulted, *args, *_RACK, "--kill-at", 4, expect_rc=17)
    assert "KILL round 4" in out
    out = _soak(faulted, *args, *_RACK, "--resume")
    assert "resumed at round 4" in out and "SOAK_OK job=pagerank" in out
    _assert_same_npz(base / "final.npz", faulted / "final.npz")


def test_soak_resume_refuses_fingerprint_mismatch(tmp_path):
    """Resuming with different hyperparameters than the checkpoint's
    fingerprint must abort instead of silently diverging."""
    out_dir = tmp_path / "run"
    _soak(out_dir, *_TRAIN_ARGS, "--kill-at", 2, expect_rc=17)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.soak", "--out", str(out_dir),
         *map(str, _TRAIN_ARGS), "--resume", "--lr", "0.01"],
        env=_ENV, capture_output=True, text=True, timeout=560)
    assert r.returncode != 0
    assert "fingerprint" in r.stdout + r.stderr
