"""Graph applications vs exact references (the paper's workloads)."""
import numpy as np
import pytest

from repro.data.pipeline import powerlaw_graph, random_edge_partition
from repro.graph.hadi import hadi, hadi_bitstring_reference
from repro.graph.pagerank import pagerank, pagerank_dense_reference
from repro.graph.spectral import power_iteration, power_iteration_reference


@pytest.fixture(scope="module")
def graph():
    return powerlaw_graph(500, 3000, seed=1), 500


@pytest.mark.parametrize("degrees", [(4, 2), (8,), (2, 2, 2)])
def test_pagerank_matches_dense(graph, degrees):
    edges, n = graph
    ref = pagerank_dense_reference(edges, n, iters=10)
    got, stats = pagerank(edges, n, m=8, degrees=degrees, iters=10)
    np.testing.assert_allclose(got, ref, rtol=1e-8, atol=1e-12)
    assert stats["reduce_time_s"] > 0


def test_pagerank_with_pallas_kernel(graph):
    edges, n = graph
    ref = pagerank_dense_reference(edges, n, iters=5)
    got, _ = pagerank(edges, n, m=4, degrees=(4,), iters=5, use_kernel=True)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-9)


def test_pagerank_scores_positive_mass_conserved(graph):
    """Positive scores; total mass equals the dense formulation's (dangling
    vertices leak teleport mass in the simple iteration — both sides match)."""
    edges, n = graph
    got, _ = pagerank(edges, n, m=8, iters=30)
    ref = pagerank_dense_reference(edges, n, iters=30)
    assert got.min() > 0
    assert got.sum() == pytest.approx(ref.sum(), rel=1e-9)
    assert 0.5 < got.sum() <= 1.0 + 1e-9


def test_hadi_bitstrings_exact(graph):
    edges, n = graph
    eff, curve, st = hadi(edges, n, m=8, max_hops=6, trials=4, bits=20)
    ref = hadi_bitstring_reference(edges, n, st["b0"].reshape(n, -1),
                                   st["hops_run"])
    np.testing.assert_array_equal(st["b_final"].reshape(n, -1), ref)
    assert 1 <= eff <= st["hops_run"]
    assert np.all(np.diff(curve) >= -1e-9)   # monotone growth


def test_power_iteration_matches_reference(graph):
    edges, n = graph
    lam, v, _ = power_iteration(edges, n, m=8, iters=25, seed=2)
    lam_ref, v_ref = power_iteration_reference(edges, n, iters=25, seed=2)
    assert lam == pytest.approx(lam_ref, rel=1e-6)
    cos = abs(np.dot(v, v_ref)) / (np.linalg.norm(v) * np.linalg.norm(v_ref))
    assert cos > 1 - 1e-8


def test_random_edge_partition_covers(graph):
    edges, n = graph
    parts = random_edge_partition(edges, 8, seed=0)
    assert sum(len(p) for p in parts) == len(edges)
    got = np.sort(np.concatenate(parts).view(np.int64).reshape(-1, 2), axis=0)
    want = np.sort(edges, axis=0)
    np.testing.assert_array_equal(
        np.sort(np.concatenate(parts), axis=0), np.sort(edges, axis=0))


def test_partition_sparsity_table1():
    """Table I analogue: per-partition vertex fraction shrinks with M."""
    edges = powerlaw_graph(20000, 200000, seed=3)
    for m, max_frac in [(8, 0.8), (64, 0.35)]:
        parts = random_edge_partition(edges, m, seed=0)
        fracs = [len(np.unique(p)) / 20000 for p in parts]
        assert np.mean(fracs) < max_frac
