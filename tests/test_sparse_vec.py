"""Sparse vector substrate: hash perm, coalescing, chunks, buckets."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.sparse_vec import (SENTINEL, HashPerm, SparseChunk,
                                   bucket_partition, merge_add, merge_add_np,
                                   segment_compact, sort_chunk,
                                   sort_coalesce_np, tree_sum, tree_sum_np)


@given(st.integers(0, 2**31), st.lists(st.integers(0, 2**32 - 1),
                                       min_size=1, max_size=200))
@settings(max_examples=50, deadline=None)
def test_hash_perm_bijection(seed, idx):
    perm = HashPerm.make(seed)
    a = np.array(idx, np.uint32)
    h = perm.fwd_np(a)
    np.testing.assert_array_equal(perm.inv_np(h), a)


def test_hash_perm_device_matches_numpy():
    perm = HashPerm.make(3)
    a = np.arange(1000, dtype=np.uint32) * 977
    np.testing.assert_array_equal(np.asarray(perm.fwd(jnp.asarray(a))),
                                  perm.fwd_np(a))
    np.testing.assert_array_equal(np.asarray(perm.inv(perm.fwd(jnp.asarray(a)))),
                                  a)


def test_hash_perm_balances_ranges():
    """The paper's §III-A argument: hashed power-law ids split evenly."""
    perm = HashPerm.make(0)
    # heavily clustered ids (hubs at low ids, Zipf-ish repeats)
    rng = np.random.RandomState(0)
    ids = (rng.zipf(1.3, 20000) % 5000).astype(np.uint32)
    h = perm.fwd_np(np.unique(ids)).astype(np.uint64)
    k = 8
    counts = np.histogram(h, bins=k, range=(0, 2**32))[0]
    assert counts.max() / max(counts.min(), 1) < 1.5


@given(st.lists(st.tuples(st.integers(0, 99), st.floats(-10, 10)),
                min_size=0, max_size=300))
@settings(max_examples=50, deadline=None)
def test_sort_coalesce_np(pairs):
    idx = np.array([p[0] for p in pairs], np.uint32)
    val = np.array([p[1] for p in pairs], np.float64)
    u, s = sort_coalesce_np(idx, val)
    dense = np.zeros(100)
    np.add.at(dense, idx.astype(int), val)
    assert np.array_equal(u, np.unique(idx))
    np.testing.assert_allclose(s, dense[u.astype(int)], rtol=1e-12, atol=1e-12)


def test_tree_sum_np_matches_dense():
    rng = np.random.RandomState(1)
    parts = []
    dense = np.zeros(500)
    for _ in range(13):
        i = rng.randint(0, 500, 60).astype(np.uint32)
        v = rng.randn(60)
        np.add.at(dense, i.astype(int), v)
        parts.append(sort_coalesce_np(i, v))
    u, s = tree_sum_np(parts)
    np.testing.assert_allclose(s, dense[u.astype(int)], rtol=1e-9)
    assert len(u) == np.count_nonzero(dense)


def _rand_chunk(rng, c, r=200, w=0):
    n = rng.randint(1, c + 1)
    idx = np.full(c, 0xFFFFFFFF, np.uint32)
    idx[:n] = np.sort(rng.randint(0, r, n).astype(np.uint32))
    shape = (c,) if w == 0 else (c, w)
    val = rng.randn(*shape).astype(np.float32)
    mask = idx != 0xFFFFFFFF
    val = val * (mask[:, None] if w else mask)
    return SparseChunk(idx=jnp.asarray(idx), val=jnp.asarray(val))


@pytest.mark.parametrize("w", [0, 3])
def test_segment_compact_and_to_dense(w):
    rng = np.random.RandomState(2)
    ch = _rand_chunk(rng, 64, w=w)
    out = segment_compact(ch, 64)
    d1 = np.asarray(ch.to_dense(200))
    d2 = np.asarray(out.to_dense(200))
    np.testing.assert_allclose(d1, d2, rtol=1e-6, atol=1e-6)
    idx = np.asarray(out.idx)
    valid = idx != 0xFFFFFFFF
    assert np.all(np.diff(idx[valid].astype(np.int64)) > 0)  # strictly sorted


@pytest.mark.parametrize("w", [0, 2])
def test_merge_add_matches_dense(w):
    rng = np.random.RandomState(3)
    a, b = _rand_chunk(rng, 48, w=w), _rand_chunk(rng, 80, w=w)
    out = merge_add(a, b, 160)
    np.testing.assert_allclose(
        np.asarray(out.to_dense(200)),
        np.asarray(a.to_dense(200)) + np.asarray(b.to_dense(200)),
        rtol=1e-5, atol=1e-6)


def test_tree_sum_device():
    rng = np.random.RandomState(4)
    chunks = [_rand_chunk(rng, 32) for _ in range(7)]
    out = tree_sum(chunks, out_capacity=256)
    dense = sum(np.asarray(c.to_dense(200)) for c in chunks)
    np.testing.assert_allclose(np.asarray(out.to_dense(200)), dense,
                               rtol=1e-5, atol=1e-5)


def test_bucket_partition_ranges_and_overflow():
    rng = np.random.RandomState(5)
    ch = _rand_chunk(rng, 64, r=1000)
    edges = jnp.asarray(np.array([0, 250, 500, 750, 1000], np.uint32))
    buckets, ovf = bucket_partition(ch, edges, 4, 32)
    assert int(ovf) == 0
    bi = np.asarray(buckets.idx)
    for b in range(4):
        v = bi[b][bi[b] != 0xFFFFFFFF]
        assert np.all((v >= b * 250) & (v < (b + 1) * 250))
    # total mass preserved (buckets are zero-padded to 4x32)
    bv = np.asarray(buckets.val).ravel()
    cv = np.asarray(ch.val).ravel()
    np.testing.assert_allclose(np.sort(bv[bv != 0]), np.sort(cv[cv != 0]),
                               rtol=1e-6)
    np.testing.assert_allclose(bv.sum(), cv.sum(), rtol=1e-5)


def test_bucket_partition_overflow_counted():
    idx = jnp.asarray(np.arange(16, dtype=np.uint32))  # all in bucket 0
    val = jnp.ones((16,), jnp.float32)
    edges = jnp.asarray(np.array([0, 1000, 2000], np.uint32))
    _, ovf = bucket_partition(SparseChunk(idx=idx, val=val), edges, 2, 8)
    assert int(ovf) == 8
